#!/usr/bin/env python
"""Execute every ```python fenced block in docs/*.md.

The docs job in CI runs this so FORMAT.md / ARCHITECTURE.md snippets
cannot drift from the code they document: each block is executed in its
own namespace (``PYTHONPATH=src`` supplied by the caller); any exception
fails the check. Non-runnable listings in the docs use ```text fences.
"""
from __future__ import annotations

import pathlib
import re
import sys
import traceback

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def blocks(md: str):
    for m in FENCE.finditer(md):
        yield md[: m.start()].count("\n") + 2, m.group(1)


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    failures = 0
    total = 0
    for doc in sorted((root / "docs").glob("*.md")):
        for line, code in blocks(doc.read_text()):
            total += 1
            label = f"{doc.relative_to(root)}:{line}"
            try:
                exec(compile(code, label, "exec"), {"__name__": "__docs__"})
                print(f"ok   {label}")
            except Exception:
                failures += 1
                print(f"FAIL {label}")
                traceback.print_exc()
    print(f"{total - failures}/{total} doc snippets passed")
    return 1 if failures or not total else 0


if __name__ == "__main__":
    sys.exit(main())

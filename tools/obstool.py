#!/usr/bin/env python
"""Inspect observability snapshots (``RemixDB.metrics()`` /
``KVServeEngine.metrics()`` JSON dumps, see docs/OBSERVABILITY.md).

    obstool.py show snap.json [--prom] [--filter SUBSTR]
    obstool.py diff before.json after.json [--filter SUBSTR]
    obstool.py health DATA_DIR [--scrub] [--json]

``show`` pretty-prints every sample (or the Prometheus text exposition
with ``--prom``); ``diff`` prints per-sample deltas — counter increases,
histogram count/sum growth with current p50/p99, gauge before→after.
``--filter`` keeps samples whose metric name contains the substring.
``health`` opens a store read-only-style, prints its durability summary
(``RemixDB.health()``), optionally running a full synchronous scrub
first (``--scrub`` — detection *and* self-repair, see
docs/ARCHITECTURE.md "Durability, scrubbing & repair"); exits non-zero
when the store is degraded.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "src")
)

from repro.obs.metrics import (  # noqa: E402
    _fmt_labels,
    diff_snapshots,
    load_snapshot,
    render_prometheus,
)


def _keep(snapshot: dict, substr: str | None) -> dict:
    if not substr:
        return snapshot
    return {
        "metrics": [
            s for s in snapshot.get("metrics", []) if substr in s["name"]
        ]
    }


def _show(args) -> int:
    snap = _keep(load_snapshot(args.snapshot), args.filter)
    if args.prom:
        sys.stdout.write(render_prometheus(snap))
        return 0
    for s in snap.get("metrics", []):
        head = f"{s['name']}{_fmt_labels(s['labels'])}"
        if s["type"] == "histogram":
            print(
                f"{head}  count={s['count']} sum={s['sum']:.6g} "
                f"p50={s['p50']:.6g} p95={s['p95']:.6g} "
                f"p99={s['p99']:.6g} max={s['max']:.6g}"
            )
        else:
            print(f"{head}  {s['type']}={s['value']}")
    return 0


def _diff(args) -> int:
    before = _keep(load_snapshot(args.before), args.filter)
    after = _keep(load_snapshot(args.after), args.filter)
    changed = 0
    for row in diff_snapshots(before, after)["diff"]:
        head = f"{row['name']}{_fmt_labels(row['labels'])}"
        if "status" in row:
            print(f"{head}  [{row['status']}]")
            changed += 1
        elif row["type"] == "histogram":
            if row["count_delta"] or row["sum_delta"]:
                print(
                    f"{head}  +count={row['count_delta']} "
                    f"+sum={row['sum_delta']:.6g} "
                    f"p50={row['p50']:.6g} p99={row['p99']:.6g}"
                )
                changed += 1
        elif row["type"] == "counter":
            if row["delta"]:
                print(f"{head}  +{row['delta']}")
                changed += 1
        elif row["before"] != row["after"]:
            print(f"{head}  {row['before']} -> {row['after']}")
            changed += 1
    print(f"# {changed} sample(s) changed")
    return 0


def _health(args) -> int:
    import json

    from repro.db.store import RemixDB

    db = RemixDB.open(args.data_dir)
    try:
        scrub_report = db.scrub(full=True) if args.scrub else None
        h = db.health()
    finally:
        db.close()
    if args.json:
        out = dict(health=h)
        if scrub_report is not None:
            out["scrub"] = scrub_report
        print(json.dumps(out, indent=2, sort_keys=True))
    else:
        print(f"status: {h['status']}")
        if scrub_report is not None:
            print(
                f"scrub: clean={scrub_report['clean']} "
                f"files={scrub_report['files_checked']} "
                f"bytes={scrub_report['bytes_read']} "
                f"repaired={len(scrub_report['repaired'])} "
                f"quarantined={len(scrub_report['quarantined'])}"
            )
        print(
            f"corruption_detected: {h['corruption_detected']}  "
            f"io_retries: {h['io']['retries']}  "
            f"io_giveups: {h['io']['giveups']}"
        )
        print(
            f"repair: remix_rebuilt={h['repair']['remix_rebuilt']} "
            f"tables_quarantined={h['repair']['tables_quarantined']} "
            f"quarantine_purged={h['repair']['quarantine_purged']}"
        )
        print(f"quarantine_files: {h['quarantine_files']}")
        for p in h["partitions"]:
            flag = "DEGRADED" if p["degraded"] else "ok"
            print(f"  partition lo={p['lo']} tables={p['tables']} [{flag}]")
        for s in h["unavailable"]:
            hi = "inf" if s["hi"] is None else s["hi"]
            print(
                f"  unavailable span [{s['lo']}, {hi}] "
                f"(quarantined: {', '.join(s['tables'])})"
            )
    return 0 if h["status"] == "ok" else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="obstool", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("show", help="pretty-print one snapshot")
    ps.add_argument("snapshot")
    ps.add_argument("--prom", action="store_true",
                    help="Prometheus text exposition format")
    ps.add_argument("--filter", default=None,
                    help="keep metrics whose name contains this substring")
    ps.set_defaults(fn=_show)
    pd = sub.add_parser("diff", help="delta between two snapshots")
    pd.add_argument("before")
    pd.add_argument("after")
    pd.add_argument("--filter", default=None)
    pd.set_defaults(fn=_diff)
    ph = sub.add_parser(
        "health", help="durability summary (optionally scrub first)"
    )
    ph.add_argument("data_dir")
    ph.add_argument("--scrub", action="store_true",
                    help="run a full synchronous scrub (detect + repair) "
                         "before reporting")
    ph.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ph.set_defaults(fn=_health)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

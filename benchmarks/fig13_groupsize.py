"""Fig 13: REMIX range-query performance vs group size D (8 tables)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import CSV, make_tables, qkeys, time_batched
from repro.core import query as Q
from repro.core.remix import build_remix

QBATCH = 2048


def run(csv: CSV):
    rng = np.random.default_rng(7)
    runs, keys = make_tables(8, 16384, locality="weak")
    for d in (16, 32, 64):
        remix, runset = build_remix(runs, d=d)
        qk = qkeys(rng, int(keys[-1]), QBATCH)
        for mode, label in (("binary", "full"), ("vector", "partial_vec")):
            t = time_batched(
                lambda q: Q.seek(remix, runset, q, ingroup=mode), qk
            )
            csv.emit(f"fig13_seek_{label},D={d}", t / QBATCH * 1e6, "")
        t = time_batched(lambda q: Q.scan(remix, runset, q, width=64), qk[:256])
        csv.emit(f"fig13_next50,D={d}", t / 256 * 1e6, "")
        csv.emit(
            f"fig13_index_bytes_per_key,D={d}",
            remix.storage_bytes() / max(1, int(remix.n_entries)),
            "bytes/key",
        )

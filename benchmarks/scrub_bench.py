"""Integrity scrub + repair cost (PR 8 durability tentpole).

Three numbers an operator needs before enabling the background scrubber:

- ``scrub_full``: unthrottled verification throughput — every table
  checksum granule + REMIX CRC + manifest agreement on a pinned Version
  (the ``db.scrub(full=True)`` operator call), reported as us/call with
  MB/s verified in the derived column;
- ``scrub_paced``: the same pass under a byte-budget rate limit (the
  background mode), confirming the limiter holds the configured rate;
- ``repair_remix``: the self-heal round trip — at-rest bit rot injected
  into the REMIX file, then scrub → CKB rebuild → manifest commit, with
  reads verified bit-identical afterwards.

Run directly (``python -m benchmarks.scrub_bench``) or via
``python -m benchmarks.run --only scrub``.
"""
from __future__ import annotations

import dataclasses
import glob
import os
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig
from repro.io.faults import flip_bytes

N_KEYS = 40_000
PACED_BPS = 4 << 20  # background budget: 4 MiB/s


def _cfg():
    return RemixDBConfig(
        vw=2,
        memtable_entries=1 << 30,
        compaction=CompactionConfig(table_cap=1 << 14, t_max=8),
    )


def _seed(root: str) -> RemixDB:
    db = RemixDB.open(root, _cfg())
    ks = np.arange(1, N_KEYS + 1, dtype=np.uint64) * 16
    vs = np.stack([ks & 0xFFFFFFFF, ks >> 32], 1).astype(np.uint32)
    db.put_batch(ks, vs)
    db.flush()
    return db


def run(csv: CSV) -> None:
    root = os.path.join(tempfile.mkdtemp(prefix="scrub-bench-"), "db")
    db = _seed(root)

    # full-throttle pass (warm one first: file handles, CKB memos)
    db.scrub(full=True)
    t0 = time.perf_counter()
    rep = db.scrub(full=True)
    dt = time.perf_counter() - t0
    assert rep["clean"]
    mbps = rep["bytes_read"] / max(dt, 1e-9) / 1e6
    csv.emit(
        "scrub_full", dt * 1e6,
        f"files={rep['files_checked']} mb_per_s={mbps:.1f}",
    )

    # paced pass: the limiter must hold ~PACED_BPS (one 4 MiB/s window)
    db.cfg = dataclasses.replace(db.cfg, scrub_bytes_per_sec=PACED_BPS)
    t0 = time.perf_counter()
    rep = db.scrub(full=False)
    dt = time.perf_counter() - t0
    eff = rep["bytes_read"] / max(dt, 1e-9)
    csv.emit(
        "scrub_paced", dt * 1e6,
        f"budget_mb_s={PACED_BPS / 1e6:.0f} "
        f"effective_mb_s={eff / 1e6:.1f}",
    )
    before = db.scan(0, N_KEYS + 1)
    db.close()

    # repair round trip: rot the REMIX, reopen, scrub heals it
    rx = sorted(glob.glob(os.path.join(root, "remix", "*.rmx")))[0]
    flip_bytes(rx, offset=128, nbytes=1)
    db = RemixDB.open(root, _cfg())
    t0 = time.perf_counter()
    rep = db.scrub(full=True)
    dt = time.perf_counter() - t0
    assert rep["repaired"], "repair did not trigger"
    after = db.scan(0, N_KEYS + 1)
    assert np.array_equal(before[0], after[0])
    assert np.array_equal(before[1], after[1])
    csv.emit(
        "repair_remix", dt * 1e6,
        f"findings={len(rep['findings'])} repaired={len(rep['repaired'])}",
    )
    db.close()


if __name__ == "__main__":
    run(CSV())

"""Op-layer engine: mixed typed batches vs the scalar legacy loop.

The experiment behind the v2 operation API (`repro.db.ops` +
`repro.db.executor`): a range-sharded :class:`repro.serve.KVServeEngine`
(two cold shards, one shared block cache) answers a **mixed** batch of
256 ops — point gets and range scans, spanning both shards — two ways:

- **scalar legacy loop**: one ``eng.get(k)`` / ``eng.scan(s, n)`` call
  per op, in order (the pre-v2 serving pattern);
- **submit()**: the same ops as one typed ``Batch`` through the
  planner–executor — reads grouped per shard (one pinned snapshot per
  shard per batch), point lookups vectorized into one ``get_batch``
  per shard, scans into one window call per (shard, partition).

Acceptance (asserted): bit-identical results, and mixed-batch
throughput **>= 5x** the scalar loop at batch 256. The pure-kind paths
(a gets-only / scans-only batch through ``submit()`` vs the direct
legacy batched calls) are measured as ratios so the op layer provably
adds no regression over ``BENCH_queries.json``'s vectorized paths.

Also emits ``results/BENCH_engine.json`` (CI smoke keeps it populated).

Run directly (``python -m benchmarks.engine_bench [--tiny] [--json P]``)
or via ``python -m benchmarks.run --only engine``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.db.ops import Batch, Op, OpKind
from repro.db.store import RemixDBConfig
from repro.db.wal import WAL
from repro.io.manifest import Storage
from repro.serve.engine import KVServeEngine

MIN_MIXED_SPEEDUP = 5.0  # acceptance bar at batch 256
MIN_PURE_RATIO = 0.5  # submit() vs direct batched call, safety net
MIN_METRICS_RATIO = 0.95  # metrics-on vs metrics-off throughput floor
MAX_SNAPSHOT_RATIO = 10.0  # full-memtable snapshot pin vs empty (O(1) bar)
SCAN_N = 20
SPLIT = 1 << 40  # shard boundary

# full-size shard (default) vs CI smoke shard (--tiny)
SIZES = dict(full=(8, 1 << 15), tiny=(4, 1 << 12))


def _build_shard(root: str, lo: int, r_tables: int, n_per_table: int,
                 seed: int) -> np.ndarray:
    """A committed single-partition store whose keys start at ``lo``."""
    rng = np.random.default_rng(seed)
    total = r_tables * n_per_table
    domain = np.uint64(lo) + np.arange(1, total + 1, dtype=np.uint64) * 64
    owner = rng.integers(0, r_tables, total)
    storage = Storage(root)
    names, runs, seqbase = [], [], 1
    for i in range(r_tables):
        kk = domain[owner == i]
        run = make_run(
            kk, seq=np.arange(seqbase, seqbase + len(kk), dtype=np.uint32)
        )
        seqbase += len(kk)
        runs.append(run)
        names.append(
            storage.write_table(
                np.asarray(run.keys), np.asarray(run.vals),
                np.asarray(run.seq), np.asarray(run.tomb),
            )
        )
    remix, _ = build_remix(runs, d=32)
    xname = storage.write_remix(remix)
    wal = WAL(storage.wal_path())
    storage.commit(
        dict(seq=seqbase, vw=2, d=32,
             partitions=[dict(lo=int(lo), tables=names, remix=xname)],
             wal=wal.save_state())
    )
    return domain


def _mixed_ops(domains: list[np.ndarray], rng, q: int) -> list[Op]:
    """3/4 gets + 1/4 scans, interleaved, spanning every shard."""
    ops: list[Op] = []
    for i in range(q):
        dom = domains[i % len(domains)]
        if i % 4 == 3:
            ops.append(Op.scan(int(rng.choice(dom)), SCAN_N))
        else:
            ops.append(Op.get(int(rng.choice(dom))))
    return ops


def _scalar_loop(eng: KVServeEngine, ops: list[Op]) -> list:
    out = []
    for op in ops:
        if op.kind is OpKind.SCAN:
            out.append(eng.scan(op.start, op.n))
        else:
            out.append(eng.get(op.key))
    return out


def _check_equal(ops, legacy, res) -> None:
    for op, ref, r in zip(ops, legacy, res.results):
        assert r.ok, f"{op} -> {r.status}"
        if op.kind is OpKind.SCAN:
            kr, vr = ref
            if not (np.array_equal(kr, r.keys)
                    and np.array_equal(vr, r.vals)):
                raise AssertionError(f"scan mismatch for {op}")
        else:
            a = ref is not None
            b = bool(r.found)
            if a != b or (a and not np.array_equal(ref, r.value)):
                raise AssertionError(f"get mismatch for {op}")


def _throughput(fn, items: list) -> float:
    t0 = time.perf_counter()
    n = 0
    for it in items:
        fn(it)
        n += len(it.ops) if isinstance(it, Batch) else len(it)
    return n / (time.perf_counter() - t0)


def bench_mixed(eng, domains, csv: CSV, q: int = 256) -> float:
    rng = np.random.default_rng(29)
    warm = [_mixed_ops(domains, rng, q) for _ in range(4)]
    timed = [_mixed_ops(domains, rng, q) for _ in range(4)]
    for ops in warm:  # equivalence + working-set warmup for both paths
        legacy = _scalar_loop(eng, ops)
        res = eng.submit(Batch(list(ops)), sync=True).result()
        _check_equal(ops, legacy, res)
    tput_s = _throughput(lambda ops: _scalar_loop(eng, ops), timed)
    tput_b = _throughput(
        lambda ops: eng.submit(Batch(list(ops)), sync=True).result(), timed
    )
    speedup = tput_b / max(tput_s, 1e-9)
    csv.emit("engine_mixed_scalar", 1e6 * q / tput_s,
             f"q={q};ops_per_s={tput_s:.0f}")
    csv.emit("engine_mixed_submit", 1e6 * q / tput_b,
             f"q={q};ops_per_s={tput_b:.0f};speedup={speedup:.1f}x")
    if speedup < MIN_MIXED_SPEEDUP:
        raise AssertionError(
            f"mixed op batch is only {speedup:.1f}x the scalar legacy "
            f"loop at batch {q} (bar: >= {MIN_MIXED_SPEEDUP}x)"
        )
    return speedup


def bench_pure_paths(eng, domains, csv: CSV, q: int = 256
                     ) -> tuple[float, float]:
    """submit() must not regress the pre-v2 vectorized physical paths.

    The direct side calls the snapshot-level primitives exactly the way
    the legacy (pre-op-layer) ``get_batch``/``scan_batch`` bodies did —
    routing, one pinned view + one vectorized call per shard — so the
    ratio isolates the op layer's planning/wrapping overhead."""
    from repro.db.sharded import route_host

    rng = np.random.default_rng(31)
    keys = np.concatenate(
        [rng.choice(d, q // len(domains), replace=False) for d in domains]
    ).astype(np.uint64)
    starts = np.concatenate(
        [rng.choice(d, 8, replace=False) for d in domains]
    ).astype(np.uint64)

    def direct_get():
        found = np.zeros(len(keys), bool)
        vals = np.zeros((len(keys), eng.shards[0].cfg.vw), np.uint32)
        sid = route_host(eng.lows, keys)
        for s in np.unique(sid):
            m = sid == s
            with eng.shards[s]._view() as view:
                f, v = view.get_batch(keys[m])
            found[m] = f
            vals[m] = v
        return found, vals

    def submit_get():
        return eng.submit(Batch([Op.multiget(keys)]), sync=True).result()

    def direct_scan():
        sid = route_host(eng.lows, starts)
        out = [None] * len(starts)
        for s in np.unique(sid):
            m = np.flatnonzero(sid == s)
            with eng.shards[s]._view() as view:
                rows = eng.shards[s]._scan_group_at(
                    view, starts[m], SCAN_N, with_vals=False
                )
            for qi, row in zip(m, rows):
                out[qi] = row
        return out

    def submit_scan():
        b = Batch([Op.scan(int(s), SCAN_N, with_vals=False)
                   for s in starts.tolist()])
        return eng.submit(b, sync=True).result()

    ratios = []
    for name, direct, submit in (
        ("get", direct_get, submit_get),
        ("scan", direct_scan, submit_scan),
    ):
        direct(), submit()  # warm
        t_d, t_s = [], []
        for _ in range(7):
            t0 = time.perf_counter()
            direct()
            t_d.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            submit()
            t_s.append(time.perf_counter() - t0)
        med_d, med_s = np.median(t_d), np.median(t_s)
        ratio = med_d / max(med_s, 1e-9)
        ratios.append(ratio)
        csv.emit(f"engine_pure_{name}", 1e6 * med_s,
                 f"direct_us={1e6 * med_d:.0f};ratio={ratio:.2f}")
        if ratio < MIN_PURE_RATIO:
            raise AssertionError(
                f"pure {name} path through submit() is {1 / ratio:.1f}x "
                f"slower than the direct physical call"
            )
    return ratios[0], ratios[1]


def bench_async(eng, domains, csv: CSV, q: int = 256) -> float:
    """Async submission: N batches in flight through the worker pool."""
    rng = np.random.default_rng(37)
    batches = [Batch(_mixed_ops(domains, rng, q)) for _ in range(4)]
    t0 = time.perf_counter()
    futs = [eng.submit(b) for b in batches]
    for f in futs:
        assert f.result(timeout=300).ok
    dt = time.perf_counter() - t0
    tput = 4 * q / dt
    csv.emit("engine_async_submit", 1e6 * q / tput,
             f"batches=4;ops_per_s={tput:.0f}")
    return tput


def bench_metrics_overhead(roots, domains, csv: CSV, q: int = 256,
                           reps: int = 5) -> float:
    """Observability must be ~free: mixed-batch throughput with the
    metrics registry on vs the no-op instruments, alternating reps on
    two engines over the same shard files (read-only workload)."""
    cfg = RemixDBConfig(promote_fraction=1e9)
    addrs = [(0, roots[0]), (SPLIT, roots[1])]
    eng_on = KVServeEngine(addrs, config=cfg)
    eng_off = KVServeEngine(addrs, config=cfg, metrics=False)
    rng = np.random.default_rng(41)
    batches = [_mixed_ops(domains, rng, q) for _ in range(3)]

    def one(eng) -> float:
        t0 = time.perf_counter()
        for ops in batches:
            assert eng.submit(Batch(list(ops)), sync=True).result().ok
        return len(batches) * q / (time.perf_counter() - t0)

    try:
        one(eng_on), one(eng_off)  # warm both working sets
        on, off = [], []
        for _ in range(reps):  # alternate so drift hits both sides
            on.append(one(eng_on))
            off.append(one(eng_off))
        ratio = float(np.median(on) / max(np.median(off), 1e-9))
    finally:
        eng_on.close()
        eng_off.close()
    csv.emit("engine_metrics_overhead", 1e6 * q / np.median(on),
             f"q={q};ratio_on_off={ratio:.3f}")
    if ratio < MIN_METRICS_RATIO:
        raise AssertionError(
            f"metrics-on throughput is {ratio:.3f}x metrics-off "
            f"(bar: >= {MIN_METRICS_RATIO}x)"
        )
    return ratio


def bench_snapshot_o1(csv: CSV, tiny: bool = False) -> float:
    """``RemixDB.snapshot()`` must be O(1) in resident MemTable entries:
    the layered MemTable freezes its mutable layer instead of copying the
    overlay dict, so pinning a view of a full memtable costs the same as
    an empty one. This is what makes the cluster tier's per-batch
    snapshot pinning and replication captures free."""
    from repro.db.store import RemixDB

    n = (1 << 12) if tiny else (1 << 15)
    reps = 300

    def pin_cost(db) -> float:
        t_best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(reps):
                db.snapshot().close()
            t_best = min(t_best, (time.perf_counter() - t0) / reps)
        return t_best

    with tempfile.TemporaryDirectory(prefix="snap-bench-") as tmp:
        cfg = RemixDBConfig(memtable_entries=4 * n)
        db = RemixDB.open(os.path.join(tmp, "db"), cfg)
        try:
            empty_s = pin_cost(db)
            ks = np.arange(n, dtype=np.uint64)
            db.put_batch(
                ks, np.stack([ks.astype(np.uint32),
                              np.ones(n, np.uint32)], 1))
            assert len(db.mem.data) >= n  # resident, not flushed
            full_s = pin_cost(db)
        finally:
            db.close()
    ratio = full_s / max(empty_s, 1e-9)
    csv.emit("engine_snapshot_pin", 1e6 * full_s,
             f"entries={n};empty_us={1e6 * empty_s:.2f};"
             f"ratio={ratio:.2f}")
    if ratio > MAX_SNAPSHOT_RATIO:
        raise AssertionError(
            f"snapshot() on a {n}-entry memtable costs {ratio:.1f}x the "
            f"empty-memtable pin (bar: <= {MAX_SNAPSHOT_RATIO}x — it "
            f"must not scale with resident entries)"
        )
    return ratio


def run(csv: CSV, tiny: bool = False, json_path: str | None = None) -> None:
    r_tables, n_per_table = SIZES["tiny" if tiny else "full"]
    with tempfile.TemporaryDirectory(prefix="engine-bench-") as tmp:
        roots = [os.path.join(tmp, f"shard{i}") for i in range(2)]
        domains = [
            _build_shard(roots[i], i * SPLIT, r_tables, n_per_table, seed=i)
            for i in range(2)
        ]
        # promotion off: the op layer over the cold engine is the subject
        cfg = RemixDBConfig(promote_fraction=1e9)
        eng = KVServeEngine(
            [(0, roots[0]), (SPLIT, roots[1])], config=cfg
        )
        speedup = bench_mixed(eng, domains, csv)
        get_ratio, scan_ratio = bench_pure_paths(eng, domains, csv)
        async_tput = bench_async(eng, domains, csv)
        # observability artifacts off the same engine: one traced batch
        # and the full labelled registry snapshot
        rng = np.random.default_rng(43)
        traced = eng.submit(
            Batch(_mixed_ops(domains, rng, 64), trace=True), sync=True
        ).result()
        trace = traced.trace
        assert trace is not None and trace.well_formed()
        snap = eng.metrics()
        estats = eng.stats()["engine"]
        eng.close()
        metrics_ratio = bench_metrics_overhead(roots, domains, csv)
    snapshot_ratio = bench_snapshot_o1(csv, tiny=tiny)
    csv.emit(
        "engine_summary", 0.0,
        f"r_tables={r_tables};n_per_table={n_per_table};"
        f"mixed_speedup={speedup:.1f}x",
    )
    out = json_path or os.environ.get(
        "BENCH_ENGINE_JSON", os.path.join("results", "BENCH_engine.json")
    )
    res_dir = os.path.dirname(out) or "."
    os.makedirs(res_dir, exist_ok=True)
    # sibling artifacts: the labelled snapshot and the Chrome trace
    # (chrome://tracing / Perfetto-loadable) — CI uploads both
    from repro.obs import save_snapshot

    save_snapshot(snap, os.path.join(res_dir, "OBS_snapshot.json"))
    trace.save_chrome(os.path.join(res_dir, "OBS_trace.json"))
    # executor section read back from the registry snapshot (the same
    # samples OBS_snapshot.json carries), not from ad-hoc counters
    ops_by_kind = {
        s["labels"]["kind"]: s["value"]
        for s in snap["metrics"]
        if s["name"] == "engine_ops"
    }
    batch_hist = next(
        (s for s in snap["metrics"] if s["name"] == "engine_batch_seconds"),
        None,
    )
    with open(out, "w") as f:
        json.dump(
            dict(
                bench="engine",
                unix_time=int(time.time()),
                store=dict(shards=2, r_tables=r_tables,
                           n_per_table=n_per_table),
                scan_n=SCAN_N,
                mixed_speedup_at_256=round(speedup, 2),
                pure_get_ratio=round(get_ratio, 3),
                pure_scan_ratio=round(scan_ratio, 3),
                async_ops_per_s=round(async_tput, 1),
                metrics_overhead_ratio=round(metrics_ratio, 3),
                snapshot_pin_ratio_full_vs_empty=round(snapshot_ratio, 3),
                executor=dict(
                    batches=sum(
                        s["value"]
                        for s in snap["metrics"]
                        if s["name"] == "engine_batches"
                    ),
                    ops=ops_by_kind,
                    batch_seconds=None if batch_hist is None else dict(
                        count=batch_hist["count"],
                        p50=batch_hist["p50"],
                        p99=batch_hist["p99"],
                    ),
                    admission=estats["admission"],
                ),
                trace=dict(
                    spans=len(trace.spans()),
                    leaf_coverage=round(trace.leaf_coverage(), 3),
                ),
            ),
            f,
            indent=2,
        )
        f.write("\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shards (4 tables x 4096 entries each)")
    ap.add_argument("--json", default=None, help="BENCH_engine.json path")
    args = ap.parse_args()
    c = CSV()
    print("name,us_per_call,derived")
    run(c, tiny=args.tiny, json_path=args.json)

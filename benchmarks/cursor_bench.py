"""Streaming range scans: one RemixCursor vs repeated ``scan()`` re-seeks.

The experiment behind the cursor layer (paper §3.2): a long or streaming
scan consumed in chunks either re-seeks per chunk — every ``scan(start,
n)`` pays the partition route, the anchors binary search, one bounded
CKB restart-point seek *per run*, and a fresh window walk — or holds one
:class:`repro.db.cursor.RemixCursor`, which seeks once and then advances
a persisted view position (comparison-free ``next``, §3.3) per chunk.

Both paths run against the same recovered (cold) store with a shared
block cache and are verified to return identical rows. Acceptance:
cursor streaming is **>= 2x** the re-seeking loop on long scans
(``MIN_CURSOR_SPEEDUP``, asserted below). Emits
``results/BENCH_cursor.json`` so CI tracks the trajectory.

Run directly (``python -m benchmarks.cursor_bench [--tiny] [--json PATH]``)
or via ``python -m benchmarks.run --only cursor``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.cache_bench import build_store
from benchmarks.common import CSV
from repro.db.store import RemixDB, RemixDBConfig

MIN_CURSOR_SPEEDUP = 2.0  # acceptance bar on the long scan
CHUNK = 64  # rows per consumer step (a streaming client's batch)

# full-size store (default) vs CI smoke store (--tiny): (tables, n/table)
SIZES = dict(full=(6, 1 << 14), tiny=(4, 1 << 11))


def _cold_cfg(**kw) -> RemixDBConfig:
    # promotion off: the subject under test is the streaming read path
    return RemixDBConfig(promote_fraction=1e9, **kw)


def _stream_reseek(db: RemixDB, start: int, total: int) -> np.ndarray:
    """Consume ``total`` rows in CHUNK-sized scans, re-seeking each time
    (the pre-cursor client pattern)."""
    out, lo = [], int(start)
    got = 0
    while got < total:
        kk, _ = db.scan(lo, min(CHUNK, total - got))
        if len(kk) == 0:
            break
        out.append(kk)
        got += len(kk)
        lo = int(kk[-1]) + 1
    return np.concatenate(out) if out else np.zeros(0, np.uint64)


def _stream_cursor(db: RemixDB, start: int, total: int) -> np.ndarray:
    """Consume ``total`` rows from one cursor: seek once, then
    ``next_batch`` per chunk."""
    out, got = [], 0
    with db.cursor(start=start, width=CHUNK + CHUNK // 2) as cur:
        while got < total:
            kk, _ = cur.next_batch(min(CHUNK, total - got))
            if len(kk) == 0:
                break
            out.append(kk)
            got += len(kk)
    return np.concatenate(out) if out else np.zeros(0, np.uint64)


def _time(fn, *args, repeats: int = 3) -> tuple[float, np.ndarray]:
    """Best-of-N wall time (seconds) + the last result. The first call
    warms the shared block cache so both paths measure steady state."""
    fn(*args)
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(csv: CSV, tiny: bool = False, json_path: str | None = None) -> None:
    r_tables, n_per_table = SIZES["tiny" if tiny else "full"]
    root = os.path.join(
        tempfile.mkdtemp(prefix="cursor-bench-"), "db"
    )
    domain = build_store(root, r_tables=r_tables, n_per_table=n_per_table)
    db = RemixDB.open(root, _cold_cfg())
    assert all(p.cold_ready() for p in db.partitions), "store not cold"

    results: dict[str, dict] = {}
    total = len(domain)
    for label, length in [("long", (total * 3) // 4), ("short", 4 * CHUNK)]:
        start = int(domain[total // 8])
        t_re, k_re = _time(_stream_reseek, db, start, length)
        t_cu, k_cu = _time(_stream_cursor, db, start, length)
        np.testing.assert_array_equal(k_cu, k_re)  # identical rows
        speedup = t_re / max(t_cu, 1e-9)
        results[label] = dict(
            rows=int(length),
            chunk=CHUNK,
            reseek_us=t_re * 1e6,
            cursor_us=t_cu * 1e6,
            speedup=speedup,
        )
        csv.emit(
            f"cursor_stream_{label}", t_cu * 1e6 / max(1, length),
            f"rows={length}atspeedup={speedup:.2f}x_vs_reseek".replace(
                "at", " "
            ),
        )
    long_speedup = results["long"]["speedup"]
    assert long_speedup >= MIN_CURSOR_SPEEDUP, (
        f"cursor streaming {long_speedup:.2f}x < {MIN_CURSOR_SPEEDUP}x "
        f"over re-seeking scans on the long range"
    )
    out = json_path or os.environ.get(
        "BENCH_CURSOR_JSON", os.path.join("results", "BENCH_cursor.json")
    )
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            dict(
                store=dict(tables=r_tables, n_per_table=n_per_table,
                           tiny=bool(tiny)),
                scans=results,
                min_speedup=MIN_CURSOR_SPEEDUP,
            ),
            f, indent=2,
        )
    print(f"# wrote {out} (long-scan speedup {long_speedup:.2f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (small store, same assertions)")
    ap.add_argument("--json", default=None, help="BENCH_cursor.json path")
    args = ap.parse_args()
    c = CSV()
    print("name,us_per_call,derived")
    run(c, tiny=args.tiny, json_path=args.json)


if __name__ == "__main__":
    main()

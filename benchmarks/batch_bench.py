"""Batched cold-query engine: vectorized multi-gets vs the scalar loop.

The experiment behind the batched query execution layer (paper §3.2–3.3
adapted to block-granular I/O, plus the Fig 10 value-block pipeline):

- **multi-get**: a recovered (cold) store answers a 256-key batch either
  with a Python loop over scalar ``cold_get`` (PR-2 behaviour) or with
  one vectorized ``cold_get_batch`` per partition — anchors binary
  search over the whole batch at once, grouped per-run seeks, and every
  touched (file, block) granule fetched exactly once. Acceptance:
  **>= 5x** steady-state throughput at batch 256, asserted below, plus
  bit-identical results.
- **coalescing**: on a fresh open, one 256-key batch must show cache
  ``misses == entries`` with zero evictions — each granule the batch
  touches was loaded exactly once.
- **prefetch**: cold scans with ``prefetch_depth > 0`` must read no more
  value blocks than the eager path (equal ``disk_bytes_read``) while
  reporting pipeline hit/waste counters.
- **ckb decoder**: batched seeks resolving keys from the prefix-
  compressed CKB entry stream (``ckb_decode``, default) must return
  bit-identical results while reading strictly fewer physical bytes
  than the fixed-width keys-section path (asserted on the full-size
  store; the tiny store's sections share 64 KB granules, so there the
  bar is "no extra bytes").

Also emits ``BENCH_queries.json`` (cold/warm get + scan throughput at
batch 1/64/256) — the perf trajectory file CI's smoke job keeps
populated from a tiny store.

Run directly (``python -m benchmarks.batch_bench [--tiny] [--json PATH]``)
or via ``python -m benchmarks.run --only batch``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.cache_bench import build_store
from benchmarks.common import CSV
from repro.db.store import RemixDB, RemixDBConfig

MIN_BATCH_SPEEDUP = 5.0  # acceptance bar at batch 256
BATCH_SIZES = (1, 64, 256)
SCAN_N = 50  # keys per range query in the scan rows

# full-size store (default) vs CI smoke store (--tiny)
SIZES = dict(full=(8, 1 << 16), tiny=(4, 1 << 12))


def _cold_cfg(**kw) -> RemixDBConfig:
    # promotion off: the subject under test is the cold engine itself
    return RemixDBConfig(promote_fraction=1e9, **kw)


def _probe(domain: np.ndarray, rng, q: int) -> np.ndarray:
    return rng.choice(domain, size=q, replace=False).astype(np.uint64)


def _scalar_get_loop(db: RemixDB, keys: np.ndarray):
    found = np.zeros(len(keys), bool)
    vals = np.zeros((len(keys), db.cfg.vw), np.uint32)
    for i, k in enumerate(keys.tolist()):
        v = db.get(k)
        if v is not None:
            found[i] = True
            vals[i] = v
    return found, vals


def _throughput(fn, batches: list[np.ndarray]) -> float:
    """Keys/second over the given query batches (steady state)."""
    t0 = time.perf_counter()
    n = 0
    for b in batches:
        fn(b)
        n += len(b)
    return n / (time.perf_counter() - t0)


def bench_multiget(root: str, domain: np.ndarray, csv: CSV, q: int = 256):
    rng = np.random.default_rng(7)
    warmups = [_probe(domain, rng, q) for _ in range(4)]
    batches = [_probe(domain, rng, q) for _ in range(4)]

    db_s = RemixDB.open(root, _cold_cfg())
    db_b = RemixDB.open(root, _cold_cfg())
    assert all(p.cold_ready() for p in db_s.partitions), "store not cold"
    # equivalence on the warmup batches — which also bring each path's
    # block working set (CKB/keys/tomb/vals granules) into the shared
    # cache, so the timed section compares engine throughput rather than
    # each side's first-touch checksum transient — then steady-state
    # throughput on fresh keys
    for warm in warmups:
        f_s, v_s = _scalar_get_loop(db_s, warm)
        f_b, v_b = db_b.get_batch(warm)
        if not (np.array_equal(f_s, f_b)
                and np.array_equal(v_s[f_s], v_b[f_b])):
            raise AssertionError("batched cold gets disagree with scalar loop")
    tput_s = _throughput(lambda b: _scalar_get_loop(db_s, b), batches)
    tput_b = _throughput(lambda b: db_b.get_batch(b), batches)
    speedup = tput_b / max(tput_s, 1e-9)
    csv.emit(
        "batch_multiget_scalar", 1e6 * q / tput_s,
        f"q={q};keys_per_s={tput_s:.0f}",
    )
    csv.emit(
        "batch_multiget_vectorized", 1e6 * q / tput_b,
        f"q={q};keys_per_s={tput_b:.0f};speedup={speedup:.1f}x",
    )
    if speedup < MIN_BATCH_SPEEDUP:
        raise AssertionError(
            f"batched cold multi-get is only {speedup:.1f}x the scalar "
            f"loop at batch {q} (acceptance bar: >= {MIN_BATCH_SPEEDUP}x)"
        )
    return speedup


def bench_coalescing(root: str, domain: np.ndarray, csv: CSV, q: int = 256):
    """Each (file, block) granule touched by a batch is fetched once."""
    rng = np.random.default_rng(11)
    db = RemixDB.open(root, _cold_cfg())
    db.get_batch(_probe(domain, rng, q))
    c = db.stats()["cache"]
    if c["evictions"] != 0 or c["misses"] != c["entries"]:
        raise AssertionError(
            f"coalescing violated: {c['misses']} loads for "
            f"{c['entries']} distinct granules ({c['evictions']} evictions)"
        )
    csv.emit(
        "batch_get_coalescing", 0.0,
        f"granules={c['entries']};loads={c['misses']};hits={c['hits']}",
    )


def bench_prefetch_scan(root: str, domain: np.ndarray, csv: CSV):
    """Fig 10 pipeline: same results, same value blocks as eager."""
    rng = np.random.default_rng(13)
    starts = _probe(domain, rng, 16)
    db_e = RemixDB.open(root, _cold_cfg(prefetch_depth=0))
    db_p = RemixDB.open(root, _cold_cfg(prefetch_depth=2))
    t0 = time.perf_counter()
    ref = [db_e.scan(int(s), SCAN_N) for s in starts]
    t_e = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = [db_p.scan(int(s), SCAN_N) for s in starts]
    t_p = time.perf_counter() - t0
    for (k1, v1), (k2, v2) in zip(ref, got):
        if not (np.array_equal(k1, k2) and np.array_equal(v1, v2)):
            raise AssertionError("prefetched scan disagrees with eager scan")
    b_e, b_p = db_e.disk_bytes_read(), db_p.disk_bytes_read()
    if b_p > b_e:
        raise AssertionError(
            f"prefetched scans read {b_p} bytes > eager {b_e}"
        )
    c = db_p.stats()["cache"]
    csv.emit(
        "scan_prefetch_pipeline", t_p * 1e6 / len(starts),
        f"eager_us={t_e * 1e6 / len(starts):.0f};bytes_eager={b_e};"
        f"bytes_prefetch={b_p};issued={c['prefetch_issued']};"
        f"hits={c['prefetch_hits']};waste={c['prefetch_waste']}",
    )


def bench_ckb_decoder(root: str, domain: np.ndarray, csv: CSV,
                      strict: bool, q: int = 256) -> float:
    """Vectorized CKB entry-stream decoder: same results, fewer bytes."""
    rng = np.random.default_rng(23)
    probes = _probe(domain, rng, q)
    db_on = RemixDB.open(root, _cold_cfg())
    db_off = RemixDB.open(root, _cold_cfg(ckb_decode=False))
    f1, v1 = db_on.get_batch(probes)
    f0, v0 = db_off.get_batch(probes)
    if not (np.array_equal(f1, f0) and np.array_equal(v1, v0)):
        raise AssertionError(
            "CKB-decoded seeks disagree with keys-section seeks"
        )
    b_on, b_off = db_on.disk_bytes_read(), db_off.disk_bytes_read()
    if b_on > b_off or (strict and b_on >= b_off):
        raise AssertionError(
            f"CKB entry-stream decoder saved no bytes: "
            f"{b_on} vs {b_off} (keys-section path)"
        )
    savings = 1 - b_on / max(b_off, 1)
    csv.emit(
        "batch_ckb_decoder", 0.0,
        f"bytes_decode={b_on};bytes_fixed={b_off};savings={savings:.1%}",
    )
    return savings


def bench_query_matrix(root: str, domain: np.ndarray) -> list[dict]:
    """Cold/warm get + scan throughput at batch 1/64/256 (JSON rows)."""
    rng = np.random.default_rng(17)
    rows = []
    for q in BATCH_SIZES:
        db = RemixDB.open(root, _cold_cfg())
        keys = _probe(domain, rng, q)
        starts = _probe(domain, rng, q)
        for op, fn, per in (
            ("get", lambda: db.get_batch(keys), q),
            ("scan", lambda: db.scan_batch(starts, SCAN_N), q),
        ):
            t0 = time.perf_counter()
            fn()
            cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            fn()
            warm = time.perf_counter() - t0
            rows.append(
                dict(op=op, batch=q,
                     cold_qps=round(per / cold, 1),
                     warm_qps=round(per / warm, 1),
                     cold_us_per_query=round(1e6 * cold / per, 2),
                     warm_us_per_query=round(1e6 * warm / per, 2))
            )
    return rows


def bench_device_vs_host(root: str, domain: np.ndarray, csv: CSV) -> dict:
    """Promoted-path comparison: the persistent device view
    (``device_path="on"`` — interpret mode on CPU) vs the legacy host
    vectorized path, batch-256 gets and 64x16 scans, parity asserted.
    ``benchmarks/kernels_bench.py`` owns the sync-count and real-device
    speedup bars; this row tracks the same pipeline on the query store."""
    rng = np.random.default_rng(23)
    keys = _probe(domain, rng, 256)
    starts = np.sort(rng.choice(domain[:-200], 64, replace=False))
    out = {}
    for mode in ("off", "on"):
        db = RemixDB.open(root, RemixDBConfig(cold_reads=False,
                                              device_path=mode))
        f, v = db.get_batch(keys)  # warm: upload / jit / cache
        db.scan_batch(starts, 16)
        t0 = time.perf_counter()
        for _ in range(3):
            db.get_batch(keys)
        tg = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        for _ in range(3):
            db.scan_batch(starts, 16)
        ts = (time.perf_counter() - t0) / 3
        out[mode] = (f, v, tg, ts)
        db.close()
    (fh, vh, hg, hs), (fd, vd, dg, ds) = out["off"], out["on"]
    assert np.array_equal(fh, fd) and np.array_equal(vh[fh], vd[fd])
    csv.emit("batch_device_get256", dg / 256 * 1e6,
             f"host={hg / 256 * 1e6:.2f}us")
    csv.emit("batch_device_scan64x16", ds / 64 * 1e6,
             f"host={hs / 64 * 1e6:.2f}us")
    return dict(
        get_us_device=round(dg / 256 * 1e6, 3),
        get_us_host=round(hg / 256 * 1e6, 3),
        scan_us_device=round(ds / 64 * 1e6, 2),
        scan_us_host=round(hs / 64 * 1e6, 2),
    )


def run(csv: CSV, tiny: bool = False, json_path: str | None = None) -> None:
    r_tables, n_per_table = SIZES["tiny" if tiny else "full"]
    with tempfile.TemporaryDirectory(prefix="batch-bench-") as tmp:
        root = os.path.join(tmp, "db")
        domain = build_store(
            root, r_tables=r_tables, n_per_table=n_per_table
        )
        speedup = bench_multiget(root, domain, csv)
        bench_coalescing(root, domain, csv)
        bench_prefetch_scan(root, domain, csv)
        savings = bench_ckb_decoder(root, domain, csv, strict=not tiny)
        matrix = bench_query_matrix(root, domain)
        device = bench_device_vs_host(root, domain, csv)
    csv.emit(
        "batch_summary", 0.0,
        f"r_tables={r_tables};n_per_table={n_per_table};"
        f"multiget_speedup={speedup:.1f}x",
    )
    out = json_path or os.environ.get(
        "BENCH_QUERIES_JSON", os.path.join("results", "BENCH_queries.json")
    )
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            dict(
                bench="queries",
                unix_time=int(time.time()),
                store=dict(r_tables=r_tables, n_per_table=n_per_table),
                scan_n=SCAN_N,
                multiget_speedup_at_256=round(speedup, 2),
                ckb_decode_savings=round(savings, 3),
                queries=matrix,
                device_vs_host=device,
            ),
            f,
            indent=2,
        )
        f.write("\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke store (4 tables x 4096 entries)")
    ap.add_argument("--json", default=None, help="BENCH_queries.json path")
    args = ap.parse_args()
    c = CSV()
    print("name,us_per_call,derived")
    run(c, tiny=args.tiny, json_path=args.json)

"""Shared benchmark helpers: table generation (weak/strong locality), timing,
CSV emission. Mirrors the paper's §5.1 setup, scaled for a CPU container:
keys 64-bit, R tables × N keys each, uniform random query keys."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.core.remix import build_remix
from repro.core.runs import make_run


def make_tables(
    r: int,
    n_per_table: int = 65536,
    locality: str = "weak",
    chunk: int = 64,
    seed: int = 0,
    vw: int = 2,
):
    """R tables as in §5.1: each key assigned to a random table (weak) or in
    64-key consecutive chunks (strong). Returns list[Run] (keys disjoint)."""
    rng = np.random.default_rng(seed)
    total = r * n_per_table
    keys = np.arange(1, total + 1, dtype=np.uint64) * 64  # spaced key domain
    if locality == "weak":
        owner = rng.integers(0, r, total)
    else:
        n_chunks = (total + chunk - 1) // chunk
        chunk_owner = rng.integers(0, r, n_chunks)
        owner = np.repeat(chunk_owner, chunk)[:total]
    runs = []
    for i in range(r):
        kk = keys[owner == i]
        runs.append(make_run(kk, seq=i, vw=vw))
    return runs, keys


def time_batched(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call of a jitted batched op (seconds)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def qkeys(rng, keyspace_max: int, q: int):
    return jnp.asarray(
        CK.pack_u64(rng.integers(1, keyspace_max, q).astype(np.uint64))
    )


class CSV:
    def __init__(self):
        self.rows = []

    def emit(self, name: str, us_per_call: float, derived: str = ""):
        line = f"{name},{us_per_call:.3f},{derived}"
        self.rows.append(line)
        print(line, flush=True)


def zipf_keys(rng, n_keys: int, q: int, theta: float = 0.99) -> np.ndarray:
    """YCSB-style zipfian item sampler over [0, n_keys)."""
    # rejection-free approximate zipfian via inverse-CDF on a harmonic grid
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = 1.0 / ranks ** theta
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(q)
    return np.searchsorted(cdf, u).astype(np.int64)

"""Cluster tier under fire: live shard split mid-run, replicas catching up.

Three range shards behind one :class:`repro.cluster.Cluster` serve
zipfian traffic from concurrent submitters while the hottest shard is
split live. The acceptance bars (asserted, not just reported):

- zero failed operations across the whole run — the cutover gates
  submissions instead of failing them;
- post-split p99 batch latency <= 2x the pre-split p99 (the split may
  briefly stall the gate but must not degrade steady-state serving);
- a read replica converges to sequence lag 0 once the writer pauses.

Emits ``results/BENCH_cluster.json`` (CI smoke keeps it populated via
``--tiny``).
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import CSV, zipf_keys
from repro.cluster import Cluster
from repro.db.compaction import CompactionConfig
from repro.db.ops import Batch, Op
from repro.db.store import RemixDBConfig

SIZES = {  # n keys preloaded per shard
    "tiny": 8_192,
    "full": 49_152,
}
SHARDS = 3
BATCH = 64
THREADS = 3


def _cfg() -> RemixDBConfig:
    return RemixDBConfig(
        vw=2,
        memtable_entries=1 << 12,
        compaction=CompactionConfig(table_cap=1 << 12, t_max=4),
    )


class _Traffic:
    """Zipfian read/write submitters recording per-batch latencies."""

    def __init__(self, cluster: Cluster, keyspace: int, seed: int = 0):
        self.cluster = cluster
        self.keyspace = keyspace
        self.seed = seed
        self.failed: list[str] = []
        self.lat: list[tuple[float, float]] = []  # (t_done, seconds)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    def _loop(self, tid: int) -> None:
        rng = np.random.default_rng(self.seed + tid)
        # zipfian ranks permuted over the key domain: hot keys spread
        # across the space but concentrated in popularity
        perm = np.random.default_rng(7).permutation(self.keyspace)
        while not self._stop.is_set():
            ranks = zipf_keys(rng, self.keyspace, BATCH)
            ks = perm[ranks].astype(np.uint64)
            write = rng.random() < 0.25
            if write:
                vs = np.stack([ks.astype(np.uint32),
                               np.full(BATCH, tid + 1, np.uint32)], 1)
                batch = Batch([Op.put(ks, vs)])
            else:
                batch = Batch([Op.multiget(ks)])
            t0 = time.perf_counter()
            try:
                res = self.cluster.submit(batch).result(timeout=120)
                for r in res.results:
                    r.raise_if_error()
            except Exception as e:  # noqa: BLE001 - the bench asserts
                with self._lock:
                    self.failed.append(repr(e))
                continue
            t1 = time.perf_counter()
            with self._lock:
                self.lat.append((t1, t1 - t0))

    def start(self) -> None:
        self._threads = [
            threading.Thread(target=self._loop, args=(i,), daemon=True)
            for i in range(THREADS)
        ]
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join()

    def window(self, t0: float, t1: float) -> np.ndarray:
        with self._lock:
            return np.array([s for td, s in self.lat if t0 <= td < t1])


def _p(arr: np.ndarray, q: float) -> float:
    return float(np.percentile(arr, q)) if len(arr) else float("nan")


def run(csv: CSV, tiny: bool = False, json_path: str | None = None) -> None:
    n_per_shard = SIZES["tiny" if tiny else "full"]
    keyspace = SHARDS * n_per_shard
    span = keyspace // SHARDS
    phase_s = 2.0 if tiny else 5.0
    with tempfile.TemporaryDirectory(prefix="cluster-bench-") as tmp:
        cluster = Cluster(
            os.path.join(tmp, "fleet"),
            lows=tuple(i * span for i in range(SHARDS)),
            config=_cfg(),
        )
        ks = np.arange(keyspace, dtype=np.uint64)
        for i in range(0, keyspace, 1 << 14):
            sl = ks[i:i + (1 << 14)]
            cluster.put_batch(
                sl, np.stack([sl.astype(np.uint32),
                              np.zeros(len(sl), np.uint32)], 1))
        cluster.flush()

        traffic = _Traffic(cluster, keyspace)
        traffic.start()
        t_start = time.perf_counter()
        time.sleep(phase_s)
        t_pre_end = time.perf_counter()

        # live split of the hottest (zipf-head) shard, mid-run
        t_split0 = time.perf_counter()
        report = cluster.split(span // 2)
        t_split1 = time.perf_counter()
        assert len(cluster.lows) == SHARDS + 1

        time.sleep(phase_s)
        t_post_end = time.perf_counter()
        traffic.stop()

        pre = traffic.window(t_start, t_pre_end)
        post = traffic.window(t_split1, t_post_end)
        p99_pre, p99_post = _p(pre, 99), _p(post, 99)
        ratio = p99_post / p99_pre if p99_pre else float("nan")
        n_ops = len(traffic.lat) * BATCH

        csv.emit("cluster_pre_split_p99", 1e6 * p99_pre,
                 f"batches={len(pre)};shards={SHARDS}")
        csv.emit("cluster_post_split_p99", 1e6 * p99_post,
                 f"batches={len(post)};shards={SHARDS + 1};"
                 f"ratio={ratio:.2f}")
        csv.emit("cluster_split_gate", 1e6 * (t_split1 - t_split0),
                 f"shipped_bytes={report['shipped']['bytes']}")

        assert not traffic.failed, traffic.failed[:5]
        if len(pre) >= 50 and len(post) >= 50 and ratio > 2.0:
            raise AssertionError(
                f"post-split p99 {1e3 * p99_post:.2f}ms is {ratio:.2f}x "
                f"pre-split (bar: <= 2x)")

        # replica: catch up live, then converge to 0 once writes pause
        rep = cluster.add_replica(cluster.lows[0])
        wk = np.arange(0, span // 4, dtype=np.uint64)
        cluster.put_batch(
            wk, np.stack([wk.astype(np.uint32),
                          np.full(len(wk), 9, np.uint32)], 1))
        lag_before = rep.seq_lag()
        t_rep0 = time.perf_counter()
        final = rep.catch_up_until(lag_target=0)
        t_rep1 = time.perf_counter()
        assert rep.seq_lag() == 0, rep.seq_lag()
        csv.emit("cluster_replica_catchup", 1e6 * (t_rep1 - t_rep0),
                 f"lag_before={lag_before};lag_after=0")

        snap = cluster.metrics()
        counters = {
            m["name"]: m.get("value", 0)
            for m in snap["metrics"]
            if m.get("type") == "counter"
            and m.get("labels", {}).get("tier") == "serve"
        }
        lows_after = cluster.lows
        cluster.close()

    csv.emit(
        "cluster_summary", 0.0,
        f"shards={SHARDS}->{len(lows_after)};ops={n_ops};failed=0",
    )
    out = json_path or os.environ.get(
        "BENCH_CLUSTER_JSON", os.path.join("results", "BENCH_cluster.json")
    )
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            dict(
                bench="cluster",
                unix_time=int(time.time()),
                store=dict(shards_before=SHARDS,
                           shards_after=len(lows_after),
                           keys=keyspace, batch=BATCH,
                           threads=THREADS, phase_s=phase_s),
                ops=n_ops,
                failed_ops=len(traffic.failed),
                p99_pre_split_ms=round(1e3 * p99_pre, 3),
                p99_post_split_ms=round(1e3 * p99_post, 3),
                p50_pre_split_ms=round(1e3 * _p(pre, 50), 3),
                p50_post_split_ms=round(1e3 * _p(post, 50), 3),
                post_over_pre_p99=round(ratio, 3),
                split=dict(
                    at=report["at"],
                    gate_ms=round(1e3 * (t_split1 - t_split0), 3),
                    shipped_bytes=report["shipped"]["bytes"],
                    shipped_files=report["shipped"]["files"],
                    final_lag=report["final"]["lag"],
                ),
                replica=dict(
                    lag_before_catchup=int(lag_before),
                    lag_after_catchup=0,
                    catchup_ms=round(1e3 * (t_rep1 - t_rep0), 3),
                    applied=final["applied"],
                ),
                counters=dict(
                    shard_split=counters.get("shard_split", 0),
                    snapshot_ship_bytes=counters.get(
                        "snapshot_ship_bytes", 0),
                    snapshot_ship_files=counters.get(
                        "snapshot_ship_files", 0),
                    replica_catchup_seqs=counters.get(
                        "replica_catchup_seqs", 0),
                ),
            ),
            f,
            indent=2,
        )
        f.write("\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (3 shards x 8192 keys)")
    ap.add_argument("--json", default=None, help="BENCH_cluster.json path")
    args = ap.parse_args()
    c = CSV()
    print("name,us_per_call,derived")
    run(c, tiny=args.tiny, json_path=args.json)

"""Kernel microbenchmarks + the fused device-resident query pipeline.

Two layers:

- **micro**: Pallas kernels (interpret on CPU) vs the pure-jnp reference
  — anchor search, the fused seek composition, and the REMIX build
  throughput (compaction-side cost the WA accounting charges).
- **device pipeline**: a promoted single-partition store answers a
  256-key batch through the persistent device view
  (``device_path="on"``): seek → selector decode → run/position resolve
  → gather, all device-side, with **exactly one host sync per batch**
  (asserted via ``repro.kernels.device_view.SYNCS``) and bit-identical
  results to the legacy host promoted path (asserted). On a real
  accelerator backend the fused pipeline must beat the host vectorized
  path **>= 5x** at batch 256; on CPU (interpret mode — what CI runs)
  the speedup is reported but not asserted.

Also emits ``BENCH_kernels.json`` — the device-pipeline perf trajectory
file CI's kernels-smoke job keeps populated from a tiny store.

Run directly (``python -m benchmarks.kernels_bench [--tiny] [--json PATH]``)
or via ``python -m benchmarks.run --only kernels``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

import jax

from benchmarks.cache_bench import build_store
from benchmarks.common import CSV, make_tables, qkeys, time_batched
from repro.core.remix import build_remix
from repro.db.store import RemixDB, RemixDBConfig
from repro.kernels import device_view, ops
from repro.kernels.anchor_search import anchor_search
from repro.kernels.ref import anchor_search_ref

MIN_DEVICE_SPEEDUP = 5.0  # acceptance bar at batch 256, real devices only
BATCH = 256
ITERS = 5

# full-size store (default) vs CI smoke store (--tiny)
SIZES = dict(full=(8, 1 << 16), tiny=(4, 1 << 12))


def bench_micro(csv: CSV) -> None:
    rng = np.random.default_rng(3)
    runs, keys = make_tables(8, 16384, locality="weak")
    t0 = time.perf_counter()
    remix, runset = build_remix(runs, d=32)
    csv.emit("kernels_remix_build", (time.perf_counter() - t0) * 1e6,
             f"{8*16384} entries")
    qk = qkeys(rng, int(keys[-1]), 1024)
    t = time_batched(
        lambda q: anchor_search(remix.anchors, q, interpret=True), qk
    )
    csv.emit("kernels_anchor_search_pallas_interp", t / 1024 * 1e6, "")
    t = time_batched(lambda q: anchor_search_ref(remix.anchors, q), qk)
    csv.emit("kernels_anchor_search_ref", t / 1024 * 1e6, "")
    t = time_batched(lambda q: ops.seek(remix, runset, q, interpret=True), qk)
    csv.emit("kernels_seek_fused_interp", t / 1024 * 1e6, "")


def _probe(domain: np.ndarray, rng, q: int) -> np.ndarray:
    hits = rng.choice(domain, q - q // 8, replace=False).astype(np.uint64)
    miss = rng.choice(domain, q // 8, replace=False).astype(np.uint64) + 1
    out = np.concatenate([hits, miss])
    rng.shuffle(out)
    return out


def _time_batches(db, probe) -> float:
    db.get_batch(probe)  # warm: upload / jit compile / cache fill
    t0 = time.perf_counter()
    for _ in range(ITERS):
        db.get_batch(probe)
    return (time.perf_counter() - t0) / ITERS


def bench_device_pipeline(root: str, domain: np.ndarray, csv: CSV) -> dict:
    """Fused promoted-get pipeline: sync-count contract, host parity,
    and device-vs-host throughput at batch 256."""
    rng = np.random.default_rng(11)
    probe = _probe(domain, rng, BATCH)
    db_h = RemixDB.open(root, RemixDBConfig(cold_reads=False,
                                            device_path="off"))
    db_d = RemixDB.open(root, RemixDBConfig(cold_reads=False,
                                            device_path="on"))

    f_h, v_h = db_h.get_batch(probe)
    f_d, v_d = db_d.get_batch(probe)  # also uploads the device view
    assert np.array_equal(f_h, f_d), "device/host found-mask mismatch"
    assert np.array_equal(v_h[f_h], v_d[f_d]), "device/host value mismatch"
    assert len(db_d.device_views) == 1  # single-partition store, resident

    s0 = device_view.SYNCS
    for _ in range(ITERS):
        db_d.get_batch(probe)
    syncs = (device_view.SYNCS - s0) / ITERS
    assert syncs == 1.0, (
        f"fused batch-{BATCH} get paid {syncs} host syncs per batch, want 1"
    )

    host_s = _time_batches(db_h, probe)
    dev_s = _time_batches(db_d, probe)
    speedup = host_s / dev_s
    backend = jax.default_backend()
    if backend not in ("cpu",):
        assert speedup >= MIN_DEVICE_SPEEDUP, (
            f"device pipeline {speedup:.1f}x < {MIN_DEVICE_SPEEDUP}x "
            f"on {backend}"
        )
    csv.emit("kernels_device_get_batch256", dev_s / BATCH * 1e6,
             f"syncs_per_batch=1;backend={backend}")
    csv.emit("kernels_host_get_batch256", host_s / BATCH * 1e6, "")
    csv.emit("kernels_device_speedup", 0.0, f"{speedup:.2f}x")

    # scan windows through the same fused path
    starts = np.sort(rng.choice(domain[:-200], 64, replace=False))
    db_h.scan_batch(starts, 16), db_d.scan_batch(starts, 16)
    t0 = time.perf_counter()
    for _ in range(ITERS):
        db_d.scan_batch(starts, 16)
    dscan = (time.perf_counter() - t0) / ITERS
    t0 = time.perf_counter()
    for _ in range(ITERS):
        db_h.scan_batch(starts, 16)
    hscan = (time.perf_counter() - t0) / ITERS
    csv.emit("kernels_device_scan64x16", dscan / 64 * 1e6, "")
    csv.emit("kernels_host_scan64x16", hscan / 64 * 1e6, "")

    out = dict(
        backend=backend,
        batch=BATCH,
        syncs_per_batch=syncs,
        device_get_us_per_key=round(dev_s / BATCH * 1e6, 3),
        host_get_us_per_key=round(host_s / BATCH * 1e6, 3),
        get_speedup=round(speedup, 2),
        device_scan_us_per_query=round(dscan / 64 * 1e6, 2),
        host_scan_us_per_query=round(hscan / 64 * 1e6, 2),
        hbm_resident_bytes=int(db_d.device_views.resident_bytes),
    )
    db_h.close(), db_d.close()
    return out


def run(csv: CSV, tiny: bool = False, json_path: str | None = None) -> None:
    bench_micro(csv)
    r_tables, n_per_table = SIZES["tiny" if tiny else "full"]
    with tempfile.TemporaryDirectory(prefix="kernels-bench-") as tmp:
        root = os.path.join(tmp, "db")
        domain = build_store(
            root, r_tables=r_tables, n_per_table=n_per_table
        )
        pipeline = bench_device_pipeline(root, domain, csv)
    out = json_path or os.environ.get(
        "BENCH_KERNELS_JSON", os.path.join("results", "BENCH_kernels.json")
    )
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(
            dict(
                bench="kernels",
                unix_time=int(time.time()),
                store=dict(r_tables=r_tables, n_per_table=n_per_table),
                pipeline=pipeline,
            ),
            f,
            indent=2,
        )
        f.write("\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke store (4 tables x 4096 entries)")
    ap.add_argument("--json", default=None, help="BENCH_kernels.json path")
    args = ap.parse_args()
    c = CSV()
    print("name,us_per_call,derived")
    run(c, tiny=args.tiny, json_path=args.json)

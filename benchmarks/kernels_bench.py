"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On CPU these establish correctness-path timings only; the BlockSpec tiling
targets TPU VMEM. Also reports the REMIX build throughput (compaction-side
cost that the WA accounting charges)."""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import CSV, make_tables, qkeys, time_batched
from repro.core.remix import build_remix
from repro.kernels import ops
from repro.kernels.anchor_search import anchor_search
from repro.kernels.ref import anchor_search_ref


def run(csv: CSV):
    rng = np.random.default_rng(3)
    runs, keys = make_tables(8, 16384, locality="weak")
    t0 = time.perf_counter()
    remix, runset = build_remix(runs, d=32)
    csv.emit("kernels_remix_build", (time.perf_counter() - t0) * 1e6,
             f"{8*16384} entries")
    qk = qkeys(rng, int(keys[-1]), 1024)
    t = time_batched(lambda q: anchor_search(remix.anchors, q, interpret=True), qk)
    csv.emit("kernels_anchor_search_pallas_interp", t / 1024 * 1e6, "")
    t = time_batched(lambda q: anchor_search_ref(remix.anchors, q), qk)
    csv.emit("kernels_anchor_search_ref", t / 1024 * 1e6, "")
    t = time_batched(lambda q: ops.seek(remix, runset, q, interpret=True), qk)
    csv.emit("kernels_seek_fused_interp", t / 1024 * 1e6, "")

"""Table 1: REMIX storage cost (bytes/key) for Facebook production KV sizes,
vs SSTable block-index (BI) and bloom filters (BF). The analytic formula is
cross-checked against a real constructed REMIX."""
from __future__ import annotations

import numpy as np

from benchmarks.common import CSV, make_tables
from repro.core.remix import build_remix

WORKLOADS = {  # name: (avg key B, avg value B)
    "UDB": (27.1, 126.7),
    "ZippyDB": (47.9, 42.9),
    "UP2X": (10.45, 46.8),
    "USR": (19, 2),
    "APP": (38, 245),
    "ETC": (41, 358),
    "VAR": (35, 115),
    "SYS": (28, 396),
}

R = 8
S = 4  # cursor offset bytes


def remix_bytes_per_key(lbar: float, d: int, r: int = R, s: int = S) -> float:
    """Paper §3.4: (L̄ + R·S)/D + ceil(log2 R)/8 bytes per key."""
    import math

    return (lbar + r * s) / d + math.ceil(math.log2(r)) / 8


def sstable_bi(key: float, val: float, handle: int = 4, block: int = 4096) -> float:
    per_block = max(1, block // (key + val))
    return (key + handle) / per_block


def run(csv: CSV):
    for name, (k, v) in WORKLOADS.items():
        bi = sstable_bi(k, v)
        bf = bi + 10 / 8
        csv.emit(f"table1_{name}_sstable_BI", bi, "bytes/key")
        csv.emit(f"table1_{name}_sstable_BI+BF", bf, "bytes/key")
        for d in (16, 32, 64):
            bpk = remix_bytes_per_key(k, d)
            csv.emit(f"table1_{name}_remix_D={d}", bpk, "bytes/key")
        ratio = remix_bytes_per_key(k, 32) / (k + v)
        csv.emit(f"table1_{name}_remix_to_data_D=32", ratio * 100, "%")
    # cross-check the formula against a really constructed REMIX (16B keys).
    # RemixDB stores 1-BYTE selectors (paper §4.1) while Table 1 assumes
    # packed ceil(log2 R)-bit selectors — both reported.
    runs, _ = make_tables(R, 8192, locality="weak")
    remix, _ = build_remix(runs, d=32)
    measured = remix.storage_bytes(anchor_key_bytes=16) / int(remix.n_entries)
    predicted = remix_bytes_per_key(16, 32)
    import math

    packed = measured - 1 + math.ceil(math.log2(R)) / 8
    csv.emit("table1_crosscheck_measured_1B_sel", measured, "bytes/key (16B keys)")
    csv.emit("table1_crosscheck_measured_packed_sel", packed, "bytes/key (16B keys)")
    csv.emit("table1_crosscheck_formula", predicted, "bytes/key (16B keys)")

"""REMIX (re)build cost: CKB-based incremental vs from-scratch (Snippet 1).

The Snippet-1 experiment: a partition holds R table files on disk and a
minor compaction appends one freshly flushed table. Building the new REMIX
  - from scratch reads every old table's key-value data (keys, vals, seq,
    tomb sections) and re-sorts all keys;
  - incrementally reads only the old tables' Compressed Keys Blocks plus
    the old REMIX's selector stream, and never touches a value block.
Both must produce bit-identical REMIX structures; the incremental path is
what buys the reference implementation its 2x random-write throughput.

Run directly (``python -m benchmarks.rebuild_bench``) or via
``python -m benchmarks.run --only rebuild``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.core import keys as CK
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.db.partition import Table
from repro.io.rebuild import incremental_build_remix
from repro.io.remix_io import dump_remix, load_remix
from repro.io.sstable import write_sstable

R_OLD = 8
N_PER_TABLE = 16384
D = 32


def _setup(root: str, seed: int = 0):
    """R_OLD tables on disk (with CKBs) + their REMIX file + one new run."""
    rng = np.random.default_rng(seed)
    total = (R_OLD + 1) * N_PER_TABLE
    domain = np.arange(1, total + 1, dtype=np.uint64) * 64
    owner = rng.integers(0, R_OLD + 1, total)
    paths, runs, seqbase = [], [], 1
    for i in range(R_OLD):
        kk = domain[owner == i]
        seqs = np.arange(seqbase, seqbase + len(kk), dtype=np.uint32)
        seqbase += len(kk)
        run = make_run(kk, seq=seqs, sort=True)
        p = os.path.join(root, f"t-{i:06d}.sst")
        write_sstable(
            p, np.asarray(run.keys), np.asarray(run.vals),
            np.asarray(run.seq), np.asarray(run.tomb),
        )
        paths.append(p)
        runs.append(run)
    old_remix, _ = build_remix(runs, d=D)
    rpath = os.path.join(root, "x-000000.rmx")
    dump_remix(old_remix, rpath)
    kk = domain[owner == R_OLD]  # the freshly flushed (in-memory) table
    new_run = make_run(
        kk, seq=np.arange(seqbase, seqbase + len(kk), dtype=np.uint32),
        sort=True,
    )
    return paths, rpath, new_run


def _fresh_handles(paths):
    """New lazy handles so per-section read accounting starts at zero."""
    return [Table.from_file(p) for p in paths]


def _section_bytes(tables, sections):
    return sum(t._rd().bytes_read[s] for t in tables for s in sections)


def run(csv: CSV) -> None:
    with tempfile.TemporaryDirectory(prefix="rebuild-bench-") as root:
        paths, rpath, new_run = _setup(root)
        nk = [np.asarray(new_run.keys)]
        ns = [np.asarray(new_run.seq)]

        # ---- from scratch: read old tables' KV data, global re-sort ----
        tabs = _fresh_handles(paths)
        t0 = time.perf_counter()
        runs = [
            make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb, sort=False)
            for t in tabs
        ] + [new_run]
        scratch, _ = build_remix(runs, d=D)
        t_scratch = time.perf_counter() - t0
        kv_scratch = _section_bytes(tabs, ("keys", "vals", "seq", "tomb"))
        val_scratch = _section_bytes(tabs, ("vals",))

        # ---- incremental: old REMIX + CKBs only ----
        tabs = _fresh_handles(paths)
        t0 = time.perf_counter()
        old_remix = load_remix(rpath)
        inc = incremental_build_remix(
            old_remix, [t.key_words() for t in tabs], nk, ns, d=D
        )
        t_inc = time.perf_counter() - t0
        ckb_inc = _section_bytes(tabs, ("ckb",))
        val_inc = _section_bytes(tabs, ("vals",))
        kv_inc = _section_bytes(tabs, ("keys", "vals", "seq", "tomb"))

        identical = all(
            np.array_equal(np.asarray(getattr(scratch, f)),
                           np.asarray(getattr(inc, f)))
            for f in ("anchors", "cursors", "selectors")
        ) and int(np.asarray(scratch.n_entries)) == int(
            np.asarray(inc.n_entries)
        )

    n = R_OLD * N_PER_TABLE
    csv.emit("rebuild_scratch", t_scratch * 1e6,
             f"kv_bytes_read={kv_scratch};value_bytes_read={val_scratch}")
    csv.emit("rebuild_incremental", t_inc * 1e6,
             f"ckb_bytes_read={ckb_inc};value_bytes_read={val_inc};"
             f"kv_bytes_read={kv_inc}")
    csv.emit(
        "rebuild_summary", 0.0,
        f"n_old_entries={n};speedup={t_scratch / max(t_inc, 1e-9):.2f}x;"
        f"read_reduction={kv_scratch / max(ckb_inc, 1):.2f}x;"
        f"bit_identical={identical}",
    )
    if not identical:
        raise AssertionError("incremental REMIX differs from scratch build")
    if val_inc != 0:
        raise AssertionError(
            f"incremental rebuild read {val_inc} value bytes (expected 0)"
        )


if __name__ == "__main__":
    c = CSV()
    print("name,us_per_call,derived")
    run(c)

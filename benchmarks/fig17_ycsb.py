"""Fig 17: YCSB A–F on RemixDB vs the leveled/tiered baselines (scaled).

CPU-harness caveat: store-level µs/op here includes host dispatch overhead
(RemixDB pays one jitted call per touched partition and full WAL
durability; the baselines keep a single runset and no WAL), so absolute
ratios are not comparable to the paper's SSD numbers — the compute-level
validation of the paper's claims is fig11/fig12.

Workloads per Table 2: A=50R/50U, B=95R/5U, C=100R, D=95R/5I(latest),
E=95Scan/5I, F=50R/50RMW; zipfian request distribution (D: latest)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import CSV, zipf_keys
from repro.db.baseline import BaselineConfig, LeveledStore, TieredStore
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig

N_KEYS = 60_000
OPS = 3_000
MEM = 8192
VW = 8

WORKLOADS = dict(
    A=dict(read=0.5, update=0.5),
    B=dict(read=0.95, update=0.05),
    C=dict(read=1.0),
    D=dict(read=0.95, insert=0.05, dist="latest"),
    E=dict(scan=0.95, insert=0.05),
    F=dict(read=0.5, rmw=0.5),
)


def build(tmpdir):
    db = RemixDB(
        RemixDBConfig(
            vw=VW, memtable_entries=MEM, wal_dir=tmpdir,
            compaction=CompactionConfig(table_cap=8192, t_max=10),
        )
    )
    bcfg = BaselineConfig(vw=VW, memtable_entries=MEM, table_cap=8192)
    return {"remixdb": db, "leveled": LeveledStore(bcfg), "tiered": TieredStore(bcfg)}


def run(csv: CSV):
    import tempfile

    rng = np.random.default_rng(17)
    keys = (rng.permutation(N_KEYS).astype(np.uint64) + 1) * 16
    vals = np.zeros((N_KEYS, VW), np.uint32)
    stores = build(tempfile.mkdtemp())
    for name, s in stores.items():
        for c in range(0, N_KEYS, MEM):
            s.put_batch(keys[c : c + MEM], vals[c : c + MEM])
        s.flush()
    skeys = np.sort(keys)
    next_key = keys.max() + 16

    for wl, mix in WORKLOADS.items():
        zipf = zipf_keys(rng, N_KEYS, OPS)
        ops = rng.random(OPS)
        for name, s in stores.items():
            inserted = 0
            t0 = time.perf_counter()
            reads = []
            scans = []
            i = 0
            while i < OPS:
                u = ops[i]
                if mix.get("dist") == "latest":
                    target = skeys[max(0, N_KEYS - 1 - zipf[i])]
                else:
                    target = skeys[zipf[i] % N_KEYS]
                racc = mix.get("read", 0)
                sacc = racc + mix.get("scan", 0)
                uacc = sacc + mix.get("update", 0)
                iacc = uacc + mix.get("insert", 0)
                if u < racc:
                    reads.append(target)
                    if len(reads) == 256 or i == OPS - 1:  # batched reads
                        s.get_batch(np.array(reads, np.uint64))
                        reads = []
                elif u < sacc:
                    scans.append(target)
                    if len(scans) == 64 or i == OPS - 1:  # batched scans
                        s.scan_batch(np.array(scans, np.uint64), 50)
                        scans = []
                elif u < uacc:
                    s.put(int(target), np.zeros(VW, np.uint32))
                elif u < iacc:
                    s.put(int(next_key + inserted * 16), np.zeros(VW, np.uint32))
                    inserted += 1
                else:  # rmw
                    reads.append(target)
                    if len(reads) == 256:
                        s.get_batch(np.array(reads, np.uint64))
                        reads = []
                    s.put(int(target), np.zeros(VW, np.uint32))
                i += 1
            if reads:
                s.get_batch(np.array(reads, np.uint64))
            if scans:
                s.scan_batch(np.array(scans, np.uint64), 50)
            dt = time.perf_counter() - t0
            csv.emit(f"fig17_ycsb_{wl}_{name}", dt / OPS * 1e6, f"{OPS/dt:.0f} ops/s")

"""Fig 11 (weak locality) + Fig 12 (strong locality): Seek, Seek+Next50 and
Get throughput vs number of tables, REMIX vs merging iterator vs bloom.

Reported as µs/op at batch Q (single CPU device; the relative trends vs R
are the paper's claims — REMIX's advantage grows with table count)."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import CSV, make_tables, qkeys, time_batched
from repro.core import merge_iter as M
from repro.core import query as Q
from repro.core.bloom import bloom_maybe_contains, build_bloom
from repro.core.remix import build_remix

RS = (1, 2, 4, 8, 16)
QBATCH = 2048
N_PER_TABLE = 16384


def run(csv: CSV, locality: str = "weak", rs=RS, d: int = 32):
    rng = np.random.default_rng(42)
    fig = "fig11" if locality == "weak" else "fig12"
    for r in rs:
        runs, keys = make_tables(r, N_PER_TABLE, locality=locality)
        remix, runset = build_remix(runs, d=d)
        qk = qkeys(rng, int(keys[-1]), QBATCH)

        t = time_batched(lambda q: Q.seek(remix, runset, q, ingroup="binary"), qk)
        csv.emit(f"{fig}a_seek_remix_full,R={r}", t / QBATCH * 1e6, f"{QBATCH/t:.0f} ops/s")
        t = time_batched(lambda q: Q.seek(remix, runset, q, ingroup="vector"), qk)
        csv.emit(f"{fig}a_seek_remix_vector,R={r}", t / QBATCH * 1e6, f"{QBATCH/t:.0f} ops/s")
        t_m = time_batched(lambda q: M.seek_cursors(runset, q), qk)
        csv.emit(f"{fig}a_seek_merging,R={r}", t_m / QBATCH * 1e6, f"{QBATCH/t_m:.0f} ops/s")

        qk2 = qk[:256]
        t = time_batched(lambda q: Q.scan(remix, runset, q, width=64), qk2)
        csv.emit(f"{fig}b_next50_remix,R={r}", t / 256 * 1e6, "")
        t_m = time_batched(lambda q: M.merge_scan(runset, q, width=64), qk2)
        csv.emit(f"{fig}b_next50_merging,R={r}", t_m / 256 * 1e6, "")

        # point queries: REMIX get (no bloom) vs bloom-prefiltered per-run get
        hit_q = jnp.asarray(
            np.stack(
                [np.zeros(QBATCH, np.uint32),
                 (rng.choice(keys, QBATCH) & 0xFFFFFFFF).astype(np.uint32)],
                axis=1,
            )
        )
        t = time_batched(lambda q: Q.get(remix, runset, q), hit_q)
        csv.emit(f"{fig}c_get_remix,R={r}", t / QBATCH * 1e6, "")
        bloom = build_bloom([np.asarray(run.keys) for run in runs])

        def bloom_get(q):
            maybe = bloom_maybe_contains(bloom, q)
            found, vals = M.merge_get(runset, q)
            return found & jnp.any(maybe, 1), vals

        t = time_batched(bloom_get, hit_q)
        csv.emit(f"{fig}c_get_sstable_bloom,R={r}", t / QBATCH * 1e6, "")
        t = time_batched(lambda q: M.merge_get(runset, q), hit_q)
        csv.emit(f"{fig}c_get_sstable_nobloom,R={r}", t / QBATCH * 1e6, "")

    # derived claims (weak locality): speedup at R=8 and R=16
    csv.emit(f"{fig}_analytic_cmp_merge,R=8",
             M.seek_comparison_cost(8, N_PER_TABLE),
             "comparisons/seek merging iterator")
    import math
    csv.emit(f"{fig}_analytic_cmp_remix,R=8",
             math.log2(8 * N_PER_TABLE / d) + math.log2(d),
             "comparisons/seek REMIX (anchor bsearch + in-group)")

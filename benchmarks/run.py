"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--only fig11,...]`` prints name,us_per_call,
derived CSV rows for every experiment (paper §5 scaled to this container).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import CSV


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of bench names")
    args = ap.parse_args()

    from benchmarks import (
        batch_bench,
        cache_bench,
        cluster_bench,
        cursor_bench,
        engine_bench,
        fig11_queries,
        fig13_groupsize,
        fig14_16_stores,
        fig17_ycsb,
        kernels_bench,
        rebuild_bench,
        scrub_bench,
        table1_storage,
    )

    benches = {
        "fig11": lambda c: fig11_queries.run(c, locality="weak"),
        "fig12": lambda c: fig11_queries.run(c, locality="strong"),
        "fig13": fig13_groupsize.run,
        "table1": table1_storage.run,
        "fig14_16": fig14_16_stores.run,
        "fig17": fig17_ycsb.run,
        "kernels": kernels_bench.run,
        "rebuild": rebuild_bench.run,
        # scrub throughput, paced scrub, REMIX repair round trip
        "scrub": scrub_bench.run,
        "cache": cache_bench.run,
        # also emits results/BENCH_queries.json (the perf trajectory file)
        "batch": batch_bench.run,
        # streaming cursor vs re-seeking scans (results/BENCH_cursor.json)
        "cursor": cursor_bench.run,
        # typed op batches through submit() (results/BENCH_engine.json)
        "engine": engine_bench.run,
        # live shard split + replica catch-up (results/BENCH_cluster.json)
        "cluster": cluster_bench.run,
    }
    if args.only:
        names = args.only.split(",")
    else:
        names = list(benches)
    csv = CSV()
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            benches[name](csv)
        except Exception:
            failures += 1
            traceback.print_exc()
            csv.emit(f"{name}_FAILED", -1.0, "exception")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

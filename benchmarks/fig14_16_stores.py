"""Fig 14–16: store-level benchmarks, scaled for the CPU container.

fig14: range query (seek+scan) throughput for RemixDB vs leveled vs tiered
       with different value sizes and access patterns.
fig15: range-scan throughput vs scan length (zipfian).
fig16: random-write throughput + write amplification.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import CSV, zipf_keys
from repro.db.baseline import BaselineConfig, LeveledStore, TieredStore
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig

N_KEYS = 120_000
MEM = 8192
CAP = 8192


def _build_stores(tmpdir: str, vw: int):
    db = RemixDB(
        RemixDBConfig(
            vw=vw, memtable_entries=MEM, wal_dir=tmpdir,
            compaction=CompactionConfig(table_cap=CAP, t_max=10),
        )
    )
    bcfg = BaselineConfig(vw=vw, memtable_entries=MEM, table_cap=CAP)
    return {"remixdb": db, "leveled": LeveledStore(bcfg), "tiered": TieredStore(bcfg)}


def _load(stores, keys, vw, csv=None, label=""):
    vals = np.zeros((len(keys), vw), np.uint32)
    vals[:, 0] = (keys & 0xFFFFFFFF).astype(np.uint32)
    for name, s in stores.items():
        t0 = time.perf_counter()
        for c in range(0, len(keys), MEM):
            s.put_batch(keys[c : c + MEM], vals[c : c + MEM])
        s.flush()
        dt = time.perf_counter() - t0
        if csv is not None:
            csv.emit(f"fig16_write_{label}_{name}", dt / len(keys) * 1e6,
                     f"WA={s.write_amplification():.2f}" if name != "remixdb"
                     else f"WA={s.table_bytes_written / max(1, s.user_bytes):.2f}")
    return stores


def _seek_throughput(stores, probes, scan_n, csv, tag):
    probes = np.asarray(probes, np.uint64)
    for name, s in stores.items():
        s.scan_batch(probes, scan_n)  # warmup at measurement shape
        t0 = time.perf_counter()
        s.scan_batch(probes, scan_n)
        dt = time.perf_counter() - t0
        csv.emit(f"{tag}_{name}", dt / len(probes) * 1e6, f"scan{scan_n}")


def run(csv: CSV):
    import tempfile

    rng = np.random.default_rng(11)
    # ---- fig14: value sizes × access patterns (seek-only ≈ scan 1) ----
    for vw, vname in ((2, "40B"), (8, "120B"), (25, "400B")):
        keys = rng.permutation(N_KEYS).astype(np.uint64) * 8
        stores = _build_stores(tempfile.mkdtemp(), vw)
        _load(stores, keys, vw)
        skeys = np.sort(keys)
        probes_seq = skeys[1000:1512]
        probes_uni = rng.choice(skeys, 512)
        probes_zipf = skeys[zipf_keys(rng, len(skeys), 512)]
        _seek_throughput(stores, probes_seq, 1, csv, f"fig14_seek_{vname}_seq")
        _seek_throughput(stores, probes_zipf, 1, csv, f"fig14_seek_{vname}_zipf")
        _seek_throughput(stores, probes_uni, 1, csv, f"fig14_seek_{vname}_uni")
        if vw == 8:
            # ---- fig15: scan lengths on the 120B store ----
            for scan_n in (10, 50, 200):
                _seek_throughput(
                    stores, probes_zipf[:256], scan_n, csv, f"fig15_scan{scan_n}"
                )
    # ---- fig16: random write + WA (fresh stores, dedicated run) ----
    keys = rng.permutation(N_KEYS).astype(np.uint64) * 8
    stores = _build_stores(tempfile.mkdtemp(), 8)
    _load(stores, keys, 8, csv=csv, label="120B")
    db = stores["remixdb"]
    csv.emit(
        "fig16_remixdb_wa_tables_plus_wal",
        db.write_amplification(),
        f"partitions={len(db.partitions)}",
    )

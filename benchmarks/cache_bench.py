"""Cold-start query cost: block cache + partial loads vs whole-table loads.

The experiment behind the block-cache subsystem: a persistent store is
built on disk (R tables x N entries + REMIX + manifest), then reopened two
ways and hit with the *first* query after recovery:

  - ``whole``  (``cold_reads=False``): PR-1 behaviour — the first query
    materializes the device RunSet, loading every section of every table;
  - ``cold``   (default): anchors binary search + bounded CKB restart-
    point seeks + single value/tomb block fetches through the shared
    LRU :class:`repro.io.blockcache.BlockCache`.

Reported per path: first-query latency, physical bytes read
(``store.disk_bytes_read()``, cache hits excluded) and the cache
hit/miss counters from ``store.stats()``. The acceptance bar is that a
cold point query reads < 10 % of the bytes the whole-table path reads.

Run directly (``python -m benchmarks.cache_bench``) or via
``python -m benchmarks.run --only cache``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import CSV
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.db.store import RemixDB, RemixDBConfig
from repro.db.wal import WAL
from repro.io.manifest import Storage

R_TABLES = 8
N_PER_TABLE = 1 << 17
D = 32
MAX_COLD_FRACTION = 0.10  # acceptance bar for a cold point query


def build_store(
    root: str,
    seed: int = 0,
    r_tables: int = R_TABLES,
    n_per_table: int = N_PER_TABLE,
    d: int = D,
) -> np.ndarray:
    """A committed single-partition store on disk; returns its key domain."""
    rng = np.random.default_rng(seed)
    total = r_tables * n_per_table
    domain = np.arange(1, total + 1, dtype=np.uint64) * 64
    owner = rng.integers(0, r_tables, total)
    storage = Storage(root)
    names, runs, seqbase = [], [], 1
    for i in range(r_tables):
        kk = domain[owner == i]
        run = make_run(
            kk, seq=np.arange(seqbase, seqbase + len(kk), dtype=np.uint32)
        )
        seqbase += len(kk)
        runs.append(run)
        names.append(
            storage.write_table(
                np.asarray(run.keys), np.asarray(run.vals),
                np.asarray(run.seq), np.asarray(run.tomb),
            )
        )
    remix, _ = build_remix(runs, d=d)
    xname = storage.write_remix(remix)
    wal = WAL(storage.wal_path())
    storage.commit(
        dict(
            seq=seqbase, vw=2, d=d,
            partitions=[dict(lo=0, tables=names, remix=xname)],
            wal=wal.save_state(),
        )
    )
    return domain


def _first_get(root: str, key: int, cold: bool):
    db = RemixDB.open(root, RemixDBConfig(cold_reads=cold))
    t0 = time.perf_counter()
    val = db.get(key)
    dt = time.perf_counter() - t0
    return db, val, dt, db.disk_bytes_read()


def run(csv: CSV) -> None:
    with tempfile.TemporaryDirectory(prefix="cache-bench-") as tmp:
        root = os.path.join(tmp, "db")
        domain = build_store(root)
        file_bytes = sum(
            os.path.getsize(os.path.join(root, "tables", f))
            for f in os.listdir(os.path.join(root, "tables"))
        )
        probe = int(domain[len(domain) // 3])

        db_w, v_w, t_whole, b_whole = _first_get(root, probe, cold=False)
        db_c, v_c, t_cold, b_cold = _first_get(root, probe, cold=True)
        if v_w is None or v_c is None or not np.array_equal(v_w, v_c):
            raise AssertionError(
                f"cold/whole point queries disagree: {v_c} vs {v_w}"
            )
        cache = db_c.stats()["cache"]

        # warm repeat: same partition, different key — counts cache hits
        t0 = time.perf_counter()
        db_c.get(int(domain[len(domain) // 7]))
        t_warm = time.perf_counter() - t0
        b_warm = db_c.disk_bytes_read() - b_cold

        # cold range scan: partial RunSet materialization per block range
        db_s = RemixDB.open(root)
        t0 = time.perf_counter()
        kk, _ = db_s.scan(int(domain[len(domain) // 2]), 100)
        t_scan = time.perf_counter() - t0
        b_scan = db_s.disk_bytes_read()
        k_ref, _ = db_w.scan(int(domain[len(domain) // 2]), 100)
        if not np.array_equal(kk, k_ref):
            raise AssertionError("cold scan disagrees with whole-table scan")

    frac = b_cold / max(1, b_whole)
    csv.emit(
        "cache_whole_get", t_whole * 1e6,
        f"bytes_read={b_whole};table_file_bytes={file_bytes}",
    )
    csv.emit(
        "cache_cold_get", t_cold * 1e6,
        f"bytes_read={b_cold};fraction_of_whole={frac:.4f};"
        f"cache_hits={cache['hits']};cache_misses={cache['misses']};"
        f"cache_evictions={cache['evictions']}",
    )
    csv.emit("cache_warm_get", t_warm * 1e6, f"extra_bytes_read={b_warm}")
    csv.emit(
        "cache_cold_scan100", t_scan * 1e6,
        f"bytes_read={b_scan};fraction_of_whole={b_scan / max(1, b_whole):.4f};"
        f"keys_returned={len(kk)}",
    )
    # the latency ratio is indicative only: the whole-table path's first
    # query also pays one-time jit compilation + device transfer, and it
    # runs first so the cold run sees a warmer OS page cache — the
    # byte counts (and the < 10 % assert) are the subsystem's real claim
    csv.emit(
        "cache_summary", 0.0,
        f"r_tables={R_TABLES};n_per_table={N_PER_TABLE};"
        f"cold_get_read_reduction={b_whole / max(1, b_cold):.1f}x;"
        f"first_query_speedup_incl_jit={t_whole / max(t_cold, 1e-9):.1f}x",
    )
    if frac >= MAX_COLD_FRACTION:
        raise AssertionError(
            f"cold point query read {frac:.1%} of the whole-table bytes "
            f"(acceptance bar: < {MAX_COLD_FRACTION:.0%})"
        )


if __name__ == "__main__":
    c = CSV()
    print("name,us_per_call,derived")
    run(c)

"""Model-based differential tests: RemixDB vs an in-memory reference.

The reference (:class:`ModelStore`) implements the full write surface —
put, delete, delete_range, CAS, TTL — as a plain dict with last-writer-
wins semantics. The harness drives both stores through the same op
sequence (interleaving flushes, compactions, snapshots, clock advances
and reopens) and asserts the merged views agree after every step.

Two drivers share one op vocabulary:

- a seeded ``random.Random`` walk (always runs; each failure message
  carries the seed, so shrinking by hand means re-running one seed);
- a hypothesis ``RuleBasedStateMachine`` (skipped when hypothesis is not
  installed; the nightly CI profile runs 500+ examples — see
  ``tests/conftest.py``).
"""
import os
import random
import shutil
import tempfile

import numpy as np
import pytest

from repro.db import clock
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig

VW = 2
KEYSPACE = 600
T0 = 1_000_000  # controlled epoch for the patchable clock


def _cfg(memtable_entries=128, table_cap=128, t_max=3):
    return RemixDBConfig(
        vw=VW,
        memtable_entries=memtable_entries,
        compaction=CompactionConfig(table_cap=table_cap, t_max=t_max),
        hot_threshold=255,
    )


@pytest.fixture(autouse=True)
def _reset_clock():
    yield
    clock.reset()


class ModelStore:
    """Reference last-writer-wins semantics over a dict.

    TTL is an absolute expiry stamp; an expired entry is
    indistinguishable from an absent one (so CAS with ``expect=None``
    succeeds on it, mirroring the store).
    """

    def __init__(self):
        self.data = {}  # key -> (val tuple, exp)

    def put(self, k, v, exp=0):
        self.data[int(k)] = (tuple(int(x) for x in v), int(exp))

    def delete(self, k):
        self.data.pop(int(k), None)

    def delete_range(self, lo, hi):
        for k in [k for k in self.data if lo <= k < hi]:
            del self.data[k]

    def get(self, k, now):
        e = self.data.get(int(k))
        if e is None:
            return None
        v, exp = e
        if exp and exp <= now:
            return None
        return v

    def cas(self, k, expect, val, now, exp=0):
        cur = self.get(k, now)
        if (cur is None) != (expect is None) or (
            cur is not None and cur != tuple(int(x) for x in expect)
        ):
            return False, cur
        if val is None:
            self.delete(k)
        else:
            self.put(k, val, exp)
        return True, cur

    def items(self, now):
        return sorted(
            (k, self.get(k, now))
            for k in self.data
            if self.get(k, now) is not None
        )


def _assert_agree(db, model, now, ctx=""):
    """Full differential check: scan, cursor stream, and point gets."""
    want = model.items(now)
    kk, vv = db.scan(0, KEYSPACE + 10)
    got = [(int(k), tuple(int(x) for x in v)) for k, v in zip(kk, vv)]
    assert got == want, f"scan != model {ctx}"
    with db.cursor(width=7) as cur:
        cur.seek(0)
        stream = [(k, tuple(int(x) for x in v)) for k, v in cur]
    assert stream == want, f"cursor != model {ctx}"
    probes = [k for k, _ in want[:16]] + [0, KEYSPACE // 2, KEYSPACE - 1]
    for k in probes:
        g = db.get(k)
        m = model.get(k, now)
        g = None if g is None else tuple(int(x) for x in g.reshape(-1))
        assert g == m, f"get({k}) = {g} != {m} {ctx}"


def _rand_val(rng):
    return [rng.randrange(1, 1 << 31) for _ in range(VW)]


def _step(db, model, rng, t, pending):
    """Apply one random op to both stores; returns the new clock time.

    ``pending`` collects (snapshot, frozen-model-items, taken-at) pairs
    verified and closed by the caller.
    """
    r = rng.random()
    now = int(clock.now())
    if r < 0.30:  # put (sometimes with TTL)
        k, v = rng.randrange(KEYSPACE), _rand_val(rng)
        ttl = rng.choice([None, None, 5, 50])
        db.put(k, np.array(v, np.uint32), ttl=ttl)
        model.put(k, v, exp=0 if ttl is None else now + ttl)
    elif r < 0.40:  # point delete
        k = rng.randrange(KEYSPACE)
        db.delete(k)
        model.delete(k)
    elif r < 0.52:  # delete_range
        lo = rng.randrange(KEYSPACE)
        hi = min(KEYSPACE, lo + rng.randrange(1, KEYSPACE // 3))
        db.delete_range(lo, hi)
        model.delete_range(lo, hi)
    elif r < 0.64:  # CAS (expect drawn from the model half the time)
        k = rng.randrange(KEYSPACE)
        cur = model.get(k, now)
        expect = cur if rng.random() < 0.5 else (
            None if rng.random() < 0.5 else _rand_val(rng))
        val = None if rng.random() < 0.2 else _rand_val(rng)
        ok_m, cur_m = model.cas(k, expect, val, now)
        ok_d, cur_d = db.cas(
            k,
            None if expect is None else np.array(expect, np.uint32),
            None if val is None else np.array(val, np.uint32),
        )
        cur_d = None if cur_d is None else tuple(
            int(x) for x in cur_d.reshape(-1))
        assert (ok_d, cur_d) == (ok_m, cur_m), f"cas({k})"
    elif r < 0.74:  # advance the clock (expires TTLs)
        t += rng.randrange(1, 40)
        clock.set_source(lambda t=t: float(t))
    elif r < 0.86:  # flush (freeze + compaction round)
        db.flush()
    else:  # pin a snapshot to verify later
        frozen = ModelStore()
        frozen.data = dict(model.data)
        pending.append((db.snapshot(), frozen))
    return t


def _verify_snapshots(pending):
    # a snapshot freezes the *data*, not the clock: TTL expiry stays
    # read-time, so the frozen model is evaluated at the current time
    now = int(clock.now())
    for snap, frozen in pending:
        kk, vv = snap.scan(0, KEYSPACE + 10)
        got = [(int(k), tuple(int(x) for x in v)) for k, v in zip(kk, vv)]
        assert got == frozen.items(now), "snapshot drifted from its view"
        snap.close()
    pending.clear()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_differential_random_walk(tmp_path, seed):
    """Seeded random walk over the full op surface, checked every step
    against the model, with a reopen (crash-free recovery) at the end."""
    rng = random.Random(seed)
    clock.set_source(lambda: float(T0))
    t = T0
    d = str(tmp_path / f"walk{seed}")
    db = RemixDB.open(d, _cfg())
    model = ModelStore()
    pending = []
    try:
        for i in range(140):
            t = _step(db, model, rng, t, pending)
            if i % 7 == 0:
                _assert_agree(db, model, int(clock.now()),
                              ctx=f"(seed={seed} step={i})")
        _verify_snapshots(pending)
        _assert_agree(db, model, int(clock.now()), ctx=f"(seed={seed})")
        # reopen: WAL replay + manifest recovery must agree too
        db.close()
        db = RemixDB.open(d, _cfg())
        _assert_agree(db, model, int(clock.now()),
                      ctx=f"(seed={seed} reopened)")
    finally:
        _verify_snapshots(pending)
        db.close()


@pytest.mark.nightly
@pytest.mark.parametrize("seed", range(20))
def test_differential_random_walk_long(tmp_path, seed):
    """Nightly: longer walks over more seeds (deeper compaction trees)."""
    rng = random.Random(1000 + seed)
    clock.set_source(lambda: float(T0))
    t = T0
    d = str(tmp_path / f"long{seed}")
    db = RemixDB.open(d, _cfg(memtable_entries=64, table_cap=64))
    model = ModelStore()
    pending = []
    try:
        for i in range(600):
            t = _step(db, model, rng, t, pending)
            if i % 25 == 0:
                _assert_agree(db, model, int(clock.now()),
                              ctx=f"(seed={seed} step={i})")
        _verify_snapshots(pending)
        db.close()
        db = RemixDB.open(d, _cfg(memtable_entries=64, table_cap=64))
        _assert_agree(db, model, int(clock.now()),
                      ctx=f"(seed={seed} reopened)")
    finally:
        _verify_snapshots(pending)
        db.close()


# ------------------------------------------------------------------
# hypothesis stateful machine (CI: deterministic profile; nightly: 500+
# examples — tests/conftest.py registers the profiles)
# ------------------------------------------------------------------
try:
    from hypothesis import strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        invariant,
        rule,
    )

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    keys_st = st.integers(0, KEYSPACE - 1)
    vals_st = st.lists(
        st.integers(1, 1 << 31), min_size=VW, max_size=VW
    )

    class StoreMachine(RuleBasedStateMachine):
        """Differential state machine: every rule applies one op to both
        stores; the invariant compares the merged views."""

        def __init__(self):
            super().__init__()
            self.dir = tempfile.mkdtemp(prefix="remix-model-")
            self.t = T0
            clock.set_source(lambda: float(self.t))
            self.db = RemixDB.open(self.dir, _cfg())
            self.model = ModelStore()

        # ---- write surface ----
        @rule(k=keys_st, v=vals_st, ttl=st.sampled_from([None, 5, 50]))
        def put(self, k, v, ttl):
            self.db.put(k, np.array(v, np.uint32), ttl=ttl)
            self.model.put(k, v, exp=0 if ttl is None else self.t + ttl)

        @rule(k=keys_st)
        def delete(self, k):
            self.db.delete(k)
            self.model.delete(k)

        @rule(lo=keys_st, n=st.integers(1, KEYSPACE // 3))
        def delete_range(self, lo, n):
            hi = min(KEYSPACE, lo + n)
            self.db.delete_range(lo, hi)
            self.model.delete_range(lo, hi)

        @rule(k=keys_st, v=vals_st, use_cur=st.booleans(),
              to_none=st.booleans())
        def cas(self, k, v, use_cur, to_none):
            expect = self.model.get(k, self.t) if use_cur else v
            val = None if to_none else v
            ok_m, cur_m = self.model.cas(k, expect, val, self.t)
            ok_d, cur_d = self.db.cas(
                k,
                None if expect is None else np.array(expect, np.uint32),
                None if val is None else np.array(val, np.uint32),
            )
            cur_d = None if cur_d is None else tuple(
                int(x) for x in cur_d.reshape(-1))
            assert (ok_d, cur_d) == (ok_m, cur_m)

        # ---- lifecycle edges ----
        @rule(dt=st.integers(1, 40))
        def advance_clock(self, dt):
            self.t += dt

        @rule()
        def flush(self):
            self.db.flush()

        @rule()
        def reopen(self):
            self.db.close()
            self.db = RemixDB.open(self.dir, _cfg())

        @invariant()
        def agrees(self):
            _assert_agree(self.db, self.model, self.t)

        def teardown(self):
            try:
                self.db.close()
            finally:
                clock.reset()
                shutil.rmtree(self.dir, ignore_errors=True)

    TestStoreMachine = StoreMachine.TestCase

"""Storage fault-injection acceptance matrix (tentpole PR drill).

The grid: {bit-flip, torn write, transient EIO} × {sstable, REMIX,
manifest, WAL}. Contract under test, per ISSUE acceptance criteria:

- every corruption is **detected** — a read either returns correct data
  or raises a typed :class:`CorruptionError` /
  :class:`UnavailableSpanError`; a silent wrong read is the only failure;
- **transient** faults are absorbed by the bounded retry (``io_retries``)
  — the op succeeds and the ``io_retry`` counter ticks;
- a corrupted REMIX is **auto-repaired** by the CKB rebuild (§3.4): after
  ``scrub()`` the store is clean and reads are bit-identical;
- **containment**: in a mixed batch only the ops touching the corrupt
  granule fail (``OpStatus.IO_ERROR``), the rest of the batch completes;
- nothing unverified is ever cached (file and mmap first-touch modes).

``faults`` marker: the seeded bit-rot matrix also runs nightly at a
wider seed grid (see ci.yml); a deterministic subset runs in tier-1.
"""
import glob
import os
import shutil

import numpy as np
import pytest

from repro.db.compaction import CompactionConfig
from repro.db.ops import Batch, Op, OpStatus
from repro.db.store import RemixDB, RemixDBConfig
from repro.io.faults import (CorruptionError, FaultPlan, TransientIOError,
                             UnavailableSpanError, flip_bytes)

pytestmark = pytest.mark.faults


def _cfg(plan=None, **kw):
    return RemixDBConfig(
        vw=2,
        memtable_entries=kw.pop("memtable_entries", 64),
        compaction=CompactionConfig(table_cap=256, t_max=4),
        hot_threshold=255,
        fault_plan=plan,
        **kw,
    )


def _fill(db, lo, hi, tag=1):
    ks = np.arange(lo, hi, dtype=np.uint64)
    vs = np.stack(
        [ks.astype(np.uint32), np.full(len(ks), tag, np.uint32)], 1
    )
    db.put_batch(ks, vs)
    return {int(k): (int(v[0]), int(v[1])) for k, v in zip(ks, vs)}


def _seed_store(d, n=500, flush=True):
    db = RemixDB.open(d, _cfg())
    model = _fill(db, 0, n)
    if flush:
        db.flush()
    db.close()
    return model


def _files(d, sub, pat):
    return sorted(glob.glob(os.path.join(d, sub, pat)))


def _check_never_wrong(db, model, hi=1 << 20):
    """The acceptance predicate: every observable outcome is correct
    data, a typed error, or a typed degraded span — never wrong bytes."""
    try:
        kk, vv = db.scan(0, hi)
    except (CorruptionError, UnavailableSpanError):
        pass
    else:
        got = {int(k): (int(v[0]), int(v[1])) for k, v in zip(kk, vv)}
        for k, v in got.items():
            assert model.get(k) == v, f"silent wrong read at {k}"
    for k in list(model)[:: max(1, len(model) // 16)]:
        try:
            v = db.get(k)
        except (CorruptionError, UnavailableSpanError):
            continue
        if v is not None:
            assert (int(v[0]), int(v[1])) == model[k]


# ------------------------------------------------ transient EIO × target
@pytest.mark.parametrize("target", [".sst", ".rmx", "MANIFEST", "wal.log"])
def test_transient_read_absorbed_by_retry(tmp_path, target):
    """One injected EIO per matching file: the bounded retry absorbs it,
    every read succeeds, and the retry counter ticks."""
    d = str(tmp_path / "db")
    model = _seed_store(d)
    plan = FaultPlan(seed=7).transient_read(target, count=1)
    db = RemixDB.open(d, _cfg(plan=plan, io_retries=2))
    try:
        kk, vv = db.scan(0, 1 << 20)
        got = {int(k): (int(v[0]), int(v[1])) for k, v in zip(kk, vv)}
        assert got == model
        assert plan.stats()["transient_read"] >= 1
        assert db.registry.counter("io_retry").value >= 1
        assert db.registry.counter("io_giveup").value == 0
        assert db.health()["io"]["retries"] >= 1
    finally:
        db.close()


def test_transient_read_giveup_is_typed(tmp_path):
    """More consecutive EIOs than the retry budget: the op fails with the
    typed TransientIOError (an OSError/EIO) and io_giveup ticks — never a
    silent empty result."""
    d = str(tmp_path / "db")
    _seed_store(d)
    plan = FaultPlan(seed=7).transient_read(".sst", count=50)
    db = RemixDB.open(d, _cfg(plan=plan, io_retries=2))
    try:
        with pytest.raises(TransientIOError):
            db.scan(0, 1 << 20)
        assert db.registry.counter("io_giveup").value >= 1
    finally:
        db.close()


# --------------------------------------------------- bit-flip × target
def test_bitflip_sstable_detected_and_quarantined(tmp_path):
    """At-rest bit rot in a value granule: reads raise typed errors (no
    wrong bytes), scrub quarantines the table, the degraded span is
    typed, keys outside it keep serving, and the state survives reopen."""
    d = str(tmp_path / "db")
    model = _seed_store(d)
    sst = _files(d, "tables", "*.sst")
    assert len(sst) >= 2
    db = RemixDB.open(d, _cfg())
    try:
        # flip inside the *vals* section so the key span of the
        # quarantined table is still extractable from the (intact) CKB
        rd = db.partitions[0].tables[0]._rd()
        lo, _hi = rd._section_range("vals")
        db.close()
        flip_bytes(sst[0], lo + 8, 4)

        db = RemixDB.open(d, _cfg())
        _check_never_wrong(db, model)
        rep = db.scrub(full=True)
        assert not rep["clean"]
        assert [f["kind"] for f in rep["findings"]] == ["table"]
        # the finding pins the checksum granule (the section label is the
        # granule's first byte — granules span section boundaries)
        assert rep["findings"][0]["blocks"]
        assert rep["quarantined"] == [os.path.basename(sst[0])]
        h = db.health()
        assert h["status"] == "degraded"
        span = h["unavailable"][0]
        assert span["tables"] == [os.path.basename(sst[0])]
        # inside the span: typed refusal; outside: correct data
        with pytest.raises(UnavailableSpanError):
            db.get(int(span["lo"]))
        if span["hi"] is not None and span["hi"] + 1 in model:
            ok = db.get(span["hi"] + 1)
            assert (int(ok[0]), int(ok[1])) == model[span["hi"] + 1]
        with pytest.raises(UnavailableSpanError):
            db.scan(0, 10)
        _check_never_wrong(db, model)
        db.close()

        # degradation is manifest state: it survives a clean reopen
        db = RemixDB.open(d, _cfg())
        assert db.health()["status"] == "degraded"
        with pytest.raises(UnavailableSpanError):
            db.get(int(span["lo"]))
        _check_never_wrong(db, model)
    finally:
        db.close()


def test_bitflip_remix_auto_repaired(tmp_path):
    """At-rest bit rot in the REMIX: open degrades (never crashes),
    scrub rebuilds the index from the CKBs and commits it, and reads are
    bit-identical to the pre-corruption store."""
    d = str(tmp_path / "db")
    db = RemixDB.open(d, _cfg())
    _fill(db, 0, 500)
    db.flush()
    kk0, vv0 = db.scan(0, 1 << 20)
    db.close()
    rx = _files(d, "remix", "*.rmx")
    assert rx
    flip_bytes(rx[0], 100, 4)

    db = RemixDB.open(d, _cfg())
    try:
        rep = db.scrub(full=True)
        assert not rep["clean"]
        assert [f["kind"] for f in rep["findings"]] == ["remix"]
        assert len(rep["repaired"]) == 1
        assert db.registry.counter("repair_remix_rebuilt").value == 1
        assert db.scrub(full=True)["clean"]
        kk, vv = db.scan(0, 1 << 20)
        assert np.array_equal(kk, kk0) and np.array_equal(vv, vv0)
        assert db.health()["status"] == "ok"
    finally:
        db.close()
    # the repaired index is the committed one after reopen too
    db = RemixDB.open(d, _cfg())
    try:
        assert db.scrub(full=True)["clean"]
        kk, vv = db.scan(0, 1 << 20)
        assert np.array_equal(kk, kk0) and np.array_equal(vv, vv0)
    finally:
        db.close()


def test_bitflip_manifest_detected(tmp_path):
    """Bit rot in the manifest body: reopen raises the typed
    CorruptionError (the manifest is the root of trust — nothing to
    rebuild it from), and a live store's scrub pins the finding."""
    d = str(tmp_path / "db")
    _seed_store(d)
    mf = _files(d, ".", "MANIFEST-*")
    flip_bytes(mf[0], 10, 4)
    with pytest.raises(CorruptionError) as ei:
        RemixDB.open(d, _cfg())
    assert ei.value.section == "manifest"


def test_bitflip_current_mismatch_scrubbed(tmp_path):
    """CURRENT / manifest-body version disagreement surfaces as a
    manifest finding (detection only, no repair invented)."""
    d = str(tmp_path / "db")
    _seed_store(d)
    db = RemixDB.open(d, _cfg())
    try:
        state = db.storage.manifest.load()
        ver = state["version"]
        # forge a stale CURRENT pointing at a renamed copy of the body
        body = os.path.join(d, f"MANIFEST-{ver:06d}")
        forged = os.path.join(d, f"MANIFEST-{ver + 7:06d}")
        shutil.copy(body, forged)
        with open(os.path.join(d, "CURRENT"), "w") as f:
            f.write(os.path.basename(forged) + "\n")
        rep = db.scrub(full=True, repair=False)
        assert [f["kind"] for f in rep["findings"]] == ["manifest"]
    finally:
        # restore so close() can commit
        with open(os.path.join(d, "CURRENT"), "w") as f:
            f.write(os.path.basename(body) + "\n")
        os.remove(forged)
        db.close()


def test_bitflip_wal_detected(tmp_path):
    """Bit rot inside a committed WAL block: replay is strict — reopen
    raises the typed CorruptionError instead of resurrecting a partial
    or wrong MemTable."""
    d = str(tmp_path / "db")
    db = RemixDB.open(d, _cfg(memtable_entries=1 << 30))
    _fill(db, 0, 300)  # stays in the WAL: no flush
    db.close()  # commits a manifest whose state references the WAL blocks
    flip_bytes(os.path.join(d, "wal.log"), 100, 4)
    with pytest.raises(CorruptionError) as ei:
        RemixDB.open(d, _cfg(memtable_entries=1 << 30))
    assert ei.value.section == "wal"


# --------------------------------------------------- torn write × target
def test_torn_write_sstable_detected(tmp_path):
    """A torn table write (flush survives in memory, bytes truncated on
    disk): reopen detects it — typed, never a partial table served."""
    d = str(tmp_path / "db")
    plan = FaultPlan(seed=3).torn_write(".sst", keep=0.5, count=1)
    db = RemixDB.open(d, _cfg(plan=plan))
    model = _fill(db, 0, 500)
    db.flush()
    # in-process reads still come from the resident tables: correct
    kk, vv = db.scan(0, 1 << 20)
    assert len(kk) == len(model)
    db.close()
    assert plan.stats()["torn_write"] == 1
    try:
        db2 = RemixDB.open(d, _cfg())
    except CorruptionError:
        return  # detected at open: typed, acceptable
    try:
        # reads over the truncated granules are typed, never wrong
        _check_never_wrong(db2, model)
        rep = db2.scrub(full=True, repair=False)
        assert not rep["clean"]
        assert any(f["kind"] == "table" for f in rep["findings"])
    finally:
        db2.close()


def test_torn_write_manifest_detected(tmp_path):
    """A torn manifest body: reopen raises typed CorruptionError
    (undecodable JSON) — the commit never silently half-applies."""
    d = str(tmp_path / "db")
    plan = FaultPlan(seed=3).torn_write("MANIFEST", keep=0.4, count=1)
    # no flush: the only manifest commit is close()'s — the torn one
    db = RemixDB.open(d, _cfg(plan=plan, memtable_entries=1 << 30))
    _fill(db, 0, 500)
    db.close()
    assert plan.stats()["torn_write"] == 1
    with pytest.raises(CorruptionError) as ei:
        RemixDB.open(d, _cfg())
    assert ei.value.section == "manifest"


def test_torn_write_wal_never_wrong(tmp_path):
    """A torn WAL block write: recovery may lose the torn tail (the disk
    lied about durability) but never serves wrong bytes — every
    recovered key has its exact pre-crash value."""
    d = str(tmp_path / "db")
    plan = FaultPlan(seed=3).torn_write("wal.log", keep=0.5, count=1)
    db = RemixDB.open(d, _cfg(plan=plan, memtable_entries=1 << 30))
    model = _fill(db, 0, 200)
    db.close()
    assert plan.stats()["torn_write"] >= 1
    try:
        db2 = RemixDB.open(d, _cfg(memtable_entries=1 << 30))
    except CorruptionError:
        return  # strict replay refused the torn block: detected, typed
    try:
        kk, vv = db2.scan(0, 1 << 20)
        for k, v in zip(kk, vv):
            assert model[int(k)] == (int(v[0]), int(v[1]))
    finally:
        db2.close()


def test_failed_fsync_surfaces(tmp_path):
    """A dying disk failing fsync: the write path raises (acknowledge
    nothing), it is not swallowed."""
    d = str(tmp_path / "db")
    plan = FaultPlan(seed=3).fail_fsync(".sst", count=1)
    db = RemixDB.open(d, _cfg(plan=plan, memtable_entries=1 << 30))
    _fill(db, 0, 500)
    with pytest.raises(OSError):
        db.flush()


# ------------------------------------------------------- containment
def test_containment_mixed_batch(tmp_path):
    """A mixed batch over a store whose one granule is corrupt: only the
    ops touching it get IO_ERROR; the rest of the batch completes. The
    whole batch never dies and nothing wrong is returned."""
    d = str(tmp_path / "db")
    model = _seed_store(d)
    sst = _files(d, "tables", "*.sst")
    db = RemixDB.open(d, _cfg())
    try:
        rd = db.partitions[0].tables[0]._rd()
        lo, _ = rd._section_range("vals")
        db.close()
        flip_bytes(sst[0], lo + 8, 4)

        db = RemixDB.open(d, _cfg())
        rep = db.scrub(full=True)  # quarantine + degrade the span
        assert rep["quarantined"]
        span = db.health()["unavailable"][0]
        bad_key = int(span["lo"])
        good_key = (
            span["hi"] + 1 if span["hi"] is not None else None
        )
        ops = [Op.get(bad_key), Op.put(10**9, np.array([7, 7], np.uint32)),
               Op.get(10**9)]
        if good_key is not None and good_key in model:
            ops.append(Op.get(good_key))
            ops.append(Op.multiget([good_key, bad_key]))
        res = db.submit(Batch(ops), sync=True).result()
        sts = [r.status for r in res.results]
        assert sts[0] == OpStatus.IO_ERROR  # the touching op, and only it
        assert sts[1] == OpStatus.OK and sts[2] == OpStatus.OK
        if good_key is not None and good_key in model:
            assert sts[3] == OpStatus.OK
            v = res.results[3].value
            assert (int(v[0]), int(v[1])) == model[good_key]
            # multiget touching the span degrades as one op — typed
            assert sts[4] == OpStatus.IO_ERROR
        with pytest.raises(UnavailableSpanError):
            res.results[0].raise_if_error()
        assert res.stats["io_errors"] >= 1
        assert db.engine().stats()["io_errors"] >= 1
    finally:
        db.close()


def test_containment_transient_multiget_isolated(tmp_path):
    """An unhealing transient fault on one table: the multiget's
    isolation fallback re-executes per key, so only the keys routed to
    the faulty granule fail; the batch itself still completes."""
    d = str(tmp_path / "db")
    model = _seed_store(d)
    sst = _files(d, "tables", "*.sst")
    # fault only the *first* table file, forever (beyond the budget)
    plan = FaultPlan(seed=5).transient_read(
        os.path.basename(sst[0]), count=-1
    )
    db = RemixDB.open(d, _cfg(plan=plan, io_retries=1))
    try:
        keys = sorted(model)
        res = db.submit(
            Batch([Op.multiget(keys[:4]), Op.multiget(keys[-4:])]),
            sync=True,
        ).result()
        sts = [r.status for r in res.results]
        # at least one side fails typed; any OK side returned exact data
        assert OpStatus.IO_ERROR in sts
        for r, ks in zip(res.results, (keys[:4], keys[-4:])):
            if r.status == OpStatus.OK:
                for j, k in enumerate(ks):
                    assert (int(r.vals[j][0]), int(r.vals[j][1])) \
                        == model[k]
    finally:
        db.close()


# --------------------------------------- cache hygiene (never unverified)
@pytest.mark.parametrize("mode", ["copy", "mmap"])
def test_unverified_bytes_never_cached(tmp_path, mode):
    """Corrupt granule read through either cache mode: the typed error
    fires on every access (first touch and after), and once the bytes are
    restored the same reader serves correct data — proving the poisoned
    bytes were never admitted to the cache."""
    d = str(tmp_path / "db")
    model = _seed_store(d)
    sst = _files(d, "tables", "*.sst")
    db = RemixDB.open(d, _cfg(cache_mode=mode))
    try:
        rd = db.partitions[0].tables[0]._rd()
        lo, _ = rd._section_range("vals")
        db.close()
        flip_bytes(sst[0], lo + 8, 4)
        db = RemixDB.open(d, _cfg(cache_mode=mode))
        with pytest.raises(CorruptionError):
            db.scan(0, 1 << 20)
        with pytest.raises(CorruptionError):  # and again: not cached
            db.scan(0, 1 << 20)
        db.close()
        flip_bytes(sst[0], lo + 8, 4)  # heal the bytes (XOR is invertible)
        db = RemixDB.open(d, _cfg(cache_mode=mode))
        kk, vv = db.scan(0, 1 << 20)
        got = {int(k): (int(v[0]), int(v[1])) for k, v in zip(kk, vv)}
        assert got == model
    finally:
        db.close()


# ------------------------------------------------------ quarantine purge
def test_quarantine_age_purge(tmp_path):
    """Quarantined files are kept for forensics, then age-purged: an old
    file goes, a fresh one stays, and the counter ticks."""
    d = str(tmp_path / "db")
    _seed_store(d)
    db = RemixDB.open(
        d, _cfg(quarantine_purge_age_s=3600.0)
    )
    try:
        qdir = db.storage.quarantine_dir
        os.makedirs(qdir, exist_ok=True)
        old = os.path.join(qdir, "t-old.sst")
        fresh = os.path.join(qdir, "t-fresh.sst")
        for p in (old, fresh):
            with open(p, "wb") as f:
                f.write(b"x" * 64)
        past = os.path.getmtime(old) - 7200
        os.utime(old, (past, past))
        rep = db.scrub(full=True)
        assert rep["clean"]
        assert not os.path.exists(old)
        assert os.path.exists(fresh)
        assert db.registry.counter("quarantine_purged").value == 1
        assert db.health()["repair"]["quarantine_purged"] == 1
        kinds = [e.kind for e in db.events.list()]
        assert "quarantine_purge" in kinds
    finally:
        db.close()


# ------------------------------------- seeded bit-rot property (satellite)
def _bitrot_roundtrip(tmp_path, seed):
    """Flip one seeded random byte anywhere under the store, reopen, and
    drive scans + probes + scrub: every outcome must be correct data, a
    typed error, or a quarantined span — never silently wrong."""
    import random

    rng = random.Random(seed)
    d = str(tmp_path / f"db{seed}")
    model = _seed_store(d, n=400)
    files = []
    for root, _, fs in os.walk(d):
        files.extend(os.path.join(root, f) for f in fs)
    victim = rng.choice(sorted(files))
    off = rng.randrange(max(1, os.path.getsize(victim)))
    flip_bytes(victim, off, 1)

    try:
        db = RemixDB.open(d, _cfg())
    except CorruptionError:
        return  # detected at open: typed, acceptable
    try:
        _check_never_wrong(db, model)
        try:
            db.scrub(full=True)
        except (CorruptionError, TransientIOError):
            pass  # a scrub read hitting the rot is itself typed
        _check_never_wrong(db, model)
    finally:
        db.close()
    # and again after any repair committed
    try:
        db = RemixDB.open(d, _cfg())
    except CorruptionError:
        return
    try:
        _check_never_wrong(db, model)
    finally:
        db.close()


@pytest.mark.parametrize("seed", range(4))
def test_bitrot_property_deterministic(tmp_path, seed):
    """Tier-1 subset of the seeded bit-rot property."""
    _bitrot_roundtrip(tmp_path, seed)


@pytest.mark.nightly
@pytest.mark.parametrize("seed", range(4, 36))
def test_bitrot_property_matrix(tmp_path, seed):
    """Nightly: the wide seed grid of the same property."""
    _bitrot_roundtrip(tmp_path, seed)


# -------------------------------------- background scrubber + serve tier
def test_background_scrub_thread(tmp_path):
    """The interval-driven scrubber runs, records passes, and is joined
    cleanly at close."""
    import time

    d = str(tmp_path / "db")
    _seed_store(d)
    db = RemixDB.open(d, _cfg(scrub_interval_s=0.05))
    try:
        deadline = time.monotonic() + 10.0
        while (time.monotonic() < deadline
               and db.registry.counter("scrub_passes").value == 0):
            time.sleep(0.02)
        assert db.registry.counter("scrub_passes").value >= 1
        assert db.health()["scrub"]["last"] is not None
        assert db.health()["scrub"]["last"]["clean"]
    finally:
        db.close()
    assert db._scrub_thread is None  # joined, not leaked


def test_serve_engine_health_and_scrub(tmp_path):
    """KVServeEngine aggregates shard healths and fans scrub() out: a
    corruption on one shard degrades the node view but not the other
    shard's span."""
    from repro.serve.engine import KVServeEngine

    d0, d1 = str(tmp_path / "s0"), str(tmp_path / "s1")
    _seed_store(d0, n=200)
    # second shard over a disjoint key range
    db = RemixDB.open(d1, _cfg())
    _fill(db, 1000, 1200)
    db.flush()
    db.close()

    eng = KVServeEngine([(0, d0), (1000, d1)], config=_cfg())
    try:
        assert eng.health()["status"] == "ok"
        reports = eng.scrub(full=True)
        assert len(reports) == 2 and all(r["clean"] for r in reports)
    finally:
        eng.close()

    sst = _files(d0, "tables", "*.sst")
    flip_bytes(sst[0], os.path.getsize(sst[0]) // 2, 4)
    eng = KVServeEngine([(0, d0), (1000, d1)], config=_cfg())
    try:
        reports = eng.scrub(full=True)
        assert not reports[0]["clean"] and reports[1]["clean"]
        h = eng.health()
        assert h["status"] == "degraded"
        assert h["shards"]["0"]["status"] == "degraded"
        assert h["shards"]["1000"]["status"] == "ok"
        assert h["corruption_detected"] >= 1
        # the healthy shard keeps serving
        v = eng.get(1005)
        assert v is not None and int(v[0]) == 1005
        with pytest.raises(UnavailableSpanError):
            eng.get(0)
    finally:
        eng.close()

"""Operation-layer (API v2) tests: typed batches through submit().

Covered here:
  - mixed Batch == the equivalent sequence of legacy calls (explicit
    cases + a hypothesis property sweep over random op sequences)
  - cross-shard mixed batches on KVServeEngine (fan-out / fan-in) and
    the serve parity surface (scan_batch, put/put_batch/delete)
  - error paths: per-op deadline-exceeded without poisoning the batch,
    cancellation (queued and mid-run) releasing pinned Versions,
    mid-scan interruption through the cursor hook
  - admission control: backpressure, byte accounting, deadline expiry
    while queued
  - background compaction: sync-mode equivalence, reads during the
    round, recovery after close
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.db.executor import AdmissionController, Executor
from repro.db.ops import Batch, Op, OpInterrupted, OpKind, OpStatus
from repro.db.store import RemixDB, RemixDBConfig


def _mem_cfg(**kw) -> RemixDBConfig:
    return RemixDBConfig(memtable_entries=1 << 30, **kw)


def _fill(db, lo=1, n=300, step=7):
    keys = np.arange(lo, lo + n, dtype=np.uint64) * step
    vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
    db.put_batch(keys, vals)
    return keys


# ---------------------------------------------------------------- mixed
def _apply_legacy(db, ops):
    """Issue ops through the legacy methods, in order."""
    out = []
    for op in ops:
        if op.kind is OpKind.GET:
            out.append(db.get(op.key))
        elif op.kind is OpKind.MULTIGET:
            out.append(db.get_batch(op.keys))
        elif op.kind is OpKind.SCAN:
            out.append(db.scan(op.start, op.n))
        elif op.kind is OpKind.PUT:
            if op.keys is None:
                out.append(db.put(op.key, op.val))
            else:
                out.append(db.put_batch(op.keys, op.val))
        else:
            out.append(db.delete(op.key))
    return out


def _check_equiv(ops, legacy, res):
    assert res.ok, [r.status for r in res.results]
    for op, ref, r in zip(ops, legacy, res.results):
        if op.kind is OpKind.GET:
            assert (ref is not None) == bool(r.found)
            if ref is not None:
                np.testing.assert_array_equal(ref, r.value)
        elif op.kind is OpKind.MULTIGET:
            np.testing.assert_array_equal(ref[0], r.found)
            np.testing.assert_array_equal(ref[1], r.vals)
        elif op.kind is OpKind.SCAN:
            np.testing.assert_array_equal(ref[0], r.keys)
            np.testing.assert_array_equal(ref[1], r.vals)


def test_mixed_batch_equals_legacy_sequence():
    db_a, db_b = RemixDB(_mem_cfg()), RemixDB(_mem_cfg())
    for db in (db_a, db_b):
        _fill(db)
    ops = [
        Op.get(7),
        Op.put(7, [9, 9]),
        Op.get(7),  # must observe the put (write edge between reads)
        Op.scan(0, 10),
        Op.delete(14),
        Op.get(14),
        Op.multiget([7, 14, 21, 99999]),
        Op.put(np.array([50, 51], np.uint64), np.ones((2, 2), np.uint32)),
        Op.scan(49, 4),
    ]
    legacy = _apply_legacy(db_b, ops)
    res = db_a.submit(Batch(list(ops)), sync=True).result()
    _check_equiv(ops, legacy, res)
    # same final store contents
    ka, va = db_a.scan(0, 1000)
    kb, vb = db_b.scan(0, 1000)
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)
    # stats reflect the batch structure
    assert res.stats["ops"] == len(ops)
    assert res.stats["kinds"]["get"] == 3


def test_mixed_batch_property_equals_legacy():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    key = st.integers(0, 120)

    def to_op(draw_tuple):
        kind, k, n = draw_tuple
        if kind == "get":
            return Op.get(k)
        if kind == "put":
            return Op.put(k, [k & 0xFFFFFFFF, n])
        if kind == "delete":
            return Op.delete(k)
        if kind == "scan":
            return Op.scan(k, n)
        return Op.multiget(np.array([k, k + n, k * 2], np.uint64))

    op_strategy = st.tuples(
        st.sampled_from(["get", "put", "delete", "scan", "mget"]),
        key,
        st.integers(1, 12),
    ).map(to_op)

    @given(st.lists(op_strategy, min_size=1, max_size=16),
           st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=25, deadline=None)
    def check(ops, seed):
        db_a, db_b = RemixDB(_mem_cfg()), RemixDB(_mem_cfg())
        rng = np.random.default_rng(seed)
        base = rng.choice(120, size=40, replace=False).astype(np.uint64)
        for db in (db_a, db_b):
            db.put_batch(
                base,
                np.stack([base, np.zeros_like(base)], 1).astype(np.uint32),
            )
        legacy = _apply_legacy(db_b, ops)
        res = db_a.submit(Batch(list(ops)), sync=True).result()
        _check_equiv(ops, legacy, res)
        ka, _ = db_a.scan(0, 500)
        kb, _ = db_b.scan(0, 500)
        np.testing.assert_array_equal(ka, kb)

    check()


def test_mixed_batch_cross_shard_serve(tmp_path):
    from repro.serve.engine import KVServeEngine

    split = 1 << 32
    roots = []
    for i, lo in enumerate((0, split)):
        root = str(tmp_path / f"s{i}")
        db = RemixDB.open(root, _mem_cfg())
        _fill(db, lo=lo // 7 + 1, n=200)
        db.flush()
        db.close()
        roots.append(root)
    eng = KVServeEngine(
        [(0, roots[0]), (split, roots[1])],
        config=RemixDBConfig(promote_fraction=1e9),
    )
    k0, k1 = 7, (split // 7 + 1) * 7
    ops = [
        Op.get(k0),
        Op.get(k1),
        Op.multiget(np.array([k0, k1, 5], np.uint64)),  # spans both shards
        Op.scan(k0, 5),
        Op.scan(k1, 5),
        Op.put(split + 42, [4, 2]),
        Op.get(split + 42),
    ]
    res = eng.submit(Batch(list(ops)), sync=True).result()
    assert res.ok
    # equals the legacy per-op calls
    assert np.array_equal(res.results[0].value, eng.get(k0))
    assert np.array_equal(res.results[1].value, eng.get(k1))
    f, v = eng.get_batch(np.array([k0, k1, 5], np.uint64))
    np.testing.assert_array_equal(res.results[2].found, f)
    np.testing.assert_array_equal(res.results[2].vals, v)
    kk, vv = eng.scan(k1, 5)
    np.testing.assert_array_equal(res.results[4].keys, kk)
    # the put landed on shard 1's memtable, not shard 0's
    assert eng.shards[1].mem.get(split + 42) is not None
    assert eng.shards[0].mem.get(split + 42) is None
    eng.close()


def test_serve_scan_batch_and_writes(tmp_path):
    from repro.serve.engine import KVServeEngine

    split = 1 << 32
    for i, lo in enumerate((0, split)):
        db = RemixDB.open(str(tmp_path / f"s{i}"), _mem_cfg())
        _fill(db, lo=lo // 7 + 1, n=150)
        db.flush()
        db.close()
    eng = KVServeEngine(
        [(0, str(tmp_path / "s0")), (split, str(tmp_path / "s1"))],
        config=RemixDBConfig(promote_fraction=1e9),
    )
    # scan_batch == per-start legacy scans (including a cross-shard one)
    starts = np.array([7, split - 10, (split // 7 + 2) * 7], np.uint64)
    out_k, out_m = eng.scan_batch(starts, 6)
    for i, s in enumerate(starts.tolist()):
        kk, _ = eng.scan(s, 6)
        np.testing.assert_array_equal(out_k[i, : len(kk)], kk)
        assert out_m[i, : len(kk)].all() and not out_m[i, len(kk):].any()
    # vectorized cross-shard put_batch + delete
    wk = np.array([3, split + 3], np.uint64)
    eng.put_batch(wk, np.full((2, 2), 5, np.uint32))
    assert eng.get(3).tolist() == [5, 5]
    assert eng.get(split + 3).tolist() == [5, 5]
    eng.delete(3)
    assert eng.get(3) is None
    eng.close()


# ------------------------------------------------------------ deadlines
def test_deadline_exceeded_does_not_poison_batch():
    db = RemixDB(_mem_cfg())
    keys = _fill(db)
    ops = [
        Op.get(int(keys[0]), deadline_ms=-1.0),  # already expired
        Op.get(int(keys[1])),
        Op.scan(0, 5, deadline_ms=-1.0),
        Op.put(123456, [1, 2], deadline_ms=-1.0),  # expired write: skipped
        Op.multiget(keys[:4]),
    ]
    res = db.submit(Batch(ops), sync=True).result()
    assert res.results[0].status is OpStatus.DEADLINE_EXCEEDED
    assert res.results[1].ok and res.results[1].found
    assert res.results[2].status is OpStatus.DEADLINE_EXCEEDED
    assert res.results[3].status is OpStatus.DEADLINE_EXCEEDED
    assert res.results[4].ok
    assert db.get(123456) is None  # the expired put never applied
    assert res.stats["deadline_exceeded"] == 3
    assert not res.ok


def test_cursor_interrupt_hook():
    from repro.db.cursor import RemixCursor

    db = RemixDB(_mem_cfg())
    _fill(db, n=500)
    db.flush()
    calls = [0]

    def boom():
        calls[0] += 1
        if calls[0] > 2:
            raise OpInterrupted(OpStatus.DEADLINE_EXCEEDED)

    with db.snapshot() as snap:
        cur = RemixCursor(snap, width=8, interrupt=boom)
        cur.seek(0)
        with pytest.raises(OpInterrupted):
            while cur.next() is not None:
                pass
    assert calls[0] > 2


# --------------------------------------------------------- cancellation
def test_queued_cancel_releases_nothing_and_raises(tmp_path):
    db = RemixDB.open(str(tmp_path / "db"), _mem_cfg(submit_workers=1))
    keys = _fill(db)
    db.flush()
    gate = threading.Event()
    entered = threading.Event()
    orig = db._get_batch_at

    def blocked(view, qk):
        entered.set()
        gate.wait(10)
        return orig(view, qk)

    db._get_batch_at = blocked
    try:
        f1 = db.submit(Batch([Op.multiget(keys[:4])]))  # occupies worker
        assert entered.wait(10)
        f2 = db.submit(Batch([Op.multiget(keys[:4])]))  # queued behind it
        assert f2.cancel()  # still queued: cancels outright
        gate.set()
        assert f1.result(timeout=10).ok
        with pytest.raises(Exception):
            f2.result(timeout=10)
    finally:
        db._get_batch_at = orig
        gate.set()
    # no pinned Versions leaked by either future
    assert db.versions.stats()["pinned"] == 0
    db.close()


def test_midrun_cancel_marks_remaining_ops_and_releases_pins(tmp_path):
    db = RemixDB.open(str(tmp_path / "db"), _mem_cfg(submit_workers=1))
    keys = _fill(db)
    db.flush()
    gate = threading.Event()
    entered = threading.Event()
    orig = db._get_batch_at

    def blocked(view, qk):
        entered.set()
        gate.wait(10)
        return orig(view, qk)

    db._get_batch_at = blocked
    try:
        # two point groups cannot exist on one shard, so force two
        # stages with a write edge: [mget] [put] [mget]
        fut = db.submit(
            Batch([
                Op.multiget(keys[:4]),
                Op.put(999999, [1, 1]),
                Op.multiget(keys[:4]),
            ])
        )
        assert entered.wait(10)
        assert not fut.cancel()  # running: cooperative interruption
        gate.set()
        res = fut.result(timeout=10)
    finally:
        db._get_batch_at = orig
        gate.set()
    assert res.results[0].ok  # in-flight group completed
    assert res.results[1].status is OpStatus.CANCELLED
    assert res.results[2].status is OpStatus.CANCELLED
    assert db.get(999999) is None
    assert db.versions.stats()["pinned"] == 0
    db.close()


# ------------------------------------------------------------ admission
def test_admission_controller_backpressure():
    adm = AdmissionController(100)
    assert adm.acquire(80)
    got = []

    def second():
        got.append(adm.acquire(50))

    t = threading.Thread(target=second)
    t.start()
    time.sleep(0.05)
    assert not got  # blocked: 80 + 50 > 100
    adm.release(80)
    t.join(5)
    assert got == [True]
    adm.release(50)
    s = adm.stats()
    assert s["inflight_bytes"] == 0 and s["waits"] == 1
    assert s["peak_bytes"] == 80
    # deadline expiry while waiting
    assert adm.acquire(100)
    assert not adm.acquire(10, deadline_at=time.monotonic() + 0.01)
    adm.release(100)
    # sole-occupancy: an over-budget batch admits when idle
    assert adm.acquire(10_000)
    adm.release(10_000)


def test_submit_deadline_expires_while_queued():
    db = RemixDB(_mem_cfg(max_inflight_bytes=64))
    _fill(db, n=10)
    eng = db.engine()
    # fill the budget so the next batch waits, with a deadline that fires
    assert eng.admission.acquire(64)
    try:
        fut = db.submit(
            Batch([Op.get(7, deadline_ms=30.0), Op.get(14, deadline_ms=30.0)]),
            sync=True,
        )
        res = fut.result(timeout=10)
        assert all(
            r.status is OpStatus.DEADLINE_EXCEEDED for r in res.results
        )
        assert not res.stats["executed"]
    finally:
        eng.admission.release(64)
    # budget free again: same ops execute fine
    assert db.submit(Batch([Op.get(7)]), sync=True).result().ok


# ------------------------------------------------- background compaction
def test_background_compaction_equivalence(tmp_path):
    cfg_bg = RemixDBConfig(memtable_entries=500, background_compaction=True)
    cfg_sy = RemixDBConfig(memtable_entries=500)
    db_bg = RemixDB.open(str(tmp_path / "bg"), cfg_bg)
    db_sy = RemixDB.open(str(tmp_path / "sy"), cfg_sy)
    for db in (db_bg, db_sy):
        _fill(db, n=450)
    r = db_bg.flush()
    assert r.get("background")
    # reads + writes race the round
    assert db_bg.get(7) is not None
    db_bg.put(888888, [8, 8])
    db_bg.wait_for_compaction()
    db_sy.flush()
    db_sy.put(888888, [8, 8])
    for db in (db_bg, db_sy):
        _fill(db, lo=2000, n=600)  # triggers a flush mid-batch
    db_bg.wait_for_compaction()
    ka, va = db_bg.scan(0, 3000)
    kb, vb = db_sy.scan(0, 3000)
    np.testing.assert_array_equal(ka, kb)
    np.testing.assert_array_equal(va, vb)
    assert db_bg.stats()["compaction"]["rounds"] >= 2
    db_bg.close()
    # recovery equals the synchronous store
    db_re = RemixDB.open(str(tmp_path / "bg"))
    kr, vr = db_re.scan(0, 3000)
    np.testing.assert_array_equal(kr, kb)
    np.testing.assert_array_equal(vr, vb)


def test_background_compaction_snapshot_during_round(tmp_path):
    db = RemixDB.open(
        str(tmp_path / "db"),
        RemixDBConfig(memtable_entries=10 ** 9, background_compaction=True),
    )
    keys = _fill(db, n=400)
    with db.snapshot() as snap:
        db.flush()
        db.put(777777, [7, 7])
        # snapshot taken before the flush ignores the concurrent round
        kk, _ = snap.scan(0, 1000)
        np.testing.assert_array_equal(kk, np.sort(keys))
        assert snap.get(777777) is None
    db.wait_for_compaction()
    assert db.get(777777) is not None
    db.close()


# ------------------------------------------------------------ op model
def test_op_model_basics():
    with pytest.raises(ValueError):
        Op.scan(0, -1)
    op = Op.put(np.array([1, 2], np.uint64), np.ones((2, 2), np.uint32))
    assert op.write_rows() == 2
    assert op.cost_bytes(vw=2) == 2 * 16
    assert not op.is_read and Op.get(1).is_read
    b = Batch().get(1).put(2, [0, 0]).scan(0, 4).delete(2).multiget([1, 2])
    assert len(b) == 5
    assert b.cost_bytes(vw=2) > 0
    assert "get" in repr(b)
    # empty multiget / empty put_batch round-trip
    db = RemixDB(_mem_cfg())
    f, v = db.get_batch(np.zeros(0, np.uint64))
    assert len(f) == 0 and v.shape == (0, 2)
    db.put_batch(np.zeros(0, np.uint64), np.zeros((0, 2), np.uint32))
    res = db.submit(Batch([Op.multiget(np.zeros(0, np.uint64))]),
                    sync=True).result()
    assert res.ok and len(res.results[0].found) == 0


def test_executor_stats_and_priority_plan():
    db = RemixDB(_mem_cfg())
    _fill(db, n=50)
    eng = db.engine()
    b = Batch([
        Op.get(7, priority=1),
        Op.scan(0, 4, priority=5),
        Op.put(1, [1, 1]),
        Op.get(14),
    ])
    stages = eng.plan(b)
    assert [s.kind for s in stages] == ["read", "write", "read"]
    assert stages[0].groups[0].priority == 5
    res = eng.submit(b, sync=True).result()
    assert res.ok
    s = eng.stats()
    assert s["batches"] >= 1 and s["ops"]["get"] >= 2
    assert s["admission"]["inflight_bytes"] == 0


def test_op_error_reraise_preserves_traceback():
    """OpResult.raise_if_error must re-raise the ORIGINAL traceback: the
    innermost frame is the one that failed inside the executor, not
    raise_if_error itself."""
    import traceback

    db = RemixDB(_mem_cfg())
    _fill(db, n=20)

    def boom(view, qk):
        raise RuntimeError("injected read failure")

    orig = db._get_batch_at
    db._get_batch_at = boom
    try:
        res = db.submit(
            Batch([Op.multiget(np.array([7, 14], np.uint64))]), sync=True
        ).result()
        r = res.results[0]
        assert r.status is OpStatus.ERROR and r.exc is not None
        with pytest.raises(RuntimeError, match="injected read failure"):
            r.raise_if_error()
        tb = traceback.extract_tb(r.exc.__traceback__)
        assert tb[-1].name == "boom", (
            f"innermost frame is {tb[-1].name!r}, original lost"
        )
        # the legacy wrapper path re-raises through raise_if_error too
        try:
            db.get_batch(np.array([7], np.uint64))
            assert False, "expected the injected failure"
        except RuntimeError as e:
            frames = traceback.extract_tb(e.__traceback__)
            assert frames[-1].name == "boom"
    finally:
        db._get_batch_at = orig


def test_delete_range_and_cas_op_kinds():
    """DELETE_RANGE and CAS flow through the op layer with the same
    batch-order semantics as the other write kinds."""
    db = RemixDB(_mem_cfg())
    keys = np.arange(0, 100, dtype=np.uint64)
    db.put_batch(keys, np.stack([keys, keys], 1).astype(np.uint32))
    res = db.submit(
        Batch([
            Op.put(200, [5, 5]),
            Op.delete_range(10, 60),
            Op.get(20),  # sequential semantics: sees the range delete
            Op.cas(200, np.array([5, 5], np.uint32), [6, 6]),
            Op.get(200),
        ]),
        sync=True,
    ).result()
    assert res.ok
    assert not res.results[2].found
    assert res.results[3].found  # swap succeeded
    assert list(res.results[4].value.reshape(-1)) == [6, 6]
    # conflict: found=False and the actual value is reported
    r = db.submit(
        Batch([Op.cas(200, np.array([5, 5], np.uint32), [7, 7])]),
        sync=True,
    ).result().results[0]
    assert not r.found and list(r.value.reshape(-1)) == [6, 6]
    with pytest.raises(ValueError):
        Op.delete_range(60, 10)


# ------------------------------------------------------- write ordering
def test_shard_sequencer_out_of_order_release():
    """The ordering primitive itself: tickets advance strictly FIFO per
    shard, and releases arriving out of order are parked until every
    predecessor has finished."""
    from repro.db.executor import ShardSequencer

    sq = ShardSequencer(2)
    t1 = sq.register([0])
    t2 = sq.register([0, 1])
    t3 = sq.register([0])
    assert sq.register([]) is None  # read-only batches take no tickets

    assert sq.await_turn(t1)
    sq.release(t3)  # parked: t1/t2 still pending on shard 0
    sq.release(t2)  # parked on shard 0, advances shard 1
    unblocked = threading.Event()

    def waiter():
        assert sq.await_turn(sq.register([0, 1]))
        unblocked.set()

    th = threading.Thread(target=waiter)
    th.start()
    assert not unblocked.wait(0.1)  # t1 still holds shard 0
    sq.release(t1)  # drains the parked releases too
    assert unblocked.wait(2.0)
    th.join()


def test_async_write_batches_apply_in_submission_order(tmp_path):
    """Two+ racing async batches: per-shard write effects must land in
    submission order, so the last-submitted put wins every key — even
    with multiple submit workers draining the queue concurrently."""
    from repro.serve.engine import KVServeEngine

    eng = KVServeEngine(
        [(0, str(tmp_path / "a")), (1 << 32, str(tmp_path / "b"))],
        submit_workers=4,
    )
    try:
        ka, kb = 5, (1 << 32) + 5
        futs = []
        rounds = 60
        for i in range(rounds):
            ks = np.array([ka, kb], np.uint64)
            vs = np.full((2, 2), i, np.uint32)
            futs.append(eng.submit(Batch([Op.put(ks, vs)])))
            # interleave read-only batches: they take no tickets and
            # must not perturb (or be blocked by) the write order
            if i % 7 == 0:
                futs.append(eng.submit(Batch([Op.multiget(ks)])))
        for f in futs:
            assert f.result(timeout=30).ok
        for key in (ka, kb):
            _, vals = eng.get_batch(np.array([key], np.uint64))
            assert int(vals[0][0]) == rounds - 1, key
        assert eng.registry.counter("engine_ordered_batches").value >= rounds
    finally:
        eng.close()
        for db in eng.shards:
            db.close()

"""Device-resident query execution tests: differential parity between
the fused device path (interpret mode on CPU), the host promoted path
and the cold path — including tombstones, TTL expiry evaluated at query
time, and range-tombstone excised spans — plus residency-manager
behavior (budget tiers, LRU + version-release eviction, counters and
events) and the index-tier host/device gather pipeline."""
import dataclasses

import numpy as np
import pytest

import repro.kernels.device_view as device_view
from repro.db import clock
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig

T0 = 1_000_000.0
TTL = 50.0

SEEDS = [0, 1, 2, 3]
NIGHTLY_SEEDS = list(range(4, 20))


def _cfg(**kw):
    kw.setdefault("hot_threshold", 255)
    kw.setdefault("memtable_entries", 128)
    kw.setdefault("compaction", CompactionConfig(table_cap=128, t_max=3))
    return RemixDBConfig(vw=2, **kw)


def _metric(db, name):
    vals = [s["value"] for s in db.registry.snapshot()["metrics"]
            if s["name"] == name]
    assert vals, f"metric {name} not registered"
    return sum(vals)


def _populate(root, seed, n=500):
    """Mixed workload: puts, overwrites, deletes, TTL'd puts and one
    range delete — flushed to disk. Returns the touched key domain."""
    clock.set_source(lambda: T0)
    rng = np.random.default_rng(seed)
    keys = rng.choice(1 << 20, size=n, replace=False).astype(np.uint64)
    db = RemixDB.open(root, _cfg(device_path="off"))
    try:
        for i, k in enumerate(keys.tolist()):
            db.put(k, [i & 0xFFFF, i ^ 7])
        for k in keys[: n // 10].tolist():
            db.delete(k)
        for k in keys[n // 10: n // 5].tolist():
            db.put(k, [9, 9], ttl=TTL)  # expires at T0 + TTL
        lo = int(keys[n // 4])
        db.delete_range(lo, lo + 4096)
        db.flush()
    finally:
        db.close()
    return np.sort(keys)


def _probe_set(domain, rng):
    """Hits, deleted keys, TTL keys, excised keys and misses."""
    probe = np.concatenate(
        [domain, rng.choice(domain, 64, replace=False) + 1, [0, (1 << 21)]]
    ).astype(np.uint64)
    rng.shuffle(probe)
    return probe


def _row_eq(a, b):
    ka, va = a
    kb, vb = b
    np.testing.assert_array_equal(ka, kb)
    if va is None or vb is None:
        assert va is None and vb is None
    else:
        np.testing.assert_array_equal(va, vb)


def _assert_stores_agree(dev, host, domain, rng):
    probe = _probe_set(domain, rng)
    f_h, v_h = host.get_batch(probe)
    f_d, v_d = dev.get_batch(probe)
    np.testing.assert_array_equal(f_h, f_d)
    np.testing.assert_array_equal(v_h[f_h], v_d[f_d])
    starts = np.sort(rng.choice(domain, 24, replace=False))
    for n in (1, 7, 33):
        rows_h = [host.scan(int(s), n) for s in starts]
        rows_d = [dev.scan(int(s), n) for s in starts]
        for a, b in zip(rows_h, rows_d):
            _row_eq(a, b)
        k_h, m_h = host.scan_batch(starts, n)
        k_d, m_d = dev.scan_batch(starts, n)
        np.testing.assert_array_equal(m_h, m_d)
        np.testing.assert_array_equal(k_h[m_h], k_d[m_d])
    for k in probe[:48].tolist():
        a, b = host.get(k), dev.get(k)
        if a is None or b is None:
            assert a is None and b is None
        else:
            np.testing.assert_array_equal(a, b)
    return int(f_h.sum())


def _parity_one_seed(tmp_path, seed):
    root = str(tmp_path / "db")
    domain = _populate(root, seed)
    rng = np.random.default_rng(seed + 100)
    dev = RemixDB.open(root, _cfg(device_path="on", cold_reads=False))
    host = RemixDB.open(root, _cfg(device_path="off", cold_reads=False))
    cold = RemixDB.open(root, _cfg(device_path="off",
                                   promote_fraction=1e9))
    try:
        found_now = _assert_stores_agree(dev, host, domain, rng)
        _assert_stores_agree(dev, cold, domain, rng)
        assert dev.device_views is not None and len(dev.device_views) > 0
        # advance past every TTL: the device view is NOT re-uploaded —
        # expiry words are compared against the query clock on device
        clock.set_source(lambda: T0 + TTL + 10.0)
        found_later = _assert_stores_agree(dev, host, domain, rng)
        assert found_later < found_now  # the TTL'd rows really expired
    finally:
        clock.reset()
        dev.close(), host.close(), cold.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_device_parity_differential(tmp_path, seed):
    _parity_one_seed(tmp_path, seed)


@pytest.mark.nightly
@pytest.mark.parametrize("seed", NIGHTLY_SEEDS)
def test_device_parity_differential_nightly(tmp_path, seed):
    _parity_one_seed(tmp_path, seed)


def test_index_tier_pipeline_parity(tmp_path):
    """Budget admits the index tier but not the value sections: the
    device resolves (run, row) windows, the host gathers value granules
    through the BlockCache in the double-buffered slice pipeline."""
    root = str(tmp_path / "db")
    domain = _populate(root, seed=7)
    host = RemixDB.open(root, _cfg(device_path="off", cold_reads=False))
    probe_cfg = RemixDB.open(root, _cfg(device_path="off"))
    full = min(p.device_view_bytes(True) for p in probe_cfg.partitions)
    idx = max(p.device_view_bytes(False) for p in probe_cfg.partitions)
    probe_cfg.close()
    assert idx < full  # the budget window below admits only the index tier
    dev = RemixDB.open(root, _cfg(device_path="on", cold_reads=False,
                                  device_budget_bytes=full - 1,
                                  device_slice=4))
    try:
        rng = np.random.default_rng(8)
        _assert_stores_agree(dev, host, domain, rng)
        tiers = {v.tier for v in dev.device_views._views.values()}
        assert tiers == {"index"}
        # a 24-query scan at slice width 4 crosses multiple slices: the
        # pipeline pays one sync per slice, never one per query
        starts = np.sort(rng.choice(domain, 24, replace=False))
        s0 = device_view.SYNCS
        dev.scan_batch(starts, 9)
        assert device_view.SYNCS - s0 < len(starts)
    finally:
        clock.reset()
        dev.close(), host.close()


def test_budget_fallback_and_counters(tmp_path):
    """A budget no tier fits falls back to the legacy promoted path
    (counted), with identical results."""
    root = str(tmp_path / "db")
    domain = _populate(root, seed=11)
    host = RemixDB.open(root, _cfg(device_path="off", cold_reads=False))
    dev = RemixDB.open(root, _cfg(device_path="on", cold_reads=False,
                                  device_budget_bytes=16))
    try:
        rng = np.random.default_rng(12)
        _assert_stores_agree(dev, host, domain, rng)
        assert len(dev.device_views) == 0
        assert _metric(dev, "device_fallback_total") > 0
        assert _metric(dev, "device_batches") == 0
        assert _metric(dev, "hbm_resident_bytes") == 0
    finally:
        clock.reset()
        dev.close(), host.close()


def test_upload_metrics_and_events(tmp_path):
    root = str(tmp_path / "db")
    domain = _populate(root, seed=13)
    dev = RemixDB.open(root, _cfg(device_path="on", cold_reads=False))
    try:
        rng = np.random.default_rng(14)
        dev.get_batch(rng.choice(domain, 64, replace=False))
        assert _metric(dev, "device_batches") > 0
        assert _metric(dev, "device_rows_gathered") > 0
        resident = _metric(dev, "hbm_resident_bytes")
        assert resident == dev.device_views.resident_bytes > 0
        ups = dev.events.list("device_upload")
        assert ups and all(e.fields["bytes"] > 0 for e in ups)
        # rewrite every partition: the version release drops stale views
        clock.set_source(lambda: T0 + 1.0)
        for k in domain[::3].tolist():
            dev.put(k, [1, 2])
        dev.flush()
        evs = dev.events.list("device_evict")
        assert evs and any(
            e.fields["reason"] == "version_release" for e in evs
        )
    finally:
        clock.reset()
        dev.close()


def test_lru_eviction_under_budget_pressure(tmp_path):
    """A budget that fits one full view but not all partitions keeps the
    resident set within budget via LRU, with correct results throughout."""
    root = str(tmp_path / "db")
    domain = _populate(root, seed=17, n=800)
    probe_cfg = RemixDB.open(root, _cfg(device_path="off"))
    per = [p.device_view_bytes(True) for p in probe_cfg.partitions]
    probe_cfg.close()
    if len(per) < 2:
        pytest.skip("workload compacted into a single partition")
    budget = max(per)  # one view at a time
    host = RemixDB.open(root, _cfg(device_path="off", cold_reads=False))
    dev = RemixDB.open(root, _cfg(device_path="on", cold_reads=False,
                                  device_budget_bytes=budget))
    try:
        rng = np.random.default_rng(18)
        _assert_stores_agree(dev, host, domain, rng)
        assert dev.device_views.resident_bytes <= budget
    finally:
        clock.reset()
        dev.close(), host.close()


def test_store_rejects_bad_device_knobs(tmp_path):
    with pytest.raises(ValueError):
        RemixDB.open(str(tmp_path / "a"), _cfg(device_path="maybe"))
    with pytest.raises(ValueError):
        RemixDB.open(str(tmp_path / "b"), _cfg(device_slice=0))

"""Versioned-core tests: immutable Versions, snapshot isolation across
flushes, cursor/scan equivalence on all three read paths, pinned-file
lifetime, the compaction-log ring, and workload-stat promotion."""
import os
import tempfile

import numpy as np
import pytest

from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig


def _cfg(tmp_path=None, **kw):
    comp = kw.pop("compaction", CompactionConfig(table_cap=256, t_max=6))
    return RemixDBConfig(
        memtable_entries=kw.pop("memtable_entries", 1 << 30),
        compaction=comp,
        wal_dir=str(tmp_path) if tmp_path is not None else None,
        hot_threshold=255,
        **kw,
    )


def _fill(db, keys):
    keys = np.asarray(keys, np.uint64)
    vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
    db.put_batch(keys, vals)
    return vals


# ---------------------------------------------------------------- snapshots
def test_snapshot_isolated_from_flush(tmp_path):
    db = RemixDB(_cfg(tmp_path))
    keys = np.arange(0, 3000, 3, dtype=np.uint64)
    _fill(db, keys)
    db.delete(6)  # a pre-snapshot delete must stay deleted in the view
    pre_k, pre_v = db.scan(0, 10_000)
    with db.snapshot() as snap:
        # post-snapshot writes + a flush publishing a new Version
        db.put_batch(np.arange(1, 3000, 3, dtype=np.uint64),
                     np.zeros((1000, 2), np.uint32))
        db.delete(9)
        db.flush()
        k1, v1 = snap.scan(0, 10_000)
        np.testing.assert_array_equal(k1, pre_k)
        np.testing.assert_array_equal(v1, pre_v)
        # point reads through the snapshot agree with the frozen view
        assert snap.get(6) is None and snap.get(9) is not None
        f, _ = snap.get_batch(np.array([1, 4, 9], np.uint64))
        assert list(f) == [False, False, True]
    # the live store sees everything
    assert db.get(9) is None and db.get(1) is not None
    k2, _ = db.scan(0, 10_000)
    assert len(k2) == len(pre_k) + 1000 - 1


def test_snapshot_versions_refcount_and_release(tmp_path):
    db = RemixDB(_cfg(tmp_path))
    _fill(db, np.arange(100, dtype=np.uint64))
    db.flush()
    v0 = db.stats()["versions"]
    assert v0["live"] == 1 and v0["pinned"] == 0
    s1, s2 = db.snapshot(), db.snapshot()
    assert db.stats()["versions"]["pinned"] == 2
    _fill(db, np.arange(100, 200, dtype=np.uint64))
    db.flush()  # old Version must stay live: two snapshots pin it
    st = db.stats()["versions"]
    assert st["live"] == 2
    s1.close()
    s1.close()  # idempotent
    assert db.stats()["versions"]["live"] == 2
    s2.close()
    st = db.stats()["versions"]
    assert st["live"] == 1 and st["pinned"] == 0


# ---------------------------------------------------------------- cursors
def test_cursor_ops_peek_next_skip(tmp_path):
    db = RemixDB(_cfg(tmp_path))
    keys = np.arange(10, 200, 10, dtype=np.uint64)
    _fill(db, keys)
    db.flush()
    db.put(15, [7, 7])  # overlay entry between table keys
    db.delete(30)  # overlay tombstone hiding a table key
    with db.cursor(start=11) as cur:
        assert cur.peek()[0] == 15
        assert cur.peek()[0] == 15  # peek does not advance
        k, v = cur.next()
        assert k == 15 and int(v[0]) == 7
        assert cur.next()[0] == 20
        assert cur.skip(2) == 2  # 40, 50 (30 is deleted)
        assert cur.next()[0] == 60
        kk, _ = cur.next_batch(4)
        np.testing.assert_array_equal(kk, [70, 80, 90, 100])
        # iteration protocol drains the rest
        rest = [k for k, _ in cur]
        assert rest == list(range(110, 200, 10))
        assert cur.next() is None and cur.peek() is None
        assert cur.skip(5) == 0


@pytest.mark.parametrize("path", ["overlay", "device", "cold"])
def test_cursor_matches_scan_on_each_read_path(tmp_path, path):
    root = str(tmp_path / "db")
    rng = np.random.default_rng(5)
    keys = np.sort(rng.choice(100_000, 4000, replace=False).astype(np.uint64))
    if path == "cold":
        db = RemixDB.open(root, _cfg(promote_fraction=1e9))
    elif path == "device":
        db = RemixDB.open(root, _cfg(cold_reads=False))
    else:
        db = RemixDB(_cfg(tmp_path))
    _fill(db, keys)
    if path != "overlay":
        db.flush()
        for k in keys[::7].tolist():
            db.delete(int(k))
        db.flush()
        if path == "cold":  # reopen so tables are lazy handles again
            db.close()
            db = RemixDB.open(root, _cfg(promote_fraction=1e9))
            assert all(p.cold_ready() for p in db.partitions)
    for start, n in [(0, 100), (int(keys[1000]), 64), (int(keys[-5]), 50)]:
        k_scan, v_scan = db.scan(start, n)
        with db.cursor(start=start) as cur:
            k_cur, v_cur = cur.next_batch(n)
        np.testing.assert_array_equal(k_cur, k_scan)
        np.testing.assert_array_equal(v_cur, v_scan)
        kb, mb = db.scan_batch(np.array([start], np.uint64), n)
        np.testing.assert_array_equal(kb[0][mb[0]], k_scan[:n])
    if path == "cold":
        assert db.stats()["resident_tables"] == 0  # stayed cold throughout


def test_cursor_streams_across_partitions_and_overlay(tmp_path):
    cfg = _cfg(tmp_path, memtable_entries=2048)
    cfg.compaction = CompactionConfig(table_cap=128, t_max=3, split_m=2)
    db = RemixDB(cfg)
    keys = np.arange(0, 4096, dtype=np.uint64)
    for _ in range(3):
        db.put_batch(keys, np.zeros((len(keys), 2), np.uint32))
        db.flush()
    assert len(db.partitions) > 1
    db.put(4096, [1, 1])  # overlay tail beyond every partition's tables
    with db.cursor() as cur:
        kk, _ = cur.next_batch(5000)
    np.testing.assert_array_equal(kk, np.arange(0, 4097, dtype=np.uint64))


# ------------------------------------------------- flush/cursor interleave
def test_cursor_survives_concurrent_flush_and_files_pinned(tmp_path):
    """The acceptance bar: a reader holding a snapshot/cursor across a
    concurrent flush (compaction publishing a new Version and rewriting
    tables) returns exactly the rows of an isolated pre-flush scan; the
    pinned Version's files outlive the commit until the snapshot closes,
    and recovery still round-trips afterwards."""
    root = str(tmp_path / "db")
    cfg = RemixDBConfig(
        memtable_entries=1 << 30, hot_threshold=255,
        compaction=CompactionConfig(table_cap=256, t_max=2),
        promote_fraction=1e9,
    )
    db = RemixDB.open(root, cfg)
    keys = np.arange(1, 4001, dtype=np.uint64) * 4
    _fill(db, keys)
    db.flush()
    db.close()

    db = RemixDB.open(root, cfg)  # cold: cursor reads straight off files
    assert all(p.cold_ready() for p in db.partitions)
    pre_k, pre_v = db.scan(0, 10_000)  # isolated pre-flush reference

    snap = db.snapshot()
    cur = snap.cursor(start=0, width=64)
    got_k = [cur.next_batch(500)[0]]  # consume part of the view...

    # ...then a flush rewrites the partition (t_max=2 forces a
    # major/split that supersedes the old table files)
    db.delete(int(keys[1000]))
    _fill(db, keys + 1)
    db.flush()
    pinned = snap.version.file_names()
    current = db.versions.current.file_names()
    assert pinned - current, "flush should have superseded some files"
    for name in pinned:  # superseded files stay on disk while pinned
        sub = "tables" if name.endswith(".sst") else "remix"
        assert os.path.exists(os.path.join(root, sub, name)), name

    while True:  # cursor keeps streaming the old Version mid-compaction
        kk, _ = cur.next_batch(500)
        if len(kk) == 0:
            break
        got_k.append(kk)
    np.testing.assert_array_equal(np.concatenate(got_k), pre_k)
    # a fresh snapshot scan of the old version also matches row-for-row
    k_old, v_old = snap.scan(0, 10_000)
    np.testing.assert_array_equal(k_old, pre_k)
    np.testing.assert_array_equal(v_old, pre_v)

    cur.close()
    snap.close()  # last pin drops -> exclusively-owned files reclaimed
    on_disk = set(os.listdir(os.path.join(root, "tables")))
    assert on_disk == {n for n in current if n.endswith(".sst")}

    # live store + recovery round-trip reflect the post-flush state
    k_live, _ = db.scan(0, 20_000)
    db.close()
    db2 = RemixDB.open(root, cfg)
    k_rec, _ = db2.scan(0, 20_000)
    np.testing.assert_array_equal(k_rec, k_live)
    assert db2.get(int(keys[1000])) is None  # the delete survived


def test_snapshot_taken_mid_flush_sees_pre_flush_state(tmp_path, monkeypatch):
    """A snapshot captured *during* a flush — after the MemTable freeze
    but before the new Version publishes — must still observe the full
    pre-flush contents: the frozen entries overlay the old Version until
    the pointer swap."""
    import repro.db.store as S

    db = RemixDB(_cfg(tmp_path))
    keys = np.arange(0, 500, 5, dtype=np.uint64)
    _fill(db, keys)
    db.delete(10)
    pre_k, pre_v = db.scan(0, 10_000)
    grabbed = {}
    real_execute = S.execute

    def spy(plan, cfg, storage=None, **kw):
        if "snap" not in grabbed:  # mid-flush: frozen, not yet published
            grabbed["snap"] = db.snapshot()
        return real_execute(plan, cfg, storage=storage, **kw)

    monkeypatch.setattr(S, "execute", spy)
    db.flush()
    with grabbed["snap"] as snap:
        kk, vv = snap.scan(0, 10_000)
        np.testing.assert_array_equal(kk, pre_k)
        np.testing.assert_array_equal(vv, pre_v)
        assert snap.get(10) is None  # the pre-flush delete holds
    # post-flush reads are unaffected
    kk, _ = db.scan(0, 10_000)
    np.testing.assert_array_equal(kk, pre_k)


# ---------------------------------------------------------------- ring log
def test_compaction_log_ring_and_totals(tmp_path):
    cfg = _cfg(tmp_path, memtable_entries=400, compaction_log_rounds=4)
    cfg.compaction = CompactionConfig(table_cap=128, t_max=4)
    db = RemixDB(cfg)
    rng = np.random.default_rng(1)
    for _ in range(10):
        ks = rng.choice(50_000, size=400, replace=False).astype(np.uint64)
        db.put_batch(ks, np.zeros((400, 2), np.uint32))
        db.flush()
    assert len(db.compaction_log) == 4  # ring: only the last N rounds
    st = db.stats()["compaction"]
    assert st["rounds"] == 10 and st["log_rounds"] == 4
    assert sum(st["kinds"].values()) >= 10  # aggregates span all rounds
    assert st["bytes_written"] > 0


# ---------------------------------------------------------------- promotion
def test_promotion_driven_by_served_workload(tmp_path):
    """A partition whose working set the block cache fully absorbs must
    still promote under traffic: the served-bytes counter keeps growing
    on cache hits while the physical disk counter stalls."""
    from repro.core.remix import build_remix
    from repro.core.runs import make_run
    from repro.db.wal import WAL
    from repro.io.manifest import Storage

    root = str(tmp_path / "db")
    n = 60_000
    keys = np.arange(1, n + 1, dtype=np.uint64) * 8
    run = make_run(keys, seq=np.arange(1, n + 1, dtype=np.uint32))
    storage = Storage(root)
    name = storage.write_table(
        np.asarray(run.keys), np.asarray(run.vals),
        np.asarray(run.seq), np.asarray(run.tomb),
    )
    remix, _ = build_remix([run], d=32)
    xname = storage.write_remix(remix)
    storage.commit(dict(
        seq=n + 1, vw=2, d=32,
        partitions=[dict(lo=0, tables=[name], remix=xname)],
        wal=WAL(storage.wal_path()).save_state(),
    ))
    # promote_fraction high so the store stays cold while we hammer it
    db = RemixDB.open(root, _cfg(promote_fraction=1e9))
    [p] = db.partitions
    start = int(keys[n // 2])
    for _ in range(40):  # same range: cache hits after the first pass
        kk, _ = db.scan(start, 500)
        assert len(kk) == 500
    frac = 0.3
    inputs = p.promotion_inputs(frac)
    assert inputs["served_bytes"] >= inputs["threshold_bytes"]
    assert inputs["disk_bytes"] < inputs["threshold_bytes"]  # cache absorbed
    assert inputs["promote"] and p.should_promote(frac)
    # the decision inputs are exposed through stats()["cache"]
    st = db.stats()["cache"]["promotion"]
    assert len(st) == 1 and st[0]["cold_scans"] >= 40
    assert st[0]["served_bytes"] == inputs["served_bytes"]
    assert db.stats()["resident_tables"] == 0  # still cold at 1e9 fraction


# ---------------------------------------------------------------- property
def test_snapshot_semantics_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(
        st.booleans(),  # True = put, False = delete
        st.integers(min_value=0, max_value=40),
        st.integers(min_value=0, max_value=2**31 - 1),
    )

    @settings(max_examples=20, deadline=None)
    @given(
        pre=st.lists(op, max_size=40),
        post=st.lists(op, max_size=25),
        flush_mid_pre=st.booleans(),
    )
    def run(pre, post, flush_mid_pre):
        db = RemixDB(_cfg(tempfile.mkdtemp(prefix="snapprop-")))
        ref: dict[int, int] = {}
        for i, (is_put, k, v) in enumerate(pre):
            if is_put:
                db.put(k, [v, 0])
                ref[k] = v
            else:
                db.delete(k)
                ref.pop(k, None)
            if flush_mid_pre and i == len(pre) // 2:
                db.flush()  # part of the view in tables, part in overlay
        want_k = np.array(sorted(ref), np.uint64)
        with db.snapshot() as snap:
            for is_put, k, v in post:
                (db.put(k, [v, 0]) if is_put else db.delete(k))
            db.flush()
            # the snapshot observes exactly the pre-flush contents
            kk, vv = snap.scan(0, 1000)
            np.testing.assert_array_equal(kk, want_k)
            if len(kk):
                np.testing.assert_array_equal(
                    vv[:, 0], [ref[int(k)] for k in kk]
                )
            # batched == scalar == cursor on the same snapshot
            probes = np.arange(0, 42, dtype=np.uint64)
            fb, vb = snap.get_batch(probes)
            for i, k in enumerate(probes.tolist()):
                v = snap.get(k)
                assert bool(fb[i]) == (v is not None)
                if v is not None:
                    assert int(vb[i, 0]) == int(v[0]) == ref.get(k, -1)
            with snap.cursor() as cur:
                ck, cv = cur.next_batch(1000)
            np.testing.assert_array_equal(ck, kk)
            np.testing.assert_array_equal(cv, vv)
        # the live store reflects the post ops
        live: dict[int, int] = dict(ref)
        for is_put, k, v in post:
            live[k] = v if is_put else None
        for k, v in live.items():
            got = db.get(k)
            assert (got is None) == (v is None)
            if v is not None:
                assert int(got[0]) == v

    run()

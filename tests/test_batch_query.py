"""Batched query engine tests: batched-vs-scalar equivalence (gets,
scans, tombstone-heavy and cross-partition batches), prefetch-pipeline
parity, mmap cache mode, batched CKB narrowing, and the WAL sync_policy
knob."""
import os

import numpy as np
import pytest

from repro.core import keys as CK
from repro.core.remix import build_remix
from repro.core.runs import (
    RowWindow,
    make_run,
    merge_ranges,
    ranges_to_rows,
)
from repro.db.store import RemixDB, RemixDBConfig
from repro.db.wal import WAL
from repro.io.ckb import CKBReader, encode_ckb
from repro.io.manifest import Storage

D = 16
NEVER_PROMOTE = 1e9


def _build_store(root, n_tables=4, n_per_table=1500, tomb_every=3, seed=0,
                 partitions=1):
    """A committed on-disk store with tombstones; returns (domain, dead).

    ``partitions`` > 1 splits the key domain into equal ranges, each with
    its own table set + REMIX, to exercise cross-partition batches.
    """
    rng = np.random.default_rng(seed)
    total = n_tables * n_per_table
    domain = np.arange(1, total + 1, dtype=np.uint64) * 16
    owner = rng.integers(0, n_tables, total)
    dead = np.zeros(total, bool)
    dead[::tomb_every] = True  # tombstone-heavy: every 3rd key deleted
    storage = Storage(root)
    parts = []
    bounds = np.linspace(0, total, partitions + 1).astype(int)
    for pi in range(partitions):
        sl = slice(bounds[pi], bounds[pi + 1])
        pk, po, pd = domain[sl], owner[sl], dead[sl]
        names, runs, seqbase = [], [], 1
        for i in range(n_tables):
            m = po == i
            kk = pk[m]
            run = make_run(
                kk,
                seq=np.arange(seqbase, seqbase + len(kk), dtype=np.uint32),
                tomb=pd[m],
            )
            seqbase += len(kk)
            runs.append(run)
            names.append(
                storage.write_table(
                    np.asarray(run.keys), np.asarray(run.vals),
                    np.asarray(run.seq), np.asarray(run.tomb),
                )
            )
        remix, _ = build_remix(runs, d=D)
        parts.append(
            dict(lo=0 if pi == 0 else int(pk[0]), tables=names,
                 remix=storage.write_remix(remix))
        )
    wal = WAL(storage.wal_path())
    storage.commit(
        dict(seq=10 * total, vw=2, d=D, partitions=parts,
             wal=wal.save_state())
    )
    return domain, dead


def _probes(domain, rng, q):
    """Hits, misses and off-by-one keys mixed into one batch."""
    hits = rng.choice(domain, q // 2, replace=False).astype(np.uint64)
    miss = rng.choice(domain, q - q // 2, replace=False).astype(np.uint64) + 1
    out = np.concatenate([hits, miss])
    rng.shuffle(out)
    return out


def _cfg(**kw):
    kw.setdefault("promote_fraction", NEVER_PROMOTE)
    return RemixDBConfig(**kw)


# ---------------------------------------------------------------- gets
@pytest.mark.parametrize("cache_mode", ["copy", "mmap"])
def test_cold_get_batch_matches_scalar_and_device(tmp_path, cache_mode):
    root = str(tmp_path / "db")
    domain, dead = _build_store(root)
    rng = np.random.default_rng(1)
    probe = _probes(domain, rng, 128)

    db_b = RemixDB.open(root, _cfg(cache_mode=cache_mode))
    db_s = RemixDB.open(root, _cfg())
    assert all(p.cold_ready() for p in db_b.partitions)
    f_b, v_b = db_b.get_batch(probe)
    f_s = np.zeros(len(probe), bool)
    v_s = np.zeros((len(probe), 2), np.uint32)
    for i, k in enumerate(probe.tolist()):
        got, val = db_s.partitions[0].cold_get(k)
        f_s[i] = got
        if got:
            v_s[i] = val
    np.testing.assert_array_equal(f_b, f_s)
    np.testing.assert_array_equal(v_b[f_b], v_s[f_s])
    # promoted device path agrees bit-for-bit
    db_d = RemixDB.open(root, _cfg(cold_reads=False))
    f_d, v_d = db_d.get_batch(probe)
    np.testing.assert_array_equal(f_b, f_d)
    np.testing.assert_array_equal(v_b[f_b], v_d[f_d])
    # tombstoned keys really came back not-found
    key_dead = dict(zip(domain.tolist(), dead.tolist()))
    for i, k in enumerate(probe.tolist()):
        if k in key_dead:
            assert bool(f_b[i]) == (not key_dead[k])


def test_cold_get_batch_coalesces_block_fetches(tmp_path):
    root = str(tmp_path / "db")
    domain, _ = _build_store(root)
    db = RemixDB.open(root, _cfg())
    rng = np.random.default_rng(2)
    db.get_batch(_probes(domain, rng, 128))
    c = db.stats()["cache"]
    # every distinct granule the batch touched was loaded exactly once
    assert c["evictions"] == 0
    assert c["misses"] == c["entries"]


def test_cross_partition_batches(tmp_path):
    root = str(tmp_path / "db")
    domain, _ = _build_store(root, partitions=3)
    rng = np.random.default_rng(3)
    probe = _probes(domain, rng, 96)
    db = RemixDB.open(root, _cfg())
    assert len(db.partitions) == 3
    assert all(p.cold_ready() for p in db.partitions)
    f_b, v_b = db.get_batch(probe)
    db_s = RemixDB.open(root, _cfg())
    for i, k in enumerate(probe.tolist()):
        v = db_s.get(k)
        assert bool(f_b[i]) == (v is not None)
        if v is not None:
            np.testing.assert_array_equal(v_b[i], v)
    # batched scans crossing the partition boundaries
    starts = np.array(
        [domain[0], domain[len(domain) // 3 - 2], domain[-40]], np.uint64
    )
    kk, mm = db.scan_batch(starts, 30)
    for row, s in enumerate(starts):
        ref, _ = db_s.scan(int(s), 30)
        np.testing.assert_array_equal(kk[row][mm[row]], ref)


# ---------------------------------------------------------------- scans
@pytest.mark.parametrize("width", [7, 40, 200])
def test_cold_scan_batch_matches_scalar(tmp_path, width):
    root = str(tmp_path / "db")
    domain, _ = _build_store(root)
    rng = np.random.default_rng(4)
    starts = np.concatenate(
        [rng.choice(domain, 24).astype(np.uint64),
         [domain[0] - 1, domain[-1], domain[-1] + 5]]
    )
    db_b = RemixDB.open(root, _cfg())
    db_s = RemixDB.open(root, _cfg())
    outs = db_b.partitions[0].cold_scan_batch(starts, width)
    for s, (kk, vv, more) in zip(starts.tolist(), outs):
        k_ref, v_ref, m_ref = db_s.partitions[0].cold_scan(s, width)
        np.testing.assert_array_equal(kk, k_ref)
        np.testing.assert_array_equal(vv, v_ref)
        assert more == m_ref


def test_prefetch_scan_parity_and_counters(tmp_path):
    root = str(tmp_path / "db")
    domain, _ = _build_store(root, n_per_table=4000)
    rng = np.random.default_rng(5)
    starts = rng.choice(domain, 8).astype(np.uint64)
    db_e = RemixDB.open(root, _cfg(prefetch_depth=0))
    db_p = RemixDB.open(root, _cfg(prefetch_depth=2))
    for s in starts.tolist():
        ke, ve = db_e.scan(s, 60)
        kp, vp = db_p.scan(s, 60)
        np.testing.assert_array_equal(ke, kp)
        np.testing.assert_array_equal(ve, vp)
    # the pipeline read no block the eager path did not
    assert db_p.disk_bytes_read() <= db_e.disk_bytes_read()
    c = db_p.stats()["cache"]
    assert c["prefetch_issued"] > 0
    assert c["prefetch_hits"] + c["prefetch_waste"] <= c["prefetch_issued"]
    assert c["prefetch_hits"] > 0


def test_scan_batch_equals_sequential_after_promotion(tmp_path):
    """Promotion mid-life must not change batched results."""
    root = str(tmp_path / "db")
    domain, _ = _build_store(root)
    starts = np.array([domain[10], domain[500], domain[-30]], np.uint64)
    cold_k, cold_m = RemixDB.open(root, _cfg()).scan_batch(starts, 20)
    dev = RemixDB.open(root, _cfg(cold_reads=False))
    dev_k, dev_m = dev.scan_batch(starts, 20)
    np.testing.assert_array_equal(cold_k[cold_m], dev_k[dev_m])
    np.testing.assert_array_equal(cold_m, dev_m)


def test_heterogeneous_scan_group_merges_fetches(tmp_path):
    """Scans of different n share one group: one `_scan_group_at` call
    per shard read stage, fewer total block accesses than per-n groups,
    and bit-identical results (the carried ROADMAP item)."""
    from repro.db.ops import Batch, Op

    root = str(tmp_path / "db")
    domain, _ = _build_store(root, n_per_table=4000)
    rng = np.random.default_rng(9)
    starts = np.sort(rng.choice(domain[:-400], 12, replace=False))
    ns = [7, 90] * 6  # interleaved: short and long scans over shared rows
    ops = [Op.scan(int(s), n) for s, n in zip(starts.tolist(), ns)]

    db_m = RemixDB.open(root, _cfg())
    calls = []
    orig = db_m._scan_group_at

    def spy(view, st, n, **kw):
        calls.append(np.zeros(len(st), np.int64) + np.asarray(n, np.int64))
        return orig(view, st, n, **kw)

    db_m._scan_group_at = spy
    res_m = db_m.engine().execute(Batch(ops)).results
    assert len(calls) == 1 and sorted(calls[0].tolist()) == sorted(ns)
    acc_m = db_m.stats()["cache"]

    # baseline: the same scans split into per-n groups (the old plan)
    db_s = RemixDB.open(root, _cfg())
    res_s = []
    for want in (7, 90):
        sub = [op for op, n in zip(ops, ns) if n == want]
        res_s.extend(db_s.engine().execute(Batch(sub)).results)
    acc_s = db_s.stats()["cache"]
    order = [i for n0 in (7, 90) for i, n in enumerate(ns) if n == n0]
    for r_s, i in zip(res_s, order):
        np.testing.assert_array_equal(res_m[i].keys, r_s.keys)
        np.testing.assert_array_equal(res_m[i].vals, r_s.vals)
    # both runs load each distinct granule once (equal misses), but the
    # split groups walk the shared granules twice — the merged row
    # windows issue strictly fewer block accesses
    assert acc_m["misses"] == acc_s["misses"]
    assert acc_m["hits"] < acc_s["hits"]


def test_cold_scan_prefetch_issues_each_granule_once(tmp_path):
    """The lookahead pipeline coalesces vals+tomb granule ids across
    sections into one deduped issue set per window emission."""
    from repro.io.blockcache import BlockCache

    root = str(tmp_path / "db")
    domain, _ = _build_store(root, n_per_table=4000)
    db = RemixDB.open(root, _cfg(prefetch_depth=2))
    t = db.partitions[0].tables[0]
    # granule ids are file-absolute, so different sections' id sets live
    # in one space and CAN collide — that's what the dedupe guards
    vb = t.row_block_ids("vals", 0, t.n)
    tb = t.row_block_ids("tomb", 0, t.n)
    assert len(vb) and len(tb) and vb[0] <= tb[0]
    issued = []
    orig = BlockCache.prefetch

    def spy(self, key, loader):
        issued.append(key)
        return orig(self, key, loader)

    BlockCache.prefetch = spy
    try:
        db.scan(int(domain[100]), 120)
    finally:
        BlockCache.prefetch = orig
    assert issued  # the pipeline ran
    assert len(issued) == len(set(issued))


# ------------------------------------------------- batched CKB narrowing
def test_ckb_narrow_batch_brackets_lower_bound():
    rng = np.random.default_rng(6)
    u = np.sort(rng.choice(1 << 40, 5000, replace=False).astype(np.uint64))
    rd = CKBReader.from_bytes(encode_ckb(CK.pack_u64(u)))
    qs = np.concatenate([u[::13], u[::17] + 1, [0, u[-1] + 9]]).astype(
        np.uint64
    )
    los = np.zeros(len(qs), np.int64)
    his = np.full(len(qs), rd.n, np.int64)
    nlo, nhi = rd.narrow_batch(qs, los, his)
    assert np.all(nlo >= los) and np.all(nhi <= his)
    assert np.all(nhi - nlo <= rd.interval)
    for q, a, b in zip(qs.tolist(), nlo.tolist(), nhi.tolist()):
        want = int(np.searchsorted(u, q, side="left"))
        assert a <= want <= b  # nhi itself is the answer when all < q


def test_seek_rows_batch_matches_scalar_seek(tmp_path):
    root = str(tmp_path / "db")
    domain, _ = _build_store(root, n_tables=2, n_per_table=3000)
    db = RemixDB.open(root, _cfg())
    t = db.partitions[0].tables[0]
    u = CK.unpack_u64(t.key_words())
    rng = np.random.default_rng(7)
    qs = np.concatenate(
        [rng.choice(u, 40).astype(np.uint64), rng.choice(u, 40) + 1,
         [0, u[-1] + 3]]
    ).astype(np.uint64)
    los = rng.integers(0, t.n // 2, len(qs)).astype(np.int64)
    his = los + rng.integers(1, 3 * D, len(qs)).astype(np.int64)
    got = t.seek_rows_batch(qs, los, his)
    for i, q in enumerate(qs.tolist()):
        qw = CK.pack_u64(np.array([q], np.uint64))[0]
        assert got[i] == t.seek_row(qw, int(los[i]), int(his[i]))


# ------------------------------------------------------- range utilities
def test_merge_ranges_and_ranges_to_rows():
    assert merge_ranges([(5, 9), (0, 3), (8, 12), (20, 20)]) == [
        (0, 3), (5, 12),
    ]
    assert merge_ranges([(0, 3), (4, 6)], gap=1) == [(0, 6)]
    rows = ranges_to_rows(np.array([0, 5]), np.array([3, 7]))
    np.testing.assert_array_equal(rows, [0, 1, 2, 5, 6])
    assert len(ranges_to_rows(np.zeros(0), np.zeros(0))) == 0


def test_row_window_gather():
    calls = []

    def fetch(section, rows):
        calls.append(section)
        if section == "keys":
            return CK.pack_u64(rows.astype(np.uint64) * 10)
        if section == "vals":
            return np.stack([rows, rows], axis=1).astype(np.uint32)
        return rows % 2 == 0

    w = RowWindow.from_scattered([(2, 5), (4, 8), (30, 31)], fetch)
    assert calls == ["keys", "vals", "tomb"]  # one fetch per section
    kk, vv, tb = w.gather(np.array([3, 30, 7]))
    np.testing.assert_array_equal(kk, [30, 300, 70])
    np.testing.assert_array_equal(vv[:, 0], [3, 30, 7])
    np.testing.assert_array_equal(tb, [False, True, False])


# ----------------------------------------------------------- sync_policy
def test_wal_sync_policy_knob(tmp_path):
    n = 400  # > 2 full blocks (170 records fit one 4 KB block at vw=2)
    for pol, min_blocks in (("none", 2), ("block", 2), ("always", n)):
        w = WAL(str(tmp_path / f"{pol}.log"), sync_policy=pol)
        for i in range(n):
            w.append(i, i + 1, False, np.zeros(2, np.uint32))
        assert w.used_blocks() >= min_blocks
        # replay sees every record regardless of policy
        assert len(list(w.replay())) == n
    with pytest.raises(ValueError):
        WAL(str(tmp_path / "bad.log"), sync_policy="sometimes")


def test_store_sync_policy_always_is_durable_without_close(tmp_path):
    root = str(tmp_path / "db")
    db = RemixDB.open(root, RemixDBConfig(sync_policy="always"))
    db.put(7, [1, 2])
    db.put(9, [3, 4])
    # no close(), no sync(): reopen must still replay both puts
    db2 = RemixDB.open(root, RemixDBConfig())
    np.testing.assert_array_equal(db2.get(7), [1, 2])
    np.testing.assert_array_equal(db2.get(9), [3, 4])


def test_store_rejects_bad_knobs(tmp_path):
    with pytest.raises(ValueError):
        RemixDB(RemixDBConfig(cache_mode="zero-copy"))
    with pytest.raises(ValueError):
        RemixDB(RemixDBConfig(prefetch_depth=-1))
    with pytest.raises(ValueError):
        RemixDB.open(str(tmp_path / "db"), RemixDBConfig(sync_policy="x"))


# -------------------------------------------------- serving front routing
def test_serve_engine_get_routes_through_batch(tmp_path):
    from repro.serve.engine import KVServeEngine

    roots = []
    for i, lo in enumerate([0, 1 << 20]):
        root = str(tmp_path / f"s{i}")
        db = RemixDB.open(root, RemixDBConfig())
        base = lo + 100
        for k in range(base, base + 50):
            db.put(k, [k & 0xFFFF, 1])
        db.flush()
        db.close()
        roots.append((lo, root))
    eng = KVServeEngine(roots, config=_cfg())
    np.testing.assert_array_equal(eng.get(105), [105 & 0xFFFF, 1])
    assert eng.get(55) is None
    keys = np.array([105, (1 << 20) + 120, 55], np.uint64)
    found, vals = eng.get_batch(keys)
    np.testing.assert_array_equal(found, [True, True, False])
    np.testing.assert_array_equal(vals[1], [((1 << 20) + 120) & 0xFFFF, 1])
    # one shared cache across shards sees the traffic
    assert eng.stats()["cache"]["hits"] + eng.stats()["cache"]["misses"] > 0


# ------------------------------------------------------ property testing
def test_batched_equals_scalar_property(tmp_path):
    """Hypothesis sweep: random batches against the scalar cold path."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    root = str(tmp_path / "db")
    domain, _ = _build_store(root, n_tables=3, n_per_table=600)
    db_b = RemixDB.open(root, _cfg())
    db_s = RemixDB.open(root, _cfg())
    p_b, p_s = db_b.partitions[0], db_s.partitions[0]
    hi = int(domain[-1]) + 32

    @settings(max_examples=30, deadline=None)
    @given(
        keys=st.lists(st.integers(0, hi), min_size=1, max_size=40),
        width=st.integers(1, 64),
    )
    def check(keys, width):
        ks = np.array(keys, np.uint64)
        f_b, v_b = p_b.cold_get_batch(ks)
        for i, k in enumerate(keys):
            got, val = p_s.cold_get(k)
            assert bool(f_b[i]) == got
            if got:
                np.testing.assert_array_equal(v_b[i], val)
        outs = p_b.cold_scan_batch(ks[:4], width)
        for s, (kk, vv, more) in zip(ks[:4].tolist(), outs):
            k_ref, v_ref, m_ref = p_s.cold_scan(s, width)
            np.testing.assert_array_equal(kk, k_ref)
            np.testing.assert_array_equal(vv, v_ref)
            assert more == m_ref

    check()

"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
the same pallas_call lowers to Mosaic on a real TPU.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import keys as K
from repro.core import query as Q
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.core.view import NEWEST_BIT, PLACEHOLDER
from repro.kernels import ops
from repro.kernels.anchor_search import anchor_search
from repro.kernels.ref import anchor_search_ref, selector_decode_ref
from repro.kernels.selector_decode import selector_decode


def random_selectors(rng, q, d, r, pad_prob=0.2):
    """Random selector tiles with tail placeholders + newest bits."""
    sel = rng.integers(0, r, size=(q, d)).astype(np.int32)
    newest = rng.random((q, d)) < 0.7
    sel = sel | (newest.astype(np.int32) << 7)
    n_pad = rng.integers(0, max(1, int(d * pad_prob)), size=q)
    for i in range(q):
        if n_pad[i]:
            sel[i, d - n_pad[i] :] = PLACEHOLDER
    cursors = rng.integers(0, 1000, size=(q, r)).astype(np.int32)
    return jnp.asarray(sel), jnp.asarray(cursors)


@pytest.mark.parametrize("d", [8, 16, 32, 64])
@pytest.mark.parametrize("r", [1, 3, 8, 16])
def test_selector_decode_sweep_d_r(d, r):
    rng = np.random.default_rng(d * 100 + r)
    for q in (1, 5, 128, 300):
        sel, cur = random_selectors(rng, q, d, r)
        got = selector_decode(sel, cur, r=r, interpret=True)
        want = selector_decode_ref(sel, cur, r=r)
        for g, w, name in zip(got, want, ("runid", "absidx", "newest", "pad")):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=f"{name} d={d} r={r} q={q}"
            )


@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_selector_decode_dtypes(dtype):
    rng = np.random.default_rng(7)
    sel, cur = random_selectors(rng, 64, 32, 4)
    got = selector_decode(sel.astype(dtype), cur, r=4, interpret=True)
    want = selector_decode_ref(sel.astype(jnp.int32), cur, r=4)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("g", [1, 7, 500, 5000])
@pytest.mark.parametrize("kw", [1, 2, 3])
def test_anchor_search_sweep(g, kw):
    rng = np.random.default_rng(g + kw)
    anchors = np.sort(
        rng.integers(0, 2**31, size=(g,)).astype(np.uint64)
    )
    a = np.zeros((g, kw), np.uint32)
    a[:, -1] = anchors & 0xFFFFFFFF
    if kw >= 2:
        a[:, -2] = anchors >> 32
    a = a[np.lexsort([a[:, w] for w in range(kw - 1, -1, -1)])]
    queries = np.concatenate(
        [
            rng.integers(0, 2**31, size=63).astype(np.uint64),
            anchors[rng.integers(0, g, size=17)],  # exact hits
            np.array([0, 2**31 - 1], np.uint64),
        ]
    )
    qa = np.zeros((queries.shape[0], kw), np.uint32)
    qa[:, -1] = queries & 0xFFFFFFFF
    if kw >= 2:
        qa[:, -2] = queries >> 32
    got = anchor_search(jnp.asarray(a), jnp.asarray(qa), interpret=True)
    want = anchor_search_ref(jnp.asarray(a), jnp.asarray(qa))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_anchor_search_block_sweeps():
    rng = np.random.default_rng(11)
    a = np.sort(rng.integers(0, 10**6, size=1000).astype(np.uint64))
    a = K.pack_u64(np.unique(a))
    qs = K.pack_u64(rng.integers(0, 10**6, size=333).astype(np.uint64))
    want = anchor_search_ref(jnp.asarray(a), jnp.asarray(qs))
    for bq in (32, 256):
        for bg in (64, 512):
            got = anchor_search(
                jnp.asarray(a), jnp.asarray(qs), block_q=bq, block_g=bg,
                interpret=True,
            )
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want), err_msg=f"bq={bq} bg={bg}"
            )


def _runset(rng, r=6, n=300, space=4000, d=32):
    runs = [
        make_run(
            np.sort(rng.choice(space, size=n, replace=False)).astype(np.uint64),
            seq=i,
        )
        for i in range(r)
    ]
    return build_remix(runs, d=d)


@pytest.mark.parametrize("d", [16, 32, 64])
def test_ops_seek_get_scan_match_reference(d):
    rng = np.random.default_rng(d)
    remix, runset = _runset(rng, d=d)
    queries = rng.integers(0, 4100, size=200).astype(np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    np.testing.assert_array_equal(
        np.asarray(ops.seek(remix, runset, qk, interpret=True)),
        np.asarray(Q.seek(remix, runset, qk)),
    )
    f1, v1 = ops.get(remix, runset, qk, interpret=True)
    f2, v2 = Q.get(remix, runset, qk)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(
        np.asarray(v1)[np.asarray(f1)], np.asarray(v2)[np.asarray(f2)]
    )
    k1, vv1, va1, _ = ops.scan(remix, runset, qk[:32], width=50, interpret=True)
    k2, vv2, va2, _ = Q.scan(remix, runset, qk[:32], width=50)
    np.testing.assert_array_equal(np.asarray(va1), np.asarray(va2))
    np.testing.assert_array_equal(
        np.asarray(k1)[np.asarray(va1)], np.asarray(k2)[np.asarray(va2)]
    )

"""Training loop, checkpoint/restart, gradient compression, serving engine."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline
from repro.models import model as M
from repro.models.kvcache import PrefixCache, RemixPageTable
from repro.models.layers import split_params
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as C
from repro.train.compress import dequantize, quantize
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def tiny_setup(arch="qwen2.5-3b", steps=60):
    cfg = reduced(get_config(arch), n_layers=2, d_model=128, d_ff=256, vocab=128)
    params = M.init_params(cfg, jax.random.key(0))
    pv, _ = split_params(params)
    opt_cfg = OptConfig(lr=1e-2, warmup=5, total_steps=steps)
    opt = init_opt_state(opt_cfg, pv)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    data = DataPipeline(vocab=cfg.vocab, batch=8, seq=32, seed=1)
    return cfg, pv, opt, step_fn, data


def test_loss_decreases():
    cfg, pv, opt, step_fn, data = tiny_setup()
    losses = []
    for i in range(40):
        pv, opt, m = step_fn(pv, opt, data.get_batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]
    assert np.isfinite(losses).all()


def test_checkpoint_exact_resume(tmp_path):
    cfg, pv, opt, step_fn, data = tiny_setup()
    # uninterrupted run of 10 steps
    p1, o1 = pv, opt
    for i in range(10):
        p1, o1, _ = step_fn(p1, o1, data.get_batch(i))
    # interrupted run: 5 steps, checkpoint, "crash", restore, 5 more
    p2, o2 = pv, opt
    for i in range(5):
        p2, o2, _ = step_fn(p2, o2, data.get_batch(i))
    C.save(str(tmp_path), 5, p2, o2, extra=dict(data=data.state(5)))
    del p2, o2
    rp, ro, extra = C.restore(str(tmp_path))
    assert extra["data"]["step"] == 5
    for i in range(extra["data"]["step"], 10):
        rp, ro, _ = step_fn(rp, ro, data.get_batch(i))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(rp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_and_latest(tmp_path):
    cfg, pv, opt, step_fn, data = tiny_setup()
    for s in (1, 2, 3, 4):
        C.save(str(tmp_path), s, pv, opt, keep=2)
    import os

    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    assert C.latest_step(str(tmp_path)) == 4


def test_data_pipeline_determinism_and_sharding():
    d = DataPipeline(vocab=100, batch=8, seq=16, seed=7)
    b1, b2 = d.get_batch(3), d.get_batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # different shards draw different data; shapes divide evenly
    s0 = DataPipeline(vocab=100, batch=8, seq=16, seed=7, shard_index=0, shard_count=2)
    s1 = DataPipeline(vocab=100, batch=8, seq=16, seed=7, shard_index=1, shard_count=2)
    a, b = s0.get_batch(0), s1.get_batch(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_quantize_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    res = jnp.zeros_like(g)
    # error feedback: accumulated dequantized updates converge to the sum
    total_q = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, res = quantize(g, res)
        total_q = total_q + dequantize(q, scale)
    np.testing.assert_allclose(
        np.asarray(total_q) / 50, np.asarray(g), atol=2e-3
    )
    # single-shot error bounded by scale/2
    q, scale, r2 = quantize(g, jnp.zeros_like(g))
    assert float(jnp.max(jnp.abs(r2))) <= float(scale) / 2 + 1e-6


def test_microbatch_accumulation_matches_full():
    """Mean of microbatch grads == full-batch grad (pre-optimizer — Adam's
    step-1 update is sign(g), which would amplify float noise)."""
    cfg, pv, opt, _, data = tiny_setup()
    b = data.get_batch(0)

    def loss(p, bb):
        return M.loss_fn(cfg, p, bb)

    g_full = jax.jit(jax.grad(loss))(pv, b)

    def split(x):
        return x.reshape(2, x.shape[0] // 2, *x.shape[1:])

    mb = jax.tree.map(split, b)
    g0 = jax.jit(jax.grad(loss))(pv, jax.tree.map(lambda x: x[0], mb))
    g1 = jax.jit(jax.grad(loss))(pv, jax.tree.map(lambda x: x[1], mb))
    g_acc = jax.tree.map(lambda a, c: (a + c) / 2, g0, g1)
    for a, c in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32),
            atol=5e-3, rtol=0.1,  # bf16 activations: mean-of-halves reorders sums
        )


def test_remix_page_table_lookup():
    t = RemixPageTable(d=8)
    oracle = {}
    rng = np.random.default_rng(5)
    for gen in range(5):
        for _ in range(40):
            h = np.uint64(rng.integers(0, 2**63))
            slot, ln = int(rng.integers(0, 1000)), int(rng.integers(1, 100))
            t.add(h, slot, ln)
            oracle[int(h)] = (slot, ln)
        t.flush_generation()
    probes = list(oracle.keys())[::3] + [1, 2, 3]
    found, slots, lens = t.lookup_batch(np.array(probes, np.uint64))
    for i, h in enumerate(probes):
        if h in oracle:
            assert found[i] and (slots[i], lens[i]) == oracle[h]
        else:
            assert not found[i]


def test_serve_engine_prefix_cache_determinism():
    cfg = reduced(
        get_config("qwen2.5-3b"), n_layers=2, d_model=128, d_ff=256, vocab=64
    )
    params = M.init_params(cfg, jax.random.key(2))
    pv, _ = split_params(params)
    rng = np.random.default_rng(6)
    shared = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab, 8).astype(np.int32)])
        for _ in range(3)
    ]
    plain = ServeEngine(cfg, pv, max_seq=96)
    outs_plain = [plain.generate(p, max_new=8) for p in prompts]
    cache = PrefixCache(cfg, n_pages=64, page_size=8)
    cached = ServeEngine(cfg, pv, max_seq=96, prefix_cache=cache)
    outs_cached = [cached.generate(p, max_new=8) for p in prompts]
    for a, b in zip(outs_plain, outs_cached):
        np.testing.assert_array_equal(a, b)
    assert cached.stats.cached_tokens > 0  # later prompts reused the prefix
    assert cache.hits >= 1

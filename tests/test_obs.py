"""Observability layer tests (repro.obs + its wiring into every tier).

Covered here:
  - metrics registry: counters/gauges/multi-gauges/histograms, labels,
    snapshot/merge/diff/Prometheus rendering, the disabled null path
  - histogram percentile estimates vs numpy ground truth (log-bucketed
    bounds: relative error bounded by the bucket growth factor)
  - stats() backward compatibility: every pre-existing stats() dict
    (store, cache, executor, WAL, versions) keeps its exact keys and
    counts through the registry-backed rewrite
  - op-lifecycle tracing: a traced mixed cross-shard batch yields a
    well-formed span tree whose leaf spans cover >= 90% of the batch
    wall time, exportable as valid Chrome trace_event JSON
  - structured event log: flush -> wal_gc -> version_publish ->
    compaction ordering, ring bounding, the JSONL sink
  - CKB interval-memo bounding: entry-budget eviction + gauges
  - thread-safety smoke: concurrent increments/observes land exactly
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.obs.events import EventLog, NULL_EVENTS
from repro.obs.metrics import (
    MetricsRegistry,
    diff_snapshots,
    merge_snapshots,
    render_prometheus,
)
from repro.obs.tracing import Sampler, Trace


# ---------------------------------------------------------------- metrics
def test_counter_gauge_basics():
    reg = MetricsRegistry(labels=dict(node="a"))
    c = reg.counter("reqs", kind="get")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("reqs", kind="get") is c  # get-or-create
    g = reg.gauge("depth")
    g.set(7)
    g.dec(2)
    assert g.value == 5
    cb = reg.gauge("live", fn=lambda: 42)
    assert cb.value == 42
    samples = reg.snapshot()["metrics"]
    names = {(s["name"], tuple(sorted(s["labels"].items()))) for s in samples}
    assert ("reqs", (("kind", "get"), ("node", "a"))) in names
    with pytest.raises(ValueError):
        c.inc(-1)


def test_disabled_registry_is_null():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    c.inc(100)
    assert c.value == 0
    assert reg.gauge("y", fn=lambda: 9).value == 0
    reg.histogram("z").observe(1.0)
    assert reg.snapshot() == {"metrics": []}


def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(7)
    obs = rng.lognormal(mean=-7.0, sigma=1.2, size=20_000)
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in obs:
        h.observe(float(v))
    # bucket bounds grow by 2**0.25 per step: a geometric-midpoint
    # estimate is off by at most ~ sqrt(growth)-1 ~ 9% relative
    for q in (0.50, 0.90, 0.95, 0.99):
        est = h.percentile(q)
        ref = float(np.percentile(obs, 100 * q))
        assert abs(est - ref) / ref < 0.1, (q, est, ref)
    s = h.summary()
    assert s["count"] == len(obs)
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    assert np.isclose(s["sum"], obs.sum(), rtol=1e-6)


def test_histogram_extremes_clamped():
    reg = MetricsRegistry()
    h = reg.histogram("b", kind="bytes")
    h.observe(3)
    assert h.percentile(0.5) == pytest.approx(3.0, rel=0.5)
    assert h.percentile(0.99) <= h.summary()["max"]
    assert reg.histogram("empty").percentile(0.99) == 0.0


def test_snapshot_merge_diff_prometheus():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("hits").inc(3)
    r2.counter("hits").inc(5)
    merged = merge_snapshots(
        (r1.snapshot(), dict(shard="0")), (r2.snapshot(), dict(shard="1"))
    )
    vals = {s["labels"]["shard"]: s["value"] for s in merged["metrics"]}
    assert vals == {"0": 3, "1": 5}
    before = r1.snapshot()
    r1.counter("hits").inc(2)
    r1.histogram("lat").observe(0.5)
    d = diff_snapshots(before, r1.snapshot())["diff"]
    by_name = {row["name"]: row for row in d}
    assert by_name["hits"]["delta"] == 2
    assert by_name["lat"]["status"] == "added"
    text = render_prometheus(r1.snapshot())
    assert "# TYPE hits counter" in text
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text


def test_registry_threaded_smoke():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("lat")
    n_threads, per = 8, 2000

    def work():
        for i in range(per):
            c.inc()
            h.observe(1e-4 * (1 + i % 7))

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per
    assert h.summary()["count"] == n_threads * per


# ---------------------------------------------------------------- events
def test_event_log_ring_and_sink(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(capacity=4, jsonl_path=str(path))
    for i in range(6):
        log.emit("tick", i=i)
    evs = log.list()
    assert [e.fields["i"] for e in evs] == [2, 3, 4, 5]  # ring dropped 0,1
    assert evs[0].seq == 3 and evs[-1].seq == 6  # seq keeps counting
    st = log.stats()
    assert st["emitted"] == 6 and st["dropped"] == 2 and st["buffered"] == 4
    log.close()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 6  # the sink saw every event, ring or not
    assert lines[0]["kind"] == "tick" and lines[0]["i"] == 0
    assert NULL_EVENTS.emit("x") is None and NULL_EVENTS.list() == []
    with pytest.raises(ValueError):
        EventLog(capacity=0)


# ---------------------------------------------------------------- tracing
def test_trace_tree_and_chrome_export():
    from repro.obs.tracing import now

    tr = Trace("batch")
    with tr.span("plan"):
        pass
    with tr.span("read", shard=0):
        t0 = now()
        tr.leaf("disk_read", t0, now(), bytes=512)
    tr.finish()
    assert tr.well_formed()
    names = [s.name for s in tr.spans()]
    assert names == ["batch", "plan", "read", "disk_read"]
    doc = json.loads(tr.to_chrome_json())
    evs = doc["traceEvents"]
    assert all(e["ph"] == "X" for e in evs)
    assert {e["name"] for e in evs} == set(names)
    by = {e["name"]: e for e in evs}
    assert by["disk_read"]["args"]["bytes"] == 512
    assert by["batch"]["ts"] == 0


def test_sampler_rate():
    s = Sampler(0.25)
    picks = [s.should_sample() for _ in range(12)]
    assert picks == [True, False, False, False] * 3
    assert not any(Sampler(0.0).should_sample() for _ in range(8))
    assert all(Sampler(1.0).should_sample() for _ in range(8))
    with pytest.raises(ValueError):
        Sampler(1.5)


# ------------------------------------------------- stats() compatibility
def _fill(db, lo=1, n=300, step=7):
    keys = np.arange(lo, lo + n, dtype=np.uint64) * step
    vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
    db.put_batch(keys, vals)
    return keys


def test_store_stats_keys_unchanged(tmp_path):
    from repro.db.store import RemixDB, RemixDBConfig

    db = RemixDB.open(
        str(tmp_path / "db"), RemixDBConfig(memtable_entries=1 << 30)
    )
    keys = _fill(db)
    db.flush()
    db.get(int(keys[0]))
    s = db.stats()
    assert set(s) == {
        "partitions", "tables", "entries", "resident_tables", "memtable",
        "wa", "wal_blocks", "disk_bytes_read", "cold", "versions",
        "compaction", "health", "engine", "cache",
    }
    assert set(s["health"]) == {
        "status", "unavailable", "quarantine_files", "partitions", "io",
        "corruption_detected", "scrub", "repair",
    }
    assert s["health"]["status"] == "ok"
    assert s["health"]["partitions"][0]["degraded"] is False
    assert set(s["compaction"]) == {
        "rounds", "bytes_written", "kinds", "log_rounds", "in_flight"
    }
    assert s["compaction"]["rounds"] == 1
    assert s["compaction"]["kinds"] == {"minor": 1}
    assert s["compaction"]["bytes_written"] == db.table_bytes_written > 0
    assert set(s["cold"]) == {"gets", "scans"}
    assert set(s["versions"]) == {"current", "live", "pinned"}
    assert set(s["cache"]) >= {
        "hits", "misses", "evictions", "entries", "cached_bytes",
        "capacity_bytes",
    }
    # wa is the registry-backed ratio of the same two counters as before
    assert s["wa"] == pytest.approx(
        (db.table_bytes_written + db.wal.bytes_written)
        / max(1, db.user_bytes)
    )
    eng = s["engine"]
    assert set(eng) == {
        "batches", "completed", "cancelled_batches", "ops",
        "deadline_exceeded", "cancelled_ops", "errors", "io_errors",
        "queue_depth", "workers", "admission", "shards",
    }
    assert eng["io_errors"] == 0
    assert eng["ops"] == {
        "get": 1, "multiget": 0, "scan": 0, "put": 1, "delete": 0,
        "delete_range": 0, "cas": 0,
    }
    assert set(eng["admission"]) == {
        "max_bytes", "inflight_bytes", "peak_bytes", "admitted", "waits"
    }
    db.close()


def test_metrics_snapshot_and_disabled_store(tmp_path):
    from repro.db.store import RemixDB, RemixDBConfig

    db = RemixDB.open(
        str(tmp_path / "on"), RemixDBConfig(memtable_entries=1 << 30)
    )
    _fill(db)
    db.flush()
    snap = db.metrics()
    names = {s["name"] for s in snap["metrics"]}
    assert {"db_user_bytes", "db_table_bytes_written", "wal_bytes_written",
            "cache_hits", "versions_published",
            "db_flush_seconds"} <= names
    text = render_prometheus(snap)
    assert "db_flush_seconds_count 1" in text
    db.close()
    off = RemixDB(RemixDBConfig(metrics=False, memtable_entries=1 << 30))
    _fill(off)
    off.flush()
    # registry-backed fields read zero; structure stays intact
    assert off.metrics() == {"metrics": []}
    assert off.events.list() == []
    assert off.stats()["compaction"]["rounds"] == 0
    off.close()


# ------------------------------------------------------- tracing (store)
def test_traced_cross_shard_batch(tmp_path):
    from repro.db.ops import Batch
    from repro.db.store import RemixDB, RemixDBConfig
    from repro.serve.engine import KVServeEngine

    split = 1 << 32
    dirs = []
    for i, lo in enumerate((0, split)):
        d = str(tmp_path / f"s{i}")
        db = RemixDB.open(d, RemixDBConfig(memtable_entries=1 << 30))
        _fill(db, lo=lo + 1, n=200, step=1)
        db.flush()
        db.close()
        dirs.append(d)
    eng = KVServeEngine([(0, dirs[0]), (split, dirs[1])])
    b = (
        Batch(trace=True)
        .get(5)
        .get(split + 10)
        .multiget(np.arange(20, 30, dtype=np.uint64))
        .scan(split + 50, 16)
        .put(9, [1, 2])
        .delete(split + 60)
    )
    res = eng.submit(b, sync=True).result()
    assert res.ok
    tr = res.trace
    assert tr is not None and tr.well_formed()
    names = [s.name for s in tr.spans()]
    assert names[0] == "batch" and "plan" in names
    assert any(n == "shard0:read" for n in names)
    assert any(n == "shard1:read" for n in names)
    assert any(n.endswith(":commit") for n in names)
    # leaf spans account for >= 90% of the batch wall time
    assert tr.leaf_coverage() >= 0.9, tr.leaf_coverage()
    doc = json.loads(tr.to_chrome_json())
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == len(names)
    assert all(
        e["ph"] == "X" and e["dur"] >= 0 and e["ts"] >= 0
        for e in doc["traceEvents"]
    )
    # untraced batches carry no trace at rate 0
    res2 = eng.submit(Batch().get(5), sync=True).result()
    assert res2.trace is None
    eng.close()


def test_trace_sample_rate(tmp_path):
    from repro.db.ops import Batch
    from repro.db.store import RemixDB, RemixDBConfig

    db = RemixDB(
        RemixDBConfig(memtable_entries=1 << 30, trace_sample_rate=0.5)
    )
    _fill(db, n=50)  # the fill batch consumes the sampler's first pick
    traces = []
    for i in range(4):
        r = db.submit(Batch().get(7), sync=True).result()
        traces.append(r.trace)
    assert [t is not None for t in traces] == [False, True, False, True]
    assert traces[1].sampled  # sampled, not explicitly requested
    assert db.engine().last_trace is traces[3]
    db.close()


# --------------------------------------------------------------- events
def test_store_event_lifecycle(tmp_path):
    from repro.db.store import RemixDB, RemixDBConfig

    sink = tmp_path / "ev.jsonl"
    db = RemixDB.open(
        str(tmp_path / "db"),
        RemixDBConfig(memtable_entries=1 << 30,
                      event_log_path=str(sink)),
    )
    _fill(db)
    db.flush()
    kinds = [e.kind for e in db.events.list()]
    # one flush round, in causal order
    for a, b in (
        ("flush", "wal_gc"),
        ("wal_gc", "wal_checkpoint"),
        ("wal_checkpoint", "version_publish"),
        ("version_publish", "compaction"),
    ):
        assert kinds.index(a) < kinds.index(b), kinds
    flush_ev = db.events.list(kind="flush")[0]
    assert flush_ev.fields["entries"] == 300
    comp = db.events.list(kind="compaction")[0]
    assert comp.fields["kinds"] == {"minor": 1}
    assert comp.fields["bytes_written"] > 0
    db.close()
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert [ln["kind"] for ln in lines] == kinds
    # reopen: recovery emits its own event
    db2 = RemixDB.open(
        str(tmp_path / "db"), RemixDBConfig(memtable_entries=1 << 30)
    )
    assert [e.kind for e in db2.events.list()] == ["recover"]
    db2.close()


def test_executor_failure_event(tmp_path):
    from repro.db.ops import Batch, Op
    from repro.db.store import RemixDB, RemixDBConfig

    db = RemixDB(RemixDBConfig(memtable_entries=1 << 30))
    _fill(db, n=20)
    eng = db.engine()

    class Boom(Exception):
        pass

    orig = eng.plan
    eng.plan = lambda batch: (_ for _ in ()).throw(Boom("planner down"))
    try:
        res = db.submit(Batch().get(1), sync=True).result()
        # plan-level failure -> per-op ERROR results, not a dead future
        assert not res.ok
        with pytest.raises(Boom):
            res.results[0].raise_if_error()
    finally:
        eng.plan = orig
    errs = db.events.list(kind="batch_error")
    assert len(errs) == 1 and "Boom" in errs[0].fields["error"]
    assert eng.registry.counter("engine_batch_failures").value == 1
    db.close()


# ------------------------------------------------------------- CKB memo
def test_ckb_memo_bounded(tmp_path):
    from repro.db.store import RemixDB, RemixDBConfig

    # tiny cache budget -> tiny memo budget (capacity_bytes // 64)
    db = RemixDB.open(
        str(tmp_path / "db"),
        RemixDBConfig(memtable_entries=1 << 30, cache_bytes=16 << 10,
                      promote_fraction=1e9),
    )
    keys = _fill(db, n=4000, step=3)
    db.flush()
    db.close()
    db = RemixDB.open(
        str(tmp_path / "db"),
        RemixDBConfig(memtable_entries=1 << 30, cache_bytes=16 << 10,
                      promote_fraction=1e9),
    )
    rng = np.random.default_rng(3)
    for _ in range(6):
        qs = rng.choice(keys, 64, replace=False).astype(np.uint64)
        f, _ = db.get_batch(qs)
        assert f.all()
    budget = (16 << 10) // 64
    entries = db._ckb_memo("entries")
    assert 0 < entries <= budget + 64  # <= budget rounded up to one row
    assert db._ckb_memo("evictions") > 0
    snap = db.metrics()
    vals = {
        s["name"]: s["value"]
        for s in snap["metrics"]
        if s["name"].startswith("ckb_memo")
    }
    assert vals["ckb_memo_entries"] == entries
    assert vals["ckb_memo_evictions"] == db._ckb_memo("evictions")
    assert vals["ckb_memo_bytes"] > 0
    db.close()


def test_write_surface_counters_and_drop_event(tmp_path):
    """The new write-surface instruments: delete_range / cas_conflict /
    ttl_expired_dropped counters, plus the range_tombstone_drop event
    from a fold that retires whole tables."""
    from repro.db import clock
    from repro.db.compaction import CompactionConfig
    from repro.db.store import RemixDB, RemixDBConfig

    t = [1000.0]
    clock.set_source(lambda: t[0])
    db = RemixDB.open(
        str(tmp_path / "db"),
        RemixDBConfig(
            memtable_entries=128,
            compaction=CompactionConfig(table_cap=128, t_max=2),
            hot_threshold=255,
        ),
    )

    def counter(name):
        return sum(
            s["value"]
            for s in db.registry.snapshot()["metrics"]
            if s["name"] == name
        )

    try:
        keys = np.arange(0, 100, dtype=np.uint64)
        db.put_batch(
            keys, np.stack([keys, keys], 1).astype(np.uint32), ttl=30
        )
        db.flush()
        # two range deletes
        db.delete_range(10, 40)
        db.delete_range(50, 60)
        assert counter("delete_range") == 2
        # one CAS conflict, one success: only the conflict counts
        ok, _ = db.cas(5, np.array([9, 9], np.uint32),
                       np.array([1, 1], np.uint32))
        assert not ok
        ok, _ = db.cas(5, np.array([5, 5], np.uint32),
                       np.array([1, 1], np.uint32))
        assert ok
        assert counter("cas_conflict") == 1
        # whole-table drop: everything is covered by one range
        db.delete_range(0, 1000)
        db.flush()
        drops = db.events.list(kind="range_tombstone_drop")
        assert drops and drops[0].fields["tables"] >= 1
        assert counter("range_tombstone_drop") >= 1
        # expire TTL rows, churn a merge over them, and watch the GC
        t[0] = 1031.0
        for i in range(6):
            db.put_batch(
                keys, np.full((100, 2), i + 1, np.uint32), ttl=1
            )
            t[0] += 5.0
            db.flush()
        assert counter("ttl_expired_dropped") > 0
    finally:
        clock.reset()
        db.close()


# ------------------------------------------ durability counters & events
def test_scrub_counters_and_events(tmp_path):
    """The scrub/repair lifecycle lands in the registry and event log:
    a clean pass ticks scrub_passes/scrub_bytes_read only; an injected
    REMIX corruption adds corruption_detected + repair_remix_rebuilt and
    emits corruption -> repair -> scrub events in causal order."""
    import glob as _glob

    from repro.db.store import RemixDB, RemixDBConfig
    from repro.io.faults import flip_bytes

    db = RemixDB.open(
        str(tmp_path / "db"), RemixDBConfig(memtable_entries=1 << 30)
    )
    _fill(db)
    db.flush()
    rep = db.scrub(full=True)
    assert rep["clean"] and rep["bytes_read"] > 0
    c = lambda n: db.registry.counter(n).value
    assert c("scrub_passes") == 1
    assert c("scrub_bytes_read") == rep["bytes_read"]
    assert c("corruption_detected") == 0
    db.close()

    rx = sorted(_glob.glob(str(tmp_path / "db" / "remix" / "*.rmx")))
    flip_bytes(rx[0], 64, 4)
    db2 = RemixDB.open(
        str(tmp_path / "db"), RemixDBConfig(memtable_entries=1 << 30)
    )
    rep = db2.scrub(full=True)
    assert not rep["clean"] and rep["repaired"]
    c = lambda n: db2.registry.counter(n).value
    assert c("corruption_detected") >= 1
    assert c("repair_remix_rebuilt") == 1
    assert c("repair_table_quarantined") == 0
    kinds = [e.kind for e in db2.events.list()]
    assert kinds.index("corruption") < kinds.index("repair") \
        < kinds.index("scrub")
    ev = db2.events.list(kind="corruption")[-1]
    assert ev.fields["target"] == "remix"
    # the new names surface through metrics() for Prometheus rendering
    names = {s["name"] for s in db2.metrics()["metrics"]}
    assert {"scrub_passes", "scrub_bytes_read", "corruption_detected",
            "repair_remix_rebuilt", "repair_table_quarantined",
            "quarantine_purged", "io_retry", "io_giveup"} <= names
    assert db2.scrub(full=True)["clean"]
    db2.close()

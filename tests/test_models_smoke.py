"""Per-architecture smoke tests on reduced configs (CPU): one forward +
one train-ish step (grads) + decode step; asserts shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.models import model as M
from repro.models.layers import split_params


def make_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    )
    if cfg.frontend == "vlm":
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)), jnp.float32
        )
    if cfg.family == "encdec":
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grads(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.key(0))
    pv, _ = split_params(params)
    batch = make_batch(cfg)

    @jax.jit
    def loss_and_grad(p, b):
        loss, grads = jax.value_and_grad(lambda q: M.loss_fn(cfg, q, b))(p)
        return loss, grads

    loss, grads = loss_and_grad(pv, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch
    logits = jax.jit(lambda p, b: M.forward(cfg, p, b))(pv, batch)
    s_out = batch["tokens"].shape[1] + (
        cfg.frontend_len if cfg.frontend == "vlm" else 0
    )
    assert logits.shape == (2, s_out, cfg.vocab), (arch, logits.shape)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.key(0))
    pv, _ = split_params(params)
    b, s = 2, 16
    cache = M.init_cache(cfg, b, s)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = jnp.zeros((b, 8, cfg.d_model), jnp.bfloat16)

    @jax.jit
    def step(p, c, tok, pos):
        return M.decode_step(cfg, p, c, tok, pos, enc_out=enc_out)

    tok = jnp.zeros((b,), jnp.int32)
    logits, cache = step(pv, cache, tok, 0)
    logits2, cache = step(pv, cache, tok, 1)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()) and bool(jnp.isfinite(logits2).all())
    for leaf in jax.tree.leaves(cache):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


def test_flash_attention_matches_naive():
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(1)
    b, s, h, kvh, hd = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, hd)), jnp.float32)
    for window, cap in [(None, None), (8, None), (None, 20.0), (8, 20.0)]:
        out = flash_attention(q, k, v, causal=True, window=window, cap=cap, block=16)
        # naive reference
        kk = jnp.repeat(k, h // kvh, axis=2)
        vv = jnp.repeat(v, h // kvh, axis=2)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
        if cap:
            sc = jnp.tanh(sc / cap) * cap
        i, j = np.arange(s)[:, None], np.arange(s)[None, :]
        mask = j <= i
        if window:
            mask &= j > i - window
        sc = jnp.where(mask[None, None], sc, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5,
            err_msg=f"window={window} cap={cap}",
        )


def test_ssd_chunked_matches_recurrence():
    from repro.models.layers import _ssd_chunked

    rng = np.random.default_rng(2)
    b, s, h, p, n, chunk = 2, 32, 3, 8, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, final = _ssd_chunked(x, dt, a, bm, cm, chunk)
    # naive per-step recurrence
    state = np.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(a)[None])  # (B,H)
        upd = np.einsum(
            "bhp,bn->bhpn",
            np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None],
            np.asarray(bm[:, t]),
        )
        state = state * da[..., None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", state, np.asarray(cm[:, t])))
    ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "minicpm3-4b", "mamba2-130m", "gemma2-27b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = reduced(get_config(arch), n_layers=2)
    params = M.init_params(cfg, jax.random.key(1))
    pv, _ = split_params(params)
    rng = np.random.default_rng(3)
    b, s = 1, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full = M.forward(cfg, pv, dict(tokens=tokens), remat=False)
    cache = M.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        logits, cache = M.decode_step(cfg, pv, cache, tokens[:, t], t)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_param_count_in_range():
    """Full configs must land near their nominal sizes (sanity of configs)."""
    expect = {
        "internvl2-26b": (17e9, 26e9),  # LM backbone only (InternLM2-20B)
        "qwen2.5-3b": (2.0e9, 3.5e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "minicpm3-4b": (3e9, 5e9),
        "gemma2-27b": (22e9, 30e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "zamba2-2.7b": (2e9, 3.4e9),
        "arctic-480b": (400e9, 520e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).n_params()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"

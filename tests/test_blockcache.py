"""Tests for the block cache + cold-start read path: LRU accounting, CKB
restart-point seeks, cold/hot query equivalence, lazy checksum detection,
introspection laziness, and the shared-cache serving front."""
import os

import numpy as np
import pytest

from repro.core import keys as CK
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.db.store import RemixDB, RemixDBConfig
from repro.db.wal import WAL
from repro.io.blockcache import BlockCache
from repro.io.ckb import CKBReader, decode_ckb, encode_ckb
from repro.io.manifest import Storage
from repro.io.sstable import SSTableReader


def test_blockcache_lru_eviction_and_counters():
    c = BlockCache(capacity_bytes=100)
    c.put("a", b"x" * 40)
    c.put("b", b"y" * 40)
    assert c.get("a") == b"x" * 40  # refresh: 'a' is now MRU
    c.put("c", b"z" * 40)  # over budget -> evicts LRU = 'b'
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    st = c.stats()
    assert st["hits"] == 3 and st["misses"] == 1 and st["evictions"] == 1
    assert st["cached_bytes"] == 80 and st["entries"] == 2
    c.put("huge", b"q" * 1000)  # larger than budget: served, never cached
    assert c.get("huge") is None
    c.clear()
    assert len(c) == 0 and c.stats()["cached_bytes"] == 0


def test_blockcache_get_or_load():
    c = BlockCache(capacity_bytes=1 << 10)
    calls = []
    load = lambda: calls.append(1) or b"data"
    assert c.get_or_load("k", load) == b"data"
    assert c.get_or_load("k", load) == b"data"
    assert len(calls) == 1  # second call was a hit


def test_ckb_reader_key_at_and_seek():
    rng = np.random.default_rng(0)
    u = np.sort(rng.choice(1 << 40, 3000, replace=False).astype(np.uint64))
    keys = CK.pack_u64(u)
    buf = encode_ckb(keys)
    rd = CKBReader.from_bytes(buf)
    assert rd.n == 3000
    for i in [0, 1, 15, 16, 17, 1234, 2999]:
        np.testing.assert_array_equal(rd.key_at(i), keys[i])
    # seek == np.searchsorted lower bound, bounded and unbounded
    probes = np.concatenate([u[::97], u[::101] + 1, [0, u[-1] + 5]])
    for q in probes:
        qw = CK.pack_u64(np.array([q], np.uint64))[0]
        want = int(np.searchsorted(u, q, side="left"))
        assert rd.seek(qw) == want
    # bounded seeks clamp to [lo, hi)
    qw = CK.pack_u64(np.array([u[500]], np.uint64))[0]
    assert rd.seek(qw, 100, 400) == 400  # everything in range is smaller
    assert rd.seek(qw, 490, 510) == 500
    assert rd.seek(qw, 501, 510) == 501  # lower bound respects lo


def _commit_store(root, runs, d=32, seq=1_000_000):
    """Commit prebuilt runs as a single-partition on-disk store."""
    storage = Storage(root)
    names = [
        storage.write_table(
            np.asarray(run.keys), np.asarray(run.vals),
            np.asarray(run.seq), np.asarray(run.tomb),
        )
        for run in runs
    ]
    remix, _ = build_remix(runs, d=d)
    xname = storage.write_remix(remix)
    wal = WAL(storage.wal_path())
    storage.commit(
        dict(
            seq=seq, vw=2, d=d,
            partitions=[dict(lo=0, tables=names, remix=xname)],
            wal=wal.save_state(),
        )
    )


def _build_store(root, r_tables=4, n_per_table=4096, tomb_every=0, d=32,
                 offset=0):
    """Committed on-disk store (tables + REMIX + manifest); returns keys."""
    rng = np.random.default_rng(1)
    total = r_tables * n_per_table
    domain = np.uint64(offset) + np.arange(1, total + 1, dtype=np.uint64) * 8
    owner = rng.integers(0, r_tables, total)
    runs, seqbase = [], 1
    for i in range(r_tables):
        kk = domain[owner == i]
        tomb = np.zeros(len(kk), bool)
        if tomb_every:
            tomb[::tomb_every] = True
        runs.append(
            make_run(
                kk, seq=np.arange(seqbase, seqbase + len(kk),
                                  dtype=np.uint32),
                tomb=tomb,
            )
        )
        seqbase += len(kk)
    _commit_store(root, runs, d=d, seq=seqbase)
    return domain


def _cold_cfg(**kw):
    # promote_fraction > 1 pins the store to the cold path for the whole test
    return RemixDBConfig(promote_fraction=kw.pop("promote_fraction", 2.0), **kw)


def test_cold_get_matches_hot(tmp_path):
    root = str(tmp_path / "db")
    domain = _build_store(root, tomb_every=7)
    rng = np.random.default_rng(2)
    probes = np.concatenate(
        [rng.choice(domain, 300, replace=False),
         rng.choice(domain, 100) + 1,  # misses
         np.array([0, int(domain[-1]) + 10], np.uint64)]
    ).astype(np.uint64)
    hot = RemixDB.open(root, RemixDBConfig(cold_reads=False))
    cold = RemixDB.open(root, _cold_cfg())
    f0, v0 = hot.get_batch(probes)
    f1, v1 = cold.get_batch(probes)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(v0[f0], v1[f1])
    st = cold.stats()
    assert st["cold"]["gets"] == len(probes)
    assert st["cache"]["hits"] > 0
    assert st["resident_tables"] == 0  # no table was fully loaded
    # at this toy scale many probes may touch every granule, but the cold
    # path can never read more than the whole-table path (cache_bench
    # asserts the < 10 % bar at realistic table sizes)
    assert cold.disk_bytes_read() <= hot.disk_bytes_read()


def test_cold_scan_matches_hot(tmp_path):
    root = str(tmp_path / "db")
    domain = _build_store(root, tomb_every=5)
    hot = RemixDB.open(root, RemixDBConfig(cold_reads=False))
    cold = RemixDB.open(root, _cold_cfg())
    for start, n in [(0, 100), (int(domain[777]), 64), (int(domain[-3]), 50)]:
        k0, v0 = hot.scan(start, n)
        k1, v1 = cold.scan(start, n)
        np.testing.assert_array_equal(k0, k1)
        np.testing.assert_array_equal(v0, v1)
    assert cold.stats()["cold"]["scans"] > 0
    assert cold.stats()["resident_tables"] == 0


def test_cold_scan_batch_matches_hot(tmp_path):
    """The cold window consumes view slots exactly like the device
    gather_view window (tombstones/old versions eat budget), so
    scan_batch results never change across the promotion boundary."""
    root = str(tmp_path / "db")
    domain = _build_store(root, tomb_every=3)
    hot = RemixDB.open(root, RemixDBConfig(cold_reads=False))
    cold = RemixDB.open(root, _cold_cfg())
    starts = np.array(
        [0, int(domain[100]), int(domain[-50]), int(domain[-1]) + 8],
        np.uint64,
    )
    k0, m0 = hot.scan_batch(starts, 20)
    k1, m1 = cold.scan_batch(starts, 20)
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(m0, m1)


def test_cold_scan_placeholder_landing_matches_device(tmp_path):
    """Multi-version clusters with a small D pad group tails with
    placeholders; when a seek lands on that tail the cold window must
    skip to the next group head exactly like the device seek does."""
    root = str(tmp_path / "db")
    rng = np.random.default_rng(9)
    u_a = np.arange(1, 401, dtype=np.uint64) * 4
    u_b = np.sort(rng.choice(u_a, 160, replace=False))  # newer versions
    runs = [
        make_run(u_a, seq=np.arange(1, 401, dtype=np.uint32)),
        make_run(u_b, seq=np.arange(1000, 1160, dtype=np.uint32)),
    ]
    _commit_store(root, runs, d=4)
    hot = RemixDB.open(root, RemixDBConfig(cold_reads=False))
    cold = RemixDB.open(root, _cold_cfg())
    starts = np.arange(0, int(u_a[-1]) + 8, 3, dtype=np.uint64)
    k0, m0 = hot.scan_batch(starts, 16)
    k1, m1 = cold.scan_batch(starts, 16)
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(m0, m1)


def test_scan_survives_tombstone_runs_wider_than_window(tmp_path):
    """A run of >= width consecutive tombstones must not truncate the
    scan: the window widens and retries instead of declaring the
    partition exhausted (both cold and device paths, scan and
    scan_batch)."""
    root = str(tmp_path / "db")
    u = np.arange(1, 101, dtype=np.uint64) * 10
    tomb = np.zeros(100, bool)
    tomb[:60] = True  # first 60 keys deleted
    runs = [make_run(u, seq=np.arange(1, 101, dtype=np.uint32), tomb=tomb)]
    _commit_store(root, runs)
    want = u[60:64]
    for cfg in (RemixDBConfig(cold_reads=False), _cold_cfg()):
        db = RemixDB.open(root, cfg)
        kk, _ = db.scan(5, 4)  # width 8 << 60 tombstones
        np.testing.assert_array_equal(kk, want)
        kb, mb = db.scan_batch(np.array([5], np.uint64), 4)
        np.testing.assert_array_equal(kb[0][mb[0]], want)


def test_recovery_adopts_persisted_group_size(tmp_path):
    """cfg.d is overridden by the manifest's d: the on-disk REMIXes were
    built with it, and cold vs promoted windows must agree."""
    root = str(tmp_path / "db")
    domain = _build_store(root, d=8)
    db = RemixDB.open(root)  # default config asks for d=32
    assert db.cfg.d == 8
    starts = np.array([0, int(domain[50]), int(domain[-30])], np.uint64)
    k0, m0 = RemixDB.open(root, RemixDBConfig(cold_reads=False)).scan_batch(
        starts, 16
    )
    k1, m1 = RemixDB.open(root, _cold_cfg()).scan_batch(starts, 16)
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(m0, m1)


def test_table_read_block_granules(tmp_path):
    """Table.read_block(section, idx) returns the verified checksum
    granule overlapping the section, straight from the file bytes."""
    root = str(tmp_path / "db")
    _build_store(root, r_tables=1, n_per_table=40_000)
    db = RemixDB.open(root, _cold_cfg())
    t = db.partitions[0].tables[0]
    rd = t._rd()
    for section in ("keys", "vals", "tomb"):
        blk = t.read_block(section, 0)
        b0 = rd.section_block0(section)
        lo = rd._data_start + b0 * rd.block_bytes
        hi = min(lo + rd.block_bytes, rd._data_end)
        with open(t.path, "rb") as f:
            f.seek(lo)
            assert blk == f.read(hi - lo)
    with pytest.raises(IndexError):
        t.read_block("keys", 10**6)


def test_cold_promotion_builds_device_index(tmp_path):
    root = str(tmp_path / "db")
    domain = _build_store(root)
    db = RemixDB.open(root, RemixDBConfig(promote_fraction=0.0))
    assert db.get(int(domain[5])) is not None  # promoted immediately
    assert db.stats()["cold"]["gets"] == 0
    assert db.partitions[0]._remix is not None


def test_corruption_detected_only_when_block_touched(tmp_path):
    root = str(tmp_path / "db")
    domain = _build_store(root, r_tables=1, n_per_table=40_000)
    storage = Storage(root)
    name = storage.manifest.load()["partitions"][0]["tables"][0]
    path = storage.table_path(name)
    rd = SSTableReader(path)
    vlo, vhi = rd._section_range("vals")
    bb = rd.block_bytes
    # first granule fully inside the vals section
    bad = (vlo - rd._data_start + bb - 1) // bb
    blo = rd._data_start + bad * bb
    assert blo >= vlo and blo + bb <= vhi, "vals section too small for test"
    with open(path, "r+b") as f:
        f.seek(blo + 17)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    # rows whose value bytes live inside / outside the corrupted granule
    row_bad = (blo + bb // 2 - vlo) // rd.row_bytes("vals")
    row_ok = 10
    assert not (blo <= vlo + row_ok * rd.row_bytes("vals") < blo + bb)
    db = RemixDB.open(root, _cold_cfg())
    # single-table store: row i of the run is domain[i]
    assert db.get(int(domain[row_ok])) is not None  # untouched block: fine
    with pytest.raises(ValueError, match="checksum"):
        db.get(int(domain[row_bad]))


def test_stats_and_repr_do_not_force_load(tmp_path):
    root = str(tmp_path / "db")
    _build_store(root)
    db = RemixDB.open(root, _cold_cfg())
    st = db.stats()
    assert st["entries"] == 4 * 4096 and st["tables"] == 4
    for p in db.partitions:
        repr(p)
        for t in p.tables:
            repr(t)
    for p in db.partitions:
        assert p._remix is None  # no index build
        for t in p.tables:
            assert not t.resident
            assert t._reader is None or sum(t._reader.bytes_read.values()) == 0
    assert db.disk_bytes_read() == 0
    assert st["resident_tables"] == 0 and st["cold"]["gets"] == 0


def test_kv_serve_engine_shared_cache(tmp_path):
    from repro.serve import KVServeEngine

    root0, root1 = str(tmp_path / "shard0"), str(tmp_path / "shard1")
    keys0 = _build_store(root0)
    split = int(keys0[-1]) + 1
    keys1 = _build_store(root1, offset=split)
    eng = KVServeEngine([(0, root0), (split, root1)], cache_bytes=8 << 20,
                        config=_cold_cfg())
    for db in eng.shards:
        assert db.block_cache is eng.cache  # one pool across all shards
    assert eng.get(int(keys0[7])) is not None
    assert eng.get(int(keys1[7])) is not None  # routed to the second shard
    f, v = eng.get_batch(np.array([int(keys0[3]), int(keys1[9]), 1], np.uint64))
    assert f[0] and f[1] and not f[2]
    kk, vv = eng.scan(0, 40)
    assert len(kk) == 40 and np.all(np.diff(kk.astype(np.int64)) > 0)
    st = eng.stats()
    assert st["shards"] == 2 and st["cold"]["gets"] >= 3
    assert st["cache"]["misses"] > 0

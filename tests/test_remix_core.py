"""Unit tests for the REMIX core against brute-force oracles."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import keys as K
from repro.core import query as Q
from repro.core import merge_iter as M
from repro.core.remix import build_remix
from repro.core.runs import make_run, stack_runs
from repro.core.view import PLACEHOLDER, build_view


def paper_fig3_runs():
    """The example of Fig. 3: three runs forming the 15-key sorted view."""
    r0 = make_run(np.array([2, 11, 23, 71, 91], np.uint64), seq=0)
    r1 = make_run(np.array([6, 7, 17, 29, 73], np.uint64), seq=1)
    r2 = make_run(np.array([4, 31, 43, 52, 67], np.uint64), seq=2)
    return [r0, r1, r2]


def brute_force_view(runs):
    """Sorted (key, seq desc) list of all entries, as u64."""
    items = []
    for i, r in enumerate(runs):
        kk = K.unpack_u64(np.asarray(r.keys))
        for j in range(r.n):
            items.append((int(kk[j]), -int(np.asarray(r.seq)[j]), i, j))
    items.sort()
    return items


def test_fig3_layout():
    runs = paper_fig3_runs()
    remix, runset = build_remix(runs, d=4)
    anchors = K.unpack_u64(np.asarray(remix.anchors))
    # Paper: anchors 2, 11, 31, 71
    assert list(anchors[:4]) == [2, 11, 31, 71]
    # Paper: cursor offsets for group of anchor 11 are (1, 2, 1)
    assert list(np.asarray(remix.cursors)[1]) == [1, 2, 1]
    # Paper run selectors (runs renumbered: R0->0 etc.):
    sels = np.asarray(remix.selectors) & 0x7F
    expect = [0, 2, 1, 1, 0, 1, 0, 1, 2, 2, 2, 2, 0, 1, 0]
    assert list(sels[:15]) == expect
    assert remix.n_slots == 16 and int(remix.n_entries) == 15


def test_seek_matches_bruteforce():
    rng = np.random.default_rng(0)
    runs = [
        make_run(np.sort(rng.choice(10_000, size=n, replace=False)).astype(np.uint64), seq=i)
        for i, n in enumerate([300, 500, 200, 400])
    ]
    remix, runset = build_remix(runs, d=32)
    items = brute_force_view(runs)
    all_keys = np.array([it[0] for it in items], np.uint64)
    queries = rng.integers(0, 10_100, size=257).astype(np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    for mode in ("vector", "binary"):
        pos = np.asarray(Q.seek(remix, runset, qk, ingroup=mode))
        keys, vals, valid = (np.asarray(x) for x in Q.gather_view(remix, runset, jnp.asarray(pos), 1))
        got = K.unpack_u64(keys[:, 0])
        expect_idx = np.searchsorted(all_keys, queries, side="left")
        for i, e in enumerate(expect_idx):
            if e >= len(all_keys):
                assert not valid[i, 0], (mode, i)
            else:
                assert valid[i, 0] and got[i] == all_keys[e], (mode, i, queries[i])


def test_scan_matches_bruteforce_and_merge_iter():
    rng = np.random.default_rng(1)
    runs = [
        make_run(np.sort(rng.choice(5_000, size=400, replace=False)).astype(np.uint64), seq=i)
        for i in range(8)
    ]
    remix, runset = build_remix(runs, d=32)
    items = brute_force_view(runs)
    queries = rng.integers(0, 5_100, size=64).astype(np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    W = 50
    keys, vals, valid, _ = Q.scan(remix, runset, qk, width=W)
    mkeys, mvals, mvalid = M.merge_scan(runset, qk, width=W)
    all_keys = np.array([it[0] for it in items], np.uint64)
    uniq = np.unique(all_keys)
    for i, q in enumerate(queries):
        start = np.searchsorted(uniq, q, side="left")
        got = K.unpack_u64(np.asarray(keys)[i][np.asarray(valid)[i]])
        mgot = K.unpack_u64(np.asarray(mkeys)[i][np.asarray(mvalid)[i]])
        # W view slots contain >= W/2 unique newest keys in this workload;
        # every returned key must be the correct next unique key in order.
        expect = uniq[start : start + len(got)]
        assert len(got) >= 25, f"too few results q={q}: {len(got)}"
        assert list(got) == list(expect), f"remix scan mismatch q={q}"
        mexpect = uniq[start : start + len(mgot)]
        assert list(mgot) == list(mexpect), f"merge scan mismatch q={q}"
        assert abs(len(mgot) - len(got)) <= 8, (len(got), len(mgot))


def test_versions_and_tombstones():
    # same key updated across runs; newest wins; tombstone hides key
    r0 = make_run(np.array([5, 10, 20], np.uint64), seq=1)
    r1 = make_run(np.array([10, 30], np.uint64), seq=2)  # 10 updated
    r2 = make_run(
        np.array([20, 40], np.uint64), seq=3, tomb=np.array([True, False])
    )  # 20 deleted
    remix, runset = build_remix([r0, r1, r2], d=4)
    qk = jnp.asarray(K.pack_u64(np.array([5, 10, 20, 30, 40, 41], np.uint64)))
    found, vals = Q.get(remix, runset, qk)
    assert list(np.asarray(found)) == [True, True, False, True, True, False]
    # newest version of 10 comes from r1 (seq=2): val[-1] stores seq
    assert int(np.asarray(vals)[1, -1]) == 2
    # scan must skip the tombstoned 20 and the old 10
    keys, vals2, valid, _ = Q.scan(remix, runset, qk[:1], width=8)
    got = K.unpack_u64(np.asarray(keys)[0][np.asarray(valid)[0]])
    assert list(got) == [5, 10, 30, 40]
    # merging iterator agrees
    mf, mv = M.merge_get(runset, qk)
    assert list(np.asarray(mf)) == [True, True, False, True, True, False]


def test_placeholders_keep_anchor_newest():
    # force a version cluster to straddle a group boundary: 7 singleton keys
    # fill slots 0..6, then key 8's two versions would sit at slots 7|8.
    r0 = make_run(np.arange(1, 9, dtype=np.uint64), seq=0)  # 1..8
    r1 = make_run(np.array([8, 9], np.uint64), seq=1)  # 8 updated
    layout = build_view(
        [np.asarray(r.keys) for r in (r0, r1)],
        [np.asarray(r.seq) for r in (r0, r1)],
        d=8,
    )
    sel = layout.sel
    assert sel[7] == PLACEHOLDER  # padding pushed the cluster to group 2
    remix, runset = build_remix([r0, r1], d=8)
    anchors = K.unpack_u64(np.asarray(remix.anchors))
    assert anchors[1] == 8  # second group starts at the NEWEST version of 8
    qk = jnp.asarray(K.pack_u64(np.array([8], np.uint64)))
    found, vals = Q.get(remix, runset, qk)
    assert bool(np.asarray(found)[0]) and int(np.asarray(vals)[0, -1]) == 1


def test_exact_fit_cluster_needs_no_placeholder():
    # a cluster ending exactly at a group boundary must NOT be padded
    r0 = make_run(np.arange(1, 8, dtype=np.uint64), seq=0)  # 1..7
    r1 = make_run(np.array([7, 8], np.uint64), seq=1)
    layout = build_view(
        [np.asarray(r.keys) for r in (r0, r1)],
        [np.asarray(r.seq) for r in (r0, r1)],
        d=8,
    )
    assert layout.sel[6] == (1 | 0x80)  # newest version of 7 from r1
    assert layout.sel[7] == 0  # old version of 7 from r0, no pad
    remix, runset = build_remix([r0, r1], d=8)
    anchors = K.unpack_u64(np.asarray(remix.anchors))
    assert anchors[1] == 8


def test_get_ingroup_modes_agree():
    rng = np.random.default_rng(2)
    runs = [
        make_run(np.sort(rng.choice(3000, size=333, replace=False)).astype(np.uint64), seq=i)
        for i in range(5)
    ]
    remix, runset = build_remix(runs, d=16)
    queries = rng.integers(0, 3100, size=128).astype(np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    f1, v1 = Q.get(remix, runset, qk, ingroup="vector")
    f2, v2 = Q.get(remix, runset, qk, ingroup="binary")
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(
        np.asarray(v1)[np.asarray(f1)], np.asarray(v2)[np.asarray(f2)]
    )


def test_empty_and_single_run():
    r0 = make_run(np.array([], np.uint64).reshape(0), seq=0)
    r1 = make_run(np.array([3], np.uint64), seq=1)
    remix, runset = build_remix([r0, r1], d=4)
    qk = jnp.asarray(K.pack_u64(np.array([1, 3, 4], np.uint64)))
    found, _ = Q.get(remix, runset, qk)
    assert list(np.asarray(found)) == [False, True, False]

"""Integration tests for RemixDB and the baseline stores."""
import numpy as np
import pytest

from repro.db.baseline import BaselineConfig, LeveledStore, TieredStore
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig


def small_cfg(tmp_path, **kw):
    comp = CompactionConfig(table_cap=256, t_max=6)
    return RemixDBConfig(
        memtable_entries=kw.pop("memtable_entries", 512),
        compaction=comp,
        wal_dir=str(tmp_path),
        hot_threshold=kw.pop("hot_threshold", 255),
        **kw,
    )


def test_put_get_scan_roundtrip(tmp_path):
    db = RemixDB(small_cfg(tmp_path))
    rng = np.random.default_rng(0)
    keys = rng.choice(100_000, size=3000, replace=False).astype(np.uint64)
    vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], axis=1).astype(np.uint32)
    db.put_batch(keys, vals)
    db.flush()
    # point lookups
    probe = np.concatenate([keys[:500], np.array([100_001, 100_002], np.uint64)])
    found, got = db.get_batch(probe)
    assert found[:500].all() and not found[500:].any()
    np.testing.assert_array_equal(got[:500, 0], (probe[:500] & 0xFFFFFFFF).astype(np.uint32))
    # range scan
    skeys = np.sort(keys)
    start = int(skeys[1000])
    kk, vv = db.scan(start, 64)
    np.testing.assert_array_equal(kk, skeys[1000:1064])


def test_overwrite_and_delete(tmp_path):
    db = RemixDB(small_cfg(tmp_path))
    db.put(5, [1, 1])
    db.put(6, [2, 2])
    db.flush()
    db.put(5, [9, 9])  # overwrite, newer version
    db.delete(6)
    db.flush()
    assert db.get(5) is not None and int(db.get(5)[0]) == 9
    assert db.get(6) is None
    kk, _ = db.scan(0, 10)
    assert list(kk) == [5]


def test_compaction_kinds_progress(tmp_path):
    cfg = small_cfg(tmp_path, memtable_entries=400)
    cfg.compaction = CompactionConfig(table_cap=128, t_max=4, split_m=2)
    db = RemixDB(cfg)
    rng = np.random.default_rng(1)
    for i in range(20):
        keys = rng.choice(50_000, size=400, replace=False).astype(np.uint64)
        vals = np.zeros((400, 2), np.uint32)
        db.put_batch(keys, vals)
        db.flush()
    kinds_seen = {k for st in db.compaction_log for k in st["kinds"]}
    assert "minor" in kinds_seen and ("major" in kinds_seen or "split" in kinds_seen)
    # store stays queryable and partitioned
    s = db.stats()
    assert s["partitions"] >= 1 and s["tables"] >= 1
    found, _ = db.get_batch(keys[:100])
    assert found.all()


def test_split_creates_partitions(tmp_path):
    cfg = small_cfg(tmp_path, memtable_entries=2048)
    cfg.compaction = CompactionConfig(table_cap=128, t_max=3, split_m=2)
    db = RemixDB(cfg)
    keys = np.arange(0, 4096, dtype=np.uint64)
    db.put_batch(keys, np.zeros((len(keys), 2), np.uint32))
    db.flush()
    for _ in range(3):  # force more data through to trigger splits
        db.put_batch(keys, np.zeros((len(keys), 2), np.uint32))
        db.flush()
    assert len(db.partitions) > 1
    # routing still exact across partition boundaries
    found, _ = db.get_batch(keys[::17])
    assert found.all()
    kk, _ = db.scan(0, 200)
    np.testing.assert_array_equal(kk, keys[:200])


def test_hot_keys_stay_buffered(tmp_path):
    cfg = small_cfg(tmp_path, hot_threshold=3, memtable_entries=1 << 30)
    db = RemixDB(cfg)
    for i in range(6):  # 6 updates to key 42 -> count 6 > 3
        db.put(42, [i, i])
    db.put(7, [7, 7])
    db.flush()
    # hot key 42 must not be in any table; cold key 7 must be
    in_tables = [int(k) for p in db.partitions for t in p.tables for k in t.keys]
    assert 7 in in_tables and 42 not in in_tables
    assert db.mem.get(42) is not None  # carried over, counter halved
    assert db.mem.get(42).count == 3
    assert int(db.get(42)[0]) == 5  # newest value survives


def test_wal_recovery(tmp_path):
    cfg = small_cfg(tmp_path, memtable_entries=1 << 30)
    db = RemixDB(cfg)
    for i in range(100):
        db.put(i, [i, 0])
    db.delete(50)
    db.wal.sync()
    mem = db.recover_memtable()  # simulate restart before flush
    assert len(mem) == 100
    assert mem.get(50).tomb and not mem.get(51).tomb
    assert int(mem.get(99).val[0]) == 99


def test_wal_gc_keeps_live_only(tmp_path):
    cfg = small_cfg(tmp_path, memtable_entries=1 << 30)
    db = RemixDB(cfg)
    for i in range(2000):
        db.put(i, [i, 0])
    blocks_before = db.wal.used_blocks() + len(db.wal._pending) // 100
    db.flush()  # everything cold -> flushed -> WAL GC drops all
    assert db.wal.used_blocks() == 0
    # hot path: re-put a few keys many times, flush, they survive GC
    cfg2 = small_cfg(tmp_path / "w2", hot_threshold=2, memtable_entries=1 << 30)
    db2 = RemixDB(cfg2)
    for _ in range(5):
        for k in (1, 2, 3):
            db2.put(k, [k, 0])
    db2.flush()
    live = {k for k, *_ in db2.wal.replay()}
    assert live == {1, 2, 3}


def test_virtual_log_block_remap(tmp_path):
    from repro.db.wal import WAL

    w = WAL(str(tmp_path / "wal.log"), vw=2)
    for i in range(500):
        w.append(i, i, False, np.array([i, 0], np.uint32))
    w.sync()
    # keep 80% of keys -> most blocks remapped valid, no rewrite
    live = set(range(0, 500, 5)).symmetric_difference(range(500))
    w.gc(set(live))
    recovered = {k for k, *_ in w.replay()}
    assert recovered == set(live)
    # keep 10% -> blocks freed + survivors rewritten
    live2 = set(range(0, 500, 10)) & live
    w.gc(live2)
    assert {k for k, *_ in w.replay()} == live2
    assert len(w.free) > 0 or w.used_blocks() < 30


def test_baseline_stores_agree_with_remixdb(tmp_path):
    rng = np.random.default_rng(3)
    keys = rng.choice(30_000, size=4000, replace=False).astype(np.uint64)
    vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
    bcfg = BaselineConfig(memtable_entries=512, table_cap=512)
    stores = [LeveledStore(bcfg), TieredStore(bcfg)]
    db = RemixDB(small_cfg(tmp_path, memtable_entries=512))
    for chunk in range(0, 4000, 1000):
        sl = slice(chunk, chunk + 1000)
        db.put_batch(keys[sl], vals[sl])
        for s in stores:
            s.put_batch(keys[sl], vals[sl])
    db.flush()
    for s in stores:
        s.flush()
    probe = np.concatenate([keys[::13], np.array([30_001], np.uint64)])
    f0, v0 = db.get_batch(probe)
    for s in stores:
        f, v = s.get_batch(probe)
        np.testing.assert_array_equal(f, f0)
        np.testing.assert_array_equal(v[f], v0[f0])
    skeys = np.sort(keys)
    start = int(skeys[100])
    k0, _ = db.scan(start, 50)
    for s in stores:
        k, _ = s.scan(start, 50)
        np.testing.assert_array_equal(k, k0)
    # tiered must write less than leveled (the paper's WA premise)
    assert stores[1].write_amplification() <= stores[0].write_amplification()


def test_scan_batch_matches_scan(tmp_path):
    rng = np.random.default_rng(9)
    keys = rng.choice(50_000, size=6000, replace=False).astype(np.uint64)
    db = RemixDB(small_cfg(tmp_path, memtable_entries=1024))
    lv = LeveledStore(BaselineConfig(memtable_entries=1024, table_cap=1024))
    vals = np.zeros((len(keys), 2), np.uint32)
    db.put_batch(keys, vals)
    lv.put_batch(keys, vals)
    db.flush()
    lv.flush()
    starts = rng.choice(np.sort(keys), 40)
    for s in (db, lv):
        bk, bm = s.scan_batch(starts, 20)
        for i, st in enumerate(starts):
            kk, _ = s.scan(int(st), 20)
            np.testing.assert_array_equal(bk[i][bm[i]], kk[:20])


def test_write_amplification_ordering(tmp_path):
    """Paper fig 16 premise: tiered < RemixDB (tiered + REMIX) < leveled."""
    rng = np.random.default_rng(4)
    n = 60_000
    keys = rng.permutation(n).astype(np.uint64)
    vals = np.zeros((n, 2), np.uint32)
    cfg = RemixDBConfig(
        memtable_entries=2048,
        wal_dir=str(tmp_path),
        compaction=CompactionConfig(table_cap=2048, t_max=10),
    )
    db = RemixDB(cfg)
    lv = LeveledStore(BaselineConfig(memtable_entries=2048, table_cap=2048))
    tr = TieredStore(BaselineConfig(memtable_entries=2048, table_cap=2048))
    for c in range(0, n, 2048):
        sl = slice(c, c + 2048)
        db.put_batch(keys[sl], vals[sl])
        lv.put_batch(keys[sl], vals[sl])
        tr.put_batch(keys[sl], vals[sl])
    db.flush()
    lv.flush()
    tr.flush()
    wa_db = db.table_bytes_written / max(1, db.user_bytes)
    wa_lv = lv.write_amplification()
    wa_tr = tr.write_amplification()
    assert wa_tr < wa_db < wa_lv, (wa_tr, wa_db, wa_lv)

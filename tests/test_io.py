"""Tests for the persistence layer: SSTables, CKBs, REMIX files, manifest
commits, incremental rebuild, and RemixDB crash recovery."""
import os

import numpy as np
import pytest

from repro.core import keys as CK
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.db.compaction import CompactionConfig
from repro.db.partition import Table
from repro.db.store import RemixDB, RemixDBConfig
from repro.io.checksum import crc32c, crc32c_py
from repro.io.ckb import decode_ckb, encode_ckb
from repro.io.manifest import Manifest, Storage
from repro.io.rebuild import incremental_build_remix
from repro.io.remix_io import dump_remix, load_remix
from repro.io.sstable import SSTableReader, write_sstable


def _table_arrays(rng, n=2000, keyspace=1 << 40, vw=2):
    u = np.sort(rng.choice(keyspace, n, replace=False).astype(np.uint64))
    keys = CK.pack_u64(u)
    vals = rng.integers(0, 2**32, (n, vw), dtype=np.uint32)
    seq = np.arange(1, n + 1, dtype=np.uint32)
    tomb = rng.random(n) < 0.1
    return keys, vals, seq, tomb


def _assert_remix_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.anchors), np.asarray(b.anchors))
    np.testing.assert_array_equal(np.asarray(a.cursors), np.asarray(b.cursors))
    np.testing.assert_array_equal(
        np.asarray(a.selectors), np.asarray(b.selectors)
    )
    assert int(np.asarray(a.n_entries)) == int(np.asarray(b.n_entries))
    assert a.d == b.d


def test_crc32c_vectors():
    assert crc32c(b"") == 0
    assert crc32c(b"123456789") == 0xE3069283  # RFC 3720 check value
    # streaming == one-shot
    assert crc32c(b"456789", crc32c(b"123")) == 0xE3069283
    # the pure-Python fallback satisfies the same reference vectors
    assert crc32c_py(b"") == 0
    assert crc32c_py(b"123456789") == 0xE3069283
    assert crc32c_py(b"456789", crc32c_py(b"123")) == 0xE3069283


def test_crc32c_numpy_matches_pure_python():
    """The vectorized slicing-by-16 path must produce byte-for-byte
    identical digests to the pure-Python loop: every length bracketing
    the chunk width / dispatch threshold, misaligned offsets, and
    streaming continuations split at arbitrary points (where the two
    implementations hand off state to each other)."""
    rng = np.random.default_rng(42)
    blob = rng.integers(0, 256, 200_001, dtype=np.uint8).tobytes()
    lengths = [0, 1, 15, 16, 17, 255, 1023, 1024, 1025, 4096, 65536,
               65537, 131072, 200_001]
    for n in lengths:
        for off in (0, 1, 7):
            d = blob[off : off + n]
            assert crc32c(d) == crc32c_py(d), (n, off)
    # streaming: numpy-then-python and python-then-numpy continuations
    d = blob[:100_000]
    want = crc32c_py(d)
    for cut in (0, 1, 15, 16, 500, 1024, 50_000, 99_999, 100_000):
        assert crc32c(d[cut:], crc32c(d[:cut])) == want, cut
        assert crc32c(d[cut:], crc32c_py(d[:cut])) == want, cut
        assert crc32c_py(d[cut:], crc32c(d[:cut])) == want, cut


def test_ckb_roundtrip_and_compression():
    rng = np.random.default_rng(0)
    keys, *_ = _table_arrays(rng, n=4000)
    buf = encode_ckb(keys)
    np.testing.assert_array_equal(decode_ckb(buf), keys)
    # dense keys share long prefixes -> real compression
    dense = CK.pack_u64(np.arange(10_000, dtype=np.uint64))
    assert len(encode_ckb(dense)) < dense.nbytes * 0.6
    # empty block
    empty = CK.pack_u64(np.zeros(0, np.uint64))
    assert decode_ckb(encode_ckb(empty)).shape == (0, 2)


def test_sstable_roundtrip_and_checksums(tmp_path):
    rng = np.random.default_rng(1)
    keys, vals, seq, tomb = _table_arrays(rng)
    p = str(tmp_path / "t.sst")
    write_sstable(p, keys, vals, seq, tomb)
    rd = SSTableReader(p)
    assert rd.n == len(keys) and rd.kw == 2 and rd.vw == 2 and rd.has_ckb
    np.testing.assert_array_equal(rd.read_keys(), keys)
    np.testing.assert_array_equal(rd.read_vals(), vals)
    np.testing.assert_array_equal(rd.read_seq(), seq)
    np.testing.assert_array_equal(rd.read_tomb(), tomb)
    np.testing.assert_array_equal(rd.read_ckb_keys(), keys)
    rd.verify()
    # single flipped byte in the data region is caught
    with open(p, "r+b") as f:
        f.seek(40 + len(keys) * 8 + 17)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ValueError, match="checksum"):
        SSTableReader(p).verify()


def test_lazy_table_handle_reads_only_what_it_needs(tmp_path):
    rng = np.random.default_rng(2)
    keys, vals, seq, tomb = _table_arrays(rng)
    p = str(tmp_path / "t.sst")
    write_sstable(p, keys, vals, seq, tomb)
    t = Table.from_file(p)
    kw = t.key_words()  # served from the CKB
    np.testing.assert_array_equal(kw, keys)
    acct = t._rd().bytes_read
    assert acct["ckb"] > 0 and acct["vals"] == 0 and acct["keys"] == 0
    np.testing.assert_array_equal(t.vals, vals)  # full load still works
    assert t._rd().bytes_read["vals"] == vals.nbytes


def test_remix_file_roundtrip_matches_storage_bytes(tmp_path):
    rng = np.random.default_rng(3)
    runs = []
    base = 1
    for _ in range(3):
        u = np.sort(rng.choice(4000, 700, replace=False).astype(np.uint64))
        runs.append(
            make_run(u, seq=np.arange(base, base + len(u), dtype=np.uint32))
        )
        base += len(u)
    remix, _ = build_remix(runs, d=16)
    p = str(tmp_path / "x.rmx")
    n = dump_remix(remix, p)  # asserts payload == storage_bytes() internally
    assert n > int(remix.storage_bytes())  # + header/crc overhead only
    _assert_remix_equal(load_remix(p), remix)


def test_incremental_rebuild_bit_identical():
    rng = np.random.default_rng(4)
    runs, base = [], 1
    for _ in range(3):
        u = np.sort(rng.choice(5000, 900, replace=False).astype(np.uint64))
        runs.append(
            make_run(u, seq=np.arange(base, base + len(u), dtype=np.uint32))
        )
        base += len(u)
    old_remix, _ = build_remix(runs, d=16)
    u_new = np.sort(rng.choice(5000, 800, replace=False).astype(np.uint64))
    new = make_run(
        u_new, seq=np.arange(base, base + len(u_new), dtype=np.uint32)
    )
    scratch, _ = build_remix(runs + [new], d=16)
    inc = incremental_build_remix(
        old_remix,
        [np.asarray(r.keys) for r in runs],
        [np.asarray(new.keys)],
        [np.asarray(new.seq)],
        d=16,
    )
    _assert_remix_equal(inc, scratch)


def test_manifest_commit_versions(tmp_path):
    m = Manifest(str(tmp_path))
    assert m.load() is None and m.current_version() == 0
    assert m.commit(dict(a=1)) == 1
    assert m.commit(dict(a=2)) == 2
    st = m.load()
    assert st["a"] == 2 and st["version"] == 2
    # only the latest manifest file is kept; CURRENT points at it
    names = [f for f in os.listdir(tmp_path) if f.startswith("MANIFEST")]
    assert names == ["MANIFEST-000002"]


def _mkdb(data_dir, **kw):
    return RemixDB(
        RemixDBConfig(
            memtable_entries=kw.pop("memtable_entries", 512),
            compaction=CompactionConfig(table_cap=256, t_max=6),
            data_dir=str(data_dir),
            hot_threshold=kw.pop("hot_threshold", 255),
            **kw,
        )
    )


def test_reopen_identical_after_compaction_cycles(tmp_path):
    db = _mkdb(tmp_path / "db")
    rng = np.random.default_rng(5)
    chunks = []
    for _ in range(4):  # >= 3 flush/compaction cycles
        keys = rng.choice(100_000, size=600, replace=False).astype(np.uint64)
        vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
        db.put_batch(keys, vals)
        db.flush()
        chunks.append(keys)
    kinds = {k for st in db.compaction_log for k in st["kinds"]}
    assert "minor" in kinds  # incremental-rebuild path exercised
    dead = int(chunks[0][0])
    db.delete(dead)
    db.close()
    probe = np.concatenate(chunks + [np.array([100_001], np.uint64)])
    f0, v0 = db.get_batch(probe)
    k0, vv0 = db.scan(0, 500)

    db2 = RemixDB.open(str(tmp_path / "db"))
    f1, v1 = db2.get_batch(probe)
    k1, vv1 = db2.scan(0, 500)
    np.testing.assert_array_equal(f0, f1)
    np.testing.assert_array_equal(v0[f0], v1[f1])
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(vv0, vv1)
    assert db2.get(dead) is None  # tombstone survives reopen


def test_crash_mid_flush_recovers_from_wal(tmp_path, monkeypatch):
    db = _mkdb(tmp_path / "db", memtable_entries=1 << 30)
    k1 = np.arange(0, 1000, dtype=np.uint64)
    db.put_batch(k1, np.stack([k1 & 0xFFFFFFFF, k1 >> 32], 1).astype(np.uint32))
    db.flush()  # committed version 1
    k2 = np.arange(1000, 2000, dtype=np.uint64)
    db.put_batch(k2, np.stack([k2 & 0xFFFFFFFF, k2 >> 32], 1).astype(np.uint32))
    db.wal.sync()  # records durable; memtable not yet flushed

    # power loss after tables/remix are written but before the commit
    monkeypatch.setattr(
        Storage, "commit",
        lambda self, state: (_ for _ in ()).throw(RuntimeError("power loss")),
    )
    with pytest.raises(RuntimeError):
        db.flush()
    monkeypatch.undo()

    db2 = RemixDB.open(str(tmp_path / "db"))
    f, v = db2.get_batch(np.arange(0, 2000, dtype=np.uint64))
    assert f.all()
    np.testing.assert_array_equal(v[:, 0], np.arange(2000, dtype=np.uint32))
    kk, _ = db2.scan(0, 2000)
    np.testing.assert_array_equal(kk, np.arange(2000, dtype=np.uint64))
    # the crashed flush's uncommitted files were collected as orphans
    live = {
        n for pe in db2.storage.load_state()["partitions"]
        for n in pe["tables"]
    }
    assert set(os.listdir(db2.storage.tables_dir)) == live


def test_wal_tail_recovery_without_close(tmp_path):
    db = _mkdb(tmp_path / "db", memtable_entries=1 << 30)
    k = np.arange(500, dtype=np.uint64)
    db.put_batch(k, np.zeros((500, 2), np.uint32))
    db.flush()  # checkpoint
    for i in range(300):  # post-checkpoint appends (no commit follows)
        db.put(10_000 + i, [i, 0])
    db.wal.sync()  # blocks hit disk; manifest never sees them

    db2 = RemixDB.open(str(tmp_path / "db"))
    f, v = db2.get_batch(np.arange(10_000, 10_300, dtype=np.uint64))
    assert f.all()
    np.testing.assert_array_equal(v[:, 0], np.arange(300, dtype=np.uint32))
    f, _ = db2.get_batch(k)
    assert f.all()
    assert db2.seq == db.seq


def test_crash_before_first_commit_recovers_wal(tmp_path):
    """Acknowledged puts survive a crash that happens before any manifest
    exists (fresh directory, no flush yet)."""
    db = _mkdb(tmp_path / "db", memtable_entries=1 << 30)
    k = np.arange(500, dtype=np.uint64)
    db.put_batch(k, np.stack([k & 0xFFFFFFFF, k >> 32], 1).astype(np.uint32))
    db.wal.sync()  # durable; no flush, no commit, hard crash

    db2 = RemixDB.open(str(tmp_path / "db"))
    f, v = db2.get_batch(k)
    assert f.all()
    np.testing.assert_array_equal(v[:, 0], np.arange(500, dtype=np.uint32))
    assert db2.seq == db.seq


def test_superseded_files_reclaimed_at_commit(tmp_path):
    """Old REMIX/table files are deleted as soon as a commit supersedes
    them, not only at the next open()."""
    db = _mkdb(tmp_path / "db")
    rng = np.random.default_rng(7)
    for _ in range(4):
        keys = rng.choice(100_000, size=600, replace=False).astype(np.uint64)
        db.put_batch(keys, np.zeros((600, 2), np.uint32))
        db.flush()
    state = db.storage.load_state()
    live_tables = {n for pe in state["partitions"] for n in pe["tables"]}
    live_remix = {pe["remix"] for pe in state["partitions"] if pe["remix"]}
    assert set(os.listdir(db.storage.tables_dir)) == live_tables
    assert set(os.listdir(db.storage.remix_dir)) == live_remix


def test_partition_build_kinds(tmp_path):
    """Minor compactions rebuild incrementally; splits fall back to scratch."""
    db = _mkdb(tmp_path / "db", memtable_entries=400)
    rng = np.random.default_rng(6)
    seen = set()
    for _ in range(8):
        keys = rng.choice(50_000, size=400, replace=False).astype(np.uint64)
        db.put_batch(keys, np.zeros((400, 2), np.uint32))
        db.flush()
        seen.update(p.last_build_kind for p in db.partitions)
    assert "incremental" in seen
    found, _ = db.get_batch(keys[:100])
    assert found.all()

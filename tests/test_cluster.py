"""Cluster tier tests: shipping, replicas, live split/merge.

The load-bearing harness is differential: a :class:`Cluster` (split and
merged live, sometimes mid-traffic) must answer every read exactly like
one monolithic :class:`RemixDB` given the same op sequence — resharding
is pure topology, never visible in data. Shipping is additionally run
against a transient-EIO fault plan to prove the copy path retries to
completion, and replica catch-up must converge to zero sequence lag once
the writer pauses.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.cluster import Cluster, Replica, ship_snapshot
from repro.db.compaction import CompactionConfig
from repro.db.ops import Batch, Op
from repro.db.store import RemixDB, RemixDBConfig
from repro.io.faults import FaultPlan, IOContext

KEY_RANGE = 1 << 16


def _cfg(**kw):
    return RemixDBConfig(
        vw=2,
        memtable_entries=kw.pop("memtable_entries", 1 << 10),
        compaction=kw.pop(
            "compaction", CompactionConfig(table_cap=1 << 12, t_max=4)
        ),
        **kw,
    )


def _vals(keys, tag):
    keys = np.asarray(keys, np.uint64)
    return np.stack(
        [keys.astype(np.uint32), np.full(len(keys), tag, np.uint32)], 1
    )


def _assert_same_reads(cluster, mono, *, n=KEY_RANGE, probes=None):
    """The whole point of the tier: topology is invisible to reads."""
    ck, cv = cluster.scan(0, n)
    mk, mv = mono.scan(0, n)
    np.testing.assert_array_equal(ck, mk)
    np.testing.assert_array_equal(cv, mv)
    if probes is not None and len(probes):
        probes = np.asarray(sorted(set(probes)), np.uint64)
        cf, cg = cluster.get_batch(probes)
        mf, mg = mono.get_batch(probes)
        np.testing.assert_array_equal(cf, mf)
        # value slots are undefined where found=False: mask them
        hit = np.asarray(cf, bool)
        np.testing.assert_array_equal(cg[hit], mg[hit])


def _workload(rng, cluster, mono, rounds=4, ops_per_round=6):
    """Apply one random op mix to both sides; returns probe keys."""
    touched = []
    for _ in range(rounds):
        for _ in range(ops_per_round):
            roll = rng.random()
            if roll < 0.6:
                ks = rng.choice(KEY_RANGE, size=64, replace=False).astype(
                    np.uint64
                )
                vs = _vals(ks, rng.integers(1, 1 << 16))
                cluster.put_batch(ks, vs)
                mono.put_batch(ks, vs)
                touched.extend(int(k) for k in ks[:8])
            elif roll < 0.8:
                lo = int(rng.integers(0, KEY_RANGE - 1))
                hi = lo + int(rng.integers(1, KEY_RANGE // 8))
                cluster.delete_range(lo, hi)
                mono.delete_range(lo, hi)
            else:
                k = int(rng.integers(0, KEY_RANGE))
                cluster.delete(k)
                mono.delete(k)
                touched.append(k)
    return touched


# ---------------------------------------------------------------- ship
def test_ship_snapshot_bit_identical(tmp_path):
    db = RemixDB.open(str(tmp_path / "src"), _cfg())
    rng = np.random.default_rng(0)
    keys = rng.choice(1 << 20, size=3000, replace=False).astype(np.uint64)
    db.put_batch(keys[:2000], _vals(keys[:2000], 1))
    db.flush()
    db.put_batch(keys[2000:], _vals(keys[2000:], 2))  # overlay rides along
    db.delete_range(100, 5000)

    report = ship_snapshot(db, str(tmp_path / "copy"))
    assert report["files"] >= 2 and report["bytes"] > 0

    db2 = RemixDB.open(str(tmp_path / "copy"), _cfg())
    try:
        for args in ((0, 4000), (1 << 19, 500)):
            np.testing.assert_array_equal(db.scan(*args)[0],
                                          db2.scan(*args)[0])
            np.testing.assert_array_equal(db.scan(*args)[1],
                                          db2.scan(*args)[1])
        f1, g1 = db.get_batch(keys)
        f2, g2 = db2.get_batch(keys)
        np.testing.assert_array_equal(f1, f2)
        np.testing.assert_array_equal(g1, g2)
    finally:
        db2.close()
        db.close()


def test_ship_snapshot_retries_transient_faults(tmp_path):
    """Transient EIO on the shipped table/REMIX reads: the copy path
    retries through the fault-plan budget and completes; the plan's
    fired counters prove the faults were actually exercised."""
    db = RemixDB.open(str(tmp_path / "src"), _cfg())
    keys = np.arange(0, 2000, dtype=np.uint64)
    db.put_batch(keys, _vals(keys, 3))
    db.flush()

    plan = (FaultPlan(seed=7)
            .transient_read(".sst", count=2)
            .transient_read(".rmx", count=1))
    io = IOContext(plan=plan, retries=4)
    report = ship_snapshot(db, str(tmp_path / "copy"), io=io)
    assert plan.fired["transient_read"] == 3  # every rule consumed
    assert report["files"] >= 2

    db2 = RemixDB.open(str(tmp_path / "copy"), _cfg())
    try:
        np.testing.assert_array_equal(db.scan(0, 3000)[0],
                                      db2.scan(0, 3000)[0])
    finally:
        db2.close()
        db.close()


def test_ship_snapshot_gives_up_past_retry_budget(tmp_path):
    from repro.io.faults import TransientIOError

    db = RemixDB.open(str(tmp_path / "src"), _cfg())
    db.put_batch(np.arange(100, dtype=np.uint64),
                 _vals(np.arange(100), 1))
    db.flush()
    io = IOContext(plan=FaultPlan().transient_read(".sst", count=10),
                   retries=2)
    with pytest.raises(TransientIOError):
        ship_snapshot(db, str(tmp_path / "copy"), io=io)
    db.close()


# ------------------------------------------------------------- replicas
def test_replica_catchup_converges_after_writer_pause(tmp_path):
    db = RemixDB.open(str(tmp_path / "src"), _cfg())
    rng = np.random.default_rng(1)
    keys = rng.choice(1 << 20, size=2000, replace=False).astype(np.uint64)
    db.put_batch(keys[:1000], _vals(keys[:1000], 1))
    db.flush()

    rep = Replica(db, str(tmp_path / "replica"))
    try:
        # steady state: tail-only rounds, no file fetches
        db.put_batch(keys[1000:1500], _vals(keys[1000:1500], 2))
        r = rep.catch_up()
        assert r["lag"] == 0 and r["files"] == 0 and r["applied"] == 500

        # across a primary flush + range delete: manifest-diff fetch
        db.put_batch(keys[1500:], _vals(keys[1500:], 3))
        db.delete_range(4096, 8192)
        db.flush()
        r = rep.catch_up()
        assert r["lag"] == 0 and r["files"] > 0

        # writer paused: the gauge reads zero and reads are identical
        snap = rep.db.registry.snapshot()
        lags = [m for m in snap["metrics"]
                if m["name"] == "replica_seq_lag"]
        assert lags and all(m["value"] == 0 for m in lags)
        np.testing.assert_array_equal(db.scan(0, 4000)[0],
                                      rep.scan(0, 4000)[0])
        np.testing.assert_array_equal(db.scan(0, 4000)[1],
                                      rep.scan(0, 4000)[1])

        # idle rounds are cheap and stable
        r = rep.catch_up()
        assert r == dict(applied=0, files=0, bytes=0,
                         version=r["version"], lag=0)
    finally:
        rep.close()
        db.close()


def test_replica_lag_tracks_writes(tmp_path):
    db = RemixDB.open(str(tmp_path / "src"), _cfg())
    db.put_batch(np.arange(100, dtype=np.uint64), _vals(np.arange(100), 1))
    rep = Replica(db, str(tmp_path / "replica"))
    try:
        assert rep.seq_lag() == 0
        db.put_batch(np.arange(100, 150, dtype=np.uint64),
                     _vals(np.arange(100, 150), 2))
        assert rep.seq_lag() == 50
        rep.catch_up_until(lag_target=0)
        assert rep.seq_lag() == 0
    finally:
        rep.close()
        db.close()


# --------------------------------------------------- split/merge (diff)
def test_split_merge_differential_vs_monolith(tmp_path):
    """Random workloads interleaved with live splits and merges: the
    cluster must stay read-identical to a monolithic store at every
    topology step, including after reopen from disk."""
    rng = np.random.default_rng(11)
    mono = RemixDB.open(str(tmp_path / "mono"), _cfg())
    cluster = Cluster(str(tmp_path / "fleet"), lows=(0,), config=_cfg())
    try:
        probes = _workload(rng, cluster, mono)
        _assert_same_reads(cluster, mono, probes=probes)

        cluster.split(KEY_RANGE // 2)
        assert len(cluster.lows) == 2
        _assert_same_reads(cluster, mono, probes=probes)

        probes += _workload(rng, cluster, mono)
        _assert_same_reads(cluster, mono, probes=probes)

        cluster.split(KEY_RANGE // 4)
        cluster.flush()
        mono.flush()
        probes += _workload(rng, cluster, mono)
        _assert_same_reads(cluster, mono, probes=probes)

        # merge everything back down to one shard
        while len(cluster.lows) > 1:
            cluster.merge(cluster.lows[-1])
            _assert_same_reads(cluster, mono, probes=probes)
        probes += _workload(rng, cluster, mono)
        _assert_same_reads(cluster, mono, probes=probes)

        snap = cluster.metrics()
        counters = {m["name"]: m.get("value", 0)
                    for m in snap["metrics"]
                    if m.get("type") == "counter"
                    and m.get("labels", {}).get("tier") == "serve"}
        assert counters.get("shard_split") == 2
        assert counters.get("shard_merge") == 2
        assert counters.get("snapshot_ship_bytes", 0) > 0

        # topology survives reopen
        ck, cv = cluster.scan(0, KEY_RANGE)
        cluster.close()
        reopened = Cluster(str(tmp_path / "fleet"), lows=None,
                           config=_cfg())
        try:
            assert reopened.lows == [0]
            np.testing.assert_array_equal(reopened.scan(0, KEY_RANGE)[0],
                                          ck)
            np.testing.assert_array_equal(reopened.scan(0, KEY_RANGE)[1],
                                          cv)
        finally:
            reopened.close()
        cluster = None
    finally:
        if cluster is not None:
            cluster.close()
        mono.close()


def test_split_under_async_traffic_zero_failed_ops(tmp_path):
    """A live split (and merge back) mid-traffic: every submitted op
    completes OK — gated callers wait out the cutover, nothing fails."""
    cluster = Cluster(str(tmp_path / "fleet"), lows=(0,), config=_cfg())
    failures = []
    completed = [0]
    stop = threading.Event()

    def traffic(tid):
        rng = np.random.default_rng(100 + tid)
        while not stop.is_set():
            ks = rng.integers(0, KEY_RANGE, size=32).astype(np.uint64)
            try:
                futs = [
                    cluster.submit(Batch([Op.put(ks, _vals(ks, tid + 1))])),
                    cluster.submit(
                        Batch([Op.multiget(ks), Op.scan(int(ks[0]), 16)])
                    ),
                ]
                for f in futs:
                    res = f.result(timeout=60)
                    for r in res.results:
                        r.raise_if_error()
                completed[0] += 1
            except Exception as e:  # pragma: no cover - the assertion
                failures.append(repr(e))

    threads = [threading.Thread(target=traffic, args=(i,)) for i in range(4)]
    try:
        for t in threads:
            t.start()
        time.sleep(0.3)
        cluster.flush()
        cluster.split(KEY_RANGE // 2)
        time.sleep(0.3)
        cluster.merge(cluster.lows[1])
        time.sleep(0.2)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not failures, failures[:5]
    assert completed[0] > 0
    assert cluster.lows == [0]
    cluster.close()


def test_placement_splits_hot_shard(tmp_path):
    """The placement loop's decision function: a zipfian-hot shard with
    enough routed ops and a materialized partition boundary gets split
    at a boundary near the load median."""
    cluster = Cluster(
        str(tmp_path / "fleet"), lows=(0,),
        config=_cfg(compaction=CompactionConfig(table_cap=1024, t_max=4)),
    )
    try:
        ks = np.arange(0, 8192, dtype=np.uint64)
        cluster.put_batch(ks, _vals(ks, 1))
        cluster.flush()
        # drive routed-op accounting with skewed gets
        rng = np.random.default_rng(5)
        for _ in range(40):
            cluster.get_batch(
                rng.integers(0, 4096, size=32).astype(np.uint64))
        assert cluster.maybe_split(factor=2.0, min_ops=16) is not None
        assert len(cluster.lows) == 2
        # counters reset enough that an idle fleet does not re-split
        assert cluster.maybe_split(factor=1 << 30, min_ops=16) is None
    finally:
        cluster.close()


def test_cluster_replica_via_add_replica(tmp_path):
    cluster = Cluster(str(tmp_path / "fleet"), lows=(0,), config=_cfg())
    try:
        ks = np.arange(0, 1000, dtype=np.uint64)
        cluster.put_batch(ks, _vals(ks, 1))
        rep = cluster.add_replica(0)
        cluster.put_batch(ks[:100], _vals(ks[:100], 2))
        rep.catch_up_until(lag_target=0)
        np.testing.assert_array_equal(cluster.scan(0, 2000)[0],
                                      rep.scan(0, 2000)[0])
        np.testing.assert_array_equal(cluster.scan(0, 2000)[1],
                                      rep.scan(0, 2000)[1])
    finally:
        cluster.close()


# ------------------------------------------------------------- nightly
@pytest.mark.nightly
@pytest.mark.parametrize("seed", range(8))
def test_nightly_replica_catchup_matrix(tmp_path, seed):
    """Multi-seed replica convergence: randomized op mixes with flush
    points in between; after every burst the replica catches up and must
    read identically; final lag is exactly zero."""
    rng = np.random.default_rng(seed)
    db = RemixDB.open(str(tmp_path / "src"), _cfg(memtable_entries=256))
    rep = Replica(db, str(tmp_path / "replica"))
    try:
        for burst in range(5):
            for _ in range(int(rng.integers(2, 6))):
                roll = rng.random()
                if roll < 0.7:
                    ks = rng.choice(4096, size=64, replace=False).astype(
                        np.uint64
                    )
                    db.put_batch(ks, _vals(ks, burst + 1))
                else:
                    lo = int(rng.integers(0, 4000))
                    db.delete_range(lo, lo + int(rng.integers(1, 500)))
            if rng.random() < 0.5:
                db.flush()
            rep.catch_up_until(lag_target=0)
            assert rep.seq_lag() == 0
            np.testing.assert_array_equal(db.scan(0, 5000)[0],
                                          rep.scan(0, 5000)[0])
            np.testing.assert_array_equal(db.scan(0, 5000)[1],
                                          rep.scan(0, 5000)[1])
    finally:
        rep.close()
        db.close()


@pytest.mark.nightly
@pytest.mark.parametrize("seed", range(4))
def test_nightly_split_merge_matrix(tmp_path, seed):
    """Randomized topology churn: alternating workload bursts and
    split/merge steps, differentially checked against a monolith."""
    rng = np.random.default_rng(1000 + seed)
    mono = RemixDB.open(str(tmp_path / "mono"), _cfg())
    cluster = Cluster(str(tmp_path / "fleet"), lows=(0,), config=_cfg())
    try:
        probes = []
        for _ in range(5):
            probes += _workload(rng, cluster, mono, rounds=2)
            lows = cluster.lows
            if len(lows) > 2 and rng.random() < 0.5:
                cluster.merge(lows[int(rng.integers(1, len(lows)))])
            else:
                at = int(rng.integers(1, KEY_RANGE))
                try:
                    cluster.split(at)
                except ValueError:
                    pass  # span had no usable boundary; topology keeps
            _assert_same_reads(cluster, mono, probes=probes)
    finally:
        cluster.close()
        mono.close()

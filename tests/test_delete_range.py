"""DeleteRange / TTL / CAS acceptance tests.

The headline assertion: a cold scan across a range-deleted span does NO
per-key tombstone merging — the REMIX cursor walk skips the excised view
interval structurally, so zero keys/vals-section granules inside the
covered row range are ever read (CKB reads at the span boundaries are
the allowed price of computing the skip).
"""
import numpy as np
import pytest

from repro.db import clock
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig
from repro.io import sstable


def _cfg(**kw):
    return RemixDBConfig(
        vw=2,
        memtable_entries=kw.pop("memtable_entries", 1 << 15),
        compaction=kw.pop(
            "compaction", CompactionConfig(table_cap=1 << 15, t_max=4)
        ),
        hot_threshold=255,
        **kw,
    )


@pytest.fixture(autouse=True)
def _reset_clock():
    yield
    clock.reset()


@pytest.fixture
def small_granules(monkeypatch):
    """Write tables with 4 KB checksum granules so block accounting is
    fine-grained (512 rows per keys/vals granule at vw=2)."""
    real = sstable.write_sstable

    def patched(*a, **kw):
        kw.setdefault("block_bytes", 4096)
        return real(*a, **kw)

    monkeypatch.setattr(sstable, "write_sstable", patched)


class _GranuleRecorder:
    """Record every (reader, granule) touched through either read path."""

    def __init__(self, monkeypatch):
        self.touched = []
        rblk = sstable.SSTableReader.read_block
        rrng = sstable.SSTableReader.read_range
        rec = self.touched

        def rec_blk(reader, idx):
            rec.append((reader, idx))
            return rblk(reader, idx)

        def rec_rng(reader, lo, hi):
            if hi > lo:
                bb = reader.block_bytes
                for bi in range(
                    (lo - reader._data_start) // bb,
                    (hi - reader._data_start - 1) // bb + 1,
                ):
                    rec.append((reader, bi))
            return rrng(reader, lo, hi)

        monkeypatch.setattr(sstable.SSTableReader, "read_block", rec_blk)
        monkeypatch.setattr(sstable.SSTableReader, "read_range", rec_rng)


def test_cold_scan_skips_excised_span_structurally(
    tmp_path, small_granules, monkeypatch
):
    """Acceptance: touched keys/vals granules in the excised span == 0.

    8192 dense keys, one table, range [2048, 6144) deleted (granule-
    aligned: 512 rows per 4 KB block). A full cursor drain off the cold
    path must return exactly the survivors while never reading a keys-
    or vals-section granule of the covered rows.
    """
    d = str(tmp_path / "db")
    db = RemixDB.open(d, _cfg())
    n = 8192
    ks = np.arange(n, dtype=np.uint64)
    vs = np.stack([ks.astype(np.uint32), ks.astype(np.uint32) + 1], 1)
    db.put_batch(ks, vs)
    db.flush()
    db.delete_range(2048, 6144)
    db.flush()
    db.close()

    db = RemixDB.open(d, _cfg())  # tables cold, REMIX recovered
    try:
        p = db.versions.current.partitions[0]
        assert db._cold_ok(p), "must exercise the cold cursor path"
        assert p.full_spans() == [(2048, 6144)]
        state = p.cold_cursor_seek(0)
        assert [(a, b) for a, b, _ in state["skips"]], "skip table empty"
        covered = []
        for t in p.tables:
            r = t._rd()
            covered.append(
                (
                    r,
                    set(r.section_row_blocks("keys", 2048, 6144))
                    | set(r.section_row_blocks("vals", 2048, 6144)),
                )
            )
        rec = _GranuleRecorder(monkeypatch)
        with db.cursor(width=64) as cur:
            cur.seek(0)
            got = [k for k, _ in cur]
        assert got == [k for k in range(n) if not 2048 <= k < 6144]
        assert rec.touched, "cold drain must read some granules"
        overlap = [
            i
            for r, cov in covered
            for rr, i in rec.touched
            if rr is r and i in cov
        ]
        assert overlap == [], f"read covered granules: {sorted(set(overlap))}"
    finally:
        db.close()


def test_whole_table_drop_at_flush(tmp_path):
    """A table entirely inside a clipped range is dropped whole at the
    fold (no merge, no read), observable via the range_tombstone_drop
    event and the disappearing table handle."""
    d = str(tmp_path / "db")
    db = RemixDB.open(d, _cfg(memtable_entries=256))
    ks = np.arange(1000, 1200, dtype=np.uint64)
    db.put_batch(ks, np.stack([ks, ks], 1).astype(np.uint32))
    db.flush()
    n_before = sum(len(p.tables) for p in db.versions.current.partitions)
    assert n_before >= 1
    db.delete_range(0, 5000)  # covers every flushed table
    db.flush()
    try:
        n_after = sum(
            len(p.tables) for p in db.versions.current.partitions
        )
        assert n_after == 0
        assert db.events.list(kind="range_tombstone_drop")
        kk, _ = db.scan(0, 10_000)
        assert len(kk) == 0
    finally:
        db.close()


def test_partial_span_scan_and_get_parity(tmp_path):
    """A range covering only *some* tables of a partition falls back to
    per-key excision in the emit path — scan, cursor and point gets must
    agree exactly."""
    d = str(tmp_path / "db")
    db = RemixDB.open(
        d,
        _cfg(
            memtable_entries=256,
            compaction=CompactionConfig(table_cap=256, t_max=6),
        ),
    )
    try:
        # two generations of tables with interleaved key ranges
        ks1 = np.arange(0, 600, 2, dtype=np.uint64)
        db.put_batch(ks1, np.stack([ks1, ks1], 1).astype(np.uint32))
        db.flush()
        db.delete_range(100, 400)  # covers the first generation only
        ks2 = np.arange(1, 600, 2, dtype=np.uint64)
        db.put_batch(ks2, np.stack([ks2, ks2], 1).astype(np.uint32))
        db.flush()
        live = sorted(
            set(int(k) for k in ks1 if not 100 <= k < 400)
            | set(int(k) for k in ks2)
        )
        kk, _ = db.scan(0, 10_000)
        assert [int(k) for k in kk] == live
        with db.cursor(width=16) as cur:
            cur.seek(0)
            assert [k for k, _ in cur] == live
        assert db.get(200) is None  # even gen, covered
        assert db.get(201) is not None  # odd gen, written after
        f, _ = db.get_batch(np.array([200, 201, 98, 350], np.uint64))
        assert list(f) == [False, True, True, False]
    finally:
        db.close()


def test_ttl_expiry_and_compaction_gc(tmp_path):
    """Expired rows vanish from reads immediately and are physically
    dropped (counter: ttl_expired_dropped) when a merge rewrites them."""
    t = [1000.0]
    clock.set_source(lambda: t[0])
    d = str(tmp_path / "db")
    db = RemixDB.open(
        d,
        _cfg(
            memtable_entries=128,
            compaction=CompactionConfig(table_cap=128, t_max=2),
        ),
    )
    try:
        ks = np.arange(0, 100, dtype=np.uint64)
        db.put_batch(ks, np.stack([ks, ks], 1).astype(np.uint32), ttl=60)
        ks2 = np.arange(100, 200, dtype=np.uint64)
        db.put_batch(ks2, np.stack([ks2, ks2], 1).astype(np.uint32))
        db.flush()
        assert db.get(5) is not None
        t[0] = 1061.0  # past the expiry
        assert db.get(5) is None
        kk, _ = db.scan(0, 1000)
        assert [int(k) for k in kk] == list(range(100, 200))
        # churn until a merge rewrites the expired rows
        for i in range(6):
            ks3 = np.arange(0, 100, dtype=np.uint64)
            db.put_batch(
                ks3, np.full((100, 2), 7 + i, np.uint32), ttl=1
            )
            t[0] += 5.0
            db.flush()
        dropped = sum(
            s["value"]
            for s in db.registry.snapshot()["metrics"]
            if s["name"] == "ttl_expired_dropped"
        )
        assert dropped > 0
        kk, _ = db.scan(0, 1000)
        assert [int(k) for k in kk] == list(range(100, 200))
    finally:
        db.close()


def test_cas_semantics(tmp_path):
    """CAS: expect-absent create, conflict reports the actual value,
    conditional delete, and TTL-expired counts as absent."""
    t = [1000.0]
    clock.set_source(lambda: t[0])
    db = RemixDB.open(str(tmp_path / "db"), _cfg())
    try:
        v1 = np.array([1, 1], np.uint32)
        v2 = np.array([2, 2], np.uint32)
        ok, cur = db.cas(5, None, v1)
        assert ok and cur is None
        ok, cur = db.cas(5, None, v2)  # expect-absent on a present key
        assert not ok and list(cur.reshape(-1)) == [1, 1]
        ok, cur = db.cas(5, v2, v2)  # wrong expectation
        assert not ok and list(cur.reshape(-1)) == [1, 1]
        ok, _ = db.cas(5, v1, v2)
        assert ok and list(db.get(5).reshape(-1)) == [2, 2]
        ok, _ = db.cas(5, v2, None)  # conditional delete
        assert ok and db.get(5) is None
        # expired-TTL key behaves as absent for expect-None
        db.put(6, v1, ttl=10)
        t[0] = 1011.0
        ok, cur = db.cas(6, None, v2)
        assert ok and cur is None
        assert list(db.get(6).reshape(-1)) == [2, 2]
    finally:
        db.close()


def test_serve_engine_cross_shard_delete_range_and_cas(tmp_path):
    """DeleteRange fans out clipped per shard; CAS routes to the owner."""
    from repro.serve.engine import KVServeEngine

    dirs = [str(tmp_path / f"s{i}") for i in range(3)]
    eng = KVServeEngine(
        list(zip([0, 1000, 2000], dirs)), config=_cfg(memtable_entries=256)
    )
    try:
        ks = np.arange(0, 3000, 7, dtype=np.uint64)
        eng.put_batch(ks, np.stack([ks, ks], 1).astype(np.uint32))
        eng.flush()
        eng.delete_range(500, 2500)  # clips into all three shards
        kk, _ = eng.scan(0, 1000)
        assert all(not 500 <= int(k) < 2500 for k in kk)
        assert eng.get(497) is not None and eng.get(504) is None
        assert eng.get(2506) is not None  # 7·358, past the range
        ok, cur = eng.cas(5000, None, np.array([4, 4], np.uint32))
        assert ok and cur is None
        ok, cur = eng.cas(
            5000, np.array([9, 9], np.uint32), np.array([5, 5], np.uint32)
        )
        assert not ok and list(cur.reshape(-1)) == [4, 4]
        ok, _ = eng.cas(5000, np.array([4, 4], np.uint32), None)
        assert ok and eng.get(5000) is None
    finally:
        eng.close()
        for db in eng.shards:
            db.close()

"""End-to-end behaviour tests: the paper's system working as a whole.

1. RemixDB lifecycle: load → compactions (all kinds) → point/range queries →
   overwrite/delete → WAL recovery — against a dict+sorted-list oracle.
2. LM pipeline: data → train steps → checkpoint → serve with the REMIX
   prefix cache, outputs consistent with teacher-forced logits.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline
from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig
from repro.models import model as M
from repro.models.layers import split_params
from repro.serve.engine import ServeEngine
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def test_kvstore_end_to_end(tmp_path):
    rng = np.random.default_rng(123)
    db = RemixDB(
        RemixDBConfig(
            memtable_entries=1024,
            wal_dir=str(tmp_path),
            compaction=CompactionConfig(table_cap=512, t_max=6),
            hot_threshold=4,
        )
    )
    oracle: dict[int, int] = {}
    # several epochs of mixed inserts/overwrites/deletes
    for epoch in range(6):
        keys = rng.choice(20_000, size=1500, replace=False).astype(np.uint64)
        vals = rng.integers(1, 2**31, size=(1500, 2)).astype(np.uint32)
        db.put_batch(keys, vals)
        for k, v in zip(keys.tolist(), vals):
            oracle[k] = int(v[0])
        dels = rng.choice(keys, size=50, replace=False)
        for k in dels.tolist():
            db.delete(k)
            oracle.pop(k, None)
        db.flush()
    # point queries match the oracle
    probe = rng.choice(20_000, size=800, replace=False).astype(np.uint64)
    found, vals = db.get_batch(probe)
    for i, k in enumerate(probe.tolist()):
        if k in oracle:
            assert found[i] and int(vals[i, 0]) == oracle[k], k
        else:
            assert not found[i], k
    # range scans match the oracle
    live = np.array(sorted(oracle), np.uint64)
    for start in rng.choice(live, size=10):
        kk, _ = db.scan(int(start), 40)
        i0 = int(np.searchsorted(live, start))
        np.testing.assert_array_equal(kk, live[i0 : i0 + 40])
    # compactions of several kinds actually ran
    kinds = {k for st in db.compaction_log for k in st["kinds"]}
    assert "minor" in kinds and ("major" in kinds or "split" in kinds)
    # WAL recovery covers the buffered (hot/unflushed) tail
    db.put(10**9, [42, 0])
    db.wal.sync()
    mem = db.recover_memtable()
    assert mem.get(10**9) is not None and int(mem.get(10**9).val[0]) == 42


def test_lm_pipeline_end_to_end(tmp_path):
    cfg = reduced(
        get_config("qwen2.5-3b"), n_layers=2, d_model=128, d_ff=256, vocab=256
    )
    params = M.init_params(cfg, jax.random.key(0))
    pv, _ = split_params(params)
    opt_cfg = OptConfig(lr=5e-3, warmup=5, total_steps=30)
    opt = init_opt_state(opt_cfg, pv)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = DataPipeline(vocab=cfg.vocab, batch=8, seq=32, seed=3)
    losses = []
    for i in range(30):
        pv, opt, m = step(pv, opt, data.get_batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # it learns
    from repro.train import checkpoint as C

    C.save(str(tmp_path), 30, pv, opt)
    rp, _, _ = C.restore(str(tmp_path))
    # serve the trained model; greedy decode consistent with forward
    eng = ServeEngine(cfg, rp, max_seq=64)
    prompt = np.asarray(data.get_batch(0)["tokens"])[0, :16].astype(np.int32)
    out = eng.generate(prompt, max_new=4)
    logits = M.forward(cfg, rp, dict(tokens=jnp.asarray(prompt[None])), remat=False)
    assert int(out[0]) == int(jnp.argmax(logits[0, -1]))

"""Shared test configuration.

Hypothesis profiles (when hypothesis is installed):

- ``ci`` (default): derandomized with a fixed seed and no deadline, so
  tier-1 CI runs are deterministic and immune to machine-speed flakes;
- ``nightly``: 500+ examples per property/state machine, randomized —
  the nightly CI job selects it via ``HYPOTHESIS_PROFILE=nightly``.

Every hypothesis failure prints its reproduction seed; re-running with
``--hypothesis-seed=<seed>`` (or the printed ``@reproduce_failure``
decorator) replays the shrunk counterexample exactly.
"""
import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        derandomize=True,
        deadline=None,
        max_examples=25,
        stateful_step_count=30,
        suppress_health_check=[HealthCheck.too_slow],
        print_blob=True,
    )
    settings.register_profile(
        "nightly",
        deadline=None,
        max_examples=500,
        stateful_step_count=50,
        suppress_health_check=[HealthCheck.too_slow],
        print_blob=True,
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # hypothesis-based tests importorskip individually
    pass

"""Property tests for the triangular-flash attention and grouped MoE."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models.layers import flash_attention


def naive_attention(q, k, v, causal, window, cap, q_offset, kv_len):
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    kk = jnp.repeat(k, h // kvh, axis=2)
    vv = jnp.repeat(v, h // kvh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    if cap:
        s = jnp.tanh(s / cap) * cap
    qpos = q_offset + np.arange(sq)[:, None]
    kpos = np.arange(sk)[None, :]
    mask = np.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


@settings(max_examples=25, deadline=None)
@given(
    sq=st.integers(1, 40),
    sk=st.integers(1, 70),
    h=st.sampled_from([1, 2, 4]),
    kvh_div=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16]),
    hd_v=st.sampled_from([8, 24]),
    causal=st.booleans(),
    window=st.sampled_from([None, 5, 16]),
    cap=st.sampled_from([None, 30.0]),
    offset=st.integers(0, 20),
    block=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 100),
)
def test_flash_matches_naive(
    sq, sk, h, kvh_div, hd, hd_v, causal, window, cap, offset, block, seed
):
    if kvh_div > h:
        kvh_div = 1
    kvh = h // kvh_div
    # causal self-attention pruning assumes q_offset aligns q & k tails
    if causal and offset + sq > sk:
        offset = max(0, sk - sq)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, sk, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, sk, kvh, hd_v)), jnp.float32)
    out = flash_attention(
        q, k, v, causal=causal, window=window, cap=cap, q_offset=offset,
        block=block,
    )
    ref = naive_attention(q, k, v, causal, window, cap, offset, None)
    # rows with no visible kv position are unspecified — mask them out
    qpos = offset + np.arange(sq)
    visible = np.ones(sq, bool)
    if causal or window:
        lo = qpos - (window or 10**9)
        hi = qpos if causal else np.full(sq, sk - 1)
        visible = (np.minimum(hi, sk - 1) > lo) & (hi >= 0)
    np.testing.assert_allclose(
        np.asarray(out)[:, visible],
        np.asarray(ref)[:, visible],
        rtol=2e-4, atol=3e-5,
    )


def test_moe_grouped_matches_ungrouped():
    """With ample capacity, G-grouped dispatch == single-group dispatch."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.models import layers as L

    cfg = reduced(get_config("qwen3-moe-235b-a22b"), n_layers=1)
    cfg = dataclasses.replace(cfg, moe_capacity=4.0)  # no token drops
    rng = np.random.default_rng(0)
    key = jax.random.key(1)
    p = L.split_params(L.init_moe(cfg, key))[0]
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)
    y1 = L.moe(cfg, p, x)
    # grouped path: emulate 4 data shards by vmapping over the batch rows
    cfg2 = dataclasses.replace(cfg, moe_local_dispatch=True)
    xg = x.reshape(4, 1, 16, cfg.d_model)
    y2 = jax.vmap(lambda xi: L.moe(cfg2, p, xi))(xg).reshape(4, 16, -1)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32),
        rtol=2e-3, atol=2e-3,
    )

"""Dry-run and distributed-store integration tests (subprocess isolation:
XLA's device count locks at first init, so fake-device tests spawn fresh
interpreters)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def run_py(code: str, timeout=900):
    return subprocess.run(
        [sys.executable, "-c", code], env=ENV, cwd=REPO,
        capture_output=True, text=True, timeout=timeout,
    )


@pytest.mark.parametrize(
    "arch,shape",
    [("qwen2.5-3b", "train_4k"), ("mamba2-130m", "decode_32k")],
)
def test_dryrun_cell_compiles(arch, shape):
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape],
        env=ENV, cwd=REPO, capture_output=True, text=True, timeout=1200,
    )
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    rec = json.loads(p.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["collectives"]["total"] > 0  # the mesh is actually used


def test_elastic_restart_resharding(tmp_path):
    """Checkpoint on an 8-device (4,2) mesh, restore on a (2,2) mesh of 4
    devices — elastic re-scale with exact data-pipeline resume."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline
from repro.models import model as M
from repro.models.layers import split_params
from repro.models.sharding import ShardingRules, set_rules
from repro.train import checkpoint as C
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.launch.train import shard_tree

cfg = reduced(get_config("qwen2.5-3b"), n_layers=2, d_model=128, d_ff=256, vocab=128)
opt_cfg = OptConfig(lr=1e-3, total_steps=10)
data = DataPipeline(vocab=cfg.vocab, batch=8, seq=16, seed=0)
axes_t = (jax.sharding.AxisType.Auto,) * 2

mesh8 = jax.make_mesh((4, 2), ("data", "model"), axis_types=axes_t)
rules8 = ShardingRules(mesh=mesh8); set_rules(rules8)
params = M.init_params(cfg, jax.random.key(0))
pv, pax = split_params(params)
with jax.set_mesh(mesh8):
    pv = shard_tree(pv, pax, rules8)
    opt = init_opt_state(opt_cfg, pv)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    for i in range(3):
        pv, opt, m = step(pv, opt, data.get_batch(i))
C.save(r"{tmp_path}", 3, pv, opt, extra=dict(data=data.state(3)))

devs = np.array(jax.devices()[:4]).reshape(2, 2)
mesh4 = jax.sharding.Mesh(devs, ("data", "model"), axis_types=axes_t)
rules4 = ShardingRules(mesh=mesh4); set_rules(rules4)
rp, ro, extra = C.restore(r"{tmp_path}")
with jax.set_mesh(mesh4):
    rp = shard_tree(rp, pax, rules4)
    step4 = jax.jit(make_train_step(cfg, opt_cfg))
    for i in range(extra["data"]["step"], 5):
        rp, ro, m = step4(rp, ro, data.get_batch(i))
assert np.isfinite(float(m["loss"]))
print("ELASTIC-OK", float(m["loss"]))
"""
    p = run_py(code)
    assert "ELASTIC-OK" in p.stdout, p.stdout[-3000:] + p.stderr[-3000:]


def test_sharded_store_routing_correct():
    """8 fake devices: distributed get == local oracle."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.remixdb import RemixServiceConfig
from repro.db.sharded import build_demo_state, make_sharded_get, _owner_of
from repro.core import keys as CK

cfg = RemixServiceConfig(entries_per_run=512, runs_per_partition=3, query_batch=1024)
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
remix, runset = build_demo_state(cfg, 8, seed=1)
step, qspec = make_sharded_get(cfg, mesh)
# probe a mix of existing keys and misses
rng = np.random.default_rng(0)
all_keys = []
for s in range(8):
    kk = CK.unpack_u64(np.asarray(runset.keys[s]).reshape(-1, 2))
    lens = np.asarray(runset.lens[s])
    for r in range(3):
        all_keys.extend(np.asarray(runset.keys[s, r])[: lens[r]].tolist())
all_keys = np.array([k for k in all_keys], dtype=np.uint32).reshape(-1, 2)
exist = all_keys[rng.choice(len(all_keys), 512, replace=False)]
miss = CK.pack_u64(rng.integers(1, 2**62, 512).astype(np.uint64) | 1)
queries = jnp.asarray(np.concatenate([exist, miss]))
with jax.set_mesh(mesh):
    sspec = NamedSharding(mesh, P(("data", "model")))
    jitted = jax.jit(step)
    found, vals = jitted(remix, runset, queries)
found = np.asarray(found)
assert found[:512].all(), f"missing {512 - found[:512].sum()} existing keys"
assert found[512:].sum() < 5, f"false positives: {found[512:].sum()}"
print("SHARDED-OK", found[:512].sum(), found[512:].sum())
"""
    p = run_py(code)
    assert "SHARDED-OK" in p.stdout, p.stdout[-3000:] + p.stderr[-3000:]

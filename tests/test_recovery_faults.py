"""Crash-recovery fault injection for range tombstones and TTL writes.

Crash images are taken by copying the live data directory (WAL synced or
deliberately torn) and reopening the copy — the original store object is
never closed cleanly, so recovery sees exactly what a power loss at the
kill point would leave behind. Kill points:

- after the WAL range-tombstone append (record durable, nothing flushed);
- mid-append (torn tail record: the PR-1 epoch-flip tail scan must
  discard it without resurrecting anything);
- mid-manifest-commit (MANIFEST written, CURRENT flip failed — reopen
  must serve the *previous* committed version + full WAL replay).
"""
import os
import shutil

import numpy as np
import pytest

from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig
from repro.io import manifest as manifest_mod

pytestmark = pytest.mark.faults  # nightly fault-matrix profile (ci.yml)


def _cfg(**kw):
    return RemixDBConfig(
        vw=2,
        memtable_entries=kw.pop("memtable_entries", 256),
        compaction=CompactionConfig(table_cap=256, t_max=4),
        hot_threshold=255,
        **kw,
    )


def _fill(db, lo, hi, tag):
    ks = np.arange(lo, hi, dtype=np.uint64)
    vs = np.stack(
        [ks.astype(np.uint32), np.full(len(ks), tag, np.uint32)], 1
    )
    db.put_batch(ks, vs)
    return {int(k): (int(v[0]), int(v[1])) for k, v in zip(ks, vs)}


def _crash_image(src, dst):
    shutil.copytree(src, dst)
    return dst


def _assert_state(db, model):
    kk, vv = db.scan(0, 1 << 20)
    got = {int(k): (int(v[0]), int(v[1])) for k, v in zip(kk, vv)}
    assert got == model


def test_crash_after_wal_range_append(tmp_path):
    """Power loss right after the range record hits the WAL: recovery
    replays it and the excision survives — flushed keys in the span stay
    dead, later writes into the span stay live."""
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg())
    model = _fill(db, 0, 400, tag=1)
    db.flush()
    model.update(_fill(db, 400, 500, tag=2))  # unflushed rows too
    db.delete_range(100, 450)
    for k in [k for k in model if 100 <= k < 450]:
        del model[k]
    db.put(120, np.array([120, 3], np.uint32))  # post-range write in span
    model[120] = (120, 3)
    db.wal.sync()  # the kill point: record durable, nothing else done
    img = _crash_image(d, str(tmp_path / "crash"))
    db.close()

    db2 = RemixDB.open(img, _cfg())
    try:
        _assert_state(db2, model)
        assert db2.get(200) is None  # excised, flushed key: never back
        assert db2.get(120) is not None
        # and the state survives a flush + clean reopen cycle
        db2.flush()
        _assert_state(db2, model)
    finally:
        db2.close()
    db3 = RemixDB.open(img, _cfg())
    try:
        _assert_state(db3, model)
    finally:
        db3.close()


def test_crash_torn_wal_range_append(tmp_path):
    """Power loss during the range append's block write: the WAL's
    atomicity unit is the 4 KB block (its epoch bit flips on rewrite), so
    a torn append means the tail block still holds its *old* content.
    The epoch-flip tail scan must then ignore it — the delete_range never
    happened, and nothing written before it is lost."""
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg())
    model = _fill(db, 0, 300, tag=1)
    db.flush()
    db.wal.sync()
    wal_path = db.wal.path
    with open(wal_path, "rb") as f:
        pre = f.read()  # durable bytes before the kill point
    db.delete_range(50, 250)
    db.wal.sync()
    img = _crash_image(d, str(tmp_path / "crash"))
    db.close()
    # the torn write: blocks touched by the append revert to their
    # pre-append content (epoch bit included); fresh blocks vanish
    img_wal = os.path.join(img, os.path.relpath(wal_path, d))
    with open(img_wal, "r+b") as f:
        f.seek(0)
        f.write(pre)
        f.truncate(len(pre))

    db2 = RemixDB.open(img, _cfg())
    try:
        _assert_state(db2, model)  # range record gone, no data lost
    finally:
        db2.close()


def _commit_bomb(monkeypatch, fail_on):
    """Arm repro.io.manifest._atomic_write to raise on its Nth call for a
    path containing ``fail_on`` (CURRENT flip or MANIFEST body)."""
    real = manifest_mod._atomic_write

    def bomb(path, data, io=None):
        if fail_on in os.path.basename(path):
            raise OSError(f"injected crash writing {os.path.basename(path)}")
        return real(path, data, io=io)

    monkeypatch.setattr(manifest_mod, "_atomic_write", bomb)
    return lambda: monkeypatch.setattr(
        manifest_mod, "_atomic_write", real
    )


@pytest.mark.parametrize("fail_on", ["CURRENT", "MANIFEST"])
def test_crash_mid_manifest_commit(tmp_path, monkeypatch, fail_on):
    """Kill inside the manifest commit (before the CURRENT flip, or
    before the MANIFEST body lands): reopen serves the previous committed
    version and the WAL replay reapplies everything since — the excised
    span included. No key is resurrected either way."""
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg())
    model = _fill(db, 0, 400, tag=1)
    db.flush()  # committed baseline
    db.delete_range(100, 300)
    for k in [k for k in model if 100 <= k < 300]:
        del model[k]
    model.update(_fill(db, 500, 550, tag=2))
    disarm = _commit_bomb(monkeypatch, fail_on)
    with pytest.raises(OSError, match="injected crash"):
        db.flush()  # dies mid-commit; WAL was not GC'd
    disarm()
    db.wal.sync()
    img = _crash_image(d, str(tmp_path / "crash"))
    db.close()

    db2 = RemixDB.open(img, _cfg())
    try:
        _assert_state(db2, model)
        assert db2.get(150) is None  # never resurrected
        db2.flush()  # a clean commit from the recovered state works
        _assert_state(db2, model)
    finally:
        db2.close()
    db3 = RemixDB.open(img, _cfg())
    try:
        _assert_state(db3, model)
        assert db3.get(150) is None
    finally:
        db3.close()


def test_wal_read_from_tail_follow(tmp_path):
    """``WAL.read_from(seq)`` returns exactly the live records past the
    floor — the replication tail-follow primitive — and skips whole
    blocks via the persisted per-block ``max_seq`` instead of rescanning
    every epoch."""
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg(memtable_entries=1 << 14))
    _fill(db, 0, 600, tag=1)
    db.delete_range(50, 80)
    mid_seq = db.seq - 1  # floor: everything after this is "the tail"
    _fill(db, 600, 900, tag=2)
    db.delete_range(700, 720)

    recs = list(db.wal.read_from(0))
    assert len(recs) == 902  # 900 puts + 2 range records
    assert sorted(int(r[1]) for r in recs) == list(range(1, 903))

    tail = list(db.wal.read_from(mid_seq))
    assert {int(r[1]) for r in tail} == set(range(mid_seq + 1, 903))
    keys = {int(r[0]) for r in tail if not r[2] & 2}
    assert keys == set(range(600, 900))

    # floor at the top: nothing to follow
    assert list(db.wal.read_from(db.seq)) == []

    # overwrites: records stay until WAL GC, so both versions may appear;
    # replication applies in seq order and the newest must win
    db.put(10, np.array([10, 9], np.uint32))
    again = sorted((r for r in db.wal.read_from(0) if int(r[0]) == 10),
                   key=lambda r: int(r[1]))
    assert int(again[-1][4][1]) == 9
    db.close()


def test_wal_read_from_torn_tail_image(tmp_path):
    """A follower tailing a crash-recovered WAL sees exactly what
    recovery kept: the torn record is gone, every durable record is
    yielded — ``read_from`` and full recovery agree on the same image."""
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg(memtable_entries=1 << 14))
    model = _fill(db, 0, 300, tag=1)
    db.wal.sync()
    wal_path = db.wal.path
    with open(wal_path, "rb") as f:
        pre = f.read()
    db.put(999, np.array([999, 7], np.uint32))  # will be torn away
    db.wal.sync()
    img = _crash_image(d, str(tmp_path / "crash"))
    db.close()
    img_wal = os.path.join(img, os.path.relpath(wal_path, d))
    with open(img_wal, "r+b") as f:
        f.seek(0)
        f.write(pre)
        f.truncate(len(pre))

    db2 = RemixDB.open(img, _cfg(memtable_entries=1 << 14))
    try:
        _assert_state(db2, model)
        recs = list(db2.wal.read_from(0))
        assert {int(r[0]) for r in recs} == set(range(0, 300))
        assert 999 not in {int(r[0]) for r in recs}
        # max_seq block skipping is consistent post-recovery too
        top = max(int(r[1]) for r in recs)
        assert list(db2.wal.read_from(top)) == []
        assert len(list(db2.wal.read_from(top - 1))) == 1
    finally:
        db2.close()


@pytest.mark.nightly
@pytest.mark.parametrize("fail_on", ["CURRENT", "MANIFEST"])
@pytest.mark.parametrize("seed", range(6))
def test_crash_matrix_random_workloads(tmp_path, monkeypatch, seed,
                                       fail_on):
    """Nightly fault-injection matrix: randomized op mixes (puts,
    deletes, range deletes, overlapping spans) crashed mid-commit, then
    recovered and differentially checked."""
    import random

    rng = random.Random(seed)
    d = str(tmp_path / "live")
    db = RemixDB.open(d, _cfg(memtable_entries=128))
    model = {}
    for round_ in range(4):
        for _ in range(rng.randrange(50, 150)):
            k = rng.randrange(1000)
            v = (rng.randrange(1 << 31), round_)
            db.put(k, np.array(v, np.uint32))
            model[k] = v
        if rng.random() < 0.7:
            lo = rng.randrange(900)
            hi = lo + rng.randrange(1, 300)
            db.delete_range(lo, hi)
            for k in [k for k in model if lo <= k < hi]:
                del model[k]
        if round_ < 3:
            db.flush()
    disarm = _commit_bomb(monkeypatch, fail_on)
    try:
        db.flush()
    except OSError:
        pass  # the kill point (flush may also survive if nothing to do)
    disarm()
    db.wal.sync()
    img = _crash_image(d, str(tmp_path / f"crash{seed}"))
    db.close()
    db2 = RemixDB.open(img, _cfg(memtable_entries=128))
    try:
        _assert_state(db2, model)
    finally:
        db2.close()

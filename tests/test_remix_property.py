"""Property-based tests (hypothesis) for REMIX invariants.

Invariants checked on arbitrary run sets:
  I1  get(k) == brute-force LSM semantics (newest version wins, tombstones hide)
  I2  seek(k) decodes to the global lower bound of k on the live sorted view
  I3  REMIX scan and merging-iterator scan return identical user-level results
  I4  every group anchor is a newest-version key; placeholders only at tails
  I5  cursor offsets equal the per-run consumed-entry counts at group heads
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import keys as K
from repro.core import merge_iter as M
from repro.core import query as Q
from repro.core.remix import build_remix
from repro.core.runs import make_run
from repro.core.view import NEWEST_BIT, PLACEHOLDER


@st.composite
def runset_strategy(draw):
    r = draw(st.integers(1, 6))
    keyspace = draw(st.integers(8, 120))
    runs = []
    truth = {}  # key -> (seq, tomb)
    for i in range(r):
        n = draw(st.integers(0, min(40, keyspace)))
        kk = draw(
            st.lists(
                st.integers(0, keyspace), min_size=n, max_size=n, unique=True
            )
        )
        kk = np.sort(np.array(kk, np.uint64)) if kk else np.zeros(0, np.uint64)
        tomb = np.array(
            draw(st.lists(st.booleans(), min_size=len(kk), max_size=len(kk))),
            bool,
        ) if len(kk) else np.zeros(0, bool)
        runs.append(make_run(kk, seq=i + 1, tomb=tomb))
        for j, key in enumerate(kk):
            prev = truth.get(int(key))
            if prev is None or prev[0] < i + 1:
                truth[int(key)] = (i + 1, bool(tomb[j]))
    d = draw(st.sampled_from([8, 16, 32]))
    if d < r:
        d = 8
    return runs, truth, d, keyspace


@settings(max_examples=60, deadline=None)
@given(runset_strategy(), st.integers(0, 200))
def test_get_matches_truth(data, qseed):
    runs, truth, d, keyspace = data
    if all(r.n == 0 for r in runs):
        return
    remix, runset = build_remix(runs, d=d)
    rng = np.random.default_rng(qseed)
    queries = rng.integers(0, keyspace + 2, size=16).astype(np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    found, vals = Q.get(remix, runset, qk)
    mfound, mvals = M.merge_get(runset, qk)
    for i, q in enumerate(queries):
        entry = truth.get(int(q))
        expect = entry is not None and not entry[1]
        assert bool(np.asarray(found)[i]) == expect, (q, entry)
        assert bool(np.asarray(mfound)[i]) == expect, (q, entry)
        if expect:
            assert int(np.asarray(vals)[i, -1]) == entry[0]  # newest seq


@settings(max_examples=40, deadline=None)
@given(runset_strategy())
def test_scan_agrees_with_merge_iter(data):
    runs, truth, d, keyspace = data
    if all(r.n == 0 for r in runs):
        return
    remix, runset = build_remix(runs, d=d)
    live = sorted(k for k, (s, t) in truth.items() if not t)
    queries = np.array([0, keyspace // 2, keyspace], np.uint64)
    qk = jnp.asarray(K.pack_u64(queries))
    w = 12
    keys, vals, valid, _ = Q.scan(remix, runset, qk, width=w)
    mkeys, mvals, mvalid = M.merge_scan(runset, qk, width=w)
    for i, q in enumerate(queries):
        got = list(K.unpack_u64(np.asarray(keys)[i][np.asarray(valid)[i]]))
        mgot = list(K.unpack_u64(np.asarray(mkeys)[i][np.asarray(mvalid)[i]]))
        start = int(np.searchsorted(np.array(live, np.uint64), q, side="left"))
        expect = live[start:]
        assert got == expect[: len(got)], (q, got, expect[:w])
        assert mgot == expect[: len(mgot)], (q, mgot, expect[:w])


@settings(max_examples=60, deadline=None)
@given(runset_strategy())
def test_structural_invariants(data):
    runs, truth, d, _ = data
    if all(r.n == 0 for r in runs):
        return
    remix, runset = build_remix(runs, d=d)
    sels = np.asarray(remix.selectors)
    r = len(runs)
    pad = sels == PLACEHOLDER
    runid = sels & 0x7F
    assert (runid[~pad] < r).all()
    # I4a: group heads are never placeholders unless the whole group is tail
    heads = sels.reshape(-1, d)[:, 0]
    total_used = int(np.max(np.flatnonzero(~pad))) + 1 if (~pad).any() else 0
    for g, h in enumerate(heads):
        if g * d < total_used:
            assert h != PLACEHOLDER
            assert h & NEWEST_BIT  # anchors point at newest versions
    # I4b: placeholders only at group tails (suffix property per group)
    for row in (sels == PLACEHOLDER).reshape(-1, d):
        if row.any():
            first = int(np.argmax(row))
            tail = row[first:]
            # placeholders in the middle only allowed if rest of group is pad
            assert tail.all() or not row[: first].any()
            assert tail.all()
    # I5: cursor offsets == consumed counts
    cursors = np.asarray(remix.cursors)
    flat_run = np.where(pad, -1, runid)
    for g in range(remix.g):
        for run in range(r):
            consumed = int(np.sum(flat_run[: g * d] == run))
            assert cursors[g, run] == consumed

"""Generate the EXPERIMENTS.md dry-run/roofline markdown tables from sweep
results. Usage: python results/mk_tables.py"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.roofline import analyze_cell  # noqa: E402

BASE = os.path.dirname(os.path.abspath(__file__))


def load(path, hlo_dir):
    rows = []
    seen = set()
    for line in open(os.path.join(BASE, path)):
        rec = json.loads(line)
        key = (rec["arch"], rec["shape"], rec["mesh"])
        if key in seen:
            continue
        seen.add(key)
        if rec.get("status") == "ok":
            hlo = os.path.join(
                BASE, hlo_dir,
                f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.hlo.gz",
            )
            rows.append(analyze_cell(rec, hlo if os.path.exists(hlo) else None))
        else:
            rows.append(rec)
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(rows):
    out = [
        "| arch | shape | mesh | compile s | HLO GFLOP/dev | state GiB/dev | temp GiB/dev | collective ops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"SKIPPED: {r.get('reason','')[:48]} |"
            )
            continue
        counts = r.get("collectives", {}).get("counts", {})
        cstr = " ".join(f"{k.split('-')[-1]}×{v}" for k, v in sorted(counts.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('compile_s','')} "
            f"| {r['flops_per_dev']/1e9:,.0f} | {fmt_bytes(r['arg_bytes'])} "
            f"| {fmt_bytes(r['temp_bytes'])} | {cstr} |"
        )
    return "\n".join(out)


def roofline_table(rows, other=None):
    """Single-pod roofline table; optional second sweep for before/after."""
    key = lambda r: (r["arch"], r["shape"])
    omap = {key(r): r for r in (other or []) if r.get("status") == "ok"}
    out = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | useful | roofline% |"
        + (" opt roofline% | Δ |" if other else ""),
        "|---|---|---|---|---|---|---|---|" + ("---|---|" if other else ""),
    ]
    for r in rows:
        if r.get("status") != "ok" or r["mesh"] != "16x16":
            continue
        line = (
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2f} | "
            f"{r['t_memory']:.2f} | {r['t_collective']:.2f} | "
            f"{r['bottleneck']} | {r.get('useful_ratio', 0):.2f} | "
            f"{100*r.get('roofline_frac', 0):.2f}% |"
        )
        if other:
            o = omap.get(key(r))
            if o and o["mesh"] == "16x16":
                d = 100 * (o.get("roofline_frac", 0) - r.get("roofline_frac", 0))
                line += f" {100*o.get('roofline_frac',0):.2f}% | {d:+.2f}pp |"
            else:
                line += " — | — |"
        out.append(line)
    return "\n".join(out)


if __name__ == "__main__":
    base = load("dryrun_baseline.jsonl", "hlo")
    opt = None
    if os.path.exists(os.path.join(BASE, "dryrun_optimized.jsonl")):
        opt = load("dryrun_optimized.jsonl", "hlo_opt")
        opt = [r for r in opt if r.get("mesh") == "16x16" or r.get("status") != "ok"]
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run (both meshes)\n")
        print(dryrun_table(base))
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod 16×16)\n")
        print(roofline_table(base, opt))

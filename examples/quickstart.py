"""Quickstart: build a REMIX over three sorted runs (the paper's Fig. 3)
and run seek / range-scan / point queries — batched, pure JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import keys as K
from repro.core import query as Q
from repro.core.remix import build_remix
from repro.core.runs import make_run

# the three sorted runs of Figure 3
r0 = make_run(np.array([2, 11, 23, 71, 91], np.uint64), seq=0)
r1 = make_run(np.array([6, 7, 17, 29, 73], np.uint64), seq=1)
r2 = make_run(np.array([4, 31, 43, 52, 67], np.uint64), seq=2)

remix, runset = build_remix([r0, r1, r2], d=4)
print("anchor keys:", K.unpack_u64(np.asarray(remix.anchors)))
print("run selectors:", (np.asarray(remix.selectors) & 0x7F)[:15])
print("cursor offsets:\n", np.asarray(remix.cursors))

# seek 17 (the paper's worked example): lands on key 17 in run R1
queries = jnp.asarray(K.pack_u64(np.array([17, 30, 100], np.uint64)))
pos = Q.seek(remix, runset, queries)
print("\nseek positions for [17, 30, 100]:", np.asarray(pos))

# range scan: 6 keys from 17 — comparison-free next operations
keys, vals, valid, _ = Q.scan(remix, runset, queries[:1], width=8)
got = K.unpack_u64(np.asarray(keys)[0][np.asarray(valid)[0]])
print("scan(17, 6):", got[:6], "(expect 17 23 29 31 43 52)")

# point queries without bloom filters
found, vals = Q.get(remix, runset, queries)
print("get [17, 30, 100]:", np.asarray(found), "(expect True False False)")

"""Serve a small LM with batched requests + the REMIX-indexed prefix cache.

Shows the paper's idea on the serving path: immutable KV-page generations
indexed by a REMIX give one-binary-search longest-prefix lookup; outputs are
bit-identical with the cache on or off, only recomputation is removed.

    PYTHONPATH=src python examples/serve_llm_prefix_cache.py
"""
import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.kvcache import PrefixCache
from repro.models.layers import split_params
from repro.serve.engine import ServeEngine

cfg = reduced(get_config("qwen2.5-3b"), n_layers=4, d_model=256, d_ff=512,
              vocab=2048)
params = M.init_params(cfg, jax.random.key(0))
pv, _ = split_params(params)

rng = np.random.default_rng(0)
system_prompt = rng.integers(0, cfg.vocab, 48).astype(np.int32)
requests = [
    np.concatenate([system_prompt, rng.integers(0, cfg.vocab, 12).astype(np.int32)])
    for _ in range(6)
]

print("== without prefix cache ==")
eng = ServeEngine(cfg, pv, max_seq=128)
t0 = time.perf_counter()
outs_plain = [eng.generate(r, max_new=12) for r in requests]
t_plain = time.perf_counter() - t0
print(f"  {len(requests)} requests in {t_plain:.2f}s "
      f"(prefill {eng.stats.prefill_tokens} tok)")

print("== with REMIX prefix cache ==")
cache = PrefixCache(cfg, n_pages=256, page_size=16)
eng2 = ServeEngine(cfg, pv, max_seq=128, prefix_cache=cache)
t0 = time.perf_counter()
outs_cached = [eng2.generate(r, max_new=12) for r in requests]
t_cached = time.perf_counter() - t0
print(f"  {len(requests)} requests in {t_cached:.2f}s "
      f"(prefill {eng2.stats.prefill_tokens} tok, "
      f"reused {eng2.stats.cached_tokens} tok, "
      f"page-table lookups {cache.table.lookups})")

for a, b in zip(outs_plain, outs_cached):
    assert np.array_equal(a, b), "prefix cache changed outputs!"
print("outputs identical with and without the cache ✓")
print(f"prefill tokens saved: "
      f"{eng.stats.prefill_tokens - eng2.stats.prefill_tokens}")
print("(note: on this CPU demo the host-side page copies can outweigh the "
      "tiny model's prefill; the win scales with model size — the point "
      "here is exact reuse via one REMIX lookup instead of per-generation "
      "probing)")

"""End-to-end RemixDB driver: load a store, run compactions, serve batched
point + range queries, report write amplification — the paper's system
(§4) end to end, with the WAL/recovery path exercised.

    PYTHONPATH=src python examples/kvstore_serving.py
"""
import tempfile
import time

import numpy as np

from repro.db.compaction import CompactionConfig
from repro.db.store import RemixDB, RemixDBConfig

rng = np.random.default_rng(0)
N = 200_000

db = RemixDB(
    RemixDBConfig(
        memtable_entries=16384,
        wal_dir=tempfile.mkdtemp(prefix="remixdb-demo-"),
        compaction=CompactionConfig(table_cap=16384, t_max=10),
        hot_threshold=8,
    )
)

print(f"loading {N} random keys ...")
keys = rng.permutation(N).astype(np.uint64) * 7
vals = np.stack([keys & 0xFFFFFFFF, keys >> 32], 1).astype(np.uint32)
t0 = time.perf_counter()
for c in range(0, N, 16384):
    db.put_batch(keys[c : c + 16384], vals[c : c + 16384])
db.flush()
dt = time.perf_counter() - t0
st = db.stats()
print(f"  loaded in {dt:.1f}s -> {st['partitions']} partitions, "
      f"{st['tables']} tables, WA={st['wa']:.2f}")
kinds = {}
for s in db.compaction_log:
    for k, v in s["kinds"].items():
        kinds[k] = kinds.get(k, 0) + v
print(f"  compactions: {kinds}")

# hot keys: update a few keys repeatedly; they stay in MemTable+WAL
for _ in range(12):
    db.put(int(keys[0]), [1, 2])
db.flush()
print(f"  hot key retained in MemTable: {db.mem.get(int(keys[0])) is not None}")

print("serving batched point queries ...")
probe = rng.choice(keys, 4096)
t0 = time.perf_counter()
found, _ = db.get_batch(probe)
print(f"  4096 gets in {(time.perf_counter()-t0)*1e3:.1f} ms, "
      f"hit rate {found.mean():.3f}")

print("range scans ...")
skeys = np.sort(keys)
t0 = time.perf_counter()
for s in skeys[:: N // 50][:32]:
    kk, vv = db.scan(int(s), 50)
    assert len(kk) >= 1
print(f"  32 seek+next50 in {(time.perf_counter()-t0)*1e3:.1f} ms")

print("WAL recovery check ...")
db.put(999_999_999, [7, 7])
db.wal.sync()
mem = db.recover_memtable()
print(f"  recovered {len(mem)} buffered entries; "
      f"999999999 present: {mem.get(999_999_999) is not None}")

"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on CPU with checkpoint/restart, demonstrating the full train stack
(data pipeline → model → AdamW → checkpointing).

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch mamba2-130m
"""
import argparse
import dataclasses
import os
import time

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline
from repro.models import model as M
from repro.models.layers import split_params
from repro.train import checkpoint as C
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro-train-ckpt")
    ap.add_argument("--full-size", action="store_true",
                    help="use the arch's real config (slow on CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        # ~100M-parameter same-family config (dense ~119M; CPU-trainable)
        cfg = reduced(
            cfg, d_model=768, n_layers=12, vocab=32000, d_ff=2048,
            n_heads=12, n_kv_heads=4, head_dim=64,
        )
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.0f}M "
          f"(training {args.steps} steps, batch {args.batch}x{args.seq})")

    params = M.init_params(cfg, jax.random.key(0))
    pv, _ = split_params(params)
    opt_cfg = OptConfig(lr=3e-4, warmup=20, total_steps=args.steps)
    opt = init_opt_state(opt_cfg, pv)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    data = DataPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0)

    start = 0
    if C.latest_step(args.ckpt) is not None:
        pv, opt, extra = C.restore(args.ckpt)
        start = extra["data"]["step"]
        print(f"resumed from checkpoint at step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        pv, opt, metrics = step_fn(pv, opt, data.get_batch(step))
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            tput = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {tput:,.0f} tok/s")
        if step and step % 100 == 0:
            C.save(args.ckpt, step, pv, opt, extra=dict(data=data.state(step)))
            print(f"  checkpointed at step {step}")
    C.save(args.ckpt, args.steps, pv, opt,
           extra=dict(data=data.state(args.steps)))
    print("done; final checkpoint written to", args.ckpt)


if __name__ == "__main__":
    main()

from repro.serve.engine import KVServeEngine, ServeEngine  # noqa: F401

"""Serving engines: the LLM batch engine and the KV-store front-end.

:class:`ServeEngine` drives the model serving pipeline (prefix cache +
prefill + decode). :class:`KVServeEngine` is the storage-side analogue: it
fronts one or more persistent :class:`repro.db.store.RemixDB` shards with
a **single block cache shared across every partition of every shard**, so
cold-start queries on any shard warm the same bytes-budgeted pool and the
operator gets one hit/miss/eviction view of the whole serving node.

Batched serving engine with a REMIX-indexed prefix cache.

Pipeline per request batch: longest-prefix match (REMIX batched lookup) →
copy cached KV pages into the decode cache → prefill the uncached suffix →
greedy decode → register new pages. Deterministic: with or without the
prefix cache, outputs are bit-identical (tested), the cache only removes
recomputation — the serving-side analogue of the paper's "reuse the sorted
view instead of rebuilding it".
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.kvcache import PrefixCache


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    prefill_tokens: int = 0
    cached_tokens: int = 0
    decoded_tokens: int = 0


class ServeEngine:
    def __init__(
        self, cfg: ModelConfig, params, max_seq: int = 256,
        prefix_cache: PrefixCache | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.prefix = prefix_cache
        self.stats = ServeStats()

        def _decode(params, cache, tok, pos):
            return M.decode_step(cfg, params, cache, tok, pos)

        self._decode = jax.jit(_decode)

    def _prefill_tokens(self, cache, tokens: np.ndarray, start: int):
        """Teacher-forced decode_step loop over the uncached suffix.

        (A fused prefill kernel is used for the dry-run shapes; the engine
        loop keeps per-position cache writes simple and exact on CPU.)
        """
        logits = None
        for t in range(start, len(tokens)):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(tokens[t : t + 1]), t
            )
        return logits, cache

    def generate(self, prompt: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy generation for one request (batch=1 internally)."""
        cfg = self.cfg
        self.stats.requests += 1
        cache = M.init_cache(cfg, 1, self.max_seq)
        start = 0
        if self.prefix is not None and cfg.family in ("dense", "moe"):
            n_cached, slots = self.prefix.match(prompt)
            if n_cached:
                k, v = self.prefix.gather(slots)  # (L, n, KVH, hd)
                kc = np.asarray(cache["k"], np.float32)
                vc = np.asarray(cache["v"], np.float32)
                kc[:, 0, : k.shape[1]] = k.astype(np.float32)
                vc[:, 0, : v.shape[1]] = v.astype(np.float32)
                cache = dict(
                    k=jnp.asarray(kc, cache["k"].dtype),
                    v=jnp.asarray(vc, cache["v"].dtype),
                )
                start = n_cached
                self.stats.cached_tokens += n_cached
        logits, cache = self._prefill_tokens(cache, prompt, start)
        self.stats.prefill_tokens += len(prompt) - start
        out = []
        pos = len(prompt)
        tok = int(np.asarray(jnp.argmax(logits[0])))
        for _ in range(max_new):
            out.append(tok)
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([tok], jnp.int32), pos
            )
            pos += 1
            tok = int(np.asarray(jnp.argmax(logits[0])))
        if self.prefix is not None and cfg.family in ("dense", "moe"):
            full = np.concatenate([prompt, np.array(out, prompt.dtype)])
            kc = np.asarray(cache["k"])[:, 0]  # (L, S, KVH, hd)
            vc = np.asarray(cache["v"])[:, 0]
            self.prefix.register(full, kc, vc)
        self.stats.decoded_tokens += len(out)
        return np.array(out, np.int32)


class KVServeEngine:
    """Range-sharded RemixDB serving front with one shared block cache.

    ``shards`` maps inclusive lower key bounds to store data directories
    (or existing :class:`RemixDB` instances); every store is opened with
    the *same* :class:`repro.io.blockcache.BlockCache`, so the byte
    budget — and the hit/miss accounting — spans all partitions of all
    shards instead of fragmenting per store. Point and range queries are
    routed by key range, mirroring the store's own routing one level up.

    The serving surface is the op layer (API v2): :meth:`submit` takes a
    typed :class:`repro.db.ops.Batch` — mixed gets, multigets, scans,
    puts and deletes, with per-op deadlines/priorities — and the shared
    :class:`repro.db.executor.Executor` fans it out across shards
    (writes to the owning shard, reads through **one pinned snapshot per
    touched shard per batch**) and back in. Every legacy method below is
    a thin wrapper building a one-kind batch and blocking on the future,
    so both surfaces stay bit-for-bit identical — the serving-side MVCC
    contract is unchanged. ``snapshot()`` exposes the pinned handle for
    callers that want consistency across *multiple* requests (e.g. a
    streaming cursor per shard).
    """

    def __init__(
        self,
        shards: list[tuple[int, object]],
        cache_bytes: int = 64 << 20,
        config=None,
        max_inflight_bytes: int = 256 << 20,
        submit_workers: int = 2,
        metrics: bool = True,
        trace_sample_rate: float = 0.0,
    ):
        from repro.db.executor import Executor
        from repro.db.store import RemixDB, RemixDBConfig
        from repro.io.blockcache import BlockCache
        from repro.obs.events import EventLog, NULL_EVENTS
        from repro.obs.metrics import MetricsRegistry

        if not shards:
            raise ValueError("KVServeEngine needs at least one shard")
        # serving-tier observability: the shared cache and the cross-shard
        # executor record into this registry; each shard store keeps its
        # own (metrics() merges them under per-shard labels)
        self.registry = MetricsRegistry(enabled=metrics)
        self.events = EventLog() if metrics else NULL_EVENTS
        self.cache = BlockCache(cache_bytes, registry=self.registry)
        self._config = config
        self._metrics_on = metrics
        self._max_inflight_bytes = max_inflight_bytes
        self._submit_workers = submit_workers
        self._trace_sample_rate = trace_sample_rate
        self.lows, self.shards = self._prepare_shards(shards)
        self.engine = self._build_engine()

    def _prepare_shards(self, shards):
        """Open/adopt ``(lo, dir-or-store)`` pairs onto the shared cache."""
        from repro.db.store import RemixDB, RemixDBConfig

        lows: list[int] = []
        out: list[RemixDB] = []
        for lo, db in sorted(shards, key=lambda s: s[0]):
            if not isinstance(db, RemixDB):
                cfg0 = self._config or RemixDBConfig()
                cfg = dataclasses.replace(
                    cfg0,
                    data_dir=str(db),
                    block_cache=self.cache,
                    metrics=cfg0.metrics and self._metrics_on,
                    trace_sample_rate=self._trace_sample_rate,
                )
                db = RemixDB(cfg)
            elif db.storage is not None and db.block_cache is not self.cache:
                # adopt a pre-opened store into the shared pool: swap its
                # private cache out of every table handle (already-cached
                # blocks stay in the old pool and simply age out)
                db.block_cache = self.cache
                for p in db.partitions:
                    for t in p.tables:
                        t.attach_cache(self.cache)
            lows.append(int(lo))
            out.append(db)
        return lows, out

    def _build_engine(self):
        from repro.db.executor import Executor

        return Executor(
            list(zip(self.lows, self.shards)),
            max_inflight_bytes=self._max_inflight_bytes,
            workers=self._submit_workers,
            registry=self.registry,
            events=self.events,
            trace_sample_rate=self._trace_sample_rate,
        )

    def swap_shards(self, shards) -> None:
        """Atomically install a new shard routing table — the cutover
        step of a live shard split/merge. Builds a fresh Executor over
        the new ``(lo, store-or-dir)`` list (same shared cache/registry;
        the counters keep accumulating), swaps it in, then drains and
        closes the old executor. Callers must quiesce submissions around
        the swap (``cluster.Cluster`` gates them); in-flight batches on
        the old executor finish normally — their stores stay open — so
        no op ever fails from a swap."""
        lows, stores = self._prepare_shards(shards)
        old = self.engine
        self.shards = stores
        self.lows = lows
        self.engine = self._build_engine()
        old.close(wait=True)
        self.events.emit("route_swap", shards=len(lows),
                         lows=[str(lo) for lo in lows])

    def _route(self, key: int) -> "object":
        return self.shards[max(0, bisect.bisect_right(self.lows, key) - 1)]

    # ---------------- operation layer (API v2) ----------------
    def submit(self, batch, *, sync: bool = False):
        """Submit a typed op batch across all shards; returns a future
        resolving to a :class:`repro.db.ops.BatchResult`."""
        return self.engine.submit(batch, sync=sync)

    def _run_one(self, op):
        from repro.db.ops import Batch

        r = self.engine.submit(Batch([op]), sync=True).result().results[0]
        r.raise_if_error()
        return r

    def close(self) -> None:
        """Drain and stop the op executor (the stores stay open)."""
        self.engine.close()

    # ---------------- legacy wrappers ----------------
    def get(self, key: int):
        """Point lookup, routed through the batched path: a scalar get is
        a batch of one, so cold shards answer it with the same vectorized
        ``cold_get_batch`` machinery (and the same block accounting) as a
        256-key batch."""
        from repro.db.ops import Op

        r = self._run_one(Op.multiget(np.array([int(key)], np.uint64)))
        return r.vals[0] if bool(r.found[0]) else None

    def snapshot(self, key: int | None = None):
        """Pin a consistent view: of the shard owning ``key``, or (when
        ``key`` is None) a list of per-shard snapshots in key order —
        close each (or use ``with``) when done."""
        if key is not None:
            return self._route(int(key)).snapshot()
        return [db.snapshot() for db in self.shards]

    def get_batch(self, keys):
        """Batched point lookups: one vectorized ``get_batch`` call per
        touched shard — a sharded batch costs O(shards) batched calls,
        never O(keys) scalar gets — each through a Version pinned for
        the duration of the batch (the store's ephemeral view: pinned
        like a snapshot but sharing the live overlay, so the serving hot
        path never copies a MemTable per request)."""
        from repro.db.ops import Op

        r = self._run_one(Op.multiget(keys))
        return r.found, r.vals

    def scan(self, start_key: int, n: int):
        """Cross-shard range scan: drain shards in key order until full,
        each shard read through a snapshot pinned for the call."""
        from repro.db.ops import Op

        r = self._run_one(Op.scan(int(start_key), int(n)))
        return r.keys, r.vals

    def scan_batch(self, starts, n: int):
        """Batched cross-shard range scans (serve-side analogue of
        ``RemixDB.scan_batch``): one vectorized window call per touched
        (shard, partition), under-full scans drain follow-on shards in
        key order. Returns (keys (Q, n) uint64, valid (Q, n))."""
        from repro.db.executor import scan_batch_via_ops

        return scan_batch_via_ops(self.engine, starts, n)

    def put(self, key: int, val) -> None:
        """Upsert, routed to the owning shard's WAL + MemTable."""
        from repro.db.ops import Op

        vw = self.shards[0].cfg.vw
        val = np.asarray(val, np.uint32).reshape(vw)
        self._run_one(Op.put(int(key), val))

    def put_batch(self, keys, vals) -> None:
        """Vectorized upserts: rows are routed to their owning shards
        and each shard's slice group-commits through its WAL in one
        append (cross-shard write fan-out of a single op)."""
        from repro.db.ops import Op

        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(
            len(keys), self.shards[0].cfg.vw
        )
        self._run_one(Op.put(keys, vals))

    def delete(self, key: int) -> None:
        """Tombstone write, routed to the owning shard."""
        from repro.db.ops import Op

        self._run_one(Op.delete(int(key)))

    def delete_range(self, start: int, end: int) -> None:
        """Range tombstone over ``[start, end)``; the executor clips the
        span to each owning shard (one WAL record per touched shard)."""
        from repro.db.ops import Op

        self._run_one(Op.delete_range(int(start), int(end)))

    def cas(self, key: int, expect, val, *, ttl=None):
        """Atomic compare-and-swap on the owning shard. Returns
        ``(swapped, actual)`` — on conflict ``actual`` is the current
        value (None when absent)."""
        from repro.db.ops import Op

        vw = self.shards[0].cfg.vw
        if expect is not None:
            expect = np.asarray(expect, np.uint32).reshape(vw)
        if val is not None:
            val = np.asarray(val, np.uint32).reshape(vw)
        r = self._run_one(Op.cas(int(key), expect, val, ttl=ttl))
        return bool(r.found), r.value

    def flush(self) -> list[dict]:
        """Flush every shard (memtable freeze + compaction round each)."""
        return [db.flush() for db in self.shards]

    def stats(self) -> dict:
        """Aggregated serving stats + the shared cache's counters."""
        per = [db.stats() for db in self.shards]
        return dict(
            shards=len(self.shards),
            cache=self.cache.stats(),
            engine=self.engine.stats(),
            disk_bytes_read=sum(s["disk_bytes_read"] for s in per),
            cold=dict(
                gets=sum(s["cold"]["gets"] for s in per),
                scans=sum(s["cold"]["scans"] for s in per),
            ),
            stores=per,
        )

    def scrub(self, full: bool = True, repair: bool = True) -> list[dict]:
        """Run an integrity scrub on every shard (see
        :meth:`repro.db.store.RemixDB.scrub`); one report per shard."""
        return [db.scrub(full=full, repair=repair) for db in self.shards]

    def health(self) -> dict:
        """Node-level durability summary: ``degraded`` if *any* shard is,
        with each shard's own report keyed by its lower key bound."""
        per = {
            str(lo): db.health()
            for lo, db in zip(self.lows, self.shards)
        }
        degraded = any(h["status"] != "ok" for h in per.values())
        return dict(
            status="degraded" if degraded else "ok",
            shards=per,
            corruption_detected=sum(
                h["corruption_detected"] for h in per.values()
            ),
            quarantine_files=sum(
                h["quarantine_files"] for h in per.values()
            ),
        )

    def metrics(self) -> dict:
        """One labelled observability snapshot for the whole serving
        node: the serving tier's registry (shared cache + cross-shard
        executor) stamped ``tier="serve"``, plus every shard store's
        registry stamped with its lower key bound (``shard="<lo>"``).
        Render with :func:`repro.obs.render_prometheus`."""
        from repro.obs.metrics import merge_snapshots

        parts = [(self.registry.snapshot(), dict(tier="serve"))]
        for lo, db in zip(self.lows, self.shards):
            parts.append((db.registry.snapshot(), dict(shard=str(lo))))
        return merge_snapshots(*parts)

"""Batched serving engine with a REMIX-indexed prefix cache.

Pipeline per request batch: longest-prefix match (REMIX batched lookup) →
copy cached KV pages into the decode cache → prefill the uncached suffix →
greedy decode → register new pages. Deterministic: with or without the
prefix cache, outputs are bit-identical (tested), the cache only removes
recomputation — the serving-side analogue of the paper's "reuse the sorted
view instead of rebuilding it".
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.kvcache import PrefixCache


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    prefill_tokens: int = 0
    cached_tokens: int = 0
    decoded_tokens: int = 0


class ServeEngine:
    def __init__(
        self, cfg: ModelConfig, params, max_seq: int = 256,
        prefix_cache: PrefixCache | None = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.prefix = prefix_cache
        self.stats = ServeStats()

        def _decode(params, cache, tok, pos):
            return M.decode_step(cfg, params, cache, tok, pos)

        self._decode = jax.jit(_decode)

    def _prefill_tokens(self, cache, tokens: np.ndarray, start: int):
        """Teacher-forced decode_step loop over the uncached suffix.

        (A fused prefill kernel is used for the dry-run shapes; the engine
        loop keeps per-position cache writes simple and exact on CPU.)
        """
        logits = None
        for t in range(start, len(tokens)):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(tokens[t : t + 1]), t
            )
        return logits, cache

    def generate(self, prompt: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy generation for one request (batch=1 internally)."""
        cfg = self.cfg
        self.stats.requests += 1
        cache = M.init_cache(cfg, 1, self.max_seq)
        start = 0
        if self.prefix is not None and cfg.family in ("dense", "moe"):
            n_cached, slots = self.prefix.match(prompt)
            if n_cached:
                k, v = self.prefix.gather(slots)  # (L, n, KVH, hd)
                kc = np.asarray(cache["k"], np.float32)
                vc = np.asarray(cache["v"], np.float32)
                kc[:, 0, : k.shape[1]] = k.astype(np.float32)
                vc[:, 0, : v.shape[1]] = v.astype(np.float32)
                cache = dict(
                    k=jnp.asarray(kc, cache["k"].dtype),
                    v=jnp.asarray(vc, cache["v"].dtype),
                )
                start = n_cached
                self.stats.cached_tokens += n_cached
        logits, cache = self._prefill_tokens(cache, prompt, start)
        self.stats.prefill_tokens += len(prompt) - start
        out = []
        pos = len(prompt)
        tok = int(np.asarray(jnp.argmax(logits[0])))
        for _ in range(max_new):
            out.append(tok)
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([tok], jnp.int32), pos
            )
            pos += 1
            tok = int(np.asarray(jnp.argmax(logits[0])))
        if self.prefix is not None and cfg.family in ("dense", "moe"):
            full = np.concatenate([prompt, np.array(out, prompt.dtype)])
            kc = np.asarray(cache["k"])[:, 0]  # (L, S, KVH, hd)
            vc = np.asarray(cache["v"])[:, 0]
            self.prefix.register(full, kc, vc)
        self.stats.decoded_tokens += len(out)
        return np.array(out, np.int32)

"""The REMIX index data structure (paper §3.1) and its construction.

A :class:`Remix` persists, per group of D sorted-view slots:
  - ``anchors``     (G, KW)  smallest (newest-version) key of the group,
  - ``cursors``     (G, R)   per-run cursor offsets at the group head,
  - ``selectors``   (G*D,)   uint8 run selectors (| 0x80 newest, 127 pad).

Construction runs on the host at compaction time; query paths are pure JAX
(see :mod:`repro.core.query` and the Pallas kernels in :mod:`repro.kernels`).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core import view as V
from repro.core.runs import Run, RunSet, stack_runs


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Remix:
    anchors: jnp.ndarray  # (G, KW) uint32
    cursors: jnp.ndarray  # (G, R) int32
    selectors: jnp.ndarray  # (G*D,) uint8
    n_entries: jnp.ndarray  # () int32 — real entries in the view
    d: int = dataclasses.field(metadata=dict(static=True))

    @property
    def g(self) -> int:
        return self.anchors.shape[0]

    @property
    def r(self) -> int:
        return self.cursors.shape[1]

    @property
    def n_slots(self) -> int:
        return self.selectors.shape[0]

    def storage_bytes(self, anchor_key_bytes: float | None = None) -> float:
        """Serialized size per paper §3.4: anchors + S*R cursors + 1B selectors.

        ``anchor_key_bytes`` overrides the per-anchor key size (e.g. the
        average user key length of a workload); defaults to KW*4.
        """
        akb = 4 * self.anchors.shape[1] if anchor_key_bytes is None else anchor_key_bytes
        s = 4  # cursor offset size (paper: 16-bit blk + 8-bit key ≈ 4 B impl)
        return self.g * (akb + s * self.r) + self.n_slots * 1


def build_remix(runs: Sequence[Run], d: int = 32) -> tuple[Remix, RunSet]:
    """Build a REMIX over ``runs``; returns (index, stacked run set)."""
    runset = stack_runs(list(runs))
    run_keys = [np.asarray(r.keys) for r in runs]
    run_seqs = [np.asarray(r.seq) for r in runs]
    layout = V.build_view(run_keys, run_seqs, d)
    return _remix_from_layout(layout, run_keys, len(runs)), runset


def remix_from_order(
    runid: np.ndarray,
    pos: np.ndarray,
    newest: np.ndarray,
    run_keys: Sequence[np.ndarray],
    d: int,
) -> Remix:
    """Build a Remix from a precomputed (key asc, seq desc) merge order.

    Skips the global sort of :func:`build_remix`: callers that already
    know the merged order — e.g. the incremental rebuild that recovers it
    from an old REMIX's selector stream plus the new runs (§4.2,
    Snippet 1) — pay only the group layout cost. ``run_keys`` must list
    every run's (Ni, KW) uint32 keys in run-id order.
    """
    if d < len(run_keys):
        raise ValueError(
            f"group size D={d} must be >= number of runs R={len(run_keys)}"
        )
    layout = V.layout_from_order(runid, pos, newest, d)
    return _remix_from_layout(layout, [np.asarray(k) for k in run_keys],
                              len(run_keys))


def _remix_from_layout(
    layout: V.ViewLayout, run_keys, r: int
) -> Remix:
    d = layout.d
    g = layout.n_groups
    kw = run_keys[0].shape[1] if run_keys else K.KW
    group_starts = np.arange(g, dtype=np.int64) * d

    # cursor offsets: #entries of run r placed in slots < group start
    cursors = np.zeros((g, r), np.int32)
    for run in range(r):
        slots_r = np.flatnonzero(layout.entry_run == run)  # ascending
        cursors[:, run] = np.searchsorted(slots_r, group_starts, side="left")

    # anchor = key at the group's first slot; a group head is never a
    # placeholder (padding only fills group tails). Trailing fully-padded
    # groups (possible when the view is tiny) get the +inf sentinel.
    anchors = np.full((g, kw), K.UINT32_MAX, np.uint32)
    head_run = layout.entry_run[group_starts]
    head_pos = layout.entry_pos[group_starts]
    for i in range(g):
        if head_run[i] >= 0:
            anchors[i] = run_keys[head_run[i]][head_pos[i]]

    return Remix(
        anchors=jnp.asarray(anchors),
        cursors=jnp.asarray(cursors),
        selectors=jnp.asarray(layout.sel),
        n_entries=jnp.asarray(layout.n_entries, jnp.int32),
        d=d,
    )

"""Sorted-view construction (paper §3.1, §4.1 versioning rules).

Builds the global sorted view over a set of sorted runs on the host (view
construction happens at compaction time, off the query path):

- entries ordered by (key asc, seq desc): versions of a key newest → oldest;
- the newest version of each key gets the selector high bit (0x80);
- the view is laid out in groups of D slots; if a multi-version key sequence
  would straddle a group boundary (leaving an old version at a group head),
  placeholder selectors (127) pad the previous group so the whole sequence
  moves to the next group — this keeps every anchor key a newest version.

Requires D >= R (a key has at most one version per run, so a version cluster
always fits in one group), as in the paper.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as K

PLACEHOLDER = 127  # 0x7f
NEWEST_BIT = 0x80


@dataclasses.dataclass(frozen=True)
class ViewLayout:
    """Host-side description of the laid-out sorted view."""

    sel: np.ndarray  # (n_slots,) uint8: run id | NEWEST_BIT, or PLACEHOLDER
    entry_run: np.ndarray  # (n_slots,) int32 run of each slot (-1 = pad)
    entry_pos: np.ndarray  # (n_slots,) int32 in-run position (-1 = pad)
    n_entries: int  # real (non-placeholder) entries
    d: int  # group size

    @property
    def n_slots(self) -> int:
        return self.sel.shape[0]

    @property
    def n_groups(self) -> int:
        return self.n_slots // self.d


def _merge_order(run_keys, run_seqs):
    """Global (key asc, seq desc) order over all runs' entries.

    Returns (runid, pos, keys_sorted, newest) host arrays.
    """
    all_keys = np.concatenate(run_keys, axis=0)
    all_seq = np.concatenate(run_seqs, axis=0)
    runid = np.concatenate(
        [np.full(k.shape[0], i, np.int32) for i, k in enumerate(run_keys)]
    )
    pos = np.concatenate(
        [np.arange(k.shape[0], dtype=np.int32) for k in run_keys]
    )
    order = K.sort_indices_np(all_keys, all_seq)
    keys_sorted = all_keys[order]
    newest = np.ones(order.shape[0], bool)
    if order.shape[0] > 1:
        newest[1:] = np.any(keys_sorted[1:] != keys_sorted[:-1], axis=-1)
    return runid[order], pos[order], keys_sorted, newest


def _layout_groups(newest: np.ndarray, d: int) -> np.ndarray:
    """Slot index for each view entry, inserting placeholder padding.

    Padding rule: a version cluster (newest entry + its following old
    versions) that would straddle a group boundary is pushed to the next
    group. Returns (n_entries,) int64 slot positions.

    Fast path: all entries newest (unique keys) → identity layout.
    """
    n = newest.shape[0]
    if n == 0:
        return np.zeros((0,), np.int64)
    if newest.all():
        return np.arange(n, dtype=np.int64)
    starts = np.flatnonzero(newest)  # cluster starts
    sizes = np.diff(np.append(starts, n))
    if int(sizes.max()) > d:
        raise ValueError(
            f"version cluster of size {int(sizes.max())} exceeds group size {d}"
        )
    # Greedy word-wrap over clusters. Singleton spans between fat clusters
    # are bulk-placed; only fat (size>1) clusters need the boundary check.
    slot_of_cluster = np.zeros(starts.shape[0], np.int64)
    cur = 0
    fat = np.flatnonzero(sizes > 1)
    prev_cluster = 0
    for fi in fat:
        # singleton span [prev_cluster, fi): contiguous placement
        span = int(fi - prev_cluster)
        if span:
            slot_of_cluster[prev_cluster:fi] = cur + np.arange(span)
            cur += span
        rem = (-cur) % d  # free slots left in current group (0 => at head)
        if rem and int(sizes[fi]) > rem:
            cur += rem  # pad with placeholders to the next group head
        slot_of_cluster[fi] = cur
        cur += int(sizes[fi])
        prev_cluster = fi + 1
    span = starts.shape[0] - prev_cluster
    if span:
        slot_of_cluster[prev_cluster:] = cur + np.arange(span)
    # expand cluster slots to entry slots
    cluster_of_entry = np.cumsum(newest) - 1
    within = np.arange(n, dtype=np.int64) - starts[cluster_of_entry]
    return slot_of_cluster[cluster_of_entry] + within


def layout_from_order(
    runid: np.ndarray, pos: np.ndarray, newest: np.ndarray, d: int
) -> ViewLayout:
    """Lay out a precomputed (key asc, seq desc) merge order into groups.

    ``runid``/``pos``/``newest`` are parallel arrays over the merged
    entries in view order. This is the sort-free half of
    :func:`build_view`; the incremental REMIX rebuild
    (:mod:`repro.io.rebuild`) calls it with an order recovered from an old
    REMIX's selector stream instead of a fresh global sort.
    """
    runid = np.asarray(runid, np.int32)
    pos = np.asarray(pos, np.int32)
    newest = np.asarray(newest, bool)
    slots = _layout_groups(newest, d)
    n_slots_used = int(slots[-1]) + 1 if slots.shape[0] else 0
    n_slots = max(d, ((n_slots_used + d - 1) // d) * d)
    sel = np.full((n_slots,), PLACEHOLDER, np.uint8)
    entry_run = np.full((n_slots,), -1, np.int32)
    entry_pos = np.full((n_slots,), -1, np.int32)
    sel[slots] = runid.astype(np.uint8) | (
        newest.astype(np.uint8) << 7
    )
    entry_run[slots] = runid
    entry_pos[slots] = pos
    return ViewLayout(
        sel=sel,
        entry_run=entry_run,
        entry_pos=entry_pos,
        n_entries=int(runid.shape[0]),
        d=d,
    )


def build_view(run_keys, run_seqs, d: int) -> ViewLayout:
    """Construct the sorted-view layout for runs given as host arrays.

    ``run_keys``: list of (Ni, KW) uint32; ``run_seqs``: list of (Ni,) uint32.
    """
    r = len(run_keys)
    if d < r:
        raise ValueError(f"group size D={d} must be >= number of runs R={r}")
    runid, pos, _, newest = _merge_order(run_keys, run_seqs)
    return layout_from_order(runid, pos, newest, d)

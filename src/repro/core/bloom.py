"""Bloom filters — the paper's point-query baseline (10 bits/key, k=7).

Vectorized build and probe over multiword keys; one filter per run, stacked
(R, words) so a query batch probes all runs at once.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

MIX1 = np.uint32(0x9E3779B1)
MIX2 = np.uint32(0x85EBCA77)
MIX3 = np.uint32(0xC2B2AE3D)


def _mix(words: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two independent 32-bit hashes from (..., KW) key words."""
    words = jnp.asarray(words, jnp.uint32)
    h1 = jnp.uint32(0x811C9DC5)
    h2 = jnp.uint32(0x01000193)
    for w in range(words.shape[-1]):
        x = words[..., w]
        h1 = (h1 ^ x) * MIX1
        h1 = h1 ^ (h1 >> 15)
        h2 = (h2 + x) * MIX2
        h2 = h2 ^ (h2 >> 13)
    h1 = (h1 ^ (h1 >> 16)) * MIX3
    h2 = h2 ^ (h2 >> 16)
    return h1, h2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BloomSet:
    bits: jnp.ndarray  # (R, W) uint32 bit arrays
    nbits: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))


def build_bloom(
    run_keys: list[np.ndarray], bits_per_key: int = 10, k: int = 7
) -> BloomSet:
    nbits = max(64, bits_per_key * max(len(kk) for kk in run_keys))
    nbits = ((nbits + 31) // 32) * 32
    words = nbits // 32
    r = len(run_keys)
    bits = np.zeros((r, words), np.uint32)
    for i, kk in enumerate(run_keys):
        if len(kk) == 0:
            continue
        h1, h2 = _mix(jnp.asarray(kk, jnp.uint32))
        h1, h2 = np.asarray(h1, np.uint64), np.asarray(h2, np.uint64)
        for j in range(k):
            pos = (h1 + np.uint64(j) * h2) % np.uint64(nbits)
            np.bitwise_or.at(
                bits[i],
                (pos // np.uint64(32)).astype(np.int64),
                np.uint32(1) << (pos % np.uint64(32)).astype(np.uint32),
            )
    return BloomSet(bits=jnp.asarray(bits), nbits=nbits, k=k)


@jax.jit
def bloom_maybe_contains(bf: BloomSet, queries: jnp.ndarray) -> jnp.ndarray:
    """(Q, KW) queries → (Q, R) bool 'may contain'."""
    h1, h2 = _mix(jnp.asarray(queries, jnp.uint32))  # (Q,)
    out = jnp.ones((queries.shape[0], bf.bits.shape[0]), bool)
    for j in range(bf.k):
        pos = (h1 + jnp.uint32(j) * h2) % jnp.uint32(bf.nbits)
        word = (pos // jnp.uint32(32)).astype(jnp.int32)
        bit = jnp.uint32(1) << (pos % jnp.uint32(32))
        hit = (bf.bits[:, word].T & bit[:, None]) != 0  # (Q, R)
        out = out & hit
    return out

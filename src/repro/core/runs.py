"""Immutable sorted runs ("table files").

A :class:`Run` is one sorted run: keys strictly ascending (unique within the
run), each entry carrying a global sequence number (larger = newer), a
tombstone flag and a fixed-width value payload. A :class:`RunSet` stacks up to
R runs into padded arrays so that (run, index) pairs can be gathered in one
vectorized op — the TPU analogue of the paper's per-table block cursor.

Padding uses the +inf sentinel key so padded slots sort after every real key.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Run:
    keys: jnp.ndarray  # (N, KW) uint32, strictly ascending
    vals: jnp.ndarray  # (N, VW) uint32 payload
    seq: jnp.ndarray  # (N,) uint32 sequence numbers (larger = newer)
    tomb: jnp.ndarray  # (N,) bool tombstones

    @property
    def n(self) -> int:
        return self.keys.shape[0]

    @property
    def kw(self) -> int:
        return self.keys.shape[1]

    @property
    def vw(self) -> int:
        return self.vals.shape[1]


def make_run(
    keys_np, vals_np=None, seq=0, tomb=None, vw: int = 2, sort: bool = True
) -> Run:
    """Build a Run from host arrays. ``keys_np``: (N,KW) uint32 or (N,) u64."""
    keys_np = np.asarray(keys_np)
    if keys_np.ndim == 1:
        keys_np = K.pack_u64(keys_np)
    keys_np = keys_np.astype(np.uint32)
    n = keys_np.shape[0]
    if np.isscalar(seq) or np.asarray(seq).ndim == 0:
        seq_np = np.full((n,), int(seq), np.uint32)
    else:
        seq_np = np.asarray(seq, np.uint32)
    tomb_np = (
        np.zeros((n,), bool) if tomb is None else np.asarray(tomb, bool)
    )
    if vals_np is None:
        # default payload: low word of the key, tagged, so tests can verify
        vals_np = np.zeros((n, vw), np.uint32)
        if n:
            vals_np[:, 0] = keys_np[:, -1]
            vals_np[:, -1] = seq_np
    vals_np = np.asarray(vals_np, np.uint32)
    if sort and n:
        order = K.sort_indices_np(keys_np, seq_np)
        keys_np, vals_np = keys_np[order], vals_np[order]
        seq_np, tomb_np = seq_np[order], tomb_np[order]
        # runs must have unique keys: keep newest per key
        keep = np.ones(n, bool)
        keep[1:] = np.any(keys_np[1:] != keys_np[:-1], axis=-1)
        keys_np, vals_np = keys_np[keep], vals_np[keep]
        seq_np, tomb_np = seq_np[keep], tomb_np[keep]
    return Run(
        keys=jnp.asarray(keys_np),
        vals=jnp.asarray(vals_np),
        seq=jnp.asarray(seq_np),
        tomb=jnp.asarray(tomb_np),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RunSet:
    """R runs stacked into padded (R, Nmax, ...) arrays for vector gathers."""

    keys: jnp.ndarray  # (R, Nmax, KW) uint32, padded with +inf sentinel
    vals: jnp.ndarray  # (R, Nmax, VW) uint32
    seq: jnp.ndarray  # (R, Nmax) uint32
    tomb: jnp.ndarray  # (R, Nmax) bool
    lens: jnp.ndarray  # (R,) int32 true lengths

    @property
    def r(self) -> int:
        return self.keys.shape[0]

    @property
    def nmax(self) -> int:
        return self.keys.shape[1]

    @property
    def kw(self) -> int:
        return self.keys.shape[2]

    @property
    def vw(self) -> int:
        return self.vals.shape[2]

    def total(self) -> int:
        return int(np.sum(np.asarray(self.lens)))

    def gather(self, run_idx: jnp.ndarray, pos: jnp.ndarray):
        """Fetch (keys, vals, seq, tomb) at (run, pos); any batch shape."""
        run_idx = jnp.clip(run_idx, 0, self.r - 1)
        pos = jnp.clip(pos, 0, self.nmax - 1)
        return (
            self.keys[run_idx, pos],
            self.vals[run_idx, pos],
            self.seq[run_idx, pos],
            self.tomb[run_idx, pos],
        )


def partial_runset(
    ranges: Sequence[tuple[int, int]],
    fetch_rows,
    kw: int,
    vw: int,
    with_seq: bool = False,
) -> tuple[RunSet, np.ndarray]:
    """Assemble a host-side RunSet covering only per-run row slices.

    The incremental-materialization primitive for cold-start range
    queries: instead of loading whole tables, the caller names one
    contiguous row range per run (the rows a REMIX scan window touches)
    and ``fetch_rows(run, section, lo, hi)`` pulls exactly those rows —
    backed by block-granular, cache-shared SSTable reads.

    ``ranges``: [lo, hi) absolute row range per run (R entries; empty
    ranges allowed). Returns ``(runset, row0)`` with numpy (host) leaves:
    row ``i`` of run ``r`` in the runset is absolute row ``row0[r] + i``
    of that run. ``seq`` is fetched only ``with_seq`` — scans don't need
    it (selector newest bits already encode version order) and skipping
    it avoids touching those blocks.
    """
    r = len(ranges)
    lens = np.array([max(0, hi - lo) for lo, hi in ranges], np.int32)
    row0 = np.array([lo for lo, _ in ranges], np.int32)
    nmax = max(1, int(lens.max()) if r else 1)
    keys = np.full((r, nmax, kw), K.UINT32_MAX, np.uint32)
    vals = np.zeros((r, nmax, vw), np.uint32)
    seq = np.zeros((r, nmax), np.uint32)
    tomb = np.zeros((r, nmax), bool)
    for i, (lo, hi) in enumerate(ranges):
        m = lens[i]
        if m <= 0:
            continue
        keys[i, :m] = fetch_rows(i, "keys", lo, hi)
        vals[i, :m] = fetch_rows(i, "vals", lo, hi)
        tomb[i, :m] = fetch_rows(i, "tomb", lo, hi)
        if with_seq:
            seq[i, :m] = fetch_rows(i, "seq", lo, hi)
    return RunSet(keys=keys, vals=vals, seq=seq, tomb=tomb, lens=lens), row0


def stack_runs(runs: Sequence[Run]) -> RunSet:
    assert len(runs) >= 1
    kw, vw = runs[0].kw, runs[0].vw
    nmax = max(1, max(r.n for r in runs))
    r = len(runs)
    keys = np.full((r, nmax, kw), K.UINT32_MAX, np.uint32)
    vals = np.zeros((r, nmax, vw), np.uint32)
    seq = np.zeros((r, nmax), np.uint32)
    tomb = np.zeros((r, nmax), bool)
    lens = np.zeros((r,), np.int32)
    for i, run in enumerate(runs):
        n = run.n
        lens[i] = n
        if n:
            keys[i, :n] = np.asarray(run.keys)
            vals[i, :n] = np.asarray(run.vals)
            seq[i, :n] = np.asarray(run.seq)
            tomb[i, :n] = np.asarray(run.tomb)
    return RunSet(
        keys=jnp.asarray(keys),
        vals=jnp.asarray(vals),
        seq=jnp.asarray(seq),
        tomb=jnp.asarray(tomb),
        lens=jnp.asarray(lens),
    )

"""Immutable sorted runs ("table files").

A :class:`Run` is one sorted run: keys strictly ascending (unique within the
run), each entry carrying a global sequence number (larger = newer), a
tombstone flag and a fixed-width value payload. A :class:`RunSet` stacks up to
R runs into padded arrays so that (run, index) pairs can be gathered in one
vectorized op — the TPU analogue of the paper's per-table block cursor.

Padding uses the +inf sentinel key so padded slots sort after every real key.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Run:
    keys: jnp.ndarray  # (N, KW) uint32, strictly ascending
    vals: jnp.ndarray  # (N, VW) uint32 payload
    seq: jnp.ndarray  # (N,) uint32 sequence numbers (larger = newer)
    tomb: jnp.ndarray  # (N,) bool tombstones

    @property
    def n(self) -> int:
        return self.keys.shape[0]

    @property
    def kw(self) -> int:
        return self.keys.shape[1]

    @property
    def vw(self) -> int:
        return self.vals.shape[1]


def make_run(
    keys_np, vals_np=None, seq=0, tomb=None, vw: int = 2, sort: bool = True
) -> Run:
    """Build a Run from host arrays. ``keys_np``: (N,KW) uint32 or (N,) u64."""
    keys_np = np.asarray(keys_np)
    if keys_np.ndim == 1:
        keys_np = K.pack_u64(keys_np)
    keys_np = keys_np.astype(np.uint32)
    n = keys_np.shape[0]
    if np.isscalar(seq) or np.asarray(seq).ndim == 0:
        seq_np = np.full((n,), int(seq), np.uint32)
    else:
        seq_np = np.asarray(seq, np.uint32)
    tomb_np = (
        np.zeros((n,), bool) if tomb is None else np.asarray(tomb, bool)
    )
    if vals_np is None:
        # default payload: low word of the key, tagged, so tests can verify
        vals_np = np.zeros((n, vw), np.uint32)
        if n:
            vals_np[:, 0] = keys_np[:, -1]
            vals_np[:, -1] = seq_np
    vals_np = np.asarray(vals_np, np.uint32)
    if sort and n:
        order = K.sort_indices_np(keys_np, seq_np)
        keys_np, vals_np = keys_np[order], vals_np[order]
        seq_np, tomb_np = seq_np[order], tomb_np[order]
        # runs must have unique keys: keep newest per key
        keep = np.ones(n, bool)
        keep[1:] = np.any(keys_np[1:] != keys_np[:-1], axis=-1)
        keys_np, vals_np = keys_np[keep], vals_np[keep]
        seq_np, tomb_np = seq_np[keep], tomb_np[keep]
    return Run(
        keys=jnp.asarray(keys_np),
        vals=jnp.asarray(vals_np),
        seq=jnp.asarray(seq_np),
        tomb=jnp.asarray(tomb_np),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RunSet:
    """R runs stacked into padded (R, Nmax, ...) arrays for vector gathers."""

    keys: jnp.ndarray  # (R, Nmax, KW) uint32, padded with +inf sentinel
    vals: jnp.ndarray  # (R, Nmax, VW) uint32
    seq: jnp.ndarray  # (R, Nmax) uint32
    tomb: jnp.ndarray  # (R, Nmax) bool
    lens: jnp.ndarray  # (R,) int32 true lengths

    @property
    def r(self) -> int:
        return self.keys.shape[0]

    @property
    def nmax(self) -> int:
        return self.keys.shape[1]

    @property
    def kw(self) -> int:
        return self.keys.shape[2]

    @property
    def vw(self) -> int:
        return self.vals.shape[2]

    def total(self) -> int:
        return int(np.sum(np.asarray(self.lens)))

    def gather(self, run_idx: jnp.ndarray, pos: jnp.ndarray):
        """Fetch (keys, vals, seq, tomb) at (run, pos); any batch shape."""
        run_idx = jnp.clip(run_idx, 0, self.r - 1)
        pos = jnp.clip(pos, 0, self.nmax - 1)
        return (
            self.keys[run_idx, pos],
            self.vals[run_idx, pos],
            self.seq[run_idx, pos],
            self.tomb[run_idx, pos],
        )


def merge_ranges_np(
    los: np.ndarray, his: np.ndarray, gap: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized [lo, hi) range coalescing: sort, drop empties, fuse
    overlaps and gaps of at most ``gap`` rows. The planning step before
    a batched fetch — each merged range becomes one contiguous read, so
    a query batch touching interleaved windows never fetches a row (or
    the block containing it) twice. Returns (mlos, mhis) arrays."""
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    live = his > los
    los, his = los[live], his[live]
    if len(los) == 0:
        return los, his
    order = np.argsort(los, kind="stable")
    los, his = los[order], his[order]
    hmax = np.maximum.accumulate(his)
    head = np.empty(len(los), bool)
    head[0] = True
    head[1:] = los[1:] > hmax[:-1] + gap
    starts = np.flatnonzero(head)
    return los[starts], np.maximum.reduceat(his, starts)


def merge_ranges(
    ranges: Sequence[tuple[int, int]], gap: int = 0
) -> list[tuple[int, int]]:
    """List-of-tuples convenience wrapper around :func:`merge_ranges_np`."""
    if not ranges:
        return []
    arr = np.asarray(ranges, np.int64).reshape(-1, 2)
    mlo, mhi = merge_ranges_np(arr[:, 0], arr[:, 1], gap=gap)
    return list(zip(mlo.tolist(), mhi.tolist()))


def ranges_to_rows(los: np.ndarray, his: np.ndarray) -> np.ndarray:
    """Expand disjoint sorted [lo, hi) ranges into one flat ascending row
    array — the vectorized equivalent of concatenating per-range
    ``np.arange`` calls."""
    los = np.asarray(los, np.int64)
    his = np.asarray(his, np.int64)
    lens = his - los
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    start_of = np.repeat(np.cumsum(lens) - lens, lens)
    return np.arange(total, dtype=np.int64) - start_of + np.repeat(los, lens)


@dataclasses.dataclass
class RowWindow:
    """Host rows of one run covering a coalesced set of row ranges.

    The cold-scan materialization primitive: instead of loading whole
    tables, a scan names the row ranges its window emits — a scalar scan
    one contiguous range per run, a query batch many interleaved ones —
    ``from_ranges``/``from_scattered`` fuse them (``merge_ranges``) and
    fetch each merged range once, and :meth:`gather` then answers any
    (absolute row) subset with a vectorized lookup. ``keys`` are stored
    unpacked (u64) since scan callers compare/emit u64 keys.
    """

    rows: np.ndarray  # (M,) int64 absolute rows, sorted ascending
    keys: np.ndarray  # (M,) uint64
    vals: np.ndarray  # (M, VW) uint32
    tomb: np.ndarray  # (M,) bool

    @classmethod
    def empty(cls, vw: int = 1) -> "RowWindow":
        """A window covering no rows (``gather`` must not be called)."""
        return cls(
            rows=np.zeros(0, np.int64),
            keys=np.zeros(0, np.uint64),
            vals=np.zeros((0, vw), np.uint32),
            tomb=np.zeros(0, bool),
        )

    @classmethod
    def from_ranges(cls, ranges, fetch_rows, gap: int = 0) -> "RowWindow":
        """``fetch_rows(section, lo, hi)`` pulls rows of one section."""
        merged = merge_ranges(ranges, gap=gap)
        if not merged:
            return cls.empty()
        rows, keys, vals, tomb = [], [], [], []
        for lo, hi in merged:
            rows.append(np.arange(lo, hi, dtype=np.int64))
            keys.append(K.unpack_u64(fetch_rows("keys", lo, hi)))
            vals.append(fetch_rows("vals", lo, hi))
            tomb.append(fetch_rows("tomb", lo, hi))
        return cls(
            rows=np.concatenate(rows),
            keys=np.concatenate(keys),
            vals=np.concatenate(vals),
            tomb=np.concatenate(tomb),
        )

    @classmethod
    def from_scattered(cls, ranges, fetch_scattered, gap: int = 0
                       ) -> "RowWindow":
        """Like :meth:`from_ranges` but with one scattered fetch per
        section for the whole merged range set —
        ``fetch_scattered(section, rows)`` pulls arbitrary rows with
        block-level dedupe (``SSTableReader.section_rows_scattered``).
        The batch-path constructor: three fetches total instead of three
        per merged range."""
        merged = merge_ranges(ranges, gap=gap)
        if not merged:
            return cls.empty()
        arr = np.asarray(merged, np.int64)
        rows = ranges_to_rows(arr[:, 0], arr[:, 1])
        return cls(
            rows=rows,
            keys=K.unpack_u64(fetch_scattered("keys", rows)),
            vals=fetch_scattered("vals", rows),
            tomb=fetch_scattered("tomb", rows),
        )

    def gather(self, want: np.ndarray):
        """(keys u64, vals, tomb) at absolute rows ``want`` (all of which
        must lie inside the fetched ranges)."""
        idx = np.searchsorted(self.rows, np.asarray(want, np.int64))
        return self.keys[idx], self.vals[idx], self.tomb[idx]


def stack_runs(runs: Sequence[Run]) -> RunSet:
    assert len(runs) >= 1
    kw, vw = runs[0].kw, runs[0].vw
    nmax = max(1, max(r.n for r in runs))
    r = len(runs)
    keys = np.full((r, nmax, kw), K.UINT32_MAX, np.uint32)
    vals = np.zeros((r, nmax, vw), np.uint32)
    seq = np.zeros((r, nmax), np.uint32)
    tomb = np.zeros((r, nmax), bool)
    lens = np.zeros((r,), np.int32)
    for i, run in enumerate(runs):
        n = run.n
        lens[i] = n
        if n:
            keys[i, :n] = np.asarray(run.keys)
            vals[i, :n] = np.asarray(run.vals)
            seq[i, :n] = np.asarray(run.seq)
            tomb[i, :n] = np.asarray(run.tomb)
    return RunSet(
        keys=jnp.asarray(keys),
        vals=jnp.asarray(vals),
        seq=jnp.asarray(seq),
        tomb=jnp.asarray(tomb),
        lens=jnp.asarray(lens),
    )

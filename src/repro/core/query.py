"""Batched REMIX query engine — pure-JAX reference implementation.

All operations are vectorized over a query batch (Q,). The "iterator" of the
paper becomes an integer *view position*: because the sorted view is
persisted, any position can be decoded to (run, in-run index) with the
group's cursor offsets + selector occurrence counts, so `next` is position+1
— comparison-free, exactly the paper's claim, and gather-friendly on TPU.
This is what makes :class:`repro.db.cursor.RemixCursor` cheap: `seek` runs
once, the position is plain host state, and every later window is a pure
:func:`gather_view` decode (`peek`/`next`/`skip` are position arithmetic —
no key comparison ever re-runs).

Two in-group search modes (paper §3.2 / Fig 11 "full" vs "partial"):
  - ``vector``: decode all D slots, compare in parallel (VPU-native; on TPU
    this replaces the paper's SIMD-assisted *linear* scan and is the fast
    default — a deliberate hardware adaptation);
  - ``binary``: sequential log2(D) probes, each decoding one slot via
    occurrence counting (the paper's CPU-oriented full binary search).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import keys as K
from repro.core.remix import Remix
from repro.core.runs import RunSet
from repro.core.view import NEWEST_BIT, PLACEHOLDER


def decode_groups(remix: Remix, runset: RunSet, g: jnp.ndarray):
    """Decode whole groups. ``g``: any int32 shape (clamped to valid range).

    Returns dict of per-slot arrays with shape g.shape + (D,):
      runid, absidx, newest, pad, keys (.. + (KW,)).
    """
    d, r = remix.d, remix.r
    g = jnp.clip(g, 0, remix.g - 1)
    sels = remix.selectors.reshape(remix.g, d)[g].astype(jnp.int32)  # (..,D)
    pad = sels == PLACEHOLDER
    newest = (sels & NEWEST_BIT) != 0
    runid = jnp.where(pad, 0, sels & 0x7F)
    onehot = (runid[..., None] == jnp.arange(r, dtype=jnp.int32)) & ~pad[..., None]
    onehot = onehot.astype(jnp.int32)  # (.., D, R)
    occ = jnp.cumsum(onehot, axis=-2) - onehot  # exclusive occurrence count
    occ = jnp.sum(occ * onehot, axis=-1)  # (.., D) own-run occurrence
    base = jnp.take_along_axis(remix.cursors[g], runid, axis=-1)  # (.., D)
    absidx = base + occ
    keys, vals, seq, tomb = runset.gather(runid, absidx)
    keys = jnp.where(pad[..., None], K.UINT32_MAX, keys)
    return dict(
        runid=runid, absidx=absidx, newest=newest & ~pad, pad=pad,
        keys=keys, vals=vals, seq=seq, tomb=tomb & ~pad,
    )


def _ingroup_vector(remix, runset, g, queries):
    """First slot in group g with key >= query, all-D parallel compare."""
    dec = decode_groups(remix, runset, g)  # (Q, D, ..)
    ge = ~K.key_lt(dec["keys"], queries[:, None, :])  # (Q, D)
    s = jnp.argmax(ge, axis=1).astype(jnp.int32)
    s = jnp.where(jnp.any(ge, axis=1), s, remix.d)
    # landing on a placeholder means the true lower bound is the next group
    is_pad = jnp.take_along_axis(
        dec["pad"], jnp.clip(s, 0, remix.d - 1)[:, None], axis=1
    )[:, 0]
    s = jnp.where((s < remix.d) & is_pad, remix.d, s)
    return s


def _decode_one_slot(
    remix: Remix, runset: RunSet, g: jnp.ndarray, j: jnp.ndarray, full=False
):
    """Decode slot j of group g via §3.2 occurrence counting. g,j: (Q,)."""
    d = remix.d
    g = jnp.clip(g, 0, remix.g - 1)
    sels = remix.selectors.reshape(remix.g, d)[g].astype(jnp.int32)  # (Q,D)
    pad = sels == PLACEHOLDER
    sel_j = jnp.take_along_axis(sels, j[:, None], axis=1)[:, 0]
    pad_j = sel_j == PLACEHOLDER
    run_j = jnp.where(pad_j, 0, sel_j & 0x7F)
    before = jnp.arange(d)[None, :] < j[:, None]
    occ = jnp.sum(
        ((sels & 0x7F) == run_j[:, None]) & ~pad & before, axis=1
    ).astype(jnp.int32)
    base = jnp.take_along_axis(remix.cursors[g], run_j[:, None], axis=1)[:, 0]
    keys, vals, seq, tomb = runset.gather(run_j, base + occ)
    keys = jnp.where(pad_j[:, None], K.UINT32_MAX, keys)
    if full:
        newest = ((sel_j & NEWEST_BIT) != 0) & ~pad_j
        return keys, vals, newest, tomb & ~pad_j, pad_j
    return keys, pad_j


def _ingroup_binary(remix, runset, g, queries):
    """Paper-faithful in-group binary search (log2 D sequential probes)."""
    d = remix.d
    q = queries.shape[0]
    lo = jnp.zeros((q,), jnp.int32)
    hi = jnp.full((q,), d, jnp.int32)
    steps = max(1, d.bit_length())

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) >> 1
        kmid, _ = _decode_one_slot(remix, runset, g, jnp.clip(mid, 0, d - 1))
        go_right = K.key_lt(kmid, queries)
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    # placeholder landing → next group
    _, pad_j = _decode_one_slot(remix, runset, g, jnp.clip(lo, 0, d - 1))
    return jnp.where((lo < d) & pad_j, d, lo)


@partial(jax.jit, static_argnames=("ingroup",))
def seek(remix: Remix, runset: RunSet, queries: jnp.ndarray, ingroup: str = "vector"):
    """Lower-bound view positions for ``queries`` (Q, KW) → (Q,) int32.

    One binary search on the anchors + one in-group search — the paper's
    seek. Returned positions may be ``n_slots`` (end) or point at the head
    of the next group when a group's keys are all smaller.
    """
    queries = jnp.asarray(queries, jnp.uint32)
    g = K.upper_bound(remix.anchors, queries) - 1
    g = jnp.clip(g, 0, remix.g - 1)
    if ingroup == "vector":
        s = _ingroup_vector(remix, runset, g, queries)
    elif ingroup == "binary":
        s = _ingroup_binary(remix, runset, g, queries)
    else:
        raise ValueError(f"unknown ingroup mode {ingroup!r}")
    return jnp.minimum(g * remix.d + s, remix.n_slots)


@partial(jax.jit, static_argnames=("width", "ingroup", "with_vals"))
def scan(
    remix: Remix,
    runset: RunSet,
    queries: jnp.ndarray,
    width: int,
    ingroup: str = "vector",
    with_vals: bool = True,
):
    """Seek + retrieve ``width`` consecutive view slots per query.

    Returns (keys (Q,W,KW), vals (Q,W,VW), valid (Q,W), pos (Q,)). ``valid``
    masks placeholders, old versions, tombstones and end-of-view; the next
    operation itself performs **no key comparisons** — it is a pure decode
    of the persisted selectors (paper §3.3).

    ``with_vals=False`` returns None for vals — callers that only need
    the key stream (e.g. ``scan_batch``'s (keys, valid) shape) drop the
    value gather entirely (XLA dead-code-eliminates it).
    """
    pos = seek(remix, runset, queries, ingroup=ingroup)
    keys, vals, valid = gather_view(remix, runset, pos, width)
    return keys, (vals if with_vals else None), valid, pos


@partial(jax.jit, static_argnames=("width",))
def gather_view(remix: Remix, runset: RunSet, pos: jnp.ndarray, width: int):
    """Decode ``width`` view slots starting at each ``pos`` (comparison-free).

    The cursor window primitive: ``pos`` may come from :func:`seek` *or*
    from a previous window's ``pos + width`` — positions are stable host
    integers, so streaming readers (``db.cursor()``) chain windows
    without ever re-seeking. Slots past ``n_slots`` (or in padded
    groups) simply decode as invalid."""
    d = remix.d
    q = pos.shape[0]
    ng = (width + d - 1) // d + 1
    g0 = jnp.clip(pos // d, 0, remix.g - 1)
    gs = g0[:, None] + jnp.arange(ng, dtype=jnp.int32)[None, :]  # (Q, NG)
    dec = decode_groups(remix, runset, gs)  # (Q, NG, D, ..)

    def flat(x):
        return x.reshape((q, ng * d) + x.shape[3:])

    off = pos - g0 * d  # 0 <= off <= D (off==D when pos is next-group head)

    def slice_one(x, o):
        return jax.lax.dynamic_slice_in_dim(x, o, width, axis=0)

    take = lambda x: jax.vmap(slice_one)(flat(x), off)
    keys, vals = take(dec["keys"]), take(dec["vals"])
    newest, pad, tomb = take(dec["newest"]), take(dec["pad"]), take(dec["tomb"])
    gslot = pos[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    in_view = gslot < jnp.minimum(remix.n_slots, (g0 + ng) * d)[..., None]
    valid = newest & ~pad & ~tomb & in_view
    return keys, vals, valid


@partial(jax.jit, static_argnames=("ingroup",))
def get(remix: Remix, runset: RunSet, queries: jnp.ndarray, ingroup: str = "vector"):
    """Point query: seek + single-slot decode (no bloom filters, paper §4).

    Returns (found (Q,), vals (Q,VW)).
    """
    queries = jnp.asarray(queries, jnp.uint32)
    pos = seek(remix, runset, queries, ingroup=ingroup)
    d = remix.d
    g, j = pos // d, pos % d
    keys, vals, newest, tomb, pad_j = _decode_one_slot(
        remix, runset, g, j, full=True
    )
    found = (
        (pos < remix.n_slots) & newest & ~tomb & K.key_eq(keys, queries)
    )
    return found, vals

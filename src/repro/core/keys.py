"""Multiword fixed-width keys.

Keys are lexicographically-ordered vectors of ``KW`` uint32 words, word 0
most significant. The default ``KW=2`` gives a 64-bit keyspace, matching the
paper's 16-byte hex-encoded 64-bit integer keys. The all-ones key is reserved
as the +inf sentinel used for padding (queries must not use it).

All comparison helpers are vectorized over arbitrary leading batch dims and
usable inside jit / Pallas (no data-dependent Python control flow).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

KW = 2  # default number of uint32 words per key (64-bit keys)

UINT32_MAX = np.uint32(0xFFFFFFFF)


def max_key(kw: int = KW) -> jnp.ndarray:
    """The +inf sentinel key (all words 0xFFFFFFFF)."""
    return jnp.full((kw,), UINT32_MAX, dtype=jnp.uint32)


def pack_u64(x) -> np.ndarray:
    """Pack uint64 scalars/arrays into (..., 2) uint32 big-word-first keys."""
    x = np.asarray(x, dtype=np.uint64)
    hi = (x >> np.uint64(32)).astype(np.uint32)
    lo = (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return np.stack([hi, lo], axis=-1)


def unpack_u64(k) -> np.ndarray:
    """Inverse of :func:`pack_u64` (for tests / host-side code)."""
    k = np.asarray(k)
    return (k[..., 0].astype(np.uint64) << np.uint64(32)) | k[..., 1].astype(
        np.uint64
    )


def key_lt(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Lexicographic a < b over the last axis. Broadcasts leading dims."""
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    kw = a.shape[-1]
    lt = a < b
    eq = a == b
    out = lt[..., 0]
    carry = eq[..., 0]
    for w in range(1, kw):
        out = out | (carry & lt[..., w])
        carry = carry & eq[..., w]
    return out


def key_eq(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.all(jnp.asarray(a, jnp.uint32) == jnp.asarray(b, jnp.uint32), axis=-1)


def key_le(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return key_lt(a, b) | key_eq(a, b)


def _bsearch(keys: jnp.ndarray, queries: jnp.ndarray, pred) -> jnp.ndarray:
    """Generic vectorized binary search.

    ``keys``: (N, KW) sorted ascending. ``queries``: (Q, KW).
    ``pred(kmid, q) -> bool``: True means "go right" (lo = mid + 1).
    Returns (Q,) int32 insertion points in [0, N].
    """
    n = keys.shape[0]
    q = queries.shape[0]
    lo = jnp.zeros((q,), jnp.int32)
    hi = jnp.full((q,), n, jnp.int32)
    steps = max(1, int(math.ceil(math.log2(n + 1))) + 1)

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) >> 1
        kmid = jnp.take(keys, jnp.clip(mid, 0, n - 1), axis=0)
        go_right = pred(kmid, queries)
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def lower_bound(keys: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """First index i with keys[i] >= query. keys (N,KW) sorted, queries (Q,KW)."""
    return _bsearch(keys, queries, lambda k, q: key_lt(k, q))


def upper_bound(keys: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """First index i with keys[i] > query."""
    return _bsearch(keys, queries, lambda k, q: key_le(k, q))


def sort_indices_np(keys: np.ndarray, seq: np.ndarray | None = None) -> np.ndarray:
    """Host-side stable ordering by (key asc, seq desc). keys (N,KW) uint32."""
    keys = np.asarray(keys, np.uint32)
    cols = []
    if seq is not None:
        seq = np.asarray(seq, np.uint64)
        cols.append(np.uint64(0xFFFFFFFFFFFFFFFF) - seq)  # seq desc
    for w in range(keys.shape[-1] - 1, -1, -1):
        cols.append(keys[:, w])
    return np.lexsort(cols)  # last col = primary = word 0

"""REMIX core: multiword keys, sorted runs, the REMIX index and query engine.

Public API:
  - :func:`repro.core.remix.build_remix` — build a Remix over runs
  - :mod:`repro.core.query` — batched seek / scan / get (paper §3)
  - :mod:`repro.core.merge_iter` — merging-iterator baseline (§2)
  - :mod:`repro.core.bloom` — bloom-filter baseline
"""
from repro.core import keys, bloom, merge_iter, query, runs, view  # noqa: F401
from repro.core.remix import Remix, build_remix  # noqa: F401
from repro.core.runs import Run, RunSet, make_run, stack_runs  # noqa: F401

"""Baseline: LevelDB-style merging iterator over R sorted runs.

A seek performs one binary search *per run* (R × log2 N comparisons); every
`next` re-compares the keys under all cursors to find the global minimum
(the min-heap of the paper, vectorized here as an R-way argmin — the same
comparison count up to log factors, which we report analytically).

User-level iteration semantics match LevelDB's DBIter: newest version per
key wins (max seqno), older duplicates and tombstoned keys are skipped.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import keys as K
from repro.core.runs import RunSet


@jax.jit
def seek_cursors(runset: RunSet, queries: jnp.ndarray) -> jnp.ndarray:
    """Per-run lower bound for each query: (Q, R) cursors."""
    queries = jnp.asarray(queries, jnp.uint32)

    def one_run(run_keys):
        return K.lower_bound(run_keys, queries)

    return jax.vmap(one_run, in_axes=0, out_axes=1)(runset.keys)


def _min_run(keys_rt: jnp.ndarray, seq_rt: jnp.ndarray) -> jnp.ndarray:
    """Index of the run holding the smallest (key, seq desc) entry.

    keys_rt: (Q, R, KW); seq_rt: (Q, R). The vectorized min-heap pop.
    """
    r = keys_rt.shape[1]
    best = jnp.zeros(keys_rt.shape[0], jnp.int32)
    for i in range(1, r):  # unrolled tournament, R is small
        bk = jnp.take_along_axis(keys_rt, best[:, None, None], axis=1)[:, 0]
        bs = jnp.take_along_axis(seq_rt, best[:, None], axis=1)[:, 0]
        ck, cs = keys_rt[:, i], seq_rt[:, i]
        better = K.key_lt(ck, bk) | (K.key_eq(ck, bk) & (cs > bs))
        best = jnp.where(better, jnp.int32(i), best)
    return best


@partial(jax.jit, static_argnames=("width",))
def merge_scan(runset: RunSet, queries: jnp.ndarray, width: int):
    """Seek + next×width with the merging iterator.

    Returns (keys (Q,W,KW), vals (Q,W,VW), valid (Q,W)). ``valid`` is False
    for duplicate older versions / tombstones / end-of-data slots (matching
    :func:`repro.core.query.scan` semantics so results are comparable).
    """
    queries = jnp.asarray(queries, jnp.uint32)
    q = queries.shape[0]
    cursors = seek_cursors(runset, queries)  # (Q, R)
    lens = runset.lens[None, :]

    def step(state, _):
        cursors, last_key, have_last = state
        kk, vv, ss, tt = runset.gather(
            jnp.arange(runset.r, dtype=jnp.int32)[None, :].repeat(q, 0), cursors
        )  # (Q, R, ..)
        exhausted = cursors >= lens
        kk = jnp.where(exhausted[..., None], K.UINT32_MAX, kk)
        sel = _min_run(kk, jnp.where(exhausted, 0, ss))  # (Q,)
        key = jnp.take_along_axis(kk, sel[:, None, None], axis=1)[:, 0]
        val = jnp.take_along_axis(vv, sel[:, None, None], axis=1)[:, 0]
        tomb = jnp.take_along_axis(tt, sel[:, None], axis=1)[:, 0]
        at_end = jnp.all(exhausted, axis=1)
        dup = have_last & K.key_eq(key, last_key)
        valid = ~at_end & ~dup & ~tomb
        cursors = cursors + (
            jnp.arange(runset.r, dtype=jnp.int32)[None, :] == sel[:, None]
        ).astype(jnp.int32) * (~at_end[:, None]).astype(jnp.int32)
        return (cursors, key, ~at_end), (key, val, valid)

    init = (cursors, jnp.zeros_like(queries), jnp.zeros((q,), bool))
    _, (keys, vals, valid) = jax.lax.scan(step, init, None, length=width)
    return (
        jnp.moveaxis(keys, 0, 1),
        jnp.moveaxis(vals, 0, 1),
        jnp.moveaxis(valid, 0, 1),
    )


@jax.jit
def merge_get(runset: RunSet, queries: jnp.ndarray):
    """Point query via per-run binary searches + newest-version pick."""
    queries = jnp.asarray(queries, jnp.uint32)
    q = queries.shape[0]
    cursors = seek_cursors(runset, queries)  # (Q,R)
    kk, vv, ss, tt = runset.gather(
        jnp.arange(runset.r, dtype=jnp.int32)[None, :].repeat(q, 0), cursors
    )
    hit = K.key_eq(kk, queries[:, None, :]) & (cursors < runset.lens[None, :])
    ss = jnp.where(hit, ss, 0)
    maxseq = jnp.max(ss, axis=1, keepdims=True)
    best = jnp.argmax(hit & (ss == maxseq), axis=1)
    found = jnp.any(hit, axis=1)
    val = jnp.take_along_axis(vv, best[:, None, None], axis=1)[:, 0]
    tomb = jnp.take_along_axis(tt, best[:, None], axis=1)[:, 0]
    return found & ~tomb, val


def seek_comparison_cost(r: int, n_per_run: int) -> float:
    """Analytic comparison count for a merging-iterator seek (paper §3.3)."""
    import math

    return r * max(1.0, math.log2(max(2, n_per_run)))

"""Pure-jnp oracles for the Pallas kernels (the ref implementations)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import keys as K
from repro.core.view import NEWEST_BIT, PLACEHOLDER


def selector_decode_ref(selectors: jnp.ndarray, cursors: jnp.ndarray, *, r: int):
    """Oracle for kernels.selector_decode: (Q,D)+(Q,R) → runid/absidx/newest/pad."""
    sel = selectors.astype(jnp.int32)
    pad = sel == PLACEHOLDER
    newest = ((sel & NEWEST_BIT) != 0) & ~pad
    runid = jnp.where(pad, 0, sel & 0x7F)
    onehot = (runid[..., None] == jnp.arange(r)) & ~pad[..., None]
    onehot = onehot.astype(jnp.int32)
    occ = jnp.cumsum(onehot, axis=-2) - onehot
    occ = jnp.sum(occ * onehot, axis=-1)
    base = jnp.take_along_axis(cursors.astype(jnp.int32), runid, axis=-1)
    return runid, base + occ, newest, pad


def anchor_search_ref(anchors: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.anchor_search: target group = upper_bound - 1, >= 0."""
    return jnp.maximum(K.upper_bound(anchors, queries) - 1, 0)

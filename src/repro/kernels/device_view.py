"""Device-resident query views: persistent HBM buffers for promoted
partitions plus the fused batched execution driver (ROADMAP item: the
"fast as the hardware allows" read lane).

A :class:`DeviceView` holds one promoted partition's REMIX structural
arrays (anchors, selector stream, cursor offsets) and its stacked run
sections as device buffers, in one of two residency tiers:

- ``full``  — keys, values, tombstones and TTL expiry words all resident:
  a batched get/scan is one jitted Pallas composition (seek → selector
  decode → run/position resolve → window emission → key/value gather)
  with **exactly one host↔device sync** — the final result fetch.
- ``index`` — everything but the value sections resident (the KV-Tandem
  split: device index plane / host block-storage plane). The device
  resolves each batch slice's row windows while the host gathers the
  *previous* slice's value granules through the ``BlockCache`` — a
  double-buffered pipeline riding JAX's async dispatch, extending the
  Fig 10 group-ahead prefetch across the host/device boundary.

Liveness is evaluated at query time on device: uploaded tombstone words
carry real tombstones plus excised-span coverage (structural, can never
revive), and per-row TTL expiry words are compared against a traced
``now`` — bit-for-bit the host path's `_build_dead` set at the same
instant, with no rebuild when the clock passes an expiry.

The :class:`DeviceViewManager` owns an HBM byte budget: LRU eviction on
upload pressure, and release-time eviction tied to the VersionSet pin
lifecycle (``retain`` drops views whose partition left every live
Version). Views hold a strong reference to their partition, so a view
can never alias a recycled ``id()``.

Host sync points are counted in the module-level ``SYNCS`` counter —
``benchmarks/kernels_bench.py`` asserts the fused batch-256 get pipeline
pays exactly one per batch.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.kernels import ops

# host↔device sync points (device→host result fetches); module-level so
# benchmarks/tests can assert the "one sync per batch" contract
SYNCS = 0


def _fetch(*arrays):
    """The single blocking device→host transfer of a fused batch."""
    global SYNCS
    SYNCS += 1
    return jax.device_get(arrays)


def _pow2pad(n: int) -> int:
    b = 8
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class DeviceView:
    """One promoted partition's resident device buffers."""

    partition: object  # strong ref: pins identity until eviction
    tier: str  # "full" | "index"
    remix: object  # padded Remix (device)
    runset: object  # padded RunSet (device; dummy 1-word vals on "index")
    exp: jnp.ndarray  # (R, Nmax) uint32 TTL expiries (device)
    nbytes: int  # accounted HBM bytes
    vw: int  # real value width (host tables for "index")

    @property
    def tables(self):
        return self.partition.tables


def _view_nbytes(remix, runset, exp) -> int:
    arrs = (
        remix.anchors, remix.cursors, remix.selectors,
        runset.keys, runset.vals, runset.seq, runset.tomb, runset.lens,
        exp,
    )
    return int(sum(int(a.size) * a.dtype.itemsize for a in arrs))


class DeviceViewManager:
    """HBM residency manager for promoted partitions' device views.

    ``budget_bytes`` bounds the resident set (LRU on upload pressure);
    ``retain(live_ids)`` is the VersionSet release hook — views whose
    partition is in no live Version are dropped with their pins.
    A partition that fits neither tier counts ``device_fallback_total``
    and the caller answers from the legacy path instead.
    """

    def __init__(
        self,
        budget_bytes: int,
        slice_width: int = 64,
        registry=None,
        events=None,
        interpret: bool | None = None,
    ):
        self.budget_bytes = int(budget_bytes)
        self.slice_width = max(1, int(slice_width))
        self._interpret = interpret  # None: kernels auto-pick off-TPU
        self._views: "OrderedDict[int, DeviceView]" = OrderedDict()
        self._resident = 0
        if registry is None:
            from repro.obs.metrics import MetricsRegistry

            registry = MetricsRegistry(enabled=False)
        if events is None:
            from repro.obs.events import NULL_EVENTS

            events = NULL_EVENTS
        self.events = events
        self._c_batches = registry.counter("device_batches")
        self._c_rows = registry.counter("device_rows_gathered")
        self._c_fallback = registry.counter("device_fallback_total")
        registry.gauge("hbm_resident_bytes", fn=lambda: self._resident)

    # ---- residency ----
    @property
    def resident_bytes(self) -> int:
        return self._resident

    def __len__(self) -> int:
        return len(self._views)

    def view_for(self, p) -> DeviceView | None:
        """Resident view for partition ``p`` — uploading on first use —
        or None when no tier fits the budget (caller falls back)."""
        v = self._views.get(id(p))
        if v is not None:
            self._views.move_to_end(id(p))
            return v
        est_full = p.device_view_bytes(with_vals=True)
        if est_full <= self.budget_bytes:
            tier = "full"
        elif (
            p.device_view_bytes(with_vals=False) <= self.budget_bytes
            and p.tables
            and all(t.path is not None for t in p.tables)
        ):
            # value sections stay host-side, gathered via the BlockCache
            tier = "index"
        else:
            self._c_fallback.inc()
            return None
        remix, runset, exp = p.device_index(with_vals=tier == "full")
        nbytes = _view_nbytes(remix, runset, exp)
        self._evict_to(self.budget_bytes - nbytes)
        vw = p.tables[0].vw if p.tables else runset.vw
        v = DeviceView(
            partition=p, tier=tier, remix=remix, runset=runset,
            exp=exp, nbytes=nbytes, vw=int(vw),
        )
        self._views[id(p)] = v
        self._resident += nbytes
        self.events.emit(
            "device_upload", lo=int(p.lo), tier=tier, bytes=int(nbytes),
            tables=len(p.tables),
        )
        return v

    def _evict_to(self, target: int, reason: str = "budget") -> None:
        while self._views and self._resident > max(0, target):
            _, v = self._views.popitem(last=False)  # LRU
            self._drop(v, reason)

    def _drop(self, v: DeviceView, reason: str) -> None:
        self._resident -= v.nbytes
        self.events.emit(
            "device_evict", lo=int(v.partition.lo), tier=v.tier,
            bytes=int(v.nbytes), reason=reason,
        )

    def retain(self, live_ids: set) -> None:
        """VersionSet release hook: drop views whose partition left every
        live Version (the device-side leg of the pin lifecycle)."""
        for key in [k for k in self._views if k not in live_ids]:
            self._drop(self._views.pop(key), "version_release")

    def clear(self) -> None:
        for key in list(self._views):
            self._drop(self._views.pop(key), "clear")

    # ---- fused batched execution ----
    def get_batch(self, dv: DeviceView, keys_u64, now) -> tuple:
        """Batched point gets. Full tier: one fused device composition +
        one result fetch. Index tier: the same single round trip returns
        (found, run, row) and values come from the host block cache."""
        keys_u64 = np.asarray(keys_u64, np.uint64)
        q = len(keys_u64)
        pad = _pow2pad(q)
        kq = np.pad(keys_u64, (0, pad - q))
        qk = jnp.asarray(CK.pack_u64(kq))
        nw = jnp.uint32(int(now))
        fd, vd, rid_d, row_d = ops.get_live(
            dv.remix, dv.runset, dv.exp, qk, nw, interpret=self._interpret
        )
        self._c_batches.inc()
        if dv.tier == "full":
            found, vals = _fetch(fd, vd)  # THE one host sync
            found, vals = found[:q], vals[:q]
            self._c_rows.inc(int(found.sum()))
            return found, vals
        found, rid, row = _fetch(fd, rid_d, row_d)
        found, rid, row = found[:q], rid[:q], row[:q]
        vals = np.zeros((q, dv.vw), np.uint32)
        for r in np.unique(rid[found]):
            m = found & (rid == r)
            vals[m] = dv.tables[r].rows_scattered("vals", row[m])
        self._c_rows.inc(int(found.sum()))
        return found, vals

    def scan_windows(
        self, dv: DeviceView, starts_u64, width: int, now,
        with_vals: bool = True,
    ) -> list:
        """Batched scan-window resolution: per query ``(keys (M,) u64,
        vals (M, VW) | None)`` — live entries of a ``width``-slot view
        window, same semantics as the host `gather_view` path."""
        starts_u64 = np.asarray(starts_u64, np.uint64)
        q = len(starts_u64)
        nw = jnp.uint32(int(now))
        if dv.tier == "full" or not with_vals:
            pad = _pow2pad(q)
            sq = np.pad(starts_u64, (0, pad - q))
            qk = jnp.asarray(CK.pack_u64(sq))
            kd, vd, md, _, _, _ = ops.scan_live(
                dv.remix, dv.runset, dv.exp, qk, nw, width=width,
                interpret=self._interpret,
            )
            self._c_batches.inc()
            if with_vals:
                keys, vals, valid = _fetch(kd, vd, md)
            else:
                keys, valid = _fetch(kd, md)
                vals = None
            out = []
            rows = 0
            for i in range(q):
                m = valid[i]
                kk = CK.unpack_u64(keys[i][m])
                rows += len(kk)
                out.append((kk, vals[i][m] if with_vals else None))
            self._c_rows.inc(rows)
            return out
        return self._scan_pipelined(dv, starts_u64, width, nw)

    def _scan_pipelined(self, dv, starts_u64, width, nw) -> list:
        """Index tier: double-buffered batch-sliced pipeline. The device
        resolves row windows for slice i+1 (async dispatch) while the
        host gathers slice i's value granules through the BlockCache."""
        s = self.slice_width
        q = len(starts_u64)
        nsl = -(-q // s)
        padded = np.zeros(nsl * s, np.uint64)
        padded[:q] = starts_u64
        pad = _pow2pad(s)

        def launch(si):
            sq = np.pad(padded[si * s:(si + 1) * s], (0, pad - s))
            qk = jnp.asarray(CK.pack_u64(sq))
            return ops.scan_live(
                dv.remix, dv.runset, dv.exp, qk, nw, width=width,
                interpret=self._interpret,
            )

        out: list = []
        rows = 0
        pending = launch(0)
        for si in range(nsl):
            nxt = launch(si + 1) if si + 1 < nsl else None
            kd, _, md, rid_d, row_d, _ = pending
            keys, valid, rid, row = _fetch(kd, md, rid_d, row_d)
            self._c_batches.inc()
            nq = min(s, q - si * s)
            keys, valid = keys[:nq], valid[:nq]
            rid, row = rid[:nq], row[:nq]
            # slice value gather: group live rows per run, one scattered
            # (granule-deduped) fetch per touched table
            vals = np.zeros((nq, width, dv.vw), np.uint32)
            rid_f, row_f = rid[valid], row[valid]
            gath = np.zeros((len(rid_f), dv.vw), np.uint32)
            for r in np.unique(rid_f):
                m = rid_f == r
                gath[m] = dv.tables[r].rows_scattered("vals", row_f[m])
            vals[valid] = gath
            for i in range(nq):
                m = valid[i]
                kk = CK.unpack_u64(keys[i][m])
                rows += len(kk)
                out.append((kk, vals[i][m]))
            pending = nxt
        self._c_rows.inc(rows)
        return out

"""Pallas TPU kernel: batched anchor search (paper §3.1 step 1).

The CPU paper binary-searches the anchor index per query. Branchy binary
search is hostile to the VPU (data-dependent gathers); the TPU-native
adaptation is *compare-and-count*: the target group of query q is
``(# anchors <= q) - 1``, computed by streaming (BG, KW) anchor tiles from
HBM through VMEM against a resident (BQ, KW) query tile and accumulating
lexicographic compare counts. O(G) work/query but bandwidth-shaped and
branch-free; ops.py composes a two-level (coarse→fine) hierarchy so the
effective work is O(sqrt(G)) per query tile for big indexes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _le_count_kernel(anchors_ref, queries_ref, count_ref, *, kw: int):
    """count[q] += sum_over_tile(anchor <= query)."""
    gi = pl.program_id(1)

    @pl.when(gi == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    a = anchors_ref[...]  # (BG, KW) uint32
    qk = queries_ref[...]  # (BQ, KW) uint32
    # lexicographic a <= q, broadcast (BQ, BG)
    le = jnp.zeros((qk.shape[0], a.shape[0]), jnp.bool_)
    eq = jnp.ones((qk.shape[0], a.shape[0]), jnp.bool_)
    for w in range(kw):
        aw = a[:, w][None, :]
        qw = qk[:, w][:, None]
        le = le | (eq & (aw < qw))
        eq = eq & (aw == qw)
    le = le | eq
    count_ref[...] += jnp.sum(le.astype(jnp.int32), axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_g", "interpret")
)
def anchor_le_count(
    anchors: jnp.ndarray,  # (G, KW) uint32, ascending (+inf padded tail ok)
    queries: jnp.ndarray,  # (Q, KW) uint32
    *,
    block_q: int = 256,
    block_g: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Return (Q,) int32: number of anchors <= query (target group + 1)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    g, kw = anchors.shape
    q = queries.shape[0]
    bq, bg = min(block_q, q), min(block_g, g)
    grid = (pl.cdiv(q, bq), pl.cdiv(g, bg))
    counts = pl.pallas_call(
        functools.partial(_le_count_kernel, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bg, kw), lambda qi, gi: (gi, 0)),
            pl.BlockSpec((bq, kw), lambda qi, gi: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda qi, gi: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.int32),
        interpret=interpret,
    )(anchors, queries)
    return counts[:, 0]


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_g", "fan", "interpret")
)
def anchor_search(
    anchors: jnp.ndarray,
    queries: jnp.ndarray,
    *,
    block_q: int = 256,
    block_g: int = 512,
    fan: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Two-level compare-and-count anchor search → (Q,) target group ids.

    Level 1 counts over every ``fan``-th anchor (the B+-tree-like top level
    of the REMIX file, §4.1); level 2 counts inside the selected span.
    Exact same result as ``upper_bound(anchors, q) - 1`` clamped to >= 0.
    """
    g, kw = anchors.shape
    if g <= fan * 4:  # small index: single level
        cnt = anchor_le_count(
            anchors, queries, block_q=block_q, block_g=block_g,
            interpret=interpret,
        )
        return jnp.maximum(cnt - 1, 0)
    top = anchors[fan - 1 :: fan]  # last anchor of each span
    tcnt = anchor_le_count(
        top, queries, block_q=block_q, block_g=block_g, interpret=interpret
    )  # spans fully <= query
    base = tcnt * fan
    # gather the fine span per query and count inside (XLA gather + kernel)
    raw_idx = base[:, None] + jnp.arange(fan)[None, :]
    in_range = raw_idx < g
    span_idx = jnp.minimum(raw_idx, g - 1)
    spans = anchors[span_idx]  # (Q, fan, KW)
    qx = queries[:, None, :]
    le = jnp.zeros(span_idx.shape, jnp.bool_)
    eq = jnp.ones(span_idx.shape, jnp.bool_)
    for w in range(kw):
        le = le | (eq & (spans[..., w] < qx[..., w]))
        eq = eq & (spans[..., w] == qx[..., w])
    fine = jnp.sum((le | eq) & in_range, axis=1).astype(jnp.int32)
    return jnp.maximum(base + fine - 1, 0)

"""Pallas TPU kernel: in-group run-selector decode (paper §3.2).

The paper counts selector occurrences with SIMD instructions to place run
cursors inside a group. The TPU-native formulation: for a (block, D) tile of
selectors, compute each slot's exclusive occurrence count of its own run via
an unrolled one-hot + prefix-sum on the VPU, then add the group's cursor
offsets to obtain absolute in-run indices.

Block layout: selectors tile (BQ, D) — D is the lane dimension (group sizes
16/32/64 are lane-friendly); R is static and unrolled. All compute is
elementwise/prefix ops in VMEM; no gathers inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.view import NEWEST_BIT, PLACEHOLDER


def _decode_kernel(sel_ref, cur_ref, runid_ref, absidx_ref, flags_ref, *, r: int):
    sel = sel_ref[...].astype(jnp.int32)  # (BQ, D)
    pad = sel == PLACEHOLDER
    newest = (sel & NEWEST_BIT) != 0
    runid = jnp.where(pad, 0, sel & 0x7F)
    occ = jnp.zeros_like(runid)
    base = jnp.zeros_like(runid)
    for rr in range(r):  # R static: unrolled one-hot prefix counting
        hit = ((runid == rr) & ~pad).astype(jnp.int32)
        cnt = jnp.cumsum(hit, axis=1) - hit  # exclusive prefix count
        occ = occ + cnt * hit
        # base uses runid even on placeholder slots (matches ref.py contract)
        base = base + (runid == rr).astype(jnp.int32) * cur_ref[:, rr][:, None]
    runid_ref[...] = runid
    absidx_ref[...] = base + occ
    flags_ref[...] = (
        newest.astype(jnp.int32) | (pad.astype(jnp.int32) << 1)
    )


@functools.partial(jax.jit, static_argnames=("r", "block_q", "interpret"))
def selector_decode(
    selectors: jnp.ndarray,  # (Q, D) uint8/int32 group selector tiles
    cursors: jnp.ndarray,  # (Q, R) int32 cursor offsets at group heads
    *,
    r: int,
    block_q: int = 128,
    interpret: bool | None = None,
):
    """Decode selector tiles → (runid (Q,D), absidx (Q,D), newest, pad)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, d = selectors.shape
    bq = min(block_q, q)
    grid = (pl.cdiv(q, bq),)
    runid, absidx, flags = pl.pallas_call(
        functools.partial(_decode_kernel, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, cursors.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, d), jnp.int32),
            jax.ShapeDtypeStruct((q, d), jnp.int32),
            jax.ShapeDtypeStruct((q, d), jnp.int32),
        ],
        interpret=interpret,
    )(selectors.astype(jnp.int32), cursors.astype(jnp.int32))
    newest = (flags & 1) != 0
    pad = (flags & 2) != 0
    return runid, absidx, newest, pad

"""Jit'd wrappers composing the Pallas kernels into full REMIX operations.

The kernels cover the compute-dense parts (anchor compare-count, selector
occurrence decode); XLA handles the HBM gathers between them (TPU gathers
are XLA's job — fusing them into Pallas would fight the memory system).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import keys as K
from repro.core.remix import Remix
from repro.core.runs import RunSet
from repro.kernels.anchor_search import anchor_search
from repro.kernels.selector_decode import selector_decode


@partial(jax.jit, static_argnames=("interpret",))
def seek(
    remix: Remix, runset: RunSet, queries: jnp.ndarray, interpret: bool | None = None
) -> jnp.ndarray:
    """Kernel-backed lower-bound seek; same contract as core.query.seek."""
    queries = jnp.asarray(queries, jnp.uint32)
    d = remix.d
    g = anchor_search(remix.anchors, queries, interpret=interpret)  # (Q,)
    sels = remix.selectors.reshape(remix.g, d)[g]  # (Q, D)
    runid, absidx, newest, pad = selector_decode(
        sels, remix.cursors[g], r=remix.r, interpret=interpret
    )
    keys, _, _, _ = runset.gather(runid, absidx)
    keys = jnp.where(pad[..., None], K.UINT32_MAX, keys)
    ge = ~K.key_lt(keys, queries[:, None, :])  # (Q, D)
    s = jnp.argmax(ge, axis=1).astype(jnp.int32)
    s = jnp.where(jnp.any(ge, axis=1), s, d)
    is_pad = jnp.take_along_axis(pad, jnp.clip(s, 0, d - 1)[:, None], axis=1)[:, 0]
    s = jnp.where((s < d) & is_pad, d, s)
    return jnp.minimum(g * d + s, remix.n_slots)


@partial(jax.jit, static_argnames=("width", "interpret"))
def gather_view(
    remix: Remix,
    runset: RunSet,
    pos: jnp.ndarray,
    width: int,
    interpret: bool | None = None,
):
    """Kernel-backed comparison-free range retrieval from view positions."""
    d = remix.d
    q = pos.shape[0]
    ng = (width + d - 1) // d + 1
    g0 = jnp.clip(pos // d, 0, remix.g - 1)
    gs = g0[:, None] + jnp.arange(ng, dtype=jnp.int32)[None, :]
    gsc = jnp.clip(gs, 0, remix.g - 1)
    sels = remix.selectors.reshape(remix.g, d)[gsc].reshape(q * ng, d)
    curs = remix.cursors[gsc].reshape(q * ng, remix.r)
    runid, absidx, newest, pad = selector_decode(
        sels, curs, r=remix.r, interpret=interpret
    )
    keys, vals, _, tomb = runset.gather(runid, absidx)
    keys = jnp.where(pad[..., None], K.UINT32_MAX, keys)

    def reshape_q(x):
        return x.reshape((q, ng * d) + x.shape[2:])

    off = pos - g0 * d

    def slice_one(x, o):
        return jax.lax.dynamic_slice_in_dim(x, o, width, axis=0)

    take = lambda x: jax.vmap(slice_one)(reshape_q(x), off)
    keys, vals = take(keys), take(vals)
    newest, pad, tomb = take(newest), take(pad), take(tomb)
    gslot = pos[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = newest & ~pad & ~tomb & (gslot < remix.n_slots)
    return keys, vals, valid


@partial(jax.jit, static_argnames=("width", "interpret"))
def scan(remix, runset, queries, width: int, interpret: bool | None = None):
    pos = seek(remix, runset, queries, interpret=interpret)
    return (*gather_view(remix, runset, pos, width, interpret=interpret), pos)


@partial(jax.jit, static_argnames=("interpret",))
def get(remix, runset, queries, interpret: bool | None = None):
    queries = jnp.asarray(queries, jnp.uint32)
    pos = seek(remix, runset, queries, interpret=interpret)
    keys, vals, valid = gather_view(remix, runset, pos, 1, interpret=interpret)
    found = valid[:, 0] & K.key_eq(keys[:, 0], queries)
    return found, vals[:, 0]


# ---- device-resident live variants (kernels/device_view.py) ----
#
# Same pipeline, but liveness is *not* baked into the runset tombstones:
# per-row TTL expiry words ride along as a (R, Nmax) uint32 array and the
# window applies `tomb | (exp != 0 & exp <= now)` with `now` a traced
# scalar — so a persistent device view never goes stale when the clock
# passes an expiry (the host path rebuilds its runset instead). The
# resolved (run, row) coordinates are returned alongside so the index-only
# residency tier can gather value granules host-side (BlockCache) from the
# same single device round trip.


@partial(jax.jit, static_argnames=("width", "interpret"))
def gather_view_live(
    remix: Remix,
    runset: RunSet,
    exp: jnp.ndarray,  # (R, Nmax) uint32 TTL expiries (0 = none)
    pos: jnp.ndarray,
    now: jnp.ndarray,  # () uint32 traced query-time clock
    width: int,
    interpret: bool | None = None,
):
    """`gather_view` with query-time liveness + (run, row) emission."""
    d = remix.d
    q = pos.shape[0]
    ng = (width + d - 1) // d + 1
    g0 = jnp.clip(pos // d, 0, remix.g - 1)
    gs = g0[:, None] + jnp.arange(ng, dtype=jnp.int32)[None, :]
    gsc = jnp.clip(gs, 0, remix.g - 1)
    sels = remix.selectors.reshape(remix.g, d)[gsc].reshape(q * ng, d)
    curs = remix.cursors[gsc].reshape(q * ng, remix.r)
    runid, absidx, newest, pad = selector_decode(
        sels, curs, r=remix.r, interpret=interpret
    )
    keys, vals, _, tomb = runset.gather(runid, absidx)
    keys = jnp.where(pad[..., None], K.UINT32_MAX, keys)
    # exp gather clips exactly like RunSet.gather so pad slots stay benign
    ex = exp[
        jnp.clip(runid, 0, exp.shape[0] - 1),
        jnp.clip(absidx, 0, exp.shape[1] - 1),
    ]
    dead = tomb | ((ex != 0) & (ex <= now))

    def reshape_q(x):
        return x.reshape((q, ng * d) + x.shape[2:])

    off = pos - g0 * d

    def slice_one(x, o):
        return jax.lax.dynamic_slice_in_dim(x, o, width, axis=0)

    take = lambda x: jax.vmap(slice_one)(reshape_q(x), off)
    keys, vals = take(keys), take(vals)
    newest, pad, dead = take(newest), take(pad), take(dead)
    runid, absidx = take(runid), take(absidx)
    gslot = pos[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = newest & ~pad & ~dead & (gslot < remix.n_slots)
    return keys, vals, valid, runid, absidx


@partial(jax.jit, static_argnames=("width", "interpret"))
def scan_live(
    remix, runset, exp, queries, now, width: int,
    interpret: bool | None = None,
):
    queries = jnp.asarray(queries, jnp.uint32)
    pos = seek(remix, runset, queries, interpret=interpret)
    return (
        *gather_view_live(
            remix, runset, exp, pos, now, width, interpret=interpret
        ),
        pos,
    )


@partial(jax.jit, static_argnames=("interpret",))
def get_live(remix, runset, exp, queries, now, interpret: bool | None = None):
    queries = jnp.asarray(queries, jnp.uint32)
    pos = seek(remix, runset, queries, interpret=interpret)
    keys, vals, valid, runid, absidx = gather_view_live(
        remix, runset, exp, pos, now, 1, interpret=interpret
    )
    found = valid[:, 0] & K.key_eq(keys[:, 0], queries)
    return found, vals[:, 0], runid[:, 0], absidx[:, 0]

"""Pallas TPU kernels for REMIX hot paths, with jnp oracles in ref.py.

  - selector_decode: in-group occurrence decode (paper §3.2 SIMD counting)
  - anchor_search:   batched compare-and-count anchor index search
  - ops:             jit'd wrappers composing kernels into seek/get/scan
  - device_view:     HBM residency manager + fused device-batch driver
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.anchor_search import anchor_le_count, anchor_search  # noqa: F401
from repro.kernels.device_view import DeviceView, DeviceViewManager  # noqa: F401
from repro.kernels.selector_decode import selector_decode  # noqa: F401

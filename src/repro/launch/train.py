"""Production training launcher.

Brings up the mesh, shards params/optimizer with the logical-axis rules,
runs the jitted train step with checkpoint/restart, and implements the
fault-tolerance contract:

  - checkpoint every N steps (atomic; resumable mid-run, `--resume`);
  - deterministic counter-based data pipeline → exact skip-ahead on restart
    and per-shard disjointness (straggler-safe: a re-scheduled host replays
    nothing);
  - elastic restart: restore reshards to the current mesh (the checkpoint
    stores logical axes, not device layouts);
  - optional int8 error-feedback gradient compression on the pod axis
    (--grad-compress) for DCN-dominated multi-pod runs;
  - per-step wall-clock watchdog (--step-timeout) that checkpoints and
    aborts cleanly if a step hangs (straggler mitigation at the job level —
    the scheduler restarts from the last step).

On this CPU container, run with small configs (see examples/train_lm.py for
a friendlier demo); on a real pod, XLA_FLAGS/TPU topology env is picked up
by jax automatically.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model as M
from repro.models.layers import is_param, split_params
from repro.models.sharding import ShardingRules, set_rules
from repro.train import checkpoint as C
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


def shard_tree(tree_vals, tree_axes, rules):
    return jax.tree.map(
        lambda v, ax: jax.device_put(v, rules.named(ax, shape=v.shape)),
        tree_vals, tree_axes,
        is_leaf=lambda x: hasattr(x, "shape") or isinstance(x, tuple),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--step-timeout", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = (
        make_production_mesh(multi_pod=args.multipod)
        if args.production_mesh
        else make_debug_mesh()
    )
    rules = ShardingRules(mesh=mesh)
    set_rules(rules)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.n_params()/1e6:.0f}M params)")

    data_shards = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    data = DataPipeline(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0,
        shard_count=1,  # single-process container; multi-host uses process id
    )
    opt_cfg = OptConfig(total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.microbatches))

    with jax.set_mesh(mesh):
        start = 0
        if args.resume and C.latest_step(args.ckpt) is not None:
            params_tree = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.key(0))
            )
            _, pax = split_params(params_tree)
            pv, opt, extra = C.restore(args.ckpt)
            pv = shard_tree(pv, pax, rules)  # elastic re-shard to this mesh
            start = extra["data"]["step"]
            print(f"resumed at step {start} (resharded to current mesh)")
        else:
            params = M.init_params(cfg, jax.random.key(0))
            pv, pax = split_params(params)
            pv = shard_tree(pv, pax, rules)
            opt = init_opt_state(opt_cfg, pv)

        t_run = time.time()
        for step in range(start, args.steps):
            t0 = time.time()
            batch = data.get_batch(step)
            pv, opt, metrics = step_fn(pv, opt, batch)
            if args.step_timeout and (time.time() - t0) > args.step_timeout:
                print(f"step {step} exceeded {args.step_timeout}s — "
                      "checkpointing and aborting for reschedule")
                C.save(args.ckpt, step, pv, opt,
                       extra=dict(data=data.state(step)))
                raise SystemExit(75)  # EX_TEMPFAIL → scheduler restarts
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{(time.time()-t0)*1e3:.0f} ms/step")
            if step and step % args.ckpt_every == 0:
                C.save(args.ckpt, step, pv, opt,
                       extra=dict(data=data.state(step)))
        C.save(args.ckpt, args.steps, pv, opt,
               extra=dict(data=data.state(args.steps)))
        tok = (args.steps - start) * args.batch * args.seq
        print(f"done: {tok/ (time.time()-t_run):,.0f} tok/s")


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct — zero
allocation), jits the step with explicit in/out shardings on the production
mesh, compiles, and records memory_analysis / cost_analysis / the HLO
collective schedule for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out results.jsonl]
"""
import argparse
import json
import re
import sys
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.models import model as M
from repro.models.layers import split_params
from repro.models.sharding import ShardingRules, get_rules, set_rules
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*([a-z0-9]+)\[([0-9,]*)\]"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = dict(
    f64=8, f32=4, bf16=2, f16=2, s64=8, u64=8, s32=4, u32=4, s16=2, u16=2,
    s8=1, u8=1, pred=1, c64=8, c128=16,
)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    NOTE: top-level only — while-loop bodies are NOT multiplied by trip
    count here; launch/roofline.py does the trip-corrected accounting.
    """
    from repro.launch.roofline import collective_line_bytes

    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        mc = collective_line_bytes(line.strip())
        if mc:
            kind, size = mc
            out[kind] = out.get(kind, 0) + size
            count[kind] = count.get(kind, 0) + 1
    return dict(bytes=out, counts=count, total=sum(out.values()))


def _shardings_for_params(cfg, mesh, rules):
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))
    from repro.models.layers import Param, is_param

    pv = jax.tree.map(lambda p: p.value, params, is_leaf=is_param)
    pax = jax.tree.map(lambda p: p.axes, params, is_leaf=is_param)
    shardings = jax.tree.map(
        lambda v, ax: rules.named(ax, shape=v.shape),
        pv, pax, is_leaf=lambda x: hasattr(x, "shape") or isinstance(x, tuple),
    )
    return pv, shardings


def _cache_sharding(cfg, cache, mesh, rules):
    """Decode-cache shardings: (L, B, S, KVH, hd) → batch + cache_seq."""
    def spec_for(path_leaf_shape):
        nd = len(path_leaf_shape)
        if nd == 5:  # (L, B, S, KVH, hd)
            return rules.physical(
                (None, "batch", "cache_seq", "kv_heads", None),
                shape=path_leaf_shape,
            )
        if nd == 4:  # (L, B, S, latent) — MLA
            return rules.physical(
                (None, "batch", "cache_seq", None), shape=path_leaf_shape
            )
        if nd == 5 or nd == 3:
            return rules.physical((None, "batch", None), shape=path_leaf_shape)
        return rules.physical(
            (None, "batch") + (None,) * (nd - 2), shape=path_leaf_shape
        )

    return jax.tree.map(
        lambda l: NamedSharding(mesh, spec_for(l.shape)), cache
    )


def run_cell(
    arch: str, shape: str, multi_pod: bool, moment_dtype: str = "float32",
    overrides: dict | None = None,
):
    """Lower + compile one cell; returns the result record."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh=mesh)
    set_rules(rules)
    rec = dict(
        arch=arch, shape=shape,
        mesh="2x16x16" if multi_pod else "16x16",
        chips=int(np.prod(list(mesh.shape.values()))),
    )
    if arch == "remixdb":
        return _run_remixdb_cell(rec, mesh, rules, t0)
    cfg = get_config(arch)
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
        rec["overrides"] = dict(overrides)
    okay, why = cell_supported(cfg, shape)
    if not okay:
        rec.update(status="skipped", reason=why)
        return rec
    spec = input_specs(cfg, shape)
    pv, pshard = _shardings_for_params(cfg, mesh, rules)
    with jax.set_mesh(mesh):
        if spec["kind"] == "train":
            opt_cfg = OptConfig(moment_dtype=moment_dtype)
            opt = jax.eval_shape(lambda: init_opt_state(opt_cfg, pv))
            oshard = dict(
                mu=pshard, nu=pshard,
                step=NamedSharding(mesh, P()),
            )
            bshard = jax.tree.map(
                lambda l: NamedSharding(
                    mesh,
                    rules.physical(
                        ("batch",) + (None,) * (len(l.shape) - 1), shape=l.shape
                    ),
                ),
                spec["batch"],
            )
            step_fn = make_train_step(cfg, opt_cfg)
            metric_shard = NamedSharding(mesh, P())
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(
                    pshard, oshard,
                    dict(loss=metric_shard, grad_norm=metric_shard,
                         lr=metric_shard),
                ),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(pv, opt, spec["batch"])
        elif spec["kind"] == "prefill":
            bshard = jax.tree.map(
                lambda l: NamedSharding(
                    mesh,
                    rules.physical(
                        ("batch",) + (None,) * (len(l.shape) - 1), shape=l.shape
                    ),
                ),
                spec["batch"],
            )
            fn = lambda p, b: M.prefill(cfg, p, b)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pv, spec["batch"])
        else:  # decode
            cache = spec["cache"]
            cshard = _cache_sharding(cfg, cache, mesh, rules)
            tshard = NamedSharding(
                mesh, rules.physical(("batch",), shape=spec["token"].shape)
            )
            pos = SHAPES[shape]["seq"] - 1

            def fn(p, c, tok):
                return M.decode_step(cfg, p, c, tok, pos)

            jitted = jax.jit(
                fn, in_shardings=(pshard, cshard, tshard), donate_argnums=(1,)
            )
            lowered = jitted.lower(pv, cache, spec["token"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return _finish_record(rec, cfg, compiled, t_lower, t_compile, spec["kind"])


HLO_DIR = os.environ.get("DRYRUN_HLO_DIR")


def _finish_record(rec, cfg, compiled, t_lower, t_compile, kind):
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    if HLO_DIR:
        import gzip

        os.makedirs(HLO_DIR, exist_ok=True)
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.hlo.gz"
        with gzip.open(os.path.join(HLO_DIR, name), "wt") as f:
            f.write(txt)
    rec.update(
        status="ok",
        kind=kind,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops=float(ca.get("flops", -1)),
        bytes_accessed=float(ca.get("bytes accessed", -1)),
        arg_bytes=int(ma.argument_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        alias_bytes=int(ma.alias_size_in_bytes),
        collectives=coll,
    )
    if cfg is not None and hasattr(cfg, "n_params"):
        rec["n_params"] = cfg.n_params()
        rec["active_params"] = cfg.active_params()
    return rec


def _run_remixdb_cell(rec, mesh, rules, t0):
    from repro.configs import get_config as gc
    from repro.db.sharded import abstract_state, make_sharded_get

    cfg = gc("remixdb")
    n_shards = int(np.prod(list(mesh.shape.values())))
    remix, runset = abstract_state(cfg, n_shards)
    step, qspec = make_sharded_get(cfg, mesh)
    queries = jax.ShapeDtypeStruct((cfg.query_batch, cfg.kw), jnp.uint32)
    sspec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            step,
            in_shardings=(
                jax.tree.map(lambda _: sspec, remix,
                             is_leaf=lambda x: hasattr(x, "shape")),
                jax.tree.map(lambda _: sspec, runset,
                             is_leaf=lambda x: hasattr(x, "shape")),
                NamedSharding(mesh, qspec),
            ),
        )
        lowered = jitted.lower(remix, runset, queries)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    rec["shape"] = f"get_{cfg.query_batch}"
    return _finish_record(rec, None, compiled, t_lower, t_compile, "kvstore")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument(
        "--override", default=None,
        help='JSON dict of ModelConfig overrides, e.g. {"param_dtype":"bfloat16"}',
    )
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None

    cells = []
    if args.all:
        for arch in ARCHS + ["remixdb"]:
            shapes = list(SHAPES) if arch != "remixdb" else ["service"]
            for shape in shapes:
                for mp in ([False, True] if args.multipod else [False]):
                    cells.append((arch, shape, mp))
    else:
        cells.append((args.arch, args.shape, args.multipod))

    out = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shape, mp in cells:
        try:
            rec = run_cell(
                arch, shape, mp, moment_dtype=args.moment_dtype,
                overrides=overrides,
            )
        except Exception as e:
            failures += 1
            rec = dict(
                arch=arch, shape=shape, mesh="2x16x16" if mp else "16x16",
                status="error", error=f"{type(e).__name__}: {e}",
            )
            traceback.print_exc()
        line = json.dumps(rec)
        print(line, flush=True)
        if out:
            out.write(line + "\n")
            out.flush()
    if out:
        out.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

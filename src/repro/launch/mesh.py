"""Production meshes. 16×16 = one v5e pod slice (256 chips); the multi-pod
mesh adds a leading 'pod' axis (2 pods = 512 chips, DCN-connected)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )

"""Roofline analysis from dry-run artifacts.

Terms per (arch × shape × mesh), all in seconds on TPU v5e constants:

  compute    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips × 819e9 B/s HBM)
  collective = collective_bytes / (chips × 50e9 B/s per ICI link)

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), so lax.scan-over-layers programs are undercounted by ~L×.
This module therefore re-derives FLOPs/bytes by walking the optimized HLO:
every dot/convolution is costed from its shapes, and ops inside a while
body are multiplied by the loop's trip count (recovered from the loop
condition's comparison constant). Collective bytes likewise multiply.

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params — the
"useful compute" yardstick; HLO/MODEL ratio flags remat & dispatch waste.
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import re

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

DTYPE_BYTES = dict(
    f64=8, f32=4, bf16=2, f16=2, s64=8, u64=8, s32=4, u32=4, s16=2, u16=2,
    s8=1, u8=1, pred=1, c64=8, c128=16, u4=1, s4=1,
)

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE = re.compile(r"while\(.*\).*condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
# result may be a scalar shape or a tuple of shapes (all-to-all emits tuples)
_COLL = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*(all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)\("
)


def collective_line_bytes(line: str):
    """(kind, bytes) if this HLO line applies a collective op, else None."""
    m = _COLL.search(line)
    if not m:
        return None
    total = sum(_bytes_of(dt, dims) for dt, dims in _SHAPE.findall(m.group(1)))
    return m.group(2), total
_CONST_CMP = re.compile(r"compare\(.*\)")
_CONSTANT = re.compile(r"constant\((\d+)\)")


def _bytes_of(dt: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return DTYPE_BYTES.get(dt, 4) * n


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    whiles: list = dataclasses.field(default_factory=list)  # (cond, body)
    calls: list = dataclasses.field(default_factory=list)  # fusion/call targets


_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_RESULT = re.compile(r"^%?[\w\.\-]+ = ([a-z0-9]+)\[([0-9,]*)\]")


_DEF = re.compile(r"^%?([\w\.\-]+) = ")
_DOT_OPS = re.compile(r"dot\(%?([\w\.\-]+), %?([\w\.\-]+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def parse_hlo(text: str):
    """Split into computations and cost each one (dots, collectives, whiles).

    HLO operands are variable references, so each computation carries a
    symbol table (instruction → shape) used to resolve dot operand shapes.
    """
    comps: dict[str, CompCost] = {}
    consts: dict[str, int] = {}  # computation -> max int constant (trip bound)
    cur = None
    symtab: dict[str, tuple] = {}
    pending_dots: list[tuple] = []

    def close_comp():
        if cur is None:
            return
        cc = comps[cur]
        for out_dt, out_dims, lhs, rhs, cdims in pending_dots:
            lshape = symtab.get(lhs)
            rshape = symtab.get(rhs)
            if lshape is None:
                continue
            lhs_dims = [int(d) for d in lshape[1].split(",") if d]
            k = 1.0
            if cdims is not None and lhs_dims:
                for i in cdims.split(","):
                    if i:
                        k *= lhs_dims[int(i)]
            elif lhs_dims:
                k = float(lhs_dims[-1])
            cc.flops += 2.0 * _elems(out_dims) * k
            cc.bytes += _bytes_of(out_dt, out_dims) + _bytes_of(*lshape)
            if rshape is not None:
                cc.bytes += _bytes_of(*rshape)

    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{"):
            m = _COMP_HDR.match(line.rstrip("{").strip())
            if m:
                close_comp()
                cur = m.group(1)
                comps[cur] = CompCost()
                consts[cur] = 0
                symtab = {}
                pending_dots = []
                continue
        if cur is None or line == "}":
            if line == "}":
                close_comp()
                cur = None
            continue
        cc = comps[cur]
        md = _DEF.match(line)
        if md:
            ms = _SHAPE.search(line[md.end() - 2 :])
            if ms:
                symtab[md.group(1)] = (ms.group(1), ms.group(2))
        for m in _CONSTANT.finditer(line):
            consts[cur] = max(consts[cur], int(m.group(1)))
        mw = _WHILE.search(line)
        if mw:
            cc.whiles.append((mw.group(1), mw.group(2)))
            continue
        mc = collective_line_bytes(line)
        if mc:
            k, b = mc
            cc.coll_bytes += b
            cc.coll_by_kind[k] = cc.coll_by_kind.get(k, 0.0) + b
            continue
        if " fusion(" in line or " call(" in line or " conditional(" in line:
            for tgt in _CALLS.findall(line):
                cc.calls.append(tgt)
            continue
        if " dot(" in line:
            mr = _RESULT.match(line)
            mo = _DOT_OPS.search(line)
            if not (mr and mo):
                continue
            mk = _LHS_CDIMS.search(line)
            pending_dots.append(
                (
                    mr.group(1), mr.group(2), mo.group(1), mo.group(2),
                    mk.group(1) if mk else None,
                )
            )
    close_comp()
    return comps, consts


def total_cost(text: str) -> dict:
    comps, consts = parse_hlo(text)

    memo: dict[str, tuple] = {}

    def cost_of(name: str, depth=0) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 12:
            return (0.0, 0.0, 0.0, {})
        cc = comps[name]
        f, b, c = cc.flops, cc.bytes, cc.coll_bytes
        kinds = dict(cc.coll_by_kind)
        for tgt in cc.calls:  # fusions / calls execute once per reference
            tf, tb, tc, tk = cost_of(tgt, depth + 1)
            f += tf
            b += tb
            c += tc
            for k, v in tk.items():
                kinds[k] = kinds.get(k, 0.0) + v
        for cond, body in cc.whiles:
            trips = max(1, consts.get(cond, 1))
            bf, bb, bc, bk = cost_of(body, depth + 1)
            f += bf * trips
            b += bb * trips
            c += bc * trips
            for k, v in bk.items():
                kinds[k] = kinds.get(k, 0.0) + v * trips
        memo[name] = (f, b, c, kinds)
        return memo[name]

    # entry = the computation containing whiles at top level; XLA text marks
    # it with ENTRY; find it as the computation whose name contains 'main'
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:  # fallback: computation with most flops after expansion
        entry = max(comps, key=lambda n: cost_of(n)[0])
    f, b, c, kinds = cost_of(entry)
    return dict(flops=f, bytes=b, coll_bytes=c, coll_by_kind=kinds, entry=entry)


def analyze_cell(rec: dict, hlo_path: str | None = None) -> dict:
    """Compute roofline terms for one dry-run record (+ optional HLO file)."""
    chips = rec.get("chips", 256)
    if hlo_path:
        with gzip.open(hlo_path, "rt") as f:
            text = f.read()
        cost = total_cost(text)
        flops_dev = cost["flops"]
        bytes_dev = max(cost["bytes"], rec.get("bytes_accessed", 0))
        coll_dev = cost["coll_bytes"]
        coll_kinds = cost["coll_by_kind"]
    else:
        flops_dev = rec.get("flops", 0)
        bytes_dev = rec.get("bytes_accessed", 0)
        coll_dev = rec.get("collectives", {}).get("total", 0)
        coll_kinds = rec.get("collectives", {}).get("bytes", {})
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bottleneck = max(terms, key=terms.get)
    out = dict(
        rec,
        flops_per_dev=flops_dev,
        bytes_per_dev=bytes_dev,
        coll_bytes_per_dev=coll_dev,
        coll_by_kind=coll_kinds,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_coll,
        bottleneck=bottleneck,
    )
    # MODEL_FLOPS yardstick
    n_act = rec.get("active_params")
    if n_act and rec.get("status") == "ok":
        if rec.get("kind") == "train":
            from repro.launch.shapes import SHAPES

            info = SHAPES[rec["shape"]]
            tokens = info["batch"] * info["seq"]
            model_flops = 6.0 * n_act * tokens
        elif rec.get("kind") == "prefill":
            from repro.launch.shapes import SHAPES

            info = SHAPES[rec["shape"]]
            tokens = info["batch"] * info["seq"]
            model_flops = 2.0 * n_act * tokens
        else:  # decode: one token per sequence
            from repro.launch.shapes import SHAPES

            info = SHAPES[rec["shape"]]
            model_flops = 2.0 * n_act * info["batch"]
        out["model_flops"] = model_flops
        hlo_total = flops_dev * chips
        out["useful_ratio"] = model_flops / hlo_total if hlo_total else 0.0
        out["roofline_frac"] = (
            (model_flops / (chips * PEAK_FLOPS)) / max(terms.values())
            if max(terms.values()) > 0
            else 0.0
        )
    return out


def main():
    import argparse, os

    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="dryrun results.jsonl")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = []
    with open(args.results) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("status") != "ok":
                rows.append(rec)
                continue
            hlo = None
            if args.hlo_dir:
                p = os.path.join(
                    args.hlo_dir,
                    f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.hlo.gz",
                )
                hlo = p if os.path.exists(p) else None
            rows.append(analyze_cell(rec, hlo))
    text = json.dumps(rows, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    # table
    hdr = f"{'arch':22s} {'shape':12s} {'mesh':8s} {'compute':>9s} {'memory':>9s} {'collect':>9s} {'bneck':>10s} {'useful':>7s} {'roofl%':>7s}"
    print(hdr)
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r.get('arch','?'):22s} {r.get('shape','?'):12s} {r.get('mesh','?'):8s} -- {r.get('status')}: {r.get('reason', r.get('error',''))[:60]}")
            continue
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute']*1e3:8.2f}m {r['t_memory']*1e3:8.2f}m "
            f"{r['t_collective']*1e3:8.2f}m {r['bottleneck']:>10s} "
            f"{r.get('useful_ratio', 0):7.2f} {100*r.get('roofline_frac', 0):6.1f}%"
        )


if __name__ == "__main__":
    main()

"""Assigned input shapes and abstract input specs per (arch × shape) cell.

Shapes (LM family): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*`` and ``long_*`` lower ``serve_step`` (one token against a KV
cache of seq_len); ``long_500k`` only for sub-quadratic archs (ssm/hybrid).
All inputs are ShapeDtypeStructs — no allocation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract inputs for the cell's step function (tokens/labels/cache...)."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    if kind in ("train", "prefill"):
        if cfg.family == "encdec":
            batch = dict(
                frontend=sds((b, s, cfg.d_model), jnp.float32),
                tokens=sds((b, s), jnp.int32),
                labels=sds((b, s), jnp.int32),
            )
        elif cfg.frontend == "vlm":
            batch = dict(
                tokens=sds((b, s - cfg.frontend_len), jnp.int32),
                labels=sds((b, s - cfg.frontend_len), jnp.int32),
                frontend=sds((b, cfg.frontend_len, cfg.d_model), jnp.float32),
            )
        else:
            batch = dict(
                tokens=sds((b, s), jnp.int32), labels=sds((b, s), jnp.int32)
            )
        if kind == "prefill":
            batch.pop("labels")
        return dict(kind=kind, batch=batch)
    # decode
    from repro.models import model as M

    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    spec = dict(
        kind="decode",
        cache=cache,
        token=sds((b,), jnp.int32),
        pos=s - 1,
    )
    if cfg.family == "encdec":
        spec["enc_out"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    return spec

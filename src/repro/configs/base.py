"""Model/architecture configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | mla | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # gemma2-style features
    window: Optional[int] = None  # local-attention window (alternating layers)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    post_norms: bool = False
    gated_act: str = "silu"  # silu | gelu
    # MLA (minicpm3 / deepseek style)
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    dense_ff_parallel: bool = False  # arctic: dense FFN residual + MoE
    moe_capacity: float = 1.25
    moe_impl: str = "dense_ec"  # dense_ec | ragged
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 64
    # hybrid (zamba2): one shared attention block applied every `attn_every`
    attn_every: int = 0
    # encoder-decoder (seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub: precomputed embeddings prepended/consumed
    frontend: Optional[str] = None  # None | vlm | audio
    frontend_len: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "float32"  # "bfloat16" halves weight collectives
    moe_local_dispatch: bool = False  # per-data-shard capacity (EP all_to_all)
    # which shapes this arch supports (see launch.shapes)
    supports_long_context: bool = False  # sub-quadratic decode (ssm/hybrid)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, v = self.d_model, self.vocab
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "encdec"):
            per_layer += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
            per_layer += (self.n_heads * hd) * d
        if self.family == "mla":
            per_layer += d * self.q_lora + self.q_lora * self.n_heads * (
                self.qk_nope + self.qk_rope
            )
            per_layer += d * (self.kv_lora + self.qk_rope)
            per_layer += self.kv_lora * self.n_heads * (self.qk_nope + self.v_head)
            per_layer += self.n_heads * self.v_head * d
        if self.family in ("dense", "mla", "encdec"):
            per_layer += 3 * d * self.d_ff
        if self.family == "moe":
            per_layer += 3 * d * self.d_ff_expert * self.n_experts
            per_layer += d * self.n_experts  # router
            if self.dense_ff_parallel:
                per_layer += 3 * d * self.d_ff
        if self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            per_layer += d * (2 * di + 2 * n + self.ssm_heads) + di * d
        if self.family == "hybrid":
            di, n = self.d_inner, self.ssm_state
            per_layer += d * (2 * di + 2 * n + self.ssm_heads) + di * d
        n_layers = self.n_layers
        total = emb + per_layer * n_layers
        if self.family == "hybrid" and self.attn_every:
            # one shared attention+mlp block
            total += d * (self.n_heads * hd) * 2 + 2 * d * (self.n_kv_heads * hd)
            total += 3 * d * self.d_ff
        if self.family == "encdec":
            # decoder cross-attention
            total += self.dec_layers * (
                d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                + (self.n_heads * hd) * d
            )
        return int(total)

    def active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        moe_all = 3 * d * self.d_ff_expert * self.n_experts * self.n_layers
        moe_active = 3 * d * self.d_ff_expert * self.top_k * self.n_layers
        return int(total - moe_all + moe_active)

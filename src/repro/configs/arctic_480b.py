"""arctic-480b [moe]: 128 experts top-2 + dense FFN residual in parallel.

[hf:Snowflake/snowflake-arctic-base].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual FFN (parallel to the MoE)
    vocab=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    dense_ff_parallel=True,
)

"""The paper's own system config: a sharded RemixDB service.

Partitions are sharded over the mesh; query batches are routed with
shard_map + all-to-all (db/sharded.py). This config drives the REMIX-service
dry-run entry alongside the ten LM architectures.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RemixServiceConfig:
    name: str = "remixdb"
    runs_per_partition: int = 8  # R (paper §5.1 uses 1..16)
    entries_per_run: int = 1 << 16  # keys per run per partition shard
    group_d: int = 32  # REMIX group size D
    kw: int = 2  # key words (64-bit keys)
    vw: int = 4  # value words
    query_batch: int = 1 << 19  # global point-query batch per step
    # (>= n_shards per device so all_to_all routing stays dense at 512 chips)
    scan_width: int = 64  # seek+next50 rounded up to lane multiple


CONFIG = RemixServiceConfig()

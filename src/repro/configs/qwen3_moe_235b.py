"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-...]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,  # no shared dense FFN
    vocab=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    d_ff_expert=1536,
    rope_theta=1e6,
)

"""internvl2-26b [vlm]: InternViT frontend (stub) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]. Backbone only per assignment; the vision frontend is
a stub providing precomputed patch embeddings via input_specs().
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    rope_theta=1e6,
    frontend="vlm",
    frontend_len=256,  # patch embeddings per image (stubbed)
    tie_embeddings=False,
)

"""zamba2-2.7b [hybrid]: Mamba2 backbone + one shared attention block applied
periodically. [arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=64,
    attn_every=6,  # shared attention block every 6 mamba layers
    supports_long_context=True,
)

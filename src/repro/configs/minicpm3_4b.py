"""minicpm3-4b [dense/MLA]: multi-head latent attention. [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="mla",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,  # MLA: per-head latents, no GQA grouping
    d_ff=6400,
    vocab=73448,
    q_lora=768,
    kv_lora=256,
    qk_nope=64,
    qk_rope=32,
    v_head=64,
    rope_theta=1e4,
)

"""seamless-m4t-medium [audio]: encoder-decoder backbone; speech frontend is
a stub providing precomputed frame embeddings. [arXiv:2308.11596; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,  # 12 encoder + 12 decoder
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend="audio",
    tie_embeddings=False,
)

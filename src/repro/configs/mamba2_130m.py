"""mamba2-130m [ssm]: attention-free SSD (state-space duality). [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=64,
    supports_long_context=True,
)

"""gemma2-27b [dense]: local/global alternating attention + logit softcaps.

[arXiv:2408.00118; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    window=4096,  # even layers local, odd layers global
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    gated_act="gelu",
    rope_theta=1e4,
)

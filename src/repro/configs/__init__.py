"""Architecture registry: one module per assigned architecture + the paper's
own RemixDB service config. ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

_MODULES = {
    "internvl2-26b": "repro.configs.internvl2_26b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
}

ARCHS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    import importlib

    if name == "remixdb":
        return importlib.import_module("repro.configs.remixdb").CONFIG
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS + ['remixdb']}")
    return importlib.import_module(_MODULES[name]).CONFIG


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=256,
        n_heads=max(1, min(cfg.n_heads, 4)),
        n_kv_heads=max(0, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads else 0,
        d_ff=512 if cfg.d_ff else 0,
        vocab=512,
        head_dim=64 if cfg.head_dim else None,
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend_len else 0,
    )
    if cfg.family == "mla":
        small.update(q_lora=96, kv_lora=64, qk_nope=32, qk_rope=16, v_head=32)
    if cfg.family == "moe":
        small.update(n_experts=8, top_k=min(cfg.top_k, 2), d_ff_expert=128)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "hybrid":
        small.update(attn_every=2)
    if cfg.family == "encdec":
        small.update(enc_layers=2, dec_layers=2, n_layers=4)
    if cfg.n_kv_heads and cfg.n_kv_heads == cfg.n_heads:
        small["n_kv_heads"] = small["n_heads"]  # keep MHA shape relation
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

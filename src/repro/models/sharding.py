"""Logical-axis sharding rules (MaxText-style).

Tensors are annotated with *logical* axis names; a rule table maps them to
physical mesh axes. Axes that do not divide evenly are dropped (replicated)
so one rule set works across all ten architectures. Changing the rule table
is the main §Perf hillclimbing lever.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical → physical mapping (single- and multi-pod meshes)
DEFAULT_RULES: dict[str, Sequence[str] | str | None] = {
    "batch": ("pod", "data"),  # data parallel over pod×data
    "seq": None,  # sequence replicated in training/prefill
    "cache_seq": "model",  # decode KV cache: sequence sharded over model
    "embed": None,  # activation d_model dim
    "vocab": "model",  # embedding/logits vocab dim (TP)
    "heads": "model",  # attention heads (TP)
    "kv_heads": None,  # GQA kv heads often tiny: replicate by default
    "mlp": "model",  # FFN hidden dim (TP)
    "experts": "model",  # MoE expert dim (EP-as-TP over experts)
    "expert_mlp": None,  # per-expert FFN hidden: replicated by default
    "fsdp": "data",  # weight d_in dim (ZeRO-3 style)
    "layers": None,  # stacked-scan layer dim
    "ssm_state": None,
    "conv": None,
}


@dataclasses.dataclass
class ShardingRules:
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))
    mesh: Mesh | None = None

    def physical(self, logical: Sequence[str | None], shape=None) -> P:
        """Map logical axis names to a PartitionSpec, dropping non-divisible
        or unknown axes (replication)."""
        mesh = self.mesh
        used: set[str] = set()
        parts = []
        if shape is not None:
            logical = tuple(logical)[: len(shape)]
        for i, name in enumerate(logical):
            spec = self.rules.get(name) if name else None
            if spec is None:
                parts.append(None)
                continue
            axes = (spec,) if isinstance(spec, str) else tuple(spec)
            if mesh is not None:
                axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
                size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
                if shape is not None and axes and shape[i] % size != 0:
                    axes = ()
            if not axes:
                parts.append(None)
            else:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def constraint(self, x, *logical):
        """with_sharding_constraint by logical names (no-op without a mesh)."""
        mesh = self.mesh
        if mesh is None or len(mesh.devices.flatten()) == 1:
            return x
        spec = self.physical(logical, shape=x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def named(self, logical: Sequence[str | None], shape=None) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.physical(logical, shape=shape))


# a module-level current rule set that model code reads; the launcher swaps
# it (plain global: model fns capture it at trace time, which is what we
# want — one jit per (mesh, rules) combination).
_CURRENT = ShardingRules(mesh=None)


def set_rules(rules: ShardingRules):
    global _CURRENT
    _CURRENT = rules


def get_rules() -> ShardingRules:
    return _CURRENT


def shard(x, *logical):
    return _CURRENT.constraint(x, *logical)

"""Model assembly per architecture family: init / loss / prefill / decode.

All families stack their repeated block over a leading 'layers' axis and run
it with lax.scan + jax.checkpoint (compile-time and memory control at 94
layers). Params are Param(value, logical_axes) trees at init; jitted entry
points consume the raw value tree (see split_params).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import shard

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


# ------------------------------------------------------------------ init
def _stack_layers(inits):
    """Stack per-layer Param trees along a new leading 'layers' axis."""
    return jax.tree.map(
        lambda *xs: L.Param(
            jnp.stack([x.value for x in xs]), ("layers",) + xs[0].axes
        ),
        *inits,
        is_leaf=L.is_param,
    )


def _init_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    p = dict(ln_attn=L.zeros((cfg.d_model,), (None,)))
    if cfg.family == "mla":
        p["attn"] = L.init_mla(cfg, ks[0])
    elif cfg.family != "ssm":
        p["attn"] = L.init_attention(cfg, ks[0])
    p["ln_mlp"] = L.zeros((cfg.d_model,), (None,))
    if cfg.family == "moe":
        p["moe"] = L.init_moe(cfg, ks[1])
        if cfg.dense_ff_parallel and cfg.d_ff:
            p["mlp"] = L.init_mlp(cfg, ks[2])
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(cfg, ks[2])
    if cfg.post_norms:
        p["ln_attn_post"] = L.zeros((cfg.d_model,), (None,))
        p["ln_mlp_post"] = L.zeros((cfg.d_model,), (None,))
    return p


def _init_mamba_block(cfg: ModelConfig, key):
    return dict(
        ln=L.zeros((cfg.d_model,), (None,)),
        mamba=L.init_mamba(cfg, key),
    )


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    params = dict(
        embed=L.mk(ks[0], (cfg.vocab, cfg.d_model), ("vocab", "fsdp"), scale=0.02),
        final_norm=L.zeros((cfg.d_model,), (None,)),
    )
    if not cfg.tie_embeddings:
        params["unembed"] = L.mk(
            ks[1], (cfg.d_model, cfg.vocab), ("fsdp", "vocab"), scale=0.02
        )
    if cfg.family in ("dense", "mla", "moe"):
        keys = jax.random.split(ks[2], cfg.n_layers)
        params["blocks"] = _stack_layers(
            [_init_block(cfg, k) for k in keys]
        )
    elif cfg.family == "ssm":
        keys = jax.random.split(ks[2], cfg.n_layers)
        params["blocks"] = _stack_layers(
            [_init_mamba_block(cfg, k) for k in keys]
        )
    elif cfg.family == "hybrid":
        keys = jax.random.split(ks[2], cfg.n_layers)
        params["blocks"] = _stack_layers(
            [_init_mamba_block(cfg, k) for k in keys]
        )
        params["shared_attn"] = _init_block(cfg, ks[3])
    elif cfg.family == "encdec":
        ekeys = jax.random.split(ks[2], cfg.enc_layers)
        dkeys = jax.random.split(ks[3], cfg.dec_layers)
        params["enc_blocks"] = _stack_layers([_init_block(cfg, k) for k in ekeys])
        dec = []
        for k in dkeys:
            k1, k2 = jax.random.split(k)
            blk = _init_block(cfg, k1)
            blk["cross"] = L.init_attention(cfg, k2)
            blk["ln_cross"] = L.zeros((cfg.d_model,), (None,))
            dec.append(blk)
        params["dec_blocks"] = _stack_layers(dec)
        params["enc_norm"] = L.zeros((cfg.d_model,), (None,))
    else:
        raise ValueError(cfg.family)
    if cfg.param_dtype == "bfloat16":
        # store weight matrices in bf16 (halves FSDP/TP collective bytes);
        # 1-D params (norm scales, biases, a_log) stay f32 for stability
        params = jax.tree.map(
            lambda p: (
                L.Param(p.value.astype(jnp.bfloat16), p.axes)
                if p.value.dtype == jnp.float32 and p.value.ndim >= 2
                else p
            ),
            params,
            is_leaf=L.is_param,
        )
    return params


# ------------------------------------------------------------------ blocks
def _dense_block(cfg: ModelConfig, p, x, positions, layer_idx, enc_out=None, train=False):
    """One transformer block (train/prefill). Handles gemma2 alternation."""
    window = None
    if cfg.window is not None:
        # even layers local, odd layers global — passed in statically via
        # per-layer window select at scan time (layer_idx is traced; use
        # jnp.where on the mask inside attention is costly, so both local
        # and global use flash attention with a traced window bound).
        window = jnp.where(layer_idx % 2 == 0, cfg.window, 1 << 30)
    h = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    if cfg.family == "mla":
        a, _ = L.mla_attention(cfg, p["attn"], h, positions, pin_kv=not train)
    else:
        a = L.attention(
            cfg, p["attn"], h, positions,
            causal=enc_out is None or True, window=window, pin_kv=not train,
        )
    if cfg.post_norms:
        a = L.rmsnorm(a, p["ln_attn_post"], cfg.norm_eps)
    x = x + a
    if "cross" in p and enc_out is not None:
        h = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        c = L.attention(
            cfg, p["cross"], h, positions, causal=False,
            kv_override=(enc_out, enc_out),
        )
        x = x + c
    h = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.family == "moe":
        m = L.moe(cfg, p["moe"], h)
        if cfg.dense_ff_parallel and "mlp" in p:
            m = m + L.mlp(cfg, p["mlp"], h)
    else:
        m = L.mlp(cfg, p["mlp"], h)
    if cfg.post_norms:
        m = L.rmsnorm(m, p["ln_mlp_post"], cfg.norm_eps)
    return x + m


def _enc_block(cfg: ModelConfig, p, x, positions):
    h = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    a = L.attention(cfg, p["attn"], h, positions, causal=False)
    x = x + a
    h = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    return x + L.mlp(cfg, p["mlp"], h)


# ------------------------------------------------------------------ forward
def _embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens].astype(L.cdtype(cfg))
    if cfg.name.startswith("gemma2"):
        x = x * math.sqrt(cfg.d_model)
    return shard(x, "batch", "seq", "embed")


def _logits(cfg, params, x):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(x.dtype)
    logits = x @ w
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return shard(logits, "batch", "seq", "vocab")


def _scan_blocks(cfg, blocks, x, positions, enc_out=None, remat=True):
    n_layers = jax.tree.leaves(blocks)[0].shape[0]
    is_mamba = "mamba" in blocks  # ssm / hybrid backbone blocks

    def body(carry, inp):
        bp, idx = inp
        if is_mamba:
            h = L.rmsnorm(carry, bp["ln"], cfg.norm_eps)
            y, _ = L.mamba_forward(cfg, bp["mamba"], h)
            out = carry + y
        else:
            out = _dense_block(
                cfg, bp, carry, positions, idx, enc_out=enc_out, train=remat
            )
        return out, None

    fn = jax.checkpoint(body, policy=REMAT_POLICY) if remat else body
    x, _ = jax.lax.scan(fn, x, (blocks, jnp.arange(n_layers)))
    return x


def forward(cfg: ModelConfig, params, batch, remat=True):
    """Training forward → logits. batch: dict(tokens, [frontend], [dec_tokens])."""
    if cfg.family == "encdec":
        enc_x = batch["frontend"].astype(L.cdtype(cfg))  # (B,S,D) stub frames
        pos_e = jnp.arange(enc_x.shape[1])[None, :]
        enc_x = _scan_blocks(cfg, params["enc_blocks"], enc_x, pos_e, remat=remat)
        enc_out = L.rmsnorm(enc_x, params["enc_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        x = _embed_tokens(cfg, params, tokens)
        pos_d = jnp.arange(tokens.shape[1])[None, :]
        x = _scan_blocks(
            cfg, params["dec_blocks"], x, pos_d, enc_out=enc_out, remat=remat
        )
        return _logits(cfg, params, x)
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vlm" and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)  # (B, P, D) patch embeddings
        x = jnp.concatenate([fe, x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, remat=remat)
    else:
        x = _scan_blocks(cfg, params["blocks"], x, positions, remat=remat)
    return _logits(cfg, params, x)


def _hybrid_forward(cfg, params, x, positions, remat=True):
    """zamba2: groups of mamba layers + one SHARED attention block."""
    per = cfg.attn_every
    n_groups = cfg.n_layers // per

    def grp(i, x):
        sub = jax.tree.map(lambda a: a[i * per : (i + 1) * per], params["blocks"])
        x = _scan_blocks(cfg, sub, x, positions, remat=remat)
        return _dense_block(cfg, params["shared_attn"], x, positions, 1)

    for i in range(n_groups):
        x = grp(i, x)
    rem = cfg.n_layers - n_groups * per
    if rem:
        sub = jax.tree.map(lambda a: a[-rem:], params["blocks"])
        x = _scan_blocks(cfg, sub, x, positions, remat=remat)
    return x


def loss_fn(cfg: ModelConfig, params, batch, remat=True):
    logits = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vlm" and "frontend" in batch:
        pad = batch["frontend"].shape[1]
        logits = logits[:, pad:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """Abstract/concrete decode cache per family."""
    hd = cfg.hd
    if cfg.family in ("dense", "moe"):
        shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, hd)
        return dict(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
    if cfg.family == "mla":
        shape = (cfg.n_layers, batch, seq, cfg.kv_lora + cfg.qk_rope)
        return dict(latent=jnp.zeros(shape, dtype))
    if cfg.family == "ssm":
        return dict(
            state=jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
            conv=jnp.zeros(
                (cfg.n_layers, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                dtype,
            ),
        )
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        return dict(
            state=jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
            conv=jnp.zeros(
                (cfg.n_layers, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                dtype,
            ),
            k=jnp.zeros((n_groups, batch, seq, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((n_groups, batch, seq, cfg.n_kv_heads, hd), dtype),
        )
    if cfg.family == "encdec":
        shape = (cfg.dec_layers, batch, seq, cfg.n_kv_heads, hd)
        # cross-attention K/V are projected ONCE from the encoder output at
        # prefill time and cached (decode must not re-project 32k frames
        # per token — that would dominate the decode roofline)
        return dict(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            cross_k=jnp.zeros(shape, dtype),
            cross_v=jnp.zeros(shape, dtype),
        )
    raise ValueError(cfg.family)


def encdec_prepare_cross(cfg: ModelConfig, params, enc_out):
    """Project encoder output to per-layer cross K/V caches (prefill)."""
    hd = cfg.hd
    b, s, _ = enc_out.shape

    def one(bp, _):
        k = (enc_out @ bp["cross"]["wk"].astype(enc_out.dtype)).reshape(
            b, s, cfg.n_kv_heads, hd
        )
        v = (enc_out @ bp["cross"]["wv"].astype(enc_out.dtype)).reshape(
            b, s, cfg.n_kv_heads, hd
        )
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(one, None, params["dec_blocks"])
    return ks, vs


def decode_step(cfg: ModelConfig, params, cache, token, pos, enc_out=None):
    """One decode step. token: (B,) int32 → (logits (B,V), new cache)."""
    x = params["embed"][token].astype(L.cdtype(cfg))
    if cfg.name.startswith("gemma2"):
        x = x * math.sqrt(cfg.d_model)
    x = shard(x, "batch", "embed")

    if cfg.family in ("dense", "moe"):
        def body(carry, inp):
            bp, kc, vc, idx = inp
            window = None
            if cfg.window is not None:
                window = jnp.where(idx % 2 == 0, cfg.window, 1 << 30)
            h = L.rmsnorm(carry, bp["ln_attn"], cfg.norm_eps)
            a, kc, vc = L.attention_decode(cfg, bp["attn"], h, kc, vc, pos, window=window)
            if cfg.post_norms:
                a = L.rmsnorm(a, bp["ln_attn_post"], cfg.norm_eps)
            x2 = carry + a
            h = L.rmsnorm(x2, bp["ln_mlp"], cfg.norm_eps)
            if cfg.family == "moe":
                m = L.moe(cfg, bp["moe"], h[:, None, :])[:, 0]
                if cfg.dense_ff_parallel and "mlp" in bp:
                    m = m + L.mlp(cfg, bp["mlp"], h)
            else:
                m = L.mlp(cfg, bp["mlp"], h)
            if cfg.post_norms:
                m = L.rmsnorm(m, bp["ln_mlp_post"], cfg.norm_eps)
            return x2 + m, (kc, vc)

        n_layers = cfg.n_layers
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], jnp.arange(n_layers))
        )
        cache = dict(k=k_new, v=v_new)
    elif cfg.family == "mla":
        def body(carry, inp):
            bp, lat, idx = inp
            h = L.rmsnorm(carry, bp["ln_attn"], cfg.norm_eps)
            a, lat = L.mla_attention(
                cfg, bp["attn"], h[:, None, :], None, decode_cache=lat, pos=pos
            )
            x2 = carry + a
            h = L.rmsnorm(x2, bp["ln_mlp"], cfg.norm_eps)
            return x2 + L.mlp(cfg, bp["mlp"], h), lat

        x, lat_new = jax.lax.scan(
            body, x, (params["blocks"], cache["latent"], jnp.arange(cfg.n_layers))
        )
        cache = dict(latent=lat_new)
    elif cfg.family == "ssm":
        def body(carry, inp):
            bp, st, cv, idx = inp
            h = L.rmsnorm(carry, bp["ln"], cfg.norm_eps)
            y, st, cv = L.mamba_decode(cfg, bp["mamba"], h, st, cv)
            return carry + y, (st, cv)

        x, (st_new, cv_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["state"], cache["conv"], jnp.arange(cfg.n_layers))
        )
        cache = dict(state=st_new, conv=cv_new)
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        n_groups = cfg.n_layers // per
        st_all, cv_all = cache["state"], cache["conv"]
        k_all, v_all = cache["k"], cache["v"]
        sts, cvs, ks, vs = [], [], [], []
        for g in range(n_groups):
            def body(carry, inp):
                bp, st, cv = inp
                h = L.rmsnorm(carry, bp["ln"], cfg.norm_eps)
                y, st, cv = L.mamba_decode(cfg, bp["mamba"], h, st, cv)
                return carry + y, (st, cv)

            sub = jax.tree.map(lambda a: a[g * per : (g + 1) * per], params["blocks"])
            x, (st, cv) = jax.lax.scan(
                body, x, (sub, st_all[g * per : (g + 1) * per], cv_all[g * per : (g + 1) * per])
            )
            sts.append(st)
            cvs.append(cv)
            bp = params["shared_attn"]
            h = L.rmsnorm(x, bp["ln_attn"], cfg.norm_eps)
            a, kc, vc = L.attention_decode(cfg, bp["attn"], h, k_all[g], v_all[g], pos)
            x = x + a
            h = L.rmsnorm(x, bp["ln_mlp"], cfg.norm_eps)
            x = x + L.mlp(cfg, bp["mlp"], h)
            ks.append(kc)
            vs.append(vc)
        cache = dict(
            state=jnp.concatenate(sts), conv=jnp.concatenate(cvs),
            k=jnp.stack(ks), v=jnp.stack(vs),
        )
    elif cfg.family == "encdec":
        hd = cfg.hd

        def body(carry, inp):
            bp, kc, vc, ck, cv, idx = inp
            h = L.rmsnorm(carry, bp["ln_attn"], cfg.norm_eps)
            a, kc, vc = L.attention_decode(cfg, bp["attn"], h, kc, vc, pos)
            x2 = carry + a
            h = L.rmsnorm(x2, bp["ln_cross"], cfg.norm_eps)
            q = (h @ bp["cross"]["wq"].astype(h.dtype)).reshape(
                -1, cfg.n_heads, hd
            )
            c = L.decode_attention(q, ck, cv, pos=ck.shape[1] - 1)
            c = c.reshape(-1, cfg.n_heads * hd) @ bp["cross"]["wo"].astype(h.dtype)
            x2 = x2 + c
            h = L.rmsnorm(x2, bp["ln_mlp"], cfg.norm_eps)
            return x2 + L.mlp(cfg, bp["mlp"], h), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"], jnp.arange(cfg.dec_layers)),
        )
        cache = dict(
            k=k_new, v=v_new, cross_k=cache["cross_k"], cross_v=cache["cross_v"]
        )
    else:
        raise ValueError(cfg.family)

    logits = _logits(cfg, params, x[:, None, :])[:, 0]
    return logits, cache


def prefill(cfg: ModelConfig, params, batch):
    """Prefill: full forward returning last-position logits (cache writes are
    exercised by decode_step; the dry-run lowers prefill as pure forward)."""
    logits = forward(cfg, params, batch, remat=False)
    return logits[:, -1]

"""REMIX-indexed KV-page table: the paper's index applied to LM serving.

Decoded/prefilled KV pages are registered in immutable *generations*: each
generation is one sorted run keyed by a 64-bit prefix hash, valued by a page
slot in the pool. Generations accumulate like L0 tables in an LSM; a REMIX
over them gives one-binary-search lookup of the longest cached prefix and a
comparison-free walk over a sequence's pages (paper §3 applied to serving
metadata). Stale entries (evicted slots) are superseded by newer runs via
REMIX's versioning (newest-bit) — no rewrite of old generations.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.core import query as Q
from repro.core.remix import build_remix
from repro.core.runs import make_run

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
_M64 = (1 << 64) - 1


def chain_hash(h: int, tokens: np.ndarray) -> int:
    """FNV-1a over token ids — the page key (stable across runs)."""
    h = int(h)
    for t in np.asarray(tokens).tolist():
        h = ((h ^ int(t)) * FNV_PRIME) & _M64
    return h


def prefix_hash(tokens: np.ndarray) -> int:
    return chain_hash(FNV_OFFSET, tokens)


class RemixPageTable:
    """LSM-of-generations page table with a REMIX global view."""

    def __init__(self, d: int = 32, max_runs: int = 8):
        self.d = d
        self.max_runs = max_runs
        self.runs: list = []
        self.gen = 0
        self._pending_keys: list[int] = []
        self._pending_vals: list[tuple[int, int]] = []
        self._index = None
        self.lookups = 0

    def add(self, key: np.uint64, slot: int, length: int):
        self._pending_keys.append(int(key))
        self._pending_vals.append((slot, length))

    def flush_generation(self):
        """Seal pending entries into a new immutable run + rebuild REMIX."""
        if not self._pending_keys:
            return
        keys = np.array(self._pending_keys, np.uint64)
        vals = np.array(self._pending_vals, np.uint32)
        self.gen += 1
        self.runs.append(make_run(keys, vals, seq=self.gen))
        self._pending_keys, self._pending_vals = [], []
        if len(self.runs) > self.max_runs:  # tiered merge of generations
            from repro.db.partition import Table, merge_tables

            tabs = [
                Table(
                    keys=CK.unpack_u64(np.asarray(r.keys)),
                    vals=np.asarray(r.vals),
                    seq=np.asarray(r.seq),
                    tomb=np.asarray(r.tomb),
                )
                for r in self.runs
            ]
            merged = merge_tables(tabs)
            self.runs = [
                make_run(merged.keys, merged.vals, seq=merged.seq, sort=False)
            ]
        self._index = None

    def index(self):
        if self._index is None:
            if not self.runs:
                return None
            self._index = build_remix(self.runs, d=max(self.d, len(self.runs)))
        return self._index

    def lookup_batch(self, hashes: np.ndarray):
        """Batched point lookups → (found (Q,), slot (Q,), length (Q,))."""
        idx = self.index()
        self.lookups += len(hashes)
        if idx is None:
            z = np.zeros(len(hashes), np.int64)
            return np.zeros(len(hashes), bool), z, z
        remix, runset = idx
        qk = jnp.asarray(CK.pack_u64(np.asarray(hashes, np.uint64)))
        found, vals = Q.get(remix, runset, qk)
        vals = np.asarray(vals)
        return np.asarray(found), vals[:, 0].astype(np.int64), vals[:, 1].astype(np.int64)


class PrefixCache:
    """Prefix KV reuse: longest cached prefix via REMIX chained-hash lookup.

    The pool holds full-layer KV pages of ``page_size`` tokens; ``match``
    probes hashes of growing prefixes (one *batched* REMIX lookup — the
    paper's batched-seek efficiency on the serving path), ``register``
    inserts new pages into the pending generation.
    """

    def __init__(self, cfg, n_pages: int, page_size: int = 16, d: int = 32):
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        hd = cfg.hd
        self.pool_k = np.zeros(
            (n_pages, cfg.n_layers, page_size, cfg.n_kv_heads, hd), np.float16
        )
        self.pool_v = np.zeros_like(self.pool_k)
        self.next_slot = 0
        self.table = RemixPageTable(d=d)
        self.hits = 0
        self.misses = 0

    def _alloc(self) -> int:
        slot = self.next_slot % self.n_pages  # ring eviction
        self.next_slot += 1
        return slot

    def register(self, tokens: np.ndarray, k_cache: np.ndarray, v_cache: np.ndarray):
        """Register all complete pages of a finished sequence.

        k_cache/v_cache: (L, S, KVH, hd) single-sequence caches.
        """
        ps = self.page_size
        h = FNV_OFFSET
        for pg in range(len(tokens) // ps):
            h = chain_hash(h, tokens[pg * ps : (pg + 1) * ps])
            slot = self._alloc()
            self.pool_k[slot] = np.asarray(
                k_cache[:, pg * ps : (pg + 1) * ps], np.float16
            )
            self.pool_v[slot] = np.asarray(
                v_cache[:, pg * ps : (pg + 1) * ps], np.float16
            )
            self.table.add(h, slot, (pg + 1) * ps)
        self.table.flush_generation()

    def match(self, tokens: np.ndarray):
        """Longest cached prefix → (n_tokens_cached, [slots...])."""
        ps = self.page_size
        n_pages = len(tokens) // ps
        if n_pages == 0:
            return 0, []
        hashes = []
        h = FNV_OFFSET
        for pg in range(n_pages):
            h = chain_hash(h, tokens[pg * ps : (pg + 1) * ps])
            hashes.append(h)
        found, slots, _ = self.table.lookup_batch(np.array(hashes, np.uint64))
        out = []
        for pg in range(n_pages):
            if not found[pg]:
                break
            out.append(int(slots[pg]))
        if out:
            self.hits += 1
        else:
            self.misses += 1
        return len(out) * ps, out

    def gather(self, slots: list[int]):
        """Assemble (L, n_tokens, KVH, hd) caches from pooled pages."""
        k = np.concatenate([self.pool_k[s] for s in slots], axis=1)
        v = np.concatenate([self.pool_v[s] for s in slots], axis=1)
        return k, v

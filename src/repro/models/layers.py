"""Model building blocks, pure JAX (no flax): norms, rope, attention
(GQA / MLA / local+softcap / flash-chunked), gated MLP, MoE, Mamba2 SSD.

Params are pytrees of ``Param(value, axes)`` where ``axes`` are *logical*
sharding axes (see models/sharding.py); ``split_params`` separates values
from the sharding annotation tree so both always share one structure.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.sharding import shard


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Param:
    """A parameter leaf: array value + static logical sharding axes."""

    value: jnp.ndarray
    axes: tuple = dataclasses.field(metadata=dict(static=True))


def is_param(x):
    return isinstance(x, Param)


def split_params(tree):
    vals = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return vals, axes


def mk(key, shape, axes, scale=None, dtype=jnp.float32):
    if scale is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(1, fan_in))
    v = jax.random.normal(key, shape, dtype) * scale
    return Param(v, axes)


def ones(shape, axes):
    return Param(jnp.ones(shape, jnp.float32), axes)


def zeros(shape, axes):
    return Param(jnp.zeros(shape, jnp.float32), axes)


def cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- norms/rope
def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """Rotate-half RoPE. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ------------------------------------------------------------ flash attention
def flash_attention(
    q, k, v, *, causal=True, window=None, cap=None, q_offset=0, kv_len=None,
    block=512, pin_kv=True,
):
    """Blocked online-softmax attention in pure JAX.

    q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd_k/hd_v). GQA via head-group
    reshape; the value width may differ from the qk width (MLA). Never
    materializes (Sq, Sk).

    The computation is a lax.scan over a STATIC list of (q-block, kv-block)
    pairs; for self-attention with ``causal=True`` the above-diagonal pairs
    are pruned, halving both FLOPs and HBM traffic versus scanning the full
    rectangle (§Perf it: "triangular flash"). ``q_offset`` is the absolute
    position of q[0]; ``kv_len`` masks the valid prefix of k/v.
    """
    orig_dtype = q.dtype
    b, sq, h, hd = q.shape
    hd_v = v.shape[-1]
    sk, kvh = k.shape[1], k.shape[2]
    groups = h // max(1, kvh)
    scale = 1.0 / math.sqrt(hd)
    blk_q = min(block, max(64, sq))
    nq = (sq + blk_q - 1) // blk_q
    padq = nq * blk_q - sq
    nk = (sk + block - 1) // block
    padk = nk * block - sk
    q = (q * scale).astype(jnp.float32)
    qg = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
    qg = qg.reshape(b, nq, blk_q, kvh, groups, hd)
    kp = jnp.pad(k, ((0, 0), (0, padk), (0, 0), (0, 0))).astype(jnp.float32)
    vp = jnp.pad(v, ((0, 0), (0, padk), (0, 0), (0, 0))).astype(jnp.float32)
    kp = kp.reshape(b, nk, block, kvh, hd)
    vp = vp.reshape(b, nk, block, kvh, hd_v)
    # pin K/V blocks replicated over the model axis: GQA kv heads are few
    # and small; without this GSPMD sub-shards kvh and re-gathers a kv
    # block on EVERY loop step (measured +38 GB/step all-gather on
    # qwen2.5 prefill_32k). Training disables the pin: the constraint's
    # BACKWARD forces cotangent re-gathers that cost more than it saves
    # (§Perf triangular-flash caveat 2b).
    if pin_kv:
        kp = shard(kp, "batch", None, None, None, None)
        vp = shard(vp, "batch", None, None, None, None)
    kv_valid = sk if kv_len is None else kv_len

    # static pair list: prune above-diagonal blocks for causal self-attn
    prune = causal and kv_len is None and isinstance(q_offset, int)
    pairs = [
        (qi, kj)
        for qi in range(nq)
        for kj in range(nk)
        if not prune or kj * block <= q_offset + (qi + 1) * blk_q - 1
    ]
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    kj_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    def step(carry, pair):
        m, l, acc = carry
        qi, kj = pair
        qb = jax.lax.dynamic_index_in_dim(qg, qi, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kp, kj, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vp, kj, 1, keepdims=False)
        qpos = q_offset + qi * blk_q + jnp.arange(blk_q)
        kpos = kj * block + jnp.arange(block)
        s = jnp.einsum("bqkgd,bjkd->bqkgj", qb, kb)  # (B,bq,KVH,G,block)
        s = softcap(s, cap)
        mask = (
            kpos[None, :] <= qpos[:, None]
            if causal
            else jnp.ones((blk_q, block), bool)
        )
        mask = mask & (kpos < kv_valid)[None, :]
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_old - m_new)
        l_new = l_old * corr + jnp.sum(p, axis=-1)
        a_new = a_old * corr[..., None] + jnp.einsum("bqkgj,bjkd->bqkgd", p, vb)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 1)
        return (m, l, acc), None

    m0 = jnp.full((b, nq, blk_q, kvh, groups), -1e30, jnp.float32)
    l0 = jnp.zeros((b, nq, blk_q, kvh, groups), jnp.float32)
    a0 = jnp.zeros((b, nq, blk_q, kvh, groups, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (qi_arr, kj_arr))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, nq * blk_q, h, hd_v)[:, :sq]
    return out.astype(orig_dtype)


def decode_attention(q, k_cache, v_cache, *, pos, window=None, cap=None):
    """Single-token attention over a (possibly seq-sharded) KV cache.

    q: (B, H, hd); caches: (B, S, KVH, hd); attends to positions <= pos.
    Plain einsum + masked softmax: with the cache's S dim sharded over the
    'model' axis, GSPMD turns the reductions into partial-softmax combines
    (flash-decode). Memory per device is O(S/shards).
    """
    b, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    groups = h // max(1, kvh)
    qg = (q * (1.0 / math.sqrt(hd))).reshape(b, kvh, groups, hd)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    )
    scores = softcap(scores, cap)
    kpos = jnp.arange(s)
    mask = kpos <= pos
    if window is not None:
        mask = mask & (kpos > pos - window)
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


# ------------------------------------------------------------------ attention
def init_attention(cfg: ModelConfig, key):
    hd = cfg.hd
    ks = jax.random.split(key, 8)
    p = dict(
        wq=mk(ks[0], (cfg.d_model, cfg.n_heads * hd), ("fsdp", "heads")),
        wk=mk(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), ("fsdp", "heads")),
        wv=mk(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), ("fsdp", "heads")),
        wo=mk(ks[3], (cfg.n_heads * hd, cfg.d_model), ("heads", "fsdp")),
    )
    if cfg.qkv_bias:
        p["bq"] = zeros((cfg.n_heads * hd,), ("heads",))
        p["bk"] = zeros((cfg.n_kv_heads * hd,), ("heads",))
        p["bv"] = zeros((cfg.n_kv_heads * hd,), ("heads",))
    return p


def attention(
    cfg: ModelConfig, p, x, positions, *, causal=True, window=None,
    kv_override=None, return_kv=False, pin_kv=True,
):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    b, s, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = (kv_override[0] if kv_override is not None else x) @ p["wk"].astype(x.dtype)
    v = (kv_override[1] if kv_override is not None else x) @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    sk = k.shape[1]
    k = k.reshape(b, sk, cfg.n_kv_heads, hd)
    v = v.reshape(b, sk, cfg.n_kv_heads, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    if causal or kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if kv_override is None else jnp.arange(sk)[None, :], cfg.rope_theta)
    out = flash_attention(
        q, k, v, causal=causal, window=window, cap=cfg.attn_softcap,
        pin_kv=pin_kv,
    )
    out = out.reshape(b, s, cfg.n_heads * hd)
    out = out @ p["wo"].astype(x.dtype)
    if return_kv:
        return shard(out, "batch", "seq", "embed"), (k, v)
    return shard(out, "batch", "seq", "embed")


def attention_decode(cfg: ModelConfig, p, x, k_cache, v_cache, pos, *, window=None):
    """One-token decode. x: (B, D); caches (B, S, KVH, hd) updated at pos."""
    b, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, 1, cfg.n_heads, hd)
    k = k.reshape(b, 1, cfg.n_kv_heads, hd)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)[:, 0]
    k = rope(k, posv, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    k_cache = shard(k_cache, "batch", "cache_seq", "kv_heads", None)
    v_cache = shard(v_cache, "batch", "cache_seq", "kv_heads", None)
    out = decode_attention(
        q, k_cache, v_cache, pos=pos, window=window, cap=cfg.attn_softcap
    )
    out = out.reshape(b, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache


# ------------------------------------------------------------------ MLA
def init_mla(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    return dict(
        wq_a=mk(ks[0], (cfg.d_model, cfg.q_lora), ("fsdp", None)),
        q_norm=zeros((cfg.q_lora,), (None,)),
        wq_b=mk(ks[1], (cfg.q_lora, h * (cfg.qk_nope + cfg.qk_rope)), (None, "heads")),
        wkv_a=mk(ks[2], (cfg.d_model, cfg.kv_lora + cfg.qk_rope), ("fsdp", None)),
        kv_norm=zeros((cfg.kv_lora,), (None,)),
        wkv_b=mk(ks[3], (cfg.kv_lora, h * (cfg.qk_nope + cfg.v_head)), (None, "heads")),
        wo=mk(ks[4], (h * cfg.v_head, cfg.d_model), ("heads", "fsdp")),
    )


def mla_attention(cfg: ModelConfig, p, x, positions, *, decode_cache=None, pos=None, pin_kv=True):
    """Multi-head latent attention (prefill path expands the latent).

    Cache stores the compressed (kv_lora + qk_rope) latent per position —
    the MLA memory saving shows up directly in the decode roofline.
    """
    b = x.shape[0]
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope, cfg.qk_rope, cfg.v_head
    q = rmsnorm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = q @ p["wq_b"].astype(x.dtype)
    kv = x @ p["wkv_a"].astype(x.dtype)  # (B, S, kv_lora + dr)
    latent = rmsnorm(kv[..., : cfg.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora :]
    if decode_cache is None:  # train / prefill: expand latent to full kv
        s = x.shape[1]
        q = q.reshape(b, s, h, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        k_rope_h = rope(k_rope.reshape(b, s, 1, dr), positions, cfg.rope_theta)
        kvx = (latent @ p["wkv_b"].astype(x.dtype)).reshape(b, s, h, dn + dv)
        k_nope, v = kvx[..., :dn], kvx[..., dn:]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope_h, (b, s, h, dr))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        # flash supports a distinct value width: no v padding (§Perf it:
        # padding v from 64→96 wasted 50% of the AV einsum flops)
        out = flash_attention(qf, k, v, causal=True, pin_kv=pin_kv)
        out = out.reshape(b, s, h * dv) @ p["wo"].astype(x.dtype)
        new_cache = jnp.concatenate([latent, k_rope], -1)  # (B,S,kv_lora+dr)
        return shard(out, "batch", "seq", "embed"), new_cache
    # ---- decode with absorbed projections (cache = latent ++ k_rope) ----
    cache, = (decode_cache,)
    lat_c = cache[..., : cfg.kv_lora]  # (B, S, kv_lora)
    kr_c = cache[..., cfg.kv_lora :]  # (B, S, dr)
    new = jnp.concatenate([latent, k_rope], -1)  # (B, 1, kv_lora+dr)
    cache = jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, axis=1)
    lat_c = cache[..., : cfg.kv_lora]
    kr_c = cache[..., cfg.kv_lora :]
    q = q.reshape(b, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_rope = rope(q_rope[:, None], posv, cfg.rope_theta)[:, 0]  # (B,h,dr)
    wkv_b = p["wkv_b"].astype(x.dtype).reshape(cfg.kv_lora, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope, w_uk)  # absorb k up-proj
    s_len = cache.shape[1]
    kpos = jnp.arange(s_len)
    # rope the cached k_rope at its own positions
    kr = rope(kr_c.reshape(b, s_len, 1, dr), kpos[None, :], cfg.rope_theta)[:, :, 0]
    scores = jnp.einsum("bhl,bsl->bhs", q_lat.astype(jnp.float32), lat_c.astype(jnp.float32))
    scores = scores + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    scores = scores / math.sqrt(dn + dr)
    scores = jnp.where((kpos <= pos)[None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhs,bsl->bhl", w, lat_c.astype(jnp.float32))
    out = jnp.einsum("bhl,lhd->bhd", out_lat.astype(x.dtype), w_uv)  # absorb v
    out = out.reshape(b, h * dv) @ p["wo"].astype(x.dtype)
    return out, cache


# ------------------------------------------------------------------ MLP / MoE
def init_mlp(cfg: ModelConfig, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(
        w_gate=mk(ks[0], (cfg.d_model, d_ff), ("fsdp", "mlp")),
        w_up=mk(ks[1], (cfg.d_model, d_ff), ("fsdp", "mlp")),
        w_down=mk(ks[2], (d_ff, cfg.d_model), ("mlp", "fsdp")),
    )


def mlp(cfg: ModelConfig, p, x):
    act = jax.nn.silu if cfg.gated_act == "silu" else partial(jax.nn.gelu, approximate=True)
    g = act(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_up"].astype(x.dtype)
    names = ("batch", "seq", "mlp") if x.ndim == 3 else ("batch", "mlp")
    h = shard(g * u, *names)
    return h @ p["w_down"].astype(x.dtype)


def init_moe(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    return dict(
        router=mk(ks[0], (d, e), (None, None), scale=0.02),
        w_gate=mk(ks[1], (e, d, f), ("experts", "fsdp", "expert_mlp")),
        w_up=mk(ks[2], (e, d, f), ("experts", "fsdp", "expert_mlp")),
        w_down=mk(ks[3], (e, f, d), ("experts", "expert_mlp", "fsdp")),
    )


def moe(cfg: ModelConfig, p, x):
    """Mixture of experts over tokens. x: (B, S, D) → (B, S, D).

    dense_ec: capacity-based gather/batched-matmul/scatter — experts shard
    over the 'experts' (model) axis, dispatch is data movement not FLOPs.
    ragged: sort + ragged_dot grouped matmul (no capacity waste).
    """
    b, s, d = x.shape
    t = b * s
    e, k, f = cfg.n_experts, cfg.top_k, cfg.d_ff_expert
    xf = x.reshape(t, d)
    logits = (xf @ p["router"].astype(jnp.float32).astype(x.dtype)).astype(jnp.float32)
    gate_w, choice = jax.lax.top_k(logits, k)  # (T, k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)
    act = jax.nn.silu if cfg.gated_act == "silu" else partial(jax.nn.gelu, approximate=True)

    if cfg.moe_impl == "ragged":
        flat_e = choice.reshape(-1)
        order = jnp.argsort(flat_e)
        tok = (jnp.arange(t * k) // k)[order]
        xs = xf[tok]  # (T*k, D)
        counts = jnp.bincount(flat_e, length=e)
        g = act(jax.lax.ragged_dot(xs, p["w_gate"].astype(x.dtype), counts))
        u = jax.lax.ragged_dot(xs, p["w_up"].astype(x.dtype), counts)
        y = jax.lax.ragged_dot(g * u, p["w_down"].astype(x.dtype), counts)
        wflat = gate_w.reshape(-1)[order].astype(y.dtype)
        out = jax.ops.segment_sum(y * wflat[:, None], tok, num_segments=t)
        return out.reshape(b, s, d).astype(x.dtype)

    # dense_ec: fixed expert capacity. With moe_local_dispatch the tokens
    # are split into G = data-shard groups (Switch-style): capacity, sort
    # and scatter are per group — dispatch tensors shrink G× and the global
    # cross-shard argsort disappears (§Perf it2).
    groups = 1
    if cfg.moe_local_dispatch:
        from repro.models.sharding import get_rules

        mesh = get_rules().mesh
        if mesh is not None:
            groups = int(
                np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")])
            )
            if t % groups:
                groups = 1
    tg = t // groups
    cap = int(math.ceil(tg * k / e * cfg.moe_capacity))
    cap = max(8, -(-cap // 8) * 8)

    def one_group(xf_g, gate_g, choice_g):
        flat_e = choice_g.reshape(-1)  # (Tg*k,)
        flat_t = jnp.arange(tg * k) // k
        order = jnp.argsort(flat_e)
        se, st_ = flat_e[order], flat_t[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        slot = jnp.arange(tg * k) - starts[se]  # position within expert
        ok = slot < cap
        gather_idx = jnp.zeros((e, cap), jnp.int32)
        gather_idx = gather_idx.at[se, jnp.where(ok, slot, cap - 1)].set(
            jnp.where(ok, st_, 0), mode="drop"
        )
        filled = jnp.zeros((e, cap), bool).at[
            se, jnp.where(ok, slot, cap - 1)
        ].set(ok, mode="drop")
        xe = xf_g[gather_idx] * filled[..., None].astype(x.dtype)  # (E,C,D)
        g_ = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype)))
        u_ = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
        y = jnp.einsum("ecf,efd->ecd", g_ * u_, p["w_down"].astype(x.dtype))
        wsort = gate_g.reshape(-1)[order]
        wslot = jnp.zeros((e, cap), jnp.float32).at[
            se, jnp.where(ok, slot, cap - 1)
        ].set(jnp.where(ok, wsort, 0.0), mode="drop")
        return jax.ops.segment_sum(
            (y * wslot[..., None].astype(y.dtype)).reshape(e * cap, d),
            gather_idx.reshape(-1),
            num_segments=tg,
        )

    if groups == 1:
        out = one_group(xf, gate_w, choice).reshape(b, s, d)
        return shard(out, "batch", "seq", "embed").astype(x.dtype)
    xg = shard(xf.reshape(groups, tg, d), "batch", None, None)
    gg = gate_w.reshape(groups, tg, k)
    cg = choice.reshape(groups, tg, k)
    out = jax.vmap(one_group)(xg, gg, cg)  # (G, Tg, D)
    out = shard(out, "batch", None, None)
    return out.reshape(b, s, d).astype(x.dtype)


# ------------------------------------------------------------------ Mamba2 SSD
def init_mamba(cfg: ModelConfig, key):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return dict(
        in_proj=mk(ks[0], (d, 2 * di + 2 * n + h), ("fsdp", "mlp")),
        conv_w=mk(ks[1], (cfg.conv_width, conv_ch), (None, "mlp"), scale=0.5),
        a_log=Param(jnp.zeros((h,), jnp.float32), (None,)),
        dt_bias=zeros((h,), (None,)),
        d_skip=ones((h,), (None,)),
        out_norm=zeros((di,), (None,)),
        out_proj=mk(ks[2], (di, d), ("mlp", "fsdp")),
    )


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk, init_state=None):
    """SSD (Mamba-2) chunked scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) >0; a: (H,) (A = -exp(a_log));
    bmat/cmat: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p_ = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    q = chunk
    da = dt * a[None, None, :]  # (B,S,H) negative
    xw = xh * dt[..., None]
    # reshape into chunks
    das = da.reshape(b, nc, q, h)
    xws = xw.reshape(b, nc, q, h, p_)
    bs = bmat.reshape(b, nc, q, n)
    cs = cmat.reshape(b, nc, q, n)
    cum = jnp.cumsum(das, axis=2)  # (B,NC,Q,H)
    # intra-chunk (diagonal blocks): decay between positions i>=j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,Q,Q,H) i,j
    causal = jnp.tril(jnp.ones((q, q), bool))
    l = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cs, bs)  # (B,NC,Q,Q)
    y_d = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, l, xws)
    # chunk states: contribution of each chunk to its end state
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,NC,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bs, decay_end, xws)
    # inter-chunk recurrence over chunk boundary states
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    def scan_fn(prev, inp):
        st, dec = inp
        new = st + prev * dec[..., None, None]
        return new, prev

    init = (
        jnp.zeros((b, h, p_, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    final, prevs = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prevs = jnp.moveaxis(prevs, 0, 1)  # (B,NC,H,P,N) state entering chunk
    decay_in = jnp.exp(cum)  # (B,NC,Q,H) decay from chunk start to i
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cs, decay_in, prevs)
    y = (y_d + y_off).reshape(b, s, h, p_)
    return y, final


def mamba_forward(cfg: ModelConfig, p, x, *, state=None, conv_state=None):
    """Mamba2 block over a full sequence. x: (B,S,D)."""
    b, s, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(x.dtype)  # (B,S,2di+2n+h)
    z, xr, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    # depthwise causal conv over (x, B, C)
    xbc = jnp.concatenate([xr, bmat, cmat], -1)  # (B,S,di+2n)
    w = p["conv_w"].astype(x.dtype)  # (W, di+2n)
    pad = cfg.conv_width - 1
    xbc_p = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xbc_p[:, i : i + s, :] * w[i][None, None, :]
        for i in range(cfg.conv_width)
    )
    conv = jax.nn.silu(conv)
    xr, bmat, cmat = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    xh = xr.reshape(b, s, h, pd).astype(jnp.float32)
    # pad S to a chunk multiple; dt=0 on padding keeps the state exact
    padn = (-s) % cfg.ssm_chunk
    if padn:
        pad2 = lambda t: jnp.pad(t, ((0, 0), (0, padn)) + ((0, 0),) * (t.ndim - 2))
        y, final = _ssd_chunked(
            pad2(xh), pad2(dt), a,
            pad2(bmat.astype(jnp.float32)), pad2(cmat.astype(jnp.float32)),
            cfg.ssm_chunk, init_state=state,
        )
        y = y[:, :s]
    else:
        y, final = _ssd_chunked(
            xh, dt, a, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
            cfg.ssm_chunk, init_state=state,
        )
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), final


def mamba_decode(cfg: ModelConfig, p, x, state, conv_state):
    """Single-token Mamba2 step. x: (B,D); state (B,H,P,N); conv (B,W-1,CH)."""
    b, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xr, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    xbc = jnp.concatenate([xr, bmat, cmat], -1)  # (B, CH)
    w = p["conv_w"].astype(x.dtype)
    hist = jnp.concatenate([conv_state, xbc[:, None, :]], 1)  # (B,W,CH)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w))
    new_conv_state = hist[:, 1:]
    xr, bmat, cmat = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, :])  # (B,H)
    xh = xr.reshape(b, h, pd).astype(jnp.float32)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], bmat.astype(jnp.float32))
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cmat.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), state, new_conv_state

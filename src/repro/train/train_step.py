"""The jitted train step: loss → grads → AdamW, with microbatch gradient
accumulation and logical-axis shardings applied at the jit boundary."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.optimizer import OptConfig, apply_updates


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics)."""

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss)(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            l = lsum / microbatches
        params, opt_state, info = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = dict(loss=l, **info)
        return params, opt_state, metrics

    return train_step

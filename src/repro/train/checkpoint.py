"""Checkpointing: atomic, incremental, reshardable — the fault-tolerance
substrate for 1000+-node runs.

Layout per step:  <dir>/step_<N>/
    manifest.msgpack   — tree structure, shapes, dtypes, data-pipeline state
    arrays.npz         — flat param/opt arrays (this process's shards)

Writes go to a tmp dir + atomic rename; ``latest`` is re-pointed only after
a complete write, so a crash mid-checkpoint never corrupts the run. Restore
reshards to whatever mesh the new job brings up (elastic re-scale): arrays
are saved logically (full value per leaf here — single-process container;
per-shard files in a multi-host deployment) and re-constrained on load.
"""
from __future__ import annotations

import os
import shutil

import msgpack
import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, params, opt_state, extra: dict | None = None, keep: int = 3):
    """Atomically write a checkpoint; prune to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(dict(params=params, opt=opt_state))
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = dict(
        step=step,
        keys=list(arrays.keys()),
        extra=extra or {},
    )
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    with open(os.path.join(ckpt_dir, ".latest.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".latest.tmp"), os.path.join(ckpt_dir, "latest"))
    _prune(ckpt_dir, keep)


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int | None = None, shardings=None):
    """Load (params, opt_state, extra). ``shardings``: optional tree of
    NamedShardings to place leaves on a (possibly different-size) mesh —
    elastic restart reshards here."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat = {k: npz[k] for k in manifest["keys"]}
    tree = _unflatten(flat)
    params, opt = tree["params"], tree["opt"]
    if shardings is not None:
        flat_s = _flatten(dict(params=shardings[0], opt=shardings[1]))
        placed = {
            k: jax.device_put(v, flat_s[k]) if k in flat_s else jnp.asarray(v)
            for k, v in flat.items()
        }
        tree = _unflatten(placed)
        params, opt = tree["params"], tree["opt"]
    else:
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
    return params, opt, manifest["extra"]

"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 1000+ nodes the pod-axis (DCN) all-reduce dominates; int8 quantization
with per-tensor scales + error feedback cuts that traffic 4× at negligible
quality cost. The residual (quantization error) is carried in the optimizer
state and re-added next step, which provably preserves convergence for
smooth objectives (error-feedback SGD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray, residual: jnp.ndarray):
    """g + residual → (int8 q, scale, new_residual)."""
    x = g.astype(jnp.float32) + residual.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_res = x - q.astype(jnp.float32) * scale
    return q, scale, new_res


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name: str):
    """psum gradients over ``axis_name`` with int8 error-feedback compression.

    Mean-reduces over the axis: int8 payload is summed (widened to int32 by
    the reduction), scales are maxed — a conservative shared-scale scheme
    that keeps the wire format at 1 byte/element.
    """
    def one(g, r):
        q, scale, new_r = quantize(g, r)
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round((g.astype(jnp.float32) + r) / scale), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        out = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        new_r = g.astype(jnp.float32) + r - (
            jnp.clip(jnp.round((g.astype(jnp.float32) + r) / scale), -127, 127)
            * scale
        )
        return out.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )

"""AdamW with warmup-cosine schedule (no optax dependency).

Optimizer moments are stored in a configurable dtype (f32 default, bf16 for
memory-tight giant-MoE configs) and are sharded exactly like their params
(ZeRO: the 'fsdp' logical axis shards both).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup))
    prog = jnp.clip(
        (step - cfg.warmup) / max(1, cfg.total_steps - cfg.warmup), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(cfg: OptConfig, params):
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros_like = lambda p: jnp.zeros(p.shape, dt)
    return dict(
        mu=jax.tree.map(zeros_like, params),
        nu=jax.tree.map(zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )


def apply_updates(cfg: OptConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    # global grad-norm clip
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = mu32 / (1 - cfg.b1 ** step)
        nhat = nu32 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, dict(mu=new_mu, nu=new_nu, step=step), dict(
        grad_norm=gnorm, lr=lr
    )

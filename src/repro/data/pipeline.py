"""Deterministic synthetic data pipeline, shardable and skippable.

Generates reproducible token batches from a counter-based PRNG (threefry):
batch ``i`` is a pure function of (seed, i), so restart/skip-ahead for
fault tolerance and straggler mitigation is exact — the pipeline can resume
at any step without replaying, and each data shard draws a disjoint slice.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataPipeline:
    vocab: int
    batch: int  # global batch
    seq: int
    seed: int = 0
    shard_index: int = 0  # this host's data shard
    shard_count: int = 1

    def local_batch(self) -> int:
        assert self.batch % self.shard_count == 0
        return self.batch // self.shard_count

    def get_batch(self, step: int) -> dict:
        """Batch for ``step`` (host-local shard): dict(tokens, labels)."""
        b = self.local_batch()
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), step),
            self.shard_index,
        )
        # zipfian-ish synthetic tokens: mixture of common + uniform ids
        k1, k2, k3 = jax.random.split(key, 3)
        common = jax.random.randint(k1, (b, self.seq), 0, max(2, self.vocab // 64))
        rare = jax.random.randint(k2, (b, self.seq), 0, self.vocab)
        pick = jax.random.bernoulli(k3, 0.8, (b, self.seq))
        tokens = jnp.where(pick, common, rare).astype(jnp.int32)
        labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(0)
        return dict(tokens=tokens, labels=labels)

    def state(self, step: int) -> dict:
        return dict(seed=self.seed, step=step, shard=self.shard_index)

"""Cluster tier: a fleet of RemixDB range shards behind one routing table.

The manifest + immutable-Version machinery makes a shard a *portable set
of files*; this package turns that into distribution primitives:

- :func:`ship.ship_snapshot` — copy a pinned Version's tables/REMIX files
  plus the WAL horizon into a fresh store directory (zero data rewrite;
  bit-identical reads).
- :class:`replica.ShardFollower` / :class:`replica.Replica` — serve a
  pinned Version and catch up by manifest-diff (fetch only new files) +
  WAL tail replay (``WAL.read_from``), staleness exposed as a gauge.
- :class:`cluster.Cluster` — an in-process fleet with live shard
  split/merge under traffic (gated routing-table swap, zero failed ops)
  and a load-driven placement loop (:mod:`placement`).
"""
from repro.cluster.cluster import Cluster
from repro.cluster.placement import pick_split
from repro.cluster.replica import Replica, ShardFollower
from repro.cluster.ship import clip_records, fetch_files, ship_snapshot

__all__ = [
    "Cluster",
    "Replica",
    "ShardFollower",
    "clip_records",
    "fetch_files",
    "pick_split",
    "ship_snapshot",
]

"""An in-process fleet of RemixDB range shards with live resharding.

:class:`Cluster` owns a :class:`repro.serve.engine.KVServeEngine` (one
shared block cache, one op executor) plus the distribution machinery:

- **Live split**: ship the hot shard's upper span to a fresh directory
  while traffic keeps flowing (snapshot ship + catch-up rounds), then
  gate submissions for one final catch-up and an atomic routing-table
  swap (:meth:`KVServeEngine.swap_shards`). No op ever fails: in-flight
  batches drain on the old executor, gated callers simply wait out the
  cutover.
- **Merge**: the inverse — bulk-copy the right shard's immutable files
  into the left neighbor under fresh names while live, then gate, take
  an atomic ``replication_snapshot`` delta, and
  :meth:`RemixDB.absorb_shard` the span in one manifest commit.
- **Replicas**: :meth:`add_replica` ships a full-range follower that
  catches up via manifest diff + WAL tail replay.
- **Placement**: a background loop watches per-shard routed-op counts
  and splits the hottest shard at the boundary
  :func:`repro.cluster.placement.pick_split` proposes.

Split points align to source partition boundaries, and the split source
is range-trimmed after cutover (``delete_range`` over the moved span),
so a later merge absorbs cleanly; the executor additionally clips scan
results to each shard's routed span, so even an untrimmed source never
leaks stale rows through the serve tier.
"""
from __future__ import annotations

import bisect
import logging
import os
import threading

from repro.cluster.placement import pick_split
from repro.cluster.replica import Replica, ShardFollower
from repro.cluster.ship import clip_records, fetch_files, subset_state
from repro.db.sharded import partition_spans

log = logging.getLogger(__name__)

KEY_SPACE = 1 << 64


class Cluster:
    """A range-sharded serving fleet rooted at one directory.

    ``lows=None`` reopens whatever ``shard-*`` directories already exist
    under ``root`` (a restarted cluster recovers its layout from disk);
    otherwise one shard directory per lower bound is created/opened.
    All public traffic methods are gated on an RLock so a split/merge
    cutover is atomic with respect to submissions — callers block for
    the (short) swap instead of failing.
    """

    def __init__(self, root: str, lows=(0,), config=None,
                 cache_bytes: int = 64 << 20,
                 max_inflight_bytes: int = 256 << 20,
                 submit_workers: int = 2, metrics: bool = True,
                 trace_sample_rate: float = 0.0, io=None):
        from repro.serve.engine import KVServeEngine

        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        if lows is None:
            found = sorted(
                int(name.split("-", 1)[1])
                for name in os.listdir(self.root)
                if name.startswith("shard-")
            )
            lows = tuple(found) if found else (0,)
        self._io = io
        self.serve = KVServeEngine(
            [(int(lo), self._dir_for(int(lo))) for lo in lows],
            cache_bytes=cache_bytes, config=config,
            max_inflight_bytes=max_inflight_bytes,
            submit_workers=submit_workers, metrics=metrics,
            trace_sample_rate=trace_sample_rate,
        )
        self.registry = self.serve.registry
        self.events = self.serve.events
        self.replicas: list[Replica] = []
        # submissions gate: held for the duration of a cutover; re-entrant
        # so admin ops can call the traffic surface they gate
        self._gate = threading.RLock()
        # serializes split/merge/replica admin against each other (and the
        # placement loop); re-entrant so maybe_split -> split nests
        self._admin = threading.RLock()
        self._ops_by_shard: dict[int, int] = {}
        self._placer: threading.Thread | None = None
        self._placer_stop: threading.Event | None = None
        self._c_splits = self.registry.counter("shard_split")
        self._c_merges = self.registry.counter("shard_merge")
        self.registry.gauge("cluster_shards",
                            fn=lambda: len(self.serve.lows))

    def _dir_for(self, lo: int) -> str:
        return os.path.join(self.root, f"shard-{int(lo):020d}")

    # ---------------- traffic (gated) ----------------
    def submit(self, batch, *, sync: bool = False):
        """Submit a typed op batch; see :meth:`KVServeEngine.submit`.
        Routed-op counts feed the placement loop."""
        with self._gate:
            self._count(batch)
            return self.serve.submit(batch, sync=sync)

    def _count(self, batch) -> None:
        for op in getattr(batch, "ops", ()):
            k = getattr(op, "key", None)
            if k is None:
                k = getattr(op, "start", None)
            if k is None:
                keys = getattr(op, "keys", None)
                if keys is None or not len(keys):
                    continue
                self._count_keys(keys)
                continue
            self._count_keys([k])

    def _count_keys(self, keys) -> None:
        """Per-shard routed-op accounting feeding the placement loop."""
        lows = self.serve.lows
        for k in keys:
            lo = lows[max(0, bisect.bisect_right(lows, int(k)) - 1)]
            self._ops_by_shard[lo] = self._ops_by_shard.get(lo, 0) + 1

    def _gated(self, fn, keys, *args, **kw):
        with self._gate:
            self._count_keys(keys)
            return fn(*args, **kw)

    def get(self, key):
        return self._gated(self.serve.get, [key], key)

    def get_batch(self, keys):
        return self._gated(self.serve.get_batch, keys, keys)

    def scan(self, start, n):
        return self._gated(self.serve.scan, [start], start, n)

    def scan_batch(self, starts, n):
        return self._gated(self.serve.scan_batch, starts, starts, n)

    def put(self, key, val):
        return self._gated(self.serve.put, [key], key, val)

    def put_batch(self, keys, vals):
        return self._gated(self.serve.put_batch, keys, keys, vals)

    def delete(self, key):
        return self._gated(self.serve.delete, [key], key)

    def delete_range(self, start, end):
        return self._gated(self.serve.delete_range, [start], start, end)

    def flush(self):
        with self._gate:
            return self.serve.flush()

    def stats(self) -> dict:
        return self.serve.stats()

    def metrics(self) -> dict:
        return self.serve.metrics()

    def health(self) -> dict:
        return self.serve.health()

    @property
    def lows(self) -> list[int]:
        return list(self.serve.lows)

    def spans(self) -> list[tuple[int, int]]:
        return partition_spans(self.serve.lows)

    # ---------------- resharding ----------------
    def _owner(self, at: int) -> int:
        return max(0, bisect.bisect_right(self.serve.lows, int(at)) - 1)

    def _align_split(self, src, at: int, lo: int, hi: int) -> int:
        """Snap ``at`` to the nearest source partition boundary inside
        ``(lo, hi)``; flushes the shard once to materialize boundaries
        when it has none (all data still in the MemTable)."""
        for attempt in range(2):
            bounds = [int(p.lo) for p in src.partitions if lo < p.lo < hi]
            if bounds:
                return min(bounds, key=lambda b: abs(b - int(at)))
            if attempt == 0:
                src.flush()
        return int(at)

    def split(self, at: int, *, align: bool = True,
              catchup_rounds: int = 8, lag_target: int = 256,
              trim_source: bool = True) -> dict:
        """Split the shard owning ``at`` into ``[lo, at)`` + ``[at, hi)``
        while serving traffic; returns a report dict.

        Phases: (1) live — ship a snapshot of ``[at, hi)`` into a fresh
        shard directory and run catch-up rounds while writes continue;
        (2) gated — drain in-flight batches, one final catch-up against
        the now-quiesced source (converges immediately), trim the moved
        span out of the source, and swap the routing table. The gate is
        held only for phase 2, so the expensive byte copy happens under
        full traffic and no operation ever observes a half-split fleet.
        """
        with self._admin:
            with self._gate:
                lows = list(self.serve.lows)
                shards = list(self.serve.shards)
            at = int(at)
            si = max(0, bisect.bisect_right(lows, at) - 1)
            lo_i, hi_i = partition_spans(lows)[si]
            src = shards[si]
            if align:
                at = self._align_split(src, at, lo_i, hi_i)
            if not lo_i < at < hi_i:
                raise ValueError(
                    f"split point {at} outside owning span "
                    f"[{lo_i}, {hi_i}) or already a boundary")
            dst_dir = self._dir_for(at)
            fol = ShardFollower(src, dst_dir, lo=at, hi=hi_i,
                                io=self._io, registry=self.registry,
                                events=self.events)
            fol.catch_up_until(lag_target=lag_target,
                               max_rounds=catchup_rounds)
            with self._gate:
                self.serve.engine.close(wait=True)
                final = fol.catch_up_until(lag_target=0, max_rounds=4)
                if trim_source:
                    # drop the moved span from the source so its own
                    # scans (and a later merge) never see stale rows;
                    # must come *after* the last catch-up or the
                    # tombstone would replicate onto the new shard
                    src.delete_range(at, min(hi_i, KEY_SPACE - 1))
                pairs = list(zip(lows, shards))
                pairs.insert(si + 1, (at, fol.db))
                self.serve.swap_shards(pairs)
                moved = self._ops_by_shard.get(lo_i, 0) // 2
                self._ops_by_shard[lo_i] = moved
                self._ops_by_shard[at] = moved
            self._c_splits.inc()
            self.events.emit("shard_split", at=str(at), src_lo=str(lo_i),
                             hi=str(min(hi_i, KEY_SPACE - 1)),
                             shipped_bytes=fol.report["bytes"],
                             final_lag=final["lag"])
            return dict(at=at, src_lo=lo_i, hi=hi_i,
                        shipped=fol.report, final=final)

    def merge(self, at: int, *, flush_source: bool = True) -> dict:
        """Merge the shard starting at boundary ``at`` into its left
        neighbor while serving traffic; the inverse of :meth:`split`.

        Phase 1 (live): bulk-copy the right shard's immutable files into
        the neighbor's directory under freshly allocated names. Phase 2
        (gated): drain, take the right shard's atomic
        ``replication_snapshot``, copy any files that appeared since,
        absorb span + records into the neighbor in one manifest commit,
        and swap routing without the retired shard. Its directory is
        left on disk for operator cleanup."""
        with self._admin:
            with self._gate:
                lows = list(self.serve.lows)
                shards = list(self.serve.shards)
            at = int(at)
            if at not in lows or at == lows[0]:
                raise ValueError(f"{at} is not a mergeable shard boundary")
            si = lows.index(at)
            b, a = shards[si], shards[si - 1]
            lo_b, hi_b = partition_spans(lows)[si]
            if flush_source:
                # shrink the gated delta: move B's overlay into tables
                # while traffic still flows
                b.flush()
            io = self._io if self._io is not None else b.io
            rename: dict[str, str] = {}
            state0 = b.storage.load_state()
            if state0 is not None:
                fetch_files(subset_state(state0, at, hi_b), b.storage,
                            a.storage, io=io, rename=rename)
            with self._gate:
                self.serve.engine.close(wait=True)
                state1, recs, _ver = b.replication_snapshot(0)
                recs = clip_records(recs, at, hi_b)
                if state1 is not None:
                    sub = subset_state(state1, at, hi_b)
                    fetch_files(sub, b.storage, a.storage, io=io,
                                rename=rename)
                else:
                    sub = dict(seq=int(b.seq), partitions=[],
                               unavailable=[])
                report = a.absorb_shard(at, hi_b, sub, recs, rename=rename)
                pairs = [(lo, db) for lo, db in zip(lows, shards)
                         if lo != at]
                self.serve.swap_shards(pairs)
                self._ops_by_shard[lows[si - 1]] = (
                    self._ops_by_shard.get(lows[si - 1], 0)
                    + self._ops_by_shard.pop(at, 0))
            b.close()
            retired_dir = b.cfg.data_dir
            if retired_dir and os.path.basename(
                    retired_dir).startswith("shard-"):
                # move the retired directory out of the shard namespace so
                # a reopened cluster's layout discovery does not resurrect
                # it; kept on disk for operator cleanup
                base = os.path.join(
                    os.path.dirname(retired_dir),
                    "retired-" + os.path.basename(retired_dir)[len("shard-"):])
                dst = base
                n = 0
                while os.path.exists(dst):
                    n += 1
                    dst = f"{base}.{n}"
                os.rename(retired_dir, dst)
            self._c_merges.inc()
            self.events.emit("shard_merge", at=str(at),
                             into=str(lows[si - 1]),
                             files=len(rename), **report)
            return dict(at=at, into=lows[si - 1], files=len(rename),
                        **report)

    # ---------------- replicas ----------------
    def add_replica(self, shard_lo: int = 0, dst_dir: str | None = None
                    ) -> Replica:
        """Ship a full-range read replica of one shard; it serves reads
        from its own store and catches up on demand (``catch_up`` /
        ``catch_up_until``)."""
        with self._admin:
            si = self.serve.lows.index(int(shard_lo))
            src = self.serve.shards[si]
            if dst_dir is None:
                dst_dir = os.path.join(
                    self.root,
                    f"replica-{int(shard_lo):020d}-{len(self.replicas)}")
            rep = Replica(src, dst_dir, io=self._io,
                          registry=self.registry, events=self.events)
            self.replicas.append(rep)
            return rep

    # ---------------- placement ----------------
    def maybe_split(self, factor: float = 2.0, min_ops: int = 512):
        """Split the hottest shard when its routed-op count exceeds
        ``factor`` times the mean of the others (or ``min_ops`` total
        for a single-shard fleet). Returns the split point or None."""
        with self._admin:
            lows = list(self.serve.lows)
            counts = {lo: int(self._ops_by_shard.get(lo, 0))
                      for lo in lows}
            total = sum(counts.values())
            if total < min_ops:
                return None
            hot = max(lows, key=lambda lo: counts[lo])
            others = [counts[lo] for lo in lows if lo != hot]
            if others:
                baseline = sum(others) / len(others)
                if counts[hot] < factor * max(1.0, baseline):
                    return None
            si = lows.index(hot)
            lo_i, hi_i = partition_spans(lows)[si]
            at = pick_split(self.serve.shards[si], lo_i, hi_i)
            if at is None or at in lows:
                return None
            self.split(at)
            return at

    def start_placement(self, interval_s: float = 0.5,
                        factor: float = 2.0, min_ops: int = 512) -> None:
        """Run :meth:`maybe_split` periodically in a daemon thread."""
        if self._placer is not None:
            return
        stop = threading.Event()

        def loop():
            while not stop.wait(interval_s):
                try:
                    self.maybe_split(factor=factor, min_ops=min_ops)
                except Exception:
                    log.exception("placement round failed")

        self._placer_stop = stop
        self._placer = threading.Thread(
            target=loop, name="cluster-placement", daemon=True)
        self._placer.start()

    def stop_placement(self) -> None:
        if self._placer is None:
            return
        self._placer_stop.set()
        self._placer.join()
        self._placer = None
        self._placer_stop = None

    # ---------------- lifecycle ----------------
    def close(self) -> None:
        self.stop_placement()
        with self._gate:
            self.serve.close()
            for rep in self.replicas:
                rep.close()
            for db in self.serve.shards:
                db.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

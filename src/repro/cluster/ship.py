"""Snapshot shipping: materialize a pinned Version as a new store dir.

A RemixDB shard is fully described by its manifest: immutable table /
REMIX files plus a WAL horizon. Shipping therefore never rewrites data —
it pins a :class:`repro.db.version.Snapshot`, hard-copies the referenced
files (with transient-fault retry through :class:`repro.io.faults.
IOContext`), writes the snapshot's MemTable overlay into a fresh WAL at
the destination, and commits a manifest. ``RemixDB.open`` on the result
recovers to a bit-identical read view.

``lo``/``hi`` restrict the ship to a key span: only partitions
intersecting ``[lo, hi)`` are copied and overlay/range records are
clipped. This is the transport half of a live shard split — the span
must start at a partition boundary of the source (or below its data);
the cluster layer aligns split points before calling in here.
"""
from __future__ import annotations

import os

from repro.db.sharded import partition_spans
from repro.io.faults import NULL_IO
from repro.io.manifest import Storage

KEY_SPACE = 1 << 64


def clip_records(records, lo: int, hi: int):
    """Clip WAL records ``(key, seq, flags, exp, val)`` to ``[lo, hi)``.

    Point records outside the span are dropped; DeleteRange records are
    intersected with the span (and dropped when the intersection is
    empty). Returns a new list.
    """
    from repro.db.wal import FLAG_RANGE, pack_range_hi, unpack_range_hi

    lo, hi = int(lo), int(hi)
    out = []
    for rec in records:
        k, s, fl, exp, v = rec
        k = int(k)
        if int(fl) & FLAG_RANGE:
            rhi = unpack_range_hi(v)
            l2, h2 = max(k, lo), min(rhi, hi)
            if l2 >= h2:
                continue
            if l2 != k or h2 != rhi:
                rec = (l2, s, fl, exp, pack_range_hi(h2, len(v)))
            out.append(rec)
        elif lo <= k < hi:
            out.append(rec)
    return out


def subset_state(state: dict, lo: int, hi: int) -> dict:
    """Restrict a manifest state to partitions intersecting ``[lo, hi)``.

    Partition lower bounds are clamped to ``lo`` (a store opened fresh
    labels its first partition lo=0 regardless of the span it serves);
    partitions at or above ``hi`` are dropped. Unavailable spans are
    intersected. The WAL block map is dropped — the subset is adopted
    into a store with its own WAL.
    """
    lo, hi = int(lo), int(hi)
    parts = sorted(state.get("partitions", []), key=lambda pe: int(pe["lo"]))
    spans = partition_spans([pe["lo"] for pe in parts])
    keep = []
    for pe, (plo, phi) in zip(parts, spans):
        if phi <= lo or plo >= hi:
            continue
        pe = dict(pe)
        pe["lo"] = max(int(pe["lo"]), lo)
        keep.append(pe)
    unavail = []
    for sp in state.get("unavailable", []):
        l2 = max(int(sp["lo"]), lo)
        h2 = min(int(sp["hi"]), hi)
        if l2 < h2:
            unavail.append(dict(sp, lo=l2, hi=h2))
    sub = dict(state, partitions=keep, unavailable=unavail)
    sub.pop("wal", None)
    return sub


def copy_file(src: str, dst: str, io=None, site: str = "ship") -> int:
    """Copy one immutable file with transient-fault retry; returns bytes.

    The read goes through the fault plan (``check_read``/``mutate_read``)
    so tests can inject transient EIO on the shipping path; ``io.run``
    retries within its budget. The write lands via tmp-file + rename so a
    crashed ship never leaves a half-written table at the destination.
    """
    io = NULL_IO if io is None else io

    def attempt() -> bytes:
        io.check_read(src)
        with open(src, "rb") as f:
            return io.mutate_read(src, 0, f.read())

    data = io.run(site, attempt)
    tmp = dst + ".ship-tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, dst)
    return len(data)


def fetch_files(state: dict, src_storage: Storage, dst_storage: Storage,
                io=None, rename: dict | None = None) -> tuple[int, int]:
    """Copy the table/REMIX files a manifest state references.

    Two modes:

    - ``rename is None`` — preserve names and skip files the destination
      already has. This is the replica catch-up path: a manifest diff
      degenerates to "fetch whatever is new".
    - ``rename`` given (a dict, mutated in place) — every source name is
      assigned a fresh name from the destination's id space (shard merge:
      two stores' ``t-%06d`` sequences collide). Names already mapped are
      skipped, so a two-phase copy (bulk while live, delta under the
      gate) ships each immutable file exactly once.

    Returns ``(files_copied, bytes_copied)``.
    """
    from repro.io.manifest import live_files

    nfiles = nbytes = 0
    for name in sorted(live_files(state)):
        is_table = name.endswith(".sst")
        src = (src_storage.table_path(name) if is_table
               else src_storage.remix_path(name))
        if rename is not None:
            if name in rename:
                continue
            new = (dst_storage.alloc_table_name() if is_table
                   else dst_storage.alloc_remix_name())
            rename[name] = new
            dst = (dst_storage.table_path(new) if is_table
                   else dst_storage.remix_path(new))
        else:
            dst = (dst_storage.table_path(name) if is_table
                   else dst_storage.remix_path(name))
            if os.path.exists(dst):
                continue
        nbytes += copy_file(src, dst, io=io)
        nfiles += 1
    return nfiles, nbytes


def ship_snapshot(db, dst_dir: str, lo: int = 0, hi: int | None = None,
                  io=None, registry=None, events=None) -> dict:
    """Ship a consistent snapshot of ``db``'s ``[lo, hi)`` span to
    ``dst_dir`` and commit a manifest there; returns a report dict.

    The snapshot is pinned for the duration, so concurrent flushes and
    compactions cannot reclaim the files being copied. The destination
    receives the source's table/REMIX files verbatim (no rewrite), a
    fresh WAL holding the clipped overlay + range tombstones at their
    original sequence numbers, and a manifest subset; opening it yields
    reads bit-identical to the snapshot.
    """
    from repro.db.store import partition_entry
    from repro.db.wal import WAL

    if db.storage is None:
        raise RuntimeError("snapshot shipping requires a persistent store")
    lo = int(lo)
    hi = KEY_SPACE if hi is None else int(hi)
    io = db.io if io is None else io
    registry = db.registry if registry is None else registry
    events = db.events if events is None else events
    c_bytes = registry.counter("snapshot_ship_bytes")
    c_files = registry.counter("snapshot_ship_files")

    os.makedirs(dst_dir, exist_ok=True)
    dst = Storage(dst_dir, with_ckb=db.cfg.ckb)
    if dst.manifest.current_version():
        raise ValueError(f"destination already holds a store: {dst_dir}")

    nfiles = nbytes = nrecs = 0
    with db.snapshot() as snap:
        parts = sorted(snap.version.partitions, key=lambda p: p.lo)
        spans = partition_spans([p.lo for p in parts])
        shipped = []
        for p, (plo, phi) in zip(parts, spans):
            if phi <= lo or plo >= hi:
                continue
            entry = partition_entry(p)
            entry["lo"] = max(int(entry["lo"]), lo)
            for nm in entry["tables"]:
                nbytes += copy_file(db.storage.table_path(nm),
                                    dst.table_path(nm), io=io)
                nfiles += 1
            if entry.get("remix"):
                nbytes += copy_file(db.storage.remix_path(entry["remix"]),
                                    dst.remix_path(entry["remix"]), io=io)
                nfiles += 1
            shipped.append(entry)
        if not shipped:
            # an empty shard is still a shard: commit a rowless partition
            # so recovery publishes a Version spanning [lo, hi)
            shipped = [dict(lo=lo, tables=[], remix=None, excised=[])]

        wal = WAL(dst.wal_path(), vw=db.cfg.vw)
        for k, e in sorted(snap.overlay.items()):
            if lo <= int(k) < hi:
                wal.append(int(k), int(e.seq), bool(e.tomb), e.val,
                           exp=int(e.exp))
                nrecs += 1
        for rlo, rhi, rseq in snap.ranges:
            l2, h2 = max(int(rlo), lo), min(int(rhi), hi)
            if l2 < h2:
                wal.append_range(l2, h2, int(rseq))
                nrecs += 1
        wal.sync()
        unavail = []
        for sp in getattr(snap.store, "_unavailable", []):
            l2 = max(int(sp["lo"]), lo)
            h2 = min(int(sp["hi"]), hi)
            if l2 < h2:
                unavail.append(dict(sp, lo=l2, hi=h2))
        state = dict(seq=int(snap.seq), vw=int(db.cfg.vw), d=int(db.cfg.d),
                     partitions=shipped, unavailable=unavail,
                     wal=wal.save_state())
        version = dst.commit(state)
        seq = int(snap.seq)

    c_bytes.inc(nbytes)
    c_files.inc(nfiles)
    events.emit("snapshot_ship", dst=os.path.basename(dst_dir.rstrip("/")),
                lo=str(lo), hi=str(hi), files=nfiles, bytes=nbytes,
                records=nrecs)
    return dict(dst=dst_dir, lo=lo, hi=hi, files=nfiles, bytes=nbytes,
                records=nrecs, partitions=len(shipped), seq=seq,
                version=version)

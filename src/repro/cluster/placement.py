"""Load-driven placement: where to split a hot shard.

REMIX partitions already carry per-partition access counters
(``cold_gets``/``cold_scans``, the paper's hot/cold accounting), so the
split point that best halves a shard's *observed* load is computable
from state the store maintains anyway. When a shard has seen no cold
traffic (fresh, or everything served from the MemTable) the row counts
are the fallback, halving data volume instead.
"""
from __future__ import annotations

from repro.db.sharded import partition_spans

KEY_SPACE = 1 << 64


def pick_split(db, lo: int = 0, hi: int | None = None) -> int | None:
    """The partition boundary inside ``(lo, hi)`` nearest the cumulative
    half of the shard's weight (observed cold traffic, falling back to
    row counts). Returns ``None`` when the span has fewer than two
    partitions — there is no boundary to split at without a rewrite,
    which this tier never does.
    """
    lo = int(lo)
    hi = KEY_SPACE if hi is None else int(hi)
    parts = sorted(db.partitions, key=lambda p: p.lo)
    spans = partition_spans([p.lo for p in parts])
    inside = [p for p, (plo, phi) in zip(parts, spans)
              if phi > lo and plo < hi]
    if len(inside) < 2:
        return None
    loads = [int(p.cold_gets) + int(p.cold_scans) for p in inside]
    if sum(loads) == 0:
        loads = [int(p.n_entries) for p in inside]
    total = sum(loads)
    if total == 0:
        # no signal at all: bisect the partition list
        return int(inside[len(inside) // 2].lo)
    best, best_err = None, None
    cum = 0
    for i in range(len(inside) - 1):
        cum += loads[i]
        boundary = int(inside[i + 1].lo)
        err = abs(2 * cum - total)  # |cum - total/2| without the division
        if boundary > lo and (best_err is None or err < best_err):
            best, best_err = boundary, err
    return best

"""Read replicas and shard followers over the version set.

A follower is born from :func:`repro.cluster.ship.ship_snapshot` and then
tracks the source incrementally: each :meth:`ShardFollower.catch_up`
round asks the primary for an atomic ``(state, records, version)``
capture (:meth:`repro.db.store.RemixDB.replication_snapshot`).

- Steady state (manifest version unchanged): the delta is just the WAL
  tail past the follower's sequence horizon — ``WAL.read_from`` skips
  whole blocks by their persisted ``max_seq``, so a quiet primary costs
  O(written blocks) metadata scans and zero record decodes.
- Across a primary flush/compaction (version changed): a manifest diff
  degenerates to "fetch the files we don't have" (tables are immutable),
  then :meth:`RemixDB.adopt_version` swaps in the new file set and
  rebuilds the overlay from the primary's live records — exactly the
  state the primary itself would recover to.

Followers never write their own WAL for replicated records: the primary
is the durability root, and a restarted follower re-catches-up.
"""
from __future__ import annotations

import dataclasses
import os

from repro.cluster.ship import (KEY_SPACE, clip_records, fetch_files,
                                ship_snapshot, subset_state)


class ShardFollower:
    """A store tracking one source shard's key span ``[lo, hi)``.

    Construction ships an initial snapshot into ``dst_dir`` and opens it;
    :meth:`catch_up` converges toward the primary. Used both as the
    catch-up phase of a live shard split (span-restricted) and as the
    base of a full-range :class:`Replica`.
    """

    def __init__(self, src, dst_dir: str, lo: int = 0, hi: int | None = None,
                 config=None, io=None, registry=None, events=None):
        from repro.db.store import RemixDB

        self.src = src
        self.lo = int(lo)
        self.hi = KEY_SPACE if hi is None else int(hi)
        self.io = src.io if io is None else io
        self.events = src.events if events is None else events
        self.report = ship_snapshot(src, dst_dir, lo=self.lo, hi=self.hi,
                                    io=self.io, registry=registry,
                                    events=self.events)
        if config is None:
            config = dataclasses.replace(
                src.cfg, data_dir=dst_dir, block_cache=None, registry=None,
                fault_plan=None, background_compaction=False,
                scrub_interval_s=0.0,
            )
        else:
            config = dataclasses.replace(config, data_dir=dst_dir)
        self.db = RemixDB(config)
        # force the first catch_up through the full adopt path: the ship
        # came from a pinned snapshot, which need not match any committed
        # manifest version of the source
        self._version: int | None = None
        reg = registry if registry is not None else self.db.registry
        self._c_seqs = reg.counter("replica_catchup_seqs")
        self._c_files = reg.counter("replica_catchup_files")
        self._c_rounds = reg.counter("replica_catchup_rounds")

    # -------------- catch-up --------------
    def seq_lag(self) -> int:
        """Sequence distance behind the primary (0 = fully caught up)."""
        return max(0, int(self.src.seq) - int(self.db.seq))

    def catch_up(self) -> dict:
        """One convergence round; returns a report dict.

        ``from_seq`` is the follower's horizon minus one: ``read_from``
        yields records strictly above the floor, and ``db.seq`` is
        one past the last applied record.
        """
        state, records, version = self.src.replication_snapshot(
            from_seq=max(0, int(self.db.seq) - 1), version=self._version)
        advance = None
        if records:
            advance = max(int(r[1]) for r in records) + 1
        if self.lo > 0 or self.hi < KEY_SPACE:
            records = clip_records(records, self.lo, self.hi)
        files = nbytes = 0
        if state is None and self._version is not None:
            applied = self.db.apply_replication(records, advance_to=advance)
        else:
            if state is not None:
                sub = subset_state(state, self.lo, self.hi)
                files, nbytes = fetch_files(sub, self.src.storage,
                                            self.db.storage, io=self.io)
                self.db.adopt_version(sub, records, advance_to=advance)
                applied = len(records)
            else:
                # source has never committed a manifest: everything it
                # has lives in its WAL, and the tail from 0 covers it
                applied = self.db.apply_replication(
                    records, advance_to=advance)
        self._version = version
        self._c_rounds.inc()
        self._c_seqs.inc(applied)
        self._c_files.inc(files)
        lag = self.seq_lag()
        self.events.emit("replica_catchup",
                         dst=os.path.basename(
                             str(self.db.cfg.data_dir).rstrip("/")),
                         applied=applied, files=files, bytes=nbytes,
                         version=version, lag=lag)
        return dict(applied=applied, files=files, bytes=nbytes,
                    version=version, lag=lag)

    def catch_up_until(self, lag_target: int = 0, max_rounds: int = 32
                       ) -> dict:
        """Repeat :meth:`catch_up` until ``seq_lag() <= lag_target`` (or
        the round budget runs out — a live primary can outrun any finite
        number of rounds; the cluster layer gates writers for the final
        round). Returns the last round's report."""
        report = dict(applied=0, files=0, bytes=0, version=self._version,
                      lag=self.seq_lag())
        for _ in range(max_rounds):
            report = self.catch_up()
            if report["lag"] <= lag_target:
                break
        return report

    def close(self) -> None:
        self.db.close()


class Replica(ShardFollower):
    """A full-range read replica of one shard.

    Serves pinned-Version reads from its own store while lagging the
    primary by ``replica_seq_lag`` sequence numbers (exported as a gauge
    on the follower's registry). Reads go through the normal store read
    path, so a replica sees exactly what the primary would have served
    at the replica's horizon.
    """

    def __init__(self, src, dst_dir: str, config=None, io=None,
                 registry=None, events=None):
        super().__init__(src, dst_dir, lo=0, hi=None, config=config,
                         io=io, registry=registry, events=events)
        reg = registry if registry is not None else self.db.registry
        reg.gauge("replica_seq_lag", fn=self.seq_lag,
                  replica=os.path.basename(str(dst_dir).rstrip("/")))

    # -------------- reads --------------
    def get(self, key):
        return self.db.get(key)

    def get_batch(self, keys):
        return self.db.get_batch(keys)

    def scan(self, start, n):
        return self.db.scan(start, n)

    def snapshot(self):
        return self.db.snapshot()

"""On-disk SSTable files: columnar sections + per-block CRC32C + CKB.

See :mod:`repro.io` for the byte-level layout diagram. Files are immutable:
writers emit ``<path>.tmp`` and atomically rename, readers only ever see
complete files. Section reads are lazy and individually checksummed — a
reader that fetches only the CKB never touches (or validates) value bytes,
which is what makes incremental REMIX rebuilds cheap (Snippet 1).
"""
from __future__ import annotations

import os
import struct

import numpy as np

from repro.io.checksum import crc32c
from repro.io.ckb import decode_ckb, encode_ckb

MAGIC = b"RMIXSST1"
FOOTER_MAGIC = b"RMIXFTR1"
VERSION = 1
FLAG_CKB = 1

DEFAULT_BLOCK = 1 << 16  # 64 KB checksum granule

_HEADER = struct.Struct("<8sHHHHQI12x")  # magic, ver, kw, vw, flags, n, blk
_FOOTER_FIXED = struct.Struct("<6QII")  # 5 section offsets, ckb_len, nblk, blk
_FOOTER_TAIL = struct.Struct("<II8s")  # footer_crc, footer_len, magic

SECTIONS = ("keys", "vals", "seq", "tomb", "ckb")


def write_sstable(
    path: str,
    keys: np.ndarray,
    vals: np.ndarray,
    seq: np.ndarray,
    tomb: np.ndarray,
    with_ckb: bool = True,
    block_bytes: int = DEFAULT_BLOCK,
) -> int:
    """Write one table file atomically; returns bytes written.

    ``keys``: (N, KW) uint32 sorted ascending (word 0 most significant);
    ``vals``: (N, VW) uint32; ``seq``: (N,) uint32; ``tomb``: (N,) bool.
    """
    keys = np.ascontiguousarray(np.asarray(keys, np.uint32))
    vals = np.ascontiguousarray(np.asarray(vals, np.uint32))
    seq = np.ascontiguousarray(np.asarray(seq, np.uint32))
    tomb = np.ascontiguousarray(np.asarray(tomb, bool))
    n, kw = keys.shape
    vw = vals.shape[1]
    sections = [
        keys.astype("<u4").tobytes(),
        vals.astype("<u4").tobytes(),
        seq.astype("<u4").tobytes(),
        tomb.astype(np.uint8).tobytes(),
    ]
    flags = 0
    if with_ckb:
        sections.append(encode_ckb(keys))
        flags |= FLAG_CKB
    else:
        sections.append(b"")
    offs = []
    pos = _HEADER.size
    for s in sections:
        offs.append(pos)
        pos += len(s)
    data = b"".join(sections)
    crcs = [
        crc32c(data[i : i + block_bytes])
        for i in range(0, max(1, len(data)), block_bytes)
    ]
    footer = _FOOTER_FIXED.pack(
        *offs, len(sections[4]), len(crcs), block_bytes
    ) + np.asarray(crcs, "<u4").tobytes()
    footer += _FOOTER_TAIL.pack(
        crc32c(footer), len(footer) + _FOOTER_TAIL.size, FOOTER_MAGIC
    )
    header = _HEADER.pack(MAGIC, VERSION, kw, vw, flags, n, block_bytes)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(data)
        f.write(footer)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return _HEADER.size + len(data) + len(footer)


class SSTableReader:
    """Lazy, checksum-verifying reader for one table file.

    Tracks per-section ``bytes_read`` so benchmarks can prove which parts
    of the file a code path touched (e.g. CKB-based rebuild: vals == 0).
    """

    def __init__(self, path: str):
        self.path = path
        self.bytes_read: dict[str, int] = {s: 0 for s in SECTIONS}
        with open(path, "rb") as f:
            hdr = f.read(_HEADER.size)
            (magic, ver, self.kw, self.vw, self.flags, self.n, self.block_bytes
             ) = _HEADER.unpack(hdr)
            if magic != MAGIC or ver != VERSION:
                raise ValueError(f"{path}: not an SSTable (v{VERSION}) file")
            f.seek(-_FOOTER_TAIL.size, os.SEEK_END)
            end = f.tell()
            fcrc, flen, fmagic = _FOOTER_TAIL.unpack(f.read(_FOOTER_TAIL.size))
            if fmagic != FOOTER_MAGIC:
                raise ValueError(f"{path}: bad footer magic")
            f.seek(end + _FOOTER_TAIL.size - flen)
            body = f.read(flen - _FOOTER_TAIL.size)
            if crc32c(body) != fcrc:
                raise ValueError(f"{path}: footer checksum mismatch")
            fixed = _FOOTER_FIXED.unpack_from(body, 0)
            self._offs = dict(zip(SECTIONS, fixed[:5]))
            self._ckb_len = fixed[5]
            n_blocks, bb = fixed[6], fixed[7]
            self._crcs = np.frombuffer(
                body, "<u4", count=n_blocks, offset=_FOOTER_FIXED.size
            )
            self._data_start = _HEADER.size
            self._data_end = self._offs["ckb"] + self._ckb_len
            self.block_bytes = bb

    @property
    def has_ckb(self) -> bool:
        return bool(self.flags & FLAG_CKB)

    def _section_range(self, name: str) -> tuple[int, int]:
        lens = dict(
            keys=self.n * self.kw * 4,
            vals=self.n * self.vw * 4,
            seq=self.n * 4,
            tomb=self.n,
            ckb=self._ckb_len,
        )
        off = self._offs[name]
        return off, off + lens[name]

    def _read_checked(self, name: str) -> bytes:
        """Read one section, verifying the CRC blocks that cover it."""
        lo, hi = self._section_range(name)
        bb = self.block_bytes
        b0 = (lo - self._data_start) // bb
        b1 = max(b0, (hi - self._data_start - 1) // bb) if hi > lo else b0
        blo = self._data_start + b0 * bb
        bhi = min(self._data_start + (b1 + 1) * bb, self._data_end)
        with open(self.path, "rb") as f:
            f.seek(blo)
            buf = f.read(bhi - blo)
        for bi in range(b0, b1 + 1):
            if bi >= len(self._crcs):
                break
            s = bi * bb - (blo - self._data_start)
            chunk = buf[s : s + bb]
            if crc32c(chunk) != int(self._crcs[bi]):
                raise ValueError(
                    f"{self.path}: block {bi} checksum mismatch"
                )
        self.bytes_read[name] += hi - lo
        return buf[lo - blo : hi - blo]

    def read_keys(self) -> np.ndarray:
        """(N, KW) uint32 from the keys section."""
        raw = self._read_checked("keys")
        return np.frombuffer(raw, "<u4").astype(np.uint32).reshape(
            self.n, self.kw
        )

    def read_vals(self) -> np.ndarray:
        raw = self._read_checked("vals")
        return np.frombuffer(raw, "<u4").astype(np.uint32).reshape(
            self.n, self.vw
        )

    def read_seq(self) -> np.ndarray:
        return np.frombuffer(self._read_checked("seq"), "<u4").astype(
            np.uint32
        )

    def read_tomb(self) -> np.ndarray:
        return np.frombuffer(self._read_checked("tomb"), np.uint8).astype(bool)

    def read_ckb_keys(self) -> np.ndarray | None:
        """Decode the CKB trailer to (N, KW) uint32, or None if absent."""
        if not self.has_ckb:
            return None
        return decode_ckb(self._read_checked("ckb"))

    def verify(self) -> None:
        """Validate every block checksum (full-file scrub)."""
        for name in SECTIONS:
            self._read_checked(name)

"""On-disk SSTable files: columnar sections + per-block CRC32C + CKB.

See :mod:`repro.io` for the byte-level layout diagram. Files are immutable:
writers emit ``<path>.tmp`` and atomically rename, readers only ever see
complete files. Section reads are lazy and individually checksummed — a
reader that fetches only the CKB never touches (or validates) value bytes,
which is what makes incremental REMIX rebuilds cheap (Snippet 1).

Two read modes (``SSTableReader(mode=...)``):

- ``"copy"`` (default): each checksum granule is read into a heap
  ``bytes`` object, verified, and cached;
- ``"mmap"``: the file is mapped once; a granule is CRC-verified on first
  touch and after that served as a zero-copy ``memoryview`` slice of the
  mapping — the block cache then holds views, not copies, and a contiguous
  multi-block :meth:`SSTableReader.read_range` costs no join.
"""
from __future__ import annotations

import mmap
import os
import struct

import numpy as np

from repro.io.checksum import crc32c
from repro.io.ckb import decode_ckb, encode_ckb
from repro.io.faults import NULL_IO, CorruptionError
from repro.obs import tracing as _tracing

MAGIC = b"RMIXSST1"
FOOTER_MAGIC = b"RMIXFTR1"
VERSION = 2
FLAG_CKB = 1
FLAG_EXP = 2  # file carries a per-row TTL expiry section

DEFAULT_BLOCK = 1 << 16  # 64 KB checksum granule

# magic, ver, kw, vw, flags, n, blk, n_rtombs
_HEADER = struct.Struct("<8sHHHHQII8x")
# 7 section offsets, ckb_len, nblk, blk
_FOOTER_FIXED = struct.Struct("<8QII")
_FOOTER_TAIL = struct.Struct("<II8s")  # footer_crc, footer_len, magic

SECTIONS = ("keys", "vals", "seq", "tomb", "exp", "rtombs", "ckb")

_RTOMB = struct.Struct("<3Q")  # lo, hi (exclusive), seq


def write_sstable(
    path: str,
    keys: np.ndarray,
    vals: np.ndarray,
    seq: np.ndarray,
    tomb: np.ndarray,
    exp: np.ndarray | None = None,
    rtombs=None,
    with_ckb: bool = True,
    block_bytes: int = DEFAULT_BLOCK,
    io=None,
) -> int:
    """Write one table file atomically; returns bytes written.

    ``keys``: (N, KW) uint32 sorted ascending (word 0 most significant);
    ``vals``: (N, VW) uint32; ``seq``: (N,) uint32; ``tomb``: (N,) bool;
    ``exp``: optional (N,) uint32 absolute TTL expiries (all-zero or None
    omits the section and clears FLAG_EXP); ``rtombs``: optional iterable
    of ``(lo, hi, seq)`` range tombstones born from the same flush as this
    table's rows (the manifest's excised spans stay authoritative — the
    section is a colocated, crash-independent record of the deletes).
    """
    keys = np.ascontiguousarray(np.asarray(keys, np.uint32))
    vals = np.ascontiguousarray(np.asarray(vals, np.uint32))
    seq = np.ascontiguousarray(np.asarray(seq, np.uint32))
    tomb = np.ascontiguousarray(np.asarray(tomb, bool))
    n, kw = keys.shape
    vw = vals.shape[1]
    sections = [
        keys.astype("<u4").tobytes(),
        vals.astype("<u4").tobytes(),
        seq.astype("<u4").tobytes(),
        tomb.astype(np.uint8).tobytes(),
    ]
    flags = 0
    if exp is not None and np.any(np.asarray(exp)):
        exp = np.ascontiguousarray(np.asarray(exp, np.uint32))
        sections.append(exp.astype("<u4").tobytes())
        flags |= FLAG_EXP
    else:
        sections.append(b"")
    rt = [(int(lo), int(hi), int(s)) for lo, hi, s in (rtombs or ())]
    sections.append(b"".join(_RTOMB.pack(*r) for r in rt))
    if with_ckb:
        sections.append(encode_ckb(keys))
        flags |= FLAG_CKB
    else:
        sections.append(b"")
    offs = []
    pos = _HEADER.size
    for s in sections:
        offs.append(pos)
        pos += len(s)
    data = b"".join(sections)
    crcs = [
        crc32c(data[i : i + block_bytes])
        for i in range(0, max(1, len(data)), block_bytes)
    ]
    footer = _FOOTER_FIXED.pack(
        *offs, len(sections[6]), len(crcs), block_bytes
    ) + np.asarray(crcs, "<u4").tobytes()
    footer += _FOOTER_TAIL.pack(
        crc32c(footer), len(footer) + _FOOTER_TAIL.size, FOOTER_MAGIC
    )
    header = _HEADER.pack(
        MAGIC, VERSION, kw, vw, flags, n, block_bytes, len(rt)
    )
    io = io or NULL_IO
    payload = io.mutate_write(path, header + data + footer)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        io.check_fsync(path)
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return _HEADER.size + len(data) + len(footer)


class SSTableReader:
    """Lazy, checksum-verifying reader for one table file.

    All data-region access goes through :meth:`read_block`, one checksum
    granule (default 64 KB) at a time: a granule is read from disk, CRC-
    verified, and (when a :class:`repro.io.blockcache.BlockCache` is
    attached) cached, so repeated queries touching the same blocks pay no
    further I/O or verification. Tracks per-section logical ``bytes_read``
    plus physical ``disk_bytes_read`` (cache hits don't count) so
    benchmarks can prove which parts of the file a code path touched.
    """

    def __init__(self, path: str, cache=None, mode: str = "copy", io=None):
        if mode not in ("copy", "mmap"):
            raise ValueError(f"mode must be 'copy' or 'mmap', got {mode!r}")
        self.path = path
        self.mode = mode
        self._cache = cache
        self._io = io or NULL_IO
        self._mm: mmap.mmap | None = None
        self._verified: set[int] | None = set() if mode == "mmap" else None
        self.bytes_read: dict[str, int] = {s: 0 for s in SECTIONS}
        self.disk_bytes_read = 0
        # cache-key namespace: path alone is not a safe identity (Storage
        # ids restart at 1+max(surviving files), so a name can be reused
        # after the highest-id tables are deleted) — bind the inode and
        # mtime captured at open so a reused name can't hit stale blocks
        st = os.stat(path)
        self._cache_key = (path, st.st_ino, st.st_mtime_ns)
        self._io.run("open", self._open_meta)
        if mode == "mmap":
            with open(path, "rb") as f:
                self._mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)

    def _open_meta(self) -> None:
        """Read + verify header and footer (retried on transient faults)."""
        path, io = self.path, self._io
        with open(path, "rb") as f:
            io.check_read(path)
            hdr = io.mutate_read(path, 0, f.read(_HEADER.size))
            try:
                (magic, ver, self.kw, self.vw, self.flags, self.n,
                 self.block_bytes, self.n_rtombs) = _HEADER.unpack(hdr)
            except struct.error:
                raise CorruptionError(path, "header", detail="truncated")
            if magic != MAGIC or ver != VERSION:
                raise CorruptionError(
                    path, "header",
                    detail=f"not an SSTable (v{VERSION}) file",
                )
            try:
                f.seek(-_FOOTER_TAIL.size, os.SEEK_END)
                end = f.tell()
                fcrc, flen, fmagic = _FOOTER_TAIL.unpack(
                    io.mutate_read(path, end, f.read(_FOOTER_TAIL.size))
                )
            except (OSError, struct.error):
                raise CorruptionError(path, "footer", detail="truncated")
            if fmagic != FOOTER_MAGIC:
                raise CorruptionError(path, "footer", detail="bad magic")
            f.seek(end + _FOOTER_TAIL.size - flen)
            body = io.mutate_read(
                path, end + _FOOTER_TAIL.size - flen,
                f.read(flen - _FOOTER_TAIL.size),
            )
            if crc32c(body) != fcrc:
                raise CorruptionError(path, "footer")
            try:
                fixed = _FOOTER_FIXED.unpack_from(body, 0)
                self._offs = dict(zip(SECTIONS, fixed[:7]))
                self._ckb_len = fixed[7]
                n_blocks, bb = fixed[8], fixed[9]
                self._crcs = np.frombuffer(
                    body, "<u4", count=n_blocks, offset=_FOOTER_FIXED.size
                )
            except (struct.error, ValueError):
                raise CorruptionError(path, "footer", detail="truncated")
            self._data_start = _HEADER.size
            self._data_end = self._offs["ckb"] + self._ckb_len
            self.block_bytes = bb

    @property
    def has_ckb(self) -> bool:
        return bool(self.flags & FLAG_CKB)

    @property
    def has_exp(self) -> bool:
        """Whether the file carries per-row TTL expiries (any nonzero)."""
        return bool(self.flags & FLAG_EXP)

    @property
    def n_blocks(self) -> int:
        """Number of checksum granules covering the data region."""
        return len(self._crcs)

    def data_bytes(self) -> int:
        """Size of the data region (all sections, without header/footer)."""
        return self._data_end - self._data_start

    def attach_cache(self, cache) -> None:
        """Share a :class:`BlockCache`; subsequent block reads go via it."""
        self._cache = cache

    def attach_io(self, io) -> None:
        """Route reads through an :class:`repro.io.faults.IOContext`
        (fault injection + bounded transient-error retry)."""
        self._io = io or NULL_IO

    def block_section(self, idx: int) -> str:
        """Logical section containing granule ``idx``'s first byte —
        the ``section`` coordinate of a :class:`CorruptionError`."""
        off = self._data_start + idx * self.block_bytes
        best = SECTIONS[0]
        for name in SECTIONS:
            if self._offs[name] <= off:
                best = name
        return best

    def _section_range(self, name: str) -> tuple[int, int]:
        lens = dict(
            keys=self.n * self.kw * 4,
            vals=self.n * self.vw * 4,
            seq=self.n * 4,
            tomb=self.n,
            exp=self.n * 4 if self.has_exp else 0,
            rtombs=self.n_rtombs * _RTOMB.size,
            ckb=self._ckb_len,
        )
        off = self._offs[name]
        return off, off + lens[name]

    def section_block0(self, name: str) -> int:
        """Granule index of the first block overlapping section ``name``."""
        lo, _ = self._section_range(name)
        return (lo - self._data_start) // self.block_bytes

    def _load_block(self, idx: int, f) -> bytes:
        """Read granule ``idx`` from ``f`` and verify its CRC32C.

        Transient faults are retried (bounded by the attached
        :class:`IOContext`); a CRC mismatch raises a typed
        :class:`CorruptionError` pinned to this file/section/granule —
        corruption is never retried and never cached.
        """
        tr = _tracing.current()
        t0 = _tracing.now() if tr is not None else 0.0
        bb = self.block_bytes
        lo = self._data_start + idx * bb
        hi = min(lo + bb, self._data_end)
        io = self._io

        def attempt() -> bytes:
            io.check_read(self.path)
            f.seek(lo)
            return io.mutate_read(self.path, lo, f.read(hi - lo))

        chunk = io.run("block", attempt)
        if crc32c(chunk) != int(self._crcs[idx]):
            raise CorruptionError(self.path, self.block_section(idx), idx)
        self.disk_bytes_read += hi - lo
        if tr is not None:
            tr.leaf("disk_read", t0, _tracing.now(), bytes=hi - lo, block=idx)
        return chunk

    def _mmap_block(self, idx: int) -> memoryview:
        """Granule ``idx`` as a zero-copy view of the mapping.

        The CRC is checked (and ``disk_bytes_read`` charged — the page
        faults happen here) only on the reader's *first* touch of the
        granule; afterwards the same pages are re-served without another
        pass, even if the block cache evicted the view in between.
        """
        bb = self.block_bytes
        lo = self._data_start + idx * bb
        hi = min(lo + bb, self._data_end)
        view = memoryview(self._mm)[lo:hi]
        if idx not in self._verified:
            tr = _tracing.current()
            t0 = _tracing.now() if tr is not None else 0.0
            io = self._io
            io.run("mmap", lambda: io.check_read(self.path))
            # verify against the (possibly fault-mutated) bytes: the CRC
            # pass must see what the injected disk would have served
            probe = (
                io.mutate_read(self.path, lo, bytes(view))
                if io.has_read_mutations(self.path) else view
            )
            if crc32c(probe) != int(self._crcs[idx]):
                raise CorruptionError(self.path, self.block_section(idx), idx)
            self._verified.add(idx)
            self.disk_bytes_read += hi - lo
            if tr is not None:
                tr.leaf("disk_read", t0, _tracing.now(),
                        bytes=hi - lo, block=idx, mmap=True)
        return view

    def _block_loader(self, idx: int):
        """Miss-path loader for granule ``idx`` in the current mode."""
        if self.mode == "mmap":
            return lambda: self._mmap_block(idx)

        def load() -> bytes:
            with open(self.path, "rb") as f:
                return self._load_block(idx, f)

        return load

    def read_block(self, idx: int) -> bytes:
        """One verified checksum granule of the data region (cached)."""
        if not 0 <= idx < len(self._crcs):
            raise IndexError(f"block {idx} out of range [0, {len(self._crcs)})")
        if self._cache is None:
            return self._block_loader(idx)()
        # open-coded get_or_load: the hit path (by far the common case on
        # batched reads) must not pay a loader-closure allocation
        data = self._cache.get((self._cache_key, idx))
        if data is None:
            data = self._block_loader(idx)()
            self._cache.put((self._cache_key, idx), data)
        return data

    def section_rows_resident(self, name: str, lo: int, hi: int) -> bool:
        """Whether rows [lo, hi) of ``name`` can be served without any
        disk read or checksum pass: every covering granule is in the
        block cache (or, in mmap mode, already verified — re-slicing the
        mapping is free). Pure probe: no counters move."""
        if self._cache is None and self.mode != "mmap":
            return False
        for bi in self.section_row_blocks(name, lo, hi):
            if self.mode == "mmap" and bi in self._verified:
                continue
            if self._cache is not None and self._cache.contains(
                (self._cache_key, bi)
            ):
                continue
            return False
        return True

    def prefetch_block(self, idx: int) -> None:
        """Pull granule ``idx`` into the shared cache ahead of demand.

        The pipelining primitive behind cold-scan value-block prefetch:
        a no-op without a cache (nothing would retain the block) or when
        the block is already resident. Loads issued here are tagged by
        the cache so ``stats()['cache']`` can report hit/waste counts.
        """
        if self._cache is None or not 0 <= idx < len(self._crcs):
            return
        self._cache.prefetch((self._cache_key, idx), self._block_loader(idx))

    def read_range(self, lo: int, hi: int) -> bytes:
        """Bytes [lo, hi) of the file (data region), block-granular+verified.

        Opens the file at most once per call: a whole-section read costs
        one open + one sequential read per uncached granule, not one
        open/close cycle per 64 KB.
        """
        if hi <= lo:
            return b""
        bb = self.block_bytes
        b0 = (lo - self._data_start) // bb
        b1 = (hi - self._data_start - 1) // bb
        if self.mode == "mmap":
            # verify (and cache) covering granules, then hand out one
            # contiguous zero-copy view — no per-block join even when the
            # range straddles granule boundaries
            for bi in range(b0, b1 + 1):
                if self._cache is None:
                    self._mmap_block(bi)
                elif self._cache.get((self._cache_key, bi)) is None:
                    self._cache.put((self._cache_key, bi),
                                    self._mmap_block(bi))
            return memoryview(self._mm)[lo:hi]
        parts = []
        f = None
        try:
            for bi in range(b0, b1 + 1):
                chunk = (
                    self._cache.get((self._cache_key, bi))
                    if self._cache is not None
                    else None
                )
                if chunk is None:
                    if f is None:
                        f = open(self.path, "rb")
                    chunk = self._load_block(bi, f)
                    if self._cache is not None:
                        self._cache.put((self._cache_key, bi), chunk)
                parts.append(chunk)
        finally:
            if f is not None:
                f.close()
        buf = parts[0] if len(parts) == 1 else b"".join(parts)
        base = self._data_start + b0 * bb
        return buf[lo - base : hi - base]

    def read_section_bytes(self, name: str, lo: int, hi: int) -> bytes:
        """Bytes [lo, hi) *relative to section ``name``* (partial read)."""
        slo, shi = self._section_range(name)
        lo, hi = slo + lo, min(slo + hi, shi)
        buf = self.read_range(lo, hi)
        self.bytes_read[name] += max(0, hi - lo)
        return buf

    def _read_checked(self, name: str) -> bytes:
        """Read one section, verifying the CRC blocks that cover it."""
        lo, hi = self._section_range(name)
        buf = self.read_range(lo, hi)
        self.bytes_read[name] += hi - lo
        return buf

    def read_keys(self) -> np.ndarray:
        """(N, KW) uint32 from the keys section."""
        raw = self._read_checked("keys")
        return np.frombuffer(raw, "<u4").astype(np.uint32).reshape(
            self.n, self.kw
        )

    def read_vals(self) -> np.ndarray:
        raw = self._read_checked("vals")
        return np.frombuffer(raw, "<u4").astype(np.uint32).reshape(
            self.n, self.vw
        )

    def read_seq(self) -> np.ndarray:
        return np.frombuffer(self._read_checked("seq"), "<u4").astype(
            np.uint32
        )

    def read_tomb(self) -> np.ndarray:
        return np.frombuffer(self._read_checked("tomb"), np.uint8).astype(bool)

    def read_exp(self) -> np.ndarray:
        """(N,) uint32 absolute TTL expiries (zeros when FLAG_EXP clear)."""
        if not self.has_exp:
            return np.zeros(self.n, np.uint32)
        return np.frombuffer(self._read_checked("exp"), "<u4").astype(
            np.uint32
        )

    def read_rtombs(self) -> list[tuple[int, int, int]]:
        """Range tombstones ``(lo, hi, seq)`` recorded with this table."""
        raw = self._read_checked("rtombs")
        return [
            _RTOMB.unpack_from(raw, i * _RTOMB.size)
            for i in range(self.n_rtombs)
        ]

    def read_ckb_keys(self) -> np.ndarray | None:
        """Decode the CKB trailer to (N, KW) uint32, or None if absent."""
        if not self.has_ckb:
            return None
        return decode_ckb(self._read_checked("ckb"))

    def row_bytes(self, name: str) -> int:
        """Fixed row width (bytes) of a columnar section."""
        return dict(
            keys=self.kw * 4, vals=self.vw * 4, seq=4, tomb=1, exp=4
        )[name]

    def section_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of a columnar section, via block-granular reads.

        Only the checksum granules overlapping the requested rows are
        fetched (and, with a cache attached, retained) — the partial-load
        primitive behind cold-start queries. Returns the typed array:
        ``keys`` (M, KW) uint32, ``vals`` (M, VW) uint32, ``seq`` (M,)
        uint32, ``tomb`` (M,) bool.
        """
        lo, hi = max(0, lo), min(hi, self.n)
        rb = self.row_bytes(name)
        raw = self.read_section_bytes(name, lo * rb, hi * rb)
        return self._typed_rows(
            name, np.frombuffer(raw, np.uint8).reshape(-1, rb)
        )

    def _typed_rows(self, name: str, out: np.ndarray) -> np.ndarray:
        """(M, row_bytes) uint8 → the section's typed row array.

        Dtype reinterpretation only — no copy (the result may be a
        read-only view of a cached block buffer; row readers never
        mutate in place).
        """
        if name == "keys":
            return out.view("<u4").reshape(-1, self.kw)
        if name == "vals":
            return out.view("<u4").reshape(-1, self.vw)
        if name in ("seq", "exp"):
            return out.view("<u4").ravel()
        return out.ravel().astype(bool)

    def section_row_blocks(self, name: str, lo: int, hi: int) -> range:
        """Granule indices covering rows [lo, hi) of section ``name``.

        The prefetch planning primitive: a cold-scan pipeline maps the
        next group's row ranges to block ids here and issues
        :meth:`prefetch_block` for each, without reading anything yet.
        """
        lo, hi = max(0, lo), min(hi, self.n)
        if hi <= lo:
            return range(0)
        rb = self.row_bytes(name)
        slo, _ = self._section_range(name)
        bb = self.block_bytes
        b0 = (slo + lo * rb - self._data_start) // bb
        b1 = (slo + hi * rb - 1 - self._data_start) // bb
        return range(b0, b1 + 1)

    def section_rows_scattered(self, name: str, rows) -> np.ndarray:
        """Arbitrary rows of a columnar section, one block fetch per
        touched granule.

        The batched-read primitive: ``rows`` (M,) int — any order,
        duplicates allowed — are mapped to checksum granules, the set of
        distinct granules is fetched exactly once each (through the
        cache), and the rows are scattered out of the block buffers with
        a vectorized gather. Returns the typed array in ``rows`` order,
        like :meth:`section_rows`.
        """
        rows = np.asarray(rows, np.int64)
        rb = self.row_bytes(name)
        if rows.size == 0:
            return self._typed_rows(name, np.zeros((0, rb), np.uint8))
        if rows.min() < 0 or rows.max() >= self.n:
            raise IndexError(f"rows out of range [0, {self.n})")
        slo, _ = self._section_range(name)
        bb = self.block_bytes
        starts = slo + rows * rb - self._data_start  # data-region offsets
        b0 = starts // bb
        b1 = (starts + rb - 1) // bb
        bufs = {
            int(bi): np.frombuffer(self.read_block(int(bi)), np.uint8)
            for bi in np.unique(np.concatenate([b0, b1]))
        }
        out = np.empty((len(rows), rb), np.uint8)
        within = b0 == b1
        for bi in np.unique(b0[within]):
            m = within & (b0 == bi)
            off = starts[m] - int(bi) * bb
            out[m] = bufs[int(bi)][off[:, None] + np.arange(rb)]
        for i in np.flatnonzero(~within):  # granule-straddling rows
            head = bufs[int(b0[i])][int(starts[i] - b0[i] * bb):]
            out[i, : len(head)] = head
            out[i, len(head):] = bufs[int(b1[i])][: rb - len(head)]
        self.bytes_read[name] += len(rows) * rb
        return self._typed_rows(name, out)

    def verify(self) -> None:
        """Validate every block checksum (full-file scrub)."""
        for name in SECTIONS:
            self._read_checked(name)

    def check_blocks(self, on_block=None) -> list[int]:
        """CRC-verify every checksum granule straight off the disk.

        The scrub primitive: bypasses the block cache entirely (a scrub
        must re-read the at-rest bytes, and must not evict the serving
        working set), charges no read counters, and *collects* failures
        instead of raising — returns the list of granule indices whose
        CRC did not match. ``on_block(nbytes)`` is invoked after each
        granule so the caller can rate-limit by byte budget.
        """
        bad: list[int] = []
        io = self._io
        bb = self.block_bytes
        with open(self.path, "rb") as f:
            for idx in range(len(self._crcs)):
                lo = self._data_start + idx * bb
                hi = min(lo + bb, self._data_end)

                def attempt() -> bytes:
                    io.check_read(self.path)
                    f.seek(lo)
                    return io.mutate_read(self.path, lo, f.read(hi - lo))

                chunk = io.run("scrub", attempt)
                if crc32c(chunk) != int(self._crcs[idx]):
                    bad.append(idx)
                if on_block is not None:
                    on_block(hi - lo)
        return bad

"""Typed storage errors + a deterministic, seed-driven fault-injection shim.

Two things live here because they are two halves of one contract:

* the **error taxonomy** every ``io/`` verification site raises —
  :class:`CorruptionError` (a CRC/decode failure pinned to ``(file,
  section, block)`` coordinates), :class:`TransientIOError` (a read that
  may succeed if retried) and :class:`UnavailableSpanError` (a key span
  whose backing table was quarantined as unrecoverable) — all subclasses
  of the bare exceptions they replaced, so pre-existing ``except
  ValueError`` / ``except OSError`` call sites keep working;
* the **fault plan** that makes those paths testable without
  monkeypatching: a :class:`FaultPlan` is handed to the store via
  ``RemixDBConfig.fault_plan`` and threaded (inside an :class:`IOContext`,
  which also carries the retry budget) under ``SSTableReader``, the WAL,
  ``load_remix`` and manifest ``_atomic_write``. Every rule is matched by
  path substring and consumed deterministically — same plan + same
  workload = same failures — and unspecified offsets are drawn from the
  plan's seeded RNG, never from global randomness.

Fault kinds (mirroring the failure modes of a real disk):

=================  ==========================================================
``transient_read``  the next ``count`` reads of a matching file raise
                    :class:`TransientIOError` (``EIO``) then heal — absorbed
                    by the read path's bounded retry (``io_retries``)
``bitflip``         reads covering ``[offset, offset+nbytes)`` of a matching
                    file see XOR-corrupted bytes — caught by granule CRCs
``torn_write``      the next matching write persists only a prefix
                    (``keep`` fraction) of its payload — what a crashed
                    non-atomic write leaves behind
``fail_fsync``      the next ``count`` fsyncs of a matching file raise
                    ``OSError`` — a dying disk acknowledging nothing
=================  ==========================================================

:func:`flip_bytes` is the companion for *real* at-rest bit rot: it XORs
bytes of a file on disk in place (the scrub/repair tests corrupt real
stores with it, then prove detection + self-healing).
"""
from __future__ import annotations

import errno
import os
import random
import threading
import time


class TransientIOError(OSError):
    """A read failure that may succeed if retried (injected ``EIO``)."""

    def __init__(self, path: str, site: str = "read"):
        super().__init__(errno.EIO, f"transient I/O error ({site})", path)
        self.path = path
        self.site = site


class CorruptionError(ValueError):
    """Bytes failed verification, pinned to ``(file, section, block)``.

    ``section`` is the logical region (``"keys"``, ``"ckb"``, ``"footer"``,
    ``"remix"``, ``"manifest"``, ``"wal"`` …) and ``block`` the checksum
    granule index when one applies (else ``None``). Subclasses
    ``ValueError`` so legacy call sites catching the bare exception keep
    working.
    """

    def __init__(self, file: str, section: str | None = None,
                 block: int | None = None, detail: str = "checksum mismatch"):
        at = section or "?"
        if block is not None:
            at += f"[{block}]"
        super().__init__(f"{file}: {at}: {detail}")
        self.file = file
        self.section = section
        self.block = block
        self.detail = detail


class UnavailableSpanError(RuntimeError):
    """A key span is degraded: its backing table(s) were quarantined.

    Raised instead of serving possibly-wrong data when a read touches a
    partition whose unrecoverable table a scrub quarantined. Carries the
    span bounds so callers (executor → ``OpStatus.IO_ERROR``) can report
    which keys are unavailable rather than crashing the batch.
    """

    def __init__(self, lo: int, hi: int | None, tables: tuple[str, ...] = ()):
        span = f"[{lo}, {'inf' if hi is None else hi})"
        super().__init__(
            f"key span {span} unavailable: quarantined table(s) "
            f"{list(tables)!r}"
        )
        self.lo = lo
        self.hi = hi
        self.tables = tuple(tables)


def flip_bytes(path: str, offset: int, nbytes: int = 1, xor: int = 0xFF) -> None:
    """XOR ``nbytes`` bytes of ``path`` in place starting at ``offset`` —
    real at-rest bit rot, for corruption tests and scrub drills."""
    with open(path, "r+b") as f:
        f.seek(offset)
        buf = bytearray(f.read(nbytes))
        for i in range(len(buf)):
            buf[i] ^= xor
        f.seek(offset)
        f.write(bytes(buf))


class _Rule:
    __slots__ = ("kind", "match", "count", "offset", "nbytes", "xor", "keep")

    def __init__(self, kind, match, count=1, offset=None, nbytes=1,
                 xor=0xFF, keep=0.5):
        self.kind = kind
        self.match = match
        self.count = count  # remaining applications (-1 = unlimited)
        self.offset = offset
        self.nbytes = nbytes
        self.xor = xor
        self.keep = keep

    def matches(self, path: str) -> bool:
        return self.count != 0 and self.match in path

    def consume(self) -> None:
        if self.count > 0:
            self.count -= 1


class FaultPlan:
    """Deterministic, seed-driven schedule of storage faults.

    Rules are added up front, matched against file paths by substring,
    and consumed in order. Thread-safe (the store reads from worker
    threads). ``stats()`` reports what actually fired so tests can assert
    the plan was exercised, not silently skipped.
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self._lock = threading.Lock()
        self._rules: list[_Rule] = []
        self.fired: dict[str, int] = {
            "transient_read": 0, "bitflip": 0, "torn_write": 0,
            "fail_fsync": 0,
        }

    # ---------------- rule construction ----------------
    def transient_read(self, match: str, count: int = 1) -> "FaultPlan":
        """The next ``count`` reads of files containing ``match`` raise
        :class:`TransientIOError`, then the site heals."""
        self._rules.append(_Rule("transient_read", match, count=count))
        return self

    def bitflip(self, match: str, offset: int | None = None,
                nbytes: int = 1, xor: int = 0xFF) -> "FaultPlan":
        """Reads of a matching file whose range covers ``offset`` see the
        bytes XORed with ``xor``. ``offset=None`` picks a seeded random
        position inside the first matching read (then stays fixed)."""
        self._rules.append(
            _Rule("bitflip", match, count=-1, offset=offset, nbytes=nbytes,
                  xor=xor)
        )
        return self

    def torn_write(self, match: str, keep: float = 0.5,
                   count: int = 1) -> "FaultPlan":
        """The next ``count`` matching writes persist only the first
        ``keep`` fraction of their payload (a torn/short write)."""
        self._rules.append(_Rule("torn_write", match, count=count, keep=keep))
        return self

    def fail_fsync(self, match: str, count: int = 1) -> "FaultPlan":
        """The next ``count`` fsyncs of a matching file raise ``OSError``."""
        self._rules.append(_Rule("fail_fsync", match, count=count))
        return self

    # ---------------- hooks (called by the io/ layer) ----------------
    def check_read(self, path: str) -> None:
        """Raise :class:`TransientIOError` if a transient rule fires."""
        with self._lock:
            for r in self._rules:
                if r.kind == "transient_read" and r.matches(path):
                    r.consume()
                    self.fired["transient_read"] += 1
                    raise TransientIOError(path)

    def has_read_mutations(self, path: str) -> bool:
        with self._lock:
            return any(
                r.kind == "bitflip" and r.matches(path) for r in self._rules
            )

    def mutate_read(self, path: str, offset: int, data) -> bytes:
        """Apply bit-flip rules overlapping ``[offset, offset+len(data))``."""
        out = None
        with self._lock:
            for r in self._rules:
                if r.kind != "bitflip" or not r.matches(path):
                    continue
                if r.offset is None:  # seeded lazy placement
                    r.offset = offset + self.rng.randrange(max(1, len(data)))
                lo = max(offset, r.offset)
                hi = min(offset + len(data), r.offset + r.nbytes)
                if lo >= hi:
                    continue
                if out is None:
                    out = bytearray(data)
                for i in range(lo - offset, hi - offset):
                    out[i] ^= r.xor
                self.fired["bitflip"] += 1
        return bytes(out) if out is not None else bytes(data)

    def mutate_write(self, path: str, data: bytes) -> bytes:
        """Apply torn-write rules: returns the (possibly truncated) bytes
        that actually reach the disk."""
        with self._lock:
            for r in self._rules:
                if r.kind == "torn_write" and r.matches(path):
                    r.consume()
                    self.fired["torn_write"] += 1
                    return data[: int(len(data) * r.keep)]
        return data

    def check_fsync(self, path: str) -> None:
        with self._lock:
            for r in self._rules:
                if r.kind == "fail_fsync" and r.matches(path):
                    r.consume()
                    self.fired["fail_fsync"] += 1
                    raise OSError(errno.EIO, "injected fsync failure", path)

    def stats(self) -> dict:
        with self._lock:
            pending = sum(1 for r in self._rules if r.count != 0)
            return dict(self.fired, rules_pending=pending)


class IOContext:
    """Fault plan + retry budget, threaded as one object under the io/ layer.

    ``run(site, fn)`` executes ``fn`` with bounded retry+backoff on
    :class:`TransientIOError` (``io_retries`` attempts after the first;
    exponential backoff from ``backoff_s``). ``on_retry``/``on_giveup``
    are counter callbacks the store wires to the ``io_retry`` /
    ``io_giveup`` instruments.
    """

    __slots__ = ("plan", "retries", "backoff_s", "on_retry", "on_giveup")

    def __init__(self, plan: FaultPlan | None = None, retries: int = 2,
                 backoff_s: float = 0.0, on_retry=None, on_giveup=None):
        self.plan = plan
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.on_retry = on_retry
        self.on_giveup = on_giveup

    # fault hooks (no-ops without a plan)
    def check_read(self, path: str) -> None:
        if self.plan is not None:
            self.plan.check_read(path)

    def mutate_read(self, path: str, offset: int, data):
        if self.plan is not None:
            return self.plan.mutate_read(path, offset, data)
        return data

    def has_read_mutations(self, path: str) -> bool:
        return self.plan is not None and self.plan.has_read_mutations(path)

    def mutate_write(self, path: str, data: bytes) -> bytes:
        if self.plan is not None:
            return self.plan.mutate_write(path, data)
        return data

    def check_fsync(self, path: str) -> None:
        if self.plan is not None:
            self.plan.check_fsync(path)

    def run(self, site: str, fn):
        """``fn()`` with bounded retry on :class:`TransientIOError`."""
        attempt = 0
        while True:
            try:
                return fn()
            except TransientIOError:
                if attempt >= self.retries:
                    if self.on_giveup is not None:
                        self.on_giveup()
                    raise
                attempt += 1
                if self.on_retry is not None:
                    self.on_retry()
                if self.backoff_s > 0.0:
                    time.sleep(self.backoff_s * (2 ** (attempt - 1)))


NULL_IO = IOContext()

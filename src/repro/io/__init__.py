"""On-disk persistence for RemixDB (paper §3.4, §4; SNIPPETS.md Snippet 1).

This package is the storage layer proper: it knows about bytes, files and
checksums, and nothing about the LSM-tree above it (``repro.db`` imports
``repro.io``, never the other way around).

Modules:

- ``io.sstable``    immutable table files: columnar key/value/seq/tomb
  sections, per-64KB-block CRC32C, optional Compressed Keys Block
  trailer; block-granular verified reads (``SSTableReader.read_block`` /
  ``section_rows``).
- ``io.ckb``        prefix-compressed sorted key streams with restart
  points; ``CKBReader`` gives random access (``key_at``) and bounded
  lower-bound ``seek`` without full decodes.
- ``io.blockcache`` the shared, bytes-budgeted LRU ``BlockCache`` over
  verified granules, shared across partitions (and stores).
- ``io.remix_io``   REMIX index (de)serialization; payload length is
  asserted equal to ``Remix.storage_bytes()`` (§3.4).
- ``io.rebuild``    incremental REMIX rebuild from the old selector
  stream + the tables' CKBs — zero value bytes read.
- ``io.manifest``   versioned registry with atomic rename commits +
  orphan GC (orphans are quarantined, then age-purged).
- ``io.faults``     the typed error taxonomy (``CorruptionError``,
  ``TransientIOError``, ``UnavailableSpanError``) + the deterministic
  ``FaultPlan`` injection shim and the ``IOContext`` retry policy
  threaded under every reader/writer in this package.
- ``io.checksum``   CRC32C.

The byte-level layout of every file format lives in the versioned spec
``docs/FORMAT.md`` (executed by CI so it cannot drift from this code);
``docs/ARCHITECTURE.md`` has the write/read/recovery data-flow diagrams.
"""
from repro.io.blockcache import BlockCache  # noqa: F401
from repro.io.checksum import crc32c  # noqa: F401
from repro.io.ckb import CKBReader, decode_ckb, encode_ckb  # noqa: F401
from repro.io.faults import (  # noqa: F401
    CorruptionError,
    FaultPlan,
    IOContext,
    TransientIOError,
    UnavailableSpanError,
    flip_bytes,
)
from repro.io.manifest import Manifest, Storage  # noqa: F401
from repro.io.rebuild import (  # noqa: F401
    decode_selector_order,
    incremental_build_remix,
)
from repro.io.remix_io import dump_remix, load_remix  # noqa: F401
from repro.io.sstable import SSTableReader, write_sstable  # noqa: F401

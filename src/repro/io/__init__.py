"""On-disk persistence for RemixDB (paper §3.4, §4; SNIPPETS.md Snippet 1).

This package is the storage layer proper: it knows about bytes, files and
checksums, and nothing about the LSM-tree above it (``repro.db`` imports
``repro.io``, never the other way around).

Table-file layout (``io.sstable``)::

    +----------------------------------------------------------------+
    | header (40 B)   magic | version | kw | vw | flags | n | blksz  |
    +----------------------------------------------------------------+
    | keys  section   n * kw * 4 B   uint32 LE words, word 0 most sig|
    | vals  section   n * vw * 4 B   uint32 LE payload               |
    | seq   section   n * 4 B        uint32 sequence numbers         |
    | tomb  section   n * 1 B        uint8 tombstone flags           |
    +----------------------------------------------------------------+
    | CKB   section   prefix-compressed sorted keys (optional)       |
    +----------------------------------------------------------------+
    | footer          section offsets | per-block CRC32C table |     |
    |                 footer CRC | footer length | magic             |
    +----------------------------------------------------------------+

The data region (everything between header and footer) is covered by
CRC32C checksums computed over fixed-size blocks (default 64 KB); readers
verify exactly the blocks overlapping the section they fetch, so a
CKB-only read never touches (or validates) value bytes.

The *Compressed Keys Block* trailer re-encodes all keys of the table in
sorted order with per-key shared-prefix truncation (restart points every
16 keys). It is the only part of a table file a REMIX rebuild needs:
``io.rebuild.incremental_build_remix`` merges the surviving tables' CKB
key streams with the old REMIX's selector stream and never reads a value
block (Snippet 1's 2x write-throughput optimization).

REMIX index files (``io.remix_io``) serialize anchors | cursors |
selectors as one contiguous little-endian payload whose byte length
equals ``Remix.storage_bytes()`` exactly (checked on write), so the
paper's §3.4 space accounting is validated against real files, and the
payload can be mapped straight into numpy arrays.

Manifest commit protocol (``io.manifest``)::

    MANIFEST-<v>.tmp  --write+fsync-->  MANIFEST-<v>   (rename, atomic)
    CURRENT.tmp       --write+fsync-->  CURRENT        (rename, atomic)

A crash at any point leaves either the old or the new version readable:
table/REMIX files are immutable once written (also tmp+rename), and files
not referenced by CURRENT's manifest are orphans removed on next open.
Recovery (``RemixDB.open``) loads the manifest's partitions as
lazily-loadable table handles, restores the WAL mapping table, scans for
WAL blocks written after the last commit (1-bit epoch flip, §4.3), and
replays the live log into a fresh MemTable.
"""
from repro.io.checksum import crc32c  # noqa: F401
from repro.io.ckb import decode_ckb, encode_ckb  # noqa: F401
from repro.io.manifest import Manifest, Storage  # noqa: F401
from repro.io.rebuild import (  # noqa: F401
    decode_selector_order,
    incremental_build_remix,
)
from repro.io.remix_io import dump_remix, load_remix  # noqa: F401
from repro.io.sstable import SSTableReader, write_sstable  # noqa: F401

"""Versioned table/partition registry with atomic rename commits (§4.3).

``Manifest`` owns the commit protocol (see the package docstring diagram):
every commit writes ``MANIFEST-<v>.tmp``, fsyncs, renames it into place,
then repoints ``CURRENT`` the same way. A crash at any step leaves either
the previous or the new version fully readable. ``Storage`` layers file
allocation on top: monotonically numbered immutable table / REMIX files
plus orphan collection for files a crashed flush wrote but never
committed.

The manifest state is a plain JSON dict; ``repro.io`` imposes no schema
beyond ``{"version": int}`` so the db layer owns its own contents
(partitions, sequence number, WAL mapping table).
"""
from __future__ import annotations

import json
import os
import re

CURRENT = "CURRENT"
_MANIFEST_RE = re.compile(r"^MANIFEST-(\d{6})$")
_FILE_RE = re.compile(r"^(t|x)-(\d{6})\.(sst|rmx)$")


def live_files(state: dict) -> set[str]:
    """Table/REMIX file names a manifest state references.

    The db layer uses this for orphan collection at recovery; with the
    Version architecture the *runtime* live set is the union of this
    over every pinned :class:`repro.db.version.Version` — a commit is
    the version edge, but files are reclaimed only when the last Version
    referencing them unpins.
    """
    live: set[str] = set()
    for pe in state.get("partitions", []):
        live.update(pe.get("tables", []))
        if pe.get("remix"):
            live.add(pe["remix"])
    return live


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class Manifest:
    """The versioned registry: MANIFEST-<v> files + the CURRENT pointer."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _current_name(self) -> str | None:
        cur = os.path.join(self.root, CURRENT)
        if not os.path.exists(cur):
            return None
        with open(cur, "r") as f:
            name = f.read().strip()
        return name or None

    def current_version(self) -> int:
        name = self._current_name()
        if name is None:
            return 0
        m = _MANIFEST_RE.match(name)
        if not m:
            raise ValueError(f"corrupt CURRENT pointer: {name!r}")
        return int(m.group(1))

    def load(self) -> dict | None:
        """State of the committed version, or None for a fresh directory."""
        name = self._current_name()
        if name is None:
            return None
        path = os.path.join(self.root, name)
        if not _MANIFEST_RE.match(name) or not os.path.exists(path):
            raise ValueError(
                f"CURRENT points at {name!r} which does not exist — "
                f"corrupt manifest directory {self.root}"
            )
        with open(path, "r") as f:
            return json.load(f)

    def commit(self, state: dict) -> int:
        """Durably publish ``state`` as the next version; returns it."""
        version = self.current_version() + 1
        state = dict(state, version=version)
        name = f"MANIFEST-{version:06d}"
        _atomic_write(
            os.path.join(self.root, name),
            json.dumps(state, separators=(",", ":")).encode(),
        )
        _atomic_write(os.path.join(self.root, CURRENT), name.encode() + b"\n")
        # previous manifest versions are superseded; keep only the latest
        for f in os.listdir(self.root):
            m = _MANIFEST_RE.match(f)
            if m and int(m.group(1)) < version:
                os.remove(os.path.join(self.root, f))
        return version


class Storage:
    """File allocation + commit glue for one RemixDB data directory.

    Layout::

        <root>/CURRENT, MANIFEST-xxxxxx      (Manifest)
        <root>/tables/t-xxxxxx.sst           (immutable table files)
        <root>/remix/x-xxxxxx.rmx            (immutable REMIX files)
        <root>/wal.log                       (block-structured WAL)
    """

    def __init__(self, root: str, with_ckb: bool = True):
        self.root = root
        self.with_ckb = with_ckb
        self.manifest = Manifest(root)
        self.tables_dir = os.path.join(root, "tables")
        self.remix_dir = os.path.join(root, "remix")
        os.makedirs(self.tables_dir, exist_ok=True)
        os.makedirs(self.remix_dir, exist_ok=True)
        self.bytes_written = 0
        self._next_id = 1 + max(
            (
                int(m.group(2))
                for d in (self.tables_dir, self.remix_dir)
                for f in os.listdir(d)
                if (m := _FILE_RE.match(f))
            ),
            default=0,
        )

    def wal_path(self) -> str:
        return os.path.join(self.root, "wal.log")

    def table_path(self, name: str) -> str:
        return os.path.join(self.tables_dir, name)

    def remix_path(self, name: str) -> str:
        return os.path.join(self.remix_dir, name)

    def alloc_table_name(self) -> str:
        name = f"t-{self._next_id:06d}.sst"
        self._next_id += 1
        return name

    def alloc_remix_name(self) -> str:
        name = f"x-{self._next_id:06d}.rmx"
        self._next_id += 1
        return name

    def write_table(self, keys, vals, seq, tomb, exp=None, rtombs=None) -> str:
        """Write one table file; returns its manifest-relative name."""
        from repro.io.sstable import write_sstable

        name = self.alloc_table_name()
        self.bytes_written += write_sstable(
            self.table_path(name), keys, vals, seq, tomb,
            exp=exp, rtombs=rtombs, with_ckb=self.with_ckb,
        )
        return name

    def write_remix(self, remix) -> str:
        """Serialize one REMIX; returns its manifest-relative name."""
        from repro.io.remix_io import dump_remix

        name = self.alloc_remix_name()
        self.bytes_written += dump_remix(remix, self.remix_path(name))
        return name

    def commit(self, state: dict) -> int:
        return self.manifest.commit(state)

    def load_state(self) -> dict | None:
        return self.manifest.load()

    def gc_orphans(self, live: set[str]) -> list[str]:
        """Remove table/REMIX files not referenced by the committed state
        (left behind by a flush that crashed before its commit)."""
        removed = []
        for d in (self.tables_dir, self.remix_dir):
            for f in os.listdir(d):
                if f.endswith(".tmp") or (
                    _FILE_RE.match(f) and f not in live
                ):
                    os.remove(os.path.join(d, f))
                    removed.append(f)
        return removed

"""Versioned table/partition registry with atomic rename commits (§4.3).

``Manifest`` owns the commit protocol (see the package docstring diagram):
every commit writes ``MANIFEST-<v>.tmp``, fsyncs, renames it into place,
then repoints ``CURRENT`` the same way. A crash at any step leaves either
the previous or the new version fully readable. ``Storage`` layers file
allocation on top: monotonically numbered immutable table / REMIX files
plus orphan collection for files a crashed flush wrote but never
committed.

The manifest state is a plain JSON dict; ``repro.io`` imposes no schema
beyond ``{"version": int}`` so the db layer owns its own contents
(partitions, sequence number, WAL mapping table).
"""
from __future__ import annotations

import json
import os
import re
import time

from repro.io.faults import NULL_IO, CorruptionError

CURRENT = "CURRENT"
QUARANTINE_DIR = "quarantine"
_MANIFEST_RE = re.compile(r"^MANIFEST-(\d{6})$")
_FILE_RE = re.compile(r"^(t|x)-(\d{6})\.(sst|rmx)$")


def live_files(state: dict) -> set[str]:
    """Table/REMIX file names a manifest state references.

    The db layer uses this for orphan collection at recovery; with the
    Version architecture the *runtime* live set is the union of this
    over every pinned :class:`repro.db.version.Version` — a commit is
    the version edge, but files are reclaimed only when the last Version
    referencing them unpins.
    """
    live: set[str] = set()
    for pe in state.get("partitions", []):
        live.update(pe.get("tables", []))
        if pe.get("remix"):
            live.add(pe["remix"])
    return live


def _atomic_write(path: str, data: bytes, io=None) -> None:
    io = io or NULL_IO
    data = io.mutate_write(path, data)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        io.check_fsync(path)
        os.fsync(f.fileno())
    os.replace(tmp, path)


class Manifest:
    """The versioned registry: MANIFEST-<v> files + the CURRENT pointer."""

    def __init__(self, root: str, io=None):
        self.root = root
        self.io = io or NULL_IO
        os.makedirs(root, exist_ok=True)

    def _current_name(self) -> str | None:
        cur = os.path.join(self.root, CURRENT)
        if not os.path.exists(cur):
            return None
        with open(cur, "rb") as f:
            raw = f.read()
        try:
            name = raw.decode("ascii").strip()
        except UnicodeDecodeError:
            raise CorruptionError(
                cur, "manifest",
                detail=f"undecodable CURRENT pointer: {raw[:32]!r}",
            )
        return name or None

    def current_version(self) -> int:
        name = self._current_name()
        if name is None:
            return 0
        m = _MANIFEST_RE.match(name)
        if not m:
            raise CorruptionError(
                os.path.join(self.root, CURRENT), "manifest",
                detail=f"corrupt CURRENT pointer: {name!r}",
            )
        return int(m.group(1))

    def load(self) -> dict | None:
        """State of the committed version, or None for a fresh directory."""
        name = self._current_name()
        if name is None:
            return None
        path = os.path.join(self.root, name)
        if not _MANIFEST_RE.match(name) or not os.path.exists(path):
            raise CorruptionError(
                os.path.join(self.root, CURRENT), "manifest",
                detail=f"CURRENT points at {name!r} which does not exist — "
                       f"corrupt manifest directory {self.root}",
            )

        def attempt() -> dict:
            with open(path, "rb") as f:
                self.io.check_read(path)
                raw = self.io.mutate_read(path, 0, f.read())
            try:
                return json.loads(raw.decode())
            except (ValueError, UnicodeDecodeError):
                raise CorruptionError(
                    path, "manifest", detail="undecodable manifest JSON"
                )

        return self.io.run("manifest", attempt)

    def verify(self) -> dict | None:
        """Scrub check: CURRENT and the manifest it points at agree and
        decode. Returns the state (None for fresh); raises
        :class:`CorruptionError` on disagreement."""
        state = self.load()
        if state is not None:
            v = state.get("version")
            if v != self.current_version():
                raise CorruptionError(
                    os.path.join(self.root, CURRENT), "manifest",
                    detail=f"CURRENT version {self.current_version()} != "
                           f"manifest body version {v}",
                )
        return state

    def commit(self, state: dict) -> int:
        """Durably publish ``state`` as the next version; returns it."""
        version = self.current_version() + 1
        state = dict(state, version=version)
        name = f"MANIFEST-{version:06d}"
        _atomic_write(
            os.path.join(self.root, name),
            json.dumps(state, separators=(",", ":")).encode(),
            io=self.io,
        )
        _atomic_write(
            os.path.join(self.root, CURRENT), name.encode() + b"\n",
            io=self.io,
        )
        # previous manifest versions are superseded; keep only the latest
        for f in os.listdir(self.root):
            m = _MANIFEST_RE.match(f)
            if m and int(m.group(1)) < version:
                os.remove(os.path.join(self.root, f))
        return version


class Storage:
    """File allocation + commit glue for one RemixDB data directory.

    Layout::

        <root>/CURRENT, MANIFEST-xxxxxx      (Manifest)
        <root>/tables/t-xxxxxx.sst           (immutable table files)
        <root>/remix/x-xxxxxx.rmx            (immutable REMIX files)
        <root>/wal.log                       (block-structured WAL)
        <root>/quarantine/                   (GC'd orphans, age-purged)
    """

    def __init__(self, root: str, with_ckb: bool = True, io=None):
        self.root = root
        self.with_ckb = with_ckb
        self.io = io or NULL_IO
        self.manifest = Manifest(root, io=self.io)
        self.tables_dir = os.path.join(root, "tables")
        self.remix_dir = os.path.join(root, "remix")
        self.quarantine_dir = os.path.join(root, QUARANTINE_DIR)
        os.makedirs(self.tables_dir, exist_ok=True)
        os.makedirs(self.remix_dir, exist_ok=True)
        self.bytes_written = 0
        self._next_id = 1 + max(
            (
                int(m.group(2))
                for d in (self.tables_dir, self.remix_dir)
                for f in os.listdir(d)
                if (m := _FILE_RE.match(f))
            ),
            default=0,
        )

    def wal_path(self) -> str:
        return os.path.join(self.root, "wal.log")

    def table_path(self, name: str) -> str:
        return os.path.join(self.tables_dir, name)

    def remix_path(self, name: str) -> str:
        return os.path.join(self.remix_dir, name)

    def alloc_table_name(self) -> str:
        name = f"t-{self._next_id:06d}.sst"
        self._next_id += 1
        return name

    def alloc_remix_name(self) -> str:
        name = f"x-{self._next_id:06d}.rmx"
        self._next_id += 1
        return name

    def write_table(self, keys, vals, seq, tomb, exp=None, rtombs=None) -> str:
        """Write one table file; returns its manifest-relative name."""
        from repro.io.sstable import write_sstable

        name = self.alloc_table_name()
        self.bytes_written += write_sstable(
            self.table_path(name), keys, vals, seq, tomb,
            exp=exp, rtombs=rtombs, with_ckb=self.with_ckb, io=self.io,
        )
        return name

    def write_remix(self, remix) -> str:
        """Serialize one REMIX; returns its manifest-relative name."""
        from repro.io.remix_io import dump_remix

        name = self.alloc_remix_name()
        self.bytes_written += dump_remix(
            remix, self.remix_path(name), io=self.io
        )
        return name

    def commit(self, state: dict) -> int:
        return self.manifest.commit(state)

    def load_state(self) -> dict | None:
        return self.manifest.load()

    def gc_orphans(self, live: set[str]) -> list[str]:
        """Quarantine table/REMIX files not referenced by the committed
        state (left behind by a flush that crashed before its commit).

        Files are *moved* into ``<root>/quarantine/`` instead of unlinked
        so a mis-scoped GC (or an operator investigating corruption) can
        still recover the bytes; :meth:`purge_quarantine` expires them by
        age. ``.tmp`` leftovers carry no committed data and are deleted
        outright.
        """
        removed = []
        for d in (self.tables_dir, self.remix_dir):
            for f in os.listdir(d):
                p = os.path.join(d, f)
                if f.endswith(".tmp"):
                    os.remove(p)
                    removed.append(f)
                elif _FILE_RE.match(f) and f not in live:
                    os.makedirs(self.quarantine_dir, exist_ok=True)
                    os.replace(p, os.path.join(self.quarantine_dir, f))
                    removed.append(f)
        return removed

    def quarantine_file(self, name: str) -> str | None:
        """Move a live table/REMIX file into the quarantine directory
        (scrub found it unrecoverable); returns its new path."""
        for d in (self.tables_dir, self.remix_dir):
            p = os.path.join(d, name)
            if os.path.exists(p):
                os.makedirs(self.quarantine_dir, exist_ok=True)
                dst = os.path.join(self.quarantine_dir, name)
                os.replace(p, dst)
                return dst
        return None

    def purge_quarantine(self, max_age_s: float) -> list[str]:
        """Delete quarantined files older than ``max_age_s`` (mtime-based);
        returns the purged names. ``max_age_s <= 0`` purges everything."""
        purged = []
        if not os.path.isdir(self.quarantine_dir):
            return purged
        cutoff = time.time() - max(0.0, max_age_s)
        for f in sorted(os.listdir(self.quarantine_dir)):
            p = os.path.join(self.quarantine_dir, f)
            try:
                if os.path.getmtime(p) <= cutoff:
                    os.remove(p)
                    purged.append(f)
            except OSError:
                continue
        return purged

"""Compressed Keys Block: prefix-compressed sorted key stream (Snippet 1).

A CKB re-encodes every key of a table (no values) in sorted order. Keys are
fixed-width ``KW`` uint32-word vectors; each key is serialized big-endian
(word 0 first) so that byte-wise shared prefixes coincide with the
lexicographic word order used everywhere else. Per key the stream stores::

    u8 shared | u8 non_shared | suffix bytes

with ``shared`` forced to 0 at every restart point (default: every 16th
key), followed by a restart-offset array so future work can binary-search
within a block. Decoding is a single sequential pass.

Layout::

    magic 'CKB1' u32 | n u32 | key_bytes u16 | restart_interval u16 |
    entry stream | restarts (u32 each) | n_restarts u32
"""
from __future__ import annotations

import struct
import threading
from collections import OrderedDict

import numpy as np

from repro.obs import tracing as _tracing

MAGIC = 0x31424B43  # 'CKB1' little-endian
_HDR = struct.Struct("<IIHH")


def _key_bytes_be(keys: np.ndarray) -> np.ndarray:
    """(N, KW) uint32 -> (N, KW*4) uint8, big-endian within each word."""
    keys = np.ascontiguousarray(np.asarray(keys, np.uint32))
    n, kw = keys.shape
    return keys.astype(">u4").view(np.uint8).reshape(n, kw * 4)


def encode_ckb(keys: np.ndarray, restart_interval: int = 16) -> bytes:
    """Encode sorted (N, KW) uint32 keys into a CKB byte string."""
    keys = np.asarray(keys, np.uint32)
    if keys.ndim != 2:
        raise ValueError("CKB keys must be (N, KW) uint32")
    n, kw = keys.shape
    kb = kw * 4
    if kb > 255:
        raise ValueError("CKB supports keys up to 255 bytes")
    raw = _key_bytes_be(keys)
    shared = np.zeros(n, np.int32)
    if n > 1:
        eq = raw[1:] == raw[:-1]
        shared[1:] = np.cumprod(eq, axis=1).sum(axis=1)
    if restart_interval > 0:
        shared[::restart_interval] = 0
    parts = [_HDR.pack(MAGIC, n, kb, restart_interval)]
    restarts = []
    off = _HDR.size
    for i in range(n):
        s = int(shared[i])
        if restart_interval > 0 and i % restart_interval == 0:
            restarts.append(off)
        suffix = raw[i, s:].tobytes()
        parts.append(bytes((s, kb - s)))
        parts.append(suffix)
        off += 2 + kb - s
    parts.append(np.asarray(restarts, "<u4").tobytes())
    parts.append(struct.pack("<I", len(restarts)))
    return b"".join(parts)


class CKBReader:
    """Restart-point random access into an encoded CKB — no full decode.

    Reads go through a ``fetch(lo, hi) -> bytes`` callback over *CKB-
    relative* byte offsets, so the backing store can be an in-memory
    buffer or a block-granular (cached, checksum-verified) view of the
    CKB section of a table file. Restart points (``shared`` forced to 0
    every ``interval`` keys at encode time) make any key decodable by
    walking at most ``interval - 1`` predecessors:

      - :meth:`key_at` decodes one key by row index;
      - :meth:`seek` lower-bounds a query key within a row range by
        binary-searching the restart keys covering the range, then
        walking one restart interval — the point-lookup primitive that
        replaces full-section decodes on the cold read path;
      - :meth:`narrow_batch` is the batched variant of the restart
        search: restart keys are materialized chunk-wise into a uint64
        array (vectorized extraction — restart entries are
        self-contained, so no sequential walk) and a whole query batch
        is narrowed to one restart interval each with a single
        ``np.searchsorted``.
    """

    RESTART_CHUNK = 512  # restart keys materialized per span fetch

    def __init__(self, length: int, fetch, memo_entries: int | None = None):
        self.length = int(length)
        self._fetch = fetch
        magic, n, kb, interval = _HDR.unpack_from(fetch(0, _HDR.size), 0)
        if magic != MAGIC:
            raise ValueError("bad CKB magic")
        if kb % 4:
            raise ValueError("CKB key size must be a whole number of words")
        if interval <= 0:
            raise ValueError("CKB has no restart points (interval 0)")
        self.n = n
        self.kb = kb
        self.interval = interval
        (self.n_restarts,) = struct.unpack(
            "<I", bytes(fetch(self.length - 4, self.length))
        )
        self._entries_end = self.length - 4 - 4 * self.n_restarts
        self._restarts: np.ndarray | None = None
        # chunk-wise materialized restart keys (only for 8-byte keys):
        # value + validity, filled by _ensure_restart_chunks
        self._rk64: np.ndarray | None = None
        self._rk_valid: np.ndarray | None = None
        # interval-decode memo (8-byte keys): keys of fully decoded
        # restart intervals, so repeated batched seeks over a warm
        # working set pay the entry-stream decode once per interval.
        # Bounded LRU: ``memo_entries`` caps decoded *key* entries held
        # (None = unbounded, e.g. small in-memory CKBs); table handles
        # derive the budget from the block-cache byte budget, so the memo
        # can no longer outgrow the cache it shadows.
        self._iv: OrderedDict[int, np.ndarray] = OrderedDict()
        self.memo_entries_budget = (
            None if memo_entries is None else max(int(memo_entries), 1)
        )
        self.memo_evictions = 0
        # guards both memos (restart chunks + decoded intervals): the op
        # layer's async worker pool reads one table from several threads
        self._memo_lock = threading.Lock()

    @classmethod
    def from_bytes(cls, buf: bytes | memoryview,
                   memo_entries: int | None = None) -> "CKBReader":
        mv = memoryview(buf)
        return cls(len(mv), lambda lo, hi: bytes(mv[lo:hi]),
                   memo_entries=memo_entries)

    def memo_stats(self) -> dict:
        """Size/eviction accounting of the interval-decode memo (feeds
        the ``ckb_memo_{entries,bytes,evictions}`` registry gauges)."""
        with self._memo_lock:
            rows = len(self._iv)
            rk = 0 if self._rk64 is None else self._rk64.nbytes
            return dict(
                entries=rows * self.interval,
                bytes=rows * self.interval * 8 + rk,
                evictions=self.memo_evictions,
                budget_entries=self.memo_entries_budget,
            )

    def _restart_offsets(self) -> np.ndarray:
        if self._restarts is None:
            raw = self._fetch(self._entries_end, self.length - 4)
            self._restarts = np.frombuffer(raw, "<u4")
        return self._restarts

    def _entry_span(self, j0: int, j1: int) -> bytes:
        """Raw entry bytes from restart j0 up to restart j1 (exclusive)."""
        offs = self._restart_offsets()
        lo = int(offs[j0])
        hi = int(offs[j1]) if j1 < self.n_restarts else self._entries_end
        return self._fetch(lo, hi)

    def _walk(self, row0: int, raw: bytes, stop_row: int):
        """Decode rows [row0, stop_row) from ``raw`` (row0 on a restart).

        Yields (row, key_bytes); ``key_bytes`` is reused between yields.
        """
        prev = bytearray(self.kb)
        off = 0
        for row in range(row0, min(stop_row, self.n)):
            s, ns = raw[off], raw[off + 1]
            off += 2
            prev[s : s + ns] = raw[off : off + ns]
            off += ns
            yield row, prev

    def key_at(self, row: int) -> np.ndarray:
        """Key at ``row`` as (KW,) uint32 — decodes one restart interval."""
        if not 0 <= row < self.n:
            raise IndexError(f"row {row} out of range [0, {self.n})")
        j = row // self.interval
        raw = self._entry_span(j, j + 1)
        for r, kb in self._walk(j * self.interval, raw, row + 1):
            if r == row:
                return (
                    np.frombuffer(bytes(kb), ">u4").astype(np.uint32)
                )
        raise AssertionError("restart walk ended before target row")

    def _restart_key(self, j: int) -> bytes:
        """Key at restart ``j`` (self-contained: shared == 0 there)."""
        offs = self._restart_offsets()
        lo = int(offs[j])
        raw = self._fetch(lo, lo + 2 + self.kb)
        return bytes(raw[2 : 2 + raw[1]])

    def _ensure_restart_chunks(self, chunks) -> None:
        """Materialize restart keys for the given chunk ids as uint64.

        A chunk's restart entries live contiguously in the entry stream;
        one span fetch (block-granular, cached) plus a vectorized numpy
        gather extracts every restart key of the chunk — no per-key
        Python walk, because restart entries are self-contained
        (``shared == 0``). Requires ``kb == 8``.
        """
        with self._memo_lock:
            if self._rk64 is None:
                self._rk64 = np.zeros(self.n_restarts, np.uint64)
                self._rk_valid = np.zeros(self.n_restarts, bool)
            offs = self._restart_offsets()
            c = self.RESTART_CHUNK
            for ci in chunks:
                a, b = ci * c, min((ci + 1) * c, self.n_restarts)
                if a >= b or self._rk_valid[a]:
                    continue
                lo = int(offs[a])
                hi = int(offs[b - 1]) + 2 + self.kb
                raw = np.frombuffer(
                    self._fetch(lo, hi), np.uint8, count=hi - lo
                )
                rel = (offs[a:b].astype(np.int64) - lo)[:, None]
                kb8 = raw[rel + 2 + np.arange(self.kb)]  # (m, 8) big-endian
                self._rk64[a:b] = kb8.copy().view(">u8").ravel()
                self._rk_valid[a:b] = True

    def narrow_batch(
        self, qs: np.ndarray, los: np.ndarray, his: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Narrow each query's row range to one restart interval.

        ``qs`` (Q,) uint64 queries, ``los``/``his`` their per-query row
        ranges (non-empty, within the run). Returns ``(nlo, nhi)`` such
        that the lower bound of ``qs[i]`` within ``[los[i], his[i])``
        provably lies in ``[nlo[i], nhi[i]]`` — with ``nhi[i]`` itself
        the answer when every key of the narrowed interval is smaller
        than the query. One vectorized rightmost-restart-``<=`` search
        replaces Q binary searches; only the restart chunks the batch
        touches are materialized (and they are memoized across batches).
        """
        ii = self.interval
        ja = los // ii
        jb = np.minimum((his - 1) // ii, self.n_restarts - 1)
        c = self.RESTART_CHUNK
        if int((jb // c - ja // c).max(initial=0)) > 1:
            chunks = range(int(ja.min()) // c, int(jb.max()) // c + 1)
        else:
            chunks = np.unique(np.concatenate([ja // c, jb // c]))
        self._ensure_restart_chunks(chunks)
        # global rightmost decoded restart with key <= q, clipped per
        # query to [ja, jb]: clipping is exact because every restart of
        # [ja, jb] is decoded and restart keys ascend with j
        js = np.flatnonzero(self._rk_valid)
        idx = np.searchsorted(self._rk64[js], qs, side="right") - 1
        cand = js[np.maximum(idx, 0)]
        j = np.clip(cand, ja, jb)
        return np.maximum(los, j * ii), np.minimum(his, (j + 1) * ii)

    def decode_intervals(self, js: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized decode of whole restart intervals from the entry
        stream (requires ``kb == 8``).

        ``js`` are unique restart indices. Returns ``(keys (U, interval)
        uint64, counts (U,))`` — interval ``j``'s rows are
        ``[j*interval, j*interval + counts)`` and positions past
        ``counts`` are undefined. The prefix-compression recurrence is
        sequential *within* an interval but independent *across* them,
        so the loop runs over the ≤ ``interval`` in-interval positions
        while every gather/scatter is vectorized over all U intervals at
        once — the decoder that lets batched seeks resolve keys straight
        from the compressed stream, with no fixed-width keys-section
        reads.
        """
        if self.kb != 8:
            raise ValueError("decode_intervals requires 8-byte keys")
        js = np.asarray(js, np.int64)
        ii = self.interval
        with self._memo_lock:
            all_counts = np.minimum(self.n - js * ii, ii).astype(np.int64)
            memo = self._iv
            todo = np.array(
                [j for j in js.tolist() if j not in memo], np.int64
            )
            if len(todo):
                tr = _tracing.current()
                t0 = _tracing.now() if tr is not None else 0.0
                keys, _ = self._decode_intervals_uncached(todo)
                if tr is not None:
                    tr.leaf("ckb_decode", t0, _tracing.now(),
                            intervals=len(todo), rows=int(len(todo)) * ii)
                for r, j in enumerate(todo.tolist()):
                    memo[j] = keys[r]
            out = np.empty((len(js), ii), np.uint64)
            for r, j in enumerate(js.tolist()):
                out[r] = memo[j]  # copies the row: safe to evict below
                memo.move_to_end(j)
            budget = self.memo_entries_budget
            if budget is not None:
                max_rows = max(1, budget // ii)
                while len(memo) > max_rows:
                    memo.popitem(last=False)
                    self.memo_evictions += 1
            return out, all_counts

    def _decode_intervals_uncached(self, js: np.ndarray
                                   ) -> tuple[np.ndarray, np.ndarray]:
        offs = self._restart_offsets()
        u = len(js)
        ii = self.interval
        counts = np.minimum(self.n - js * ii, ii).astype(np.int64)
        # one span fetch per touched restart *chunk* — the same spans
        # narrow_batch already pulled through the block cache, so this
        # adds joins, not granule reads — then a shared flat byte buffer
        c = self.RESTART_CHUNK
        cj = js // c
        base = np.zeros(u, np.int64)
        chunks: list[np.ndarray] = []
        pos = 0
        for ci in np.unique(cj):
            a = int(ci) * c
            b = min(a + c, self.n_restarts)
            lo = int(offs[a])
            hi = int(offs[b]) if b < self.n_restarts else self._entries_end
            raw = np.frombuffer(
                self._fetch(lo, hi), np.uint8, count=hi - lo
            )
            chunks.append(raw)
            m = cj == ci
            base[m] = pos + (offs[js[m]].astype(np.int64) - lo)
            pos += len(raw)
        raw = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        kb = self.kb
        cur = np.zeros((u, kb), np.uint8)
        out = np.zeros((u, ii), np.uint64)
        ptr = base.copy()
        jj = np.arange(kb)
        for k in range(ii):
            act = k < counts
            p = np.where(act, ptr, 0)
            shared = raw[p].astype(np.int64)  # entry: u8 shared | u8 ns
            # fixed-width keys ⇒ ns == kb - shared: suffix byte j of the
            # key replaces positions [shared, kb)
            take = (jj[None, :] >= shared[:, None]) & act[:, None]
            src = p[:, None] + 2 + (jj[None, :] - shared[:, None])
            cur = np.where(take, raw[np.where(take, src, 0)], cur)
            out[:, k] = cur.copy().view(">u8").ravel()
            ptr = ptr + np.where(act, 2 + kb - shared, 0)
        return out, counts

    def seek_batch(
        self, qs: np.ndarray, nlo: np.ndarray, nhi: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Resolve a batch of narrowed seeks entirely from the entry
        stream: the vectorized counterpart of :meth:`seek` over ranges
        produced by :meth:`narrow_batch` (each within one restart
        interval).

        Returns ``(rows, keyat, known)``: ``rows[i]`` is the lower bound
        of ``qs[i]`` within ``[nlo[i], nhi[i]]`` (``nhi`` itself when
        every key in range is smaller); ``known[i]`` marks rows whose
        key was decoded (always, except ``rows[i] == nhi[i]``), with the
        key in ``keyat[i]`` — callers verify point hits without touching
        the fixed-width keys section.
        """
        ii = self.interval
        j = np.asarray(nlo, np.int64) // ii
        uj, inv = np.unique(j, return_inverse=True)
        keys, counts = self.decode_intervals(uj)
        krows = keys[inv]  # (Q, interval)
        cnt = counts[inv]
        valid = np.arange(ii)[None, :] < cnt[:, None]
        lt = (krows < np.asarray(qs, np.uint64)[:, None]) & valid
        rows = j * ii + lt.sum(axis=1)
        rows = np.clip(rows, nlo, nhi)
        idx = rows - j * ii
        known = idx < cnt
        keyat = krows[np.arange(len(rows)), np.minimum(idx, ii - 1)]
        keyat = np.where(known, keyat, np.uint64(0))
        return rows, keyat, known

    def seek(self, key: np.ndarray, lo: int = 0, hi: int | None = None) -> int:
        """Lower bound of ``key`` within rows [lo, hi): first row whose key
        is >= ``key``, or ``hi`` when every key in range is smaller.

        Bounded seeks ([lo, hi) from a REMIX group's cursor offsets span at
        most D rows) touch only the restart intervals covering the range,
        keeping block reads O(1) per run instead of O(log n) scattered
        probes across the whole compressed block.
        """
        hi = self.n if hi is None else min(hi, self.n)
        lo = max(0, lo)
        if hi <= lo:
            return hi
        qb = bytes(
            np.asarray(key, np.uint32).astype(">u4").view(np.uint8)
        )
        # rightmost restart in range whose key <= query: start decoding there
        ja = lo // self.interval
        jb = min((hi - 1) // self.interval, self.n_restarts - 1)
        a, b = ja, jb
        while a < b:  # invariant: answer restart in [a, b]
            mid = (a + b + 1) >> 1
            if self._restart_key(mid) <= qb:
                a = mid
            else:
                b = mid - 1
        # the answer is in interval a, or is the head row of interval a+1
        # (whose restart key is known > query): walk at most two intervals
        jend = min(a + 1, jb)
        raw = self._entry_span(a, jend + 1)
        stop = min(hi, (jend + 1) * self.interval)
        for row, kb in self._walk(a * self.interval, raw, stop):
            if row < lo:
                continue
            if bytes(kb) >= qb:
                return row
        return hi


def decode_ckb(buf: bytes | memoryview) -> np.ndarray:
    """Decode a CKB back into (N, KW) uint32 keys (sorted order)."""
    mv = memoryview(buf)
    magic, n, kb, _interval = _HDR.unpack_from(mv, 0)
    if magic != MAGIC:
        raise ValueError("bad CKB magic")
    if kb % 4:
        raise ValueError("CKB key size must be a whole number of words")
    out = np.zeros((n, kb), np.uint8)
    prev = np.zeros(kb, np.uint8)
    off = _HDR.size
    for i in range(n):
        s, ns = mv[off], mv[off + 1]
        off += 2
        prev[s : s + ns] = np.frombuffer(mv[off : off + ns], np.uint8)
        off += ns
        out[i] = prev
    return out.view(">u4").astype(np.uint32).reshape(n, kb // 4)

"""Compressed Keys Block: prefix-compressed sorted key stream (Snippet 1).

A CKB re-encodes every key of a table (no values) in sorted order. Keys are
fixed-width ``KW`` uint32-word vectors; each key is serialized big-endian
(word 0 first) so that byte-wise shared prefixes coincide with the
lexicographic word order used everywhere else. Per key the stream stores::

    u8 shared | u8 non_shared | suffix bytes

with ``shared`` forced to 0 at every restart point (default: every 16th
key), followed by a restart-offset array so future work can binary-search
within a block. Decoding is a single sequential pass.

Layout::

    magic 'CKB1' u32 | n u32 | key_bytes u16 | restart_interval u16 |
    entry stream | restarts (u32 each) | n_restarts u32
"""
from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x31424B43  # 'CKB1' little-endian
_HDR = struct.Struct("<IIHH")


def _key_bytes_be(keys: np.ndarray) -> np.ndarray:
    """(N, KW) uint32 -> (N, KW*4) uint8, big-endian within each word."""
    keys = np.ascontiguousarray(np.asarray(keys, np.uint32))
    n, kw = keys.shape
    return keys.astype(">u4").view(np.uint8).reshape(n, kw * 4)


def encode_ckb(keys: np.ndarray, restart_interval: int = 16) -> bytes:
    """Encode sorted (N, KW) uint32 keys into a CKB byte string."""
    keys = np.asarray(keys, np.uint32)
    if keys.ndim != 2:
        raise ValueError("CKB keys must be (N, KW) uint32")
    n, kw = keys.shape
    kb = kw * 4
    if kb > 255:
        raise ValueError("CKB supports keys up to 255 bytes")
    raw = _key_bytes_be(keys)
    shared = np.zeros(n, np.int32)
    if n > 1:
        eq = raw[1:] == raw[:-1]
        shared[1:] = np.cumprod(eq, axis=1).sum(axis=1)
    if restart_interval > 0:
        shared[::restart_interval] = 0
    parts = [_HDR.pack(MAGIC, n, kb, restart_interval)]
    restarts = []
    off = _HDR.size
    for i in range(n):
        s = int(shared[i])
        if restart_interval > 0 and i % restart_interval == 0:
            restarts.append(off)
        suffix = raw[i, s:].tobytes()
        parts.append(bytes((s, kb - s)))
        parts.append(suffix)
        off += 2 + kb - s
    parts.append(np.asarray(restarts, "<u4").tobytes())
    parts.append(struct.pack("<I", len(restarts)))
    return b"".join(parts)


def decode_ckb(buf: bytes | memoryview) -> np.ndarray:
    """Decode a CKB back into (N, KW) uint32 keys (sorted order)."""
    mv = memoryview(buf)
    magic, n, kb, _interval = _HDR.unpack_from(mv, 0)
    if magic != MAGIC:
        raise ValueError("bad CKB magic")
    if kb % 4:
        raise ValueError("CKB key size must be a whole number of words")
    out = np.zeros((n, kb), np.uint8)
    prev = np.zeros(kb, np.uint8)
    off = _HDR.size
    for i in range(n):
        s, ns = mv[off], mv[off + 1]
        off += 2
        prev[s : s + ns] = np.frombuffer(mv[off : off + ns], np.uint8)
        off += ns
        out[i] = prev
    return out.view(">u4").astype(np.uint32).reshape(n, kb // 4)

"""REMIX index (de)serialization (paper §3.4).

One contiguous little-endian payload — anchors | cursors | selectors —
whose byte length equals ``Remix.storage_bytes()`` exactly (asserted on
write): the paper's space accounting is validated against real files. The
payload is a straight concatenation of C-ordered arrays, so loading is a
single read + three ``np.frombuffer`` views (mmap-friendly: no per-element
parsing, no byte swapping on little-endian hosts).
"""
from __future__ import annotations

import os
import struct

import numpy as np

from repro.core.remix import Remix
from repro.io.checksum import crc32c
from repro.io.faults import NULL_IO, CorruptionError

MAGIC = b"RMIXIDX1"
VERSION = 1
_HEADER = struct.Struct("<8sHHHHIIIQ")  # magic ver kw r d | g n_slots n_entries | payload_len


def dump_remix(remix: Remix, path: str, io=None) -> int:
    """Serialize ``remix`` atomically to ``path``; returns bytes written."""
    anchors = np.ascontiguousarray(np.asarray(remix.anchors, np.uint32))
    cursors = np.ascontiguousarray(np.asarray(remix.cursors, np.int32))
    selectors = np.ascontiguousarray(np.asarray(remix.selectors, np.uint8))
    g, kw = anchors.shape
    r = cursors.shape[1]
    payload = (
        anchors.astype("<u4").tobytes()
        + cursors.astype("<i4").tobytes()
        + selectors.tobytes()
    )
    expect = int(remix.storage_bytes())
    if len(payload) != expect:
        raise AssertionError(
            f"serialized REMIX is {len(payload)} B but storage_bytes() "
            f"claims {expect} B — §3.4 accounting drifted from the format"
        )
    header = _HEADER.pack(
        MAGIC, VERSION, kw, r, remix.d, g, selectors.shape[0],
        int(np.asarray(remix.n_entries)), len(payload),
    )
    io = io or NULL_IO
    blob = io.mutate_write(
        path, header + payload + struct.pack("<I", crc32c(payload))
    )
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        io.check_fsync(path)
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return _HEADER.size + len(payload) + 4


def load_remix(path: str, io=None) -> Remix:
    """Load a serialized REMIX back into a (device-resident) Remix.

    Transient faults are retried per the :class:`IOContext`; a bad magic,
    a truncated file or a payload CRC mismatch raises a typed
    :class:`CorruptionError` with ``section="remix"`` — the scrubber's
    cue to rebuild the file from the tables' CKBs (§3.4 redundancy).
    """
    import jax.numpy as jnp

    io = io or NULL_IO

    def attempt():
        with open(path, "rb") as f:
            io.check_read(path)
            raw = io.mutate_read(path, 0, f.read())
        try:
            hdr = _HEADER.unpack_from(raw, 0)
        except struct.error:
            raise CorruptionError(path, "remix", detail="truncated header")
        magic, ver, kw, r, d, g, n_slots, n_entries, plen = hdr
        if magic != MAGIC or ver != VERSION:
            raise CorruptionError(
                path, "remix", detail="not a REMIX index file"
            )
        payload = raw[_HEADER.size:_HEADER.size + plen]
        tail = raw[_HEADER.size + plen:_HEADER.size + plen + 4]
        if len(payload) != plen or len(tail) != 4:
            raise CorruptionError(path, "remix", detail="truncated payload")
        (crc,) = struct.unpack("<I", tail)
        return hdr, payload, crc

    hdr, payload, crc = io.run("remix", attempt)
    magic, ver, kw, r, d, g, n_slots, n_entries, plen = hdr
    if crc32c(payload) != crc:
        raise CorruptionError(path, "remix")
    na, nc = g * kw * 4, g * r * 4
    if plen != na + nc + n_slots:
        raise CorruptionError(
            path, "remix",
            detail=f"payload length {plen} != storage_bytes {na + nc + n_slots}",
        )
    anchors = np.frombuffer(payload, "<u4", count=g * kw).astype(
        np.uint32
    ).reshape(g, kw)
    cursors = np.frombuffer(payload, "<i4", count=g * r, offset=na).astype(
        np.int32
    ).reshape(g, r)
    selectors = np.frombuffer(
        payload, np.uint8, count=n_slots, offset=na + nc
    ).copy()
    return Remix(
        anchors=jnp.asarray(anchors),
        cursors=jnp.asarray(cursors),
        selectors=jnp.asarray(selectors),
        n_entries=jnp.asarray(n_entries, jnp.int32),
        d=d,
    )


def check_remix(path: str, io=None) -> int:
    """Integrity-check a REMIX file at rest without touching the device.

    Scrub primitive: verifies magic/version, payload CRC, and the §3.4
    accounting invariant (payload length == anchors + cursors + selectors
    == ``storage_bytes()``). Raises :class:`CorruptionError` on any
    mismatch; returns the number of bytes read.
    """
    io = io or NULL_IO

    def attempt() -> bytes:
        with open(path, "rb") as f:
            io.check_read(path)
            return io.mutate_read(path, 0, f.read())

    raw = io.run("remix_scrub", attempt)
    try:
        hdr = _HEADER.unpack_from(raw, 0)
    except struct.error:
        raise CorruptionError(path, "remix", detail="truncated header")
    magic, ver, kw, r, d, g, n_slots, n_entries, plen = hdr
    if magic != MAGIC or ver != VERSION:
        raise CorruptionError(path, "remix", detail="not a REMIX index file")
    payload = raw[_HEADER.size:_HEADER.size + plen]
    tail = raw[_HEADER.size + plen:_HEADER.size + plen + 4]
    if len(payload) != plen or len(tail) != 4:
        raise CorruptionError(path, "remix", detail="truncated payload")
    if crc32c(payload) != struct.unpack("<I", tail)[0]:
        raise CorruptionError(path, "remix")
    if plen != g * kw * 4 + g * r * 4 + n_slots:
        raise CorruptionError(
            path, "remix",
            detail=f"payload length {plen} != storage_bytes "
                   f"{g * kw * 4 + g * r * 4 + n_slots}",
        )
    return len(raw)

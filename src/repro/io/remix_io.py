"""REMIX index (de)serialization (paper §3.4).

One contiguous little-endian payload — anchors | cursors | selectors —
whose byte length equals ``Remix.storage_bytes()`` exactly (asserted on
write): the paper's space accounting is validated against real files. The
payload is a straight concatenation of C-ordered arrays, so loading is a
single read + three ``np.frombuffer`` views (mmap-friendly: no per-element
parsing, no byte swapping on little-endian hosts).
"""
from __future__ import annotations

import os
import struct

import numpy as np

from repro.core.remix import Remix
from repro.io.checksum import crc32c

MAGIC = b"RMIXIDX1"
VERSION = 1
_HEADER = struct.Struct("<8sHHHHIIIQ")  # magic ver kw r d | g n_slots n_entries | payload_len


def dump_remix(remix: Remix, path: str) -> int:
    """Serialize ``remix`` atomically to ``path``; returns bytes written."""
    anchors = np.ascontiguousarray(np.asarray(remix.anchors, np.uint32))
    cursors = np.ascontiguousarray(np.asarray(remix.cursors, np.int32))
    selectors = np.ascontiguousarray(np.asarray(remix.selectors, np.uint8))
    g, kw = anchors.shape
    r = cursors.shape[1]
    payload = (
        anchors.astype("<u4").tobytes()
        + cursors.astype("<i4").tobytes()
        + selectors.tobytes()
    )
    expect = int(remix.storage_bytes())
    if len(payload) != expect:
        raise AssertionError(
            f"serialized REMIX is {len(payload)} B but storage_bytes() "
            f"claims {expect} B — §3.4 accounting drifted from the format"
        )
    header = _HEADER.pack(
        MAGIC, VERSION, kw, r, remix.d, g, selectors.shape[0],
        int(np.asarray(remix.n_entries)), len(payload),
    )
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(payload)
        f.write(struct.pack("<I", crc32c(payload)))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return _HEADER.size + len(payload) + 4


def load_remix(path: str) -> Remix:
    """Load a serialized REMIX back into a (device-resident) Remix."""
    import jax.numpy as jnp

    with open(path, "rb") as f:
        hdr = _HEADER.unpack(f.read(_HEADER.size))
        magic, ver, kw, r, d, g, n_slots, n_entries, plen = hdr
        if magic != MAGIC or ver != VERSION:
            raise ValueError(f"{path}: not a REMIX index file")
        payload = f.read(plen)
        (crc,) = struct.unpack("<I", f.read(4))
    if crc32c(payload) != crc:
        raise ValueError(f"{path}: REMIX payload checksum mismatch")
    na, nc = g * kw * 4, g * r * 4
    anchors = np.frombuffer(payload, "<u4", count=g * kw).astype(
        np.uint32
    ).reshape(g, kw)
    cursors = np.frombuffer(payload, "<i4", count=g * r, offset=na).astype(
        np.int32
    ).reshape(g, r)
    selectors = np.frombuffer(
        payload, np.uint8, count=n_slots, offset=na + nc
    ).copy()
    return Remix(
        anchors=jnp.asarray(anchors),
        cursors=jnp.asarray(cursors),
        selectors=jnp.asarray(selectors),
        n_entries=jnp.asarray(n_entries, jnp.int32),
        d=d,
    )

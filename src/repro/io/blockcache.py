"""Shared LRU cache of checksum-verified file blocks.

One :class:`BlockCache` instance is shared by every lazy table handle of a
store (and, via :class:`repro.serve.engine.KVServeEngine`, across stores):
the cache key is ``(file identity, block index)``, so partitions compete
for one bytes-budgeted pool instead of each hoarding private copies.
Cached payloads are the *verified* 64 KB checksum granules of SSTable
data regions — a hit skips both the disk read and the CRC32C check, which
is safe because table files are immutable and readers bind the file's
inode + mtime into the key (``SSTableReader._cache_key``): a file *name*
can be reused by a later ``Storage`` (ids restart at 1 + the highest
surviving file), but a reused name never resolves to stale blocks.

Capacity is a byte budget, not an entry count: eviction pops
least-recently-used granules until the budget holds. Hit/miss/eviction
counters feed ``RemixDB.stats()["cache"]``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Hashable

DEFAULT_CAPACITY = 64 << 20  # 64 MB


class BlockCache:
    """Bytes-budgeted LRU over immutable, already-verified file blocks."""

    def __init__(self, capacity_bytes: int = DEFAULT_CAPACITY):
        if capacity_bytes <= 0:
            raise ValueError("cache capacity must be positive")
        self.capacity_bytes = int(capacity_bytes)
        self._blocks: OrderedDict[Hashable, bytes] = OrderedDict()
        self.cached_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._blocks)

    def get(self, key: Hashable) -> bytes | None:
        """Cached payload for ``key`` (marks it most-recently-used)."""
        data = self._blocks.get(key)
        if data is None:
            self.misses += 1
            return None
        self._blocks.move_to_end(key)
        self.hits += 1
        return data

    def put(self, key: Hashable, data: bytes) -> None:
        """Insert ``data``; evicts LRU entries to stay within budget.

        Payloads larger than the whole budget are served but not cached.
        """
        old = self._blocks.pop(key, None)
        if old is not None:
            self.cached_bytes -= len(old)
        if len(data) > self.capacity_bytes:
            return
        self._blocks[key] = data
        self.cached_bytes += len(data)
        while self.cached_bytes > self.capacity_bytes:
            _, victim = self._blocks.popitem(last=False)
            self.cached_bytes -= len(victim)
            self.evictions += 1

    def get_or_load(self, key: Hashable, loader: Callable[[], bytes]) -> bytes:
        """``get`` with a miss-path ``loader()`` whose result is cached."""
        data = self.get(key)
        if data is None:
            data = loader()
            self.put(key, data)
        return data

    def clear(self) -> None:
        self._blocks.clear()
        self.cached_bytes = 0

    def stats(self) -> dict:
        return dict(
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            entries=len(self._blocks),
            cached_bytes=self.cached_bytes,
            capacity_bytes=self.capacity_bytes,
        )

"""Shared LRU cache of checksum-verified file blocks.

One :class:`BlockCache` instance is shared by every lazy table handle of a
store (and, via :class:`repro.serve.engine.KVServeEngine`, across stores):
the cache key is ``(file identity, block index)``, so partitions compete
for one bytes-budgeted pool instead of each hoarding private copies.
Cached payloads are the *verified* 64 KB checksum granules of SSTable
data regions — a hit skips both the disk read and the CRC32C check, which
is safe because table files are immutable and readers bind the file's
inode + mtime into the key (``SSTableReader._cache_key``): a file *name*
can be reused by a later ``Storage`` (ids restart at 1 + the highest
surviving file), but a reused name never resolves to stale blocks.

Capacity is a byte budget, not an entry count: eviction pops
least-recently-used granules until the budget holds. Hit/miss/eviction
counters live in a :class:`repro.obs.metrics.MetricsRegistry` (names
``cache_*``); the legacy attributes (``cache.hits`` …) and the
``stats()`` dict read straight from the registry instruments, so
``RemixDB.stats()["cache"]`` is bit-compatible with the pre-registry
layout.

Payloads are any immutable bytes-like object. In ``cache_mode="copy"``
(the default) they are heap ``bytes``; in ``cache_mode="mmap"``
(:class:`repro.io.sstable.SSTableReader`) they are zero-copy
``memoryview`` slices of the table file's mapping — the budget then
bounds *verified mapped* bytes rather than heap copies, and an eviction
merely drops the view (a later access re-serves the same pages without
another checksum pass).

Prefetch accounting (paper Fig 10 pipeline): blocks inserted through
:meth:`prefetch` are tagged until their first ``get``. A tagged block
served to a reader counts as a *prefetch hit*; a tagged block evicted
(or cleared) before anyone read it counts as *prefetch waste*. The
counters surface in ``stats()`` so cold-scan pipelining can prove it
fetches no block the eager path would not have fetched.

Tracing: when a trace is active on the calling thread (see
:mod:`repro.obs.tracing`), :meth:`get_or_load` records a ``cache_fetch``
span (with hit/miss and byte count); the miss path's ``loader()`` runs
inside it, so ``disk_read`` leaf spans from the SSTable reader nest
underneath.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Hashable

from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

DEFAULT_CAPACITY = 64 << 20  # 64 MB


class BlockCache:
    """Bytes-budgeted LRU over immutable, already-verified file blocks."""

    def __init__(self, capacity_bytes: int = DEFAULT_CAPACITY,
                 registry: "_metrics.MetricsRegistry | None" = None):
        if capacity_bytes <= 0:
            raise ValueError("cache capacity must be positive")
        self.capacity_bytes = int(capacity_bytes)
        self._blocks: OrderedDict[Hashable, bytes] = OrderedDict()
        self.cached_bytes = 0
        self._prefetched: set[Hashable] = set()
        reg = registry if registry is not None else _metrics.MetricsRegistry()
        self.registry = reg
        self._c_hits = reg.counter("cache_hits")
        self._c_misses = reg.counter("cache_misses")
        self._c_evictions = reg.counter("cache_evictions")
        self._c_pf_issued = reg.counter("cache_prefetch_issued")
        self._c_pf_hits = reg.counter("cache_prefetch_hits")
        self._c_pf_waste = reg.counter("cache_prefetch_waste")
        reg.gauge("cache_entries", fn=lambda: len(self._blocks))
        reg.gauge("cache_cached_bytes", fn=lambda: self.cached_bytes)
        reg.gauge("cache_capacity_bytes", fn=lambda: self.capacity_bytes)

    # legacy counter attributes — live views over the registry
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @property
    def evictions(self) -> int:
        return self._c_evictions.value

    @property
    def prefetch_issued(self) -> int:
        return self._c_pf_issued.value

    @property
    def prefetch_hits(self) -> int:
        return self._c_pf_hits.value

    @property
    def prefetch_waste(self) -> int:
        return self._c_pf_waste.value

    def __len__(self) -> int:
        return len(self._blocks)

    def contains(self, key: Hashable) -> bool:
        """Residence probe with **no** side effects: hit/miss counters,
        LRU order and prefetch tags are untouched. Lets readers plan
        around residency (e.g. skip pipelining a fully-warm window)
        without distorting the accounting the tests assert on."""
        return key in self._blocks

    def get(self, key: Hashable) -> bytes | None:
        """Cached payload for ``key`` (marks it most-recently-used)."""
        data = self._blocks.get(key)
        if data is None:
            self._c_misses.inc()
            return None
        self._blocks.move_to_end(key)
        self._c_hits.inc()
        if key in self._prefetched:
            self._prefetched.discard(key)
            self._c_pf_hits.inc()
        return data

    def put(self, key: Hashable, data: bytes) -> None:
        """Insert ``data``; evicts LRU entries to stay within budget.

        Payloads larger than the whole budget are served but not cached.
        """
        old = self._blocks.pop(key, None)
        if old is not None:
            self.cached_bytes -= len(old)
        if len(data) > self.capacity_bytes:
            return
        self._blocks[key] = data
        self.cached_bytes += len(data)
        while self.cached_bytes > self.capacity_bytes:
            vkey, victim = self._blocks.popitem(last=False)
            self.cached_bytes -= len(victim)
            self._c_evictions.inc()
            if vkey in self._prefetched:
                self._prefetched.discard(vkey)
                self._c_pf_waste.inc()

    def get_or_load(self, key: Hashable, loader: Callable[[], bytes]) -> bytes:
        """``get`` with a miss-path ``loader()`` whose result is cached."""
        tr = _tracing.current()
        if tr is None:
            data = self.get(key)
            if data is None:
                data = loader()
                self.put(key, data)
            return data
        with tr.span("cache_fetch") as sp:
            data = self.get(key)
            hit = data is not None
            if data is None:
                data = loader()
                self.put(key, data)
            sp.args.update(hit=hit, bytes=len(data))
        return data

    def prefetch(self, key: Hashable, loader: Callable[[], bytes]) -> None:
        """Load ``key`` into the cache ahead of demand (Fig 10 pipeline).

        No-op when the block is already resident (the demand path — or an
        earlier prefetch — won the race). A prefetched block stays tagged
        until its first :meth:`get`; see the module docstring for how the
        hit/waste counters resolve. Prefetch loads do not count as misses:
        ``misses`` keeps meaning "demand reads that had to touch disk".
        """
        if key in self._blocks:
            return
        data = loader()
        self.put(key, data)
        if key in self._blocks:  # may be budget-rejected (oversized payload)
            self._prefetched.add(key)
            self._c_pf_issued.inc()

    def clear(self) -> None:
        self._blocks.clear()
        self.cached_bytes = 0
        self._c_pf_waste.inc(len(self._prefetched))
        self._prefetched.clear()

    def stats(self) -> dict:
        return dict(
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
            entries=len(self._blocks),
            cached_bytes=self.cached_bytes,
            capacity_bytes=self.capacity_bytes,
            prefetch_issued=self.prefetch_issued,
            prefetch_hits=self.prefetch_hits,
            prefetch_waste=self.prefetch_waste,
        )

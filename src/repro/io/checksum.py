"""CRC32C (Castagnoli) — the checksum used by per-block table integrity.

Pure-Python slicing-by-8 over numpy-precomputed tables: no dependency on a
native crc32c wheel (the container has none), ~8 bytes of input per Python
loop iteration. The hot loop indexes plain Python lists and iterates a
``tolist()``-ed u64 view of the input — both several times faster than
numpy scalar indexing, which matters because every cold-read cache miss
checksums a 64 KB granule. Matches the RFC 3720 reference
(crc32c(b"123456789") == 0xE3069283).
"""
from __future__ import annotations

import numpy as np

_POLY = np.uint32(0x82F63B78)


def _make_tables() -> np.ndarray:
    t = np.zeros((8, 256), np.uint32)
    row = np.arange(256, dtype=np.uint32)
    for _ in range(8):
        row = np.where(row & 1, (row >> 1) ^ _POLY, row >> 1).astype(np.uint32)
    t[0] = row
    for k in range(1, 8):
        t[k] = (t[k - 1] >> 8) ^ t[0][t[k - 1] & 0xFF]
    return t


_T = _make_tables()
# plain lists: CPython list indexing is ~5x cheaper than numpy scalar
# indexing, and the loop below does 8 lookups per input word
_T0, _T1, _T2, _T3, _T4, _T5, _T6, _T7 = (_T[i].tolist() for i in range(8))


def crc32c(data: bytes | bytearray | memoryview, crc: int = 0) -> int:
    """CRC32C of ``data``; pass a previous value in ``crc`` to continue."""
    crc = (crc ^ 0xFFFFFFFF) & 0xFFFFFFFF
    mv = memoryview(data).cast("B")
    n = len(mv)
    n8 = n & ~7
    if n8:
        for w in np.frombuffer(mv[:n8], "<u8").tolist():
            w ^= crc
            crc = (
                _T7[w & 0xFF]
                ^ _T6[(w >> 8) & 0xFF]
                ^ _T5[(w >> 16) & 0xFF]
                ^ _T4[(w >> 24) & 0xFF]
                ^ _T3[(w >> 32) & 0xFF]
                ^ _T2[(w >> 40) & 0xFF]
                ^ _T1[(w >> 48) & 0xFF]
                ^ _T0[(w >> 56) & 0xFF]
            )
    for i in range(n8, n):
        crc = _T0[(crc ^ mv[i]) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFF

"""CRC32C (Castagnoli) — the checksum used by per-block table integrity.

Two implementations, byte-for-byte identical (asserted in
``tests/test_io.py``), no dependency on a native crc32c wheel (the
container has none):

- :func:`crc32c_py` — pure-Python slicing-by-8 over precomputed tables;
  the fallback and the reference for small inputs/tails (~8 bytes per
  loop iteration).
- a **vectorized numpy slicing-by-16** path for large buffers (every
  64 KB cache-granule verification): the per-chunk table contribution
  ``F(chunk)`` is GF(2)-linear, so all chunks are reduced with 16
  whole-array gathers, and the sequential dependency on the running CRC
  — ``crc' = F(chunk) ^ G(crc)`` with ``G`` the linear "advance 16 zero
  bytes" map — is folded in ``log2(n/16)`` vectorized rounds using
  memoized byte-tables of ``G^(2^l)``. No Python-level per-chunk loop
  remains; ~60x faster than the scalar loop on 64 KB granules.

Matches the RFC 3720 reference (crc32c(b"123456789") == 0xE3069283).
"""
from __future__ import annotations

import threading

import numpy as np

_POLY = np.uint32(0x82F63B78)
_W = 16  # vector-path chunk width (slicing-by-16)
_VECTOR_MIN = 1024  # below this the scalar loop wins (setup costs)


def _make_tables(rows: int) -> np.ndarray:
    t = np.zeros((rows, 256), np.uint32)
    row = np.arange(256, dtype=np.uint32)
    for _ in range(8):
        row = np.where(row & 1, (row >> 1) ^ _POLY, row >> 1).astype(np.uint32)
    t[0] = row
    for k in range(1, rows):
        t[k] = (t[k - 1] >> 8) ^ t[0][t[k - 1] & 0xFF]
    return t


_T = _make_tables(_W)  # row k: CRC contribution of a byte k zero-bytes early
# plain lists: CPython list indexing is ~5x cheaper than numpy scalar
# indexing, and the scalar loop below does 8 lookups per input word
_T0, _T1, _T2, _T3, _T4, _T5, _T6, _T7 = (_T[i].tolist() for i in range(8))


def crc32c_py(data: bytes | bytearray | memoryview, crc: int = 0) -> int:
    """Pure-Python slicing-by-8 CRC32C (reference / fallback path)."""
    crc = (crc ^ 0xFFFFFFFF) & 0xFFFFFFFF
    mv = memoryview(data).cast("B")
    crc = _tail(mv, 0, len(mv), crc)
    return crc ^ 0xFFFFFFFF


def _tail(mv: memoryview, lo: int, hi: int, crc: int) -> int:
    """Scalar slicing-by-8 over ``mv[lo:hi]`` on the *internal* state."""
    n8 = lo + ((hi - lo) & ~7)
    if n8 > lo:
        for w in np.frombuffer(mv[lo:n8], "<u8").tolist():
            w ^= crc
            crc = (
                _T7[w & 0xFF]
                ^ _T6[(w >> 8) & 0xFF]
                ^ _T5[(w >> 16) & 0xFF]
                ^ _T4[(w >> 24) & 0xFF]
                ^ _T3[(w >> 32) & 0xFF]
                ^ _T2[(w >> 40) & 0xFF]
                ^ _T1[(w >> 48) & 0xFF]
                ^ _T0[(w >> 56) & 0xFF]
            )
    for i in range(n8, hi):
        crc = _T0[(crc ^ mv[i]) & 0xFF] ^ (crc >> 8)
    return crc


# ---- vectorized slicing-by-16 ----
# G advances the 32-bit CRC state across one 16-byte chunk of zeros: the
# state XORs into the chunk's first 4 bytes, which use tables 15..12.
_GPOW: list[tuple[np.ndarray, ...]] = [
    (_T[15], _T[14], _T[13], _T[12])
]
_GPOW_LOCK = threading.Lock()  # guards extension (readers verify blocks
# concurrently with the flush writer under the Version architecture)


def _apply_map(tabs, x):
    """Apply a byte-decomposed 32→32 GF(2)-linear map to uint32 ``x``
    (scalar or array): T[a ^ b] == T[a] ^ T[b], so four gathers supply
    the full map."""
    g0, g1, g2, g3 = tabs
    return (
        g0[x & 0xFF]
        ^ g1[(x >> 8) & 0xFF]
        ^ g2[(x >> 16) & 0xFF]
        ^ g3[(x >> 24) & 0xFF]
    )


def _gpow(level: int):
    """Byte-tables of ``G^(2^level)`` (memoized; each level is the
    previous one composed with itself — linearity again). Extension is
    locked: entries are immutable and only ever appended, so lock-free
    reads of already-built levels stay safe."""
    if len(_GPOW) <= level:
        with _GPOW_LOCK:
            while len(_GPOW) <= level:
                prev = _GPOW[-1]
                _GPOW.append(tuple(_apply_map(prev, t) for t in prev))
    return _GPOW[level]


def _crc_chunks16(mv: memoryview, crc: int) -> int:
    """Advance the internal CRC state over ``mv`` (len % 16 == 0, > 0).

    ``state_m = G^m(state_0) ^ XOR_i G^(m-1-i)(F(chunk_i))``: the F
    terms come from 16 vectorized table gathers over the whole buffer,
    the XOR-fold is a binary tree — at each level the left half of every
    pair advances past the right half's chunks via ``G^(2^l)`` — and the
    initial state is advanced by ``G^m`` using the same memoized tables.
    """
    b = np.frombuffer(mv, np.uint8).reshape(-1, _W)
    f = _T[15][b[:, 0]]
    for j in range(1, _W):
        f = f ^ _T[15 - j][b[:, j]]
    m = len(f)
    # fold the per-chunk contributions (front-pad with zero segments:
    # G is linear, so they contribute nothing)
    cap = 1 << (m - 1).bit_length()
    if cap != m:
        f = np.concatenate([np.zeros(cap - m, np.uint32), f])
    level = 0
    while len(f) > 1:
        f = _apply_map(_gpow(level), f[0::2]) ^ f[1::2]
        level += 1
    # advance the incoming state past all m chunks
    state = np.uint32(crc)
    bit = 0
    while (1 << bit) <= m:
        if m & (1 << bit):
            state = _apply_map(_gpow(bit), state)
        bit += 1
    return int(state ^ f[0])


def crc32c(data: bytes | bytearray | memoryview, crc: int = 0) -> int:
    """CRC32C of ``data``; pass a previous value in ``crc`` to continue.

    Dispatches large buffers to the vectorized numpy slicing-by-16 path
    and finishes ragged tails (and serves small inputs) with the scalar
    loop — results are identical to :func:`crc32c_py` for every input
    and continuation split.
    """
    mv = memoryview(data).cast("B")
    n = len(mv)
    state = (crc ^ 0xFFFFFFFF) & 0xFFFFFFFF
    n16 = n & ~(_W - 1)
    if n16 >= _VECTOR_MIN:
        state = _crc_chunks16(mv[:n16], state)
        state = _tail(mv, n16, n, state)
    else:
        state = _tail(mv, 0, n, state)
    return state ^ 0xFFFFFFFF

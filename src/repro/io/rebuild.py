"""Incremental REMIX rebuild from CKBs + the old REMIX (Snippet 1, §4.2).

A minor compaction appends new table files to a partition and leaves the
existing ones untouched. The old REMIX's selector stream already encodes
the merge order of the old runs, so the new sorted view can be built by

  1. decoding the old selectors into the old runs' (run, pos) sequence —
     zero key comparisons between old runs;
  2. merging the new runs' keys among themselves (new data only);
  3. interleaving the two ordered streams with one binary search of the
     new keys into the old key stream (ties: new first, since LSM sequence
     numbers of a key are strictly increasing across flushes).

Keys come from the tables' Compressed Keys Blocks, so the rebuild reads
the old REMIX and the CKBs and never touches a value block — the 2x
random-write throughput optimization of the reference implementation.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.remix import Remix, remix_from_order
from repro.core.view import NEWEST_BIT, PLACEHOLDER, _merge_order


def decode_selector_order(
    selectors: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover (runid, pos, newest) of the real entries, in view order.

    A selector stores ``run | NEWEST_BIT`` (or PLACEHOLDER for padding)
    and entries of one run appear in run order, so the in-run position is
    just the running occurrence count of each run id.
    """
    sel = np.asarray(selectors, np.uint8)
    real = sel != PLACEHOLDER
    packed = sel[real]
    runid = (packed & (NEWEST_BIT - 1)).astype(np.int32)
    newest = (packed & NEWEST_BIT) != 0
    pos = np.zeros(runid.shape[0], np.int32)
    for r in np.unique(runid):
        m = runid == r
        pos[m] = np.arange(int(m.sum()), dtype=np.int32)
    return runid, pos, newest


def _rank(keys: np.ndarray) -> np.ndarray:
    """Map (N, KW) uint32 keys to a 1-D array with the same ordering."""
    keys = np.asarray(keys, np.uint32)
    kw = keys.shape[1]
    if kw == 1:
        return keys[:, 0]
    if kw == 2:
        return (keys[:, 0].astype(np.uint64) << np.uint64(32)) | keys[
            :, 1
        ].astype(np.uint64)
    # arbitrary width: big-endian bytes compare lexicographically
    raw = np.ascontiguousarray(keys.astype(">u4")).view(np.uint8)
    raw = raw.reshape(keys.shape[0], kw * 4)
    return np.array([r.tobytes() for r in raw], object)


def incremental_build_remix(
    old_remix: Remix,
    old_run_keys: Sequence[np.ndarray],
    new_run_keys: Sequence[np.ndarray],
    new_run_seqs: Sequence[np.ndarray],
    d: int,
) -> Remix:
    """Build the REMIX over ``old runs + new runs`` without sorting old keys.

    ``old_run_keys``: each old run's (Ni, KW) uint32 keys (typically CKB
    decodes), in the same run order the old REMIX was built with.
    ``new_run_keys``/``new_run_seqs``: the freshly written runs. Returns a
    Remix bit-identical to ``build_remix`` over all runs from scratch.
    """
    r_old = len(old_run_keys)
    if r_old == 0 or len(new_run_keys) == 0:
        raise ValueError("incremental rebuild needs >=1 old and >=1 new run")
    o_run, o_pos, _ = decode_selector_order(old_remix.selectors)
    # old stream keys, already in (key asc, seq desc) order
    ranks = [_rank(np.asarray(k, np.uint32)) for k in old_run_keys]
    o_rank = np.empty(o_run.shape[0], ranks[0].dtype)
    for r in range(r_old):
        m = o_run == r
        if m.any():
            o_rank[m] = ranks[r][o_pos[m]]
    # new stream: merge the new runs among themselves (key asc, seq desc)
    n_run, n_pos, n_keys_sorted, _ = _merge_order(
        [np.asarray(k, np.uint32) for k in new_run_keys],
        [np.asarray(s, np.uint32) for s in new_run_seqs],
    )
    n_rank = _rank(n_keys_sorted)
    # interleave: every new entry goes before old entries of equal key
    # (its seq is strictly newer), i.e. insertion point side='left'
    ins = np.searchsorted(o_rank, n_rank, side="left")
    n_total = o_rank.shape[0] + n_rank.shape[0]
    new_final = ins + np.arange(n_rank.shape[0])
    old_final = np.delete(np.arange(n_total), new_final)
    runid = np.zeros(n_total, np.int32)
    pos = np.zeros(n_total, np.int32)
    rank = np.empty(n_total, o_rank.dtype if o_rank.shape[0] else n_rank.dtype)
    runid[old_final] = o_run
    pos[old_final] = o_pos
    rank[old_final] = o_rank
    runid[new_final] = n_run + r_old
    pos[new_final] = n_pos
    rank[new_final] = n_rank
    newest = np.ones(n_total, bool)
    if n_total > 1:
        newest[1:] = rank[1:] != rank[:-1]
    all_keys = [np.asarray(k, np.uint32) for k in old_run_keys] + [
        np.asarray(k, np.uint32) for k in new_run_keys
    ]
    return remix_from_order(runid, pos, newest, all_keys, d)

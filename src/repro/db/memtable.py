"""MemTable: in-memory write buffer with per-key 8-bit update counters.

The paper (§4.2, following TRIAD) counts updates per key so that compaction
can retain frequently-updated keys in the MemTable/WAL instead of repeatedly
rewriting them into table files. Counters saturate at 255 and are halved when
a key is carried over by a compaction.

Keys are 64-bit ints; values are fixed-width uint32 word vectors. Entries
carry an optional absolute TTL expiry (``exp`` unix seconds, 0 = none).

Range tombstones (DeleteRange) live beside the point entries as a list of
``(lo, hi, seq)`` triples: live entries covered at delete time are eagerly
converted to point tombstones (entries are *replaced*, never mutated, so
snapshot views keep the pre-delete Entry objects), and the triple
itself hides every table row in [lo, hi) until the next flush turns it
into a manifest-level excised span.

Persistent layered overlay: entries are stored as a stack of immutable
layers plus one small mutable top layer. :meth:`snapshot_view` freezes
the top (an O(1) pointer push — no dict copy, however large the
MemTable) and returns a :class:`LayeredMap` over the frozen stack, so
``db.snapshot()`` is O(1) and high-pin-rate serving (replica catch-up,
per-batch snapshots) never pays an O(memtable) copy. Writes go to a
fresh top layer and can never reach a frozen view; layer count is
bounded by merging frozen layers (amortized) once it exceeds
``MAX_LAYERS``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Entry:
    seq: int
    tomb: bool
    val: np.ndarray  # (VW,) uint32
    count: int  # 8-bit update counter
    exp: int = 0  # absolute TTL expiry, unix seconds (0 = no TTL)


def entry_dead(e: Entry, now: float) -> bool:
    """True when the entry is a tombstone or its TTL has expired."""
    return e.tomb or (e.exp != 0 and e.exp <= now)


class LayeredMap:
    """Read-only dict-like view over a stack of entry dicts.

    ``layers`` is ordered newest → oldest; a key's entry is the one in
    the newest layer holding it. The view is what snapshots hold as
    their overlay: frozen views are immutable (their layers are never
    written again), the live view (``MemTable.data``) reads through to
    the mutable top layer. ``len``/``bool`` report the number of
    *distinct* keys, captured at construction.
    """

    __slots__ = ("layers", "_n")

    def __init__(self, layers, n: int):
        self.layers = tuple(layers)
        self._n = int(n)

    def get(self, key, default=None):
        for d in self.layers:
            e = d.get(key)
            if e is not None:
                return e
        return default

    def __getitem__(self, key):
        e = self.get(key)
        if e is None:
            raise KeyError(key)
        return e

    def __contains__(self, key) -> bool:
        return self.get(key) is not None

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def __iter__(self):
        if len(self.layers) == 1:
            yield from self.layers[0]
            return
        seen: set[int] = set()
        for d in self.layers:
            for k in d:
                if k not in seen:
                    seen.add(k)
                    yield k

    def keys(self):
        return iter(self)

    def values(self):
        for _, e in self.items():
            yield e

    def items(self):
        if len(self.layers) == 1:
            yield from self.layers[0].items()
            return
        seen: set[int] = set()
        for d in self.layers:
            for k, e in d.items():
                if k not in seen:
                    seen.add(k)
                    yield k, e


class MemTable:
    # frozen-layer budget: a snapshot_view() that would leave more than
    # this many frozen layers first merges them into one (new dict —
    # existing views keep their own layer tuples untouched)
    MAX_LAYERS = 4

    def __init__(self, vw: int = 2):
        self.vw = vw
        self._top: dict[int, Entry] = {}  # mutable newest layer
        self._frozen: tuple[dict, ...] = ()  # immutable, newest → oldest
        self._n = 0  # distinct keys across all layers
        self.ranges: list[tuple[int, int, int]] = []  # (lo, hi, seq)

    def __len__(self) -> int:
        return self._n

    @property
    def data(self) -> LayeredMap:
        """Live dict-like view over all layers (reads see every write;
        snapshot consumers use :meth:`snapshot_view` instead)."""
        return LayeredMap((self._top,) + self._frozen, self._n)

    def snapshot_view(self) -> LayeredMap:
        """O(1) frozen view of the current contents.

        Freezes the mutable top layer (pointer push, no copy) so later
        writes land in a fresh top and can never reach the returned
        view. Callers must hold the store's ``_state_lock`` (the same
        lock writers insert under).
        """
        if self._top:
            frozen = (self._top,) + self._frozen
            self._top = {}
            if len(frozen) > self.MAX_LAYERS:
                merged: dict[int, Entry] = {}
                for d in reversed(frozen):
                    merged.update(d)
                frozen = (merged,)
            self._frozen = frozen
        return LayeredMap(self._frozen or ({},), self._n)

    def _lookup(self, key: int) -> Entry | None:
        e = self._top.get(key)
        if e is not None:
            return e
        for d in self._frozen:
            e = d.get(key)
            if e is not None:
                return e
        return None

    def put(self, key: int, val: np.ndarray, seq: int, tomb: bool = False,
            exp: int = 0):
        prev = self._lookup(key)
        if prev is None:
            self._n += 1
            count = 1
        else:
            count = min(255, prev.count + 1)
        self._top[key] = Entry(seq=seq, tomb=tomb, val=val, count=count,
                               exp=int(exp))

    def put_batch(self, keys, vals, seq0: int, tomb=None, exp=None) -> int:
        """Vectorized put; returns the next unused sequence number."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.vw)
        tomb = np.zeros(len(keys), bool) if tomb is None else np.asarray(tomb)
        exp = (
            np.zeros(len(keys), np.uint32) if exp is None
            else np.asarray(exp, np.uint32)
        )
        seq = seq0
        for k, v, t, e in zip(keys.tolist(), vals, tomb.tolist(),
                              exp.tolist()):
            self.put(k, v, seq, t, e)
            seq += 1
        return seq

    def delete_range(self, lo: int, hi: int, seq: int):
        """Record a range tombstone [lo, hi) at sequence ``seq``.

        Covered live entries with an older seq are eagerly replaced by
        point tombstones: after this, a covered key never resurfaces from
        the overlay, and table rows are hidden by the (lo, hi, seq) triple
        until the flush attaches it to the partitions as an excised span.
        """
        for k, e in list(self.data.items()):
            if lo <= k < hi and e.seq < seq and not e.tomb:
                self._top[k] = Entry(
                    seq=seq, tomb=True,
                    val=np.zeros(self.vw, np.uint32), count=e.count,
                )
        self.ranges.append((int(lo), int(hi), int(seq)))

    def purge_range(self, lo: int, hi: int) -> int:
        """Drop every entry with key in [lo, hi) and clip buffered range
        tombstones to the outside of it (shard absorb/merge: the span's
        authoritative state now comes from the absorbed shard). Collapses
        the layer stack; existing snapshot views are unaffected (they
        hold their own layer tuples). Returns the number dropped."""
        kept = {
            k: e for k, e in self.data.items() if not (lo <= k < hi)
        }
        dropped = self._n - len(kept)
        self._top = kept
        self._frozen = ()
        self._n = len(kept)
        ranges: list[tuple[int, int, int]] = []
        for rlo, rhi, s in self.ranges:
            if rlo < lo and rlo < min(rhi, lo):
                ranges.append((rlo, min(rhi, lo), s))
            if rhi > hi and max(rlo, hi) < rhi:
                ranges.append((max(rlo, hi), rhi, s))
        self.ranges = ranges
        return dropped

    def covers(self, key: int) -> bool:
        """True when any buffered range tombstone covers ``key``."""
        return any(lo <= key < hi for lo, hi, _ in self.ranges)

    def carry_over(self, key: int, entry: Entry):
        """Re-insert a compaction-excluded hot key (counter halving, §4.2)."""
        cur = self._lookup(key)
        if cur is None:
            self._n += 1
            self._top[key] = Entry(
                seq=entry.seq, tomb=entry.tomb, val=entry.val,
                count=max(1, entry.count // 2), exp=entry.exp,
            )
        else:
            # newer update already buffered: fold the halved old count in
            # (entries are replaced, not mutated — frozen views may share
            # the current object)
            self._top[key] = Entry(
                seq=cur.seq, tomb=cur.tomb, val=cur.val,
                count=min(255, cur.count + max(1, entry.count // 2)),
                exp=cur.exp,
            )

    def get(self, key: int) -> Entry | None:
        return self._lookup(key)

    def sorted_items(self):
        return sorted(self.data.items())

    def range_items(self, lo: int, hi: int):
        return [(k, e) for k, e in self.sorted_items() if lo <= k < hi]

    def approx_bytes(self, key_bytes: int = 8) -> int:
        return self._n * (key_bytes + 4 * self.vw + 8)

    def to_arrays(self):
        items = self.sorted_items()
        keys = np.array([k for k, _ in items], np.uint64)
        vals = (
            np.stack([e.val for _, e in items])
            if items
            else np.zeros((0, self.vw), np.uint32)
        )
        seq = np.array([e.seq for _, e in items], np.uint32)
        tomb = np.array([e.tomb for _, e in items], bool)
        counts = np.array([e.count for _, e in items], np.int32)
        exp = np.array([e.exp for _, e in items], np.uint32)
        return keys, vals, seq, tomb, counts, exp

"""MemTable: in-memory write buffer with per-key 8-bit update counters.

The paper (§4.2, following TRIAD) counts updates per key so that compaction
can retain frequently-updated keys in the MemTable/WAL instead of repeatedly
rewriting them into table files. Counters saturate at 255 and are halved when
a key is carried over by a compaction.

Keys are 64-bit ints; values are fixed-width uint32 word vectors. Entries
carry an optional absolute TTL expiry (``exp`` unix seconds, 0 = none).

Range tombstones (DeleteRange) live beside the point entries as a list of
``(lo, hi, seq)`` triples: live entries covered at delete time are eagerly
converted to point tombstones (entries are *replaced*, never mutated, so
snapshot dict copies keep the pre-delete Entry objects), and the triple
itself hides every table row in [lo, hi) until the next flush turns it
into a manifest-level excised span.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Entry:
    seq: int
    tomb: bool
    val: np.ndarray  # (VW,) uint32
    count: int  # 8-bit update counter
    exp: int = 0  # absolute TTL expiry, unix seconds (0 = no TTL)


def entry_dead(e: Entry, now: float) -> bool:
    """True when the entry is a tombstone or its TTL has expired."""
    return e.tomb or (e.exp != 0 and e.exp <= now)


class MemTable:
    def __init__(self, vw: int = 2):
        self.vw = vw
        self.data: dict[int, Entry] = {}
        self.ranges: list[tuple[int, int, int]] = []  # (lo, hi, seq)

    def __len__(self) -> int:
        return len(self.data)

    def put(self, key: int, val: np.ndarray, seq: int, tomb: bool = False,
            exp: int = 0):
        prev = self.data.get(key)
        count = 1 if prev is None else min(255, prev.count + 1)
        self.data[key] = Entry(seq=seq, tomb=tomb, val=val, count=count,
                               exp=int(exp))

    def put_batch(self, keys, vals, seq0: int, tomb=None, exp=None) -> int:
        """Vectorized put; returns the next unused sequence number."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.vw)
        tomb = np.zeros(len(keys), bool) if tomb is None else np.asarray(tomb)
        exp = (
            np.zeros(len(keys), np.uint32) if exp is None
            else np.asarray(exp, np.uint32)
        )
        seq = seq0
        for k, v, t, e in zip(keys.tolist(), vals, tomb.tolist(),
                              exp.tolist()):
            self.put(k, v, seq, t, e)
            seq += 1
        return seq

    def delete_range(self, lo: int, hi: int, seq: int):
        """Record a range tombstone [lo, hi) at sequence ``seq``.

        Covered live entries with an older seq are eagerly replaced by
        point tombstones: after this, a covered key never resurfaces from
        the overlay, and table rows are hidden by the (lo, hi, seq) triple
        until the flush attaches it to the partitions as an excised span.
        """
        for k, e in list(self.data.items()):
            if lo <= k < hi and e.seq < seq and not e.tomb:
                self.data[k] = Entry(
                    seq=seq, tomb=True,
                    val=np.zeros(self.vw, np.uint32), count=e.count,
                )
        self.ranges.append((int(lo), int(hi), int(seq)))

    def covers(self, key: int) -> bool:
        """True when any buffered range tombstone covers ``key``."""
        return any(lo <= key < hi for lo, hi, _ in self.ranges)

    def carry_over(self, key: int, entry: Entry):
        """Re-insert a compaction-excluded hot key (counter halving, §4.2)."""
        cur = self.data.get(key)
        if cur is None:
            self.data[key] = Entry(
                seq=entry.seq, tomb=entry.tomb, val=entry.val,
                count=max(1, entry.count // 2), exp=entry.exp,
            )
        else:
            # newer update already buffered: fold the halved old count in
            cur.count = min(255, cur.count + max(1, entry.count // 2))

    def get(self, key: int) -> Entry | None:
        return self.data.get(key)

    def sorted_items(self):
        return sorted(self.data.items())

    def range_items(self, lo: int, hi: int):
        return [(k, e) for k, e in sorted(self.data.items()) if lo <= k < hi]

    def approx_bytes(self, key_bytes: int = 8) -> int:
        return len(self.data) * (key_bytes + 4 * self.vw + 8)

    def to_arrays(self):
        items = self.sorted_items()
        keys = np.array([k for k, _ in items], np.uint64)
        vals = (
            np.stack([e.val for _, e in items])
            if items
            else np.zeros((0, self.vw), np.uint32)
        )
        seq = np.array([e.seq for _, e in items], np.uint32)
        tomb = np.array([e.tomb for _, e in items], bool)
        counts = np.array([e.count for _, e in items], np.int32)
        exp = np.array([e.exp for _, e in items], np.uint32)
        return keys, vals, seq, tomb, counts, exp

"""MemTable: in-memory write buffer with per-key 8-bit update counters.

The paper (§4.2, following TRIAD) counts updates per key so that compaction
can retain frequently-updated keys in the MemTable/WAL instead of repeatedly
rewriting them into table files. Counters saturate at 255 and are halved when
a key is carried over by a compaction.

Keys are 64-bit ints; values are fixed-width uint32 word vectors.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Entry:
    seq: int
    tomb: bool
    val: np.ndarray  # (VW,) uint32
    count: int  # 8-bit update counter


class MemTable:
    def __init__(self, vw: int = 2):
        self.vw = vw
        self.data: dict[int, Entry] = {}

    def __len__(self) -> int:
        return len(self.data)

    def put(self, key: int, val: np.ndarray, seq: int, tomb: bool = False):
        prev = self.data.get(key)
        count = 1 if prev is None else min(255, prev.count + 1)
        self.data[key] = Entry(seq=seq, tomb=tomb, val=val, count=count)

    def put_batch(self, keys, vals, seq0: int, tomb=None) -> int:
        """Vectorized put; returns the next unused sequence number."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.vw)
        tomb = np.zeros(len(keys), bool) if tomb is None else np.asarray(tomb)
        seq = seq0
        for k, v, t in zip(keys.tolist(), vals, tomb.tolist()):
            self.put(k, v, seq, t)
            seq += 1
        return seq

    def carry_over(self, key: int, entry: Entry):
        """Re-insert a compaction-excluded hot key (counter halving, §4.2)."""
        cur = self.data.get(key)
        if cur is None:
            self.data[key] = Entry(
                seq=entry.seq, tomb=entry.tomb, val=entry.val,
                count=max(1, entry.count // 2),
            )
        else:
            # newer update already buffered: fold the halved old count in
            cur.count = min(255, cur.count + max(1, entry.count // 2))

    def get(self, key: int) -> Entry | None:
        return self.data.get(key)

    def sorted_items(self):
        return sorted(self.data.items())

    def range_items(self, lo: int, hi: int):
        return [(k, e) for k, e in sorted(self.data.items()) if lo <= k < hi]

    def approx_bytes(self, key_bytes: int = 8) -> int:
        return len(self.data) * (key_bytes + 4 * self.vw + 8)

    def to_arrays(self):
        items = self.sorted_items()
        keys = np.array([k for k, _ in items], np.uint64)
        vals = (
            np.stack([e.val for _, e in items])
            if items
            else np.zeros((0, self.vw), np.uint32)
        )
        seq = np.array([e.seq for _, e in items], np.uint32)
        tomb = np.array([e.tomb for _, e in items], bool)
        counts = np.array([e.count for _, e in items], np.int32)
        return keys, vals, seq, tomb, counts

"""Typed operation model: the logical half of the v2 query API.

The public surface of the store is a small algebra of **operations** —
``Get`` / ``MultiGet`` / ``Scan`` / ``Put`` / ``Delete`` — carried in a
:class:`Batch` and submitted through one entry point
(``engine.submit(batch) -> future``, see :mod:`repro.db.executor`). This
is the KV-Tandem-style split the ROADMAP asks for: a narrow logical API
(this module: plain dataclasses, no I/O, no JAX) compiled by a
planner–executor onto the physical LSM engine (snapshots, REMIX cursors,
the vectorized cold paths, the WAL group commit).

Every op carries an optional ``deadline_ms`` (relative to submission)
and a ``priority`` scheduling hint. Results come back as one
:class:`OpResult` per op with an explicit :class:`OpStatus` — a deadline
miss or cancellation marks *that op* and never poisons the rest of the
batch.

``Put``/``Delete`` accept either a scalar key or a key array: the
vectorized forms are first-class ops (a ``put_batch`` is one ``Put`` op
over N keys), so a single op can group-commit through the WAL and
fan out across shards.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.db import clock


def _ttl_to_exp(ttl) -> int | np.ndarray:
    """seconds-from-now (scalar or per-key array) -> absolute u32 expiry."""
    if ttl is None:
        return 0
    now = int(clock.now())
    if np.ndim(ttl) == 0:
        return now + int(ttl)
    return (np.asarray(ttl, np.int64) + now).astype(np.uint32)


class OpKind(enum.Enum):
    GET = "get"
    MULTIGET = "multiget"
    SCAN = "scan"
    PUT = "put"
    DELETE = "delete"
    DELETE_RANGE = "delete_range"
    CAS = "cas"


READ_KINDS = frozenset((OpKind.GET, OpKind.MULTIGET, OpKind.SCAN))
WRITE_KINDS = frozenset(
    (OpKind.PUT, OpKind.DELETE, OpKind.DELETE_RANGE, OpKind.CAS)
)


class OpStatus(enum.Enum):
    OK = "ok"
    DEADLINE_EXCEEDED = "deadline_exceeded"
    CANCELLED = "cancelled"
    ERROR = "error"
    # a typed storage failure (CorruptionError / exhausted
    # TransientIOError / UnavailableSpanError): the corrupt granule
    # fails only the ops that touch it, never the whole batch
    IO_ERROR = "io_error"


class OpInterrupted(Exception):
    """Raised inside the execution engine when an in-flight op's deadline
    expires or its batch is cancelled mid-run (see ``RemixCursor``'s
    ``interrupt`` hook); converted to a per-op status by the executor."""

    def __init__(self, status: OpStatus):
        super().__init__(status.value)
        self.status = status


@dataclasses.dataclass(frozen=True)
class Op:
    """One typed operation. Build via the factory classmethods — the
    constructor is shape-agnostic and does no validation beyond them."""

    kind: OpKind
    key: int = 0  # Get / scalar Put / scalar Delete
    keys: np.ndarray | None = None  # MultiGet / vectorized Put / Delete
    start: int = 0  # Scan / DeleteRange lower bound (inclusive)
    n: int = 0  # Scan result budget
    val: np.ndarray | None = None  # Put value row(s) / Cas new value
    with_vals: bool = True  # Scan: materialize value rows too
    deadline_ms: float | None = None  # relative to submit()
    priority: int = 0  # scheduling hint (higher first among reads)
    end: int = 0  # DeleteRange upper bound (exclusive)
    expect: np.ndarray | None = None  # Cas expected value (None = absent)
    exp: int | np.ndarray = 0  # Put/Cas absolute TTL expiry (0 = none)

    # ---------------- factories ----------------
    @classmethod
    def get(cls, key: int, *, deadline_ms: float | None = None,
            priority: int = 0) -> "Op":
        return cls(OpKind.GET, key=int(key), deadline_ms=deadline_ms,
                   priority=priority)

    @classmethod
    def multiget(cls, keys, *, deadline_ms: float | None = None,
                 priority: int = 0) -> "Op":
        return cls(OpKind.MULTIGET, keys=np.asarray(keys, np.uint64),
                   deadline_ms=deadline_ms, priority=priority)

    @classmethod
    def scan(cls, start: int, n: int, *, with_vals: bool = True,
             deadline_ms: float | None = None, priority: int = 0) -> "Op":
        if n < 0:
            raise ValueError("scan budget n must be >= 0")
        return cls(OpKind.SCAN, start=int(start), n=int(n),
                   with_vals=with_vals, deadline_ms=deadline_ms,
                   priority=priority)

    @classmethod
    def put(cls, key, val, *, ttl: float | None = None,
            deadline_ms: float | None = None, priority: int = 0) -> "Op":
        """Scalar (``key`` int) or vectorized (``key`` array) upsert.

        ``ttl`` (seconds, scalar or per-key array) converts to an
        absolute expiry against :func:`repro.db.clock.now` at op
        construction; after it passes, reads treat the key as absent.
        """
        exp = _ttl_to_exp(ttl)
        if np.ndim(key) == 0:
            return cls(OpKind.PUT, key=int(key),
                       val=np.asarray(val, np.uint32), exp=exp,
                       deadline_ms=deadline_ms, priority=priority)
        keys = np.asarray(key, np.uint64)
        vals = np.asarray(val, np.uint32)
        if len(keys):
            vals = vals.reshape(len(keys), -1)
        else:
            vals = vals.reshape(0, vals.shape[-1] if vals.ndim else 1)
        return cls(OpKind.PUT, keys=keys, val=vals, exp=exp,
                   deadline_ms=deadline_ms, priority=priority)

    @classmethod
    def delete(cls, key, *, deadline_ms: float | None = None,
               priority: int = 0) -> "Op":
        if np.ndim(key) == 0:
            return cls(OpKind.DELETE, key=int(key),
                       deadline_ms=deadline_ms, priority=priority)
        return cls(OpKind.DELETE, keys=np.asarray(key, np.uint64),
                   deadline_ms=deadline_ms, priority=priority)

    @classmethod
    def delete_range(cls, start: int, end: int, *,
                     deadline_ms: float | None = None,
                     priority: int = 0) -> "Op":
        """Delete every key in [start, end) as one range tombstone —
        O(1) written regardless of how many keys the span covers."""
        if end < start:
            raise ValueError("delete_range needs start <= end")
        return cls(OpKind.DELETE_RANGE, start=int(start), end=int(end),
                   deadline_ms=deadline_ms, priority=priority)

    @classmethod
    def cas(cls, key: int, expect, val, *, ttl: float | None = None,
            deadline_ms: float | None = None, priority: int = 0) -> "Op":
        """Compare-and-swap: install ``val`` (or delete, when ``val`` is
        None) iff the key's current visible value equals ``expect``
        (``expect=None`` = expect-absent). The result's ``found`` is the
        success flag and ``value`` the actual pre-op value on conflict."""
        return cls(
            OpKind.CAS, key=int(key),
            expect=None if expect is None else np.asarray(expect, np.uint32),
            val=None if val is None else np.asarray(val, np.uint32),
            exp=_ttl_to_exp(ttl), deadline_ms=deadline_ms, priority=priority,
        )

    # ---------------- introspection ----------------
    @property
    def is_read(self) -> bool:
        return self.kind in READ_KINDS

    def write_rows(self) -> int:
        """Rows a write op commits (0 for reads)."""
        if self.kind not in WRITE_KINDS:
            return 0
        if self.kind is OpKind.DELETE_RANGE:
            return 1  # one range-tombstone record, whatever it covers
        return 1 if self.keys is None else len(self.keys)

    def cost_bytes(self, vw: int) -> int:
        """Admission-control estimate of the op's in-flight footprint."""
        row = 8 + 4 * vw
        if self.kind is OpKind.GET:
            return row
        if self.kind is OpKind.MULTIGET:
            return row * len(self.keys)
        if self.kind is OpKind.SCAN:
            return row * max(1, self.n)
        return row * self.write_rows()

    def __repr__(self) -> str:
        bits = [self.kind.value]
        if self.kind is OpKind.SCAN:
            bits.append(f"start={self.start}, n={self.n}")
        elif self.kind is OpKind.DELETE_RANGE:
            bits.append(f"start={self.start}, end={self.end}")
        elif self.keys is not None:
            bits.append(f"keys={len(self.keys)}")
        else:
            bits.append(f"key={self.key}")
        if self.deadline_ms is not None:
            bits.append(f"deadline_ms={self.deadline_ms}")
        if self.priority:
            bits.append(f"priority={self.priority}")
        return f"Op({', '.join(bits)})"


class Batch:
    """An ordered list of ops submitted as one unit.

    Semantics: a batch is equivalent to issuing its ops **in order**
    through the legacy methods (property-tested) — reads grouped and
    vectorized per shard between write edges, writes group-committed.
    Builder methods chain::

        b = Batch().put(1, [1, 0]).get(1).scan(0, 8)
        res = db.submit(b).result()

    ``trace=True`` opts this batch into op-lifecycle tracing regardless
    of the executor's ``trace_sample_rate``: the executor records a span
    tree (admission → plan → per-shard groups → cache/disk/CKB leaves)
    and returns it on ``BatchResult.trace``.
    """

    def __init__(self, ops: list[Op] | None = None, *, trace: bool = False):
        self.ops: list[Op] = list(ops) if ops else []
        self.trace = bool(trace)

    def add(self, op: Op) -> "Batch":
        self.ops.append(op)
        return self

    def get(self, key: int, **kw) -> "Batch":
        return self.add(Op.get(key, **kw))

    def multiget(self, keys, **kw) -> "Batch":
        return self.add(Op.multiget(keys, **kw))

    def scan(self, start: int, n: int, **kw) -> "Batch":
        return self.add(Op.scan(start, n, **kw))

    def put(self, key, val, **kw) -> "Batch":
        return self.add(Op.put(key, val, **kw))

    def delete(self, key, **kw) -> "Batch":
        return self.add(Op.delete(key, **kw))

    def delete_range(self, start: int, end: int, **kw) -> "Batch":
        return self.add(Op.delete_range(start, end, **kw))

    def cas(self, key: int, expect, val, **kw) -> "Batch":
        return self.add(Op.cas(key, expect, val, **kw))

    def __len__(self) -> int:
        return len(self.ops)

    def __iter__(self):
        return iter(self.ops)

    def cost_bytes(self, vw: int) -> int:
        return sum(op.cost_bytes(vw) for op in self.ops)

    def __repr__(self) -> str:
        kinds: dict[str, int] = {}
        for op in self.ops:
            kinds[op.kind.value] = kinds.get(op.kind.value, 0) + 1
        return f"Batch({kinds})"


@dataclasses.dataclass
class OpResult:
    """Outcome of one op. Which payload fields are set depends on kind:

    - Get: ``found`` / ``value`` (None when absent)
    - MultiGet: ``found (Q,)`` / ``vals (Q, VW)``
    - Scan: ``keys (M,)`` / ``vals (M, VW)`` (vals None with
      ``with_vals=False``), M <= n
    - Put / Delete / DeleteRange: status only
    - Cas: ``found`` = swap succeeded; on conflict ``value`` holds the
      actual current value (None when the key was absent)
    """

    status: OpStatus = OpStatus.OK
    found: np.ndarray | bool | None = None
    value: np.ndarray | None = None
    keys: np.ndarray | None = None
    vals: np.ndarray | None = None
    error: str | None = None
    # the captured exception behind an ERROR status: per-op isolation
    # inside a batch, but the legacy wrappers re-raise it unchanged
    exc: BaseException | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def ok(self) -> bool:
        return self.status is OpStatus.OK

    def raise_if_error(self) -> None:
        """Re-raise an ERROR/IO_ERROR op's original exception (wrapper
        helper).

        The captured traceback is reattached so the re-raise points at
        the frame that actually failed inside the executor, not here.
        """
        if self.status in (OpStatus.ERROR, OpStatus.IO_ERROR):
            if self.exc is not None:
                raise self.exc.with_traceback(self.exc.__traceback__)
            raise RuntimeError(self.error or "op failed")


@dataclasses.dataclass
class BatchResult:
    """Per-op results (batch order) + the batch's execution stats.

    ``trace`` carries the :class:`repro.obs.tracing.Trace` span tree when
    the batch was traced (``Batch(trace=True)`` or sampled), else None.
    """

    results: list[OpResult]
    stats: dict
    trace: object | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i: int) -> OpResult:
        return self.results[i]

    def __iter__(self):
        return iter(self.results)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

"""Write-ahead log with *virtual logs* (paper §4.3).

One physical file holds a sequence of 4 KB blocks. A *virtual log* is a
mapping table (list of physical block ids + expected 1-bit epoch + validity
bitmap). Garbage collection builds a new virtual log in the same file:
blocks with >= 1/4 of their data still valid are remapped as-is (their
bitmap masks dead records); sparser blocks are freed and their survivors
rewritten. Each block's first byte carries the 1-bit epoch that flips on
every physical overwrite, so recovery can distinguish remapped-valid blocks
from stale *unwritten* blocks, exactly as in the paper.

Record format inside a block (fixed width): key u64 | seq u32 | flags u32 |
exp u32 | VW*u32 value. Records never span blocks. ``flags`` bit 0 is the
point-tombstone bit; bit 1 marks a *range tombstone* (DeleteRange): key
holds the inclusive lower bound, the first two value words pack the
exclusive upper bound (lo 32 bits then hi 32 bits), and ``exp`` is unused.
``exp`` on ordinary records is the absolute TTL expiry in unix seconds
(0 = no TTL).

Durability is a policy knob (``sync_policy``), mirroring the usual LSM
WAL options:

- ``"block"`` (default): group commit — records buffer in memory until a
  4 KB block fills, and the block write is fsynced immediately. A crash
  loses at most one partial block of un-flushed appends; an explicit
  ``sync()`` (or ``close()``) flushes and fsyncs the tail.
- ``"always"``: every append is flushed and fsynced before returning —
  per-put durability at the cost of one (possibly near-empty) block per
  record until GC repacks them.
- ``"none"``: blocks are written when full but only fsynced by an
  explicit ``sync()``/``close()`` — fastest, loses the OS write-back
  window on power failure.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import struct

import numpy as np

from repro.io.checksum import crc32c
from repro.io.faults import NULL_IO, CorruptionError
from repro.obs import metrics as _metrics

BLOCK = 4096
# 1-bit epoch in byte 0 + u16 record count + u32 CRC32C of the record
# payload (bytes HDR..HDR+n*rec_size). A CRC of 0 marks a legacy block
# written before checksums existed and skips verification.
HDR = 8
_HDR_STRUCT = struct.Struct("<BxHI")

FLAG_TOMB = 1  # record is a point tombstone
FLAG_RANGE = 2  # record is a range tombstone (key=lo, val[0:2]=hi)


def _rec_size(vw: int) -> int:
    return 8 + 4 + 4 + 4 + 4 * vw


def pack_range_hi(hi: int, vw: int) -> np.ndarray:
    """Encode a range tombstone's exclusive upper bound in the value words."""
    if vw < 2:
        raise ValueError("range tombstones need vw >= 2")
    v = np.zeros(vw, np.uint32)
    v[0] = hi & 0xFFFFFFFF
    v[1] = (hi >> 32) & 0xFFFFFFFF
    return v


def unpack_range_hi(val: np.ndarray) -> int:
    return int(val[0]) | (int(val[1]) << 32)


@dataclasses.dataclass
class BlockMap:
    """Mapping-table entry for one block of a virtual log."""

    phys: int  # physical block index in the file
    epoch: int  # expected 1-bit value (paper: inverted for unwritten blocks)
    written: bool  # False => 'unwritten' placeholder slot
    bitmap: int  # validity bitmap over records (bit i = record i live)
    # highest live seq in the block, None when unknown (e.g. restored
    # from an old checkpoint) — lets read_from() skip whole blocks at or
    # below a replication checkpoint without reading them
    max_seq: int | None = None


class VirtualLog:
    """The active virtual log: mapping table + append cursor."""

    def __init__(self, timestamp: int):
        self.timestamp = timestamp
        self.blocks: list[BlockMap] = []


class WAL:
    SYNC_POLICIES = ("none", "block", "always")

    def __init__(
        self,
        path: str,
        vw: int = 2,
        capacity_blocks: int = 1 << 20,
        sync_policy: str = "block",
        registry: "_metrics.MetricsRegistry | None" = None,
        ioctx=None,
    ):
        if sync_policy not in self.SYNC_POLICIES:
            raise ValueError(
                f"sync_policy must be one of {self.SYNC_POLICIES}, "
                f"got {sync_policy!r}"
            )
        self.path = path
        self.vw = vw
        self.ioctx = ioctx or NULL_IO
        self.sync_policy = sync_policy
        self.rec_size = _rec_size(vw)
        self.recs_per_block = (BLOCK - HDR) // self.rec_size
        self.capacity_blocks = capacity_blocks
        self.epoch_bits: dict[int, int] = {}  # phys block -> current 1-bit
        self.free: list[int] = []
        # blocks freed by a GC whose mapping table is not yet durably
        # committed: reusing them would corrupt the checkpointed virtual
        # log, so they are held here until release_quarantine()
        self.quarantine: list[int] = []
        self.next_phys = 0
        self.vlog = VirtualLog(timestamp=1)
        self._pending: list[tuple[int, int, int, int, np.ndarray]] = []
        self._dirty = False  # blocks written since the last fsync
        # physical write accounting (for WA ratios) — registry-backed;
        # the legacy ``wal.bytes_written`` attribute reads it back out
        reg = registry if registry is not None else _metrics.MetricsRegistry()
        self._c_bytes_written = reg.counter("wal_bytes_written")
        self._c_blocks_flushed = reg.counter("wal_blocks_flushed")
        self._c_fsyncs = reg.counter("wal_fsyncs")
        self._c_gc_rounds = reg.counter("wal_gc_rounds")
        reg.gauge("wal_used_blocks", fn=self.used_blocks)
        reg.gauge("wal_free_blocks", fn=lambda: len(self.free))
        # highest sequence number ever appended — the durable sequence
        # horizon. Checkpointed with the mapping table and advanced by
        # tail recovery, so a reopened store never reissues a seq that a
        # (possibly GC-masked) record already consumed; Versions adopt it
        # as their seq_horizon floor.
        self.max_seq = 0
        if not os.path.exists(path):
            with open(path, "wb"):
                pass

    @property
    def bytes_written(self) -> int:
        return self._c_bytes_written.value

    # ---------- append path ----------
    def append(self, key: int, seq: int, tomb: bool, val: np.ndarray,
               exp: int = 0, flags: int | None = None):
        fl = (FLAG_TOMB if tomb else 0) if flags is None else flags
        self._pending.append(
            (key, seq, fl, int(exp), np.asarray(val, np.uint32))
        )
        self.max_seq = max(self.max_seq, int(seq))
        if self.sync_policy == "always":
            self._flush_pending()
            self._fsync()
        elif len(self._pending) >= self.recs_per_block:
            self._flush_pending()
            if self.sync_policy == "block":
                self._fsync()

    def append_range(self, lo: int, hi: int, seq: int):
        """Durably record a DeleteRange [lo, hi) at sequence ``seq``."""
        self.append(lo, seq, False, pack_range_hi(hi, self.vw),
                    flags=FLAG_RANGE)

    def append_batch(self, keys, seqs, tombs, vals, exps=None):
        if exps is None:
            exps = (0,) * len(keys)
        for k, s, t, v, e in zip(keys, seqs, tombs, vals, exps):
            self._pending.append(
                (int(k), int(s), FLAG_TOMB if t else 0, int(e), v)
            )
            self.max_seq = max(self.max_seq, int(s))
        flushed = False
        while len(self._pending) >= self.recs_per_block:
            self._flush_pending()
            flushed = True
        if self.sync_policy == "always":
            self._flush_pending()
            flushed = True
        if flushed and self.sync_policy in ("block", "always"):
            self._fsync()

    def _alloc_block(self) -> int:
        if self.free:
            return self.free.pop()
        phys = self.next_phys
        self.next_phys += 1
        if phys >= self.capacity_blocks:
            raise RuntimeError("WAL capacity exceeded (4 GB budget, §4.3)")
        return phys

    def _flush_pending(self):
        if not self._pending:
            return
        n = min(len(self._pending), self.recs_per_block)
        recs, self._pending = self._pending[:n], self._pending[n:]
        phys = self._alloc_block()
        epoch = self.epoch_bits.get(phys, 0) ^ 1  # flips on every overwrite
        self.epoch_bits[phys] = epoch
        buf = io.BytesIO()
        for k, s, fl, e, v in recs:
            buf.write(struct.pack("<QIII", k, s, fl, e))
            buf.write(np.asarray(v, np.uint32).tobytes())
        payload = buf.getvalue()
        data = (_HDR_STRUCT.pack(epoch, n, crc32c(payload)) + payload).ljust(
            BLOCK, b"\0"
        )
        data = self.ioctx.mutate_write(self.path, data)
        with open(self.path, "r+b") as f:
            f.seek(phys * BLOCK)
            f.write(data)
        self._dirty = True
        self._c_bytes_written.inc(BLOCK)
        self._c_blocks_flushed.inc()
        self.vlog.blocks.append(
            BlockMap(phys=phys, epoch=epoch, written=True,
                     bitmap=(1 << n) - 1,
                     max_seq=max(int(s) for _, s, _, _, _ in recs))
        )

    def _fsync(self):
        """fsync the log file if blocks were written since the last one."""
        if self._dirty:
            with open(self.path, "rb") as f:
                self.ioctx.check_fsync(self.path)
                os.fsync(f.fileno())
            self._dirty = False
            self._c_fsyncs.inc()

    def sync(self):
        """Flush buffered records to blocks and fsync them to disk: after
        sync() returns, everything appended so far survives power loss."""
        while self._pending:
            self._flush_pending()
        self._fsync()

    # ---------- read / recovery path ----------
    def _read_block(self, phys: int, strict: bool = True):
        """Read + verify one physical block (retried on transient faults).

        A failed payload CRC means the block's bytes are not what was
        durably acknowledged: with ``strict`` that raises a typed
        :class:`CorruptionError` (the block is part of the committed
        mapping — its loss must be surfaced, never silently replayed);
        tail recovery passes ``strict=False`` to treat a torn candidate
        block as never-written instead (returns ``(None, [])``).
        """
        ioctx = self.ioctx

        def attempt() -> bytes:
            with open(self.path, "rb") as f:
                ioctx.check_read(self.path)
                f.seek(phys * BLOCK)
                return ioctx.mutate_read(
                    self.path, phys * BLOCK, f.read(BLOCK)
                )

        data = ioctx.run("wal", attempt)
        try:
            epoch, n, crc = _HDR_STRUCT.unpack_from(data, 0)
        except struct.error:
            if strict:
                raise CorruptionError(
                    self.path, "wal", phys, detail="truncated block"
                )
            return None, []
        bad = (
            n > self.recs_per_block
            or len(data) < HDR + n * self.rec_size
            or (crc != 0 and crc32c(data[HDR:HDR + n * self.rec_size]) != crc)
        )
        if bad:
            if strict:
                raise CorruptionError(self.path, "wal", phys)
            return None, []
        recs = []
        off = HDR
        for _ in range(n):
            k, s, fl, e = struct.unpack_from("<QIII", data, off)
            v = np.frombuffer(
                data, np.uint32, count=self.vw, offset=off + 20
            ).copy()
            recs.append((k, s, fl, e, v))
            off += self.rec_size
        return epoch, recs

    def replay(self):
        """Yield all live records ``(key, seq, flags, exp, val)`` of the
        current virtual log, in log order."""
        self.sync()
        for bm in self.vlog.blocks:
            if not bm.written:
                continue
            epoch, recs = self._read_block(bm.phys)
            if epoch != bm.epoch:  # stale block: treat as unwritten (§4.3)
                continue
            for i, rec in enumerate(recs):
                if bm.bitmap >> i & 1:
                    yield rec

    def read_from(self, seq: int):
        """Tail-follow: yield live records with sequence > ``seq``.

        The replication catch-up primitive — a follower that has applied
        everything up to a checkpoint ``seq`` replays only what came
        after. Blocks whose tracked ``max_seq`` is at or below the floor
        are skipped without touching disk (no full-epoch rescan); blocks
        restored from an old checkpoint have an unknown ``max_seq`` and
        are read once, after which the bound is cached on the mapping
        entry. Callers must serialize against gc() (the store's write
        lock does this — see ``RemixDB.replication_snapshot``).
        """
        self.sync()
        floor = int(seq)
        for bm in self.vlog.blocks:
            if not bm.written:
                continue
            if bm.max_seq is not None and bm.max_seq <= floor:
                continue
            epoch, recs = self._read_block(bm.phys)
            if epoch != bm.epoch:
                continue
            if bm.max_seq is None:
                live_seqs = [
                    int(s) for i, (_, s, _, _, _) in enumerate(recs)
                    if bm.bitmap >> i & 1
                ]
                bm.max_seq = max(live_seqs, default=0)
                if bm.max_seq <= floor:
                    continue
            for i, rec in enumerate(recs):
                if bm.bitmap >> i & 1 and int(rec[1]) > floor:
                    yield rec

    # ---------- garbage collection ----------
    def gc(self, live_keys: set[int], defer_free: bool = False,
           live_range_seqs: set[int] | None = None):
        """Build a new virtual log keeping only records of ``live_keys``
        (plus range tombstones whose seq is in ``live_range_seqs`` — ranges
        already committed to the manifest as excised spans are droppable).

        Blocks with >= 1/4 valid records are remapped with a masking bitmap;
        others are freed and their survivors rewritten (batched re-append).

        With ``defer_free`` the freed blocks are quarantined instead of
        returned to the free list: until the new mapping table is durably
        committed, the previous checkpoint still references them, and a
        crash between GC and commit must find their contents intact. Call
        :meth:`release_quarantine` after the commit.
        """
        self.sync()
        self._c_gc_rounds.inc()
        ranges = live_range_seqs if live_range_seqs is not None else set()
        new = VirtualLog(timestamp=self.vlog.timestamp + 1)
        rewrite: list[tuple[int, int, int, int, np.ndarray]] = []
        freed = []

        def _alive(k, s, fl):
            if fl & FLAG_RANGE:
                return s in ranges
            return k in live_keys

        for bm in self.vlog.blocks:
            if not bm.written:
                continue
            epoch, recs = self._read_block(bm.phys)
            if epoch != bm.epoch:
                continue
            live = [
                i
                for i, (k, s, fl, e, v) in enumerate(recs)
                if (bm.bitmap >> i & 1) and _alive(k, s, fl)
            ]
            if len(recs) and len(live) * 4 >= len(recs):
                bitmap = 0
                for i in live:
                    bitmap |= 1 << i
                new.blocks.append(
                    BlockMap(phys=bm.phys, epoch=bm.epoch, written=True,
                             bitmap=bitmap,
                             max_seq=max(int(recs[i][1]) for i in live))
                )
            else:
                for i in live:
                    rewrite.append(recs[i])
                freed.append(bm.phys)
                # record as unwritten in the new mapping table with the
                # *inverted* epoch so a scan detects it as not-yet-written
                new.blocks.append(
                    BlockMap(
                        phys=bm.phys,
                        epoch=self.epoch_bits.get(bm.phys, 0) ^ 1,
                        written=False,
                        bitmap=0,
                    )
                )
        self.vlog = new
        (self.quarantine if defer_free else self.free).extend(freed)
        self._pending.extend(rewrite)
        self.sync()

    def release_quarantine(self):
        """Return quarantined blocks to the free list (mapping committed)."""
        self.free.extend(self.quarantine)
        self.quarantine = []

    # ---------- checkpoint / crash recovery ----------
    def save_state(self) -> dict:
        """JSON-safe snapshot of the mapping table for a manifest commit.

        Quarantined blocks are saved as free: the state being committed is
        exactly what makes their reuse safe again.
        """
        self.sync()
        return dict(
            timestamp=self.vlog.timestamp,
            max_seq=self.max_seq,
            next_phys=self.next_phys,
            free=sorted(self.free + self.quarantine),
            epoch=[[k, v] for k, v in sorted(self.epoch_bits.items())],
            blocks=[
                [b.phys, b.epoch, int(b.written), b.bitmap,
                 -1 if b.max_seq is None else b.max_seq]
                for b in self.vlog.blocks
            ],
        )

    def restore_state(self, state: dict):
        """Adopt a checkpointed mapping table (inverse of save_state)."""
        self.vlog = VirtualLog(timestamp=int(state["timestamp"]))
        self.vlog.blocks = [
            BlockMap(phys=b[0], epoch=b[1], written=bool(b[2]), bitmap=b[3],
                     # 5th element (max seq, -1 = unknown) is absent in
                     # checkpoints written before tail-follow existed
                     max_seq=(None if len(b) < 5 or b[4] < 0 else int(b[4])))
            for b in state["blocks"]
        ]
        self.next_phys = int(state["next_phys"])
        self.max_seq = int(state.get("max_seq", 0))
        self.free = [int(b) for b in state["free"]]
        self.quarantine = []
        self.epoch_bits = {int(k): int(v) for k, v in state["epoch"]}
        self._pending = []

    def recover_tail(self) -> int:
        """Adopt blocks written after the checkpoint (epoch flip scan, §4.3).

        Appends since the last commit went either to checkpoint-free blocks
        or past ``next_phys``; in both cases the block's on-disk epoch bit
        is the checkpointed expectation flipped. Returns #blocks adopted.
        """
        n_phys = os.path.getsize(self.path) // BLOCK
        candidates = sorted(set(self.free) | set(range(self.next_phys, n_phys)))
        adopted = 0
        for phys in candidates:
            if phys >= n_phys:
                continue
            epoch, recs = self._read_block(phys, strict=False)
            if epoch != self.epoch_bits.get(phys, 0) ^ 1 or not recs:
                continue
            self.epoch_bits[phys] = epoch
            self.max_seq = max(
                self.max_seq, max(int(s) for _, s, _, _, _ in recs)
            )
            if phys in self.free:
                self.free.remove(phys)
            self.next_phys = max(self.next_phys, phys + 1)
            self.vlog.blocks.append(
                BlockMap(phys=phys, epoch=epoch, written=True,
                         bitmap=(1 << len(recs)) - 1,
                         max_seq=max(int(s) for _, s, _, _, _ in recs))
            )
            adopted += 1
        return adopted

    def manifest(self) -> str:
        return json.dumps(
            dict(
                timestamp=self.vlog.timestamp,
                blocks=[dataclasses.asdict(b) for b in self.vlog.blocks],
            )
        )

    def used_blocks(self) -> int:
        return sum(1 for b in self.vlog.blocks if b.written)

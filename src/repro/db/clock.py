"""Wall-clock source for per-key TTL expiry.

Every liveness decision (``exp != 0 and exp <= clock.now()``) goes
through :func:`now` so tests can drive a logical clock: monkeypatch
``repro.db.clock.now`` (or use :func:`set_source`) and expiry becomes
deterministic. ``exp`` values are absolute unix seconds stored as u32;
0 means "no TTL".
"""
from __future__ import annotations

import time as _time

_source = _time.time


def now() -> float:
    """Current time in seconds (patchable)."""
    return _source()


def set_source(fn) -> None:
    """Install an alternative time source (tests: a logical clock)."""
    global _source
    _source = fn


def reset() -> None:
    global _source
    _source = _time.time

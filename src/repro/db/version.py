"""Immutable, refcounted store Versions (LevelDB-style version set, §4.2–4.3).

A :class:`Version` is a frozen snapshot of the store below the MemTable:
the partition list (each partition owning its immutable tables + REMIX)
plus the sequence horizon that was current when the version was created.
``flush()``/compaction never mutate a published Version — they build new
:class:`~repro.db.partition.Partition` objects off to the side (table
writes, incremental REMIX rebuild, manifest commit = the version edge)
and publish them through :meth:`VersionSet.publish`, a pointer swap.

In-flight readers *pin* the Version they started on; a retired Version —
and the tables/REMIXes only it references — is released when its last
pin drops, never mid-read. The release callback lets the store fold the
retired tables' I/O accounting and garbage-collect files that were kept
on disk solely for that Version.

:class:`Snapshot` is the read-side handle: a pinned Version plus a frozen
MemTable overlay, giving every query issued through it the exact store
contents at creation time regardless of concurrent flushes. Snapshots
are context managers; the store's own ``get``/``scan`` calls use
ephemeral (unpinned) snapshots of the live state.
"""
from __future__ import annotations

import os
import threading


class Version:
    """One immutable store version: partitions + sequence horizon."""

    __slots__ = ("vid", "partitions", "seq_horizon", "refs")

    def __init__(self, vid: int, partitions, seq_horizon: int):
        self.vid = vid
        self.partitions = tuple(partitions)
        self.seq_horizon = int(seq_horizon)
        self.refs = 0  # managed by VersionSet under its lock

    def __repr__(self) -> str:
        return (
            f"Version(vid={self.vid}, partitions={len(self.partitions)}, "
            f"seq_horizon={self.seq_horizon}, refs={self.refs})"
        )

    def file_names(self) -> set[str]:
        """Manifest-relative table/REMIX file names this version pins."""
        live: set[str] = set()
        for p in self.partitions:
            for t in p.tables:
                if t.path is not None:
                    live.add(os.path.basename(t.path))
            if p.remix_name:
                live.add(p.remix_name)
        return live

    def tables(self):
        for p in self.partitions:
            yield from p.tables


class VersionSet:
    """The registry of live Versions + the ``current`` pointer.

    ``publish`` installs a new current Version (the pointer swap at the
    end of a flush); the previous current keeps serving any reader that
    pinned it and is released — triggering ``on_release(version,
    remaining_live)`` — only when its last pin drops. All refcount state
    is guarded by one lock so readers can pin from any thread while a
    flush publishes.
    """

    def __init__(self, on_release=None, registry=None):
        # reentrant: a cyclic-GC-collected Snapshot's finalizer may call
        # unpin() on the very thread that is inside publish()/pin_current
        # holding this lock — a plain Lock would self-deadlock. Reentrant
        # unpins are safe: they run at points where the registry is
        # consistent, and the ``v is not self.current`` guard keeps the
        # in-flight publish's versions alive.
        self._lock = threading.RLock()
        self._live: dict[int, Version] = {}
        self._next_vid = 1
        self.current: Version | None = None
        self.on_release = on_release
        if registry is None:
            from repro.obs import metrics as _metrics

            registry = _metrics.MetricsRegistry()
        self._c_publishes = registry.counter("versions_published")
        self._c_releases = registry.counter("versions_released")
        registry.gauge("versions_live", fn=lambda: len(self._live))
        registry.gauge("versions_pinned", fn=lambda: self.stats()["pinned"])

    def publish(self, partitions, seq_horizon: int) -> Version:
        """Install a new current Version; the old one is unpinned (and
        released immediately when no reader holds it)."""
        with self._lock:
            v = Version(self._next_vid, partitions, seq_horizon)
            self._next_vid += 1
            v.refs = 1  # the ``current`` pointer's own pin
            self._live[v.vid] = v
            old, self.current = self.current, v
        self._c_publishes.inc()
        if old is not None:
            self.unpin(old)
        return v

    def pin_current(self) -> Version:
        with self._lock:
            v = self.current
            v.refs += 1
            return v

    def unpin(self, v: Version) -> None:
        fire = False
        with self._lock:
            v.refs -= 1
            if v.refs == 0 and v is not self.current:
                del self._live[v.vid]
                remaining = list(self._live.values())
                fire = True
        if fire:
            self._c_releases.inc()
            if self.on_release is not None:
                self.on_release(v, remaining)

    def live_versions(self) -> list[Version]:
        with self._lock:
            return list(self._live.values())

    def stats(self) -> dict:
        with self._lock:
            return dict(
                current=self.current.vid if self.current else 0,
                live=len(self._live),
                pinned=max(0, (self.current.refs - 1) if self.current else 0)
                + sum(
                    v.refs
                    for v in self._live.values()
                    if v is not self.current
                ),
            )


class Snapshot:
    """A consistent read view: pinned Version + frozen MemTable overlay.

    Every read issued through a Snapshot — ``get``/``get_batch``/
    ``scan``/``scan_batch``/``cursor`` — observes exactly the store
    contents at creation time: concurrent flushes publish new Versions
    without touching this one, and the overlay is a point-in-time copy
    of the MemTable (writes after the snapshot go to the live dict).

    Obtained from :meth:`repro.db.store.RemixDB.snapshot` (pinned; use as
    a context manager or call :meth:`close`). The store's direct read
    methods use ephemeral unpinned snapshots of the live state, so both
    paths run the same query code.
    """

    def __init__(self, store, version: Version, overlay: dict,
                 seq: int, pinned: bool = False, shared: bool = False,
                 ranges: tuple = ()):
        self.store = store
        self.version = version
        self.overlay = overlay  # key -> MemTable Entry (frozen iff copied)
        # overlay range tombstones (lo, hi, seq): DeleteRanges buffered in
        # the (frozen) MemTable at creation — they hide every table row in
        # [lo, hi) until a flush converts them to partition excised spans
        self.ranges = tuple(ranges)
        # sequence horizon at creation: every write with seq < this is
        # visible (version.seq_horizon covers the table state; overlay
        # entries extend visibility up to this snapshot's horizon)
        self.seq = int(seq)
        self.pinned = pinned
        # shared=True: overlay IS the store's live MemTable dict (the
        # ephemeral per-call view) — iterating it must coordinate with
        # writers via store._state_lock; a public snapshot()'s private
        # copy needs no such care
        self.shared = shared
        self.closed = False

    @property
    def partitions(self):
        return self.version.partitions

    def covers(self, key: int) -> bool:
        """Whether an overlay range tombstone hides table rows at ``key``
        (overlay *entries* for the key take precedence — check them
        first; any entry newer than the range was written after it)."""
        return any(lo <= key < hi for lo, hi, _ in self.ranges)

    # ---- reads (delegating to the store's shared query engine) ----
    def get(self, key: int):
        return self.store._get_at(self, key)

    def get_batch(self, keys):
        return self.store._get_batch_at(self, keys)

    def scan(self, start_key: int, n: int):
        return self.store._scan_at(self, start_key, n)

    def scan_batch(self, starts, n: int):
        return self.store._scan_batch_at(self, starts, n)

    def cursor(self, start: int = 0, width: int = 64):
        """A :class:`repro.db.cursor.RemixCursor` positioned at the lower
        bound of ``start`` over this snapshot's merged view."""
        from repro.db.cursor import RemixCursor

        cur = RemixCursor(self, width=width)
        cur.seek(start)
        return cur

    # ---- lifecycle ----
    def close(self) -> None:
        """Drop the pin; idempotent. After the last snapshot of a retired
        Version closes, its exclusively-owned tables/files are released."""
        if self.pinned and not self.closed:
            self.closed = True
            self.store.versions.unpin(self.version)

    def __enter__(self) -> "Snapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def __repr__(self) -> str:
        return (
            f"Snapshot(version={self.version.vid}, seq={self.seq}, "
            f"overlay={len(self.overlay)}, pinned={self.pinned}, "
            f"closed={self.closed})"
        )

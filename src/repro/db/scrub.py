"""Background integrity scrub over the committed store state.

The scrubber walks a pinned Version's files **at rest** — every table
checksum granule (via :meth:`repro.io.sstable.SSTableReader.check_blocks`,
which bypasses the block cache so the serving working set is never
evicted or polluted), every REMIX payload CRC + structural length
(:func:`repro.io.remix_io.check_remix`), and CURRENT/manifest agreement
(:meth:`repro.io.manifest.Manifest.verify`) — under a byte-budget rate
limit, and reports findings as ``(file, section, blocks)`` coordinates.

Repair itself lives in :meth:`repro.db.store.RemixDB.scrub`: a corrupt
REMIX is rebuilt from the tables' Compressed Keys Blocks (the §3.4
redundancy — zero value bytes read) and committed as a new manifest
version; a table with unrecoverable granules is dropped from the
manifest with its key span recorded, so reads over that span degrade to
a typed :class:`repro.io.faults.UnavailableSpanError` instead of
silently missing rows. :func:`rebuild_remix` is the shared rebuild
primitive (also exercised directly by the fault-matrix tests).
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.io.faults import CorruptionError


@dataclasses.dataclass
class Finding:
    """One scrub detection, pinned to file coordinates.

    ``kind`` routes the repair: ``"table"`` (quarantine + degrade),
    ``"remix"`` (rebuild from CKBs), ``"manifest"`` (surfaced only —
    the manifest is the root of trust, nothing to rebuild it from).
    """

    kind: str  # "table" | "remix" | "manifest"
    file: str
    section: str | None = None
    blocks: tuple = ()
    detail: str = "checksum mismatch"

    def to_dict(self) -> dict:
        return dict(
            kind=self.kind,
            file=os.path.basename(self.file),
            section=self.section,
            blocks=list(self.blocks),
            detail=self.detail,
        )


@dataclasses.dataclass
class ScrubReport:
    files_checked: int = 0
    bytes_read: int = 0
    findings: list = dataclasses.field(default_factory=list)
    repaired: list = dataclasses.field(default_factory=list)
    quarantined: list = dataclasses.field(default_factory=list)
    duration_s: float = 0.0

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return dict(
            clean=self.clean,
            files_checked=self.files_checked,
            bytes_read=self.bytes_read,
            findings=[f.to_dict() for f in self.findings],
            repaired=list(self.repaired),
            quarantined=list(self.quarantined),
            duration_s=round(self.duration_s, 6),
        )


class RateLimiter:
    """Byte-budget pacing for a background scrub pass.

    Callable: feed it each verified chunk's size; it sleeps just enough
    to keep the cumulative rate at ``bytes_per_sec`` (0 = unlimited, the
    synchronous ``scrub(full=True)`` mode). Sleeps are capped at 1 s per
    call so a stop request is never stalled behind one long nap.
    """

    def __init__(self, bytes_per_sec: int = 0):
        self.rate = max(0, int(bytes_per_sec))
        self._t0 = time.monotonic()
        self._bytes = 0

    def __call__(self, nbytes: int) -> None:
        self._bytes += int(nbytes)
        if self.rate <= 0:
            return
        due = self._t0 + self._bytes / self.rate
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(min(delay, 1.0))


def scrub_version(storage, partitions, limiter=None) -> ScrubReport:
    """One at-rest integrity pass over a pinned partition list.

    Verifies manifest/CURRENT agreement, then every lazy table handle's
    checksum granules and every persisted REMIX, re-reading raw file
    bytes (cache-bypassing) through each handle's own ``IOContext`` so
    injected faults and retry budgets apply exactly as on the read path.
    Pure detection: mutates nothing, returns a :class:`ScrubReport`.
    """
    from repro.io.remix_io import check_remix

    rep = ScrubReport()
    t0 = time.monotonic()
    limiter = limiter or (lambda n: None)

    def on_block(n: int) -> None:
        rep.bytes_read += int(n)
        limiter(n)

    try:
        storage.manifest.verify()
    except CorruptionError as e:
        rep.findings.append(Finding(
            kind="manifest", file=e.file, section=e.section,
            detail=e.detail,
        ))
    rep.files_checked += 1  # the manifest/CURRENT pair counts as one
    for p in partitions:
        for t in p.tables:
            if t.path is None:
                continue  # in-memory table: no at-rest bytes to verify
            rep.files_checked += 1
            try:
                rd = t._rd()
                bad = rd.check_blocks(on_block=on_block)
            except CorruptionError as e:
                rep.findings.append(Finding(
                    kind="table", file=t.path, section=e.section,
                    blocks=() if e.block is None else (e.block,),
                    detail=e.detail,
                ))
                continue
            if bad:
                rep.findings.append(Finding(
                    kind="table", file=t.path,
                    section=rd.block_section(bad[0]), blocks=tuple(bad),
                ))
        if p.remix_name:
            rep.files_checked += 1
            path = storage.remix_path(p.remix_name)
            try:
                on_block(check_remix(path, io=storage.io))
            except CorruptionError as e:
                rep.findings.append(Finding(
                    kind="remix", file=path, section="remix",
                    detail=e.detail,
                ))
    rep.duration_s = time.monotonic() - t0
    return rep


def rebuild_remix(tables, d: int = 32):
    """Rebuild a partition's REMIX from its tables' key metadata alone.

    The §3.4 redundancy argument made executable: the index is a pure
    function of the runs' (keys, seq) columns, both of which survive in
    the table files (keys preferentially from the prefix-compressed CKB
    trailer), so a corrupt/lost REMIX file is never data loss. No value
    bytes are read; the returned :class:`repro.core.remix.Remix` is
    servable cold and byte-compatible with ``dump_remix``.
    """
    from repro.core.remix import build_remix
    from repro.core.runs import make_run

    runs = []
    for t in tables:
        kw = np.asarray(t.key_words(), np.uint32)  # prefers the CKB
        runs.append(make_run(
            kw, None, seq=np.asarray(t.seq), tomb=np.asarray(t.tomb),
            vw=t.vw, sort=False,
        ))
    remix, _ = build_remix(runs, d=max(int(d), len(runs) or 1))
    return remix

"""Key-range partitions: table files + one REMIX per partition (paper §4).

Tables are host numpy arrays (the "files"); the partition lazily builds its
REMIX + stacked RunSet (jnp, device-resident) when first queried after a
change — compaction invalidates the cache, mirroring the paper's "new
version of the partition includes ... a new REMIX file".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as CK
from repro.core.remix import Remix, build_remix
from repro.core.runs import Run, RunSet, make_run

KEY_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def _pad_index(remix: Remix, runset: RunSet, d: int) -> tuple[Remix, RunSet]:
    """Pad (G, R, Nmax) to power-of-two buckets; query semantics unchanged
    (pad groups are all-placeholder with +inf anchors, pad runs are empty)."""
    from repro.core.view import PLACEHOLDER

    g2 = _pow2(remix.g, 4)
    r2 = _pow2(remix.r, 1)
    n2 = _pow2(runset.nmax, 64)
    if (g2, r2, n2) == (remix.g, remix.r, runset.nmax):
        return remix, runset
    anchors = np.full((g2, runset.kw), 0xFFFFFFFF, np.uint32)
    anchors[: remix.g] = np.asarray(remix.anchors)
    cursors = np.zeros((g2, r2), np.int32)
    cursors[: remix.g, : remix.r] = np.asarray(remix.cursors)
    selectors = np.full((g2 * d,), PLACEHOLDER, np.uint8)
    selectors[: remix.n_slots] = np.asarray(remix.selectors)
    keys = np.full((r2, n2, runset.kw), 0xFFFFFFFF, np.uint32)
    keys[: runset.r, : runset.nmax] = np.asarray(runset.keys)
    vals = np.zeros((r2, n2, runset.vw), np.uint32)
    vals[: runset.r, : runset.nmax] = np.asarray(runset.vals)
    seq = np.zeros((r2, n2), np.uint32)
    seq[: runset.r, : runset.nmax] = np.asarray(runset.seq)
    tomb = np.zeros((r2, n2), bool)
    tomb[: runset.r, : runset.nmax] = np.asarray(runset.tomb)
    lens = np.zeros((r2,), np.int32)
    lens[: runset.r] = np.asarray(runset.lens)
    import jax.numpy as jnp

    return (
        Remix(
            anchors=jnp.asarray(anchors),
            cursors=jnp.asarray(cursors),
            selectors=jnp.asarray(selectors),
            n_entries=remix.n_entries,
            d=d,
        ),
        RunSet(
            keys=jnp.asarray(keys),
            vals=jnp.asarray(vals),
            seq=jnp.asarray(seq),
            tomb=jnp.asarray(tomb),
            lens=jnp.asarray(lens),
        ),
    )


class Table:
    """One immutable sorted table file.

    Either fully in-memory (``keys``/``vals``/``seq``/``tomb`` arrays) or a
    lazily-loadable handle onto an on-disk SSTable (``path``): column
    sections are fetched — and checksum-verified — on first access.
    ``key_words()`` serves REMIX (re)builds from the table's Compressed
    Keys Block when one exists, so a rebuild never reads value bytes.
    """

    def __init__(
        self,
        keys: np.ndarray | None = None,  # (N,) uint64 ascending, unique
        vals: np.ndarray | None = None,  # (N, VW) uint32
        seq: np.ndarray | None = None,  # (N,) uint32
        tomb: np.ndarray | None = None,  # (N,) bool
        path: str | None = None,
    ):
        if keys is None and path is None:
            raise ValueError("Table needs in-memory arrays or a file path")
        self._keys, self._vals = keys, vals
        self._seq, self._tomb = seq, tomb
        self.path = path
        self._reader = None

    @classmethod
    def from_file(cls, path: str) -> "Table":
        return cls(path=path)

    def _rd(self):
        if self._reader is None:
            from repro.io.sstable import SSTableReader

            self._reader = SSTableReader(self.path)
        return self._reader

    @property
    def keys(self) -> np.ndarray:
        if self._keys is None:
            self._keys = CK.unpack_u64(self._rd().read_keys())
        return self._keys

    @property
    def vals(self) -> np.ndarray:
        if self._vals is None:
            self._vals = self._rd().read_vals()
        return self._vals

    @property
    def seq(self) -> np.ndarray:
        if self._seq is None:
            self._seq = self._rd().read_seq()
        return self._seq

    @property
    def tomb(self) -> np.ndarray:
        if self._tomb is None:
            self._tomb = self._rd().read_tomb()
        return self._tomb

    @property
    def n(self) -> int:
        if self._keys is not None:
            return len(self._keys)
        return self._rd().n

    @property
    def vw(self) -> int:
        if self._vals is not None:
            return self._vals.shape[1]
        return self._rd().vw

    def key_words(self) -> np.ndarray:
        """(N, KW) uint32 key words for index builds; prefers the CKB."""
        if self._keys is not None:
            return CK.pack_u64(self._keys)
        rd = self._rd()
        if rd.has_ckb:
            return rd.read_ckb_keys()
        return rd.read_keys()

    def bytes(self, key_bytes: int = 8) -> int:
        return self.n * (key_bytes + self.vw * 4 + 5)


def merge_tables(tables: list[Table], drop_tombs: bool = False) -> Table:
    """Sort-merge tables, newest version per key wins (tiered major merge)."""
    keys = np.concatenate([t.keys for t in tables])
    vals = np.concatenate([t.vals for t in tables])
    seq = np.concatenate([t.seq for t in tables])
    tomb = np.concatenate([t.tomb for t in tables])
    neg = np.uint64(0xFFFFFFFFFFFFFFFF) - seq.astype(np.uint64)
    order = np.lexsort([neg, keys])
    keys, vals, seq, tomb = keys[order], vals[order], seq[order], tomb[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    keys, vals, seq, tomb = keys[keep], vals[keep], seq[keep], tomb[keep]
    if drop_tombs:
        live = ~tomb
        keys, vals, seq, tomb = keys[live], vals[live], seq[live], tomb[live]
    return Table(keys=keys, vals=vals, seq=seq, tomb=tomb)


def chunk_table(t: Table, cap: int) -> list[Table]:
    """Split a merged table into files of at most ``cap`` entries."""
    if t.n == 0:
        return []
    return [
        Table(
            keys=t.keys[i : i + cap],
            vals=t.vals[i : i + cap],
            seq=t.seq[i : i + cap],
            tomb=t.tomb[i : i + cap],
        )
        for i in range(0, t.n, cap)
    ]


class Partition:
    def __init__(self, lo: int, tables: list[Table] | None = None, d: int = 32):
        self.lo = int(lo)  # inclusive lower bound of the key range
        self.tables: list[Table] = tables or []
        self.d = d
        self._remix: Remix | None = None
        self._runset: RunSet | None = None
        self.remix_bytes = 0  # last REMIX build size (for WA accounting)
        # last built (unpadded) REMIX + the tables it covered: a minor
        # compaction that only appends tables rebuilds incrementally from
        # it + the tables' CKBs instead of re-sorting everything (§4.2)
        self._built_remix: Remix | None = None
        self._built_tables: list[Table] = []
        self.remix_name: str | None = None  # manifest name when persisted
        self.last_build_kind = "none"  # none | scratch | incremental | reuse

    def invalidate(self):
        """Drop the padded query cache; the last built REMIX is kept as the
        base for an incremental rebuild."""
        self._remix = None
        self._runset = None

    def preload_index(self, remix: Remix):
        """Adopt a deserialized REMIX for the current table list (recovery
        path): the next ``index()`` reuses it instead of rebuilding."""
        self._built_remix = remix
        self._built_tables = list(self.tables)
        self.remix_bytes = int(remix.storage_bytes())

    @property
    def n_entries(self) -> int:
        return sum(t.n for t in self.tables)

    def data_bytes(self) -> int:
        return sum(t.bytes() for t in self.tables)

    def index(self) -> tuple[Remix, RunSet]:
        """Build (or reuse) the partition's REMIX + stacked runs.

        Shapes are bucket-padded to powers of two so every partition of a
        store shares the same compiled query executables (shape-stable
        kernels — one jit per bucket instead of one per partition).
        """
        if self._remix is None:
            tabs = self.tables or [
                Table(
                    keys=np.zeros(0, np.uint64),
                    vals=np.zeros((0, 2), np.uint32),
                    seq=np.zeros(0, np.uint32),
                    tomb=np.zeros(0, bool),
                )
            ]
            d = max(self.d, len(tabs))  # paper requires D >= R
            remix = self._try_incremental(tabs, d)
            if remix is not None:
                from repro.core.runs import stack_runs

                runset = stack_runs(
                    [
                        make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb,
                                 sort=False)
                        for t in tabs
                    ]
                )
            else:
                runs = [
                    make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb,
                             sort=False)
                    for t in tabs
                ]
                remix, runset = build_remix(runs, d=d)
                self.last_build_kind = "scratch"
            self._built_remix = remix
            self._built_tables = list(tabs) if self.tables else []
            self.remix_bytes = int(remix.storage_bytes())
            self._remix, self._runset = _pad_index(remix, runset, d)
        return self._remix, self._runset

    def _try_incremental(self, tabs: list[Table], d: int) -> Remix | None:
        """Reuse/extend the last built REMIX when this rebuild only appended
        tables (minor compaction) — zero key comparisons among old runs.

        Returns None when the table set changed in any other way (major,
        split, first build) or the group size moved; those rebuild from
        scratch.
        """
        prev, base = self._built_remix, self._built_tables
        if prev is None or not base or prev.r != len(base) or prev.d != d:
            return None
        if len(tabs) < len(base) or any(
            a is not b for a, b in zip(base, tabs)
        ):
            return None
        if len(tabs) == len(base):  # nothing changed: reuse as-is
            self.last_build_kind = "reuse"
            return prev
        from repro.io.rebuild import incremental_build_remix

        new = tabs[len(base):]
        remix = incremental_build_remix(
            prev,
            [t.key_words() for t in base],
            [t.key_words() for t in new],
            [np.asarray(t.seq) for t in new],
            d=d,
        )
        self.last_build_kind = "incremental"
        return remix

    def persist_index(self, storage) -> None:
        """Build (if needed) and serialize this partition's REMIX; the
        padded on-device copy is derived, only the unpadded index persists."""
        self.index()
        self.remix_name = storage.write_remix(self._built_remix)

    def estimate_remix_bytes(self, extra_entries: int = 0) -> int:
        """Size estimate of a REMIX over current + new entries (§4.2 Abort)."""
        n = self.n_entries + extra_entries
        r = len(self.tables) + 1
        groups = max(1, n // self.d)
        return int(groups * (8 + 4 * r) + n)

"""Key-range partitions: table files + one REMIX per partition (paper §4).

Tables are host numpy arrays (the "files"); the partition lazily builds its
REMIX + stacked RunSet (jnp, device-resident) when first queried after a
change — compaction invalidates the cache, mirroring the paper's "new
version of the partition includes ... a new REMIX file".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as CK
from repro.core.remix import Remix, build_remix
from repro.core.runs import Run, RunSet, make_run, partial_runset
from repro.core.view import NEWEST_BIT, PLACEHOLDER

KEY_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def _pad_index(remix: Remix, runset: RunSet, d: int) -> tuple[Remix, RunSet]:
    """Pad (G, R, Nmax) to power-of-two buckets; query semantics unchanged
    (pad groups are all-placeholder with +inf anchors, pad runs are empty)."""
    g2 = _pow2(remix.g, 4)
    r2 = _pow2(remix.r, 1)
    n2 = _pow2(runset.nmax, 64)
    if (g2, r2, n2) == (remix.g, remix.r, runset.nmax):
        return remix, runset
    anchors = np.full((g2, runset.kw), 0xFFFFFFFF, np.uint32)
    anchors[: remix.g] = np.asarray(remix.anchors)
    cursors = np.zeros((g2, r2), np.int32)
    cursors[: remix.g, : remix.r] = np.asarray(remix.cursors)
    selectors = np.full((g2 * d,), PLACEHOLDER, np.uint8)
    selectors[: remix.n_slots] = np.asarray(remix.selectors)
    keys = np.full((r2, n2, runset.kw), 0xFFFFFFFF, np.uint32)
    keys[: runset.r, : runset.nmax] = np.asarray(runset.keys)
    vals = np.zeros((r2, n2, runset.vw), np.uint32)
    vals[: runset.r, : runset.nmax] = np.asarray(runset.vals)
    seq = np.zeros((r2, n2), np.uint32)
    seq[: runset.r, : runset.nmax] = np.asarray(runset.seq)
    tomb = np.zeros((r2, n2), bool)
    tomb[: runset.r, : runset.nmax] = np.asarray(runset.tomb)
    lens = np.zeros((r2,), np.int32)
    lens[: runset.r] = np.asarray(runset.lens)
    import jax.numpy as jnp

    return (
        Remix(
            anchors=jnp.asarray(anchors),
            cursors=jnp.asarray(cursors),
            selectors=jnp.asarray(selectors),
            n_entries=remix.n_entries,
            d=d,
        ),
        RunSet(
            keys=jnp.asarray(keys),
            vals=jnp.asarray(vals),
            seq=jnp.asarray(seq),
            tomb=jnp.asarray(tomb),
            lens=jnp.asarray(lens),
        ),
    )


class Table:
    """One immutable sorted table file.

    Either fully in-memory (``keys``/``vals``/``seq``/``tomb`` arrays) or a
    lazily-loadable handle onto an on-disk SSTable (``path``): column
    sections are fetched — and checksum-verified — on first access.
    ``key_words()`` serves REMIX (re)builds from the table's Compressed
    Keys Block when one exists, so a rebuild never reads value bytes.
    """

    def __init__(
        self,
        keys: np.ndarray | None = None,  # (N,) uint64 ascending, unique
        vals: np.ndarray | None = None,  # (N, VW) uint32
        seq: np.ndarray | None = None,  # (N,) uint32
        tomb: np.ndarray | None = None,  # (N,) bool
        path: str | None = None,
    ):
        if keys is None and path is None:
            raise ValueError("Table needs in-memory arrays or a file path")
        self._keys, self._vals = keys, vals
        self._seq, self._tomb = seq, tomb
        self.path = path
        self._reader = None
        self._cache = None
        self._ckb = None
        self._n: int | None = None if keys is None else len(keys)

    @classmethod
    def from_file(cls, path: str) -> "Table":
        return cls(path=path)

    def __repr__(self) -> str:
        # must not force-load a lazy handle: report only what is resident
        if self.resident:
            return f"Table(n={len(self._keys)}, resident=True)"
        n = "?" if self._reader is None else self._reader.n
        return f"Table(path={self.path!r}, n={n}, resident=False)"

    @property
    def resident(self) -> bool:
        """Whether the column arrays are fully loaded in memory."""
        return self._keys is not None

    def attach_cache(self, cache) -> None:
        """Route this handle's block reads through a shared BlockCache."""
        self._cache = cache
        if self._reader is not None:
            self._reader.attach_cache(cache)

    def _rd(self):
        if self._reader is None:
            from repro.io.sstable import SSTableReader

            self._reader = SSTableReader(self.path, cache=self._cache)
        return self._reader

    # ---- block-granular access (cold read path) ----
    def read_block(self, section: str, idx: int) -> bytes:
        """``idx``-th checksum granule overlapping ``section`` (cached)."""
        rd = self._rd()
        return rd.read_block(rd.section_block0(section) + idx)

    def rows(self, section: str, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of one columnar section via partial block reads."""
        return self._rd().section_rows(section, lo, hi)

    def ckb(self):
        """Restart-point CKB reader over cached block reads (or None)."""
        if self._ckb is None:
            rd = self._rd()
            if not rd.has_ckb:
                return None
            from repro.io.ckb import CKBReader

            self._ckb = CKBReader(
                rd._ckb_len,
                lambda lo, hi: rd.read_section_bytes("ckb", lo, hi),
            )
        return self._ckb

    def key_at(self, row: int) -> np.ndarray:
        """(KW,) uint32 key words at ``row`` without loading the section."""
        ckb = self.ckb()
        if ckb is not None:
            return ckb.key_at(row)
        return self.rows("keys", row, row + 1)[0]

    def seek_row(self, key_words: np.ndarray, lo: int, hi: int) -> int:
        """Lower bound of ``key_words`` within rows [lo, hi).

        Prefers the CKB restart-point binary search; tables without a CKB
        fall back to probing key rows (still block-granular).
        """
        ckb = self.ckb()
        if ckb is not None:
            return ckb.seek(key_words, lo, hi)
        q = CK.unpack_u64(np.asarray(key_words, np.uint32)[None, :])[0]
        while lo < hi:
            mid = (lo + hi) // 2
            kmid = CK.unpack_u64(self.rows("keys", mid, mid + 1))[0]
            if kmid < q:
                lo = mid + 1
            else:
                hi = mid
        return lo

    @property
    def keys(self) -> np.ndarray:
        if self._keys is None:
            self._keys = CK.unpack_u64(self._rd().read_keys())
        return self._keys

    @property
    def vals(self) -> np.ndarray:
        if self._vals is None:
            self._vals = self._rd().read_vals()
        return self._vals

    @property
    def seq(self) -> np.ndarray:
        if self._seq is None:
            self._seq = self._rd().read_seq()
        return self._seq

    @property
    def tomb(self) -> np.ndarray:
        if self._tomb is None:
            self._tomb = self._rd().read_tomb()
        return self._tomb

    @property
    def n(self) -> int:
        if self._n is None:  # header-only read; no section is loaded
            self._n = self._rd().n
        return self._n

    @property
    def vw(self) -> int:
        if self._vals is not None:
            return self._vals.shape[1]
        return self._rd().vw

    def key_words(self) -> np.ndarray:
        """(N, KW) uint32 key words for index builds; prefers the CKB."""
        if self._keys is not None:
            return CK.pack_u64(self._keys)
        rd = self._rd()
        if rd.has_ckb:
            return rd.read_ckb_keys()
        return rd.read_keys()

    def bytes(self, key_bytes: int = 8) -> int:
        return self.n * (key_bytes + self.vw * 4 + 5)


def merge_tables(tables: list[Table], drop_tombs: bool = False) -> Table:
    """Sort-merge tables, newest version per key wins (tiered major merge)."""
    keys = np.concatenate([t.keys for t in tables])
    vals = np.concatenate([t.vals for t in tables])
    seq = np.concatenate([t.seq for t in tables])
    tomb = np.concatenate([t.tomb for t in tables])
    neg = np.uint64(0xFFFFFFFFFFFFFFFF) - seq.astype(np.uint64)
    order = np.lexsort([neg, keys])
    keys, vals, seq, tomb = keys[order], vals[order], seq[order], tomb[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    keys, vals, seq, tomb = keys[keep], vals[keep], seq[keep], tomb[keep]
    if drop_tombs:
        live = ~tomb
        keys, vals, seq, tomb = keys[live], vals[live], seq[live], tomb[live]
    return Table(keys=keys, vals=vals, seq=seq, tomb=tomb)


def chunk_table(t: Table, cap: int) -> list[Table]:
    """Split a merged table into files of at most ``cap`` entries."""
    if t.n == 0:
        return []
    return [
        Table(
            keys=t.keys[i : i + cap],
            vals=t.vals[i : i + cap],
            seq=t.seq[i : i + cap],
            tomb=t.tomb[i : i + cap],
        )
        for i in range(0, t.n, cap)
    ]


class Partition:
    def __init__(self, lo: int, tables: list[Table] | None = None, d: int = 32):
        self.lo = int(lo)  # inclusive lower bound of the key range
        self.tables: list[Table] = tables or []
        self.d = d
        self._remix: Remix | None = None
        self._runset: RunSet | None = None
        self.remix_bytes = 0  # last REMIX build size (for WA accounting)
        # last built (unpadded) REMIX + the tables it covered: a minor
        # compaction that only appends tables rebuilds incrementally from
        # it + the tables' CKBs instead of re-sorting everything (§4.2)
        self._built_remix: Remix | None = None
        self._built_tables: list[Table] = []
        self.remix_name: str | None = None  # manifest name when persisted
        self.last_build_kind = "none"  # none | scratch | incremental | reuse
        # cold read path: host-side view of the (preloaded) REMIX + counters
        self._host: dict | None = None
        self.cold_gets = 0
        self.cold_scans = 0

    def __repr__(self) -> str:
        # introspection must not force-load lazy table handles
        return (
            f"Partition(lo={self.lo}, tables={len(self.tables)}, "
            f"resident={sum(t.resident for t in self.tables)}, "
            f"built={self.last_build_kind})"
        )

    def invalidate(self):
        """Drop the padded query cache; the last built REMIX is kept as the
        base for an incremental rebuild."""
        self._remix = None
        self._runset = None

    def preload_index(self, remix: Remix):
        """Adopt a deserialized REMIX for the current table list (recovery
        path): the next ``index()`` reuses it instead of rebuilding."""
        self._built_remix = remix
        self._built_tables = list(self.tables)
        self.remix_bytes = int(remix.storage_bytes())

    # ---------------- cold read path (block-granular, no table loads) ----
    def cold_ready(self) -> bool:
        """True when queries can be served straight off the on-disk REMIX
        + block cache, without materializing the device RunSet (the state
        right after ``RemixDB.open``: REMIX deserialized, tables lazy)."""
        return (
            self._remix is None
            and self._built_remix is not None
            and bool(self.tables)
            and len(self._built_tables) == len(self.tables)
            and all(a is b for a, b in zip(self._built_tables, self.tables))
            and all(t.path is not None and not t.resident for t in self.tables)
        )

    def cold_disk_bytes(self) -> int:
        """Physical bytes cold reads have pulled from this partition."""
        return sum(
            t._reader.disk_bytes_read
            for t in self.tables
            if t._reader is not None
        )

    def should_promote(self, fraction: float = 0.5) -> bool:
        """Once cold reads have fetched a sizable fraction of the data
        region, building the device-resident RunSet pays for itself."""
        total = sum(t._rd().data_bytes() for t in self.tables)  # header-only
        return self.cold_disk_bytes() >= fraction * max(1, total)

    def _host_index(self) -> dict:
        """Host numpy view of the built REMIX (anchors as u64 for search)."""
        rm = self._built_remix
        if self._host is None or self._host["remix"] is not rm:
            anchors = np.asarray(rm.anchors)
            self._host = dict(
                remix=rm,
                anch64=CK.unpack_u64(anchors),
                cursors=np.asarray(rm.cursors),
                selectors=np.asarray(rm.selectors),
                d=rm.d,
                n_slots=rm.n_slots,
            )
        return self._host

    def _group_rows(self, hx: dict, g: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-run row ranges [cur, nxt) covered by group ``g``."""
        cur = hx["cursors"][g].astype(np.int64)
        if g + 1 < hx["cursors"].shape[0]:
            nxt = hx["cursors"][g + 1].astype(np.int64)
        else:
            nxt = np.array([t.n for t in self.tables], np.int64)
        return cur, nxt

    def cold_get(self, key: int) -> tuple[bool, np.ndarray | None]:
        """Point lookup from the on-disk REMIX without loading any table.

        Anchors binary search on the host, then one *bounded* CKB
        restart-point seek per run — the group's cursor offsets restrict
        each seek to at most D rows, so each run contributes O(1) block
        reads — and finally at most one tomb byte and one value row are
        fetched from the run the selector names (§3.2 adapted to
        block-granular I/O). Returns (found, value row)."""
        hx = self._host_index()
        self.cold_gets += 1
        d, sels = hx["d"], hx["selectors"]
        g = max(
            int(np.searchsorted(hx["anch64"], np.uint64(key), side="right"))
            - 1,
            0,
        )
        cur, nxt = self._group_rows(hx, g)
        qw = CK.pack_u64(np.array([key], np.uint64))[0]
        rows = [
            t.seek_row(qw, int(cur[r]), int(nxt[r]))
            for r, t in enumerate(self.tables)
        ]
        s = int(sum(rows[r] - int(cur[r]) for r in range(len(rows))))
        pos = g * d + s
        if s >= d or pos >= hx["n_slots"]:
            return False, None
        sel = int(sels[pos])
        if sel == PLACEHOLDER or not (sel & NEWEST_BIT):
            return False, None
        run = sel & 0x7F
        row = rows[run]
        t = self.tables[run]
        if not np.array_equal(t.key_at(row), qw):
            return False, None
        if bool(t.rows("tomb", row, row + 1)[0]):
            return False, None
        return True, t.rows("vals", row, row + 1)[0]

    def cold_scan(self, start: int, width: int):
        """Range scan over a ``width``-slot view window without whole-table
        loads: seek as in :meth:`cold_get`, walk the selector stream
        (comparison-free next, §3.3) to find the touched per-run row
        ranges, then materialize only those ranges via
        :func:`repro.core.runs.partial_runset`. The window covers exactly
        ``width`` view slots from the seek position — placeholders, old
        versions and tombstones consume budget — matching the device
        path's ``gather_view`` window bit-for-bit, so promotion never
        changes scan results. Returns (keys (M,) u64, vals (M, VW),
        more) — live entries in ascending order, M ≤ width, and whether
        view slots remain beyond the window (so an all-invalid window is
        distinguishable from an exhausted partition)."""
        hx = self._host_index()
        self.cold_scans += 1
        d, sels, n_slots = hx["d"], hx["selectors"], hx["n_slots"]
        g = max(
            int(np.searchsorted(hx["anch64"], np.uint64(start), side="right"))
            - 1,
            0,
        )
        cur, nxt = self._group_rows(hx, g)
        qw = CK.pack_u64(np.array([start], np.uint64))[0]
        nextrow = np.array(
            [
                t.seek_row(qw, int(cur[r]), int(nxt[r]))
                for r, t in enumerate(self.tables)
            ],
            np.int64,
        )
        row0 = nextrow.copy()
        pos = g * d + int(np.sum(nextrow - cur))
        # device-seek parity (_ingroup_vector): landing on a trailing
        # placeholder means every real entry of the group is < start, so
        # the true lower bound is the next group's head — the window must
        # not waste budget on the placeholder tail. The row pointers are
        # already cursors[g+1] in that case (all group entries consumed).
        if pos < min(n_slots, (g + 1) * d) and int(sels[pos]) == PLACEHOLDER:
            pos = (g + 1) * d
        pos = min(pos, n_slots)
        emit: list[tuple[int, int]] = []  # (run, absolute row), view order
        stop = min(n_slots, pos + width)  # slot budget == device window
        while pos < stop:
            sel = int(sels[pos])
            pos += 1
            if sel == PLACEHOLDER:
                continue
            run = sel & 0x7F
            row = int(nextrow[run])
            nextrow[run] += 1
            if sel & NEWEST_BIT:
                emit.append((run, row))
        vw = self.tables[0].vw if self.tables else 2
        more = stop < n_slots
        if not emit:
            return np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32), more
        kw = self.tables[0]._rd().kw
        ranges = [
            (int(row0[r]), int(nextrow[r])) for r in range(len(self.tables))
        ]
        rs, r0 = partial_runset(
            ranges,
            lambda r, sec, lo, hi: self.tables[r].rows(sec, lo, hi),
            kw=kw,
            vw=vw,
        )
        out_k: list[int] = []
        out_v: list[np.ndarray] = []
        for run, row in emit:
            i = row - int(r0[run])
            if rs.tomb[run, i]:
                continue
            out_k.append(int(CK.unpack_u64(rs.keys[run, i][None, :])[0]))
            out_v.append(rs.vals[run, i])
        if not out_k:
            return np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32), more
        return np.array(out_k, np.uint64), np.stack(out_v), more

    @property
    def n_entries(self) -> int:
        return sum(t.n for t in self.tables)

    def data_bytes(self) -> int:
        return sum(t.bytes() for t in self.tables)

    def index(self) -> tuple[Remix, RunSet]:
        """Build (or reuse) the partition's REMIX + stacked runs.

        Shapes are bucket-padded to powers of two so every partition of a
        store shares the same compiled query executables (shape-stable
        kernels — one jit per bucket instead of one per partition).
        """
        if self._remix is None:
            tabs = self.tables or [
                Table(
                    keys=np.zeros(0, np.uint64),
                    vals=np.zeros((0, 2), np.uint32),
                    seq=np.zeros(0, np.uint32),
                    tomb=np.zeros(0, bool),
                )
            ]
            d = max(self.d, len(tabs))  # paper requires D >= R
            remix = self._try_incremental(tabs, d)
            if remix is not None:
                from repro.core.runs import stack_runs

                runset = stack_runs(
                    [
                        make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb,
                                 sort=False)
                        for t in tabs
                    ]
                )
            else:
                runs = [
                    make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb,
                             sort=False)
                    for t in tabs
                ]
                remix, runset = build_remix(runs, d=d)
                self.last_build_kind = "scratch"
            self._built_remix = remix
            self._built_tables = list(tabs) if self.tables else []
            self.remix_bytes = int(remix.storage_bytes())
            self._remix, self._runset = _pad_index(remix, runset, d)
        return self._remix, self._runset

    def _try_incremental(self, tabs: list[Table], d: int) -> Remix | None:
        """Reuse/extend the last built REMIX when this rebuild only appended
        tables (minor compaction) — zero key comparisons among old runs.

        Returns None when the table set changed in any other way (major,
        split, first build) or the group size moved; those rebuild from
        scratch.
        """
        prev, base = self._built_remix, self._built_tables
        if prev is None or not base or prev.r != len(base) or prev.d != d:
            return None
        if len(tabs) < len(base) or any(
            a is not b for a, b in zip(base, tabs)
        ):
            return None
        if len(tabs) == len(base):  # nothing changed: reuse as-is
            self.last_build_kind = "reuse"
            return prev
        from repro.io.rebuild import incremental_build_remix

        new = tabs[len(base):]
        remix = incremental_build_remix(
            prev,
            [t.key_words() for t in base],
            [t.key_words() for t in new],
            [np.asarray(t.seq) for t in new],
            d=d,
        )
        self.last_build_kind = "incremental"
        return remix

    def persist_index(self, storage) -> None:
        """Build (if needed) and serialize this partition's REMIX; the
        padded on-device copy is derived, only the unpadded index persists."""
        self.index()
        self.remix_name = storage.write_remix(self._built_remix)

    def estimate_remix_bytes(self, extra_entries: int = 0) -> int:
        """Size estimate of a REMIX over current + new entries (§4.2 Abort)."""
        n = self.n_entries + extra_entries
        r = len(self.tables) + 1
        groups = max(1, n // self.d)
        return int(groups * (8 + 4 * r) + n)

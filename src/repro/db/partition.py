"""Key-range partitions: table files + one REMIX per partition (paper §4).

Tables are host numpy arrays (the "files"); the partition lazily builds its
REMIX + stacked RunSet (jnp, device-resident) when first queried after a
change — compaction invalidates the cache, mirroring the paper's "new
version of the partition includes ... a new REMIX file".
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as CK
from repro.core.remix import Remix, build_remix
from repro.core.runs import Run, RunSet, make_run

KEY_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def _pad_index(remix: Remix, runset: RunSet, d: int) -> tuple[Remix, RunSet]:
    """Pad (G, R, Nmax) to power-of-two buckets; query semantics unchanged
    (pad groups are all-placeholder with +inf anchors, pad runs are empty)."""
    from repro.core.view import PLACEHOLDER

    g2 = _pow2(remix.g, 4)
    r2 = _pow2(remix.r, 1)
    n2 = _pow2(runset.nmax, 64)
    if (g2, r2, n2) == (remix.g, remix.r, runset.nmax):
        return remix, runset
    anchors = np.full((g2, runset.kw), 0xFFFFFFFF, np.uint32)
    anchors[: remix.g] = np.asarray(remix.anchors)
    cursors = np.zeros((g2, r2), np.int32)
    cursors[: remix.g, : remix.r] = np.asarray(remix.cursors)
    selectors = np.full((g2 * d,), PLACEHOLDER, np.uint8)
    selectors[: remix.n_slots] = np.asarray(remix.selectors)
    keys = np.full((r2, n2, runset.kw), 0xFFFFFFFF, np.uint32)
    keys[: runset.r, : runset.nmax] = np.asarray(runset.keys)
    vals = np.zeros((r2, n2, runset.vw), np.uint32)
    vals[: runset.r, : runset.nmax] = np.asarray(runset.vals)
    seq = np.zeros((r2, n2), np.uint32)
    seq[: runset.r, : runset.nmax] = np.asarray(runset.seq)
    tomb = np.zeros((r2, n2), bool)
    tomb[: runset.r, : runset.nmax] = np.asarray(runset.tomb)
    lens = np.zeros((r2,), np.int32)
    lens[: runset.r] = np.asarray(runset.lens)
    import jax.numpy as jnp

    return (
        Remix(
            anchors=jnp.asarray(anchors),
            cursors=jnp.asarray(cursors),
            selectors=jnp.asarray(selectors),
            n_entries=remix.n_entries,
            d=d,
        ),
        RunSet(
            keys=jnp.asarray(keys),
            vals=jnp.asarray(vals),
            seq=jnp.asarray(seq),
            tomb=jnp.asarray(tomb),
            lens=jnp.asarray(lens),
        ),
    )


@dataclasses.dataclass
class Table:
    """One immutable sorted table file."""

    keys: np.ndarray  # (N,) uint64 ascending, unique
    vals: np.ndarray  # (N, VW) uint32
    seq: np.ndarray  # (N,) uint32
    tomb: np.ndarray  # (N,) bool

    @property
    def n(self) -> int:
        return len(self.keys)

    def bytes(self, key_bytes: int = 8) -> int:
        return self.n * (key_bytes + self.vals.shape[1] * 4 + 5)


def merge_tables(tables: list[Table], drop_tombs: bool = False) -> Table:
    """Sort-merge tables, newest version per key wins (tiered major merge)."""
    keys = np.concatenate([t.keys for t in tables])
    vals = np.concatenate([t.vals for t in tables])
    seq = np.concatenate([t.seq for t in tables])
    tomb = np.concatenate([t.tomb for t in tables])
    neg = np.uint64(0xFFFFFFFFFFFFFFFF) - seq.astype(np.uint64)
    order = np.lexsort([neg, keys])
    keys, vals, seq, tomb = keys[order], vals[order], seq[order], tomb[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    keys, vals, seq, tomb = keys[keep], vals[keep], seq[keep], tomb[keep]
    if drop_tombs:
        live = ~tomb
        keys, vals, seq, tomb = keys[live], vals[live], seq[live], tomb[live]
    return Table(keys=keys, vals=vals, seq=seq, tomb=tomb)


def chunk_table(t: Table, cap: int) -> list[Table]:
    """Split a merged table into files of at most ``cap`` entries."""
    if t.n == 0:
        return []
    return [
        Table(
            keys=t.keys[i : i + cap],
            vals=t.vals[i : i + cap],
            seq=t.seq[i : i + cap],
            tomb=t.tomb[i : i + cap],
        )
        for i in range(0, t.n, cap)
    ]


class Partition:
    def __init__(self, lo: int, tables: list[Table] | None = None, d: int = 32):
        self.lo = int(lo)  # inclusive lower bound of the key range
        self.tables: list[Table] = tables or []
        self.d = d
        self._remix: Remix | None = None
        self._runset: RunSet | None = None
        self.remix_bytes = 0  # last REMIX build size (for WA accounting)

    def invalidate(self):
        self._remix = None
        self._runset = None

    @property
    def n_entries(self) -> int:
        return sum(t.n for t in self.tables)

    def data_bytes(self) -> int:
        return sum(t.bytes() for t in self.tables)

    def index(self) -> tuple[Remix, RunSet]:
        """Build (or reuse) the partition's REMIX + stacked runs.

        Shapes are bucket-padded to powers of two so every partition of a
        store shares the same compiled query executables (shape-stable
        kernels — one jit per bucket instead of one per partition).
        """
        if self._remix is None:
            tabs = self.tables or [
                Table(
                    keys=np.zeros(0, np.uint64),
                    vals=np.zeros((0, 2), np.uint32),
                    seq=np.zeros(0, np.uint32),
                    tomb=np.zeros(0, bool),
                )
            ]
            runs = [
                make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb, sort=False)
                for t in tabs
            ]
            d = max(self.d, len(runs))  # paper requires D >= R
            remix, runset = build_remix(runs, d=d)
            self.remix_bytes = int(remix.storage_bytes())
            self._remix, self._runset = _pad_index(remix, runset, d)
        return self._remix, self._runset

    def estimate_remix_bytes(self, extra_entries: int = 0) -> int:
        """Size estimate of a REMIX over current + new entries (§4.2 Abort)."""
        n = self.n_entries + extra_entries
        r = len(self.tables) + 1
        groups = max(1, n // self.d)
        return int(groups * (8 + 4 * r) + n)

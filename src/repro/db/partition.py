"""Key-range partitions: table files + one REMIX per partition (paper §4).

Tables are host numpy arrays (the "files") or lazy on-disk handles; the
partition lazily builds its REMIX + stacked RunSet (jnp, device-resident)
when first queried. Partitions are *logically immutable* once published
in a :class:`repro.db.version.Version`: compaction never mutates a live
partition's table list — it derives a successor via
:meth:`Partition.clone_with_tables` (sharing unchanged table handles and
the built REMIX as the incremental-rebuild base), mirroring the paper's
"new version of the partition includes ... a new REMIX file" with the old
version still servable by pinned readers. The query caches (``index()``,
host view) are benign fills shared across versions — they never change
query results, only where they are answered from.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import keys as CK
from repro.core.remix import Remix, build_remix
from repro.core.runs import (
    Run,
    RunSet,
    RowWindow,
    make_run,
    merge_ranges_np,
    ranges_to_rows,
)
from repro.core.view import NEWEST_BIT, PLACEHOLDER
from repro.db import clock

KEY_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def _pow2(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


def _pad_index(remix: Remix, runset: RunSet, d: int) -> tuple[Remix, RunSet]:
    """Pad (G, R, Nmax) to power-of-two buckets; query semantics unchanged
    (pad groups are all-placeholder with +inf anchors, pad runs are empty)."""
    g2 = _pow2(remix.g, 4)
    r2 = _pow2(remix.r, 1)
    n2 = _pow2(runset.nmax, 64)
    if (g2, r2, n2) == (remix.g, remix.r, runset.nmax):
        return remix, runset
    anchors = np.full((g2, runset.kw), 0xFFFFFFFF, np.uint32)
    anchors[: remix.g] = np.asarray(remix.anchors)
    cursors = np.zeros((g2, r2), np.int32)
    cursors[: remix.g, : remix.r] = np.asarray(remix.cursors)
    selectors = np.full((g2 * d,), PLACEHOLDER, np.uint8)
    selectors[: remix.n_slots] = np.asarray(remix.selectors)
    keys = np.full((r2, n2, runset.kw), 0xFFFFFFFF, np.uint32)
    keys[: runset.r, : runset.nmax] = np.asarray(runset.keys)
    vals = np.zeros((r2, n2, runset.vw), np.uint32)
    vals[: runset.r, : runset.nmax] = np.asarray(runset.vals)
    seq = np.zeros((r2, n2), np.uint32)
    seq[: runset.r, : runset.nmax] = np.asarray(runset.seq)
    tomb = np.zeros((r2, n2), bool)
    tomb[: runset.r, : runset.nmax] = np.asarray(runset.tomb)
    lens = np.zeros((r2,), np.int32)
    lens[: runset.r] = np.asarray(runset.lens)
    import jax.numpy as jnp

    return (
        Remix(
            anchors=jnp.asarray(anchors),
            cursors=jnp.asarray(cursors),
            selectors=jnp.asarray(selectors),
            n_entries=remix.n_entries,
            d=d,
        ),
        RunSet(
            keys=jnp.asarray(keys),
            vals=jnp.asarray(vals),
            seq=jnp.asarray(seq),
            tomb=jnp.asarray(tomb),
            lens=jnp.asarray(lens),
        ),
    )


class Table:
    """One immutable sorted table file.

    Either fully in-memory (``keys``/``vals``/``seq``/``tomb`` arrays) or a
    lazily-loadable handle onto an on-disk SSTable (``path``): column
    sections are fetched — and checksum-verified — on first access.
    ``key_words()`` serves REMIX (re)builds from the table's Compressed
    Keys Block when one exists, so a rebuild never reads value bytes.
    """

    def __init__(
        self,
        keys: np.ndarray | None = None,  # (N,) uint64 ascending, unique
        vals: np.ndarray | None = None,  # (N, VW) uint32
        seq: np.ndarray | None = None,  # (N,) uint32
        tomb: np.ndarray | None = None,  # (N,) bool
        path: str | None = None,
        cache_mode: str = "copy",
        ckb_decode: bool = True,
        exp: np.ndarray | None = None,  # (N,) uint32 TTL expiry (0 = none)
    ):
        if keys is None and path is None:
            raise ValueError("Table needs in-memory arrays or a file path")
        self._keys, self._vals = keys, vals
        self._seq, self._tomb = seq, tomb
        self._exp = exp
        self._ttl_any: bool | None = None
        self.path = path
        self.cache_mode = cache_mode
        # batched seeks decode the prefix-compressed CKB entry stream
        # (vectorized) instead of reading fixed-width key rows
        self.ckb_decode = ckb_decode
        self._reader = None
        self._cache = None
        self._ioctx = None
        self._ckb = None
        self._n: int | None = None if keys is None else len(keys)

    @classmethod
    def from_file(cls, path: str, cache_mode: str = "copy",
                  ckb_decode: bool = True) -> "Table":
        return cls(path=path, cache_mode=cache_mode, ckb_decode=ckb_decode)

    def __repr__(self) -> str:
        # must not force-load a lazy handle: report only what is resident
        if self.resident:
            return f"Table(n={len(self._keys)}, resident=True)"
        n = "?" if self._reader is None else self._reader.n
        return f"Table(path={self.path!r}, n={n}, resident=False)"

    @property
    def resident(self) -> bool:
        """Whether the column arrays are fully loaded in memory."""
        return self._keys is not None

    def attach_cache(self, cache) -> None:
        """Route this handle's block reads through a shared BlockCache."""
        self._cache = cache
        if self._reader is not None:
            self._reader.attach_cache(cache)

    def attach_io(self, ioctx) -> None:
        """Route this handle's reads through an ``IOContext`` (fault
        injection + bounded transient-error retry)."""
        self._ioctx = ioctx
        if self._reader is not None:
            self._reader.attach_io(ioctx)

    def _rd(self):
        if self._reader is None:
            from repro.io.sstable import SSTableReader

            self._reader = SSTableReader(
                self.path, cache=self._cache, mode=self.cache_mode,
                io=self._ioctx,
            )
        return self._reader

    # ---- block-granular access (cold read path) ----
    def read_block(self, section: str, idx: int) -> bytes:
        """``idx``-th checksum granule overlapping ``section`` (cached)."""
        rd = self._rd()
        return rd.read_block(rd.section_block0(section) + idx)

    def rows(self, section: str, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of one columnar section via partial block reads."""
        return self._rd().section_rows(section, lo, hi)

    def rows_resident(self, section: str, lo: int, hi: int) -> bool:
        """Side-effect-free probe: rows [lo, hi) servable without I/O."""
        return self._rd().section_rows_resident(section, lo, hi)

    def ckb(self):
        """Restart-point CKB reader over cached block reads (or None).

        The reader's interval-decode memo is bounded by an entry budget
        tied to the block-cache byte budget (1/64th of it in decoded
        8-byte key entries per reader), so a long-lived handle over a
        huge table can no longer hold more decoded keys than the cache
        it shadows holds raw bytes. Cacheless handles keep a small
        fixed budget.
        """
        if self._ckb is None:
            rd = self._rd()
            if not rd.has_ckb:
                return None
            from repro.io.ckb import CKBReader

            cap = getattr(self._cache, "capacity_bytes", None)
            budget = (cap // 64) if cap else (1 << 20)
            self._ckb = CKBReader(
                rd._ckb_len,
                lambda lo, hi: rd.read_section_bytes("ckb", lo, hi),
                memo_entries=budget,
            )
        return self._ckb

    def key_at(self, row: int) -> np.ndarray:
        """(KW,) uint32 key words at ``row`` without loading the section."""
        ckb = self.ckb()
        if ckb is not None:
            return ckb.key_at(row)
        return self.rows("keys", row, row + 1)[0]

    def seek_row(self, key_words: np.ndarray, lo: int, hi: int) -> int:
        """Lower bound of ``key_words`` within rows [lo, hi).

        Prefers the CKB restart-point binary search; tables without a CKB
        fall back to probing key rows (still block-granular).
        """
        ckb = self.ckb()
        if ckb is not None:
            return ckb.seek(key_words, lo, hi)
        q = CK.unpack_u64(np.asarray(key_words, np.uint32)[None, :])[0]
        while lo < hi:
            mid = (lo + hi) // 2
            kmid = CK.unpack_u64(self.rows("keys", mid, mid + 1))[0]
            if kmid < q:
                lo = mid + 1
            else:
                hi = mid
        return lo

    # ---- batched access (cold batch query path) ----
    def rows_scattered(self, section: str, rows) -> np.ndarray:
        """Arbitrary rows of one section; each touched granule fetched
        once (see ``SSTableReader.section_rows_scattered``)."""
        return self._rd().section_rows_scattered(section, rows)

    def keys_u64_rows(self, rows) -> np.ndarray:
        """(M,) uint64 keys at the given rows, via scattered block reads."""
        return CK.unpack_u64(self.rows_scattered("keys", rows))

    def prefetch_rows(self, section: str, lo: int, hi: int) -> None:
        """Issue cache loads for the granules covering rows [lo, hi)."""
        self.prefetch_blocks(self.row_block_ids(section, lo, hi))

    def row_block_ids(self, section: str, lo: int, hi: int):
        """Granule ids covering rows [lo, hi) of one section (no I/O).
        Ids are file-absolute, so adjacent sections sharing a boundary
        granule report the same id — callers dedupe across sections."""
        return self._rd().section_row_blocks(section, lo, hi)

    def prefetch_blocks(self, ids) -> None:
        """Issue cache loads for an explicit granule id set."""
        rd = self._rd()
        for bi in ids:
            rd.prefetch_block(bi)

    def seek_rows_batch(self, qs: np.ndarray, los, his,
                        return_keys: bool = False):
        """Lower bounds of ``qs`` (Q,) u64 within per-query row ranges.

        The batched counterpart of :meth:`seek_row`, same results, no
        per-query binary search: the CKB's restart keys narrow every
        query to one restart interval in a single vectorized pass
        (:meth:`repro.io.ckb.CKBReader.narrow_batch`), then the narrowed
        intervals are resolved — by default straight from the
        prefix-compressed entry stream (the vectorized
        :meth:`repro.io.ckb.CKBReader.seek_batch` decoder: zero
        keys-section bytes), or, with ``ckb_decode`` off / no usable
        CKB, by fetching the narrowed fixed-width key rows with ranges
        merged across the whole batch and one ``np.searchsorted``.
        Clipping the candidate row into each query's narrowed range is
        exact because keys ascend with row number.

        With ``return_keys`` the result is ``(rows, keyat, known)``:
        where ``known[i]``, ``keyat[i]`` is the key at ``rows[i]`` —
        point lookups verify hits with zero extra key fetches on the
        decoder path (the fallback path reports nothing as known).

        The entry-stream decoder only runs when the caller wants the
        keys (``return_keys``): there the decode replaces *two* keys-
        section reads (seek + hit verification). Seek-only callers
        (the scan paths, which must read the keys section anyway to
        emit rows) keep the cheaper narrow + scattered-fetch resolve.
        """
        qs = np.asarray(qs, np.uint64)
        los = np.maximum(np.asarray(los, np.int64), 0)
        his = np.minimum(np.asarray(his, np.int64), self.n)
        out = his.copy()
        keyat = np.zeros(len(qs), np.uint64)
        known = np.zeros(len(qs), bool)
        act = his > los
        if not act.any():
            return (out, keyat, known) if return_keys else out
        ckb = self.ckb()
        if (ckb is not None and ckb.kb == 8 and self.ckb_decode
                and return_keys):
            nlo, nhi = ckb.narrow_batch(qs[act], los[act], his[act])
            rows, ka, kn = ckb.seek_batch(qs[act], nlo, nhi)
            out[act] = rows
            keyat[act] = ka
            known[act] = kn
            return (out, keyat, known) if return_keys else out
        nlo, nhi = los.copy(), his.copy()
        if ckb is not None and ckb.kb == 8:
            nlo[act], nhi[act] = ckb.narrow_batch(qs[act], los[act], his[act])
        mlo, mhi = merge_ranges_np(nlo[act], nhi[act])
        rows_cat = ranges_to_rows(mlo, mhi)
        keys_cat = self.keys_u64_rows(rows_cat)  # one scattered fetch
        idx = np.searchsorted(keys_cat, qs, side="left")
        hit = idx < len(rows_cat)
        cand = np.where(
            hit, rows_cat[np.minimum(idx, len(rows_cat) - 1)],
            np.iinfo(np.int64).max,
        )
        out = np.where(act, np.clip(cand, nlo, nhi), his)
        return (out, keyat, known) if return_keys else out

    @property
    def keys(self) -> np.ndarray:
        if self._keys is None:
            self._keys = CK.unpack_u64(self._rd().read_keys())
        return self._keys

    @property
    def vals(self) -> np.ndarray:
        if self._vals is None:
            self._vals = self._rd().read_vals()
        return self._vals

    @property
    def seq(self) -> np.ndarray:
        if self._seq is None:
            self._seq = self._rd().read_seq()
        return self._seq

    @property
    def tomb(self) -> np.ndarray:
        if self._tomb is None:
            self._tomb = self._rd().read_tomb()
        return self._tomb

    @property
    def exp(self) -> np.ndarray:
        """(N,) uint32 absolute TTL expiries; zeros when none were set."""
        if self._exp is None:
            if self.path is not None:
                self._exp = self._rd().read_exp()
            else:
                self._exp = np.zeros(self.n, np.uint32)
        return self._exp

    def ttl_present(self) -> bool:
        """Whether any row of this table carries a TTL (cheap: lazy
        handles answer from the file header flag, no section read)."""
        if self._ttl_any is None:
            if self._exp is not None:
                self._ttl_any = bool(np.any(self._exp))
            elif self.path is not None:
                self._ttl_any = bool(self._rd().has_exp)
            else:
                self._ttl_any = False
        return self._ttl_any

    # ---- liveness (tombstone OR expired TTL) ----
    def dead(self, now: float | None = None) -> np.ndarray:
        """(N,) bool: rows hidden from reads — tombstones plus rows whose
        TTL expired as of ``now`` (defaults to ``clock.now()``)."""
        if not self.ttl_present():
            return self.tomb
        if now is None:
            now = clock.now()
        e = self.exp
        return self.tomb | ((e != 0) & (e <= np.uint32(int(now))))

    def dead_rows(self, lo: int, hi: int,
                  now: float | None = None) -> np.ndarray:
        """Rows [lo, hi) of the combined liveness column (cold path):
        tomb | expired, fetching the exp section only when the table
        carries TTLs at all."""
        tomb = self.rows("tomb", lo, hi)
        if not self.ttl_present():
            return tomb
        if now is None:
            now = clock.now()
        e = self.rows("exp", lo, hi)
        return tomb | ((e != 0) & (e <= np.uint32(int(now))))

    def dead_rows_scattered(self, rows,
                            now: float | None = None) -> np.ndarray:
        """Scattered-row counterpart of :meth:`dead_rows`."""
        tomb = self.rows_scattered("tomb", rows)
        if not self.ttl_present():
            return tomb
        if now is None:
            now = clock.now()
        e = self.rows_scattered("exp", rows)
        return tomb | ((e != 0) & (e <= np.uint32(int(now))))

    def min_future_exp(self, now: float) -> int | None:
        """Smallest TTL expiry still in the future, or None: the instant
        a device index built at ``now`` goes stale."""
        if not self.ttl_present():
            return None
        e = self.exp
        fut = e[(e != 0) & (e > np.uint32(int(now)))]
        return int(fut.min()) if fut.size else None

    @property
    def n(self) -> int:
        if self._n is None:  # header-only read; no section is loaded
            self._n = self._rd().n
        return self._n

    @property
    def vw(self) -> int:
        if self._vals is not None:
            return self._vals.shape[1]
        return self._rd().vw

    def key_words(self) -> np.ndarray:
        """(N, KW) uint32 key words for index builds; prefers the CKB."""
        if self._keys is not None:
            return CK.pack_u64(self._keys)
        rd = self._rd()
        if rd.has_ckb:
            return rd.read_ckb_keys()
        return rd.read_keys()

    def bytes(self, key_bytes: int = 8) -> int:
        return self.n * (key_bytes + self.vw * 4 + 5)


@dataclasses.dataclass
class ExcisedSpan:
    """One committed range tombstone: every row with key in [lo, hi) of a
    *covered* table is dead, unconditionally.

    Coverage is by table identity: a span attaches at flush covering
    exactly the tables that existed then (all of whose seqs precede the
    delete's), so no seq comparison is ever needed on the read path —
    newer writes land in tables born later, which the span does not
    cover. Compaction shrinks the coverage set (merges drop covered rows
    from their inputs); a span whose coverage empties is garbage."""

    lo: int
    hi: int  # exclusive
    seq: int
    tables: tuple

    def __post_init__(self):
        self._ids = frozenset(id(t) for t in self.tables)

    def covers_table(self, t: Table) -> bool:
        return id(t) in self._ids

    def retain(self, tables: list[Table]) -> "ExcisedSpan":
        """The span restricted to the handles surviving in ``tables``."""
        kept = tuple(t for t in tables if id(t) in self._ids)
        return ExcisedSpan(self.lo, self.hi, self.seq, kept)


def excise_rows(t: Table, spans: list[ExcisedSpan]) -> tuple[Table, int]:
    """Copy of ``t`` with rows covered by ``spans`` removed; returns the
    copy (or ``t`` itself when nothing is covered) and the row count
    dropped. Dropping (not tombstoning) is exact: any older version of a
    covered key lives in a table some covering span also covers."""
    cov = None
    for sp in spans:
        if sp.covers_table(t):
            m = (t.keys >= np.uint64(sp.lo)) & (t.keys < np.uint64(sp.hi))
            cov = m if cov is None else (cov | m)
    if cov is None or not cov.any():
        return t, 0
    keep = ~cov
    return (
        Table(keys=t.keys[keep], vals=t.vals[keep], seq=t.seq[keep],
              tomb=t.tomb[keep], exp=t.exp[keep]),
        int(cov.sum()),
    )


def merge_tables(
    tables: list[Table],
    drop_tombs: bool = False,
    excised: list[ExcisedSpan] | None = None,
    now: float | None = None,
    stats: dict | None = None,
) -> Table:
    """Sort-merge tables, newest version per key wins (tiered major merge).

    ``excised`` spans drop covered input rows before the merge (outputs
    are then *not* covered — the caller's clone drops the merged handles
    from every span's coverage set). Rows whose TTL expired as of ``now``
    are GC'd: converted to tombstones (they must keep hiding older
    versions that may survive in unmerged tables) and, with
    ``drop_tombs``, removed outright. ``stats`` (optional dict) receives
    ``rows_excised`` / ``rows_expired`` counts.
    """
    n_exc = 0
    if excised:
        masked = []
        for t in tables:
            t2, dropped = excise_rows(t, excised)
            n_exc += dropped
            masked.append(t2)
        tables = masked
    keys = np.concatenate([t.keys for t in tables])
    vals = np.concatenate([t.vals for t in tables])
    seq = np.concatenate([t.seq for t in tables])
    tomb = np.concatenate([t.tomb for t in tables])
    exp = np.concatenate([t.exp for t in tables])
    neg = np.uint64(0xFFFFFFFFFFFFFFFF) - seq.astype(np.uint64)
    order = np.lexsort([neg, keys])
    keys, vals, seq = keys[order], vals[order], seq[order]
    tomb, exp = tomb[order], exp[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]
    keys, vals, seq = keys[keep], vals[keep], seq[keep]
    tomb, exp = tomb[keep], exp[keep]
    if now is None:
        now = clock.now()
    expired = (exp != 0) & (exp <= np.uint32(int(now))) & ~tomb
    n_ttl = int(expired.sum())
    if n_ttl:
        tomb = tomb | expired
        vals = vals.copy()
        vals[expired] = 0
        exp = exp.copy()
        exp[expired] = 0
    if drop_tombs:
        live = ~tomb
        keys, vals, seq = keys[live], vals[live], seq[live]
        tomb, exp = tomb[live], exp[live]
    if stats is not None:
        stats["rows_excised"] = stats.get("rows_excised", 0) + n_exc
        stats["rows_expired"] = stats.get("rows_expired", 0) + n_ttl
    return Table(keys=keys, vals=vals, seq=seq, tomb=tomb, exp=exp)


def chunk_table(t: Table, cap: int) -> list[Table]:
    """Split a merged table into files of at most ``cap`` entries."""
    if t.n == 0:
        return []
    return [
        Table(
            keys=t.keys[i : i + cap],
            vals=t.vals[i : i + cap],
            seq=t.seq[i : i + cap],
            tomb=t.tomb[i : i + cap],
            exp=t.exp[i : i + cap],
        )
        for i in range(0, t.n, cap)
    ]


class Partition:
    def __init__(self, lo: int, tables: list[Table] | None = None, d: int = 32):
        self.lo = int(lo)  # inclusive lower bound of the key range
        self.tables: list[Table] = tables or []
        self.d = d
        self._remix: Remix | None = None
        self._runset: RunSet | None = None
        self.remix_bytes = 0  # last REMIX build size (for WA accounting)
        # committed range tombstones covering (subsets of) self.tables
        self.excised: list[ExcisedSpan] = []
        # earliest future TTL expiry baked into the built device index:
        # past this instant the runset's tomb marks are stale and index()
        # rebuilds them (REMIX structure is unaffected by liveness)
        self._ttl_next: float | None = None
        # last built (unpadded) REMIX + the tables it covered: a minor
        # compaction that only appends tables rebuilds incrementally from
        # it + the tables' CKBs instead of re-sorting everything (§4.2)
        self._built_remix: Remix | None = None
        self._built_tables: list[Table] = []
        self.remix_name: str | None = None  # manifest name when persisted
        self.last_build_kind = "none"  # none | scratch | incremental | reuse
        # cold read path: host-side view of the (preloaded) REMIX + counters
        self._host: dict | None = None
        self.cold_gets = 0
        self.cold_scans = 0
        # workload statistics for the promotion decision: logical row
        # bytes served by cold reads (counted on cache hits too, unlike
        # the physical ``cold_disk_bytes``)
        self.cold_served_rows = 0

    def __repr__(self) -> str:
        # introspection must not force-load lazy table handles
        return (
            f"Partition(lo={self.lo}, tables={len(self.tables)}, "
            f"resident={sum(t.resident for t in self.tables)}, "
            f"built={self.last_build_kind})"
        )

    def clone_with_tables(self, tables: list[Table],
                          carry_built: bool = False) -> "Partition":
        """Copy-on-write successor over a new table list.

        The compaction primitive of the Version architecture: the clone
        shares unchanged :class:`Table` handles (and with ``carry_built``
        the last built REMIX, so a minor compaction that only appended
        tables rebuilds incrementally) while this partition — possibly
        still pinned by older Versions — keeps serving its exact old
        view. Cold-read workload counters carry over so promotion
        decisions survive the version edge.
        """
        p2 = Partition(lo=self.lo, tables=list(tables), d=self.d)
        if carry_built:
            p2._built_remix = self._built_remix
            p2._built_tables = list(self._built_tables)
        # spans follow the surviving covered handles; a span whose whole
        # coverage set was compacted away (its rows dropped in the merge)
        # is garbage-collected here
        p2.excised = [
            s2 for s in self.excised if (s2 := s.retain(tables)).tables
        ]
        p2.cold_gets = self.cold_gets
        p2.cold_scans = self.cold_scans
        p2.cold_served_rows = self.cold_served_rows
        return p2

    def attach_excised(self, lo: int, hi: int, seq: int) -> None:
        """Attach a freshly flushed range tombstone covering every table
        this partition holds *right now* (their rows all predate it)."""
        if self.tables and lo < hi:
            self.excised.append(
                ExcisedSpan(int(lo), int(hi), int(seq), tuple(self.tables))
            )

    def full_spans(self) -> list[tuple[int, int]]:
        """Merged sorted [lo, hi) spans covering *all* current tables —
        the spans a cursor can skip structurally (nothing in the
        partition can be live inside them)."""
        spans = sorted(
            (s.lo, s.hi)
            for s in self.excised
            if all(s.covers_table(t) for t in self.tables)
        )
        out: list[tuple[int, int]] = []
        for lo, hi in spans:
            if out and lo <= out[-1][1]:
                out[-1] = (out[-1][0], max(hi, out[-1][1]))
            else:
                out.append((lo, hi))
        return out

    def _span_dead(self, r: int, keys: np.ndarray) -> np.ndarray:
        """(M,) bool: which of run ``r``'s emitted keys an excised span
        hides (partial-coverage fallback — full coverage is skipped
        structurally upstream)."""
        out = np.zeros(len(keys), bool)
        t = self.tables[r]
        for sp in self.excised:
            if sp.covers_table(t):
                out |= (keys >= np.uint64(sp.lo)) & (keys < np.uint64(sp.hi))
        return out

    def _covered(self, r: int, key: int) -> bool:
        t = self.tables[r]
        return any(
            sp.covers_table(t) and sp.lo <= key < sp.hi
            for sp in self.excised
        )

    def preload_index(self, remix: Remix):
        """Adopt a deserialized REMIX for the current table list (recovery
        path): the next ``index()`` reuses it instead of rebuilding."""
        self._built_remix = remix
        self._built_tables = list(self.tables)
        self.remix_bytes = int(remix.storage_bytes())

    # ---------------- cold read path (block-granular, no table loads) ----
    def cold_ready(self) -> bool:
        """True when queries can be served straight off the on-disk REMIX
        + block cache, without materializing the device RunSet (the state
        right after ``RemixDB.open``: REMIX deserialized, tables lazy)."""
        return (
            self._remix is None
            and self._built_remix is not None
            and bool(self.tables)
            and len(self._built_tables) == len(self.tables)
            and all(a is b for a, b in zip(self._built_tables, self.tables))
            and all(t.path is not None and not t.resident for t in self.tables)
        )

    def cold_disk_bytes(self) -> int:
        """Physical bytes cold reads have pulled from this partition."""
        return sum(
            t._reader.disk_bytes_read
            for t in self.tables
            if t._reader is not None
        )

    def _row_bytes(self) -> int:
        """Logical bytes per served row (matches ``Table.bytes()``)."""
        vw = self.tables[0].vw if self.tables else 2
        return 8 + 4 * vw + 5

    def promotion_inputs(self, fraction: float = 0.5) -> dict:
        """Observed-workload inputs of the promotion decision.

        Two counters, both compared against the same ``fraction`` of the
        partition's data bytes:

        - ``disk_bytes`` — physical bytes cold reads pulled (cache hits
          excluded): the original pay-as-you-go signal.
        - ``served_bytes`` — logical row bytes cold queries *touched*,
          hits included. Once the block cache absorbs a hot partition's
          working set the disk counter stalls, so a byte-fraction rule
          alone would never promote it no matter how much traffic it
          serves; the served counter keeps observing the workload.
        """
        total = sum(t._rd().data_bytes() for t in self.tables)  # header-only
        disk = self.cold_disk_bytes()
        served = self.cold_served_rows * self._row_bytes()
        threshold = int(fraction * max(1, total))
        return dict(
            lo=self.lo,
            data_bytes=int(total),
            disk_bytes=int(disk),
            served_bytes=int(served),
            cold_gets=int(self.cold_gets),
            cold_scans=int(self.cold_scans),
            threshold_bytes=threshold,
            promote=bool(disk >= threshold or served >= threshold),
        )

    def should_promote(self, fraction: float = 0.5) -> bool:
        """Build the device RunSet once the observed cold workload — the
        physical bytes it pulled *or* the logical bytes it served out of
        the cache — reaches ``fraction`` of the data region (see
        :meth:`promotion_inputs` for the two counters)."""
        return self.promotion_inputs(fraction)["promote"]

    def _host_index(self) -> dict:
        """Host numpy view of the built REMIX (anchors as u64 for search)."""
        rm = self._built_remix
        if self._host is None or self._host["remix"] is not rm:
            anchors = np.asarray(rm.anchors)
            self._host = dict(
                remix=rm,
                anch64=CK.unpack_u64(anchors),
                cursors=np.asarray(rm.cursors),
                selectors=np.asarray(rm.selectors),
                d=rm.d,
                n_slots=rm.n_slots,
            )
        return self._host

    def _group_rows(self, hx: dict, g: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-run row ranges [cur, nxt) covered by group ``g``."""
        cur = hx["cursors"][g].astype(np.int64)
        if g + 1 < hx["cursors"].shape[0]:
            nxt = hx["cursors"][g + 1].astype(np.int64)
        else:
            nxt = np.array([t.n for t in self.tables], np.int64)
        return cur, nxt

    def _group_bounds_batch(self, hx: dict, keys: np.ndarray):
        """Vectorized anchors search + cursor gather for a key batch.

        Returns (g (Q,), cur (Q, R), nxt (Q, R)) — the batched analogue
        of one scalar searchsorted + :meth:`_group_rows` per key.
        """
        g = np.maximum(
            np.searchsorted(hx["anch64"], keys, side="right") - 1, 0
        )
        cursors = hx["cursors"]
        gcount = cursors.shape[0]
        ns = np.array([t.n for t in self.tables], np.int64)
        cur = cursors[g].astype(np.int64)
        nxt = np.where(
            (g + 1 < gcount)[:, None],
            cursors[np.minimum(g + 1, gcount - 1)].astype(np.int64),
            ns[None, :],
        )
        return g, cur, nxt

    def _gather_emit(self, er, erow, windows, vw: int):
        """Emit live (key, value) rows for one walked window.

        ``er``/``erow`` are the emitted runs/absolute rows in view order;
        ``windows[r]`` answers run ``r``'s rows (``RowWindow.gather``).
        Shared by the scalar and batched scan paths so both stay
        bit-identical by construction: gather per run, scatter back into
        view order, drop dead rows (tombstones, expired TTLs, and keys an
        excised span hides).
        """
        kk = np.empty(len(er), np.uint64)
        vv = np.empty((len(er), vw), np.uint32)
        dead = np.zeros(len(er), bool)
        for r in np.unique(er):
            m = er == r
            kk[m], vv[m], dead[m] = windows[r].gather(erow[m])
            if self.excised:
                dead[m] |= self._span_dead(r, kk[m])
        live = ~dead
        return kk[live], vv[live]

    def _seek_slot(self, hx: dict, g: int, cur, nextrow) -> int:
        """View position implied by the per-run seek results of group
        ``g`` (with the device-parity placeholder hop)."""
        d, sels, n_slots = hx["d"], hx["selectors"], hx["n_slots"]
        pos = g * d + int(np.sum(nextrow - cur))
        # device-seek parity (_ingroup_vector): landing on a trailing
        # placeholder means every real entry of the group is < start, so
        # the true lower bound is the next group's head — the window must
        # not waste budget on the placeholder tail.
        if pos < min(n_slots, (g + 1) * d) and int(sels[pos]) == PLACEHOLDER:
            pos = (g + 1) * d
        return min(pos, n_slots)

    def _walk_from(self, hx: dict, pos: int, nextrow, width: int):
        """Vectorized selector walk of ``width`` view slots from ``pos``.

        Replaces the slot-by-slot Python loop: the whole window's
        selectors are classified at once and each run's occurrences get
        consecutive rows via one cumulative count per run. Requires
        ``nextrow`` to hold each run's next absolute row at ``pos`` —
        which is exactly what a seek produces and what this walk leaves
        behind, so windows chain without re-seeking (the cursor's
        comparison-free ``next``, §3.3). Mutates ``nextrow`` to the
        post-window pointers. Returns ``(pos, stop, valid, win,
        rows_abs, newest)``: window slot bounds, the per-slot
        non-placeholder mask, raw selector values, absolute rows
        assigned per slot, and the newest-version emission mask.
        """
        sels, n_slots = hx["selectors"], hx["n_slots"]
        stop = min(n_slots, pos + width)
        win = sels[pos:stop].astype(np.int64)
        valid = win != PLACEHOLDER
        rows_abs = np.zeros(len(win), np.int64)
        for r in range(len(self.tables)):
            m = valid & ((win & 0x7F) == r)
            c = int(np.count_nonzero(m))
            if c:
                rows_abs[m] = int(nextrow[r]) + np.arange(c)
                nextrow[r] += c
        newest = valid & ((win & NEWEST_BIT) != 0)
        return pos, stop, valid, win, rows_abs, newest

    def _walk_window(self, hx: dict, g: int, cur, nextrow, width: int):
        """Seek-position + selector walk in one step (scan entry point)."""
        pos = self._seek_slot(hx, g, cur, nextrow)
        return self._walk_from(hx, pos, nextrow, width)

    def cold_get(self, key: int) -> tuple[bool, np.ndarray | None]:
        """Point lookup from the on-disk REMIX without loading any table.

        Anchors binary search on the host, then one *bounded* CKB
        restart-point seek per run — the group's cursor offsets restrict
        each seek to at most D rows, so each run contributes O(1) block
        reads — and finally at most one tomb byte and one value row are
        fetched from the run the selector names (§3.2 adapted to
        block-granular I/O). Returns (found, value row)."""
        hx = self._host_index()
        self.cold_gets += 1
        self.cold_served_rows += 1
        d, sels = hx["d"], hx["selectors"]
        g = max(
            int(np.searchsorted(hx["anch64"], np.uint64(key), side="right"))
            - 1,
            0,
        )
        cur, nxt = self._group_rows(hx, g)
        qw = CK.pack_u64(np.array([key], np.uint64))[0]
        rows = [
            t.seek_row(qw, int(cur[r]), int(nxt[r]))
            for r, t in enumerate(self.tables)
        ]
        s = int(sum(rows[r] - int(cur[r]) for r in range(len(rows))))
        pos = g * d + s
        if s >= d or pos >= hx["n_slots"]:
            return False, None
        sel = int(sels[pos])
        if sel == PLACEHOLDER or not (sel & NEWEST_BIT):
            return False, None
        run = sel & 0x7F
        row = rows[run]
        t = self.tables[run]
        if not np.array_equal(t.key_at(row), qw):
            return False, None
        if self._covered(run, int(key)):
            return False, None
        if bool(t.dead_rows(row, row + 1)[0]):
            return False, None
        return True, t.rows("vals", row, row + 1)[0]

    def cold_get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized point lookups off the on-disk REMIX.

        The batched counterpart of :meth:`cold_get`, bit-identical per
        key, with the per-key Python work replaced by whole-batch array
        ops: one vectorized anchors binary search, one grouped
        :meth:`Table.seek_rows_batch` per run (restart-narrowed,
        range-merged), a vectorized selector resolve, and finally
        key-check/tombstone/value fetches grouped per run with all
        (file, block) granules deduplicated — each granule a batch
        touches is read exactly once. Returns (found (Q,), vals (Q, VW)).
        """
        keys = np.asarray(keys, np.uint64)
        q = len(keys)
        vw = self.tables[0].vw if self.tables else 2
        found = np.zeros(q, bool)
        vals = np.zeros((q, vw), np.uint32)
        if q == 0 or not self.tables:
            return found, vals
        hx = self._host_index()
        self.cold_gets += q
        self.cold_served_rows += q
        d, sels, n_slots = hx["d"], hx["selectors"], hx["n_slots"]
        nrun = len(self.tables)
        g, cur, nxt = self._group_bounds_batch(hx, keys)
        rows = np.empty((q, nrun), np.int64)
        keyat = np.empty((q, nrun), np.uint64)
        known = np.empty((q, nrun), bool)
        for r, t in enumerate(self.tables):
            rows[:, r], keyat[:, r], known[:, r] = t.seek_rows_batch(
                keys, cur[:, r], nxt[:, r], return_keys=True
            )
        s = (rows - cur).sum(axis=1)
        pos = g * d + s
        ok = (s < d) & (pos < n_slots)
        sel = np.where(
            ok, sels[np.minimum(pos, n_slots - 1)].astype(np.int64),
            PLACEHOLDER,
        )
        ok &= (sel != PLACEHOLDER) & ((sel & NEWEST_BIT) != 0)
        run = np.where(ok, sel & 0x7F, 0)
        row = rows[np.arange(q), np.minimum(run, nrun - 1)]
        for r in np.unique(run[ok]):
            t = self.tables[r]
            m = ok & (run == r)
            rr = row[m]
            # hit verification: keys the CKB decoder already resolved
            # cost nothing; only unresolved rows (decoder off / no CKB)
            # fall back to a fixed-width keys-section fetch
            kn = known[m, r]
            match = np.empty(len(rr), bool)
            match[kn] = keyat[m, r][kn] == keys[m][kn]
            if (~kn).any():
                match[~kn] = t.keys_u64_rows(rr[~kn]) == keys[m][~kn]
            qi = np.flatnonzero(m)[match]
            rv = rr[match]
            if not len(qi):
                continue
            live = ~t.dead_rows_scattered(rv)
            if self.excised:
                live &= ~self._span_dead(r, keys[qi])
            found[qi] = live
            if live.any():
                vals[qi[live]] = t.rows_scattered("vals", rv[live])
        return found, vals

    def cold_scan(self, start: int, width: int, prefetch_depth: int = 0):
        """Range scan over a ``width``-slot view window without whole-table
        loads: seek as in :meth:`cold_get`, walk the selector stream
        (comparison-free next, §3.3) to find the touched per-run row
        ranges, then materialize only the emitted row spans per run. The
        window covers exactly ``width`` view slots from the seek
        position — placeholders, old versions and tombstones consume
        budget — matching the device path's ``gather_view`` window
        bit-for-bit, so promotion never changes scan results.

        With ``prefetch_depth > 0`` the materialization is pipelined per
        selector group (paper Fig 10): while group *i*'s rows are being
        fetched and emitted, the value/tomb blocks of groups
        ``i+1 .. i+depth`` — already known exactly from the decoded
        selector stream — are issued into the block cache, so a demand
        read behind the emitter always finds its granule resident. The
        prefetched block set equals the eager path's demand set (the
        stream names precisely which rows each group touches), so
        pipelining never reads a block the eager path would not.

        Returns (keys (M,) u64, vals (M, VW), more) — live entries in
        ascending order, M ≤ width, and whether view slots remain beyond
        the window (so an all-invalid window is distinguishable from an
        exhausted partition)."""
        state = self.cold_cursor_seek(start)
        return self.cold_cursor_window(
            state, width, prefetch_depth=prefetch_depth
        )

    def _emit_window(
        self, pos, stop, win, rows_abs, newest, depth, vw, d
    ) -> tuple[np.ndarray, np.ndarray]:
        """Materialize and emit one walked window, group-pipelined.

        The window's emitted slots are split into selector-group chunks
        (one chunk — the whole window — when ``depth == 0``, i.e. the
        eager path). Per chunk and run, the emitted row span is fetched
        as one coalesced range; with ``depth > 0`` the *next* chunks'
        value/tomb granules are issued to the cache first.
        """
        runsel = win & 0x7F
        slots = np.arange(pos, stop)
        if depth > 0 and not self._window_resident(runsel, rows_abs, newest):
            bounds = (
                [pos]
                + list(range((pos // d + 1) * d, stop, d))
                + [stop]
            )
        else:
            # eager path — or a fully-warm window, where the group-ahead
            # pipeline would issue no prefetch (every granule resident)
            # and only pay per-group fetch overhead: one span per run
            bounds = [pos, stop]
        nrun = len(self.tables)
        chunk_ranges: list[list[tuple[int, int]]] = []
        for a, b in zip(bounds[:-1], bounds[1:]):
            inb = (slots >= a) & (slots < b) & newest
            rng = []
            for r in range(nrun):
                rr = rows_abs[inb & (runsel == r)]
                rng.append((int(rr[0]), int(rr[-1]) + 1) if len(rr) else (0, 0))
            chunk_ranges.append(rng)
        ks_out: list[np.ndarray] = []
        vs_out: list[np.ndarray] = []
        issued: set[tuple[int, int]] = set()  # (run, granule) already sent
        for ci, (a, b) in enumerate(zip(bounds[:-1], bounds[1:])):
            for cj in range(ci + 1, min(ci + 1 + depth, len(chunk_ranges))):
                for r in range(nrun):
                    lo2, hi2 = chunk_ranges[cj][r]
                    if hi2 <= lo2:
                        continue
                    # one deduped issue set per (chunk, run): the vals
                    # and tomb sections share boundary granules, and
                    # successive lookahead windows revisit chunks — each
                    # granule is issued to the cache at most once per
                    # window emission
                    t = self.tables[r]
                    ids = set(t.row_block_ids("vals", lo2, hi2))
                    ids.update(t.row_block_ids("tomb", lo2, hi2))
                    fresh = [bi for bi in sorted(ids)
                             if (r, bi) not in issued]
                    issued.update((r, bi) for bi in fresh)
                    t.prefetch_blocks(fresh)
            inb = (slots >= a) & (slots < b) & newest
            if not inb.any():
                continue
            er, erow = runsel[inb], rows_abs[inb]
            # each run's emitted rows lie inside one contiguous span
            # (occurrence counting assigns window rows in view order),
            # so per section one span fetch + an index gather suffices —
            # no range merging or searchsorted row resolution needed
            kk = np.empty(len(er), np.uint64)
            vv2 = np.empty((len(er), vw), np.uint32)
            dead = np.zeros(len(er), bool)
            for r in np.unique(er):
                m = er == r
                lo2, hi2 = chunk_ranges[ci][r]
                idx = erow[m] - lo2  # old-version rows interleave: gather
                t = self.tables[r]
                kk[m] = CK.unpack_u64(t.rows("keys", lo2, hi2))[idx]
                vv2[m] = t.rows("vals", lo2, hi2)[idx]
                dead[m] = t.dead_rows(lo2, hi2)[idx]
                if self.excised:
                    dead[m] |= self._span_dead(r, kk[m])
            live = ~dead
            ks_out.append(kk[live])
            vs_out.append(vv2[live])
        if not ks_out:
            return np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32)
        return np.concatenate(ks_out), np.concatenate(vs_out)

    # ---- cursor continuation (streaming scans without re-seeking) ----
    def _cursor_state(self, start: int) -> dict:
        """Bare continuation state (no skip table): the view position of
        ``start``'s lower bound plus the per-run next-row pointers."""
        hx = self._host_index()
        g = max(
            int(np.searchsorted(hx["anch64"], np.uint64(start), side="right"))
            - 1,
            0,
        )
        cur, nxt = self._group_rows(hx, g)
        qw = CK.pack_u64(np.array([start], np.uint64))[0]
        nextrow = np.array(
            [
                t.seek_row(qw, int(cur[r]), int(nxt[r]))
                for r, t in enumerate(self.tables)
            ],
            np.int64,
        )
        return dict(pos=self._seek_slot(hx, g, cur, nextrow), nextrow=nextrow)

    def cold_cursor_seek(self, start: int) -> dict:
        """Continuation state for a streaming cold scan: the view position
        of ``start``'s lower bound plus the per-run next-row pointers.

        One anchors binary search + one bounded CKB seek per run — paid
        exactly once per cursor; every subsequent window is a pure
        selector-stream decode (:meth:`cold_cursor_window`).

        Excised spans covering *all* tables additionally contribute a
        ``skips`` table of view-position intervals: everything inside
        them is dead by construction, so the window walk jumps over them
        structurally — no selector decode, no key/value block reads —
        resuming with the span-end seek's next-row pointers."""
        state = self._cursor_state(start)
        spans = self.full_spans() if self.excised else ()
        if spans:
            skips = []
            for lo, hi in spans:
                a = self._cursor_state(lo)
                b = self._cursor_state(hi)
                if b["pos"] > a["pos"]:
                    skips.append((int(a["pos"]), int(b["pos"]),
                                  b["nextrow"]))
            if skips:
                state["skips"] = sorted(skips)
        return state

    def cold_cursor_window(self, state: dict, width: int,
                           prefetch_depth: int = 0):
        """Walk the next ``width`` view slots from ``state`` (no seek).

        The comparison-free ``next × width`` of the paper's cursor
        (§3.3): decode the persisted selector stream from the saved
        position, fetch only the emitted row spans, advance the state.
        Returns (keys, vals, more) exactly like :meth:`cold_scan`; a
        fresh ``cold_cursor_seek(start)`` followed by chained windows
        yields bit-identical rows to repeated ``cold_scan`` calls."""
        hx = self._host_index()
        self.cold_scans += 1
        vw = self.tables[0].vw if self.tables else 2
        pos0 = int(state["pos"])
        # structural skip: jump excised view intervals, clamp the walk so
        # a window never enters one (its blocks are never touched)
        for slo, shi, nrow in state.get("skips", ()):
            if slo <= pos0 < shi:
                pos0 = shi
                state["pos"] = shi
                state["nextrow"] = nrow.copy()
            elif pos0 < slo:
                width = min(width, slo - pos0)
                break
        if pos0 >= hx["n_slots"]:
            return np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32), False
        pos, stop, valid, win, rows_abs, newest = self._walk_from(
            hx, pos0, state["nextrow"], width
        )
        state["pos"] = stop
        more = stop < hx["n_slots"]
        if not bool(newest.any()):
            return np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32), more
        kk, vv = self._emit_window(
            pos, stop, win, rows_abs, newest, prefetch_depth, vw, hx["d"]
        )
        self.cold_served_rows += len(kk)
        return kk, vv, more

    def _window_resident(self, runsel, rows_abs, newest) -> bool:
        """Whether every granule a window's emission touches is already
        cached/verified (no I/O left to overlap — pipelining it would be
        pure per-group overhead). Side-effect-free."""
        for r in range(len(self.tables)):
            rr = rows_abs[newest & (runsel == r)]
            if not len(rr):
                continue
            lo, hi = int(rr[0]), int(rr[-1]) + 1
            t = self.tables[r]
            if not all(
                t.rows_resident(sec, lo, hi)
                for sec in ("keys", "vals", "tomb")
            ):
                return False
        return True

    def _dead_fetcher(self, r: int):
        """Section fetcher for run ``r`` whose "tomb" answers are the
        combined liveness column (tomb | expired TTL) — lets RowWindow
        stay liveness-agnostic. Free when the table carries no TTLs."""
        t = self.tables[r]
        if not t.ttl_present():
            return t.rows_scattered
        now = clock.now()

        def fetch(section, rows):
            if section == "tomb":
                return t.dead_rows_scattered(rows, now)
            return t.rows_scattered(section, rows)

        return fetch

    def cold_scan_batch(self, starts, width) -> list[tuple]:
        """Batched :meth:`cold_scan`: one vectorized anchors search and
        one grouped per-run seek for the whole batch, then per-query
        selector walks whose touched row spans are **coalesced per run**
        (``merge_ranges``) before fetching — interleaved scan windows
        share granules, and each touched (file, block) granule is read
        at most once for the batch. ``width`` may be a scalar or a (Q,)
        array — heterogeneous scan groups merge their row windows into
        the same coalesced fetch set. Returns a list of per-query
        ``(keys, vals, more)`` triples, bit-identical to cold_scan.

        (No prefetch pipeline here: the batch path already fetches every
        window's blocks in one coalesced pass up front, which strictly
        dominates group-ahead prefetching.)"""
        starts = np.asarray(starts, np.uint64)
        q = len(starts)
        widths = np.zeros(q, np.int64) + np.asarray(width, np.int64)
        vw = self.tables[0].vw if self.tables else 2
        empty = (np.zeros(0, np.uint64), np.zeros((0, vw), np.uint32), False)
        if q == 0 or not self.tables:
            return [empty] * q
        hx = self._host_index()
        self.cold_scans += q
        n_slots = hx["n_slots"]
        nrun = len(self.tables)
        g, cur, nxt = self._group_bounds_batch(hx, starts)
        nextrow = np.empty((q, nrun), np.int64)
        for r, t in enumerate(self.tables):
            nextrow[:, r] = t.seek_rows_batch(starts, cur[:, r], nxt[:, r])
        walks = []
        ranges_by_run: list[list[tuple[int, int]]] = [[] for _ in range(nrun)]
        for i in range(q):
            pos, stop, valid, win, rows_abs, newest = self._walk_window(
                hx, int(g[i]), cur[i], nextrow[i], int(widths[i])
            )
            er = (win & 0x7F)[newest]
            erow = rows_abs[newest]
            for r in np.unique(er):
                rr = erow[er == r]
                ranges_by_run[r].append((int(rr[0]), int(rr[-1]) + 1))
            walks.append((er, erow, stop < n_slots))
        windows = [
            RowWindow.from_scattered(ranges_by_run[r], self._dead_fetcher(r))
            for r in range(nrun)
        ]
        out = []
        for er, erow, more in walks:
            if er.size == 0:
                out.append((empty[0], empty[1], more))
                continue
            kk, vv = self._gather_emit(er, erow, windows, vw)
            self.cold_served_rows += len(kk)
            out.append((kk, vv, more))
        return out

    @property
    def n_entries(self) -> int:
        return sum(t.n for t in self.tables)

    def data_bytes(self) -> int:
        return sum(t.bytes() for t in self.tables)

    def index(self) -> tuple[Remix, RunSet]:
        """Build (or reuse) the partition's REMIX + stacked runs.

        Shapes are bucket-padded to powers of two so every partition of a
        store shares the same compiled query executables (shape-stable
        kernels — one jit per bucket instead of one per partition).
        """
        # TTL staleness: tomb marks were baked at build time; once the
        # clock passes the earliest future expiry, rebuild the runset
        # (the REMIX itself is liveness-independent and gets reused)
        if (
            self._remix is not None
            and self._ttl_next is not None
            and clock.now() >= self._ttl_next
        ):
            self._remix = None
            self._runset = None
        if self._remix is None:
            tabs = self.tables or [
                Table(
                    keys=np.zeros(0, np.uint64),
                    vals=np.zeros((0, 2), np.uint32),
                    seq=np.zeros(0, np.uint32),
                    tomb=np.zeros(0, bool),
                )
            ]
            d = max(self.d, len(tabs))  # paper requires D >= R
            now = clock.now()
            runs = [
                make_run(t.keys, t.vals, seq=t.seq,
                         tomb=self._build_dead(t, now), sort=False)
                for t in tabs
            ]
            nexts = [t.min_future_exp(now) for t in tabs]
            self._ttl_next = min(
                (x for x in nexts if x is not None), default=None
            )
            remix = self._try_incremental(tabs, d)
            if remix is not None:
                from repro.core.runs import stack_runs

                runset = stack_runs(runs)
            else:
                remix, runset = build_remix(runs, d=d)
                self.last_build_kind = "scratch"
            self._built_remix = remix
            self._built_tables = list(tabs) if self.tables else []
            self.remix_bytes = int(remix.storage_bytes())
            self._remix, self._runset = _pad_index(remix, runset, d)
        return self._remix, self._runset

    def _build_dead(self, t: Table, now: float) -> np.ndarray:
        """Liveness column baked into the device runset for table ``t``:
        tombstones, TTL-expired rows, and rows an excised span covers.
        Exact for point/scan results: a covered or expired newest version
        decodes as a tombstone slot, and any newer uncovered version
        lives in a later-born table the span doesn't cover."""
        return t.dead(now) | self._span_cover(t)

    def _span_cover(self, t: Table) -> np.ndarray:
        """(N,) bool: rows of ``t`` hidden by an excised span covering it
        — structural deadness (a covered row can never revive), safe to
        bake into any uploaded view regardless of the query clock."""
        dead = np.zeros(t.n, bool)
        for sp in self.excised:
            if sp.covers_table(t):
                m = (t.keys >= np.uint64(sp.lo)) & (t.keys < np.uint64(sp.hi))
                if m.any():
                    dead = dead | m
        return dead

    # ---------------- device-resident view (kernels/device_view.py) ----
    def device_view_bytes(self, with_vals: bool = True) -> int:
        """Estimated padded device-buffer bytes of :meth:`device_index`
        (header-cheap: no section loads) — the upload/tier decision input
        of the :class:`~repro.kernels.device_view.DeviceViewManager`."""
        tabs = self.tables
        r2 = _pow2(max(1, len(tabs)), 1)
        n2 = _pow2(max((t.n for t in tabs), default=1), 64)
        d = max(self.d, len(tabs))
        kw = 2
        vw = (tabs[0].vw if tabs else 2) if with_vals else 1
        g2 = _pow2(max(1, -(-self.n_entries // d)), 4)
        per_row = 4 * kw + 4 * vw + 4 + 1 + 4  # keys+vals+seq+tomb+exp
        return int(g2 * (4 * kw + 4 * r2 + d) + r2 * n2 * per_row + r2 * 4)

    def device_index(self, with_vals: bool = True):
        """Padded ``(remix, runset, exp)`` for the device-resident view.

        Unlike :meth:`index`, liveness is *not* baked at build time: the
        runset tombstones carry only real tombstones plus excised-span
        coverage (structural), and the per-row TTL expiry words ride
        along as a padded (R, Nmax) uint32 array so the device evaluates
        ``tomb | (exp != 0 & exp <= now)`` at query time — bit-for-bit
        the :meth:`_build_dead` set at the same instant, and a persistent
        view never goes stale when the clock passes an expiry.

        With ``with_vals=False`` (the index-only residency tier) the
        value sections stay host-side: the runset carries 1-word dummy
        values and callers gather real value granules through the
        BlockCache from the returned (run, row) coordinates.

        Shares the REMIX structure cache (``_built_remix`` /
        incremental rebuilds) with :meth:`index` — the structure is
        liveness-independent, so the two paths reuse each other's build.
        """
        tabs = self.tables or [
            Table(
                keys=np.zeros(0, np.uint64),
                vals=np.zeros((0, 2), np.uint32),
                seq=np.zeros(0, np.uint32),
                tomb=np.zeros(0, bool),
            )
        ]
        d = max(self.d, len(tabs))  # paper requires D >= R
        runs, exps = [], []
        for t in tabs:
            dead = np.asarray(t.tomb, bool) | self._span_cover(t)
            vals = t.vals if with_vals else np.zeros((t.n, 1), np.uint32)
            runs.append(
                make_run(t.keys, vals, seq=t.seq, tomb=dead, sort=False)
            )
            exps.append(
                np.asarray(t.exp, np.uint32)
                if t.ttl_present()
                else np.zeros(t.n, np.uint32)
            )
        remix = self._try_incremental(tabs, d)
        if remix is not None:
            from repro.core.runs import stack_runs

            runset = stack_runs(runs)
        else:
            remix, runset = build_remix(runs, d=d)
            self.last_build_kind = "scratch"
        self._built_remix = remix
        self._built_tables = list(tabs) if self.tables else []
        self.remix_bytes = int(remix.storage_bytes())
        remix_p, runset_p = _pad_index(remix, runset, d)
        exp_p = np.zeros((runset_p.r, runset_p.nmax), np.uint32)
        for i, e in enumerate(exps):
            exp_p[i, : len(e)] = e
        import jax.numpy as jnp

        return remix_p, runset_p, jnp.asarray(exp_p)

    def _try_incremental(self, tabs: list[Table], d: int) -> Remix | None:
        """Reuse/extend the last built REMIX when this rebuild only appended
        tables (minor compaction) — zero key comparisons among old runs.

        Returns None when the table set changed in any other way (major,
        split, first build) or the group size moved; those rebuild from
        scratch.
        """
        prev, base = self._built_remix, self._built_tables
        if prev is None or not base or prev.r != len(base) or prev.d != d:
            return None
        if len(tabs) < len(base) or any(
            a is not b for a, b in zip(base, tabs)
        ):
            return None
        if len(tabs) == len(base):  # nothing changed: reuse as-is
            self.last_build_kind = "reuse"
            return prev
        from repro.io.rebuild import incremental_build_remix

        new = tabs[len(base):]
        remix = incremental_build_remix(
            prev,
            [t.key_words() for t in base],
            [t.key_words() for t in new],
            [np.asarray(t.seq) for t in new],
            d=d,
        )
        self.last_build_kind = "incremental"
        return remix

    def persist_index(self, storage) -> None:
        """Build (if needed) and serialize this partition's REMIX; the
        padded on-device copy is derived, only the unpadded index persists."""
        self.index()
        self.remix_name = storage.write_remix(self._built_remix)

    def estimate_remix_bytes(self, extra_entries: int = 0) -> int:
        """Size estimate of a REMIX over current + new entries (§4.2 Abort)."""
        n = self.n_entries + extra_entries
        r = len(self.tables) + 1
        groups = max(1, n // self.d)
        return int(groups * (8 + 4 * r) + n)

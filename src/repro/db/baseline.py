"""Baseline LSM stores: leveled (LevelDB-like) and tiered (PebblesDB-like).

Same MemTable + Table machinery as RemixDB, but queries run through the
merging iterator over all overlapping sorted runs (plus optional bloom
filters for point queries) — the configurations the paper compares against
(§5.2). Write amplification is tracked identically for the fig-16 bench.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import keys as CK
from repro.core import merge_iter as M
from repro.core.bloom import bloom_maybe_contains, build_bloom
from repro.core.runs import make_run, stack_runs
from repro.db.memtable import MemTable
from repro.db.partition import Table, chunk_table, merge_tables


@dataclasses.dataclass
class BaselineConfig:
    vw: int = 2
    memtable_entries: int = 1 << 18
    table_cap: int = 65536
    l0_limit: int = 4  # L0 run count triggering compaction into L1
    level_ratio: int = 10  # leveled: size ratio between adjacent levels
    tier_t: int = 4  # tiered: runs per level before merge (ScyllaDB T=4)
    use_bloom: bool = True


class _LSMBase:
    def __init__(self, cfg: BaselineConfig | None = None):
        self.cfg = cfg or BaselineConfig()
        self.mem = MemTable(vw=self.cfg.vw)
        self.seq = 1
        self.user_bytes = 0
        self.table_bytes_written = 0
        self._runset_cache = None

    def put_batch(self, keys, vals):
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.cfg.vw)
        self.seq = self.mem.put_batch(keys, vals, self.seq)
        self.user_bytes += len(keys) * (8 + 4 * self.cfg.vw)
        if len(self.mem) >= self.cfg.memtable_entries:
            self.flush()

    def put(self, key, val):
        self.put_batch([key], [val])

    def _mem_to_table(self) -> Table:
        keys, vals, seq, tomb, *_ = self.mem.to_arrays()
        self.mem = MemTable(vw=self.cfg.vw)
        return Table(keys=keys, vals=vals, seq=seq, tomb=tomb)

    # ---- query plumbing shared by both baselines ----
    def _sorted_runs(self) -> list[Table]:
        raise NotImplementedError

    def runset(self):
        if self._runset_cache is None:
            tables = self._sorted_runs() or [
                Table(
                    keys=np.zeros(0, np.uint64),
                    vals=np.zeros((0, self.cfg.vw), np.uint32),
                    seq=np.zeros(0, np.uint32),
                    tomb=np.zeros(0, bool),
                )
            ]
            runs = [
                make_run(t.keys, t.vals, seq=t.seq, tomb=t.tomb, sort=False)
                for t in tables
            ]
            rs = stack_runs(runs)
            blooms = (
                build_bloom([np.asarray(r.keys) for r in runs])
                if self.cfg.use_bloom
                else None
            )
            self._runset_cache = (rs, blooms)
        return self._runset_cache

    def n_runs(self) -> int:
        return len(self._sorted_runs())

    def get_batch(self, keys):
        keys = np.asarray(keys, np.uint64)
        found = np.zeros(len(keys), bool)
        vals = np.zeros((len(keys), self.cfg.vw), np.uint32)
        rest = []
        for i, k in enumerate(keys.tolist()):
            e = self.mem.get(k)
            if e is not None:
                found[i] = not e.tomb
                vals[i] = e.val
            else:
                rest.append(i)
        if rest:
            rest = np.array(rest)
            rs, _ = self.runset()
            qk = jnp.asarray(CK.pack_u64(keys[rest]))
            f, v = M.merge_get(rs, qk)
            found[rest] = np.asarray(f)
            vals[rest] = np.asarray(v)
        return found, vals

    def scan(self, start_key: int, n: int):
        rs, _ = self.runset()
        qk = jnp.asarray(CK.pack_u64(np.array([start_key], np.uint64)))
        width = n + n // 2 + 8
        keys, vals, valid = M.merge_scan(rs, qk, width=width)
        kk = CK.unpack_u64(np.asarray(keys)[0][np.asarray(valid)[0]])
        vv = np.asarray(vals)[0][np.asarray(valid)[0]]
        merged: dict[int, np.ndarray | None] = {
            int(k): v for k, v in zip(kk, vv)
        }
        limit = int(kk[-1]) if len(kk) >= n else (1 << 64)
        for k, e in self.mem.data.items():
            if start_key <= k <= limit:
                merged[k] = None if e.tomb else e.val
        items = sorted(
            ((k, v) for k, v in merged.items() if v is not None),
            key=lambda kv: kv[0],
        )[:n]
        if not items:
            return np.zeros(0, np.uint64), np.zeros((0, self.cfg.vw), np.uint32)
        return (
            np.array([k for k, _ in items], np.uint64),
            np.stack([v for _, v in items]),
        )

    def scan_batch(self, starts, n: int):
        """Batched scans via the merging iterator (single jitted call)."""
        starts = np.asarray(starts, np.uint64)
        rs, _ = self.runset()
        qk = jnp.asarray(CK.pack_u64(starts))
        width = n + max(8, n // 2)
        keys, vals, valid = M.merge_scan(rs, qk, width=width)
        keys = CK.unpack_u64(np.asarray(keys))
        valid = np.asarray(valid)
        out_k = np.zeros((len(starts), n), np.uint64)
        out_m = np.zeros((len(starts), n), bool)
        for i in range(len(starts)):
            kk = keys[i][valid[i]][:n]
            out_k[i, : len(kk)] = kk
            out_m[i, : len(kk)] = True
        if len(self.mem):
            for i in range(len(starts)):
                kk, _ = self.scan(int(starts[i]), n)
                out_k[i, : len(kk)] = kk[:n]
                out_m[i] = False
                out_m[i, : len(kk)] = True
        return out_k, out_m

    def write_amplification(self) -> float:
        return self.table_bytes_written / max(1, self.user_bytes)


class LeveledStore(_LSMBase):
    """Leveled compaction: L0 overlapping runs, L1.. single sorted runs."""

    def __init__(self, cfg: BaselineConfig | None = None):
        super().__init__(cfg)
        self.l0: list[Table] = []
        self.levels: list[Table] = []  # one merged run per level, L1..

    def _level_cap(self, i: int) -> int:
        return self.cfg.table_cap * 4 * (self.cfg.level_ratio ** i)

    def flush(self):
        t = self._mem_to_table()
        if t.n == 0:
            return
        self.table_bytes_written += t.bytes()
        self.l0.append(t)
        self._runset_cache = None
        if len(self.l0) >= self.cfg.l0_limit:
            self._compact_l0()

    def _compact_l0(self):
        inputs = self.l0 + ([self.levels[0]] if self.levels else [])
        merged = merge_tables(inputs, drop_tombs=len(self.levels) <= 1)
        self.table_bytes_written += merged.bytes()
        if self.levels:
            self.levels[0] = merged
        else:
            self.levels.append(merged)
        self.l0 = []
        # cascade: push overflowing levels down (each rewrite amplifies)
        i = 0
        while i < len(self.levels) and self.levels[i].n > self._level_cap(i + 1):
            if i + 1 >= len(self.levels):
                self.levels.append(self.levels[i])
                self.levels[i] = None  # type: ignore
            else:
                merged = merge_tables(
                    [self.levels[i], self.levels[i + 1]],
                    drop_tombs=(i + 2 >= len(self.levels)),
                )
                self.table_bytes_written += merged.bytes()
                self.levels[i + 1] = merged
                self.levels[i] = None  # type: ignore
            self.levels[i] = Table(
                keys=np.zeros(0, np.uint64),
                vals=np.zeros((0, self.cfg.vw), np.uint32),
                seq=np.zeros(0, np.uint32),
                tomb=np.zeros(0, bool),
            )
            i += 1
        self._runset_cache = None

    def _sorted_runs(self) -> list[Table]:
        return [t for t in self.l0 if t.n] + [
            t for t in self.levels if t is not None and t.n
        ]


class TieredStore(_LSMBase):
    """Tiered compaction: up to T overlapping runs per level (§2)."""

    def __init__(self, cfg: BaselineConfig | None = None):
        super().__init__(cfg)
        self.tiers: list[list[Table]] = [[]]

    def flush(self):
        t = self._mem_to_table()
        if t.n == 0:
            return
        self.table_bytes_written += t.bytes()
        self.tiers[0].append(t)
        self._runset_cache = None
        i = 0
        while i < len(self.tiers) and len(self.tiers[i]) >= self.cfg.tier_t:
            merged = merge_tables(
                self.tiers[i], drop_tombs=(i + 1 >= len(self.tiers))
            )
            self.table_bytes_written += merged.bytes()
            if i + 1 >= len(self.tiers):
                self.tiers.append([])
            self.tiers[i + 1].append(merged)
            self.tiers[i] = []
            i += 1

    def _sorted_runs(self) -> list[Table]:
        return [t for tier in self.tiers for t in tier if t.n]

"""RemixDB (paper §4): a REMIX-indexed, tiered-compaction, partitioned store.

  - memtable:   sorted write buffer with 8-bit update counters (§4.2 TRIAD)
  - wal:        4 KB-block write-ahead log with virtual logs + GC (§4.3)
  - partition:  key-range partition = table files + one REMIX
  - compaction: abort / minor / major / split procedures (§4.2)
  - version:    immutable refcounted Versions + pinned Snapshots (MVCC)
  - cursor:     RemixCursor — §3.2 seek/peek/next/skip over a snapshot
  - ops:        typed operation model (Op / Batch / OpResult, API v2)
  - executor:   planner–executor behind submit(): admission, deadlines,
                cross-shard fan-out, async futures
  - store:      the RemixDB public API
  - sstable:    baseline SSTable metadata (block index + bloom filters)
  - baseline:   LevelDB-like leveled / tiered comparison stores
"""
from repro.db.cursor import RemixCursor  # noqa: F401
from repro.db.executor import Executor  # noqa: F401
from repro.db.ops import (  # noqa: F401
    Batch,
    BatchResult,
    Op,
    OpKind,
    OpResult,
    OpStatus,
)
from repro.db.store import RemixDB, RemixDBConfig  # noqa: F401
from repro.db.version import Snapshot, Version, VersionSet  # noqa: F401

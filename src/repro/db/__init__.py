"""RemixDB (paper §4): a REMIX-indexed, tiered-compaction, partitioned store.

  - memtable:   sorted write buffer with 8-bit update counters (§4.2 TRIAD)
  - wal:        4 KB-block write-ahead log with virtual logs + GC (§4.3)
  - partition:  key-range partition = table files + one REMIX
  - compaction: abort / minor / major / split procedures (§4.2)
  - store:      the RemixDB public API
  - sstable:    baseline SSTable metadata (block index + bloom filters)
  - baseline:   LevelDB-like leveled / tiered comparison stores
"""
from repro.db.store import RemixDB, RemixDBConfig  # noqa: F401

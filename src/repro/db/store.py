"""RemixDB: the public key-value store API (paper §4).

Write path: put/delete → WAL append + MemTable (update counters). When the
MemTable exceeds its budget, ``flush()`` freezes it, routes the new data to
partitions, plans + executes compactions (abort/minor/major/split), carries
hot keys back (TRIAD-style), and garbage-collects the WAL's virtual log.

Read path: MemTable overlay first, then the owning partition's REMIX
(batched JAX seek/get/scan — no bloom filters, §4).
"""
from __future__ import annotations

import bisect
import dataclasses
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.core import query as Q
from repro.db.compaction import (
    CompactionConfig,
    Plan,
    apply_abort_budget,
    execute,
    plan_partition,
)
from repro.db.memtable import MemTable
from repro.db.partition import Partition, Table
from repro.db.sharded import route_host
from repro.db.wal import WAL


@dataclasses.dataclass
class RemixDBConfig:
    vw: int = 2  # value words (uint32)
    d: int = 32  # REMIX group size
    memtable_entries: int = 1 << 18
    hot_threshold: int = 8  # update count above which a key stays buffered
    compaction: CompactionConfig = dataclasses.field(
        default_factory=CompactionConfig
    )
    wal_dir: str | None = None
    use_kernels: bool = False  # route queries through the Pallas kernel path
    # in-group search mode: "auto" picks binary probes on CPU (gathers are
    # scalar-expensive) and the vectorized all-slot compare on TPU (§Perf)
    ingroup: str = "auto"
    # persistence root: when set, flushes write SSTables + REMIX files there
    # and commit a manifest; RemixDB.open(dir) recovers the store from it
    data_dir: str | None = None
    ckb: bool = True  # append Compressed Keys Blocks to new table files
    # block cache budget for cold reads (shared across all partitions of
    # the store; pass a BlockCache via ``block_cache`` to share it across
    # stores, e.g. from serve.KVServeEngine)
    cache_bytes: int = 64 << 20
    block_cache: object | None = dataclasses.field(default=None, repr=False)
    # serve recovered partitions via block-granular cold reads until
    # promotion, instead of loading whole tables on first query
    cold_reads: bool = True
    # build the device RunSet once cold reads fetched this fraction of a
    # partition's data region
    promote_fraction: float = 0.5
    # cold-scan pipelining (paper Fig 10): while one selector group's
    # rows are emitted, issue the next `prefetch_depth` groups'
    # value/tomb blocks into the cache; 0 = eager (fetch on demand).
    # Never reads a block the eager path would not (the selector stream
    # names exactly which rows each group touches).
    prefetch_depth: int = 1
    # block-read mode for lazy table handles: "copy" reads each verified
    # granule into heap bytes; "mmap" maps the file once and serves
    # zero-copy memoryview slices after a single checksum pass
    cache_mode: str = "copy"
    # WAL durability: "block" (default) group-commits — fsync whenever a
    # full 4 KB block is written; "always" fsyncs every put; "none" only
    # fsyncs on explicit sync()/close()
    sync_policy: str = "block"



def _pow2pad(n: int) -> int:
    """Next power-of-two bucket (bounds jit recompiles per batch size)."""
    b = 8
    while b < n:
        b <<= 1
    return b


class RemixDB:
    def __init__(self, config: RemixDBConfig | None = None):
        self.cfg = config or RemixDBConfig()
        # resolve the in-group search mode once; query paths only ever see
        # a valid "binary"/"vector" (a stray "auto" would raise in seek)
        mode = self.cfg.ingroup
        if mode == "auto":
            mode = "binary" if jax.default_backend() == "cpu" else "vector"
        if mode not in ("binary", "vector"):
            raise ValueError(
                f"ingroup must be 'auto', 'binary' or 'vector', got {mode!r}"
            )
        self._ingroup = mode
        if self.cfg.cache_mode not in ("copy", "mmap"):
            raise ValueError(
                f"cache_mode must be 'copy' or 'mmap', "
                f"got {self.cfg.cache_mode!r}"
            )
        if self.cfg.prefetch_depth < 0:
            raise ValueError("prefetch_depth must be >= 0")
        self.mem = MemTable(vw=self.cfg.vw)
        self.storage = None
        self.block_cache = None
        state = None
        if self.cfg.data_dir is not None:
            from repro.io.blockcache import BlockCache
            from repro.io.manifest import Storage

            self.storage = Storage(self.cfg.data_dir, with_ckb=self.cfg.ckb)
            # explicit None check: an empty BlockCache is falsy (len == 0)
            self.block_cache = (
                self.cfg.block_cache
                if self.cfg.block_cache is not None
                else BlockCache(self.cfg.cache_bytes)
            )
            state = self.storage.load_state()
            wal_path = self.storage.wal_path()
        else:
            wal_dir = self.cfg.wal_dir or tempfile.mkdtemp(prefix="remixdb-")
            os.makedirs(wal_dir, exist_ok=True)
            wal_path = os.path.join(wal_dir, "wal.log")
        self.wal = WAL(wal_path, vw=self.cfg.vw,
                       sync_policy=self.cfg.sync_policy)
        self.partitions: list[Partition] = [Partition(lo=0, d=self.cfg.d)]
        self.seq = 1
        # physical-read bytes of table handles retired by compaction, so
        # disk_bytes_read() is monotonic across table replacement
        self._retired_disk_bytes = 0
        # write-amplification accounting (fig 16)
        self.user_bytes = 0
        self.table_bytes_written = 0
        self.compaction_log: list[dict] = []
        if state is not None:
            self._recover(state)
        elif self.storage is not None:
            # fresh directory (or crashed before the first commit): any
            # table/REMIX files present are orphans of an uncommitted
            # flush, but WAL blocks written before the crash are real
            # acknowledged data — adopt and replay them (empty checkpoint,
            # so every written block shows as an epoch flip)
            self.storage.gc_orphans(set())
            if self.wal.recover_tail():
                self._replay_wal()

    @classmethod
    def open(cls, data_dir: str, config: RemixDBConfig | None = None
             ) -> "RemixDB":
        """Open (or create) a persistent RemixDB rooted at ``data_dir``:
        recovers partitions from the committed manifest and replays the
        WAL tail on top (§4.3)."""
        cfg = config or RemixDBConfig()
        cfg = dataclasses.replace(cfg, data_dir=data_dir)
        return cls(cfg)

    def _recover(self, state: dict) -> None:
        """Rebuild partitions/WAL/MemTable from a committed manifest."""
        from repro.io.remix_io import load_remix

        if int(state.get("vw", self.cfg.vw)) != self.cfg.vw:
            raise ValueError(
                f"data dir has vw={state['vw']}, config has vw={self.cfg.vw}"
            )
        # adopt the persisted group size: the on-disk REMIXes were built
        # with it and the cold path serves them directly — keeping a
        # mismatched cfg.d would make cold and promoted query windows
        # cover different slot counts (vw, by contrast, changes the value
        # API shape, so a mismatch there is an error)
        d_disk = int(state.get("d", self.cfg.d))
        if d_disk != self.cfg.d:
            self.cfg = dataclasses.replace(self.cfg, d=d_disk)
        live: set[str] = set()
        parts: list[Partition] = []
        for pe in state["partitions"]:
            tables = []
            for nm in pe["tables"]:
                t = Table.from_file(
                    self.storage.table_path(nm),
                    cache_mode=self.cfg.cache_mode,
                )
                t.attach_cache(self.block_cache)
                tables.append(t)
            live.update(pe["tables"])
            p = Partition(lo=int(pe["lo"]), tables=tables, d=self.cfg.d)
            if pe.get("remix"):
                live.add(pe["remix"])
                p.remix_name = pe["remix"]
                p.preload_index(
                    load_remix(self.storage.remix_path(pe["remix"]))
                )
            parts.append(p)
        if parts:
            self.partitions = sorted(parts, key=lambda p: p.lo)
        self.storage.gc_orphans(live)
        self.seq = int(state.get("seq", 1))
        self.wal.restore_state(state["wal"])
        self.wal.recover_tail()
        self._replay_wal()

    def _replay_wal(self) -> None:
        """Rebuild the MemTable from the WAL's live log; advance seq past
        every replayed record."""
        self.mem = self.recover_memtable()
        for e in self.mem.data.values():
            self.seq = max(self.seq, e.seq + 1)

    def _commit(self) -> None:
        """Durably publish the current version (atomic manifest commit)."""
        state = dict(
            seq=int(self.seq),
            vw=self.cfg.vw,
            d=self.cfg.d,
            partitions=[
                dict(
                    lo=p.lo,
                    tables=[os.path.basename(t.path) for t in p.tables],
                    remix=p.remix_name,
                )
                for p in self.partitions
            ],
            wal=self.wal.save_state(),
        )
        self.storage.commit(state)
        # files superseded by this version (old REMIXes, compacted-away
        # tables) are unreferenced now — reclaim them immediately instead
        # of leaking until the next open()
        live = {n for pe in state["partitions"] for n in pe["tables"]}
        live |= {pe["remix"] for pe in state["partitions"] if pe["remix"]}
        self.storage.gc_orphans(live)

    def close(self) -> None:
        """Flush WAL buffers and, in persistent mode, commit a manifest so
        reopening needs no tail scan. The MemTable stays in the WAL."""
        self.wal.sync()
        if self.storage is not None:
            self._commit()
            self.wal.release_quarantine()

    # ---------------- write path ----------------
    def put(self, key: int, val) -> None:
        val = np.asarray(val, np.uint32).reshape(self.cfg.vw)
        self.wal.append(int(key), self.seq, False, val)
        self.mem.put(int(key), val, self.seq)
        self.user_bytes += 8 + 4 * self.cfg.vw
        self.seq += 1
        self._maybe_flush()

    def delete(self, key: int) -> None:
        val = np.zeros(self.cfg.vw, np.uint32)
        self.wal.append(int(key), self.seq, True, val)
        self.mem.put(int(key), val, self.seq, tomb=True)
        self.user_bytes += 8 + 4 * self.cfg.vw
        self.seq += 1
        self._maybe_flush()

    def put_batch(self, keys, vals) -> None:
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.cfg.vw)
        seqs = np.arange(self.seq, self.seq + len(keys), dtype=np.uint64)
        self.wal.append_batch(keys, seqs, np.zeros(len(keys), bool), vals)
        self.seq = self.mem.put_batch(keys, vals, self.seq)
        self.user_bytes += len(keys) * (8 + 4 * self.cfg.vw)
        self._maybe_flush()

    def _maybe_flush(self):
        if len(self.mem) >= self.cfg.memtable_entries:
            self.flush()

    # ---------------- flush / compaction ----------------
    def _route(self, key: int) -> int:
        los = [p.lo for p in self.partitions]
        return max(0, bisect.bisect_right(los, key) - 1)

    def flush(self) -> dict:
        """Freeze the MemTable and run one compaction round (§4.2)."""
        keys, vals, seq, tomb, counts = self.mem.to_arrays()
        if len(keys) == 0:
            return dict(kinds={})
        hot = counts > self.cfg.hot_threshold
        frozen = self.mem
        self.mem = MemTable(vw=self.cfg.vw)
        # hot keys skip compaction; carried over with halved counters
        for k in np.asarray(keys[hot], np.uint64).tolist():
            self.mem.carry_over(int(k), frozen.data[int(k)])
        keys, vals, seq, tomb = (
            keys[~hot], vals[~hot], seq[~hot], tomb[~hot],
        )
        # route new data to partitions
        pidx = route_host([p.lo for p in self.partitions], keys)
        plans: list[Plan] = []
        for i, p in enumerate(self.partitions):
            m = pidx == i
            t = Table(keys=keys[m], vals=vals[m], seq=seq[m], tomb=tomb[m])
            plans.append(plan_partition(p, t, self.cfg.compaction))
        apply_abort_budget(plans, self.cfg.compaction)
        kinds: dict[str, int] = {}
        new_parts: list[Partition] = []
        for p, pl in zip(self.partitions, plans):
            kinds[pl.kind] = kinds.get(pl.kind, 0) + 1
            res = execute(pl, self.cfg.compaction, storage=self.storage)
            self.table_bytes_written += res.bytes_written
            if res.carried is not None:  # aborted: back into the MemTable
                for j in range(res.carried.n):
                    e = frozen.data[int(res.carried.keys[j])]
                    self.mem.carry_over(int(res.carried.keys[j]), e)
            if res.new_partitions is not None:
                new_parts.extend(res.new_partitions)
            else:
                new_parts.append(p)
        new_parts.sort(key=lambda p: p.lo)
        live_before = sum(p.cold_disk_bytes() for p in self.partitions)
        self.partitions = new_parts
        self._retired_disk_bytes += max(
            0, live_before - sum(p.cold_disk_bytes() for p in new_parts)
        )
        # WAL GC: only carried/hot keys remain live in the log (§4.3).
        # In persistent mode freed blocks stay quarantined until the new
        # mapping table is committed with the manifest: a crash in between
        # must still be able to replay the previous checkpoint's blocks.
        self.wal.gc(set(self.mem.data.keys()),
                    defer_free=self.storage is not None)
        if self.storage is not None:
            self._commit()
            self.wal.release_quarantine()
        stats = dict(kinds=kinds)
        self.compaction_log.append(stats)
        return stats

    # ---------------- read path ----------------
    def _query_mod(self):
        if self.cfg.use_kernels:
            from repro.kernels import ops

            return ops
        return Q

    def _qkw(self) -> dict:
        """Per-backend query kwargs (§Perf: binary in-group probes win on
        CPU, the vectorized all-slot compare wins on TPU). ``auto`` was
        resolved once at construction; only valid modes reach seek."""
        if self.cfg.use_kernels:
            return {}
        return dict(ingroup=self._ingroup)

    def _cold_ok(self, p: Partition) -> bool:
        """Serve this partition via block-granular cold reads?

        True only while the recovered on-disk REMIX still matches the
        table list and cold reads haven't yet pulled enough blocks to
        justify building the device RunSet (promotion)."""
        return (
            self.cfg.cold_reads
            and self.block_cache is not None
            and p.cold_ready()
            and not p.should_promote(self.cfg.promote_fraction)
        )

    def get(self, key: int):
        e = self.mem.get(int(key))
        if e is not None:
            return None if e.tomb else e.val
        p = self.partitions[self._route(int(key))]
        if self._cold_ok(p):
            found, val = p.cold_get(int(key))
            return val if found else None
        remix, runset = p.index()
        qk = jnp.asarray(CK.pack_u64(np.array([key], np.uint64)))
        found, val = self._query_mod().get(remix, runset, qk, **self._qkw())
        return np.asarray(val)[0] if bool(np.asarray(found)[0]) else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookups. Returns (found (Q,), vals (Q,VW))."""
        keys = np.asarray(keys, np.uint64)
        found = np.zeros(len(keys), bool)
        vals = np.zeros((len(keys), self.cfg.vw), np.uint32)
        rest = []
        for i, k in enumerate(keys.tolist()):
            e = self.mem.get(k)
            if e is not None:
                found[i] = not e.tomb
                vals[i] = e.val
            else:
                rest.append(i)
        if rest:
            rest = np.array(rest)
            pidx = route_host([p.lo for p in self.partitions], keys[rest])
            for pi in np.unique(pidx):
                sel = rest[pidx == pi]
                p = self.partitions[pi]
                if self._cold_ok(p):
                    f, v = p.cold_get_batch(keys[sel])
                    found[sel] = f
                    vals[sel[f]] = v[f]
                    continue
                remix, runset = p.index()
                kq = keys[sel]
                pad = _pow2pad(len(kq))
                kq = np.pad(kq, (0, pad - len(kq)))
                qk = jnp.asarray(CK.pack_u64(kq))
                f, v = self._query_mod().get(remix, runset, qk, **self._qkw())
                found[sel] = np.asarray(f)[: len(sel)]
                vals[sel] = np.asarray(v)[: len(sel)]
        return found, vals

    def scan(self, start_key: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Range scan: seek + next×n across partitions + MemTable overlay."""
        out_k: list[int] = []
        out_v: list[np.ndarray] = []
        pi = self._route(int(start_key))
        lo = int(start_key)
        base_width = max(8, n + n // 2)
        width = base_width
        while len(out_k) < n and pi < len(self.partitions):
            p = self.partitions[pi]
            hi = (
                self.partitions[pi + 1].lo
                if pi + 1 < len(self.partitions)
                else 1 << 64
            )
            if self._cold_ok(p):
                kk, vv, more = p.cold_scan(
                    lo, width, prefetch_depth=self.cfg.prefetch_depth
                )
            else:
                remix, runset = p.index()
                qk = jnp.asarray(CK.pack_u64(np.array([lo], np.uint64)))
                keys, vals, valid, pos = self._query_mod().scan(
                    remix, runset, qk, width=width, **self._qkw()
                )
                kk = CK.unpack_u64(np.asarray(keys)[0][np.asarray(valid)[0]])
                vv = np.asarray(vals)[0][np.asarray(valid)[0]]
                more = int(np.asarray(pos)[0]) + width < remix.n_slots
            if len(kk) == 0 and more:
                # every slot in the window was a tombstone/old version but
                # the view has more: widen and retry — advancing to the
                # next partition here would silently drop its live tail.
                # (On the device path each new width jit-compiles once;
                # widths are powers of two of base_width, so the compile
                # set stays O(log max-tombstone-run) process-wide.)
                width *= 2
                continue
            got_in_range = 0
            for j in range(len(kk)):
                if int(kk[j]) >= hi:
                    break
                out_k.append(int(kk[j]))
                out_v.append(vv[j])
                got_in_range += 1
            if got_in_range == 0 or (len(kk) > got_in_range):
                # nothing (more) in this partition's range: advance partition
                pi += 1
                lo = self.partitions[pi].lo if pi < len(self.partitions) else 0
                width = base_width  # widening was partition-local
            else:
                lo = int(kk[got_in_range - 1]) + 1
                width = base_width  # widening was window-local too
        # overlay MemTable entries in range
        merged: dict[int, np.ndarray | None] = {}
        for k, v in zip(out_k, out_v):
            merged[k] = v
        limit = max(out_k) if len(out_k) >= n else (1 << 64)
        for k, e in self.mem.data.items():
            if int(start_key) <= k <= limit:
                merged[k] = None if e.tomb else e.val
        items = sorted(
            ((k, v) for k, v in merged.items() if v is not None),
            key=lambda kv: kv[0],
        )[:n]
        if not items:
            return np.zeros(0, np.uint64), np.zeros((0, self.cfg.vw), np.uint32)
        return (
            np.array([k for k, _ in items], np.uint64),
            np.stack([v for _, v in items]),
        )

    def scan_batch(self, starts, n: int):
        """Batched range scans (one jitted call per touched partition).

        Returns (keys (Q, n) uint64, valid (Q, n)). Queries whose range
        crosses a partition boundary fall back to the sequential path.
        """
        starts = np.asarray(starts, np.uint64)
        q = len(starts)
        out_k = np.zeros((q, n), np.uint64)
        out_m = np.zeros((q, n), bool)
        pidx = route_host([p.lo for p in self.partitions], starts)
        width = n + max(8, n // 2)
        for pi in np.unique(pidx):
            sel = np.flatnonzero(pidx == pi)
            p = self.partitions[pi]
            hi = (
                self.partitions[pi + 1].lo
                if pi + 1 < len(self.partitions)
                else 1 << 64
            )
            def emit_row(qi, kk):
                """Clip one query's window to the partition — shared by
                the cold and device branches so promotion never changes
                results. Any under-full row falls back to the sequential
                scan: the fixed window alone can't distinguish "partition
                tail reached" from "window swallowed by a tombstone run
                or a partition boundary", and scan() handles both."""
                kk = kk[kk < hi][:n]
                out_k[qi, : len(kk)] = kk
                out_m[qi, : len(kk)] = True
                if len(kk) < n:
                    kk2, _ = self.scan(int(starts[qi]), n)
                    out_k[qi, : len(kk2)] = kk2[:n]
                    out_m[qi] = False
                    out_m[qi, : len(kk2)] = True

            if self._cold_ok(p):
                for qi, (kk, _, _) in zip(
                    sel, p.cold_scan_batch(starts[sel], width)
                ):
                    emit_row(qi, kk)
                continue
            remix, runset = p.index()
            sq = starts[sel]
            pad = _pow2pad(len(sq))
            sq = np.pad(sq, (0, pad - len(sq)))
            qk = jnp.asarray(CK.pack_u64(sq))
            keys, vals, valid, _ = self._query_mod().scan(
                remix, runset, qk, width=width, **self._qkw()
            )
            keys = CK.unpack_u64(np.asarray(keys))[: len(sel)]
            valid = np.asarray(valid)[: len(sel)]
            for row, qi in enumerate(sel):
                emit_row(qi, keys[row][valid[row]])
        # memtable overlay (host merge) only if buffered entries exist
        if len(self.mem):
            for qi in range(q):
                kk, _ = self.scan(int(starts[qi]), n)
                out_k[qi, : len(kk)] = kk[:n]
                out_m[qi] = False
                out_m[qi, : len(kk)] = True
        return out_k, out_m

    # ---------------- stats / recovery ----------------
    def write_amplification(self) -> float:
        total = self.table_bytes_written + self.wal.bytes_written
        return total / max(1, self.user_bytes)

    def disk_bytes_read(self) -> int:
        """Physical table-file bytes read so far (cache hits excluded).

        Monotonic: counts from handles retired by compaction are folded
        into ``_retired_disk_bytes`` when their partition list is swapped.
        """
        return self._retired_disk_bytes + sum(
            p.cold_disk_bytes() for p in self.partitions
        )

    def stats(self) -> dict:
        """Store counters. Introspection-safe: never force-loads a lazy
        table handle (entries come from cached file headers) and never
        builds a partition index."""
        out = dict(
            partitions=len(self.partitions),
            tables=sum(len(p.tables) for p in self.partitions),
            entries=sum(p.n_entries for p in self.partitions),
            resident_tables=sum(
                t.resident for p in self.partitions for t in p.tables
            ),
            memtable=len(self.mem),
            wa=self.write_amplification(),
            wal_blocks=self.wal.used_blocks(),
            # all physical table-file reads, not only cold-path ones
            # (whole-table loads and rebuilds count too)
            disk_bytes_read=self.disk_bytes_read(),
            cold=dict(
                gets=sum(p.cold_gets for p in self.partitions),
                scans=sum(p.cold_scans for p in self.partitions),
            ),
        )
        if self.block_cache is not None:
            out["cache"] = self.block_cache.stats()
        return out

    def recover_memtable(self) -> MemTable:
        """Rebuild the MemTable from the WAL's live virtual log (§4.3)."""
        mem = MemTable(vw=self.cfg.vw)
        for k, s, t, v in sorted(self.wal.replay(), key=lambda r: r[1]):
            mem.put(k, v, s, t)
        return mem

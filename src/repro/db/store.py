"""RemixDB: the public key-value store API (paper §4).

Write path: put/delete → WAL append + MemTable (update counters). When the
MemTable exceeds its budget, ``flush()`` freezes it, routes the new data to
partitions, plans + executes compactions (abort/minor/major/split), carries
hot keys back (TRIAD-style), and garbage-collects the WAL's virtual log.

Read path: MemTable overlay first, then the owning partition's REMIX
(batched JAX seek/get/scan — no bloom filters, §4).

Versioned core: the store below the MemTable is a chain of immutable,
refcounted :class:`~repro.db.version.Version` objects. A flush builds new
partitions *off to the side* (copy-on-write — see
``compaction.execute``), commits the manifest (the version edge), and
publishes the new Version with a pointer swap; readers holding a
:meth:`snapshot` pin their Version until dropped, so a compaction never
invalidates an in-flight read and retired tables/files are reclaimed
only when their last Version unpins. All scans run through
:class:`~repro.db.cursor.RemixCursor`, the paper's §3.2 cursor over the
merged (overlay + cold + promoted) view — ``scan``/``scan_batch`` are
thin wrappers, and streaming consumers can hold one cursor instead of
re-seeking per chunk.

Operation layer (API v2): the typed entry point is
:meth:`RemixDB.submit` — build a :class:`repro.db.ops.Batch` of
Get/MultiGet/Scan/Put/Delete ops (with per-op deadlines and priorities)
and get a future back; the :class:`repro.db.executor.Executor` plans the
batch (stage split, shard routing, one pinned snapshot per shard) and
compiles it onto this store's physical primitives (``_get_at`` /
``_get_batch_at`` / ``_scan_group_at`` / ``_apply_writes``). Every
legacy method below (``get``/``get_batch``/``scan``/``scan_batch``/
``put``/``put_batch``/``delete``) is a thin wrapper that builds a
one-kind batch and blocks on the future, so both surfaces share one
code path and stay bit-for-bit identical.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import os
import tempfile
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import keys as CK
from repro.core import query as Q
from repro.db import clock
from repro.db.compaction import (
    CompactionConfig,
    Plan,
    apply_abort_budget,
    execute,
    plan_partition,
)
from repro.db.cursor import RemixCursor
from repro.db.memtable import MemTable, entry_dead
from repro.db.ops import Batch, Op, OpInterrupted
from repro.db.partition import ExcisedSpan, Partition, Table
from repro.db.sharded import partition_spans, route_host, route_one
from repro.db.version import Snapshot, VersionSet
from repro.db.wal import FLAG_RANGE, FLAG_TOMB, WAL, unpack_range_hi
from repro.io.faults import (CorruptionError, IOContext,
                             UnavailableSpanError)
from repro.obs.events import EventLog, NULL_EVENTS
from repro.obs.metrics import MetricsRegistry, merge_snapshots


@dataclasses.dataclass
class RemixDBConfig:
    vw: int = 2  # value words (uint32)
    d: int = 32  # REMIX group size
    memtable_entries: int = 1 << 18
    hot_threshold: int = 8  # update count above which a key stays buffered
    compaction: CompactionConfig = dataclasses.field(
        default_factory=CompactionConfig
    )
    wal_dir: str | None = None
    use_kernels: bool = False  # route queries through the Pallas kernel path
    # in-group search mode: "auto" picks binary probes on CPU (gathers are
    # scalar-expensive) and the vectorized all-slot compare on TPU (§Perf)
    ingroup: str = "auto"
    # persistence root: when set, flushes write SSTables + REMIX files there
    # and commit a manifest; RemixDB.open(dir) recovers the store from it
    data_dir: str | None = None
    ckb: bool = True  # append Compressed Keys Blocks to new table files
    # block cache budget for cold reads (shared across all partitions of
    # the store; pass a BlockCache via ``block_cache`` to share it across
    # stores, e.g. from serve.KVServeEngine)
    cache_bytes: int = 64 << 20
    block_cache: object | None = dataclasses.field(default=None, repr=False)
    # serve recovered partitions via block-granular cold reads until
    # promotion, instead of loading whole tables on first query
    cold_reads: bool = True
    # promote a partition to the device RunSet once the observed cold
    # workload — physical bytes pulled OR logical row bytes served (cache
    # hits included) — reaches this fraction of its data region; the
    # decision inputs are exposed in stats()["cache"]["promotion"]
    promote_fraction: float = 0.5
    # ---- device-resident query execution (docs/ARCHITECTURE.md) ----
    # promoted-partition read routing: "auto" answers promoted reads
    # from persistent device views when a real accelerator backend is
    # attached; "on" forces the device path everywhere (on CPU the
    # kernels run in Pallas interpret mode — the CI parity
    # configuration); "off" keeps the legacy jitted host-array path
    device_path: str = "auto"
    # HBM byte budget for resident device views (LRU-evicted under
    # upload pressure; views whose partition left every live Version
    # are dropped at release). A partition that fits neither residency
    # tier falls back to the legacy path (device_fallback_total)
    device_budget_bytes: int = 256 << 20
    # batch-slice width of the host/device overlapped value pipeline
    # (index-only tier: the device resolves row windows for slice i+1
    # while the host gathers value granules for slice i)
    device_slice: int = 64
    # cold-scan pipelining (paper Fig 10): while one selector group's
    # rows are emitted, issue the next `prefetch_depth` groups'
    # value/tomb blocks into the cache; 0 = eager (fetch on demand).
    # Never reads a block the eager path would not (the selector stream
    # names exactly which rows each group touches).
    prefetch_depth: int = 1
    # block-read mode for lazy table handles: "copy" reads each verified
    # granule into heap bytes; "mmap" maps the file once and serves
    # zero-copy memoryview slices after a single checksum pass
    cache_mode: str = "copy"
    # WAL durability: "block" (default) group-commits — fsync whenever a
    # full 4 KB block is written; "always" fsyncs every put; "none" only
    # fsyncs on explicit sync()/close()
    sync_policy: str = "block"
    # per-round compaction log entries retained (ring of the last N
    # rounds); aggregate counters live in stats()["compaction"], so
    # long-running stores don't grow memory with flush count
    compaction_log_rounds: int = 64
    # run compaction + manifest commit on a background thread: flush()
    # returns right after the MemTable freeze and the round publishes
    # off-thread under the writer lock (wait_for_compaction() joins it).
    # Readers are unaffected either way (Version pointer swap).
    background_compaction: bool = False
    # resolve batched cold seeks from the prefix-compressed CKB entry
    # stream (vectorized decoder) instead of fixed-width keys-section
    # reads; False falls back to the keys-section path
    ckb_decode: bool = True
    # op-layer admission control: bytes of submitted-but-unfinished
    # batches before submit() blocks (backpressure)
    max_inflight_bytes: int = 256 << 20
    # worker threads serving async submit(); sync submissions (and the
    # legacy wrappers) execute inline and never touch them
    submit_workers: int = 2
    # ---- observability (docs/OBSERVABILITY.md) ----
    # master toggle: False hands every layer no-op instruments and a
    # null event log, removing even the counter lock acquires (the
    # registry-backed stats()/wa fields then read as zero)
    metrics: bool = True
    # fraction of op batches traced without an explicit Batch(trace=True)
    # (deterministic 1-in-round(1/rate) sampling; 0 disables)
    trace_sample_rate: float = 0.0
    # ring capacity of the structured lifecycle event log
    event_log_capacity: int = 256
    # optional JSONL sink mirroring every event append-only to disk
    event_log_path: str | None = None
    # share a MetricsRegistry across components (e.g. per-shard labelled
    # registries from a serving tier); None creates a private one
    registry: object | None = dataclasses.field(default=None, repr=False)
    # ---- durability / fault injection (docs/ARCHITECTURE.md) ----
    # deterministic fault-injection plan (repro.io.FaultPlan) threaded
    # under every reader/writer of this store's files; None = no faults
    fault_plan: object | None = dataclasses.field(default=None, repr=False)
    # bounded retry budget for transient read/fsync faults (TransientIO-
    # Error): per site, with exponential backoff between attempts
    io_retries: int = 2
    io_retry_backoff_s: float = 0.0
    # background integrity scrub cadence (seconds); 0 disables the
    # thread — db.scrub(full=True) stays available synchronously
    scrub_interval_s: float = 0.0
    # byte-budget rate limit for background scrub passes (bytes/sec of
    # at-rest reads); 0 = unthrottled. Full/sync scrubs ignore it.
    scrub_bytes_per_sec: int = 0
    # age after which quarantined files (GC'd orphans + unrecoverable
    # tables) are purged for good; checked at each scrub pass and close
    quarantine_purge_age_s: float = 7 * 24 * 3600.0



def _pow2pad(n: int) -> int:
    """Next power-of-two bucket (bounds jit recompiles per batch size)."""
    b = 8
    while b < n:
        b <<= 1
    return b


def partition_entry(p: Partition, rename=None) -> dict:
    """The manifest entry for one partition (table/REMIX file basenames
    + excised spans). ``rename`` maps basenames when the files were
    shipped under fresh names (shard merge into a dir with collisions).
    """
    nm = (lambda n: n) if rename is None else (lambda n: rename.get(n, n))
    return dict(
        lo=p.lo,
        tables=[nm(os.path.basename(t.path)) for t in p.tables],
        remix=None if p.remix_name is None else nm(p.remix_name),
        excised=[
            dict(
                lo=s.lo, hi=s.hi, seq=s.seq,
                tables=[
                    nm(os.path.basename(t.path))
                    for t in s.tables
                    if t.path is not None
                ],
            )
            for s in p.excised
        ],
    )


def partition_entry_renamed(pe: dict, rename=None) -> dict:
    """A manifest partition entry with file basenames mapped through
    ``rename`` (no-op when None/empty)."""
    if not rename:
        return pe
    out = dict(pe)
    out["tables"] = [rename.get(n, n) for n in pe["tables"]]
    if pe.get("remix"):
        out["remix"] = rename.get(pe["remix"], pe["remix"])
    out["excised"] = [
        {**se, "tables": [rename.get(n, n) for n in se.get("tables", [])]}
        for se in pe.get("excised", [])
    ]
    return out


class RemixDB:
    def __init__(self, config: RemixDBConfig | None = None):
        self.cfg = config or RemixDBConfig()
        # resolve the in-group search mode once; query paths only ever see
        # a valid "binary"/"vector" (a stray "auto" would raise in seek)
        mode = self.cfg.ingroup
        if mode == "auto":
            mode = "binary" if jax.default_backend() == "cpu" else "vector"
        if mode not in ("binary", "vector"):
            raise ValueError(
                f"ingroup must be 'auto', 'binary' or 'vector', got {mode!r}"
            )
        self._ingroup = mode
        if self.cfg.cache_mode not in ("copy", "mmap"):
            raise ValueError(
                f"cache_mode must be 'copy' or 'mmap', "
                f"got {self.cfg.cache_mode!r}"
            )
        if self.cfg.prefetch_depth < 0:
            raise ValueError("prefetch_depth must be >= 0")
        if self.cfg.device_path not in ("auto", "on", "off"):
            raise ValueError(
                f"device_path must be 'auto', 'on' or 'off', "
                f"got {self.cfg.device_path!r}"
            )
        if self.cfg.device_slice < 1:
            raise ValueError("device_slice must be >= 1")
        # observability: one registry + one lifecycle event log shared by
        # every layer this store owns (cache, WAL, versions, executor);
        # metrics=False hands out no-op instruments and a null event log
        self.registry = (
            self.cfg.registry
            if self.cfg.registry is not None
            else MetricsRegistry(enabled=self.cfg.metrics)
        )
        self.events = (
            EventLog(self.cfg.event_log_capacity,
                     jsonl_path=self.cfg.event_log_path)
            if self.cfg.metrics
            else NULL_EVENTS
        )
        # device-resident query views for promoted partitions: persistent
        # HBM buffers + the fused batched execution driver. "auto" only
        # engages on a real accelerator backend; "on" forces the path
        # (Pallas interpret mode on CPU — how CI parity-tests it)
        self.device_views = None
        if self.cfg.device_path == "on" or (
            self.cfg.device_path == "auto"
            and jax.default_backend() not in ("cpu",)
        ):
            from repro.kernels.device_view import DeviceViewManager

            self.device_views = DeviceViewManager(
                self.cfg.device_budget_bytes,
                slice_width=self.cfg.device_slice,
                registry=self.registry,
                events=self.events,
            )
        self.mem = MemTable(vw=self.cfg.vw)
        # durability plumbing: one IOContext (fault plan + bounded retry)
        # threaded under every file this store reads or writes
        self._c_io_retry = self.registry.counter("io_retry")
        self._c_io_giveup = self.registry.counter("io_giveup")
        self._c_corruption = self.registry.counter("corruption_detected")
        self._c_scrub_passes = self.registry.counter("scrub_passes")
        self._c_scrub_bytes = self.registry.counter("scrub_bytes_read")
        self._c_repair_remix = self.registry.counter("repair_remix_rebuilt")
        self._c_quarantined = self.registry.counter(
            "repair_table_quarantined"
        )
        self._c_quarantine_purged = self.registry.counter(
            "quarantine_purged"
        )
        self.io = IOContext(
            plan=self.cfg.fault_plan,
            retries=self.cfg.io_retries,
            backoff_s=self.cfg.io_retry_backoff_s,
            on_retry=self._c_io_retry.inc,
            on_giveup=self._c_io_giveup.inc,
        )
        # key spans whose backing table was quarantined as unrecoverable:
        # reads over them raise UnavailableSpanError (graceful
        # degradation) instead of silently missing rows; persisted in the
        # manifest so degradation survives restarts
        self._unavailable: list[dict] = []
        self._last_scrub: dict | None = None
        self.storage = None
        self.block_cache = None
        state = None
        if self.cfg.data_dir is not None:
            from repro.io.blockcache import BlockCache
            from repro.io.manifest import Storage

            self.storage = Storage(self.cfg.data_dir, with_ckb=self.cfg.ckb,
                                   io=self.io)
            # explicit None check: an empty BlockCache is falsy (len == 0)
            self.block_cache = (
                self.cfg.block_cache
                if self.cfg.block_cache is not None
                else BlockCache(self.cfg.cache_bytes,
                                registry=self.registry)
            )
            state = self.storage.load_state()
            wal_path = self.storage.wal_path()
        else:
            wal_dir = self.cfg.wal_dir or tempfile.mkdtemp(prefix="remixdb-")
            os.makedirs(wal_dir, exist_ok=True)
            wal_path = os.path.join(wal_dir, "wal.log")
        self.wal = WAL(wal_path, vw=self.cfg.vw,
                       sync_policy=self.cfg.sync_policy,
                       registry=self.registry, ioctx=self.io)
        self.seq = 1
        # registry-backed accounting; the legacy attribute names
        # (user_bytes, table_bytes_written, compaction_totals, ...) are
        # read-only property views over these counters so stats() and
        # write_amplification() stay bit-compatible
        reg = self.registry
        # physical-read bytes of table handles retired with their last
        # Version, so disk_bytes_read() is monotonic across table
        # replacement
        self._c_retired_bytes = reg.counter("db_retired_disk_bytes")
        # write-amplification accounting (fig 16)
        self._c_user_bytes = reg.counter("db_user_bytes")
        self._c_table_bytes = reg.counter("db_table_bytes_written")
        self._c_comp_rounds = reg.counter("db_compaction_rounds")
        self._c_comp_bytes = reg.counter("db_compaction_bytes_written")
        # tentpole op counters (asserted in tests/test_obs.py)
        self._c_delete_range = reg.counter("delete_range")
        self._c_cas_conflict = reg.counter("cas_conflict")
        self._c_ttl_dropped = reg.counter("ttl_expired_dropped")
        self._c_rtomb_drop = reg.counter("range_tombstone_drop")
        self._comp_kinds: set[str] = set()  # plan kinds seen so far
        self._h_flush = reg.histogram("db_flush_seconds")
        reg.gauge("db_memtable_entries", fn=lambda: len(self.mem))
        reg.gauge("db_partitions", fn=lambda: len(self.partitions))
        reg.gauge(
            "db_tables",
            fn=lambda: sum(len(p.tables) for p in self.partitions),
        )
        reg.gauge("db_disk_bytes_read", fn=self.disk_bytes_read)
        reg.multi_gauge(
            "db_partition_cold_gets",
            fn=lambda: [
                (dict(lo=str(p.lo)), p.cold_gets) for p in self.partitions
            ],
        )
        reg.multi_gauge(
            "db_partition_cold_scans",
            fn=lambda: [
                (dict(lo=str(p.lo)), p.cold_scans) for p in self.partitions
            ],
        )
        reg.gauge("ckb_memo_entries", fn=lambda: self._ckb_memo("entries"))
        reg.gauge("ckb_memo_bytes", fn=lambda: self._ckb_memo("bytes"))
        reg.gauge(
            "ckb_memo_evictions", fn=lambda: self._ckb_memo("evictions")
        )
        # last-N compaction rounds (ring); lifetime aggregates live in
        # the registry counters above (see the compaction_totals view)
        self.compaction_log: collections.deque = collections.deque(
            maxlen=max(1, self.cfg.compaction_log_rounds)
        )
        # one writer at a time; readers never take this lock — they pin
        # a Version and proceed. Reentrant because a publish inside
        # flush() releases the old Version, whose hook may reach
        # _gc_files on the same thread.
        self._flush_lock = threading.RLock()
        # serializes the write path end-to-end (seq allocation + WAL
        # append + MemTable apply) against other writers and against the
        # compaction round's WAL GC / checkpoint — with async submit()
        # several executor workers may write concurrently
        self._write_lock = threading.Lock()
        # serializes flush scheduling (freeze + background hand-off)
        self._flush_gate = threading.Lock()
        # guards the (_bg_thread, _bg_error) handoff: wait_for_compaction
        # is public and may race a writer-triggered flush() installing
        # the next round's thread
        self._bg_lock = threading.Lock()
        self._bg_thread: threading.Thread | None = None
        self._bg_error: BaseException | None = None
        # op-layer executor, created on first submit()/wrapper call
        self._ops_engine = None
        self._engine_lock = threading.Lock()
        self._in_flush = False  # file GC defers to flush-end while set
        # guards the (current Version, overlay source, seq) triple that
        # snapshots capture, against the flush's freeze/publish edges
        self._state_lock = threading.Lock()
        # while a flush is compacting, readers overlay the *frozen*
        # MemTable (the data mid-compaction) instead of the drained live
        # one — a snapshot taken mid-flush must still see pre-flush state
        self._flush_overlay: dict | None = None
        # the frozen MemTable's range tombstones, visible to readers for
        # the same window: they become partition excised spans at publish
        self._flush_ranges: list | None = None
        self.versions = VersionSet(on_release=self._on_version_release,
                                   registry=self.registry)
        self.versions.publish(
            [Partition(lo=0, d=self.cfg.d)], seq_horizon=0
        )
        if state is not None:
            self._recover(state)
        elif self.storage is not None:
            # fresh directory (or crashed before the first commit): any
            # table/REMIX files present are orphans of an uncommitted
            # flush, but WAL blocks written before the crash are real
            # acknowledged data — adopt and replay them (empty checkpoint,
            # so every written block shows as an epoch flip)
            self.storage.gc_orphans(set())
            if self.wal.recover_tail():
                self._replay_wal()
        # optional background scrubber (rate-limited integrity pass)
        self._scrub_stop = threading.Event()
        self._scrub_thread: threading.Thread | None = None
        if self.storage is not None and self.cfg.scrub_interval_s > 0:
            self._scrub_thread = threading.Thread(
                target=self._scrub_loop, name="remixdb-scrub", daemon=True
            )
            self._scrub_thread.start()

    def _scrub_loop(self) -> None:
        while not self._scrub_stop.wait(self.cfg.scrub_interval_s):
            try:
                self.scrub(full=False)
            except Exception:
                # scrubbing must never take the store down; failures are
                # visible through io_giveup / events
                pass

    @classmethod
    def open(cls, data_dir: str, config: RemixDBConfig | None = None
             ) -> "RemixDB":
        """Open (or create) a persistent RemixDB rooted at ``data_dir``:
        recovers partitions from the committed manifest and replays the
        WAL tail on top (§4.3)."""
        cfg = config or RemixDBConfig()
        cfg = dataclasses.replace(cfg, data_dir=data_dir)
        return cls(cfg)

    @property
    def partitions(self):
        """The current Version's partitions (immutable tuple). Mutating
        store state goes through ``flush()``/``VersionSet.publish``."""
        return self.versions.current.partitions

    # ---- registry-backed views of the legacy accounting attributes ----
    @property
    def user_bytes(self) -> int:
        return self._c_user_bytes.value

    @property
    def table_bytes_written(self) -> int:
        return self._c_table_bytes.value

    @property
    def _retired_disk_bytes(self) -> int:
        return self._c_retired_bytes.value

    @property
    def compaction_totals(self) -> dict:
        kinds = {}
        for k in sorted(self._comp_kinds):
            v = self.registry.counter("compaction_plans", kind=k).value
            if v:
                kinds[k] = v
        return dict(
            rounds=self._c_comp_rounds.value,
            kinds=kinds,
            bytes_written=self._c_comp_bytes.value,
        )

    def _ckb_memo(self, field: str) -> int:
        """Aggregate CKB interval-memo accounting over resident readers
        (header-cheap: never materializes a reader)."""
        total = 0
        for p in self.partitions:
            for t in p.tables:
                ck = getattr(t, "_ckb", None)
                if ck is not None:
                    total += ck.memo_stats()[field]
        return total

    def _recover(self, state: dict) -> None:
        """Rebuild partitions/WAL/MemTable from a committed manifest."""
        from repro.io.manifest import live_files

        if int(state.get("vw", self.cfg.vw)) != self.cfg.vw:
            raise ValueError(
                f"data dir has vw={state['vw']}, config has vw={self.cfg.vw}"
            )
        # files a crashed flush wrote but never committed are orphans:
        # collect them before building table handles over the directory
        self.storage.gc_orphans(live_files(state))
        # adopt the persisted group size: the on-disk REMIXes were built
        # with it and the cold path serves them directly — keeping a
        # mismatched cfg.d would make cold and promoted query windows
        # cover different slot counts (vw, by contrast, changes the value
        # API shape, so a mismatch there is an error)
        d_disk = int(state.get("d", self.cfg.d))
        if d_disk != self.cfg.d:
            self.cfg = dataclasses.replace(self.cfg, d=d_disk)
        parts: list[Partition] = [
            self._build_partition(pe) for pe in state["partitions"]
        ]
        # degraded spans (quarantined tables) survive restarts
        self._unavailable = [dict(s) for s in state.get("unavailable", [])]
        if not parts:
            parts = [Partition(lo=0, d=self.cfg.d)]
        self.seq = int(state.get("seq", 1))
        # publishing releases the construction placeholder, whose release
        # hook garbage-collects files the manifest doesn't reference
        self.versions.publish(
            sorted(parts, key=lambda p: p.lo), seq_horizon=self.seq
        )
        self.wal.restore_state(state["wal"])
        self.wal.recover_tail()
        self._replay_wal()
        self.events.emit("recover", partitions=len(parts),
                         memtable=len(self.mem))

    def _build_partition(self, pe: dict) -> Partition:
        """One Partition (table handles + excised spans + preloaded
        REMIX) from its manifest entry — shared by recovery, replica
        catch-up adoption, and shard absorption."""
        from repro.io.remix_io import load_remix

        tables = []
        for nm in pe["tables"]:
            t = Table.from_file(
                self.storage.table_path(nm),
                cache_mode=self.cfg.cache_mode,
                ckb_decode=self.cfg.ckb_decode,
            )
            t.attach_cache(self.block_cache)
            t.attach_io(self.io)
            tables.append(t)
        p = Partition(lo=int(pe["lo"]), tables=tables, d=self.cfg.d)
        by_name = dict(zip(pe["tables"], tables))
        for se in pe.get("excised", []):
            span_tabs = tuple(
                by_name[nm] for nm in se["tables"] if nm in by_name
            )
            if span_tabs:
                p.excised.append(ExcisedSpan(
                    int(se["lo"]), int(se["hi"]), int(se["seq"]),
                    span_tabs,
                ))
        if pe.get("remix"):
            p.remix_name = pe["remix"]
            try:
                p.preload_index(
                    load_remix(self.storage.remix_path(pe["remix"]),
                               io=self.io)
                )
            except CorruptionError as e:
                # a corrupt REMIX never blocks open: queries rebuild
                # the index from the (verified) tables, and the next
                # scrub() re-persists it from the CKBs
                self._c_corruption.inc()
                self.events.emit(
                    "corruption", target="remix",
                    file=os.path.basename(e.file),
                    section=e.section, blocks=[], detail=e.detail,
                )
        return p

    def _replay_wal(self) -> None:
        """Rebuild the MemTable from the WAL's live log; advance seq past
        every replayed record and the WAL's durable sequence horizon."""
        self.mem = self.recover_memtable()
        for e in self.mem.data.values():
            self.seq = max(self.seq, e.seq + 1)
        self.seq = max(self.seq, self.wal.max_seq + 1)

    def _commit(self, parts) -> None:
        """Durably publish ``parts`` as the next manifest version — the
        version edge (atomic rename commit, §4.3)."""
        state = dict(
            seq=int(self.seq),
            vw=self.cfg.vw,
            d=self.cfg.d,
            partitions=[partition_entry(p) for p in parts],
            wal=self.wal.save_state(),
            unavailable=[dict(s) for s in self._unavailable],
        )
        self.storage.commit(state)

    def _gc_files(self, from_flush: bool = False) -> None:
        """Reclaim table/REMIX files no live Version references.

        The live set spans *every* pinned Version, not only the
        committed one: files superseded by a commit survive until the
        last snapshot reading them unpins (no mid-read deletion), then
        the release hook calls back here. Never interleaves with a
        flush mid-write — fresh tables (and ``.tmp`` staging files)
        belong to no Version until publish and would be collected as
        orphans: other threads block on the flush lock, and a release
        reached *from inside* the flush (same thread, via publish or a
        snapshot finalizer) defers to the collection flush() itself
        runs after publishing.
        """
        if self._in_flush and not from_flush:
            return  # fast path: flush-end gc will cover it
        # non-blocking from release hooks: a reader dropping the last pin
        # right as a flush starts must not stall for the whole compaction.
        # Skipping is safe — files are immutable orphans once unreferenced
        # and the next collection (flush end, close, open) reclaims them.
        if not self._flush_lock.acquire(blocking=from_flush):
            return
        try:
            if self._in_flush and not from_flush:
                return
            live: set[str] = set()
            for v in self.versions.live_versions():
                live |= v.file_names()
            removed = self.storage.gc_orphans(live)
            if removed:
                self.events.emit("file_gc", removed=len(removed))
        finally:
            self._flush_lock.release()

    def _on_version_release(self, version, remaining) -> None:
        """A Version's last pin dropped: fold the physical-read counters
        of tables only it referenced, then drop their files."""
        live_ids = {id(t) for v in remaining for t in v.tables()}
        retired = sum(
            t._reader.disk_bytes_read
            for t in version.tables()
            if id(t) not in live_ids and t._reader is not None
        )
        if retired:  # hooks run on whichever thread unpins
            self._c_retired_bytes.inc(retired)
        if self.device_views is not None:
            # device-side leg of the pin lifecycle: views whose partition
            # is in no live Version release their HBM with the Version
            self.device_views.retain(
                {id(p) for v in remaining for p in v.partitions}
            )
        if self.storage is not None:
            self._gc_files()

    def close(self) -> None:
        """Flush WAL buffers and, in persistent mode, commit a manifest so
        reopening needs no tail scan. The MemTable stays in the WAL."""
        if self._scrub_thread is not None:
            self._scrub_stop.set()
            self._scrub_thread.join(timeout=5.0)
            self._scrub_thread = None
        if self._ops_engine is not None:
            self._ops_engine.close()
        if self.cfg.background_compaction:
            self.wait_for_compaction()
        self.wal.sync()
        if self.storage is not None:
            self._commit(self.versions.current.partitions)
            self.wal.release_quarantine()
            self._gc_files()
        self.events.close()

    # ---------------- durability: scrub / repair / health ----------------
    def scrub(self, full: bool = True, repair: bool = True) -> dict:
        """One integrity pass over the committed state; self-heals.

        Verifies every table checksum granule, every persisted REMIX and
        manifest/CURRENT agreement against a pinned Version (concurrent
        flushes never race it). ``full=True`` runs unthrottled (the
        synchronous operator call); ``full=False`` paces reads at
        ``cfg.scrub_bytes_per_sec`` (the background loop). With
        ``repair=True`` a corrupt REMIX is rebuilt from the tables' CKBs
        (§3.4 redundancy) and committed as a new manifest version, and a
        table with unrecoverable granules is quarantined — dropped from
        the manifest with its key span recorded so reads over it degrade
        to :class:`UnavailableSpanError` instead of silently missing
        rows. Also age-purges the quarantine directory. Returns the
        :class:`~repro.db.scrub.ScrubReport` as a dict.
        """
        from repro.db.scrub import RateLimiter, scrub_version

        if self.storage is None:
            return dict(clean=True, files_checked=0, bytes_read=0,
                        findings=[], repaired=[], quarantined=[],
                        duration_s=0.0)
        limiter = RateLimiter(0 if full else self.cfg.scrub_bytes_per_sec)
        with self.snapshot() as snap:
            rep = scrub_version(self.storage, snap.version.partitions,
                                limiter)
        self._c_scrub_passes.inc()
        self._c_scrub_bytes.inc(rep.bytes_read)
        if rep.findings:
            self._c_corruption.inc(len(rep.findings))
            for f in rep.findings:
                fd = f.to_dict()
                fd["target"] = fd.pop("kind")  # "kind" is emit()'s own
                self.events.emit("corruption", **fd)
            if repair:
                self._repair(rep)
        purged = self.storage.purge_quarantine(
            self.cfg.quarantine_purge_age_s
        )
        if purged:
            self._c_quarantine_purged.inc(len(purged))
            self.events.emit("quarantine_purge", removed=len(purged))
        out = rep.to_dict()
        self._last_scrub = dict(
            clean=out["clean"],
            files_checked=out["files_checked"],
            bytes_read=out["bytes_read"],
            findings=len(rep.findings),
            repaired=len(rep.repaired),
            quarantined=len(rep.quarantined),
        )
        self.events.emit("scrub", **self._last_scrub)
        return out

    def _table_span(self, p: Partition, t: Table) -> tuple[int, int | None]:
        """Inclusive key span a quarantined table may have covered.

        Prefers the table's own first/last key (via the CKB); if those
        bytes are themselves unreadable, degrade the whole partition
        span — over-refusing is safe, silently missing rows is not.
        """
        try:
            lo = int(CK.unpack_u64(t.key_at(0)))
            hi = int(CK.unpack_u64(t.key_at(t.n - 1)))
            return lo, hi
        except Exception:
            parts = self.partitions
            idx = next(
                (i for i, q in enumerate(parts) if q is p), None
            )
            if idx is not None and idx + 1 < len(parts):
                return parts[idx].lo, parts[idx + 1].lo - 1
            return (p.lo, None)

    def _repair(self, rep) -> None:
        """Apply repairs for a scrub's findings via a manifest version
        edge (never in place): REMIX rebuild from CKBs for ``remix``
        findings, quarantine + degraded-span bookkeeping for ``table``
        findings. ``manifest`` findings are surfaced only — the manifest
        is the root of trust, there is nothing to rebuild it from.
        """
        from repro.db.scrub import rebuild_remix

        bad_tables = {
            f.file for f in rep.findings if f.kind == "table"
        }
        bad_remix = {
            os.path.basename(f.file)
            for f in rep.findings if f.kind == "remix"
        }
        if not bad_tables and not bad_remix:
            return
        with self._flush_lock:
            parts = self.versions.current.partitions
            new_parts: list[Partition] = []
            changed = False
            for p in parts:
                bad_in_p = [t for t in p.tables if t.path in bad_tables]
                remix_bad = bool(p.remix_name) and p.remix_name in bad_remix
                if not bad_in_p and not remix_bad:
                    new_parts.append(p)
                    continue
                changed = True
                for t in bad_in_p:
                    lo, hi = self._table_span(p, t)
                    nm = os.path.basename(t.path)
                    self._unavailable.append(
                        dict(lo=int(lo),
                             hi=None if hi is None else int(hi),
                             tables=[nm])
                    )
                    self._c_quarantined.inc()
                    rep.quarantined.append(nm)
                    self.events.emit("quarantine", file=nm, lo=int(lo),
                                     hi=hi if hi is None else int(hi))
                keep = [t for t in p.tables if t.path not in bad_tables]
                p2 = p.clone_with_tables(keep)
                if keep and (remix_bad or bad_in_p):
                    # rebuild the index from the surviving tables' CKBs
                    # (no value bytes read) and persist it under a fresh
                    # name — the corrupt file is never overwritten
                    remix = rebuild_remix(
                        keep, d=max(self.cfg.d, len(keep))
                    )
                    nm = self.storage.write_remix(remix)
                    p2.remix_name = nm
                    p2.preload_index(remix)
                    if remix_bad:
                        self._c_repair_remix.inc()
                        rep.repaired.append(nm)
                        self.events.emit("repair", target="remix",
                                         partition=int(p.lo), file=nm)
                new_parts.append(p2)
            if not changed:
                return
            # the version edge: commit, publish, then GC — dropped files
            # move to quarantine/ once their last pinned Version releases
            with self._write_lock:
                self._commit(new_parts)
            with self._state_lock:
                self.versions.publish(new_parts, seq_horizon=self.seq)
            self._gc_files(from_flush=True)

    def health(self) -> dict:
        """Operator-facing durability summary: degradation status, the
        unavailable key spans, quarantine backlog, and the retry /
        corruption / scrub / repair counters."""
        qdir = (
            self.storage.quarantine_dir if self.storage is not None
            else None
        )
        qfiles = (
            len(os.listdir(qdir))
            if qdir is not None and os.path.isdir(qdir) else 0
        )
        parts = self.partitions
        pl = []
        for i, p in enumerate(parts):
            p_hi = parts[i + 1].lo - 1 if i + 1 < len(parts) else None
            deg = any(
                (p_hi is None or int(s["lo"]) <= p_hi)
                and (s.get("hi") is None or p.lo <= int(s["hi"]))
                for s in self._unavailable
            )
            pl.append(dict(lo=int(p.lo), tables=len(p.tables),
                           degraded=deg))
        return dict(
            status="degraded" if self._unavailable else "ok",
            unavailable=[dict(s) for s in self._unavailable],
            quarantine_files=qfiles,
            partitions=pl,
            io=dict(retries=self._c_io_retry.value,
                    giveups=self._c_io_giveup.value),
            corruption_detected=self._c_corruption.value,
            scrub=dict(passes=self._c_scrub_passes.value,
                       bytes_read=self._c_scrub_bytes.value,
                       last=self._last_scrub),
            repair=dict(
                remix_rebuilt=self._c_repair_remix.value,
                tables_quarantined=self._c_quarantined.value,
                quarantine_purged=self._c_quarantine_purged.value,
            ),
        )

    # ---------------- operation layer (API v2) ----------------
    def engine(self):
        """This store's op-layer :class:`repro.db.executor.Executor`
        (one shard: the store itself), created on first use."""
        if self._ops_engine is None:
            with self._engine_lock:
                if self._ops_engine is None:
                    from repro.db.executor import Executor

                    self._ops_engine = Executor(
                        [(0, self)],
                        max_inflight_bytes=self.cfg.max_inflight_bytes,
                        workers=self.cfg.submit_workers,
                        registry=self.registry,
                        events=self.events,
                        trace_sample_rate=self.cfg.trace_sample_rate,
                    )
        return self._ops_engine

    def submit(self, batch, *, sync: bool = False):
        """Submit a typed op :class:`~repro.db.ops.Batch`; returns a
        future resolving to a :class:`~repro.db.ops.BatchResult`. The
        single entry point every read/write below compiles onto."""
        return self.engine().submit(batch, sync=sync)

    def _run_one(self, op: Op):
        """Wrapper helper: one-op batch, inline, unwrap or re-raise."""
        r = self.engine().submit(Batch([op]), sync=True).result().results[0]
        r.raise_if_error()
        return r

    # ---------------- write path ----------------
    def put(self, key: int, val, ttl: float | None = None) -> None:
        # eager shape/dtype validation so bad input raises here, with
        # the original exception type, not inside the executor
        val = np.asarray(val, np.uint32).reshape(self.cfg.vw)
        self._run_one(Op.put(int(key), val, ttl=ttl))

    def delete(self, key: int) -> None:
        self._run_one(Op.delete(int(key)))

    def delete_range(self, start: int, end: int) -> None:
        """Delete every key in [start, end) with one range tombstone."""
        self._run_one(Op.delete_range(int(start), int(end)))

    def cas(self, key: int, expect, val, ttl: float | None = None):
        """Compare-and-swap: install ``val`` (or delete it, when ``val``
        is None) iff the key's current value equals ``expect`` (None =
        expect-absent). Returns ``(ok, actual)`` — ``actual`` is the
        conflicting current value (None when absent) on failure."""
        r = self._run_one(Op.cas(int(key), expect, val, ttl=ttl))
        return bool(r.found), r.value

    def put_batch(self, keys, vals, ttl=None) -> None:
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32).reshape(len(keys), self.cfg.vw)
        self._run_one(Op.put(keys, vals, ttl=ttl))

    def _apply_writes(self, keys, vals, tombs, exps=None) -> None:
        """The physical write primitive: one group-committed row chunk.

        A single WAL ``append_batch`` (group commit under the configured
        ``sync_policy``) plus the MemTable apply, in row order, under the
        write lock — ``put``/``delete``/``put_batch`` are one-chunk
        special cases and a mixed op batch's write stage lands here once
        per shard. The flush trigger runs after the lock is released so
        a triggered compaction never deadlocks against the writer."""
        keys = np.asarray(keys, np.uint64)
        n = len(keys)
        if n == 0:
            return
        vals = np.asarray(vals, np.uint32).reshape(n, self.cfg.vw)
        tombs = np.asarray(tombs, bool)
        exps = (
            np.zeros(n, np.uint32) if exps is None
            else np.broadcast_to(
                np.asarray(exps, np.uint32), (n,)
            ).copy()
        )
        with self._write_lock:
            seqs = np.arange(self.seq, self.seq + n, dtype=np.uint64)
            self.wal.append_batch(keys, seqs, tombs, vals, exps=exps)
            # MemTable inserts take the state lock so concurrent readers
            # can materialize a stable view of the live overlay (cursor
            # seeks iterate it; dict iteration must not race a resize)
            with self._state_lock:
                self.seq = self.mem.put_batch(keys, vals, self.seq,
                                              tomb=tombs, exp=exps)
            self._c_user_bytes.inc(n * (8 + 4 * self.cfg.vw))
        self._maybe_flush()

    def _apply_delete_range(self, lo: int, hi: int) -> None:
        """Physical primitive for one DeleteRange op: a single WAL range
        record + the MemTable range tombstone, under the write lock."""
        lo, hi = int(lo), int(hi)
        if hi <= lo:
            return
        with self._write_lock:
            s = self.seq
            self.wal.append_range(lo, hi, s)
            with self._state_lock:
                self.mem.delete_range(lo, hi, s)
                self.seq = s + 1
            self._c_user_bytes.inc(8 + 4 * self.cfg.vw)
        self._c_delete_range.inc()
        self._maybe_flush()

    def _apply_cas(self, key: int, expect, val, exp: int = 0):
        """Physical primitive for one Cas op. Atomicity rides the write
        lock: the read of the current committed value and the conditional
        append happen with every other writer excluded. Returns
        ``(ok, actual)`` where ``actual`` is the pre-op value (None when
        absent) — reported back on conflict."""
        key = int(key)
        with self._write_lock:
            with self._view() as v:
                cur = self._get_at(v, key)
            if expect is None:
                ok = cur is None
            else:
                ok = cur is not None and np.array_equal(
                    np.asarray(cur, np.uint32).reshape(-1),
                    np.asarray(expect, np.uint32).reshape(-1),
                )
            if not ok:
                self._c_cas_conflict.inc()
                return False, cur
            tomb = val is None
            row = (
                np.zeros((1, self.cfg.vw), np.uint32)
                if tomb
                else np.asarray(val, np.uint32).reshape(1, self.cfg.vw)
            )
            seqs = np.array([self.seq], np.uint64)
            self.wal.append_batch(
                np.array([key], np.uint64), seqs, np.array([tomb]), row,
                exps=np.array([exp], np.uint32),
            )
            with self._state_lock:
                self.seq = self.mem.put_batch(
                    np.array([key], np.uint64), row, self.seq,
                    tomb=np.array([tomb]), exp=np.array([exp], np.uint32),
                )
            self._c_user_bytes.inc(8 + 4 * self.cfg.vw)
        self._maybe_flush()
        return True, cur

    def _maybe_flush(self):
        if len(self.mem) >= self.cfg.memtable_entries:
            self.flush()

    # ---------------- flush / compaction ----------------
    def flush(self) -> dict:
        """Freeze the MemTable and run one compaction round (§4.2),
        building the next Version off to the side.

        Readers are never blocked or invalidated: live partitions are
        not mutated (copy-on-write ``execute``), the manifest commit is
        the durable version edge, and only then is the new Version
        published with a pointer swap. Snapshots opened before the flush
        keep serving the old Version until they close.

        With ``background_compaction`` this returns right after the
        freeze (``{"kinds": {}, "background": True}``): the compaction +
        manifest commit + publish run on a background thread under the
        writer lock, at most one round in flight — a second flush (or
        ``close``/``wait_for_compaction``) joins the pending round
        first. Reads during the round see the frozen overlay + the old
        Version, exactly like a reader that raced a synchronous flush.
        """
        if not self.cfg.background_compaction:
            with self._flush_lock:
                return self._flush_locked()
        with self._flush_gate:
            self.wait_for_compaction()
            with self._flush_lock:
                frozen = self._freeze()
            if frozen is None:
                return dict(kinds={})
            t = threading.Thread(
                target=self._bg_compact, args=frozen, daemon=True
            )
            with self._bg_lock:
                self._bg_thread = t
            t.start()
        return dict(kinds={}, background=True)

    def wait_for_compaction(self) -> None:
        """Join the in-flight background compaction round, if any;
        re-raises its failure. No-op in synchronous mode."""
        with self._bg_lock:
            t = self._bg_thread
        if t is not None:
            t.join()
        with self._bg_lock:
            # only clear the round we joined: a concurrent flush() may
            # already have installed the next round's thread
            if self._bg_thread is t:
                self._bg_thread = None
            err, self._bg_error = self._bg_error, None
        if err is not None:
            raise err

    def _bg_compact(self, *frozen) -> None:
        try:
            with self._flush_lock:
                self._compact(*frozen)
        except BaseException as e:  # surfaced by wait_for_compaction()
            self._bg_error = e
        finally:
            with self._state_lock:
                self._flush_overlay = None
                self._flush_ranges = None
                self._in_flush = False

    def _freeze(self):
        """Swap in a fresh MemTable and install the frozen overlay; the
        start-of-flush edge shared by both flush modes. Returns the
        ``_compact`` arguments, or None when there is nothing to flush."""
        with self._state_lock:
            keys, vals, seq, tomb, counts, exp = self.mem.to_arrays()
            if len(keys) == 0 and not self.mem.ranges:
                return None
            hot = counts > self.cfg.hot_threshold
            frozen = self.mem
            # freeze edge: from here until publish, readers overlay the
            # frozen entries — pairing the old Version with the drained
            # live MemTable would make the data under compaction invisible
            self.mem = MemTable(vw=self.cfg.vw)
            self._flush_overlay = frozen.data
            self._flush_ranges = list(frozen.ranges)
            self._in_flush = True
        self.events.emit("flush", entries=int(len(keys)),
                         hot=int(hot.sum()), ranges=len(frozen.ranges))
        return (frozen, keys, vals, seq, tomb, exp, hot)

    def _flush_locked(self) -> dict:
        frozen = self._freeze()
        if frozen is None:
            return dict(kinds={})
        try:
            return self._compact(*frozen)
        finally:
            with self._state_lock:
                self._flush_overlay = None
                self._flush_ranges = None
                self._in_flush = False

    def _fold_flush_ranges(self, p: Partition, span, ranges) -> Partition:
        """Clip this flush's range tombstones to one partition and fold
        them in, returning a clone: tables falling entirely inside a
        range are dropped whole (their files are never read again), the
        remainder get an excised span pinned to the surviving tables."""
        plo, phi = span
        clipped = [
            (max(lo, plo), min(hi, phi), s)
            for lo, hi, s in ranges
            if max(lo, plo) < min(hi, phi)
        ]
        if not clipped:
            return p
        keep, dropped = [], 0
        for t in p.tables:
            if t.n and any(
                rl <= int(CK.unpack_u64(t.key_at(0)))
                and int(CK.unpack_u64(t.key_at(t.n - 1))) < rh
                for rl, rh, _ in clipped
            ):
                dropped += 1
            else:
                keep.append(t)
        base = p
        # table list unchanged: the persisted REMIX still describes the
        # clone exactly (covered rows are hidden structurally at read
        # time), so the cold-serving state survives the fold
        p = p.clone_with_tables(keep, carry_built=not dropped)
        if not dropped:
            p.remix_name = base.remix_name
        else:
            self._c_rtomb_drop.inc(dropped)
            self.events.emit("range_tombstone_drop", lo=int(p.lo),
                             tables=int(dropped))
        for rl, rh, rs in clipped:
            p.attach_excised(rl, rh, rs)
        return p

    def _compact(self, frozen, keys, vals, seq, tomb, exp, hot) -> dict:
        t_round = time.monotonic()
        # hot keys skip compaction; carried over with halved counters
        # (under the state lock: with background compaction, writers may
        # be inserting into the live MemTable concurrently)
        with self._state_lock:
            for k in np.asarray(keys[hot], np.uint64).tolist():
                self.mem.carry_over(int(k), frozen.data[int(k)])
        keys, vals, seq, tomb, exp = (
            keys[~hot], vals[~hot], seq[~hot], tomb[~hot], exp[~hot],
        )
        # route new data to partitions of the current version; range
        # tombstones frozen with this MemTable fold into per-partition
        # excised spans (on clones — published only at the version edge)
        base = self.versions.current.partitions
        spans = partition_spans([p.lo for p in base])
        pidx = route_host([p.lo for p in base], keys)
        plans: list[Plan] = []
        clones: list[Partition] = []
        for i, p in enumerate(base):
            m = pidx == i
            if frozen.ranges:
                p = self._fold_flush_ranges(p, spans[i], frozen.ranges)
            clones.append(p)
            t = Table(keys=keys[m], vals=vals[m], seq=seq[m], tomb=tomb[m],
                      exp=exp[m])
            plans.append(plan_partition(p, t, self.cfg.compaction))
        apply_abort_budget(plans, self.cfg.compaction)
        kinds: dict[str, int] = {}
        round_bytes = 0
        new_parts: list[Partition] = []
        for p, pl in zip(clones, plans):
            kinds[pl.kind] = kinds.get(pl.kind, 0) + 1
            res = execute(pl, self.cfg.compaction, storage=self.storage,
                          registry=self.registry)
            self._c_table_bytes.inc(res.bytes_written)
            round_bytes += res.bytes_written
            if res.rows_expired:
                self._c_ttl_dropped.inc(res.rows_expired)
            if res.carried is not None:  # aborted: back into the MemTable
                with self._state_lock:
                    for j in range(res.carried.n):
                        e = frozen.data[int(res.carried.keys[j])]
                        self.mem.carry_over(int(res.carried.keys[j]), e)
            if res.new_partitions is not None:
                new_parts.extend(res.new_partitions)
            else:
                new_parts.append(p)
        new_parts.sort(key=lambda p: p.lo)
        # WAL GC: only carried/hot keys (plus anything written since the
        # freeze) remain live in the log (§4.3). The write lock stalls
        # concurrent appenders for the GC + checkpoint window so no
        # record can land between the live-key snapshot and the rewrite
        # — a put that misses the snapshot would otherwise be dropped
        # from the log while only existing in the volatile MemTable.
        # In persistent mode freed blocks stay quarantined until the new
        # mapping table is committed with the manifest: a crash in between
        # must still be able to replay the previous checkpoint's blocks.
        with self._write_lock:
            with self._state_lock:
                live_keys = set(self.mem.data.keys())
                live_range_seqs = {s for _, _, s in self.mem.ranges}
            self.wal.gc(live_keys, defer_free=self.storage is not None,
                        live_range_seqs=live_range_seqs)
            self.events.emit("wal_gc", live_keys=len(live_keys),
                             used_blocks=self.wal.used_blocks())
            if self.storage is not None:
                self._commit(new_parts)  # the version edge
                self.events.emit("wal_checkpoint",
                                 blocks=self.wal.used_blocks())
        # pointer swap: readers pinning the old Version keep it alive
        # (with no pins its exclusively-owned files are reclaimed at the
        # flush-end gc below); the frozen overlay retires in the same
        # critical section so no reader pairs the new Version with it
        with self._state_lock:
            v = self.versions.publish(new_parts, seq_horizon=self.seq)
            self._flush_overlay = None
            self._flush_ranges = None
        self.events.emit("version_publish", vid=v.vid,
                         partitions=len(new_parts))
        if self.storage is not None:
            with self._write_lock:
                self.wal.release_quarantine()
            self._gc_files(from_flush=True)
        stats = dict(kinds=kinds)
        self.compaction_log.append(stats)
        self._c_comp_rounds.inc()
        self._c_comp_bytes.inc(round_bytes)
        self._comp_kinds.update(kinds)
        dt = time.monotonic() - t_round
        self._h_flush.observe(dt)
        self.events.emit("compaction", kinds=dict(kinds),
                         bytes_written=int(round_bytes),
                         duration_s=round(dt, 6))
        return stats

    # ---------------- replication / cluster ----------------
    def replication_snapshot(self, from_seq: int = 0,
                             version: int | None = None):
        """Atomically capture what a follower needs to catch up:
        ``(manifest state, live WAL records after from_seq, committed
        manifest version)``.

        When ``version`` matches the committed manifest version the
        state is returned as ``None`` and the records are the WAL tail
        past ``from_seq`` (the cheap steady-state path); otherwise the
        full committed state plus *all* live records are returned so the
        follower can adopt the new file set and rebuild its overlay.
        The write lock serializes against concurrent appends, WAL GC,
        and flush commits, so state and records are always consistent
        with each other.
        """
        if self.storage is None:
            raise RuntimeError("replication needs a persistent store "
                               "(data_dir)")
        with self._write_lock:
            cur = self.storage.manifest.current_version()
            if version is not None and int(version) == cur:
                return None, list(self.wal.read_from(from_seq)), cur
            return self.storage.load_state(), \
                list(self.wal.read_from(0)), cur

    def apply_replication(self, records, advance_to: int | None = None
                          ) -> int:
        """Apply WAL-shaped records ``(key, seq, flags, exp, val)`` from
        a primary into the MemTable, oldest first — no local WAL append
        (the primary's log is the durability root; a follower restart
        re-ships or re-catches-up). Records at or below the local seq
        horizon are skipped. ``advance_to`` bumps the horizon past
        records a span-restricted follower clipped away, so the next
        tail read does not re-fetch them. Returns the number applied."""
        n = 0
        with self._write_lock, self._state_lock:
            for k, s, fl, e, v in sorted(records, key=lambda r: int(r[1])):
                s = int(s)
                if s < self.seq:
                    continue
                if fl & FLAG_RANGE:
                    self.mem.delete_range(int(k), unpack_range_hi(v), s)
                else:
                    self.mem.put(int(k), v, s,
                                 tomb=bool(fl & FLAG_TOMB), exp=int(e))
                self.seq = s + 1
                n += 1
            if advance_to is not None:
                self.seq = max(self.seq, int(advance_to))
        return n

    def adopt_version(self, state: dict, records,
                      advance_to: int | None = None) -> None:
        """Replica catch-up across a primary flush: adopt a newer
        committed manifest ``state`` (files already fetched into this
        store's directory) and rebuild the overlay from the primary's
        live WAL ``records`` — together they are exactly the state the
        primary itself would recover to. Readers swap atomically from
        the old Version + overlay to the new pair; pinned snapshots keep
        the old one until they unpin."""
        if int(state.get("vw", self.cfg.vw)) != self.cfg.vw:
            raise ValueError("adopt_version: vw mismatch")
        parts = [self._build_partition(pe) for pe in state["partitions"]]
        if not parts:
            parts = [Partition(lo=0, d=self.cfg.d)]
        mem = MemTable(vw=self.cfg.vw)
        seq = int(state.get("seq", 1))
        for k, s, fl, e, v in sorted(records, key=lambda r: int(r[1])):
            if fl & FLAG_RANGE:
                mem.delete_range(int(k), unpack_range_hi(v), int(s))
            else:
                mem.put(int(k), v, int(s),
                        tomb=bool(fl & FLAG_TOMB), exp=int(e))
            seq = max(seq, int(s) + 1)
        if advance_to is not None:
            seq = max(seq, int(advance_to))
        with self._state_lock:
            self.seq = max(self.seq, seq)
            self.mem = mem
            self._unavailable = [
                dict(s) for s in state.get("unavailable", [])
            ]
            self.versions.publish(
                sorted(parts, key=lambda p: p.lo), seq_horizon=self.seq
            )

    def absorb_shard(self, lo: int, hi: int, state: dict, records,
                     rename=None) -> dict:
        """Merge a retired right-neighbor shard's key span [lo, hi) into
        this store (the live half of a shard merge; the neighbor's files
        were already copied into this directory, under ``rename`` when
        basenames collided).

        Under the flush + write locks: purge this store's stale entries
        in the span (leftovers from a past split — the absorbed shard
        owns the authoritative copy), GC the WAL down to the surviving
        overlay, append the neighbor's live records (their original
        seqs; ranges are disjoint so cross-store seq collisions never
        compare on the same key), adopt its partitions, and commit one
        manifest covering the union.
        """
        if self.storage is None:
            raise RuntimeError("absorb_shard needs a persistent store")
        with self._flush_lock:
            with self._write_lock:
                recs = sorted(records, key=lambda r: int(r[1]))
                with self._state_lock:
                    self.mem.purge_range(lo, hi)
                    live_keys = set(self.mem.data.keys())
                    live_range_seqs = {s for _, _, s in self.mem.ranges}
                # stale WAL records in the span must not resurface on
                # recovery: rebuild the virtual log around the purge
                self.wal.gc(live_keys, defer_free=True,
                            live_range_seqs=live_range_seqs)
                for k, s, fl, e, v in recs:
                    self.wal.append(int(k), int(s), False, v, exp=int(e),
                                    flags=int(fl))
                self.wal.sync()
                # adopt the neighbor's partitions, clamping lows into the
                # span: a store opened fresh labels its first partition
                # lo=0 even when serving [lo, hi) — its rows are still in
                # span (cluster routing), only the label moves. Partitions
                # at/above ``hi`` are stale leftovers of a split the
                # neighbor itself underwent: skipped, their data lives in
                # the shard beyond ``hi``.
                new_parts = []
                for pe in state["partitions"]:
                    if int(pe["lo"]) >= hi:
                        continue
                    pe2 = dict(partition_entry_renamed(pe, rename))
                    pe2["lo"] = max(int(pe2["lo"]), lo)
                    new_parts.append(self._build_partition(pe2))
                with self._state_lock:
                    cur = self.versions.current.partitions
                    parts = sorted(
                        [p for p in cur if not (lo <= p.lo < hi)]
                        + new_parts,
                        key=lambda p: p.lo,
                    )
                    for k, s, fl, e, v in recs:
                        if fl & FLAG_RANGE:
                            self.mem.delete_range(
                                int(k), unpack_range_hi(v), int(s)
                            )
                        else:
                            self.mem.put(int(k), v, int(s),
                                         tomb=bool(fl & FLAG_TOMB),
                                         exp=int(e))
                        self.seq = max(self.seq, int(s) + 1)
                    self.seq = max(self.seq, int(state.get("seq", 1)))
                    for s in state.get("unavailable", []):
                        se = dict(s)
                        l2, h2 = max(int(se["lo"]), lo), min(int(se["hi"]), hi)
                        if l2 < h2:
                            se["lo"], se["hi"] = l2, h2
                            self._unavailable.append(se)
                self._commit(parts)
            self.wal.release_quarantine()
            with self._state_lock:
                self.versions.publish(parts, seq_horizon=self.seq)
        self._gc_files()
        self.events.emit("shard_absorb", lo=lo, hi=min(hi, 2**64 - 1),
                         partitions=len(new_parts), records=len(recs))
        return dict(partitions=len(new_parts), records=len(recs))

    # ---------------- snapshots / cursors ----------------
    def snapshot(self) -> Snapshot:
        """A pinned, point-in-time view of the whole store: the current
        Version plus a frozen MemTable overlay. Reads through it are
        immune to concurrent flushes; close it (or use ``with``) to let
        retired versions free their tables/files. The public MVCC
        handle (§4.2's "old version remains servable").

        O(1): the overlay is a frozen layered view
        (``MemTable.snapshot_view``), not a dict copy — snapshotting a
        full MemTable costs the same as an empty one."""
        with self._state_lock:
            v = self.versions.pin_current()
            overlay = (
                self._flush_overlay
                if self._flush_overlay is not None
                else self.mem.snapshot_view()
            )
            return Snapshot(self, v, overlay, seq=self.seq, pinned=True,
                            ranges=self._live_ranges())

    @contextlib.contextmanager
    def _view(self):
        """Ephemeral *pinned* view of the live state for one read call:
        same code path as public snapshots, sharing the live overlay
        dict instead of copying it. The pin matters — without it a
        concurrent flush could release the version and delete its files
        mid-read; a Python reference keeps objects alive, not files."""
        with self._state_lock:
            v = self.versions.pin_current()
            src = (
                self._flush_overlay
                if self._flush_overlay is not None
                else self.mem.data
            )
            snap = Snapshot(self, v, src, seq=self.seq, pinned=True,
                            shared=True, ranges=self._live_ranges())
        try:
            yield snap
        finally:
            snap.close()

    def _live_ranges(self) -> tuple:
        """Unflushed range tombstones a new view must honor (call under
        ``_state_lock``): the frozen MemTable's while a flush is in
        flight (they become partition spans only at publish), else the
        live MemTable's."""
        src = (
            self._flush_ranges
            if self._flush_overlay is not None
            else self.mem.ranges
        )
        return tuple(src or ())

    def cursor(self, start: int = 0, width: int = 64) -> RemixCursor:
        """A streaming cursor (seek/peek/next/skip/next_batch, §3.2) over
        a fresh snapshot; the snapshot is released when the cursor is
        closed. Long scans seek once and stream — see
        ``benchmarks/cursor_bench.py``."""
        cur = RemixCursor(self.snapshot(), width=width, owns_snapshot=True)
        cur.seek(int(start))
        return cur

    # ---------------- read path ----------------
    def _query_mod(self):
        if self.cfg.use_kernels:
            from repro.kernels import ops

            return ops
        return Q

    def _qkw(self) -> dict:
        """Per-backend query kwargs (§Perf: binary in-group probes win on
        CPU, the vectorized all-slot compare wins on TPU). ``auto`` was
        resolved once at construction; only valid modes reach seek."""
        if self.cfg.use_kernels:
            return {}
        return dict(ingroup=self._ingroup)

    def _device_view(self, p: Partition):
        """Resident device view for a promoted partition (uploaded on
        first use), or None — disabled, over budget, or ineligible —
        in which case callers answer from the legacy jitted path."""
        if self.device_views is None:
            return None
        return self.device_views.view_for(p)

    def _cold_ok(self, p: Partition) -> bool:
        """Serve this partition via block-granular cold reads?

        True only while the recovered on-disk REMIX still matches the
        table list and the observed cold workload hasn't yet justified
        building the device RunSet (promotion)."""
        if not (
            self.cfg.cold_reads
            and self.block_cache is not None
            and p.cold_ready()
        ):
            return False
        if not p.should_promote(self.cfg.promote_fraction):
            return True
        # promotion edge: first read that tips this partition over emits
        # one lifecycle event (the flag lives on the partition so its
        # clones in later Versions don't re-emit)
        if not getattr(p, "_promotion_emitted", False):
            p._promotion_emitted = True
            self.events.emit("promotion", lo=int(p.lo),
                             tables=len(p.tables),
                             cold_gets=int(p.cold_gets),
                             cold_scans=int(p.cold_scans))
        return False

    def get(self, key: int):
        r = self._run_one(Op.get(int(key)))
        return r.value if r.found else None

    # ---- graceful degradation over quarantined spans ----
    def _check_unavailable_point(self, key: int) -> None:
        """Raise :class:`UnavailableSpanError` if ``key`` falls in a span
        whose backing table was quarantined as unrecoverable — a typed
        refusal, never a silent miss."""
        for s in self._unavailable:
            hi = s.get("hi")
            if int(s["lo"]) <= key and (hi is None or key <= int(hi)):
                raise UnavailableSpanError(
                    int(s["lo"]), hi if hi is None else int(hi),
                    tuple(s.get("tables", ())),
                )

    def _check_unavailable_scan(self, start: int) -> None:
        """Scans are refused conservatively: a scan starting at or below
        a degraded span's upper bound could silently skip its rows."""
        for s in self._unavailable:
            hi = s.get("hi")
            if hi is None or start <= int(hi):
                raise UnavailableSpanError(
                    int(s["lo"]), hi if hi is None else int(hi),
                    tuple(s.get("tables", ())),
                )

    def _get_at(self, view: Snapshot, key: int):
        e = view.overlay.get(int(key))
        if e is not None:
            return None if entry_dead(e, clock.now()) else e.val
        if view.ranges and view.covers(int(key)):
            return None  # hidden by an unflushed range tombstone
        if self._unavailable:
            self._check_unavailable_point(int(key))
        parts = view.partitions
        p = parts[route_one(parts, int(key))]
        if self._cold_ok(p):
            found, val = p.cold_get(int(key))
            return val if found else None
        dv = self._device_view(p)
        if dv is not None:
            f, v = self.device_views.get_batch(
                dv, np.array([key], np.uint64), clock.now()
            )
            return v[0] if bool(f[0]) else None
        remix, runset = p.index()
        qk = jnp.asarray(CK.pack_u64(np.array([key], np.uint64)))
        found, val = self._query_mod().get(remix, runset, qk, **self._qkw())
        return np.asarray(val)[0] if bool(np.asarray(found)[0]) else None

    def get_batch(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookups. Returns (found (Q,), vals (Q,VW))."""
        r = self._run_one(Op.multiget(keys))
        return r.found, r.vals

    def _get_batch_at(self, view: Snapshot, keys):
        keys = np.asarray(keys, np.uint64)
        found = np.zeros(len(keys), bool)
        vals = np.zeros((len(keys), self.cfg.vw), np.uint32)
        rest = []
        now = clock.now()
        for i, k in enumerate(keys.tolist()):
            e = view.overlay.get(k)
            if e is not None:
                found[i] = not entry_dead(e, now)
                vals[i] = e.val
            elif not (view.ranges and view.covers(k)):
                rest.append(i)
        parts = view.partitions
        if rest and self._unavailable:
            for i in rest:
                self._check_unavailable_point(int(keys[i]))
        if rest:
            rest = np.array(rest)
            pidx = route_host([p.lo for p in parts], keys[rest])
            for pi in np.unique(pidx):
                sel = rest[pidx == pi]
                p = parts[pi]
                if self._cold_ok(p):
                    f, v = p.cold_get_batch(keys[sel])
                    found[sel] = f
                    vals[sel[f]] = v[f]
                    continue
                dv = self._device_view(p)
                if dv is not None:
                    f, v = self.device_views.get_batch(dv, keys[sel], now)
                    found[sel] = f
                    vals[sel] = v
                    continue
                remix, runset = p.index()
                kq = keys[sel]
                pad = _pow2pad(len(kq))
                kq = np.pad(kq, (0, pad - len(kq)))
                qk = jnp.asarray(CK.pack_u64(kq))
                f, v = self._query_mod().get(remix, runset, qk, **self._qkw())
                found[sel] = np.asarray(f)[: len(sel)]
                vals[sel] = np.asarray(v)[: len(sel)]
        return found, vals

    def scan(self, start_key: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Range scan: one cursor seek + ``next_batch(n)`` over the merged
        view (partitions + MemTable overlay)."""
        r = self._run_one(Op.scan(int(start_key), int(n)))
        return r.keys, r.vals

    def _scan_at(self, view: Snapshot, start_key: int, n: int,
                 interrupt=None):
        if self._unavailable:
            self._check_unavailable_scan(int(start_key))
        cur = RemixCursor(view, width=max(8, n + n // 2),
                          interrupt=interrupt)
        cur.seek(int(start_key))
        return cur.next_batch(n)

    def scan_batch(self, starts, n: int):
        """Batched range scans (one jitted call per touched partition).

        Returns (keys (Q, n) uint64, valid (Q, n)). Queries whose range
        crosses a partition boundary fall back to the cursor path.
        """
        from repro.db.executor import scan_batch_via_ops

        return scan_batch_via_ops(self.engine(), starts, n)

    def _scan_batch_at(self, view: Snapshot, starts, n: int):
        """(keys (Q, n), valid (Q, n)) for a pinned view — the snapshot
        API's batched scan, reformatted from :meth:`_scan_group_at`."""
        starts = np.asarray(starts, np.uint64)
        q = len(starts)
        out_k = np.zeros((q, n), np.uint64)
        out_m = np.zeros((q, n), bool)
        for i, (kk, _) in enumerate(
            self._scan_group_at(view, starts, n, with_vals=False)
        ):
            kk = kk[:n]
            out_k[i, : len(kk)] = kk
            out_m[i, : len(kk)] = True
        return out_k, out_m

    def _scan_group_at(self, view: Snapshot, starts, n,
                       with_vals: bool = True, interrupts=None) -> list:
        """Vectorized group of range scans over one pinned view: the
        physical primitive behind Scan ops, ``scan_batch`` and the serve
        engine's batched scans. ``n`` may be a scalar or a (Q,) array —
        heterogeneous scan groups merge their row windows so overlapping
        scans of different lengths share granule fetches (cold path) and
        one jitted window call (promoted path).

        One jitted (or cold batched) window call per touched partition;
        per query the window is clipped to the partition span, and any
        under-full row falls back to the cursor path — the fixed window
        alone can't distinguish "partition tail reached" from "window
        swallowed by a tombstone run or a partition boundary", and the
        cursor handles both (so promotion never changes results).
        Batches over a non-empty overlay take the cursor path per query,
        like the legacy ``scan_batch`` did.

        Returns one entry per query: ``(keys (M,), vals (M, VW))`` with
        ``vals`` None when ``with_vals`` is False, or the
        :class:`~repro.db.ops.OpInterrupted` instance when that query's
        ``interrupts`` checker fired mid-scan (deadline/cancel) — the
        executor converts it to a per-op status.
        """
        starts = np.asarray(starts, np.uint64)
        q = len(starts)
        if self._unavailable:
            for s in starts.tolist():
                self._check_unavailable_scan(int(s))
        checks = interrupts if interrupts is not None else [None] * q
        ns = np.zeros(q, np.int64) + np.asarray(n, np.int64)
        empty_v = np.zeros((0, self.cfg.vw), np.uint32)
        empty_row = (np.zeros(0, np.uint64), empty_v if with_vals else None)
        out: list = [None] * q
        act = ns > 0
        for qi in np.flatnonzero(~act):
            out[qi] = empty_row
        if not act.any():
            return out

        def row_fallback(qi):
            try:
                kk, vv = self._scan_at(
                    view, int(starts[qi]), int(ns[qi]), interrupt=checks[qi]
                )
            except OpInterrupted as e:
                return e
            return kk, (vv if with_vals else None)

        # a lone scan keeps the legacy streaming profile: the cursor
        # path pipelines value/tomb blocks ahead (Fig 10, prefetch_depth)
        # — the batched window path instead coalesces across queries,
        # which only wins with > 1 scan sharing granules. Batches over a
        # non-empty overlay (entries or unflushed range tombstones)
        # merge per query through the cursor too.
        if q == 1 or view.overlay or view.ranges:
            return [
                out[qi] if out[qi] is not None else row_fallback(qi)
                for qi in range(q)
            ]
        parts = view.partitions
        spans = partition_spans([p.lo for p in parts])
        pidx = route_host([p.lo for p in parts], starts)
        widths = ns + np.maximum(8, ns // 2)
        for pi in np.unique(pidx[act]):
            sel = np.flatnonzero((pidx == pi) & act)
            p = parts[pi]
            hi = spans[pi][1]

            def emit_row(qi, kk, vv):
                nn = int(ns[qi])
                m = kk < hi  # clip to the partition's key span
                kk = kk[m][:nn]
                if len(kk) < nn:
                    out[qi] = row_fallback(qi)
                    return
                out[qi] = (kk, vv[m][:nn] if with_vals else None)

            if self._cold_ok(p):
                # per-query widths: the coalesced fetch set merges row
                # windows across different n values (shared granules)
                for qi, (kk, vv, _) in zip(
                    sel, p.cold_scan_batch(starts[sel], widths[sel])
                ):
                    emit_row(qi, kk, vv)
                continue
            # promoted: one fixed-width window call per partition (jit
            # shape-stability); max width over the group, per-query n
            # clipping keeps results bit-identical to per-n groups
            width = int(widths[sel].max())
            dv = self._device_view(p)
            if dv is not None:
                for qi, (kk, vv) in zip(
                    sel,
                    self.device_views.scan_windows(
                        dv, starts[sel], width, clock.now(),
                        with_vals=with_vals,
                    ),
                ):
                    emit_row(qi, kk, vv)
                continue
            remix, runset = p.index()
            sq = starts[sel]
            pad = _pow2pad(len(sq))
            sq = np.pad(sq, (0, pad - len(sq)))
            qk = jnp.asarray(CK.pack_u64(sq))
            kw = dict(self._qkw())
            if not self.cfg.use_kernels:
                # skip the value gather (XLA dead-code-eliminates it)
                # when the caller only needs keys, e.g. scan_batch
                kw["with_vals"] = with_vals
            keys, vals, valid, _ = self._query_mod().scan(
                remix, runset, qk, width=width, **kw
            )
            keys = CK.unpack_u64(np.asarray(keys))[: len(sel)]
            valid = np.asarray(valid)[: len(sel)]
            vals = None if vals is None else np.asarray(vals)[: len(sel)]
            for row, qi in enumerate(sel):
                v = vals[row][valid[row]] if vals is not None else None
                emit_row(qi, keys[row][valid[row]], v)
        return out

    # ---------------- stats / recovery ----------------
    def write_amplification(self) -> float:
        total = self.table_bytes_written + self.wal.bytes_written
        return total / max(1, self.user_bytes)

    def disk_bytes_read(self) -> int:
        """Physical table-file bytes read so far (cache hits excluded).

        Monotonic: counts from handles retired with their last Version
        are folded into ``_retired_disk_bytes`` on release; live counts
        span every pinned Version (tables shared between versions are
        counted once).
        """
        total = self._retired_disk_bytes
        seen: set[int] = set()
        for v in self.versions.live_versions():
            for t in v.tables():
                if id(t) in seen:
                    continue
                seen.add(id(t))
                if t._reader is not None:
                    total += t._reader.disk_bytes_read
        return total

    def stats(self) -> dict:
        """Store counters. Introspection-safe: never force-loads a lazy
        table handle (entries come from cached file headers) and never
        builds a partition index."""
        parts = self.partitions
        out = dict(
            partitions=len(parts),
            tables=sum(len(p.tables) for p in parts),
            entries=sum(p.n_entries for p in parts),
            resident_tables=sum(
                t.resident for p in parts for t in p.tables
            ),
            memtable=len(self.mem),
            wa=self.write_amplification(),
            wal_blocks=self.wal.used_blocks(),
            # all physical table-file reads, not only cold-path ones
            # (whole-table loads and rebuilds count too)
            disk_bytes_read=self.disk_bytes_read(),
            cold=dict(
                gets=sum(p.cold_gets for p in parts),
                scans=sum(p.cold_scans for p in parts),
            ),
            versions=self.versions.stats(),
            compaction=dict(
                rounds=self.compaction_totals["rounds"],
                bytes_written=self.compaction_totals["bytes_written"],
                kinds=dict(self.compaction_totals["kinds"]),
                log_rounds=len(self.compaction_log),
                in_flight=bool(self._in_flush),
            ),
        )
        out["health"] = self.health()
        if self._ops_engine is not None:
            out["engine"] = self._ops_engine.stats()
        if self.block_cache is not None:
            out["cache"] = self.block_cache.stats()
            # promotion decision inputs per cold-servable partition
            # (header-only table reads; nothing is force-loaded)
            out["cache"]["promotion"] = [
                p.promotion_inputs(self.cfg.promote_fraction)
                for p in parts
                if p.cold_ready()
            ]
        return out

    def metrics(self) -> dict:
        """One merged observability snapshot (``{"metrics": [...]}``):
        this store's registry plus any component running its own (an
        externally shared :class:`~repro.io.blockcache.BlockCache`).
        Render with :func:`repro.obs.render_prometheus`, diff with
        :func:`repro.obs.diff_snapshots` (or ``tools/obstool.py``)."""
        parts = [self.registry.snapshot()]
        bc = self.block_cache
        if bc is not None and getattr(bc, "registry", None) is not None \
                and bc.registry is not self.registry:
            parts.append(bc.registry.snapshot())
        eng = self._ops_engine
        if eng is not None and eng.registry is not self.registry:
            parts.append(eng.registry.snapshot())
        return merge_snapshots(*parts)

    def recover_memtable(self) -> MemTable:
        """Rebuild the MemTable from the WAL's live virtual log (§4.3).

        Replays in sequence order so a range tombstone re-hides exactly
        the older point entries it hid before the crash."""
        mem = MemTable(vw=self.cfg.vw)
        for k, s, fl, e, v in sorted(self.wal.replay(), key=lambda r: r[1]):
            if fl & FLAG_RANGE:
                mem.delete_range(k, unpack_range_hi(v), s)
            else:
                mem.put(k, v, s, tomb=bool(fl & FLAG_TOMB), exp=int(e))
        return mem

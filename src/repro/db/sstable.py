"""Baseline SSTable metadata: sparse block index + bloom filter (§2, §5.1).

Models LevelDB/RocksDB's per-table format: one index entry per 4 KB data
block and a 10-bits/key bloom filter. Used by the baseline stores and by the
Table-1 storage-cost benchmark.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bloom import BloomSet, build_bloom


@dataclasses.dataclass
class SSTableMeta:
    block_first_key: np.ndarray  # (B,) uint64 first key per 4 KB block
    bloom: BloomSet | None
    n: int

    @staticmethod
    def build(
        keys: np.ndarray,
        kv_bytes: int,
        block_bytes: int = 4096,
        bloom_bits: int = 10,
        with_bloom: bool = True,
    ) -> "SSTableMeta":
        from repro.core import keys as CK

        per_block = max(1, block_bytes // max(1, kv_bytes))
        firsts = keys[::per_block]
        bloom = (
            build_bloom([CK.pack_u64(keys)], bits_per_key=bloom_bits)
            if with_bloom and len(keys)
            else None
        )
        return SSTableMeta(block_first_key=firsts, bloom=bloom, n=len(keys))

    def index_bytes(self, key_bytes: int = 8, handle_bytes: int = 4) -> int:
        return len(self.block_first_key) * (key_bytes + handle_bytes)

    def bloom_bytes(self, bits_per_key: int = 10) -> int:
        return (self.n * bits_per_key + 7) // 8
